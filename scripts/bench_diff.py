#!/usr/bin/env python3
"""Diff a fresh BENCH_gk_select.json against the committed baseline.

Two classes of check, per run (keyed by algorithm x exec_mode):

* structural — rounds / data_scans / exact must match the baseline
  exactly. These are the protocol's shape (fused = 2/2, stream query =
  1/1, forced fallback = 3/3); any drift is a regression regardless of
  hardware.
* performance — band_scan_wall_s must not exceed baseline by more than
  --max-regress (default 25%) AND --min-delta-s absolute (noise floor);
  executor_utilization (threads runs) must not drop below baseline by
  more than --max-regress. Performance checks are skipped per-field when
  the baseline value sits under the calibration floor (an uncalibrated
  baseline stores 0.0 there — refresh it from the workflow artifact of a
  green run to arm them).

Exit code 0 = no regression, 1 = regression, 2 = usage/schema error.
"""

import argparse
import json
import sys


def load_runs(path):
    with open(path) as f:
        doc = json.load(f)
    runs = {}
    for run in doc.get("runs", []):
        key = (run.get("algorithm"), run.get("exec_mode"))
        runs[key] = run
    if not runs:
        print(f"error: no runs found in {path}", file=sys.stderr)
        sys.exit(2)
    return runs


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("fresh")
    ap.add_argument("--max-regress", type=float, default=0.25,
                    help="allowed relative regression (default 0.25)")
    ap.add_argument("--min-wall", type=float, default=1e-4,
                    help="baseline walls under this are uncalibrated; skip")
    ap.add_argument("--min-delta-s", type=float, default=0.002,
                    help="absolute wall-regression noise floor, seconds")
    ap.add_argument("--min-util", type=float, default=0.05,
                    help="baseline utilizations under this are skipped")
    args = ap.parse_args()

    base_runs = load_runs(args.baseline)
    fresh_runs = load_runs(args.fresh)

    failures = []
    checked = 0
    for key, base in sorted(base_runs.items()):
        name = f"{key[0]} [{key[1]}]"
        fresh = fresh_runs.get(key)
        if fresh is None:
            failures.append(f"{name}: run missing from fresh bench")
            continue

        # structural shape: must match exactly
        for field in ("rounds", "data_scans", "exact"):
            if base.get(field) != fresh.get(field):
                failures.append(
                    f"{name}: {field} changed {base.get(field)} -> {fresh.get(field)}"
                )
            checked += 1

        # band-extract scan wall clock
        bw, fw = base.get("band_scan_wall_s", 0.0), fresh.get("band_scan_wall_s", 0.0)
        if bw >= args.min_wall:
            checked += 1
            if fw > bw * (1 + args.max_regress) and fw - bw > args.min_delta_s:
                failures.append(
                    f"{name}: band_scan_wall_s {bw:.4f}s -> {fw:.4f}s "
                    f"(+{(fw / bw - 1) * 100:.0f}%, limit {args.max_regress * 100:.0f}%)"
                )
        else:
            print(f"note: {name}: baseline band_scan_wall_s uncalibrated "
                  f"({bw}); skipping wall check")

        # pool efficiency (meaningful on threads runs only)
        bu = base.get("executor_utilization", 0.0)
        fu = fresh.get("executor_utilization", 0.0)
        if key[1] == "threads" and bu >= args.min_util:
            checked += 1
            if fu < bu * (1 - args.max_regress):
                failures.append(
                    f"{name}: executor_utilization {bu:.2f} -> {fu:.2f} "
                    f"(-{(1 - fu / bu) * 100:.0f}%, limit {args.max_regress * 100:.0f}%)"
                )
        elif key[1] == "threads":
            print(f"note: {name}: baseline executor_utilization uncalibrated "
                  f"({bu}); skipping utilization check")

    for key in sorted(set(fresh_runs) - set(base_runs)):
        print(f"note: new run {key[0]} [{key[1]}] not in baseline (ok)")

    if failures:
        print(f"\n{len(failures)} perf-tracking regression(s):")
        for f in failures:
            print(f"  FAIL {f}")
        return 1
    print(f"\nperf tracking OK: {checked} checks across {len(base_runs)} runs")
    return 0


if __name__ == "__main__":
    sys.exit(main())
