#!/usr/bin/env python3
"""Diff a fresh BENCH_gk_select.json against the committed baseline.

Two classes of check, per run (keyed by algorithm x exec_mode):

* structural — rounds / data_scans / exact must match the baseline
  exactly. These are the protocol's shape (fused = 2/2, stream query =
  1/1, forced fallback = 3/3); any drift is a regression regardless of
  hardware.
* invariant — any fresh record carrying `band_efficiency` must have it
  in [0, 1], unconditionally (no baseline needed, no calibration
  floor): GK Select's band extract truncates at the 16eps*n+64 budget,
  so shipped/budget > 1.0 is a protocol bug, not a perf regression.
  `band_candidates`/`band_budget` must agree with the ratio.
* performance — band_scan_wall_s must not exceed baseline by more than
  --max-regress (default 25%) AND --min-delta-s absolute (noise floor);
  executor_utilization (threads runs) must not drop below baseline by
  more than --max-regress; simd_speedup (the simd_vs_scalar record) must
  not drop below baseline by more than --max-regress;
  fault_overhead_ratio (the fault_overhead record) must not grow past
  baseline by more than --max-regress; trace_overhead_ratio (the
  trace_overhead record — Null span sink vs a live Chrome sink) must
  not grow past baseline by more than --max-regress, pinning the
  tracing layer's disabled-path cost at ~1.0; concurrent_speedup (the
  serve_throughput records — concurrent QuantileService qps over a
  serialized single-engine baseline) must not drop below baseline by
  more than --max-regress, and serve_p99_s (tail query latency under
  concurrent load) must not grow past baseline by more than
  --max-regress with the same wall-clock noise floors. Performance
  checks are skipped per-field when the baseline value sits under the
  calibration floor (an uncalibrated baseline stores 0.0 there).

Named baselines: `--save-baseline <name>` snapshots the fresh JSON as
.bench-baselines/<name>.json (only after the diff passes, when a
baseline was resolved), and `--baseline <name>` diffs against a
previously saved snapshot instead of the positional baseline path —
so a box can pin its own calibrated walls without touching the
committed repo-root baseline.

Schema evolution: a key that exists in the fresh JSON but not in the
baseline is *not yet tracked* — reported as a note, never a failure —
so newly added record fields (e.g. `simd` / `simd_lane_width`) don't
break the perf-tracking job on the first run against an old baseline.
The reverse direction IS a failure: a tracked baseline key that the
fresh JSON silently omits means the emitter regressed.

Exit code 0 = no regression, 1 = regression, 2 = usage/schema error.

Calibration workflow (ROADMAP "Calibrate the perf-tracking baseline")
---------------------------------------------------------------------

The committed baseline pins the structural shape on day one but carries
`"calibrated": false` with zeroed walls, because wall-clock numbers are
only comparable within one runner class. To arm the 25% gates:

1. Let the CI `perf-tracking` job run green on the target runner class.
   It regenerates the JSON (`repro bench json --n 4000000`) and uploads
   it as the `BENCH_gk_select` workflow artifact.
2. Download that artifact and commit it as `BENCH_gk_select.json` at the
   repo root (optionally add `"calibrated": true` and a short note for
   provenance — the checker keys off the per-field floors, not the
   flag).
3. From then on this script enforces, per (algorithm, exec_mode) run:
   - band_scan_wall_s: fresh ≤ baseline × (1 + --max-regress), with the
     --min-delta-s absolute noise floor (floor: --min-wall);
   - executor_utilization on threads runs: fresh ≥ baseline ×
     (1 − --max-regress) (floor: --min-util);
   - simd_speedup on the simd_vs_scalar record: fresh ≥ baseline ×
     (1 − --max-regress) (floor: --min-speedup), guarding the SIMD
     tile's ≥1.5x single-thread win on AVX2 runners;
   - fault_overhead_ratio on the fault_overhead record: fresh ≤
     baseline × (1 + --max-regress) (floor: --min-ratio), guarding the
     recovery layer's armed-but-idle cost (~1.0).
   Re-calibrate (repeat 1–2) whenever the runner class or the bench
   geometry changes; walls from different hardware are not comparable.
"""

import argparse
import json
import os
import shutil
import sys


def load_runs(path):
    with open(path) as f:
        doc = json.load(f)
    runs = {}
    for run in doc.get("runs", []):
        key = (run.get("algorithm"), run.get("exec_mode"))
        runs[key] = run
    if not runs:
        print(f"error: no runs found in {path}", file=sys.stderr)
        sys.exit(2)
    return runs


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline", nargs="?",
                    help="baseline JSON path (or use --baseline <name>)")
    ap.add_argument("fresh")
    ap.add_argument("--baseline", dest="baseline_name", metavar="NAME",
                    help="diff against the saved .bench-baselines/<NAME>.json "
                         "instead of the positional baseline path")
    ap.add_argument("--save-baseline", dest="save_baseline", metavar="NAME",
                    help="snapshot the fresh JSON as "
                         ".bench-baselines/<NAME>.json (after a passing diff)")
    ap.add_argument("--baselines-dir", default=".bench-baselines",
                    help="where named baselines live (default .bench-baselines)")
    ap.add_argument("--max-regress", type=float, default=0.25,
                    help="allowed relative regression (default 0.25)")
    ap.add_argument("--min-wall", type=float, default=1e-4,
                    help="baseline walls under this are uncalibrated; skip")
    ap.add_argument("--min-delta-s", type=float, default=0.002,
                    help="absolute wall-regression noise floor, seconds")
    ap.add_argument("--min-util", type=float, default=0.05,
                    help="baseline utilizations under this are skipped")
    ap.add_argument("--min-speedup", type=float, default=1.05,
                    help="baseline simd speedups under this are skipped")
    ap.add_argument("--min-ratio", type=float, default=0.5,
                    help="baseline fault_overhead_ratios under this are "
                         "uncalibrated; skip")
    args = ap.parse_args()

    base_path = args.baseline
    if args.baseline_name:
        base_path = os.path.join(args.baselines_dir,
                                 args.baseline_name + ".json")
        if not os.path.exists(base_path):
            print(f"error: named baseline {base_path} not found "
                  f"(save one with --save-baseline {args.baseline_name})",
                  file=sys.stderr)
            return 2
    fresh_runs = load_runs(args.fresh)

    if base_path is None:
        if not args.save_baseline:
            print("error: no baseline given (positional path, --baseline "
                  "<name>, or --save-baseline <name>)", file=sys.stderr)
            return 2
        # save-only mode: nothing to diff against yet
        return save_baseline(args)
    base_runs = load_runs(base_path)

    failures = []
    checked = 0

    # structural invariant, enforced on EVERY fresh record that carries
    # the field — baseline-independent, never skipped: the band extract
    # truncates at its budget, so the ratio can never legitimately
    # exceed 1.0
    for key, fresh in sorted(fresh_runs.items()):
        if "band_efficiency" not in fresh:
            continue
        name = f"{key[0]} [{key[1]}]"
        eff = fresh["band_efficiency"]
        cand = fresh.get("band_candidates", 0)
        budget = fresh.get("band_budget", 0)
        checked += 1
        if not 0.0 <= eff <= 1.0:
            failures.append(
                f"{name}: band_efficiency {eff} outside [0, 1] — the "
                f"extract shipped past its 16eps*n+64 budget"
            )
        if cand > budget:
            failures.append(
                f"{name}: band_candidates {cand} > band_budget {budget}"
            )
        if budget:
            implied = cand / budget
            if abs(implied - eff) > 1e-9:
                failures.append(
                    f"{name}: band_efficiency {eff} disagrees with "
                    f"candidates/budget = {implied}"
                )

    for key, base in sorted(base_runs.items()):
        name = f"{key[0]} [{key[1]}]"
        fresh = fresh_runs.get(key)
        if fresh is None:
            failures.append(f"{name}: run missing from fresh bench")
            continue

        # structural shape: must match exactly where the baseline tracks
        # it; a field the baseline doesn't carry yet is a note, not a
        # failure (old baseline, new emitter)
        for field in ("rounds", "data_scans", "exact"):
            if field not in base:
                print(f"note: {name}: {field} not yet tracked by baseline; "
                      f"skipping")
                continue
            if field not in fresh:
                failures.append(
                    f"{name}: {field} missing from fresh bench "
                    f"(baseline tracks {base.get(field)})"
                )
                continue
            if base[field] != fresh[field]:
                failures.append(
                    f"{name}: {field} changed {base[field]} -> {fresh[field]}"
                )
            checked += 1

        # band-extract scan wall clock
        bw, fw = base.get("band_scan_wall_s", 0.0), fresh.get("band_scan_wall_s", 0.0)
        if bw >= args.min_wall:
            checked += 1
            if "band_scan_wall_s" not in fresh:
                failures.append(
                    f"{name}: band_scan_wall_s missing from fresh bench "
                    f"(baseline tracks {bw:.4f}s)"
                )
            elif fw > bw * (1 + args.max_regress) and fw - bw > args.min_delta_s:
                failures.append(
                    f"{name}: band_scan_wall_s {bw:.4f}s -> {fw:.4f}s "
                    f"(+{(fw / bw - 1) * 100:.0f}%, limit {args.max_regress * 100:.0f}%)"
                )
        elif "band_scan_wall_s" in base:
            print(f"note: {name}: baseline band_scan_wall_s uncalibrated "
                  f"({bw}); skipping wall check")

        # pool efficiency (meaningful on threads runs only)
        bu = base.get("executor_utilization", 0.0)
        fu = fresh.get("executor_utilization", 0.0)
        if key[1] == "threads" and bu >= args.min_util:
            checked += 1
            if fu < bu * (1 - args.max_regress):
                failures.append(
                    f"{name}: executor_utilization {bu:.2f} -> {fu:.2f} "
                    f"(-{(1 - fu / bu) * 100:.0f}%, limit {args.max_regress * 100:.0f}%)"
                )
        elif key[1] == "threads":
            print(f"note: {name}: baseline executor_utilization uncalibrated "
                  f"({bu}); skipping utilization check")

        # recovery-layer idle overhead (the fault_overhead record only):
        # an armed-but-idle FaultPlan must stay ~free, so the ratio may
        # not grow past the regression budget once calibrated
        br = base.get("fault_overhead_ratio", 0.0)
        fr = fresh.get("fault_overhead_ratio", 0.0)
        if br >= args.min_ratio:
            checked += 1
            if fr > br * (1 + args.max_regress):
                failures.append(
                    f"{name}: fault_overhead_ratio {br:.3f} -> {fr:.3f} "
                    f"(+{(fr / br - 1) * 100:.0f}%, limit {args.max_regress * 100:.0f}%)"
                )
        elif "fault_overhead_ratio" in base:
            print(f"note: {name}: baseline fault_overhead_ratio uncalibrated "
                  f"({br}); skipping overhead check")

        # span-tracing idle overhead (the trace_overhead record only):
        # the default Null sink must stay ~free next to a live Chrome
        # sink, so the ratio may not grow past the budget once calibrated
        bt = base.get("trace_overhead_ratio", 0.0)
        ft = fresh.get("trace_overhead_ratio", 0.0)
        if bt >= args.min_ratio:
            checked += 1
            if ft > bt * (1 + args.max_regress):
                failures.append(
                    f"{name}: trace_overhead_ratio {bt:.3f} -> {ft:.3f} "
                    f"(+{(ft / bt - 1) * 100:.0f}%, limit {args.max_regress * 100:.0f}%)"
                )
        elif "trace_overhead_ratio" in base:
            print(f"note: {name}: baseline trace_overhead_ratio uncalibrated "
                  f"({bt}); skipping overhead check")

        # serving-layer scaling (the serve_throughput records only):
        # concurrent qps over serialized qps must not drop past the
        # regression budget once calibrated — the concurrent service
        # losing its scaling win is a perf regression even though every
        # answer stays exact
        bss = base.get("concurrent_speedup", 0.0)
        fss = fresh.get("concurrent_speedup", 0.0)
        if bss >= args.min_speedup:
            checked += 1
            if fss < bss * (1 - args.max_regress):
                failures.append(
                    f"{name}: concurrent_speedup {bss:.2f}x -> {fss:.2f}x "
                    f"(-{(1 - fss / bss) * 100:.0f}%, limit {args.max_regress * 100:.0f}%)"
                )
        elif "concurrent_speedup" in base:
            print(f"note: {name}: baseline concurrent_speedup uncalibrated "
                  f"({bss}); skipping serve speedup check")

        # serving-layer tail latency: p99 under concurrent load may not
        # grow past the budget once calibrated (same wall-clock floors
        # as band_scan_wall_s)
        bp, fp = base.get("serve_p99_s", 0.0), fresh.get("serve_p99_s", 0.0)
        if bp >= args.min_wall:
            checked += 1
            if "serve_p99_s" not in fresh:
                failures.append(
                    f"{name}: serve_p99_s missing from fresh bench "
                    f"(baseline tracks {bp:.4f}s)"
                )
            elif fp > bp * (1 + args.max_regress) and fp - bp > args.min_delta_s:
                failures.append(
                    f"{name}: serve_p99_s {bp:.4f}s -> {fp:.4f}s "
                    f"(+{(fp / bp - 1) * 100:.0f}%, limit {args.max_regress * 100:.0f}%)"
                )
        elif "serve_p99_s" in base:
            print(f"note: {name}: baseline serve_p99_s uncalibrated "
                  f"({bp}); skipping tail-latency check")

        # SIMD tile throughput win (the simd_vs_scalar record only)
        bs = base.get("simd_speedup", 0.0)
        fs = fresh.get("simd_speedup", 0.0)
        if bs >= args.min_speedup:
            checked += 1
            if fs < bs * (1 - args.max_regress):
                failures.append(
                    f"{name}: simd_speedup {bs:.2f}x -> {fs:.2f}x "
                    f"(-{(1 - fs / bs) * 100:.0f}%, limit {args.max_regress * 100:.0f}%)"
                )
        elif "simd_speedup" in base:
            print(f"note: {name}: baseline simd_speedup uncalibrated "
                  f"({bs}); skipping speedup check")

    for key in sorted(set(fresh_runs) - set(base_runs)):
        print(f"note: new run {key[0]} [{key[1]}] not in baseline (ok)")

    if failures:
        print(f"\n{len(failures)} perf-tracking regression(s):")
        for f in failures:
            print(f"  FAIL {f}")
        if args.save_baseline:
            print(f"note: not saving baseline '{args.save_baseline}' over a "
                  f"failing diff")
        return 1
    print(f"\nperf tracking OK: {checked} checks across {len(base_runs)} runs")
    if args.save_baseline:
        return save_baseline(args)
    return 0


def save_baseline(args):
    os.makedirs(args.baselines_dir, exist_ok=True)
    dest = os.path.join(args.baselines_dir, args.save_baseline + ".json")
    shutil.copyfile(args.fresh, dest)
    print(f"saved baseline {dest}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
