#!/usr/bin/env python3
"""Repo-invariant lint: CI-gated static checks for gkselect.

Stdlib-only, in the mold of check_prom.py / check_trace.py. Each rule
enforces one invariant documented in docs/INVARIANTS.md and is cited by
rule id in every failure message:

  GK-I1  every `unsafe` site carries a SAFETY justification
  GK-I2  GKSELECT_* env reads live only in rust/src/engine/env.rs
  GK-I3  no `allow(deprecated)` outside the pinned shim suites
  GK-I4  service/ lock acquisitions follow shard -> writer -> published
         -> registry order, and never `.lock().unwrap()` (poison-unsafe)
  GK-I5  no wall-clock / nondeterminism sources in answer-bearing paths

Usage:
  scripts/lint_repo.py [--root DIR]   # lint the tree (exit 1 on violation)
  scripts/lint_repo.py --self-test    # run every rule against its own
                                      # good/bad fixtures (exit 1 on bug)

Exit codes: 0 = clean, 1 = violations (or self-test failure), 2 = usage.
"""

from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

DOC = "docs/INVARIANTS.md"

# --- shared scanning helpers -------------------------------------------------

FN_RE = re.compile(r"^\s*(?:pub(?:\([^)]*\))?\s+)?(?:async\s+)?(?:unsafe\s+)?fn\s+\w+")
CFG_TEST_RE = re.compile(r"^\s*#\[cfg\(test\)\]")


def strip_test_module(text: str) -> str:
    """Drop everything from the first `#[cfg(test)]` to EOF.

    Repo convention keeps the unit-test module at the end of each file;
    rules about runtime behavior don't apply to test bodies.
    """
    out = []
    for line in text.splitlines():
        if CFG_TEST_RE.match(line):
            break
        out.append(line)
    return "\n".join(out)


def strip_line_comment(line: str) -> str:
    """Best-effort `// ...` removal for pattern matching (not parsing)."""
    return line.split("//", 1)[0]


class Violation:
    def __init__(self, rule: str, path: str, lineno: int, message: str):
        self.rule = rule
        self.path = path
        self.lineno = lineno
        self.message = message

    def render(self) -> str:
        anchor = self.rule.lower()
        return (
            f"{self.path}:{self.lineno}: [{self.rule}] {self.message} "
            f"(see {DOC}#{anchor})"
        )


# --- GK-I1: unsafe sites carry SAFETY justifications -------------------------

UNSAFE_RE = re.compile(r"\bunsafe\b")
SAFETY_RE = re.compile(r"//\s*SAFETY:|///?\s*#\s*Safety")
COMMENT_OR_ATTR_RE = re.compile(r"^\s*(//|#\[|#!\[|\*|/\*)")


def check_unsafe_safety(path: str, text: str) -> list[Violation]:
    """Every `unsafe` keyword must be preceded by a `// SAFETY:` comment
    or a `# Safety` doc section within the contiguous run of comment /
    attribute lines directly above it."""
    violations = []
    lines = text.splitlines()
    for i, line in enumerate(lines):
        code = strip_line_comment(line)
        if not UNSAFE_RE.search(code):
            continue
        # walk the contiguous comment/attr block above, plus the line itself
        justified = bool(SAFETY_RE.search(line))
        j = i - 1
        while j >= 0 and (COMMENT_OR_ATTR_RE.match(lines[j]) or not lines[j].strip()):
            if SAFETY_RE.search(lines[j]):
                justified = True
                break
            j -= 1
        if not justified:
            violations.append(
                Violation(
                    "GK-I1",
                    path,
                    i + 1,
                    "unsafe without a `// SAFETY:` comment or `# Safety` doc "
                    "directly above",
                )
            )
    return violations


# --- GK-I2: GKSELECT_* env reads centralized in engine/env.rs ----------------

ENV_READ_RE = re.compile(r"\benv::(var|var_os)\s*\(")
ENV_ALLOWLIST = {
    "rust/src/engine/env.rs",  # the one documented read site
}
# PROPKIT_SEED is the propkit replay knob — a test-harness control, not
# engine configuration, so reading it from tests/harness code is fine.
ENV_KNOB_EXEMPT_RE = re.compile(r"PROPKIT_SEED")


def check_env_reads(path: str, text: str) -> list[Violation]:
    violations = []
    allowed = path in ENV_ALLOWLIST
    for i, line in enumerate(strip_test_module(text).splitlines()):
        code = strip_line_comment(line)
        if not ENV_READ_RE.search(code):
            continue
        if ENV_KNOB_EXEMPT_RE.search(code) and "GKSELECT" not in code:
            continue
        if "GKSELECT" in code:
            if path != "rust/src/engine/env.rs":
                violations.append(
                    Violation(
                        "GK-I2",
                        path,
                        i + 1,
                        "GKSELECT_* env read outside engine/env.rs",
                    )
                )
        elif not allowed:
            violations.append(
                Violation(
                    "GK-I2",
                    path,
                    i + 1,
                    "env::var read outside engine/env.rs (only PROPKIT_SEED "
                    "is exempt)",
                )
            )
    return violations


# --- GK-I3: allow(deprecated) only in the pinned shim suites -----------------

ALLOW_DEPRECATED_RE = re.compile(r"allow\(deprecated\)")
DEPRECATED_ALLOWLIST = {
    # the bit-identity pinning suites for the #[deprecated] shim surface
    "rust/tests/proptest_engine.rs",
    "rust/tests/integration_runtime.rs",
}


def check_allow_deprecated(path: str, text: str) -> list[Violation]:
    if path in DEPRECATED_ALLOWLIST:
        return []
    violations = []
    for i, line in enumerate(text.splitlines()):
        if ALLOW_DEPRECATED_RE.search(strip_line_comment(line)):
            violations.append(
                Violation(
                    "GK-I3",
                    path,
                    i + 1,
                    "allow(deprecated) outside the pinned shim suites",
                )
            )
    return violations


# --- GK-I4: service/ lock discipline -----------------------------------------

# Acquisition sites, in documented order. A function body must acquire
# in non-decreasing level order (shard directory -> writer token ->
# published pointer -> metrics registry).
LOCK_LEVELS = [
    (0, "shard directory", re.compile(r"\.streams\)")),
    (1, "writer token", re.compile(r"lock_writer\(|\.writer\.try_lock|relock\(&self\.writer")),
    (2, "published pointer", re.compile(r"relock\(&self\.published")),
    (3, "metrics registry", re.compile(r"\.registry\.lock\(|relock\(&self\.registry")),
]
LOCK_UNWRAP_RE = re.compile(r"\.lock\(\)\s*\.unwrap\(\)")


def check_service_lock_order(path: str, text: str) -> list[Violation]:
    violations = []
    current_fn = "<module>"
    level = -1
    for i, line in enumerate(strip_test_module(text).splitlines()):
        code = strip_line_comment(line)
        if FN_RE.match(code):
            current_fn = code.strip()
            level = -1
        if LOCK_UNWRAP_RE.search(code):
            violations.append(
                Violation(
                    "GK-I4",
                    path,
                    i + 1,
                    "poison-unsafe `.lock().unwrap()` in service/ — use "
                    "relock / unwrap_or_else(|e| e.into_inner())",
                )
            )
        for lvl, name, pattern in LOCK_LEVELS:
            if pattern.search(code):
                if lvl < level:
                    violations.append(
                        Violation(
                            "GK-I4",
                            path,
                            i + 1,
                            f"{name} (level {lvl}) acquired after a "
                            f"level-{level} lock in `{current_fn}` — order "
                            "is shard -> writer -> published -> registry",
                        )
                    )
                level = max(level, lvl)
    return violations


# --- GK-I5: no wall-clock / nondeterminism in answer-bearing paths -----------

# Modules whose code derives the answer (quantile values, rank bounds,
# band classification, snapshots). The cluster substrate and obs layer
# measure wall time for *reports*; that never feeds an answer and is
# deliberately out of scope here.
ANSWER_BEARING_DIRS = (
    "rust/src/algorithms/",
    "rust/src/select/",
    "rust/src/sketch/",
    "rust/src/sort/",
    "rust/src/stream/",
    "rust/src/service/",
    "rust/src/data/",
    "rust/src/engine/",
)
ANSWER_BEARING_FILES = {
    "rust/src/lib.rs",
    "rust/src/runtime/simd.rs",  # the band kernel is the answer path
    "rust/src/runtime/kernels.rs",
}
NONDETERMINISM = [
    (re.compile(r"Instant::now"), "wall clock (Instant::now)"),
    (re.compile(r"SystemTime"), "wall clock (SystemTime)"),
    (re.compile(r"\bHashMap\b|\bHashSet\b"), "unordered hash collection (RandomState)"),
    (re.compile(r"thread_rng|rand::random"), "ambient RNG"),
]


def is_answer_bearing(path: str) -> bool:
    return path in ANSWER_BEARING_FILES or path.startswith(ANSWER_BEARING_DIRS)


def check_answer_path_determinism(path: str, text: str) -> list[Violation]:
    if not is_answer_bearing(path):
        return []
    violations = []
    for i, line in enumerate(strip_test_module(text).splitlines()):
        code = strip_line_comment(line)
        for pattern, what in NONDETERMINISM:
            if pattern.search(code):
                violations.append(
                    Violation(
                        "GK-I5",
                        path,
                        i + 1,
                        f"{what} in an answer-bearing module — answers must "
                        "be deterministic functions of (data, config, seed)",
                    )
                )
    return violations


# --- driver ------------------------------------------------------------------

ALL_CHECKS = [
    ("GK-I1", check_unsafe_safety),
    ("GK-I2", check_env_reads),
    ("GK-I3", check_allow_deprecated),
    ("GK-I4", check_service_lock_order),
    ("GK-I5", check_answer_path_determinism),
]


def lint_tree(root: Path) -> list[Violation]:
    violations: list[Violation] = []
    for base in ("rust/src", "rust/tests"):
        for f in sorted((root / base).rglob("*.rs")):
            rel = f.relative_to(root).as_posix()
            text = f.read_text(encoding="utf-8")
            for rule, check in ALL_CHECKS:
                if rule == "GK-I4" and not rel.startswith("rust/src/service/"):
                    continue
                violations.extend(check(rel, text))
    return violations


# --- self-test fixtures: every rule exercised both ways ----------------------

FIXTURES = [
    # (rule, path-the-fixture-pretends-to-be, source, expected violations)
    (
        "GK-I1",
        "rust/src/x.rs",
        "// SAFETY: lock held for the whole call\nunsafe impl Send for X {}\n",
        0,
    ),
    (
        "GK-I1",
        "rust/src/x.rs",
        "/// # Safety\n/// caller checked avx2\n#[target_feature(enable = \"avx2\")]\nunsafe fn f() {}\n",
        0,
    ),
    ("GK-I1", "rust/src/x.rs", "fn f() {\n    unsafe { g() }\n}\n", 1),
    (
        "GK-I2",
        "rust/src/engine/env.rs",
        'let v = std::env::var("GKSELECT_SIMD");\n',
        0,
    ),
    (
        "GK-I2",
        "rust/src/stream/store.rs",
        'let v = std::env::var("GKSELECT_SIMD");\n',
        1,
    ),
    ("GK-I2", "rust/src/stream/store.rs", 'let v = std::env::var("HOME");\n', 1),
    (
        "GK-I2",
        "rust/tests/proptest_gk_select.rs",
        'if std::env::var("PROPKIT_SEED").is_err() {\n',
        0,
    ),
    (
        "GK-I3",
        "rust/tests/proptest_engine.rs",
        "#![allow(deprecated)]\n",
        0,
    ),
    ("GK-I3", "rust/src/engine/mod.rs", "#[allow(deprecated)]\nfn f() {}\n", 1),
    (
        "GK-I4",
        "rust/src/service/shard.rs",
        "fn ok(&self) {\n"
        "    let map = relock(&self.shard(stream).streams);\n"
        "    let w = entry.lock_writer();\n"
        "    let p = relock(&self.published);\n"
        "    let r = self.registry.lock().unwrap_or_else(|e| e.into_inner());\n"
        "}\n",
        0,
    ),
    (
        "GK-I4",
        "rust/src/service/shard.rs",
        "fn inverted(&self) {\n"
        "    let r = self.registry.lock().unwrap_or_else(|e| e.into_inner());\n"
        "    let w = entry.lock_writer();\n"
        "}\n",
        1,
    ),
    (
        "GK-I4",
        "rust/src/service/mod.rs",
        "fn poison_unsafe(&self) {\n    let r = self.registry.lock().unwrap();\n}\n",
        1,
    ),
    (
        "GK-I4",
        "rust/src/service/mod.rs",
        "fn fresh_per_fn(&self) {\n    let p = relock(&self.published);\n}\n"
        "fn other(&self) {\n    let w = entry.lock_writer();\n}\n",
        0,
    ),
    (
        "GK-I5",
        "rust/src/sketch/mod.rs",
        "fn f() {\n    let t = Instant::now();\n}\n",
        1,
    ),
    (
        "GK-I5",
        "rust/src/sketch/mod.rs",
        "fn f() {\n    let m = std::collections::BTreeMap::new();\n}\n"
        "#[cfg(test)]\nmod tests {\n    fn t() { let m = std::collections::HashMap::new(); }\n}\n",
        0,
    ),
    (
        "GK-I5",
        "rust/src/cluster/pool.rs",
        "fn f() {\n    let t = Instant::now(); // substrate timing: out of scope\n}\n",
        0,
    ),
]


def self_test() -> int:
    checks = dict(ALL_CHECKS)
    failures = 0
    rules_hit_bad = set()
    for rule, path, source, expected in FIXTURES:
        got = checks[rule](path, source)
        if rule == "GK-I5" and not is_answer_bearing(path):
            pass  # fixture exercises the scope boundary itself
        if len(got) != expected:
            failures += 1
            print(
                f"FAIL: self-test fixture for {rule} on {path}: expected "
                f"{expected} violation(s), got {len(got)}: "
                f"{[v.render() for v in got]}",
                file=sys.stderr,
            )
        if expected:
            rules_hit_bad.add(rule)
            for v in got:
                if v.rule != rule:
                    failures += 1
                    print(f"FAIL: fixture for {rule} reported {v.rule}", file=sys.stderr)
                if DOC not in v.render():
                    failures += 1
                    print(f"FAIL: {rule} message must cite {DOC}", file=sys.stderr)
    missing = {rule for rule, _ in ALL_CHECKS} - rules_hit_bad
    if missing:
        failures += 1
        print(f"FAIL: rules with no failing fixture: {sorted(missing)}", file=sys.stderr)
    if failures:
        return 1
    print(f"self-test OK: {len(FIXTURES)} fixtures across {len(ALL_CHECKS)} rules")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--root",
        type=Path,
        default=Path(__file__).resolve().parent.parent,
        help="repo root (default: the script's repo)",
    )
    ap.add_argument(
        "--self-test",
        action="store_true",
        help="run the linter's own good/bad fixtures instead of the tree",
    )
    args = ap.parse_args()

    if args.self_test:
        return self_test()

    if not (args.root / "rust" / "src").is_dir():
        print(f"FAIL: {args.root} does not look like the repo root", file=sys.stderr)
        return 2

    violations = lint_tree(args.root)
    for v in violations:
        print(v.render(), file=sys.stderr)
    if violations:
        print(f"{len(violations)} invariant violation(s); see {DOC}", file=sys.stderr)
        return 1
    print("lint_repo OK: GK-I1..GK-I5 hold across rust/src and rust/tests")
    return 0


if __name__ == "__main__":
    sys.exit(main())
