#!/usr/bin/env python3
"""Validate a Chrome-trace file emitted by `repro trace` / TraceSink::Chrome.

Checks the contract the CI `trace` job pins (stdlib only, exit 0/1/2):

* the file is valid JSON with a non-empty `traceEvents` array;
* every event is a complete-span record: `ph` == "X", string `name`
  and `cat`, numeric `ts` / `dur` (µs, dur >= 0), integer `pid` /
  `tid`, and an `args` object carrying `span_id` (> 0);
* `cat` is one of the span kinds the tracer emits;
* within each pid (one drained trace per pid) span ids are unique and
  every non-zero `parent_id` resolves to an earlier-opened span id in
  the same pid — the tree property `repro trace` promises;
* each pid has at least one root (parent_id 0);
* attempt spans (`cat` == "attempt") carry `partition`, `attempt` and
  an `outcome` drawn from the attempt-outcome vocabulary.

Usage: check_trace.py trace.json [--expect-attempts] [--expect-roots N]
       [--expect-outcome KIND ...]

`--expect-attempts` additionally requires at least one attempt span;
`--expect-roots N` pins the root-span count (batch workload = 1 query
root); `--expect-outcome KIND` (repeatable) requires at least one
attempt span with that outcome — the chaos workload must show `panic`
(a retried attempt) and `speculative-win` (a straggler mitigation).
"""

import argparse
import json
import sys

SPAN_KINDS = {"query", "stream-query", "ingest", "stage", "reduce", "attempt"}
ATTEMPT_OUTCOMES = {
    "ok",
    "panic",
    "transient",
    "lost",
    "speculative-win",
    "speculative-loss",
}


def fail(msg):
    print(f"FAIL: {msg}", file=sys.stderr)
    return 1


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("trace")
    ap.add_argument("--expect-attempts", action="store_true",
                    help="require at least one attempt span (chaos runs)")
    ap.add_argument("--expect-roots", type=int, default=None,
                    help="pin the total root-span count across all pids")
    ap.add_argument("--expect-outcome", action="append", default=[],
                    metavar="KIND", choices=sorted(ATTEMPT_OUTCOMES),
                    help="require at least one attempt span with this "
                         "outcome (repeatable)")
    args = ap.parse_args()

    try:
        with open(args.trace) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"error: cannot read {args.trace}: {e}", file=sys.stderr)
        return 2

    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        return fail("traceEvents missing or empty")

    seen = {}  # pid -> set of span ids opened so far (events are in order)
    roots = 0
    attempts = 0
    outcomes_seen = set()
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            return fail(f"{where}: not an object")
        if ev.get("ph") != "X":
            return fail(f"{where}: ph is {ev.get('ph')!r}, want 'X'")
        for key in ("name", "cat"):
            if not isinstance(ev.get(key), str) or not ev[key]:
                return fail(f"{where}: missing string {key}")
        if ev["cat"] not in SPAN_KINDS:
            return fail(f"{where}: unknown span kind {ev['cat']!r}")
        for key in ("ts", "dur"):
            if not isinstance(ev.get(key), (int, float)):
                return fail(f"{where}: missing numeric {key}")
        if ev["dur"] < 0:
            return fail(f"{where}: negative dur {ev['dur']}")
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), int):
                return fail(f"{where}: missing integer {key}")
        span_args = ev.get("args")
        if not isinstance(span_args, dict):
            return fail(f"{where}: missing args object")
        sid = span_args.get("span_id")
        if not isinstance(sid, int) or sid <= 0:
            return fail(f"{where}: args.span_id missing or not a positive int")
        parent = span_args.get("parent_id", 0)
        if not isinstance(parent, int) or parent < 0:
            return fail(f"{where}: args.parent_id must be a non-negative int")

        ids = seen.setdefault(ev["pid"], set())
        if sid in ids:
            return fail(f"{where}: duplicate span id {sid} in pid {ev['pid']}")
        if parent == 0:
            roots += 1
        elif parent not in ids:
            return fail(
                f"{where}: parent_id {parent} does not resolve to an "
                f"earlier span in pid {ev['pid']}"
            )
        ids.add(sid)

        if ev["cat"] == "attempt":
            attempts += 1
            outcome = span_args.get("outcome")
            if outcome not in ATTEMPT_OUTCOMES:
                return fail(f"{where}: attempt outcome {outcome!r} not in "
                            f"{sorted(ATTEMPT_OUTCOMES)}")
            outcomes_seen.add(outcome)
            for key in ("partition", "attempt"):
                if not isinstance(span_args.get(key), int):
                    return fail(f"{where}: attempt span missing integer {key}")

    if roots == 0:
        return fail("no root spans (parent_id 0) anywhere in the trace")
    if args.expect_roots is not None and roots != args.expect_roots:
        return fail(f"root-span count {roots}, expected {args.expect_roots}")
    if args.expect_attempts and attempts == 0:
        return fail("expected attempt spans, found none")
    for kind in args.expect_outcome:
        if kind not in outcomes_seen:
            return fail(f"expected an attempt span with outcome {kind!r}; "
                        f"saw {sorted(outcomes_seen)}")

    print(
        f"trace OK: {len(events)} spans, {len(seen)} trace(s), "
        f"{roots} root(s), {attempts} attempt span(s)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
