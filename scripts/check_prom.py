#!/usr/bin/env python3
"""Validate Prometheus text-exposition scrapes emitted by `repro metrics`.

Checks the contract the CI `metrics` job pins (stdlib only, exit 0/1/2):

* every series line parses as `name{labels} value` with a legal metric
  name, legal label names, and properly quoted/escaped label values;
* every series belongs to a family that declared `# HELP` and `# TYPE`
  *before* its first sample, and each family is declared exactly once;
* operation series carry the four standard labels (`kind`, `stream`,
  `exec_mode`, `simd`); store gauges carry the three stream-scoped ones;
* every `gkselect_band_efficiency_ratio` sample is in [0, 1] — the
  paper's no-full-shuffle claim (extracts truncate at the 16eps*n+64
  budget, so shipped/budget can never exceed 1);
* with a second scrape of the same engine taken later, every series
  whose family TYPE is `counter` is monotone non-decreasing from the
  first scrape to the second, and no counter series disappears.

Usage: check_prom.py final.prom [--earlier early.prom]
       [--expect-kind KIND ...] [--expect-stream ID ...]

`--expect-kind` (repeatable) requires at least one `gkselect_ops_total`
series with that `kind` label; `--expect-stream` requires a store
residency gauge for that stream id.
"""

import argparse
import re
import sys

NAME_RE = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
LABEL_RE = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
OP_LABELS = {"kind", "stream", "exec_mode", "simd"}
STORE_LABELS = {"stream", "exec_mode", "simd"}
KINDS = {"batch", "stream", "ingest", "sketched", "degraded"}


def fail(msg):
    print(f"FAIL: {msg}", file=sys.stderr)
    return 1


class BadScrape(Exception):
    pass


def parse_labels(body, where):
    """Parse the `k="v",...` body of a label set, honouring \\ escapes."""
    labels = {}
    i = 0
    while i < len(body):
        eq = body.find("=", i)
        if eq < 0:
            raise BadScrape(f"{where}: missing '=' in label set {body!r}")
        name = body[i:eq]
        if not LABEL_RE.match(name):
            raise BadScrape(f"{where}: bad label name {name!r}")
        if eq + 1 >= len(body) or body[eq + 1] != '"':
            raise BadScrape(f"{where}: label {name} value not quoted")
        j = eq + 2
        value = []
        while j < len(body):
            c = body[j]
            if c == "\\":
                if j + 1 >= len(body) or body[j + 1] not in '\\"n':
                    raise BadScrape(f"{where}: bad escape in label {name}")
                value.append({"n": "\n"}.get(body[j + 1], body[j + 1]))
                j += 2
            elif c == '"':
                break
            else:
                value.append(c)
                j += 1
        else:
            raise BadScrape(f"{where}: unterminated value for label {name}")
        if name in labels:
            raise BadScrape(f"{where}: duplicate label {name}")
        labels[name] = "".join(value)
        i = j + 1
        if i < len(body):
            if body[i] != ",":
                raise BadScrape(f"{where}: expected ',' after label {name}")
            i += 1
    return labels


def parse_scrape(path):
    """Return (types, helps, series) where series maps
    (name, sorted-label-items) -> float value."""
    types, helps, series = {}, {}, {}
    with open(path) as f:
        lines = f.read().splitlines()
    for lineno, line in enumerate(lines, 1):
        where = f"{path}:{lineno}"
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            parts = line.split(" ", 3)
            if len(parts) < 4 or not NAME_RE.match(parts[2]):
                raise BadScrape(f"{where}: malformed HELP line")
            if parts[2] in helps:
                raise BadScrape(f"{where}: duplicate HELP for {parts[2]}")
            helps[parts[2]] = parts[3]
            continue
        if line.startswith("# TYPE "):
            parts = line.split(" ")
            if len(parts) != 4 or not NAME_RE.match(parts[2]):
                raise BadScrape(f"{where}: malformed TYPE line")
            if parts[3] not in ("counter", "gauge", "histogram", "summary",
                                "untyped"):
                raise BadScrape(f"{where}: unknown TYPE {parts[3]!r}")
            if parts[2] in types:
                raise BadScrape(f"{where}: duplicate TYPE for {parts[2]}")
            types[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue  # comments are legal
        m = re.match(r"^([a-zA-Z_:][a-zA-Z0-9_:]*)\{(.*)\}\s+(\S+)$", line)
        if not m:
            raise BadScrape(f"{where}: unparseable series line {line!r}")
        name, body, raw = m.groups()
        if name not in types or name not in helps:
            raise BadScrape(f"{where}: series {name} has no TYPE/HELP above it")
        labels = parse_labels(body, where)
        try:
            value = float(raw)
        except ValueError:
            raise BadScrape(f"{where}: non-numeric value {raw!r}")
        if name.startswith("gkselect_store_"):
            want = STORE_LABELS
        else:
            want = OP_LABELS | ({"ledger"} if name == "gkselect_bytes_total"
                                else set())
            want = want | ({"quantile"}
                           if name == "gkselect_task_latency_us" else set())
        if set(labels) != want:
            raise BadScrape(
                f"{where}: {name} labels {sorted(labels)} != {sorted(want)}")
        if "kind" in labels and labels["kind"] not in KINDS:
            raise BadScrape(f"{where}: unknown kind {labels['kind']!r}")
        key = (name, tuple(sorted(labels.items())))
        if key in series:
            raise BadScrape(f"{where}: duplicate series {key}")
        series[key] = value
    if not series:
        raise BadScrape(f"{path}: no series at all")
    return types, helps, series


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("scrape", help="the (final) scrape to validate")
    ap.add_argument("--earlier", default=None,
                    help="an earlier scrape of the same engine: counters "
                         "must be monotone non-decreasing earlier -> final")
    ap.add_argument("--expect-kind", action="append", default=[],
                    metavar="KIND", choices=sorted(KINDS),
                    help="require a gkselect_ops_total series with this "
                         "kind label (repeatable)")
    ap.add_argument("--expect-stream", action="append", default=[],
                    metavar="ID",
                    help="require store gauges for this stream (repeatable)")
    args = ap.parse_args()

    try:
        types, _, series = parse_scrape(args.scrape)
    except OSError as e:
        print(f"error: cannot read {args.scrape}: {e}", file=sys.stderr)
        return 2
    except BadScrape as e:
        return fail(str(e))

    for (name, labels), value in series.items():
        if name == "gkselect_band_efficiency_ratio" and not 0 <= value <= 1:
            return fail(f"{name}{dict(labels)} = {value}, must be in [0, 1]")
        if types.get(name) == "counter" and value < 0:
            return fail(f"counter {name}{dict(labels)} is negative: {value}")

    kinds_seen = {dict(labels)["kind"] for (name, labels) in series
                  if name == "gkselect_ops_total"}
    for kind in args.expect_kind:
        if kind not in kinds_seen:
            return fail(f"no gkselect_ops_total series with kind={kind!r}; "
                        f"saw {sorted(kinds_seen)}")
    streams_seen = {dict(labels)["stream"] for (name, labels) in series
                    if name.startswith("gkselect_store_")}
    for stream in args.expect_stream:
        if stream not in streams_seen:
            return fail(f"no store gauges for stream {stream!r}; "
                        f"saw {sorted(streams_seen)}")

    monotone_checked = 0
    if args.earlier:
        try:
            early_types, _, early = parse_scrape(args.earlier)
        except OSError as e:
            print(f"error: cannot read {args.earlier}: {e}", file=sys.stderr)
            return 2
        except BadScrape as e:
            return fail(str(e))
        for key, before in early.items():
            name = key[0]
            # the earlier scrape's TYPE decides: a counter family that
            # disappears entirely is as wrong as one that rewinds
            if early_types.get(name) != "counter":
                continue
            after = series.get(key)
            if after is None:
                return fail(f"counter series {key} vanished between scrapes")
            if after < before:
                return fail(f"counter {key} went backwards: "
                            f"{before} -> {after}")
            monotone_checked += 1
        if monotone_checked == 0:
            return fail("earlier scrape shares no counter series with final")

    print(f"prom OK: {len(series)} series, {len(types)} families, "
          f"kinds {sorted(kinds_seen)}, "
          f"{monotone_checked} counters monotone")
    return 0


if __name__ == "__main__":
    sys.exit(main())
