//! ε ablation (E9, §V-6): how sketch precision trades pivot quality
//! against candidate volume inside GK Select. Paper-scale sweep with the
//! modelled fabric: `repro bench ablation`. Every run routes through
//! `QuantileEngine::execute`.

use gkselect::config::ReproConfig;
use gkselect::data::Distribution;
use gkselect::engine::{QuantileQuery, Source};
use gkselect::harness::{engine_for, make_cluster, AlgoChoice};
use gkselect::util::benchkit::Bench;

fn main() {
    let bench = Bench::new("ablation_epsilon").samples(10);
    let n = 1_000_000u64;
    for eps in [0.05, 0.01, 0.001] {
        let mut cfg = ReproConfig::default();
        cfg.algorithm.epsilon = eps;
        let mut cluster = make_cluster(&cfg, 10);
        let data = Distribution::Uniform
            .generator(cfg.algorithm.seed)
            .generate(&mut cluster, n);
        let mut engine = engine_for(&cfg, AlgoChoice::GkSelect, 10).unwrap();
        bench.run(&format!("gk_select/eps{eps}"), || {
            engine
                .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
                .expect("quantile run")
                .value()
        });
        // observable trade-off: candidate traffic vs eps
        let out = engine
            .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
            .unwrap();
        println!(
            "bench ablation_epsilon/eps{eps}/driver_bytes      {}",
            out.report.bytes_to_driver
        );
    }
}
