//! Figs. 3–4 — GK Select runtime across the four input distributions at
//! the 50th and 99th percentiles. Paper-scale CIs:
//! `repro bench dist --n 1e8` / `--n 1e9` (EXPERIMENTS.md E3/E4).
//! Every run routes through `QuantileEngine::execute`.

use gkselect::config::ReproConfig;
use gkselect::data::Distribution;
use gkselect::engine::{QuantileQuery, Source};
use gkselect::harness::{engine_for, make_cluster, AlgoChoice};
use gkselect::util::benchkit::Bench;

fn main() {
    let cfg = ReproConfig::default();
    let bench = Bench::new("fig3_distributions").samples(10);
    let n = 500_000u64;
    for dist in [
        Distribution::Uniform,
        Distribution::Zipf,
        Distribution::Bimodal,
        Distribution::Sorted,
    ] {
        let mut cluster = make_cluster(&cfg, 10);
        let data = dist.generator(cfg.algorithm.seed).generate(&mut cluster, n);
        for (qlabel, q) in [("q50", 0.5), ("q99", 0.99)] {
            let mut engine = engine_for(&cfg, AlgoChoice::GkSelect, 10).unwrap();
            bench.run(&format!("{}_{qlabel}/n{n}", dist.label()), || {
                engine
                    .execute(Source::Dataset(&data), QuantileQuery::Single(q))
                    .expect("quantile run")
                    .value()
            });
        }
    }
}
