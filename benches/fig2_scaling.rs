//! Fig. 2 — runtime vs n at 30 core nodes (120 partitions).
//!
//! Paper-scale: `repro bench fig --nodes 30` (EXPERIMENTS.md E2); the
//! headline ≈10.5× sort gap is read off the large-n rows of that sweep.

use gkselect::config::ReproConfig;
use gkselect::data::Distribution;
use gkselect::harness::{build_algorithm, make_cluster, AlgoChoice};
use gkselect::util::benchkit::Bench;

fn main() {
    let cfg = ReproConfig::default();
    let nodes = 30;
    let bench = Bench::new("fig2_30nodes").samples(10);
    let n = 1_000_000u64;
    let mut cluster = make_cluster(&cfg, nodes);
    let data = Distribution::Uniform
        .generator(cfg.algorithm.seed)
        .generate(&mut cluster, n);
    for choice in AlgoChoice::PAPER_SET {
        let mut alg = build_algorithm(&cfg, choice).unwrap();
        bench.run(&format!("{}/n{n}", choice.label().replace(' ', "_")), || {
            alg.quantile(&mut cluster, &data, 0.5)
                .expect("quantile run")
                .value
        });
    }

    // modelled-time headline at bench scale: GK Select vs Full Sort
    let mut gk = build_algorithm(&cfg, AlgoChoice::GkSelect).unwrap();
    let mut fs = build_algorithm(&cfg, AlgoChoice::FullSort).unwrap();
    let t_gk = gk.quantile(&mut cluster, &data, 0.5).unwrap().report.elapsed_secs;
    let t_fs = fs.quantile(&mut cluster, &data, 0.5).unwrap().report.elapsed_secs;
    println!(
        "bench fig2_30nodes/headline_speedup_model        {:.2}x (full sort / gk select, n={n})",
        t_fs / t_gk
    );
}
