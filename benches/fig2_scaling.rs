//! Fig. 2 — runtime vs n at 30 core nodes (120 partitions).
//!
//! Paper-scale: `repro bench fig --nodes 30` (EXPERIMENTS.md E2); the
//! headline ≈10.5× sort gap is read off the large-n rows of that sweep.
//! Every run routes through `QuantileEngine::execute`.

use gkselect::config::ReproConfig;
use gkselect::data::Distribution;
use gkselect::engine::{QuantileQuery, Source};
use gkselect::harness::{engine_for, make_cluster, AlgoChoice};
use gkselect::util::benchkit::Bench;

fn main() {
    let cfg = ReproConfig::default();
    let nodes = 30;
    let bench = Bench::new("fig2_30nodes").samples(10);
    let n = 1_000_000u64;
    let mut cluster = make_cluster(&cfg, nodes);
    let data = Distribution::Uniform
        .generator(cfg.algorithm.seed)
        .generate(&mut cluster, n);
    for choice in AlgoChoice::PAPER_SET {
        let mut engine = engine_for(&cfg, choice, nodes).unwrap();
        bench.run(&format!("{}/n{n}", choice.label().replace(' ', "_")), || {
            engine
                .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
                .expect("quantile run")
                .value()
        });
    }

    // modelled-time headline at bench scale: GK Select vs Full Sort
    let mut gk = engine_for(&cfg, AlgoChoice::GkSelect, nodes).unwrap();
    let mut fs = engine_for(&cfg, AlgoChoice::FullSort, nodes).unwrap();
    let t_gk = gk
        .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
        .unwrap()
        .report
        .elapsed_secs;
    let t_fs = fs
        .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
        .unwrap()
        .report
        .elapsed_secs;
    println!(
        "bench fig2_30nodes/headline_speedup_model        {:.2}x (full sort / gk select, n={n})",
        t_fs / t_gk
    );
}
