//! Table IV — wall-clock growth of each algorithm across a doubling-n
//! ladder. The fitted scaling exponents are printed by
//! `repro bench table4` (EXPERIMENTS.md E5). Every run routes through
//! `QuantileEngine::execute`.

use gkselect::config::ReproConfig;
use gkselect::data::Distribution;
use gkselect::engine::{QuantileQuery, Source};
use gkselect::harness::{engine_for, make_cluster, stats, AlgoChoice};
use gkselect::util::benchkit::Bench;

fn main() {
    let cfg = ReproConfig::default();
    let bench = Bench::new("table4_scaling").samples(5);
    let ladder = [250_000u64, 500_000, 1_000_000];
    for choice in [
        AlgoChoice::GkSelect,
        AlgoChoice::GkSketch,
        AlgoChoice::FullSort,
        AlgoChoice::HistSelect,
    ] {
        let mut pts = Vec::new();
        for &n in &ladder {
            let mut cluster = make_cluster(&cfg, 10);
            let data = Distribution::Uniform
                .generator(cfg.algorithm.seed)
                .generate(&mut cluster, n);
            let mut engine = engine_for(&cfg, choice, 10).unwrap();
            let s = bench.run(&format!("{}/n{n}", choice.label().replace(' ', "_")), || {
                engine
                    .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
                    .expect("quantile run")
                    .value()
            });
            pts.push((n as f64, s.p50_s));
        }
        println!(
            "bench table4_scaling/{}/wall_slope              {:.3}",
            choice.label().replace(' ', "_"),
            stats::loglog_slope(&pts)
        );
    }
}
