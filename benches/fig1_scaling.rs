//! Fig. 1 — runtime vs n at 10 core nodes (40 partitions).
//!
//! Wall-clock regression tracking at bench-scale n; the paper-scale sweep
//! with the modelled EMR fabric is `repro bench fig --nodes 10`
//! (EXPERIMENTS.md E1). Every run routes through `QuantileEngine::execute`.

use gkselect::config::ReproConfig;
use gkselect::data::Distribution;
use gkselect::engine::{QuantileQuery, Source};
use gkselect::harness::{engine_for, make_cluster, AlgoChoice};
use gkselect::util::benchkit::Bench;

fn main() {
    let cfg = ReproConfig::default();
    let nodes = 10;
    let bench = Bench::new("fig1_10nodes").samples(10);
    for n in [100_000u64, 1_000_000] {
        let mut cluster = make_cluster(&cfg, nodes);
        let data = Distribution::Uniform
            .generator(cfg.algorithm.seed)
            .generate(&mut cluster, n);
        for choice in AlgoChoice::PAPER_SET {
            let mut engine = engine_for(&cfg, choice, nodes).unwrap();
            bench.run(&format!("{}/n{n}", choice.label().replace(' ', "_")), || {
                engine
                    .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
                    .expect("quantile run")
                    .value()
            });
        }
    }
}
