//! §Perf micro-benches — the executor hot loops the optimization pass
//! iterates on: pivot counting (native, and PJRT when artifacts exist),
//! Dutch partition, quickselect, histogram, RNG.

use gkselect::data::pcg::Pcg64;
use gkselect::runtime::{KernelBackend, NativeBackend, PjrtBackend};
use gkselect::select::{dutch_partition, select_kth, SplitMix64};
use gkselect::util::benchkit::Bench;
use gkselect::Key;
use std::path::Path;

fn data(n: usize) -> Vec<Key> {
    let mut rng = Pcg64::new(42, 1);
    (0..n).map(|_| rng.next_u64() as Key).collect()
}

fn main() {
    let n = 4_000_000usize;
    let xs = data(n);

    let bench = Bench::new("hot_count_pivot").samples(20);
    let mut native = NativeBackend::new();
    bench.run_throughput("native_4m", n as u64, || native.count_pivot(&xs, 0).lt);

    // PJRT path when artifacts are present (interpret-mode Pallas through
    // XLA CPU — correctness vehicle; §Perf compares the gap)
    if let Ok(mut pjrt) = PjrtBackend::load(Path::new("artifacts")) {
        let small = &xs[..512 * 1024];
        let pjrt_bench = Bench::new("hot_count_pivot_pjrt").samples(5);
        pjrt_bench.run_throughput("pjrt_512k", small.len() as u64, || {
            pjrt.count_pivot(small, 0).lt
        });
    } else {
        println!("bench hot_count_pivot_pjrt/skipped (no artifacts — run `make artifacts`)");
    }

    let m = 1_000_000usize;
    let ys = data(m);
    let bench = Bench::new("hot_dutch_partition").samples(20);
    bench.run_throughput("dutch_1m", m as u64, || {
        let mut a = ys.clone();
        dutch_partition(&mut a, 0).lt
    });

    let bench = Bench::new("hot_quickselect").samples(20);
    bench.run_throughput("median_1m", m as u64, || {
        let mut a = ys.clone();
        select_kth(&mut a, m / 2, 99)
    });
    bench.run_throughput("sort_baseline_1m", m as u64, || {
        let mut a = ys.clone();
        a.sort_unstable();
        a[m / 2]
    });

    let bench = Bench::new("hot_minmax_hist").samples(20);
    bench.run_throughput("minmax_4m", n as u64, || native.minmax(&xs));
    bench.run_throughput("histogram_128_4m", n as u64, || {
        native.histogram(&xs, i32::MIN as i64, (1u64 << 32) as i64 / 128 + 1, 128)
    });

    let bench = Bench::new("hot_rng").samples(20);
    let mut rng = SplitMix64::new(5);
    bench.run("splitmix_below", || rng.below(1_000_000));
}
