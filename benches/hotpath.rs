//! §Perf micro-benches — the executor hot loops the optimization pass
//! iterates on: pivot counting (native, and PJRT when artifacts exist),
//! the fused band_extract kernel vs the split count passes it replaces,
//! Dutch partition, quickselect, histogram, RNG.
//!
//! Also emits `BENCH_gk_select.json`: rounds / data_scans /
//! virtual-clock seconds for GK Select on the paper's `emr(30)` shape,
//! fused two-round path vs the seed three-round path (forced via a zero
//! candidate budget), so the perf trajectory is machine-readable across
//! PRs.

use gkselect::algorithms::gk_select::{GkSelect, GkSelectParams};
use gkselect::algorithms::QuantileAlgorithm;
use gkselect::cluster::{Cluster, ClusterConfig};
use gkselect::data::pcg::Pcg64;
use gkselect::data::{DataGenerator, Distribution};
use gkselect::runtime::{KernelBackend, NativeBackend};
use gkselect::select::{dutch_partition, select_kth, SplitMix64};
use gkselect::util::benchkit::{write_json, Bench, JsonVal};
use gkselect::Key;
use std::path::Path;

fn data(n: usize) -> Vec<Key> {
    let mut rng = Pcg64::new(42, 1);
    (0..n).map(|_| rng.next_u64() as Key).collect()
}

/// One GK Select run on the `emr(30)` shape → a JSON record.
fn gk_select_record(
    label: &str,
    dist: Distribution,
    n: u64,
    budget: Option<usize>,
) -> JsonVal {
    let mut cluster = Cluster::new(ClusterConfig::emr(30));
    let dataset = dist.generator(42).generate(&mut cluster, n);
    let mut alg = GkSelect::new(GkSelectParams {
        candidate_budget: budget,
        ..Default::default()
    });
    let out = alg
        .quantile(&mut cluster, &dataset, 0.75)
        .expect("bench run failed");
    println!(
        "bench gk_select_emr30/{label:<32} rounds {} scans {} model {:>10.4}s",
        out.report.rounds, out.report.data_scans, out.report.elapsed_secs
    );
    JsonVal::obj(vec![
        ("algorithm", JsonVal::Str(format!("gk_select_{label}"))),
        ("distribution", JsonVal::Str(dist.label().to_string())),
        ("n", JsonVal::U64(n)),
        ("q", JsonVal::F64(0.75)),
        ("rounds", JsonVal::U64(out.report.rounds)),
        ("data_scans", JsonVal::U64(out.report.data_scans)),
        ("stage_boundaries", JsonVal::U64(out.report.stage_boundaries)),
        ("shuffles", JsonVal::U64(out.report.shuffles)),
        ("persists", JsonVal::U64(out.report.persists)),
        (
            "network_volume_bytes",
            JsonVal::U64(out.report.network_volume_bytes),
        ),
        ("elapsed_model_s", JsonVal::F64(out.report.elapsed_secs)),
        ("exact", JsonVal::Bool(out.report.exact)),
    ])
}

fn main() {
    let n = 4_000_000usize;
    let xs = data(n);

    let bench = Bench::new("hot_count_pivot").samples(20);
    let mut native = NativeBackend::new();
    bench.run_throughput("native_4m", n as u64, || native.count_pivot(&xs, 0).lt);

    // fused band_extract vs the split passes it replaces: same pivot, an
    // ε-sized band around it (≈1% of the value space), generous budget
    let span = (u32::MAX as f64 * 0.005) as i32;
    let (lo, hi) = (-span, span);
    let budget = n / 10;
    let bench = Bench::new("hot_band_extract").samples(20);
    bench.run_throughput("fused_4m", n as u64, || {
        native.band_extract(&xs, 0, lo, hi, budget).band.inner
    });
    bench.run_throughput("split_count_then_band_4m", n as u64, || {
        // the seed shape: one count_pivot pass + one band_count pass
        let c = native.count_pivot(&xs, 0);
        let b = native.band_count(&xs, lo, hi);
        c.lt + b.band
    });
    let queries = [
        (0, lo, hi),
        (1 << 20, (1 << 20) - span, (1 << 20) + span),
        (-(1 << 24), -(1 << 24) - span, -(1 << 24) + span),
    ];
    bench.run_throughput("multi3_fused_4m", n as u64, || {
        native
            .multi_band_extract(&xs, &queries, budget)
            .iter()
            .map(|e| e.band.inner)
            .sum::<u64>()
    });

    // PJRT path when artifacts are present (interpret-mode Pallas through
    // XLA CPU — correctness vehicle; §Perf compares the gap)
    #[cfg(feature = "pjrt")]
    {
        use gkselect::runtime::PjrtBackend;
        if let Ok(mut pjrt) = PjrtBackend::load(Path::new("artifacts")) {
            let small = &xs[..512 * 1024];
            let pjrt_bench = Bench::new("hot_count_pivot_pjrt").samples(5);
            pjrt_bench.run_throughput("pjrt_512k", small.len() as u64, || {
                pjrt.count_pivot(small, 0).lt
            });
            pjrt_bench.run_throughput("pjrt_band_extract_512k", small.len() as u64, || {
                pjrt.band_extract(small, 0, lo, hi, budget).band.inner
            });
        } else {
            println!(
                "bench hot_count_pivot_pjrt/skipped (no artifacts — run `make artifacts`)"
            );
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("bench hot_count_pivot_pjrt/skipped (built without the `pjrt` feature)");

    let m = 1_000_000usize;
    let ys = data(m);
    let bench = Bench::new("hot_dutch_partition").samples(20);
    bench.run_throughput("dutch_1m", m as u64, || {
        let mut a = ys.clone();
        dutch_partition(&mut a, 0).lt
    });

    let bench = Bench::new("hot_quickselect").samples(20);
    bench.run_throughput("median_1m", m as u64, || {
        let mut a = ys.clone();
        select_kth(&mut a, m / 2, 99)
    });
    bench.run_throughput("sort_baseline_1m", m as u64, || {
        let mut a = ys.clone();
        a.sort_unstable();
        a[m / 2]
    });

    let bench = Bench::new("hot_minmax_hist").samples(20);
    bench.run_throughput("minmax_4m", n as u64, || native.minmax(&xs));
    bench.run_throughput("histogram_128_4m", n as u64, || {
        native.histogram(&xs, i32::MIN as i64, (1u64 << 32) as i64 / 128 + 1, 128)
    });

    let bench = Bench::new("hot_rng").samples(20);
    let mut rng = SplitMix64::new(5);
    bench.run("splitmix_below", || rng.below(1_000_000));

    // ---- machine-readable perf trajectory: BENCH_gk_select.json --------
    let bn = 4_000_000u64;
    let mut records = vec![
        // the fused two-round path, acceptance distributions
        gk_select_record("fused", Distribution::Uniform, bn, None),
        gk_select_record("fused_zipf", Distribution::Zipf, bn, None),
        gk_select_record("fused_bimodal", Distribution::Bimodal, bn, None),
        gk_select_record("fused_sorted", Distribution::Sorted, bn, None),
    ];
    // the seed path's round/scan shape, same workload: budget 0 forces
    // the overflow fallback, reproducing the seed's 3 rounds and 3 data
    // scans (sketch + count + secondPass). Caveat: the middle scan here
    // is the fused six-counter kernel where the seed ran plain
    // count_pivot, so this baseline is marginally costlier per scanned
    // key than the true seed and the time delta read from this file may
    // be slightly *overstated* by that compute difference; the 3→2
    // round and 3→2 scan accounting, which dominates the delta on the
    // EMR fabric model, is structural and exact. See `note` in the JSON.
    records.push(gk_select_record(
        "three_round_baseline",
        Distribution::Uniform,
        bn,
        Some(0),
    ));
    let doc = JsonVal::obj(vec![
        ("bench", JsonVal::Str("gk_select".into())),
        ("cluster", JsonVal::Str("emr(30)".into())),
        (
            "note",
            JsonVal::Str(
                "three_round_baseline replays the seed path's 3-round/3-scan \
                 shape via a zero candidate budget; its middle scan is the \
                 fused kernel (slightly costlier than the seed's count_pivot), \
                 so the time improvement vs this baseline may be slightly \
                 overstated by that compute delta — the 3->2 round and 3->2 \
                 scan reduction is structural and exact"
                    .into(),
            ),
        ),
        ("runs", JsonVal::Arr(records)),
    ]);
    let path = Path::new("BENCH_gk_select.json");
    write_json(path, &doc).expect("writing BENCH_gk_select.json");
    println!("wrote {}", path.display());
}
