//! §Perf micro-benches — the executor hot loops the optimization pass
//! iterates on: pivot counting (native, and PJRT when artifacts exist),
//! the fused band_extract kernel vs the split count passes it replaces,
//! the SIMD tile vs the scalar oracle on that same fused scan
//! (`simd_vs_scalar` family), Dutch partition, quickselect, histogram,
//! RNG.
//!
//! Also emits `BENCH_gk_select.json` (via [`gkselect::harness::write_bench_json`],
//! shared with `repro bench json`): rounds / data_scans / virtual-clock
//! seconds for GK Select on the paper's `emr(30)` shape — the fused
//! two-round path vs the seed three-round path (forced via a zero
//! candidate budget), a threads-vs-sequential pair recording the *real*
//! parallel wall-clock of the fused band-extract scan through the
//! OS-thread executor pool, and the `stream_query[_threads]` serving
//! hot path: one exact query answered from cached ingest-time sketches
//! after 32 micro-batches (rounds=1 / data_scans=1). The CI
//! `perf-tracking` job diffs this file against the committed baseline
//! (`scripts/bench_diff.py`).

use gkselect::data::pcg::Pcg64;
use gkselect::harness;
use gkselect::runtime::{KernelBackend, NativeBackend, SimdPolicy};
use gkselect::select::{dutch_partition, select_kth, SplitMix64};
use gkselect::util::benchkit::Bench;
use gkselect::Key;
use std::path::Path;

fn data(n: usize) -> Vec<Key> {
    let mut rng = Pcg64::new(42, 1);
    (0..n).map(|_| rng.next_u64() as Key).collect()
}

fn main() {
    let n = 4_000_000usize;
    let xs = data(n);

    let bench = Bench::new("hot_count_pivot").samples(20);
    let native = NativeBackend::new();
    bench.run_throughput("native_4m", n as u64, || native.count_pivot(&xs, 0).lt);

    // fused band_extract vs the split passes it replaces: same pivot, an
    // ε-sized band around it (≈1% of the value space), generous budget
    let span = (u32::MAX as f64 * 0.005) as i32;
    let (lo, hi) = (-span, span);
    let budget = n / 10;
    let bench = Bench::new("hot_band_extract").samples(20);
    bench.run_throughput("fused_4m", n as u64, || {
        native.band_extract(&xs, 0, lo, hi, budget).band.inner
    });
    bench.run_throughput("split_count_then_band_4m", n as u64, || {
        // the seed shape: one count_pivot pass + one band_count pass
        let c = native.count_pivot(&xs, 0);
        let b = native.band_count(&xs, lo, hi);
        c.lt + b.band
    });
    let queries = [
        (0, lo, hi),
        (1 << 20, (1 << 20) - span, (1 << 20) + span),
        (-(1 << 24), -(1 << 24) - span, -(1 << 24) + span),
    ];
    bench.run_throughput("multi3_fused_4m", n as u64, || {
        native
            .multi_band_extract(&xs, &queries, budget)
            .iter()
            .map(|e| e.band.inner)
            .sum::<u64>()
    });

    // explicit dispatch pins: the SIMD tile vs the scalar oracle on the
    // same fused scan (the `native` runs above use the ambient
    // GKSELECT_SIMD policy; these two force each path)
    let scalar_be = NativeBackend::with_policy(SimdPolicy::ForceScalar);
    let simd_be = NativeBackend::with_policy(SimdPolicy::ForceSimd);
    println!(
        "bench simd_vs_scalar/dispatch = {} (lane width {})",
        simd_be.dispatch().label(),
        simd_be.simd_lane_width()
    );
    let bench = Bench::new("simd_vs_scalar").samples(20);
    bench.run_throughput("band_extract_scalar_4m", n as u64, || {
        scalar_be.band_extract(&xs, 0, lo, hi, budget).band.inner
    });
    bench.run_throughput("band_extract_simd_4m", n as u64, || {
        simd_be.band_extract(&xs, 0, lo, hi, budget).band.inner
    });
    bench.run_throughput("multi3_scalar_4m", n as u64, || {
        scalar_be
            .multi_band_extract(&xs, &queries, budget)
            .iter()
            .map(|e| e.band.inner)
            .sum::<u64>()
    });
    bench.run_throughput("multi3_simd_4m", n as u64, || {
        simd_be
            .multi_band_extract(&xs, &queries, budget)
            .iter()
            .map(|e| e.band.inner)
            .sum::<u64>()
    });

    // PJRT path when artifacts are present (interpret-mode Pallas through
    // XLA CPU — correctness vehicle; §Perf compares the gap)
    #[cfg(feature = "pjrt")]
    {
        use gkselect::runtime::PjrtBackend;
        if let Ok(pjrt) = PjrtBackend::load(Path::new("artifacts")) {
            let small = &xs[..512 * 1024];
            let pjrt_bench = Bench::new("hot_count_pivot_pjrt").samples(5);
            pjrt_bench.run_throughput("pjrt_512k", small.len() as u64, || {
                pjrt.count_pivot(small, 0).lt
            });
            pjrt_bench.run_throughput("pjrt_band_extract_512k", small.len() as u64, || {
                pjrt.band_extract(small, 0, lo, hi, budget).band.inner
            });
        } else {
            println!(
                "bench hot_count_pivot_pjrt/skipped (no artifacts — run `make artifacts`)"
            );
        }
    }
    #[cfg(not(feature = "pjrt"))]
    println!("bench hot_count_pivot_pjrt/skipped (built without the `pjrt` feature)");

    let m = 1_000_000usize;
    let ys = data(m);
    let bench = Bench::new("hot_dutch_partition").samples(20);
    bench.run_throughput("dutch_1m", m as u64, || {
        let mut a = ys.clone();
        dutch_partition(&mut a, 0).lt
    });

    let bench = Bench::new("hot_quickselect").samples(20);
    bench.run_throughput("median_1m", m as u64, || {
        let mut a = ys.clone();
        select_kth(&mut a, m / 2, 99)
    });
    bench.run_throughput("sort_baseline_1m", m as u64, || {
        let mut a = ys.clone();
        a.sort_unstable();
        a[m / 2]
    });

    let bench = Bench::new("hot_minmax_hist").samples(20);
    bench.run_throughput("minmax_4m", n as u64, || native.minmax(&xs));
    bench.run_throughput("histogram_128_4m", n as u64, || {
        native.histogram(&xs, i32::MIN as i64, (1u64 << 32) as i64 / 128 + 1, 128)
    });

    let bench = Bench::new("hot_rng").samples(20);
    let mut rng = SplitMix64::new(5);
    bench.run("splitmix_below", || rng.below(1_000_000));

    // ---- machine-readable perf trajectory: BENCH_gk_select.json --------
    // (fused vs three-round baseline, plus threads-vs-sequential real
    // wall-clock for the fused band-extract scan — shared implementation
    // with `repro bench json`)
    harness::write_bench_json(Path::new("."), 4_000_000, SimdPolicy::from_env())
        .expect("writing BENCH_gk_select.json");
}
