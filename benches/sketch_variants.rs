//! §IV-E — insert throughput of the three GK variants (E7).
//!
//! The paper's analytic claim: Spark GK pays an unavoidable `n log B`
//! buffer-sort term (B = 50 000), while mSGK's adaptive buffer tracks the
//! summary size and recovers the classical amortized bound. This bench
//! measures inserts/second per variant and the driver-side fold vs tree
//! merge cost at the paper's 120-partition shape.

use gkselect::data::pcg::Pcg64;
use gkselect::sketch::classical::ClassicalGk;
use gkselect::sketch::modified::{fold_merge, tree_merge, ModifiedGk};
use gkselect::sketch::spark::SparkGk;
use gkselect::sketch::{GkCore, QuantileSketch};
use gkselect::util::benchkit::Bench;
use gkselect::Key;

fn data(n: usize) -> Vec<Key> {
    let mut rng = Pcg64::new(7, 7);
    (0..n).map(|_| rng.next_u64() as Key).collect()
}

fn main() {
    let n = 200_000usize;
    let xs = data(n);
    let bench = Bench::new("sketch_insert").samples(10);

    bench.run_throughput("classical", n as u64, || {
        let mut sk = ClassicalGk::new(0.01);
        for &v in &xs {
            sk.insert(v);
        }
        sk.finalize();
        sk.summary_len()
    });
    bench.run_throughput("spark_B50k", n as u64, || {
        let mut sk = SparkGk::new(0.01);
        for &v in &xs {
            sk.insert(v);
        }
        sk.finalize();
        sk.summary_len()
    });
    bench.run_throughput("modified_adaptive", n as u64, || {
        let mut sk = ModifiedGk::new(0.01);
        for &v in &xs {
            sk.insert(v);
        }
        sk.finalize();
        sk.summary_len()
    });
    bench.run_throughput("bulk_from_sorted", n as u64, || {
        let mut copy = xs.clone();
        gkselect::sort::radix::radix_sort_i32(&mut copy);
        gkselect::sketch::GkCore::from_sorted(&copy, 0.01).samples.len()
    });
    bench.run_throughput("kll_k200", n as u64, || {
        let mut sk = gkselect::sketch::kll::KllSketch::new(7);
        for &v in &xs {
            sk.insert(v);
        }
        sk.retained()
    });

    // driver-side merge: 120 partitions' sketches (30-node shape)
    let cores: Vec<GkCore> = (0..120)
        .map(|i| {
            let mut rng = Pcg64::new(i, 3);
            let mut sk = ModifiedGk::new(0.01);
            for _ in 0..20_000 {
                sk.insert(rng.next_u64() as Key);
            }
            sk.into_core()
        })
        .collect();
    let merge_bench = Bench::new("sketch_merge_120p").samples(10);
    merge_bench.run("foldLeft", || fold_merge(cores.clone()).unwrap().count);
    merge_bench.run("treeReduce", || tree_merge(cores.clone()).unwrap().count);
}
