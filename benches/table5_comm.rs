//! Table V — communication/synchronization counters. The full table is
//! printed from measured counters by `repro bench table5`
//! (EXPERIMENTS.md E6); this bench asserts the counter *claims* hold on
//! every iteration while tracking the query wall cost. Every run routes
//! through `QuantileEngine::execute`.

use gkselect::config::ReproConfig;
use gkselect::data::Distribution;
use gkselect::engine::{QuantileQuery, Source};
use gkselect::harness::{engine_for, make_cluster, AlgoChoice};
use gkselect::util::benchkit::Bench;

fn main() {
    let cfg = ReproConfig::default();
    let bench = Bench::new("table5_counters").samples(10);
    let n = 500_000u64;
    let mut cluster = make_cluster(&cfg, 10);
    let data = Distribution::Uniform
        .generator(cfg.algorithm.seed)
        .generate(&mut cluster, n);

    let mut engine = engine_for(&cfg, AlgoChoice::GkSelect, 10).unwrap();
    bench.run("gk_select_counter_invariants", || {
        let out = engine
            .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
            .expect("run");
        // Table V row for GK Select: 0 shuffles, ≤3 rounds, 0 persists
        assert_eq!(out.report.shuffles, 0);
        assert!(out.report.rounds <= 3);
        assert_eq!(out.report.persists, 0);
        out.value()
    });

    let mut engine = engine_for(&cfg, AlgoChoice::FullSort, 10).unwrap();
    bench.run("full_sort_counter_invariants", || {
        let out = engine
            .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
            .expect("run");
        // Table V row for Full Sort: 1 shuffle, 1 round, O(n) volume
        assert_eq!(out.report.shuffles, 1);
        assert_eq!(out.report.rounds, 1);
        out.value()
    });

    let mut engine = engine_for(&cfg, AlgoChoice::Afs, 10).unwrap();
    bench.run("afs_counter_invariants", || {
        let out = engine
            .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
            .expect("run");
        // Table V row for AFS: no shuffle, O(log n) rounds + persists
        assert_eq!(out.report.shuffles, 0);
        assert!(out.report.rounds >= 3 && out.report.persists >= 1);
        out.value()
    });
}
