//! Offline stand-in for the `anyhow` crate, covering exactly the subset
//! this workspace uses: [`Error`], [`Result`], the [`anyhow!`], [`bail!`]
//! and [`ensure!`] macros, and the [`Context`] extension trait for
//! `Result` and `Option`.
//!
//! The repo builds in air-gapped environments, so crates.io dependencies
//! are vendored as path dependencies. Semantics mirror the real crate:
//! context frames stack outermost-first, `{}` displays the outermost
//! message, `{:#}` joins the whole chain with `": "`, and `{:?}` prints
//! the outermost message followed by a `Caused by:` list.

use std::fmt;

/// Drop-in for `anyhow::Result<T>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Chain-of-messages error value (stand-in for `anyhow::Error`).
///
/// `chain[0]` is the outermost context, `chain.last()` the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message (stand-in for
    /// `anyhow::Error::msg` / the `anyhow!` macro's output).
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Push a new outermost context frame.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the chain from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, outermost first
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// Any std error converts via `?`. The source chain is flattened into the
// message chain so `{:#}` and `{:?}` show the full causal path.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(err: E) -> Self {
        let mut chain = vec![err.to_string()];
        let mut source = err.source();
        while let Some(cause) = source {
            chain.push(cause.to_string());
            source = cause.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(...)` / `.with_context(...)` to
/// `Result` and `Option` (stand-in for `anyhow::Context`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

// One blanket impl covers both `Result<T, E: std::error::Error>` (via
// the `From` blanket above) and `Result<T, Error>` (via the reflexive
// `From<T> for T`), with no overlapping-impl question.
impl<T, E> Context<T> for Result<T, E>
where
    Error: From<E>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// `anyhow!("...{}", args)` — build an [`Error`] from format args.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// `bail!("...")` — return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// `ensure!(cond, "...")` — bail unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "no such file")
    }

    #[test]
    fn display_shows_outermost_context() {
        let e: Error = Err::<(), _>(io_err())
            .context("reading manifest")
            .unwrap_err();
        assert_eq!(e.to_string(), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: no such file");
    }

    #[test]
    fn debug_lists_causes() {
        let e = anyhow!("root").context("outer");
        let s = format!("{e:?}");
        assert!(s.contains("outer") && s.contains("Caused by") && s.contains("root"));
    }

    #[test]
    fn option_context() {
        let none: Option<u32> = None;
        assert!(Context::context(none, "missing").is_err());
        assert_eq!(Context::context(Some(3), "missing").unwrap(), 3);
    }

    #[test]
    fn macros_build_and_bail() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(5).unwrap_err().to_string().contains("five"));
        assert!(f(20).unwrap_err().to_string().contains("too big: 20"));
    }

    #[test]
    fn bare_ensure_names_condition() {
        fn f(x: u32) -> Result<()> {
            ensure!(x == 1);
            Ok(())
        }
        assert!(f(2).unwrap_err().to_string().contains("x == 1"));
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<i32> {
            let n: i32 = "not a number".parse()?;
            Ok(n)
        }
        assert!(f().is_err());
    }
}
