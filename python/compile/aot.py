"""AOT-lower the L2 pipeline to HLO text artifacts for the rust runtime.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(what the published `xla` 0.1.6 crate binds) rejects; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Usage:  python -m compile.aot --out-dir ../artifacts
Emits one `<kind>.hlo.txt` per artifact in model.ARTIFACTS plus a
`manifest.json` describing buffer geometry so the rust side never has to
guess shapes.
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(kind: str) -> str:
    fn = model.ARTIFACTS[kind]()
    args = model.example_args(kind)
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only", nargs="*", default=None, help="subset of artifact kinds to emit"
    )
    ns = ap.parse_args()

    os.makedirs(ns.out_dir, exist_ok=True)
    kinds = ns.only or list(model.ARTIFACTS)

    manifest = {
        "buf_len": model.BUF_LEN,
        "chunk": model.CHUNK,
        "hist_chunk": model.HIST_CHUNK,
        "nbins": model.NBINS,
        "dtype": "i32",
        "artifacts": {},
    }

    for kind in kinds:
        text = lower_artifact(kind)
        path = os.path.join(ns.out_dir, f"{kind}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][kind] = {
            "file": f"{kind}.hlo.txt",
            "bytes": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(ns.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(ns.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
