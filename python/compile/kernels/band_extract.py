"""Fused pivot + band kernel: count AND extract in one read of the buffer.

The two-round GK Select protocol needs, per partition chunk,

    counts[0] = |{x <  pivot}|          (lt)
    counts[1] = |{x == pivot}|          (eq)
    counts[2] = |{x <  lo}|             (below)
    counts[3] = |{x == lo}|             (eq_lo)
    counts[4] = |{lo < x < hi}|         (inner — the extracted candidates)
    counts[5] = |{x == hi}|             (eq_hi)

plus the open-band values themselves, compacted to the front of a
buf_len-sized output slot. Endpoint runs are counted, never copied, so
duplicate-heavy data cannot widen the extraction: the open band's size is
bounded by the GK invariant at O(eps*n) regardless of duplication.

The counting reductions run as a single Pallas kernel over CHUNK tiles
(one read of the buffer feeding all six accumulators). The compaction is
a cumsum-scatter at the jnp level of the same jitted artifact: positions
are the exclusive prefix sum of the band mask, non-band lanes are routed
to a dump slot past the live region and dropped (mode="drop"), keeping
the whole pass linear and branchless.

Artifact output is one i64 vector of length 6 + buf_len:
    out[:6]           = counts
    out[6:6+inner]    = compacted open-band values (as i64)
so the rust wrapper needs a single-output executable (matching run1's
to_tuple1 contract) and slices by counts[4].
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def band_extract_kernel(x_ref, pivot_ref, lo_ref, hi_ref, valid_ref, out_ref, *, chunk):
    """Grid-step body: six fused masked reductions over one CHUNK tile.

    out_ref holds [lt, eq, below, eq_lo, inner, eq_hi] as int64,
    accumulated across the grid. Same int32 tile-mask trick as
    count_pivot.py (§Perf L1.1).
    """
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros((6,), jnp.int64)

    x = x_ref[...]
    pivot = pivot_ref[0]
    lo = lo_ref[0]
    hi = hi_ref[0]

    remaining = valid_ref[0].astype(jnp.int32) - step.astype(jnp.int32) * chunk
    live = jnp.clip(remaining, 0, chunk)
    idx = jax.lax.iota(jnp.int32, chunk)
    mask = idx < live

    def msum(cond):
        return jnp.sum(jnp.where(mask & cond, 1, 0).astype(jnp.int32))

    lt = msum(x < pivot)
    eq = msum(x == pivot)
    below = msum(x < lo)
    eq_lo = msum(x == lo)
    inner = msum((x > lo) & (x < hi))
    eq_hi = msum(x == hi)

    out_ref[...] += jnp.stack([lt, eq, below, eq_lo, inner, eq_hi]).astype(jnp.int64)


def build_band_extract(buf_len, chunk, dtype=jnp.int32):
    """Return fn(x[buf_len], pivot[1], lo[1], hi[1], valid[1]) -> i64[6+buf_len]."""
    if buf_len % chunk != 0:
        raise ValueError(f"buf_len {buf_len} not a multiple of chunk {chunk}")
    grid = buf_len // chunk

    kernel = functools.partial(band_extract_kernel, chunk=chunk)

    def fn(x, pivot, lo, hi, valid):
        x = x.astype(dtype)
        pivot = pivot.astype(dtype)
        lo = lo.astype(dtype)
        hi = hi.astype(dtype)
        valid = valid.astype(jnp.int64)

        counts = pl.pallas_call(
            kernel,
            grid=(grid,),
            in_specs=[
                pl.BlockSpec((chunk,), lambda i: (i,)),
                pl.BlockSpec((1,), lambda i: (0,)),
                pl.BlockSpec((1,), lambda i: (0,)),
                pl.BlockSpec((1,), lambda i: (0,)),
                pl.BlockSpec((1,), lambda i: (0,)),
            ],
            out_specs=pl.BlockSpec((6,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((6,), jnp.int64),
            interpret=True,
        )(x, pivot, lo, hi, valid)

        # cumsum-scatter compaction of the open-band values: linear,
        # branchless, static shapes (out-of-band lanes -> dump slot).
        # Length comes from the traced buffer itself so the jnp stage
        # follows whatever geometry the caller lowers with.
        blen = x.shape[0]
        idx = jax.lax.iota(jnp.int32, blen)
        live = idx.astype(jnp.int64) < valid[0]
        flags = live & (x > lo[0]) & (x < hi[0])
        pos = jnp.cumsum(flags) - 1  # exclusive prefix sum at flagged lanes
        dest = jnp.where(flags, pos, blen)  # blen == dump slot
        packed = (
            jnp.zeros((blen + 1,), jnp.int64)
            .at[dest]
            .set(x.astype(jnp.int64), mode="drop")[:blen]
        )
        return jnp.concatenate([counts, packed])

    return fn
