"""Three-way pivot count kernel: |{x < pi}|, |{x == pi}|, |{x > pi}|.

This is the executor-side hot loop of every round-structured algorithm in
the paper (GK Select step 4, AFS/Jeffers step 2): a single linear pass over
the partition classifying each key against the broadcast pivot.

The buffer is processed in CHUNK-sized VMEM tiles; the (3,) accumulator is
initialised on grid step 0 and carried across steps. Keys at global index
>= `valid` are padding and are excluded via an iota mask, so one artifact
(fixed buffer length) serves arbitrary partition tails.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def count_pivot_kernel(x_ref, pivot_ref, valid_ref, out_ref, *, chunk):
    """Grid-step body: classify one CHUNK tile against the pivot.

    out_ref holds [lt, eq, gt] as int64 and is accumulated across the grid.

    §Perf L1.1: the tile mask uses int32 index math (valid <= buf_len fits
    i32, doubling SIMD lanes vs i64), and `gt` is derived arithmetically
    from the tile's live length instead of a third masked reduction.
    """
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros((3,), jnp.int64)

    x = x_ref[...]
    pivot = pivot_ref[0]
    # live length of this tile, clamped into [0, chunk] — int32 throughout
    remaining = valid_ref[0].astype(jnp.int32) - step.astype(jnp.int32) * chunk
    live = jnp.clip(remaining, 0, chunk)
    idx = jax.lax.iota(jnp.int32, chunk)
    mask = idx < live

    lt = jnp.sum(jnp.where(mask & (x < pivot), 1, 0).astype(jnp.int32))
    eq = jnp.sum(jnp.where(mask & (x == pivot), 1, 0).astype(jnp.int32))
    gt = live - lt - eq

    out_ref[...] += jnp.stack([lt, eq, gt]).astype(jnp.int64)


def build_count_pivot(buf_len, chunk, dtype=jnp.int32):
    """Return a jittable fn(x[buf_len], pivot[1], valid[1]) -> counts[3].

    buf_len must be a multiple of chunk; grid = buf_len // chunk.
    """
    if buf_len % chunk != 0:
        raise ValueError(f"buf_len {buf_len} not a multiple of chunk {chunk}")
    grid = buf_len // chunk

    kernel = functools.partial(count_pivot_kernel, chunk=chunk)

    def fn(x, pivot, valid):
        return pl.pallas_call(
            kernel,
            grid=(grid,),
            in_specs=[
                pl.BlockSpec((chunk,), lambda i: (i,)),
                pl.BlockSpec((1,), lambda i: (0,)),
                pl.BlockSpec((1,), lambda i: (0,)),
            ],
            out_specs=pl.BlockSpec((3,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((3,), jnp.int64),
            interpret=True,
        )(x.astype(dtype), pivot.astype(dtype), valid.astype(jnp.int64))

    return fn
