"""Pure-jnp correctness oracles for every L1 kernel.

Each `ref_*` function computes the same quantity as its Pallas counterpart
using plain jax.numpy over the full (unpadded) valid prefix. pytest asserts
bit-exact equality (all outputs are integer counts or exact extremes, so
allclose degenerates to equality).
"""

import jax.numpy as jnp


def ref_count_pivot(x, pivot, valid):
    """[|{x<pivot}|, |{x==pivot}|, |{x>pivot}|] over x[:valid]."""
    v = x[: int(valid)]
    return jnp.array(
        [
            jnp.sum(v < pivot),
            jnp.sum(v == pivot),
            jnp.sum(v > pivot),
        ],
        jnp.int64,
    )


def ref_band_count(x, lo, hi, valid):
    """[|{x<lo}|, |{lo<=x<=hi}|, |{x>hi}|] over x[:valid]."""
    v = x[: int(valid)]
    return jnp.array(
        [
            jnp.sum(v < lo),
            jnp.sum((v >= lo) & (v <= hi)),
            jnp.sum(v > hi),
        ],
        jnp.int64,
    )


def ref_band_extract(x, pivot, lo, hi, valid):
    """([lt, eq, below, eq_lo, inner, eq_hi], open-band values) over x[:valid]."""
    v = x[: int(valid)]
    counts = jnp.array(
        [
            jnp.sum(v < pivot),
            jnp.sum(v == pivot),
            jnp.sum(v < lo),
            jnp.sum(v == lo),
            jnp.sum((v > lo) & (v < hi)),
            jnp.sum(v == hi),
        ],
        jnp.int64,
    )
    candidates = v[(v > lo) & (v < hi)].astype(jnp.int64)
    return counts, candidates


def ref_histogram(x, lo, width, nbins, valid):
    """Equi-width histogram with clamped out-of-range values."""
    v = x[: int(valid)].astype(jnp.int64)
    bins = jnp.clip((v - jnp.int64(lo)) // jnp.int64(width), 0, nbins - 1)
    return jnp.zeros((nbins,), jnp.int64).at[bins].add(1)


def ref_minmax(x, valid, dtype=jnp.int32):
    """[min, max] over x[:valid]; [dtype.max, dtype.min] when empty."""
    v = x[: int(valid)]
    info = jnp.iinfo(dtype) if jnp.issubdtype(dtype, jnp.integer) else jnp.finfo(dtype)
    if v.size == 0:
        return jnp.array([info.max, info.min], dtype)
    return jnp.array([jnp.min(v), jnp.max(v)], dtype)
