"""Band count kernel: rank-window statistics around a pivot interval.

Counts, over the valid prefix of the buffer,
    out[0] = |{x <  lo}|
    out[1] = |{lo <= x <= hi}|   (the candidate band)
    out[2] = |{x >  hi}|

Used by the histogram-select extension (DESIGN.md S14) to decide which
value band still contains the target rank, and by the epsilon-ablation to
measure candidate-band volume without materialising candidates.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def band_count_kernel(x_ref, lo_ref, hi_ref, valid_ref, out_ref, *, chunk):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros((3,), jnp.int64)

    x = x_ref[...]
    lo = lo_ref[0]
    hi = hi_ref[0]

    # §Perf L1.1: int32 tile mask + arithmetic third count (see
    # count_pivot.py)
    remaining = valid_ref[0].astype(jnp.int32) - step.astype(jnp.int32) * chunk
    live = jnp.clip(remaining, 0, chunk)
    idx = jax.lax.iota(jnp.int32, chunk)
    mask = idx < live

    below = jnp.sum(jnp.where(mask & (x < lo), 1, 0).astype(jnp.int32))
    band = jnp.sum(jnp.where(mask & (x >= lo) & (x <= hi), 1, 0).astype(jnp.int32))
    above = live - below - band

    out_ref[...] += jnp.stack([below, band, above]).astype(jnp.int64)


def build_band_count(buf_len, chunk, dtype=jnp.int32):
    """Return fn(x[buf_len], lo[1], hi[1], valid[1]) -> counts[3]."""
    if buf_len % chunk != 0:
        raise ValueError(f"buf_len {buf_len} not a multiple of chunk {chunk}")
    grid = buf_len // chunk

    kernel = functools.partial(band_count_kernel, chunk=chunk)

    def fn(x, lo, hi, valid):
        return pl.pallas_call(
            kernel,
            grid=(grid,),
            in_specs=[
                pl.BlockSpec((chunk,), lambda i: (i,)),
                pl.BlockSpec((1,), lambda i: (0,)),
                pl.BlockSpec((1,), lambda i: (0,)),
                pl.BlockSpec((1,), lambda i: (0,)),
            ],
            out_specs=pl.BlockSpec((3,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((3,), jnp.int64),
            interpret=True,
        )(x.astype(dtype), lo.astype(dtype), hi.astype(dtype), valid.astype(jnp.int64))

    return fn
