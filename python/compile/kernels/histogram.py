"""Equi-width histogram kernel over a value range [lo, lo + nbins*width).

Per grid step, the CHUNK tile is bucketed with 64-bit arithmetic (the value
span can exceed i32 range: hi - lo up to 2e9) and accumulated into the
(nbins,) histogram via a one-hot comparison matrix — the VPU-friendly
formulation of scatter-add (Pallas has no atomic scatter on TPU; a
CHUNK x NBINS compare+reduce keeps everything dense in VMEM).

Values outside the range are clamped into the first/last bin; padding
beyond `valid` is dropped. Used by the histogram-select extension to narrow
the candidate value band in O(1) rounds per refinement.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def histogram_kernel(x_ref, lo_ref, width_ref, valid_ref, out_ref, *, chunk, nbins):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros((nbins,), jnp.int64)

    x = x_ref[...].astype(jnp.int64)
    lo = lo_ref[0].astype(jnp.int64)
    width = width_ref[0].astype(jnp.int64)
    valid = valid_ref[0]

    idx = step * chunk + jax.lax.iota(jnp.int64, chunk)
    mask = idx < valid

    bins = jnp.clip((x - lo) // width, 0, nbins - 1)
    # one-hot accumulate: (chunk, nbins) bool -> column sums
    onehot = bins[:, None] == jax.lax.iota(jnp.int64, nbins)[None, :]
    contrib = jnp.where(onehot & mask[:, None], 1, 0).astype(jnp.int64)
    out_ref[...] += jnp.sum(contrib, axis=0)


def build_histogram(buf_len, chunk, nbins, dtype=jnp.int32):
    """Return fn(x[buf_len], lo[1], width[1], valid[1]) -> hist[nbins]."""
    if buf_len % chunk != 0:
        raise ValueError(f"buf_len {buf_len} not a multiple of chunk {chunk}")
    grid = buf_len // chunk

    kernel = functools.partial(histogram_kernel, chunk=chunk, nbins=nbins)

    def fn(x, lo, width, valid):
        return pl.pallas_call(
            kernel,
            grid=(grid,),
            in_specs=[
                pl.BlockSpec((chunk,), lambda i: (i,)),
                pl.BlockSpec((1,), lambda i: (0,)),
                pl.BlockSpec((1,), lambda i: (0,)),
                pl.BlockSpec((1,), lambda i: (0,)),
            ],
            out_specs=pl.BlockSpec((nbins,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((nbins,), jnp.int64),
            interpret=True,
        )(
            x.astype(dtype),
            lo.astype(jnp.int64),
            width.astype(jnp.int64),
            valid.astype(jnp.int64),
        )

    return fn
