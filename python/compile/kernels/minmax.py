"""Min/max reduction kernel over the valid prefix of the buffer.

Seeds the histogram-select value range and the data-validation pass. The
padded tail is neutralised by substituting the dtype's extremes before the
tile reduction; the (2,) accumulator [min, max] is carried across steps.

If `valid == 0` the result is [dtype_max, dtype_min] — the caller treats
that sentinel pair as "empty partition".
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def minmax_kernel(x_ref, valid_ref, out_ref, *, chunk, dtype):
    step = pl.program_id(0)
    info = jnp.iinfo(dtype) if jnp.issubdtype(dtype, jnp.integer) else jnp.finfo(dtype)

    @pl.when(step == 0)
    def _init():
        # scalar stores: a captured i32[2] array constant is rejected by
        # the Pallas tracer ("captures constants ... pass them as inputs")
        out_ref[0] = jnp.array(info.max, dtype)
        out_ref[1] = jnp.array(info.min, dtype)

    x = x_ref[...]

    # §Perf L1.1: int32 tile mask (see count_pivot.py)
    remaining = valid_ref[0].astype(jnp.int32) - step.astype(jnp.int32) * chunk
    live = jnp.clip(remaining, 0, chunk)
    idx = jax.lax.iota(jnp.int32, chunk)
    mask = idx < live

    tile_min = jnp.min(jnp.where(mask, x, info.max))
    tile_max = jnp.max(jnp.where(mask, x, info.min))

    out_ref[0] = jnp.minimum(out_ref[0], tile_min)
    out_ref[1] = jnp.maximum(out_ref[1], tile_max)


def build_minmax(buf_len, chunk, dtype=jnp.int32):
    """Return fn(x[buf_len], valid[1]) -> [min, max] (dtype)."""
    if buf_len % chunk != 0:
        raise ValueError(f"buf_len {buf_len} not a multiple of chunk {chunk}")
    grid = buf_len // chunk

    kernel = functools.partial(minmax_kernel, chunk=chunk, dtype=dtype)

    def fn(x, valid):
        return pl.pallas_call(
            kernel,
            grid=(grid,),
            in_specs=[
                pl.BlockSpec((chunk,), lambda i: (i,)),
                pl.BlockSpec((1,), lambda i: (0,)),
            ],
            out_specs=pl.BlockSpec((2,), lambda i: (0,)),
            out_shape=jax.ShapeDtypeStruct((2,), dtype),
            interpret=True,
        )(x.astype(dtype), valid.astype(jnp.int64))

    return fn
