"""L1 Pallas kernels for the gkselect pivot pass.

Every kernel is a streaming reduction over a fixed-size buffer of keys:
the buffer is tiled into CHUNK-sized blocks via BlockSpec (the HBM->VMEM
schedule), the grid walks the blocks, and a small accumulator is carried
across grid steps. A `valid` scalar masks the padded tail so one lowered
artifact serves any partition length.

Kernels are lowered with interpret=True: the CPU PJRT plugin cannot run
Mosaic custom-calls, and correctness is what we validate here; TPU perf is
estimated from VMEM footprint in DESIGN.md §Perf.
"""

from .count_pivot import build_count_pivot, count_pivot_kernel
from .band_count import build_band_count, band_count_kernel
from .band_extract import build_band_extract, band_extract_kernel
from .histogram import build_histogram, histogram_kernel
from .minmax import build_minmax, minmax_kernel

__all__ = [
    "build_count_pivot",
    "count_pivot_kernel",
    "build_band_count",
    "band_count_kernel",
    "build_band_extract",
    "band_extract_kernel",
    "build_histogram",
    "histogram_kernel",
    "build_minmax",
    "minmax_kernel",
]
