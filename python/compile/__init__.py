"""Build-time compile path: L2 jax model + L1 Pallas kernels -> HLO text.

Nothing in this package runs at L3 request time; `make artifacts` invokes
`python -m compile.aot` once and the rust coordinator loads the emitted
`artifacts/*.hlo.txt` through PJRT.
"""

import jax

# Counts are int64 (n reaches 1e9 and sums cross partitions); without x64
# jax silently downcasts jnp.int64 literals to int32 and Pallas ref stores
# then fail on dtype mismatch.
jax.config.update("jax_enable_x64", True)
