"""L2: the jax pivot-pass pipeline lowered to the AOT artifacts.

The rust coordinator's executor hot loop is a handful of streaming
reductions over partition buffers. Each public `make_*` function here
returns a jitted jax callable whose *whole body* is the corresponding L1
Pallas kernel (plus any fusion-friendly post-processing), so the lowered
HLO is exactly the executor-side compute the paper describes:

  - pivot pass      (GK Select step 4, AFS/Jeffers local count)
  - band pass       (candidate-band volume, epsilon ablation)
  - histogram pass  (histogram-select range refinement)
  - minmax pass     (range seeding / data validation)
  - fused pivot+band pass (one read of the buffer feeding both reductions;
    the L2-level fusion the perf pass compares against two separate passes)

Buffer geometry is fixed at lowering time (HLO has static shapes); the rust
wrapper streams a partition through the executable BUF_LEN keys at a time
and passes the live length in `valid`.
"""

import jax
import jax.numpy as jnp

from .kernels import (
    build_band_count,
    build_band_extract,
    build_count_pivot,
    build_histogram,
    build_minmax,
)

# Geometry shared with the rust runtime via artifacts/manifest.json.
BUF_LEN = 1 << 17  # keys per executable call
CHUNK = 1 << 14  # keys per VMEM tile (grid = BUF_LEN / CHUNK = 8)
NBINS = 128
HIST_CHUNK = 1 << 12  # smaller tile: the one-hot matrix is CHUNK x NBINS

DTYPE = jnp.int32


def make_count_pivot(buf_len=BUF_LEN, chunk=CHUNK):
    """fn(x[buf_len] i32, pivot[1] i32, valid[1] i64) -> i64[3] (lt, eq, gt)."""
    inner = build_count_pivot(buf_len, chunk, DTYPE)

    def fn(x, pivot, valid):
        return (inner(x, pivot, valid),)

    return fn


def make_band_count(buf_len=BUF_LEN, chunk=CHUNK):
    """fn(x, lo, hi, valid) -> i64[3] (below, band, above)."""
    inner = build_band_count(buf_len, chunk, DTYPE)

    def fn(x, lo, hi, valid):
        return (inner(x, lo, hi, valid),)

    return fn


def make_histogram(buf_len=BUF_LEN, chunk=HIST_CHUNK, nbins=NBINS):
    """fn(x, lo, width, valid) -> i64[nbins]."""
    inner = build_histogram(buf_len, chunk, nbins, DTYPE)

    def fn(x, lo, width, valid):
        return (inner(x, lo, width, valid),)

    return fn


def make_minmax(buf_len=BUF_LEN, chunk=CHUNK):
    """fn(x, valid) -> i32[2] (min, max)."""
    inner = build_minmax(buf_len, chunk, DTYPE)

    def fn(x, valid):
        return (inner(x, valid),)

    return fn


def make_band_extract(buf_len=BUF_LEN, chunk=CHUNK):
    """Fused count+extract pass for the two-round GK Select protocol.

    fn(x, pivot, lo, hi, valid) -> i64[6 + buf_len]: six fused counters
    ([lt, eq, below, eq_lo, inner, eq_hi]) followed by the open-band
    values compacted to the front. One executable dispatch replaces the
    old count_pivot round AND the candidate-extraction round's read.
    """
    inner = build_band_extract(buf_len, chunk, DTYPE)

    def fn(x, pivot, lo, hi, valid):
        return (inner(x, pivot, lo, hi, valid),)

    return fn


def make_pivot_band(buf_len=BUF_LEN, chunk=CHUNK):
    """Fused pass: one buffer read feeding the pivot AND band reductions.

    Returns (counts[3], band[3]) in a single executable so the rust hot
    path pays one PJRT dispatch instead of two when both are needed
    (GK Select step 4 + ablation instrumentation).
    """
    count = build_count_pivot(buf_len, chunk, DTYPE)
    band = build_band_count(buf_len, chunk, DTYPE)

    def fn(x, pivot, lo, hi, valid):
        return (count(x, pivot, valid), band(x, lo, hi, valid))

    return fn


def example_args(kind):
    """ShapeDtypeStructs for jax.jit(...).lower(...) per artifact kind."""
    x = jax.ShapeDtypeStruct((BUF_LEN,), DTYPE)
    s32 = jax.ShapeDtypeStruct((1,), DTYPE)
    s64 = jax.ShapeDtypeStruct((1,), jnp.int64)
    if kind == "count_pivot":
        return (x, s32, s64)
    if kind == "band_count":
        return (x, s32, s32, s64)
    if kind == "band_extract":
        return (x, s32, s32, s32, s64)
    if kind == "histogram":
        return (x, s64, s64, s64)
    if kind == "minmax":
        return (x, s64)
    if kind == "pivot_band":
        return (x, s32, s32, s32, s64)
    raise ValueError(f"unknown artifact kind {kind!r}")


ARTIFACTS = {
    "count_pivot": make_count_pivot,
    "band_count": make_band_count,
    "band_extract": make_band_extract,
    "histogram": make_histogram,
    "minmax": make_minmax,
    "pivot_band": make_pivot_band,
}
