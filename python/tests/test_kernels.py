"""L1 kernel vs pure-jnp oracle — the core build-time correctness signal.

Hypothesis sweeps buffer geometry (buf_len, chunk), dtypes, valid-prefix
lengths (including 0 and full), and adversarial value placement (pivot
present/absent, duplicates, extremes). All kernel outputs are integer
counts or exact extremes, so comparisons are exact.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    build_band_count,
    build_band_extract,
    build_count_pivot,
    build_histogram,
    build_minmax,
)
from compile.kernels.ref import (
    ref_band_count,
    ref_band_extract,
    ref_count_pivot,
    ref_histogram,
    ref_minmax,
)

I32 = np.iinfo(np.int32)

# (buf_len, chunk) geometries: single-tile, multi-tile, non-power-of-two grid
GEOMETRIES = [(64, 64), (128, 32), (192, 64), (1024, 256)]

DTYPES = [jnp.int32, jnp.float32]


def pad_to(x, buf_len, fill):
    out = np.full((buf_len,), fill, dtype=x.dtype)
    out[: len(x)] = x
    return out


@st.composite
def data_and_pivot(draw, buf_len):
    n = draw(st.integers(min_value=0, max_value=buf_len))
    values = draw(
        st.lists(
            st.integers(min_value=-(10**9), max_value=10**9 - 1),
            min_size=n,
            max_size=n,
        )
    )
    # pivot: either drawn from the data (forcing eq hits) or arbitrary
    if values and draw(st.booleans()):
        pivot = draw(st.sampled_from(values))
    else:
        pivot = draw(st.integers(min_value=-(10**9), max_value=10**9 - 1))
    return np.array(values, dtype=np.int64), pivot, n


class TestCountPivot:
    @pytest.mark.parametrize("buf_len,chunk", GEOMETRIES)
    @pytest.mark.parametrize("dtype", DTYPES)
    @settings(max_examples=25, deadline=None)
    @given(dp=data_and_pivot(64))
    def test_matches_ref(self, buf_len, chunk, dtype, dp):
        values, pivot, n = dp
        fn = build_count_pivot(buf_len, chunk, dtype)
        x = pad_to(values.astype(np.int32), buf_len, I32.max)
        got = fn(
            jnp.asarray(x),
            jnp.asarray([pivot], jnp.int32),
            jnp.asarray([n], jnp.int64),
        )
        want = ref_count_pivot(
            jnp.asarray(x).astype(dtype), jnp.asarray(pivot, dtype), n
        )
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert int(np.asarray(got).sum()) == n  # partition of the valid prefix

    def test_empty_prefix(self):
        fn = build_count_pivot(64, 32)
        got = fn(
            jnp.zeros(64, jnp.int32),
            jnp.asarray([5], jnp.int32),
            jnp.asarray([0], jnp.int64),
        )
        np.testing.assert_array_equal(np.asarray(got), [0, 0, 0])

    def test_all_equal(self):
        fn = build_count_pivot(128, 32)
        x = np.full(128, 7, np.int32)
        got = fn(jnp.asarray(x), jnp.asarray([7], jnp.int32), jnp.asarray([100], jnp.int64))
        np.testing.assert_array_equal(np.asarray(got), [0, 100, 0])

    def test_extremes(self):
        fn = build_count_pivot(64, 64)
        x = np.array([I32.min, I32.max] * 16, np.int32)
        x = pad_to(x, 64, 0)
        got = fn(jnp.asarray(x), jnp.asarray([0], jnp.int32), jnp.asarray([32], jnp.int64))
        np.testing.assert_array_equal(np.asarray(got), [16, 0, 16])

    def test_bad_geometry_raises(self):
        with pytest.raises(ValueError):
            build_count_pivot(100, 64)


class TestBandCount:
    @pytest.mark.parametrize("buf_len,chunk", GEOMETRIES)
    @settings(max_examples=25, deadline=None)
    @given(dp=data_and_pivot(64), span=st.integers(0, 10**8))
    def test_matches_ref(self, buf_len, chunk, dp, span):
        values, lo, n = dp
        hi = min(lo + span, 10**9 - 1)
        fn = build_band_count(buf_len, chunk)
        x = pad_to(values.astype(np.int32), buf_len, I32.max)
        got = fn(
            jnp.asarray(x),
            jnp.asarray([lo], jnp.int32),
            jnp.asarray([hi], jnp.int32),
            jnp.asarray([n], jnp.int64),
        )
        want = ref_band_count(jnp.asarray(x), lo, hi, n)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert int(np.asarray(got).sum()) == n

    def test_inverted_band_is_empty(self):
        fn = build_band_count(64, 32)
        x = np.arange(64, dtype=np.int32)
        got = fn(
            jnp.asarray(x),
            jnp.asarray([50], jnp.int32),
            jnp.asarray([10], jnp.int32),
            jnp.asarray([64], jnp.int64),
        )
        assert int(np.asarray(got)[1]) == 0


class TestBandExtract:
    @pytest.mark.parametrize("buf_len,chunk", GEOMETRIES)
    @settings(max_examples=25, deadline=None)
    @given(dp=data_and_pivot(64), span=st.integers(0, 10**8))
    def test_matches_ref(self, buf_len, chunk, dp, span):
        values, lo, n = dp
        hi = min(lo + span, 10**9 - 1)
        pivot = lo
        fn = build_band_extract(buf_len, chunk)
        x = pad_to(values.astype(np.int32), buf_len, I32.max)
        got = np.asarray(
            fn(
                jnp.asarray(x),
                jnp.asarray([pivot], jnp.int32),
                jnp.asarray([lo], jnp.int32),
                jnp.asarray([hi], jnp.int32),
                jnp.asarray([n], jnp.int64),
            )
        )
        counts, cands = ref_band_extract(
            jnp.asarray(x), jnp.asarray(pivot), jnp.asarray(lo), jnp.asarray(hi), n
        )
        np.testing.assert_array_equal(got[:6], np.asarray(counts))
        inner = int(got[4])
        assert inner == len(np.asarray(cands))
        # compaction preserves the open-band multiset, in order
        np.testing.assert_array_equal(got[6 : 6 + inner], np.asarray(cands))
        # and the rest of the packed slot is untouched zeros
        np.testing.assert_array_equal(got[6 + inner :], np.zeros(buf_len - inner))
        # the buckets partition the prefix (lo == hi aliases the endpoint
        # counters; the rust wrapper zeroes eq_hi in that case)
        eq_hi = 0 if lo == hi else int(got[5])
        above = n - int(got[2] + got[3] + got[4]) - eq_hi
        assert above >= 0

    def test_empty_prefix(self):
        fn = build_band_extract(64, 32)
        got = np.asarray(
            fn(
                jnp.zeros(64, jnp.int32),
                jnp.asarray([1], jnp.int32),
                jnp.asarray([0], jnp.int32),
                jnp.asarray([5], jnp.int32),
                jnp.asarray([0], jnp.int64),
            )
        )
        np.testing.assert_array_equal(got[:6], [0, 0, 0, 0, 0, 0])
        assert got[6:].sum() == 0

    def test_extraction_is_open_interval(self):
        # endpoints are counted, not extracted — the duplicate-heavy
        # guarantee the two-round protocol relies on
        fn = build_band_extract(64, 32)
        x = pad_to(np.array([10, 20, 20, 25, 30, 30, 30, 40], np.int32), 64, 0)
        got = np.asarray(
            fn(
                jnp.asarray(x),
                jnp.asarray([25], jnp.int32),
                jnp.asarray([20], jnp.int32),
                jnp.asarray([30], jnp.int32),
                jnp.asarray([8], jnp.int64),
            )
        )
        # [lt, eq, below, eq_lo, inner, eq_hi]
        np.testing.assert_array_equal(got[:6], [3, 1, 1, 2, 1, 3])
        assert got[6] == 25
        assert got[7:].sum() == 0

    def test_collapsed_band(self):
        fn = build_band_extract(64, 32)
        x = pad_to(np.array([1, 2, 2, 3], np.int32), 64, 0)
        got = np.asarray(
            fn(
                jnp.asarray(x),
                jnp.asarray([2], jnp.int32),
                jnp.asarray([2], jnp.int32),
                jnp.asarray([2], jnp.int32),
                jnp.asarray([4], jnp.int64),
            )
        )
        # lo == hi: inner empty, both endpoint counters see the run (the
        # rust wrapper zeroes eq_hi when normalizing)
        np.testing.assert_array_equal(got[:6], [1, 2, 1, 2, 0, 2])
        assert got[6:].sum() == 0

    def test_multi_chunk_compaction(self):
        # candidates spread across several tiles must compact contiguously
        fn = build_band_extract(128, 32)
        x = np.zeros(128, np.int32)
        x[5], x[40], x[70], x[100] = 11, 12, 13, 14
        got = np.asarray(
            fn(
                jnp.asarray(x),
                jnp.asarray([12], jnp.int32),
                jnp.asarray([10], jnp.int32),
                jnp.asarray([15], jnp.int32),
                jnp.asarray([128], jnp.int64),
            )
        )
        assert int(got[4]) == 4
        np.testing.assert_array_equal(got[6:10], [11, 12, 13, 14])
        assert got[10:].sum() == 0


class TestHistogram:
    @pytest.mark.parametrize("buf_len,chunk", [(64, 32), (256, 64)])
    @pytest.mark.parametrize("nbins", [4, 16, 128])
    @settings(max_examples=20, deadline=None)
    @given(dp=data_and_pivot(64))
    def test_matches_ref(self, buf_len, chunk, nbins, dp):
        values, _, n = dp
        lo, width = -(10**9), (2 * 10**9) // nbins + 1
        fn = build_histogram(buf_len, chunk, nbins)
        x = pad_to(values.astype(np.int32), buf_len, 0)
        got = fn(
            jnp.asarray(x),
            jnp.asarray([lo], jnp.int64),
            jnp.asarray([width], jnp.int64),
            jnp.asarray([n], jnp.int64),
        )
        want = ref_histogram(jnp.asarray(x), lo, width, nbins, n)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert int(np.asarray(got).sum()) == n

    def test_out_of_range_clamps(self):
        fn = build_histogram(64, 32, 8)
        x = pad_to(np.array([-100, 100], np.int32), 64, 0)
        # range [0, 8*4) => -100 clamps to bin 0, 100 clamps to bin 7
        got = np.asarray(
            fn(
                jnp.asarray(x),
                jnp.asarray([0], jnp.int64),
                jnp.asarray([4], jnp.int64),
                jnp.asarray([2], jnp.int64),
            )
        )
        assert got[0] == 1 and got[7] == 1 and got.sum() == 2

    def test_total_mass_preserved(self):
        fn = build_histogram(256, 64, 16)
        rng = np.random.default_rng(0)
        x = rng.integers(I32.min, I32.max, 256).astype(np.int32)
        got = np.asarray(
            fn(
                jnp.asarray(x),
                jnp.asarray([I32.min], jnp.int64),
                jnp.asarray([(2**32) // 16 + 1], jnp.int64),
                jnp.asarray([200], jnp.int64),
            )
        )
        assert got.sum() == 200


class TestMinMax:
    @pytest.mark.parametrize("buf_len,chunk", GEOMETRIES)
    @settings(max_examples=25, deadline=None)
    @given(dp=data_and_pivot(64))
    def test_matches_ref(self, buf_len, chunk, dp):
        values, _, n = dp
        fn = build_minmax(buf_len, chunk)
        x = pad_to(values.astype(np.int32), buf_len, 0)
        got = fn(jnp.asarray(x), jnp.asarray([n], jnp.int64))
        want = ref_minmax(jnp.asarray(x), n)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_empty_sentinel(self):
        fn = build_minmax(64, 32)
        got = np.asarray(fn(jnp.zeros(64, jnp.int32), jnp.asarray([0], jnp.int64)))
        assert got[0] == I32.max and got[1] == I32.min

    def test_singleton(self):
        fn = build_minmax(64, 32)
        x = pad_to(np.array([-42], np.int32), 64, 99)
        got = np.asarray(fn(jnp.asarray(x), jnp.asarray([1], jnp.int64)))
        assert got[0] == -42 and got[1] == -42
