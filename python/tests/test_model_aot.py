"""L2 model composition + AOT lowering sanity.

Checks that every artifact kind lowers to parseable HLO text with the
expected parameter/result shapes, and that the fused pivot_band pass agrees
with running the two kernels separately.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model
from compile.aot import lower_artifact
from compile.kernels.ref import ref_band_count, ref_count_pivot


def small_geometry(monkeypatch):
    monkeypatch.setattr(model, "BUF_LEN", 256)
    monkeypatch.setattr(model, "CHUNK", 64)
    monkeypatch.setattr(model, "HIST_CHUNK", 64)
    monkeypatch.setattr(model, "NBINS", 16)


@pytest.mark.parametrize("kind", sorted(model.ARTIFACTS))
def test_lowering_produces_hlo_text(kind, monkeypatch):
    small_geometry(monkeypatch)
    text = lower_artifact(kind)
    assert "HloModule" in text
    assert "ENTRY" in text
    # buffer parameter shape survives lowering
    assert f"s32[{model.BUF_LEN}]" in text


def test_pivot_band_fusion_matches_separate(monkeypatch):
    small_geometry(monkeypatch)
    rng = np.random.default_rng(7)
    x = rng.integers(-1000, 1000, model.BUF_LEN).astype(np.int32)
    n, pivot, lo, hi = 200, 13, -100, 250

    fused = model.make_pivot_band(model.BUF_LEN, model.CHUNK)
    counts, band = fused(
        jnp.asarray(x),
        jnp.asarray([pivot], jnp.int32),
        jnp.asarray([lo], jnp.int32),
        jnp.asarray([hi], jnp.int32),
        jnp.asarray([n], jnp.int64),
    )
    np.testing.assert_array_equal(
        np.asarray(counts), np.asarray(ref_count_pivot(jnp.asarray(x), pivot, n))
    )
    np.testing.assert_array_equal(
        np.asarray(band), np.asarray(ref_band_count(jnp.asarray(x), lo, hi, n))
    )


def test_example_args_match_artifacts():
    for kind in model.ARTIFACTS:
        args = model.example_args(kind)
        assert args[0].shape == (model.BUF_LEN,)
    with pytest.raises(ValueError):
        model.example_args("nope")


def test_jit_executes_count_pivot(monkeypatch):
    small_geometry(monkeypatch)
    fn = jax.jit(model.make_count_pivot(model.BUF_LEN, model.CHUNK))
    x = jnp.arange(model.BUF_LEN, dtype=jnp.int32)
    (out,) = fn(x, jnp.asarray([10], jnp.int32), jnp.asarray([100], jnp.int64))
    np.testing.assert_array_equal(np.asarray(out), [10, 1, 89])
