//! Property tests for the engine-lifetime metrics registry: for any
//! random interleaving of ingests, batch/stream/sketched queries, and
//! recoverable chaos, under BOTH exec modes,
//!
//! * the registry's lifetime totals — per (kind, stream) bin and the
//!   grand total — are exactly the sum of the per-operation
//!   [`MetricsReport`]s the engine handed out (u64 counters bit-exact,
//!   modelled seconds up to float associativity),
//! * the latency folds account for every task attempt of every report,
//! * the Prometheus render is deterministic (two renders of one state
//!   are byte-identical) with totals in sorted key order,
//! * the qlog carries one parseable JSON line per operation, in order,
//!   agreeing with the report it logs,
//! * and `MetricsMode::Off` (the default) is invisible: same answers,
//!   same protocol counters, zero registry state.
//!
//! Every engine pins its metrics mode explicitly, so `GKSELECT_METRICS`
//! cannot perturb what these properties measure.

use gkselect::cluster::dataset::Dataset;
use gkselect::cluster::metrics::MetricsReport;
use gkselect::cluster::{ClusterConfig, ExecMode, FaultPlan};
use gkselect::engine::{AlgoChoice, EngineBuilder, QuantileEngine, QuantileQuery, Source};
use gkselect::obs::registry::OpTotals;
use gkselect::obs::{MetricsMode, OpKind};
use gkselect::stream::MicroBatch;
use gkselect::util::minijson;
use gkselect::util::propkit::{check, Gen};
use gkselect::Key;

fn gen_geometry(g: &mut Gen) -> (usize, usize) {
    let executors = g.usize_in(1, 3);
    let partitions = executors * g.usize_in(1, 3);
    (executors, partitions)
}

fn gen_values(g: &mut Gen, min: usize) -> Vec<Key> {
    let n = g.usize_in(min, 800);
    (0..n).map(|_| g.i32_in(-500_000, 500_000)).collect()
}

/// Recoverable plan (mirrors `proptest_trace.rs`): every fault retires
/// within the default retry budget, straggler multipliers stay off the
/// 2.0 speculation boundary so outcomes are mode-independent.
fn gen_recoverable_plan(g: &mut Gen, partitions: usize) -> FaultPlan {
    let mut plan = FaultPlan::seeded(g.u64())
        .panics(g.f64_unit() * 0.2)
        .transients(g.f64_unit() * 0.25);
    if g.bool() {
        plan = plan.stragglers(g.f64_unit() * 0.4, 2.5 + g.f64_unit() * 2.0);
    }
    if g.bool() {
        plan = plan.panic_task(g.usize_in(0, 1) as u64, g.usize_in(0, partitions - 1));
    }
    plan
}

/// One step of the random workload script, replayed identically in both
/// exec modes.
#[derive(Debug, Clone)]
enum Op {
    Batch(QuantileQuery),
    Ingest(&'static str, Vec<Key>),
    StreamQuery(&'static str, QuantileQuery),
}

/// Random interleaving of ingest/query ops. Stream queries only target
/// streams a prior op has ingested, so every script is executable.
fn gen_script(g: &mut Gen) -> Vec<Op> {
    const STREAMS: [&str; 2] = ["alpha", "beta"];
    let mut ingested: Vec<&'static str> = Vec::new();
    let len = g.usize_in(3, 8);
    let mut script = Vec::with_capacity(len);
    for _ in 0..len {
        let roll = g.usize_in(0, 3);
        if roll == 0 || (roll == 2 && ingested.is_empty()) {
            let id = STREAMS[g.usize_in(0, 1)];
            if !ingested.contains(&id) {
                ingested.push(id);
            }
            script.push(Op::Ingest(id, gen_values(g, 1)));
        } else if roll == 2 {
            let id = ingested[g.usize_in(0, ingested.len() - 1)];
            let q = if g.bool() {
                QuantileQuery::Single(g.f64_unit())
            } else {
                QuantileQuery::Sketched { q: g.f64_unit(), eps: 0.05 }
            };
            script.push(Op::StreamQuery(id, q));
        } else {
            let q = match g.usize_in(0, 3) {
                0 => QuantileQuery::Single(g.f64_unit()),
                1 => QuantileQuery::Multi(vec![0.25, g.f64_unit(), 0.95]),
                2 => QuantileQuery::Rank(0),
                _ => QuantileQuery::Sketched { q: g.f64_unit(), eps: 0.05 },
            };
            script.push(Op::Batch(q));
        }
    }
    script
}

fn engine(
    executors: usize,
    partitions: usize,
    mode: ExecMode,
    faults: Option<FaultPlan>,
    metrics: MetricsMode,
) -> QuantileEngine {
    EngineBuilder::new()
        .cluster(
            ClusterConfig::local(executors, partitions)
                .with_exec_mode(mode)
                .with_fault_plan(faults),
        )
        .algorithm(AlgoChoice::GkSelect)
        .metrics(metrics)
        .build()
        .unwrap()
}

/// Run the script, returning each operation's (key, report) in order.
fn run_script(
    eng: &mut QuantileEngine,
    data: &Dataset<Key>,
    script: &[Op],
) -> Vec<((OpKind, String), MetricsReport)> {
    let mut out = Vec::with_capacity(script.len());
    for op in script {
        match op {
            Op::Batch(q) => {
                let r = eng.execute(Source::Dataset(data), q.clone()).unwrap();
                out.push(((r.op_kind(), String::new()), r.report));
            }
            Op::Ingest(id, values) => {
                let r = eng.ingest(id, MicroBatch::new(values.clone())).unwrap();
                out.push(((OpKind::Ingest, id.to_string()), r.report));
            }
            Op::StreamQuery(id, q) => {
                let r = eng.execute(Source::Stream(id), q.clone()).unwrap();
                out.push(((r.op_kind(), id.to_string()), r.report));
            }
        }
    }
    out
}

/// Reference accumulator: sum reports into an [`OpTotals`] by hand,
/// field by field — the independent ledger the registry must match.
fn sum_reports<'a>(reports: impl Iterator<Item = &'a MetricsReport>) -> OpTotals {
    let mut t = OpTotals::default();
    for r in reports {
        t.ops += 1;
        t.records += r.n;
        t.rounds += r.rounds;
        t.stage_boundaries += r.stage_boundaries;
        t.data_scans += r.data_scans;
        t.shuffles += r.shuffles;
        t.persists += r.persists;
        t.bytes_to_driver += r.bytes_to_driver;
        t.bytes_shuffled += r.bytes_shuffled;
        t.bytes_tree_reduced += r.bytes_tree_reduced;
        t.bytes_broadcast += r.bytes_broadcast;
        t.bytes_persisted += r.bytes_persisted;
        t.messages += r.messages;
        t.faults_injected += r.faults_injected;
        t.tasks_retried += r.tasks_retried;
        t.speculative_launched += r.speculative_launched;
        t.speculative_wins += r.speculative_wins;
        t.degraded_queries += r.degraded_queries;
        t.band_candidates += r.band_candidates;
        t.band_budget += r.band_budget;
        t.elapsed_secs += r.elapsed_secs;
        t.wall_stage_secs += r.wall_stage_secs;
    }
    t
}

/// u64 counters must match bit-exactly; the float sums only up to
/// associativity (the registry adds per-bin, then merges bins).
fn assert_totals_eq(got: &OpTotals, want: &OpTotals, what: &str) {
    let strip = |t: &OpTotals| OpTotals {
        elapsed_secs: 0.0,
        wall_stage_secs: 0.0,
        ..t.clone()
    };
    assert_eq!(strip(got), strip(want), "{what}: u64 counters must be the exact sum");
    assert!(
        (got.elapsed_secs - want.elapsed_secs).abs() <= 1e-9 * (1.0 + want.elapsed_secs.abs()),
        "{what}: elapsed_secs {} vs {}",
        got.elapsed_secs,
        want.elapsed_secs
    );
    assert!(
        (got.wall_stage_secs - want.wall_stage_secs).abs()
            <= 1e-9 * (1.0 + want.wall_stage_secs.abs()),
        "{what}: wall_stage_secs {} vs {}",
        got.wall_stage_secs,
        want.wall_stage_secs
    );
}

#[test]
fn prop_registry_totals_are_the_exact_sum_of_reports() {
    check("registry_totals_sum", 15, |g| {
        let (executors, partitions) = gen_geometry(g);
        let data = Dataset::from_vec(gen_values(g, 32), partitions).unwrap();
        let script = gen_script(g);
        let plan = g.bool().then(|| gen_recoverable_plan(g, partitions));

        for mode in [ExecMode::Sequential, ExecMode::Threads] {
            let mut eng =
                engine(executors, partitions, mode, plan.clone(), MetricsMode::Memory);
            let ledger = run_script(&mut eng, &data, &script);
            let snap = eng.metrics_snapshot();

            assert_eq!(snap.ops, ledger.len() as u64, "one absorb per operation");
            assert_eq!(snap.exec_mode, mode.label());

            // grand total == sum over every report, independent of binning
            assert_totals_eq(
                &snap.grand(),
                &sum_reports(ledger.iter().map(|(_, r)| r)),
                &format!("grand [{mode:?}]"),
            );
            // each (kind, stream) bin == sum over exactly its reports,
            // and no bin exists without a report behind it
            let mut keys: Vec<_> = ledger.iter().map(|(k, _)| k.clone()).collect();
            keys.sort();
            keys.dedup();
            assert_eq!(
                snap.totals.iter().map(|(k, _)| k.clone()).collect::<Vec<_>>(),
                keys,
                "bins are exactly the keys seen, in sorted order [{mode:?}]"
            );
            for key in &keys {
                let want = sum_reports(
                    ledger.iter().filter(|(k, _)| k == key).map(|(_, r)| r),
                );
                let got = snap.totals_for(key.0, &key.1).unwrap();
                assert_totals_eq(got, &want, &format!("bin {key:?} [{mode:?}]"));
            }
            // band efficiency ≤ 1.0 on every bin and the grand total:
            // extracts truncate at their budget, so sums can't exceed it
            for (key, t) in &snap.totals {
                assert!(t.band_efficiency() <= 1.0, "bin {key:?} [{mode:?}]");
                assert!(t.band_candidates <= t.band_budget, "bin {key:?} [{mode:?}]");
            }
            // latency folds account for every task attempt of every report
            for l in &snap.latency {
                let attempts: u64 = ledger
                    .iter()
                    .filter(|((k, _), _)| *k == l.kind)
                    .flat_map(|(_, r)| r.stage_attempt_us.iter())
                    .map(|stage| stage.len() as u64)
                    .sum();
                assert_eq!(l.tasks, attempts, "latency fold {:?} [{mode:?}]", l.kind);
                assert!(l.p50_us <= l.p95_us && l.p95_us <= l.p99_us && l.p99_us <= l.max_us);
            }
            // residency gauges mirror the store: every ingested stream
            // sampled, records exact (compaction never drops records)
            for (id, res) in &snap.residency {
                let ingested: u64 = script
                    .iter()
                    .filter_map(|op| match op {
                        Op::Ingest(s, v) if *s == id.as_str() => Some(v.len() as u64),
                        _ => None,
                    })
                    .sum();
                assert_eq!(res.records, ingested, "stream {id} records [{mode:?}]");
                assert!(res.sealed_epochs >= res.live_epochs);
            }
        }
    });
}

#[test]
fn prop_prometheus_render_is_deterministic_and_sorted() {
    check("registry_prom_stable", 10, |g| {
        let (executors, partitions) = gen_geometry(g);
        let data = Dataset::from_vec(gen_values(g, 32), partitions).unwrap();
        let script = gen_script(g);
        let mut eng =
            engine(executors, partitions, ExecMode::Sequential, None, MetricsMode::Memory);
        let ledger = run_script(&mut eng, &data, &script);

        let a = eng.registry().render_prometheus();
        let b = eng.registry().render_prometheus();
        assert_eq!(a, b, "two renders of one state are byte-identical");

        // every family that has a series also has HELP and TYPE heads
        for name in ["gkselect_ops_total", "gkselect_bytes_total", "gkselect_band_efficiency_ratio"]
        {
            assert!(a.contains(&format!("# HELP {name} ")), "{name} HELP");
            assert!(a.contains(&format!("# TYPE {name} ")), "{name} TYPE");
        }
        // ops series come out in the snapshot's sorted key order
        let rendered: Vec<&str> = a
            .lines()
            .filter(|l| l.starts_with("gkselect_ops_total{"))
            .collect();
        let snap = eng.metrics_snapshot();
        assert_eq!(rendered.len(), snap.totals.len());
        for (line, ((kind, stream), t)) in rendered.iter().zip(&snap.totals) {
            assert!(
                line.contains(&format!("kind=\"{}\"", kind.label()))
                    && line.contains(&format!("stream=\"{stream}\""))
                    && line.ends_with(&format!(" {}", t.ops)),
                "series order mirrors the sorted snapshot: {line}"
            );
        }
        // the absorbed ledger is what the scrape reports
        assert!(a.contains(&format!(
            "gkselect_ops_total{{kind=\"{}\",stream=\"\",exec_mode=\"sequential\"",
            ledger
                .iter()
                .find(|((_, s), _)| s.is_empty())
                .map(|((k, _), _)| k.label())
                .unwrap_or("batch"),
        )) || ledger.iter().all(|((_, s), _)| !s.is_empty()));
    });
}

#[test]
fn prop_qlog_is_one_parseable_line_per_operation() {
    check("registry_qlog_parses", 10, |g| {
        let (executors, partitions) = gen_geometry(g);
        let data = Dataset::from_vec(gen_values(g, 32), partitions).unwrap();
        let script = gen_script(g);
        let mut eng =
            engine(executors, partitions, ExecMode::Sequential, None, MetricsMode::Memory);
        let ledger = run_script(&mut eng, &data, &script);

        let lines = eng.registry().qlog_lines().to_vec();
        assert_eq!(lines.len(), ledger.len(), "one qlog line per operation");
        for (i, (line, ((kind, stream), report))) in lines.iter().zip(&ledger).enumerate() {
            let j = minijson::parse(line)
                .unwrap_or_else(|e| panic!("qlog line {i} must parse: {e}\n{line}"));
            assert_eq!(j.get("seq").and_then(|v| v.as_u64()), Some(i as u64 + 1));
            assert_eq!(
                j.get("op").and_then(|v| v.as_str()),
                Some(kind.label()),
                "line {i}"
            );
            assert_eq!(j.get("n").and_then(|v| v.as_u64()), Some(report.n), "line {i}");
            assert_eq!(
                j.get("rounds").and_then(|v| v.as_u64()),
                Some(report.rounds),
                "line {i}"
            );
            assert_eq!(
                j.get("bytes_moved").and_then(|v| v.as_u64()),
                Some(report.network_volume_bytes),
                "line {i}"
            );
            // stream field present exactly for stream-keyed ops; no
            // trace field because no trace sink is armed here
            assert_eq!(
                j.get("stream").and_then(|v| v.as_str()),
                (!stream.is_empty()).then_some(stream.as_str()),
                "line {i}"
            );
            assert!(j.get("trace").is_none(), "line {i}: no sink, no join key");
            assert!(j.get("band_efficiency").is_some(), "line {i}");
        }
    });
}

#[test]
fn prop_off_mode_is_invisible() {
    check("registry_off_invisible", 10, |g| {
        let (executors, partitions) = gen_geometry(g);
        let data = Dataset::from_vec(gen_values(g, 32), partitions).unwrap();
        let script = gen_script(g);

        // MetricsMode::Off is the builder default — this run IS the
        // metrics-disabled configuration
        let mut off_eng =
            engine(executors, partitions, ExecMode::Sequential, None, MetricsMode::Off);
        assert!(!off_eng.registry().is_enabled());
        let off = run_script(&mut off_eng, &data, &script);

        let mut on_eng =
            engine(executors, partitions, ExecMode::Sequential, None, MetricsMode::Memory);
        let on = run_script(&mut on_eng, &data, &script);

        // zero registry state with Off...
        let snap = off_eng.metrics_snapshot();
        assert_eq!((snap.ops, off_eng.registry().ops()), (0, 0));
        assert!(snap.totals.is_empty());
        assert!(snap.latency.is_empty());
        assert!(snap.residency.is_empty());
        assert!(off_eng.registry().qlog_lines().is_empty());
        // ...and identical operations: same keys, same protocol counters
        assert_eq!(off.len(), on.len());
        for (i, ((ka, ra), (kb, rb))) in off.iter().zip(&on).enumerate() {
            assert_eq!(ka, kb, "op {i}");
            assert_eq!(
                (ra.rounds, ra.data_scans, ra.n, ra.network_volume_bytes),
                (rb.rounds, rb.data_scans, rb.n, rb.network_volume_bytes),
                "absorbing must not change what op {i} reports"
            );
        }
    });
}
