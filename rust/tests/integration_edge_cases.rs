//! Adversarial / failure-injection integration tests: degenerate
//! partitioning, extreme values, pathological duplicates, and sketch
//! variants — the inputs a production deployment actually sees. Every
//! query goes through `QuantileEngine::execute`.

use gkselect::algorithms::approx_quantile::{MergeStrategy, SketchVariant};
use gkselect::prelude::*;
use gkselect::Key;

fn gk_engine(parts: usize, eps: f64, variant: SketchVariant) -> QuantileEngine {
    EngineBuilder::new()
        .cluster(ClusterConfig::local(2, parts.max(2)))
        .algorithm(AlgoChoice::GkSelect)
        .epsilon(eps)
        .sketch_variant(variant)
        .build()
        .unwrap()
}

fn engine_of(parts: usize, choice: AlgoChoice) -> QuantileEngine {
    EngineBuilder::new()
        .cluster(ClusterConfig::local(2, parts.max(2)))
        .algorithm(choice)
        .build()
        .unwrap()
}

fn check_exact(engine: &mut QuantileEngine, data: &Dataset<Key>, q: f64) {
    let truth = oracle_quantile(data, q).unwrap();
    let out = engine
        .execute(Source::Dataset(data), QuantileQuery::Single(q))
        .unwrap();
    assert_eq!(out.value(), truth, "{} q={q}", out.report.algorithm);
}

#[test]
fn empty_partitions_interleaved() {
    let data = Dataset::from_partitions(vec![
        vec![],
        vec![5, 1, 9],
        vec![],
        vec![3],
        vec![],
        vec![7, 2, 8, 4, 6],
    ])
    .unwrap();
    for q in [0.0, 0.5, 1.0] {
        check_exact(&mut gk_engine(6, 0.05, SketchVariant::Bulk), &data, q);
        check_exact(&mut gk_engine(6, 0.05, SketchVariant::Modified), &data, q);
        check_exact(&mut engine_of(6, AlgoChoice::HistSelect), &data, q);
        check_exact(&mut engine_of(6, AlgoChoice::Afs), &data, q);
    }
}

#[test]
fn single_record_per_partition() {
    let data = Dataset::from_partitions((0..16).map(|i| vec![i * 7 % 13]).collect()).unwrap();
    for q in [0.0, 0.33, 0.5, 1.0] {
        check_exact(&mut gk_engine(16, 0.1, SketchVariant::Bulk), &data, q);
        check_exact(&mut engine_of(16, AlgoChoice::Jeffers), &data, q);
    }
}

#[test]
fn i32_extremes_dataset() {
    let mut vals = vec![Key::MIN; 100];
    vals.extend(vec![Key::MAX; 100]);
    vals.extend(vec![0; 100]);
    vals.extend(-50..50);
    let data = Dataset::from_vec(vals, 8).unwrap();
    for q in [0.0, 0.25, 0.5, 0.75, 1.0] {
        check_exact(&mut gk_engine(8, 0.02, SketchVariant::Bulk), &data, q);
        check_exact(&mut engine_of(8, AlgoChoice::FullSort), &data, q);
        check_exact(&mut engine_of(8, AlgoChoice::HistSelect), &data, q);
    }
}

#[test]
fn two_value_distribution() {
    // k lands exactly at the value boundary — exercises the eq-run exit
    let mut vals = vec![1; 5_000];
    vals.extend(vec![2; 5_000]);
    let data = Dataset::from_vec(vals, 8).unwrap();
    for q in [0.4999, 0.5, 0.5001] {
        check_exact(&mut gk_engine(8, 0.01, SketchVariant::Bulk), &data, q);
    }
}

#[test]
fn severely_skewed_partition_sizes() {
    // one giant partition + many tiny ones (real ingestion skew)
    let mut parts: Vec<Vec<Key>> = vec![(0..50_000).map(|i| i * 3 % 1000).collect()];
    for i in 0..15 {
        parts.push(vec![i]);
    }
    let data = Dataset::from_partitions(parts).unwrap();
    for q in [0.1, 0.5, 0.9] {
        check_exact(&mut gk_engine(16, 0.01, SketchVariant::Bulk), &data, q);
        check_exact(&mut gk_engine(16, 0.01, SketchVariant::Spark), &data, q);
    }
}

#[test]
fn all_sketch_variants_give_exact_gk_select() {
    let mut scratch = Cluster::new(ClusterConfig::local(2, 8));
    let data = gkselect::data::Distribution::Bimodal
        .generator(7)
        .generate(&mut scratch, 40_000);
    let truth = oracle_quantile(&data, 0.9).unwrap();
    for variant in [
        SketchVariant::Classical,
        SketchVariant::Spark,
        SketchVariant::Modified,
        SketchVariant::Bulk,
    ] {
        let mut engine = gk_engine(8, 0.01, variant);
        let out = engine
            .execute(Source::Dataset(&data), QuantileQuery::Single(0.9))
            .unwrap();
        assert_eq!(out.value(), truth, "variant {variant:?}");
    }
    // merge strategies too
    for merge in [MergeStrategy::Fold, MergeStrategy::Tree] {
        let mut engine = EngineBuilder::new()
            .cluster(ClusterConfig::local(2, 8))
            .algorithm(AlgoChoice::GkSelect)
            .sketch_merge(merge)
            .build()
            .unwrap();
        let out = engine
            .execute(Source::Dataset(&data), QuantileQuery::Single(0.9))
            .unwrap();
        assert_eq!(out.value(), truth, "merge {merge:?}");
    }
}

#[test]
fn epsilon_extremes_still_exact() {
    let mut scratch = Cluster::new(ClusterConfig::local(2, 8));
    let data = gkselect::data::Distribution::Uniform
        .generator(8)
        .generate(&mut scratch, 30_000);
    let truth = oracle_quantile(&data, 0.5).unwrap();
    for eps in [0.4, 0.001] {
        let mut engine = gk_engine(8, eps, SketchVariant::Bulk);
        let out = engine
            .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
            .unwrap();
        assert_eq!(out.value(), truth, "eps {eps}");
    }
}

#[test]
fn quantile_sweep_dense() {
    // every percentile over a small dataset — catches off-by-one rank
    // conventions
    let data = Dataset::from_vec((0..1000).rev().collect::<Vec<Key>>(), 4).unwrap();
    let mut engine = gk_engine(4, 0.05, SketchVariant::Bulk);
    for pct in 0..=100 {
        let q = pct as f64 / 100.0;
        let truth = oracle_quantile(&data, q).unwrap();
        let out = engine
            .execute(Source::Dataset(&data), QuantileQuery::Single(q))
            .unwrap();
        assert_eq!(out.value(), truth, "pct={pct}");
    }
}

#[test]
fn more_partitions_than_values() {
    let data = Dataset::from_vec(vec![3, 1, 2], 12).unwrap();
    check_exact(&mut gk_engine(12, 0.1, SketchVariant::Bulk), &data, 0.5);
    check_exact(&mut engine_of(12, AlgoChoice::FullSort), &data, 0.5);
}
