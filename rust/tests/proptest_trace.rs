//! Property tests for the span-tracing layer: for any random cluster
//! geometry, dataset, and recoverable [`FaultPlan`], the trace tree is
//!
//! * structurally well-formed (one root, attempts under stages, parents
//!   resolve backwards),
//! * identical in shape under `ExecMode::Sequential` and
//!   `ExecMode::Threads` (attempt stitching is deterministic),
//! * an exact ledger of the fault layer — retry and speculation spans
//!   appear exactly where the plan injects them, and a recovered run
//!   differs from the fault-free run only by its attempt spans —
//!
//! and the default `TraceSink::Null` is invisible: same values, same
//! protocol counters, no trace on the outcome. Every engine here pins
//! its trace mode explicitly, so `GKSELECT_TRACE` (like the CI chaos
//! job's `GKSELECT_FAULTS` in `proptest_faults.rs`) cannot perturb what
//! these properties measure.

use gkselect::cluster::dataset::Dataset;
use gkselect::cluster::{ClusterConfig, ExecMode, FaultPlan};
use gkselect::engine::{AlgoChoice, EngineBuilder, QuantileEngine, QuantileQuery, Source};
use gkselect::obs::{AttemptOutcome, SpanKind, Trace, TraceMode};
use gkselect::stream::MicroBatch;
use gkselect::util::propkit::{check, Gen};
use gkselect::Key;

fn gen_geometry(g: &mut Gen) -> (usize, usize) {
    let executors = g.usize_in(1, 4);
    let partitions = executors * g.usize_in(1, 4);
    (executors, partitions)
}

fn gen_values(g: &mut Gen) -> Vec<Key> {
    let n = g.usize_in(1, 1_500);
    (0..n).map(|_| g.i32_in(-500_000, 500_000)).collect()
}

/// Recoverable plan (every fault retires within the default budget);
/// straggler multipliers avoid the 2.0 speculation boundary so
/// speculative outcomes are mode-independent.
fn gen_recoverable_plan(g: &mut Gen, partitions: usize) -> FaultPlan {
    let mut plan = FaultPlan::seeded(g.u64())
        .panics(g.f64_unit() * 0.25)
        .transients(g.f64_unit() * 0.3);
    if g.bool() {
        let mult = if g.bool() {
            2.5 + g.f64_unit() * 3.0
        } else {
            1.0 + g.f64_unit() * 0.4
        };
        plan = plan.stragglers(g.f64_unit() * 0.5, mult);
    }
    if g.bool() {
        plan = plan.panic_task(g.usize_in(0, 1) as u64, g.usize_in(0, partitions - 1));
    }
    plan
}

fn engine(
    executors: usize,
    partitions: usize,
    mode: ExecMode,
    faults: Option<FaultPlan>,
    trace: TraceMode,
) -> QuantileEngine {
    EngineBuilder::new()
        .cluster(
            ClusterConfig::local(executors, partitions)
                .with_exec_mode(mode)
                .with_fault_plan(faults),
        )
        .algorithm(AlgoChoice::GkSelect)
        .trace(trace)
        .build()
        .unwrap()
}

/// Everything about a span except its timestamps (wall clocks differ
/// run to run; model clocks differ once faults charge retry time).
type SpanShape = (
    u64,
    u64,
    &'static str,
    String,
    Option<u64>,
    Option<usize>,
    Option<usize>,
    Option<u32>,
    Option<&'static str>,
);

fn shape(trace: &Trace) -> Vec<SpanShape> {
    trace
        .spans
        .iter()
        .map(|s| {
            (
                s.id,
                s.parent,
                s.kind.label(),
                s.name.clone(),
                s.stage,
                s.partition,
                s.executor,
                s.attempt,
                s.outcome.map(|o| o.label()),
            )
        })
        .collect()
}

/// The non-attempt skeleton: what must survive fault recovery unchanged.
fn skeleton(trace: &Trace) -> Vec<(&'static str, String, Option<u64>)> {
    trace
        .spans
        .iter()
        .filter(|s| s.kind != SpanKind::Attempt)
        .map(|s| (s.kind.label(), s.name.clone(), s.stage))
        .collect()
}

#[test]
fn prop_trace_trees_are_well_formed_and_mode_identical() {
    check("trace_tree_pinned", 20, |g| {
        let (executors, partitions) = gen_geometry(g);
        let data = Dataset::from_vec(gen_values(g), partitions).unwrap();
        let plan = gen_recoverable_plan(g, partitions);
        let query = QuantileQuery::Single(g.f64_unit());

        // fault-free reference: one Ok attempt 0 per (stage, partition)
        let clean = engine(executors, partitions, ExecMode::Sequential, None, TraceMode::Memory)
            .execute(Source::Dataset(&data), query.clone())
            .unwrap();
        let clean_trace = clean.trace().expect("memory sink").clone();
        assert!(clean_trace.is_well_formed());
        assert_eq!(clean_trace.roots().count(), 1);
        assert_eq!(clean_trace.roots().next().unwrap().kind, SpanKind::Query);
        // GK Select fused batch protocol: 2 stages = 2 data scans
        assert_eq!(clean_trace.spans_of_kind(SpanKind::Stage).count(), 2);
        for s in clean_trace.spans_of_kind(SpanKind::Attempt) {
            assert_eq!((s.attempt, s.outcome), (Some(0), Some(AttemptOutcome::Ok)));
        }
        assert_eq!(
            clean_trace.spans_of_kind(SpanKind::Attempt).count(),
            2 * partitions,
            "one Ok attempt per partition per stage"
        );

        let mut shapes = Vec::new();
        for mode in [ExecMode::Sequential, ExecMode::Threads] {
            let out = engine(executors, partitions, mode, Some(plan.clone()), TraceMode::Memory)
                .execute(Source::Dataset(&data), query.clone())
                .unwrap_or_else(|e| panic!("recoverable plan [{plan}] failed: {e}"));
            assert_eq!(out.values, clean.values, "tracing must not change answers");
            let trace = out.trace().expect("memory sink").clone();
            assert!(trace.is_well_formed(), "malformed tree under [{plan}]");
            assert_eq!(trace.roots().count(), 1);
            // a recovered run differs from the fault-free run ONLY by
            // its attempt spans: the query/stage/reduce skeleton is the
            // same tree
            assert_eq!(
                skeleton(&trace),
                skeleton(&clean_trace),
                "fault recovery must not add or drop driver spans under [{plan}]"
            );
            // retries show up as extra attempt spans, one per retry
            let extra = trace.spans_of_kind(SpanKind::Attempt).count()
                - clean_trace.spans_of_kind(SpanKind::Attempt).count();
            let ledger = (out.report.tasks_retried + out.report.speculative_launched) as usize;
            assert_eq!(extra, ledger, "attempt spans must mirror the ledger under [{plan}]");
            shapes.push(shape(&trace));
        }
        assert_eq!(
            shapes[0], shapes[1],
            "span tree must be mode-identical under [{plan}]"
        );
    });
}

#[test]
fn prop_injected_faults_appear_exactly_where_planned() {
    check("trace_attempts_placed", 20, |g| {
        let (executors, partitions) = gen_geometry(g);
        let data = Dataset::from_vec(gen_values(g), partitions).unwrap();
        // one targeted injection: stage s, partition p, fails attempt 0
        // once, recovered by attempt 1 (default persistence)
        let stage = g.usize_in(0, 1) as u64;
        let target = g.usize_in(0, partitions - 1);
        let plan = FaultPlan::seeded(g.u64()).panic_task(stage, target);

        let out = engine(
            executors,
            partitions,
            ExecMode::Sequential,
            Some(plan.clone()),
            TraceMode::Memory,
        )
        .execute(Source::Dataset(&data), QuantileQuery::Single(g.f64_unit()))
        .unwrap();
        assert_eq!(out.report.tasks_retried, 1);
        let trace = out.trace().unwrap();

        for s in [0u64, 1] {
            for p in 0..partitions {
                let fates: Vec<_> = trace
                    .spans_of_kind(SpanKind::Attempt)
                    .filter(|a| (a.stage, a.partition) == (Some(s), Some(p)))
                    .map(|a| (a.attempt.unwrap(), a.outcome.unwrap()))
                    .collect();
                if (s, p) == (stage, target) {
                    assert_eq!(
                        fates,
                        vec![(0, AttemptOutcome::Panic), (1, AttemptOutcome::Ok)],
                        "injected panic at stage {s} partition {p} under [{plan}]"
                    );
                    // the failed attempt records why
                    let panic_span = trace
                        .spans_of_kind(SpanKind::Attempt)
                        .find(|a| {
                            (a.stage, a.partition, a.outcome)
                                == (Some(s), Some(p), Some(AttemptOutcome::Panic))
                        })
                        .unwrap();
                    assert!(
                        panic_span.attrs.iter().any(|(k, _)| k == "fault"),
                        "failed attempts must carry a fault attr"
                    );
                } else {
                    assert_eq!(
                        fates,
                        vec![(0, AttemptOutcome::Ok)],
                        "no injection at stage {s} partition {p} under [{plan}]"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_null_sink_is_invisible() {
    check("trace_null_invisible", 15, |g| {
        let (executors, partitions) = gen_geometry(g);
        let data = Dataset::from_vec(gen_values(g), partitions).unwrap();
        let q = g.f64_unit();

        // TraceMode::Off resolves to TraceSink::Null — the builder
        // default — so this run IS the tracing-disabled configuration
        let mut off_eng =
            engine(executors, partitions, ExecMode::Sequential, None, TraceMode::Off);
        assert!(!off_eng.cluster().tracer.is_enabled(), "Null sink keeps hooks disarmed");
        let off = off_eng
            .execute(Source::Dataset(&data), QuantileQuery::Single(q))
            .unwrap();
        assert!(off.trace().is_none(), "Null sink must not attach a trace");

        let on = engine(executors, partitions, ExecMode::Sequential, None, TraceMode::Memory)
            .execute(Source::Dataset(&data), QuantileQuery::Single(q))
            .unwrap();
        assert!(on.trace().is_some());

        // everything the protocol promises is identical with and without
        // span collection (walls aside, which no outcome field compares)
        assert_eq!(off.values, on.values);
        assert_eq!(off.degraded, on.degraded);
        assert_eq!(off.report.rounds, on.report.rounds);
        assert_eq!(off.report.data_scans, on.report.data_scans);
        assert_eq!(off.report.exact, on.report.exact);
        // stage latency sketches are always on, independent of tracing
        assert_eq!(off.report.stage_stats.len(), 2);
        assert_eq!(on.report.stage_stats.len(), 2);
    });
}

#[test]
fn prop_stream_ingest_and_query_get_distinct_span_kinds() {
    check("trace_stream_kinds", 15, |g| {
        let (executors, partitions) = gen_geometry(g);
        let values = gen_values(g);
        let mut eng = engine(executors, partitions, ExecMode::Sequential, None, TraceMode::Memory);

        let ing = eng.ingest("s", MicroBatch::new(values)).unwrap();
        let itrace = ing.trace.as_ref().expect("memory sink traces ingests");
        assert!(itrace.is_well_formed());
        assert_eq!(itrace.roots().count(), 1);
        assert_eq!(itrace.roots().next().unwrap().kind, SpanKind::Ingest);
        // streaming append path: 1 round, 1 scan over the new records
        assert_eq!(itrace.spans_of_kind(SpanKind::Stage).count(), 1);
        assert_eq!(
            itrace.spans_of_kind(SpanKind::Attempt).count(),
            partitions,
            "one sketch task per partition"
        );

        let out = eng
            .execute(Source::Stream("s"), QuantileQuery::Single(g.f64_unit()))
            .unwrap();
        let qtrace = out.trace().expect("memory sink traces stream queries");
        assert!(qtrace.is_well_formed());
        assert_eq!(qtrace.roots().count(), 1);
        assert_eq!(qtrace.roots().next().unwrap().kind, SpanKind::StreamQuery);
        // cached-sketch serving path: the single band-extract scan
        assert_eq!(qtrace.spans_of_kind(SpanKind::Stage).count(), 1);
        assert!(qtrace
            .spans_of_kind(SpanKind::Attempt)
            .all(|a| a.outcome == Some(AttemptOutcome::Ok)));
    });
}
