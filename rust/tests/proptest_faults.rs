//! Property tests for the fault-injection and recovery layer: for any
//! random cluster geometry, dataset, and seeded [`FaultPlan`], in BOTH
//! execution modes, a query either
//!
//! * returns the bit-identical fault-free exact answer (every injected
//!   fault recovered within the retry budget), or
//! * fails with a typed `EngineError::StageFailed`, or
//! * degrades to an explicitly-marked ε-approximate answer under
//!   `DegradePolicy::SketchAnswer` —
//!
//! and never panics and never returns a silently wrong exact value.
//! Fault decisions are a pure function of the plan, so recovery tallies
//! and outcomes must be identical across `Sequential` and `Threads`.

use gkselect::algorithms::oracle_quantile;
use gkselect::cluster::dataset::Dataset;
use gkselect::cluster::{Cluster, ClusterConfig, ExecMode, FaultPlan};
use gkselect::engine::{
    AlgoChoice, DegradePolicy, EngineBuilder, EngineError, QuantileEngine, QuantileQuery, Source,
};
use gkselect::stream::MicroBatch;
use gkselect::util::propkit::{check, Gen};
use gkselect::Key;

/// Random geometry stressing the recovery paths: mostly partitions ≫
/// executors, sometimes square, sometimes the 1-executor degenerate
/// case (where speculation has nowhere to run).
fn gen_geometry(g: &mut Gen) -> (usize, usize) {
    let executors = match g.usize_in(0, 3) {
        0 => 1,
        _ => g.usize_in(1, 6),
    };
    let partitions = match g.usize_in(0, 2) {
        0 => executors,
        _ => executors * g.usize_in(2, 8),
    };
    (executors, partitions)
}

fn gen_values(g: &mut Gen) -> Vec<Key> {
    let n = g.usize_in(1, 2_000);
    match g.usize_in(0, 2) {
        0 => (0..n).map(|_| g.i32_in(-1_000_000_000, 999_999_999)).collect(),
        1 => (0..n).map(|_| g.i32_in(0, 6)).collect(), // duplicate-heavy
        _ => {
            let mut v: Vec<Key> = (0..n).map(|_| g.i32_in(-40_000, 40_000)).collect();
            v.sort_unstable();
            v
        }
    }
}

/// A plan whose every failure is recoverable within the default retry
/// budget: injected panics/transients persist for at most 3 attempts
/// (`max_task_retries = 3` allows 4), executor loss kills tasks once,
/// and stragglers never fail at all. Straggler multipliers avoid the
/// `2.0` speculation boundary so win counts are mode-independent.
fn gen_recoverable_plan(g: &mut Gen, executors: usize, partitions: usize) -> FaultPlan {
    let mut plan = FaultPlan::seeded(g.u64())
        .panics(g.f64_unit() * 0.3)
        .transients(g.f64_unit() * 0.4)
        .attempts(1 + g.usize_in(0, 2) as u32);
    if g.bool() {
        let mult = if g.bool() {
            2.5 + g.f64_unit() * 3.0 // speculation launches and wins
        } else {
            1.0 + g.f64_unit() * 0.4 // below the detection threshold
        };
        plan = plan.stragglers(g.f64_unit() * 0.5, mult);
    }
    if g.bool() {
        plan = plan.lose_executor(g.usize_in(0, 2) as u64, g.usize_in(0, executors - 1));
    }
    if g.bool() {
        plan = plan.panic_task(g.usize_in(0, 2) as u64, g.usize_in(0, partitions - 1));
    }
    plan
}

/// Engine on an explicit local cluster: the explicit shape pins the
/// fault wiring, so `GKSELECT_FAULTS` (e.g. the CI chaos job) cannot
/// perturb what these properties measure.
fn engine(
    executors: usize,
    partitions: usize,
    mode: ExecMode,
    faults: Option<FaultPlan>,
) -> QuantileEngine {
    EngineBuilder::new()
        .cluster(
            ClusterConfig::local(executors, partitions)
                .with_exec_mode(mode)
                .with_fault_plan(faults),
        )
        .algorithm(AlgoChoice::GkSelect)
        .build()
        .unwrap()
}

#[test]
fn prop_recoverable_faults_never_change_answers_in_either_mode() {
    check("faults_recoverable_identical", 25, |g| {
        let (executors, partitions) = gen_geometry(g);
        let values = gen_values(g);
        let data = Dataset::from_vec(values, partitions).unwrap();
        let plan = gen_recoverable_plan(g, executors, partitions);
        let qs: Vec<f64> = (0..g.usize_in(1, 3)).map(|_| g.f64_unit()).collect();
        let query = if qs.len() == 1 {
            QuantileQuery::Single(qs[0])
        } else {
            QuantileQuery::Multi(qs.clone())
        };

        let clean = engine(executors, partitions, ExecMode::Sequential, None)
            .execute(Source::Dataset(&data), query.clone())
            .unwrap();
        for (&q, &v) in qs.iter().zip(clean.values.iter()) {
            assert_eq!(v, oracle_quantile(&data, q).unwrap(), "clean run q={q}");
        }

        let mut reports = Vec::new();
        for mode in [ExecMode::Sequential, ExecMode::Threads] {
            let out = engine(executors, partitions, mode, Some(plan.clone()))
                .execute(Source::Dataset(&data), query.clone())
                .unwrap_or_else(|e| {
                    panic!("recoverable plan [{plan}] must never fail ({}): {e}", mode.label())
                });
            assert_eq!(
                out.values, clean.values,
                "faulted answers must be bit-identical to the fault-free run under [{plan}]"
            );
            assert!(out.report.exact && !out.degraded);
            assert_eq!(out.report.rounds, clean.report.rounds, "recovery adds no rounds");
            assert_eq!(out.report.data_scans, clean.report.data_scans);
            reports.push(out.report);
        }
        let (seq, thr) = (&reports[0], &reports[1]);
        assert_eq!(
            (seq.faults_injected, seq.tasks_retried, seq.speculative_launched, seq.speculative_wins),
            (thr.faults_injected, thr.tasks_retried, thr.speculative_launched, thr.speculative_wins),
            "fault decisions must be mode-independent under [{plan}]"
        );
    });
}

#[test]
fn prop_unrecoverable_faults_fail_typed_or_degrade_never_lie() {
    check("faults_unrecoverable_typed", 20, |g| {
        let (executors, partitions) = gen_geometry(g);
        let values = gen_values(g);
        let data = Dataset::from_vec(values, partitions).unwrap();
        let q = g.f64_unit();
        let truth = oracle_quantile(&data, q).unwrap();
        // failures persist past any retry budget; the rate decides how
        // many stages they land on
        let plan = FaultPlan::seeded(g.u64())
            .panics(0.2 + g.f64_unit() * 0.8)
            .attempts(u32::MAX);
        let degrade = if g.bool() { DegradePolicy::Fail } else { DegradePolicy::SketchAnswer };

        let mut outcomes: Vec<Result<Vec<Key>, ()>> = Vec::new();
        for mode in [ExecMode::Sequential, ExecMode::Threads] {
            let mut eng = EngineBuilder::new()
                .cluster(
                    ClusterConfig::local(executors, partitions)
                        .with_exec_mode(mode)
                        .with_fault_plan(Some(plan.clone())),
                )
                .algorithm(AlgoChoice::GkSelect)
                .degrade_policy(degrade)
                .build()
                .unwrap();
            match eng.execute(Source::Dataset(&data), QuantileQuery::Single(q)) {
                Ok(out) => {
                    if out.degraded {
                        assert!(
                            matches!(degrade, DegradePolicy::SketchAnswer),
                            "only SketchAnswer may degrade"
                        );
                        assert!(!out.report.exact, "degraded answers must not claim exactness");
                        assert!(out.report.degraded_queries >= 1);
                        // the ε contract (engine default ε = 0.01, same
                        // slack as `repro validate` gives merged sketches)
                        let mut all = data.to_vec();
                        all.sort_unstable();
                        let n = all.len() as f64;
                        let lo = all.partition_point(|&x| x < out.value()) as f64;
                        let hi = all.partition_point(|&x| x <= out.value()) as f64;
                        let target = q * n;
                        let err = if target < lo {
                            (lo - target) / n
                        } else if target > hi {
                            (target - hi) / n
                        } else {
                            0.0
                        };
                        assert!(err <= 5.0 * 0.01, "rank error {err:.4} > 5ε under [{plan}]");
                    } else {
                        // the plan happened to miss every stage this query
                        // ran: the answer must be the exact one
                        assert_eq!(out.value(), truth, "silently wrong value under [{plan}]");
                        assert!(out.report.exact);
                    }
                    outcomes.push(Ok(out.values));
                }
                Err(EngineError::StageFailed { attempts, .. }) => {
                    assert!(attempts >= 1);
                    outcomes.push(Err(()));
                }
                Err(other) => panic!("expected StageFailed under [{plan}], got {other}"),
            }
        }
        assert_eq!(
            outcomes[0], outcomes[1],
            "outcome (values or typed failure) must be mode-identical under [{plan}]"
        );
    });
}

#[test]
fn prop_failed_ingest_leaves_the_sketch_store_unchanged() {
    check("faults_ingest_atomic", 15, |g| {
        let (executors, partitions) = gen_geometry(g);
        let good: Vec<Key> =
            (0..g.usize_in(1, 2_000)).map(|_| g.i32_in(-100_000, 100_000)).collect();
        let bad: Vec<Key> =
            (0..g.usize_in(1, 2_000)).map(|_| g.i32_in(-100_000, 100_000)).collect();
        for mode in [ExecMode::Sequential, ExecMode::Threads] {
            let mut eng = engine(executors, partitions, mode, None);
            eng.ingest("s", MicroBatch::new(good.clone())).unwrap();
            let (epochs, records) = {
                let st = eng.store().stream("s").unwrap();
                (st.live_epochs(), st.total_count())
            };

            // arm a persistent every-stage failure, then retry the ingest:
            // it must fail typed and seal nothing
            let mut cc = eng.cluster().cfg.clone();
            cc.faults = Some(FaultPlan::seeded(g.u64()).panics(1.0).attempts(u32::MAX));
            *eng.cluster_mut() = Cluster::new(cc);
            let err = eng.ingest("s", MicroBatch::new(bad.clone())).unwrap_err();
            assert!(matches!(err, EngineError::StageFailed { .. }), "{err}");
            let st = eng.store().stream("s").unwrap();
            assert_eq!(st.live_epochs(), epochs, "failed ingest must not seal an epoch");
            assert_eq!(st.total_count(), records, "failed ingest must not change counts");

            // disarm: the stream still answers exactly from the records
            // that were actually sealed
            let mut cc = eng.cluster().cfg.clone();
            cc.faults = None;
            *eng.cluster_mut() = Cluster::new(cc);
            let q = g.f64_unit();
            let out = eng.execute(Source::Stream("s"), QuantileQuery::Single(q)).unwrap();
            let live = eng.store().stream("s").unwrap().live_dataset().unwrap();
            assert_eq!(out.value(), oracle_quantile(&live, q).unwrap(), "q={q}");
            assert!(out.report.exact && !out.degraded);
        }
    });
}
