//! Cross-module integration: every algorithm against the oracle across
//! the full (distribution × quantile × cluster-shape) matrix, plus the
//! Table V counter contracts — all through the engine façade
//! (`EngineBuilder` → `QuantileEngine::execute`).

use gkselect::config::ReproConfig;
use gkselect::data::{DataGenerator, Distribution};
use gkselect::harness::{engine_for, make_cluster, AlgoChoice};
use gkselect::prelude::*;

fn cfg() -> ReproConfig {
    ReproConfig {
        backend: "native".into(),
        ..Default::default()
    }
}

const DISTS: [Distribution; 4] = [
    Distribution::Uniform,
    Distribution::Zipf,
    Distribution::Bimodal,
    Distribution::Sorted,
];

#[test]
fn exact_algorithms_match_oracle_across_matrix() {
    let cfg = cfg();
    for dist in DISTS {
        let mut cluster = make_cluster(&cfg, 3);
        let data = dist.generator(91).generate(&mut cluster, 40_000);
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
            let truth = oracle_quantile(&data, q).unwrap();
            for choice in [
                AlgoChoice::GkSelect,
                AlgoChoice::Afs,
                AlgoChoice::Jeffers,
                AlgoChoice::FullSort,
                AlgoChoice::HistSelect,
            ] {
                let mut engine = engine_for(&cfg, choice, 3).unwrap();
                let out = engine
                    .execute(Source::Dataset(&data), QuantileQuery::Single(q))
                    .unwrap();
                assert_eq!(
                    out.value(),
                    truth,
                    "{} {} q={q}",
                    choice.label(),
                    dist.label()
                );
            }
        }
    }
}

#[test]
fn approx_algorithm_stays_within_rank_band() {
    let cfg = cfg();
    for dist in DISTS {
        let mut cluster = make_cluster(&cfg, 3);
        let data = dist.generator(92).generate(&mut cluster, 60_000);
        let mut sorted = data.to_vec();
        sorted.sort_unstable();
        let n = sorted.len() as f64;
        for q in [0.25, 0.5, 0.75, 0.99] {
            let mut engine = engine_for(&cfg, AlgoChoice::GkSketch, 3).unwrap();
            let out = engine
                .execute(Source::Dataset(&data), QuantileQuery::Single(q))
                .unwrap();
            let lo = sorted.partition_point(|&x| x < out.value()) as f64;
            let hi = sorted.partition_point(|&x| x <= out.value()) as f64;
            let target = q * n;
            let err = if target < lo {
                lo - target
            } else if target > hi {
                target - hi
            } else {
                0.0
            };
            // 12 partitions merged pairwise: allow a few ε of slack
            assert!(
                err <= 5.0 * 0.01 * n + 2.0,
                "{} q={q}: rank err {err}",
                dist.label()
            );
        }
    }
}

#[test]
fn table5_contract_gk_select() {
    let cfg = cfg();
    let mut cluster = make_cluster(&cfg, 5);
    let data = Distribution::Uniform.generator(93).generate(&mut cluster, 100_000);
    let mut engine = engine_for(&cfg, AlgoChoice::GkSelect, 5).unwrap();
    let out = engine
        .execute(Source::Dataset(&data), QuantileQuery::Single(0.37))
        .unwrap();
    assert!(out.report.rounds <= 3, "GK Select used {} rounds", out.report.rounds);
    assert_eq!(out.report.shuffles, 0);
    assert_eq!(out.report.persists, 0);
    assert!(out.report.exact);
}

#[test]
fn table5_contract_full_sort() {
    let cfg = cfg();
    let mut cluster = make_cluster(&cfg, 5);
    let data = Distribution::Uniform.generator(94).generate(&mut cluster, 100_000);
    let mut engine = engine_for(&cfg, AlgoChoice::FullSort, 5).unwrap();
    let out = engine
        .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
        .unwrap();
    assert_eq!(out.report.shuffles, 1);
    assert_eq!(out.report.rounds, 1);
    // O(n) network volume: the shuffle moves most records
    assert!(out.report.bytes_shuffled as f64 > 0.5 * 100_000.0 * 4.0);
}

#[test]
fn table5_contract_count_discard() {
    let cfg = cfg();
    let mut cluster = make_cluster(&cfg, 5);
    let data = Distribution::Uniform.generator(95).generate(&mut cluster, 100_000);
    for choice in [AlgoChoice::Afs, AlgoChoice::Jeffers] {
        let mut engine = engine_for(&cfg, choice, 5).unwrap();
        let out = engine
            .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
            .unwrap();
        assert!(out.report.rounds >= 3, "{}: rounds", choice.label());
        assert!(out.report.persists >= 1, "{}: persists", choice.label());
        assert_eq!(out.report.shuffles, 0, "{}: shuffles", choice.label());
    }
}

#[test]
fn table5_contract_gk_sketch() {
    let cfg = cfg();
    let mut cluster = make_cluster(&cfg, 5);
    let data = Distribution::Uniform.generator(96).generate(&mut cluster, 100_000);
    let mut engine = engine_for(&cfg, AlgoChoice::GkSketch, 5).unwrap();
    let out = engine
        .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
        .unwrap();
    assert_eq!(out.report.rounds, 1);
    assert_eq!(out.report.shuffles, 0);
    assert_eq!(out.report.persists, 0);
    assert!(!out.report.exact);
}

#[test]
fn modelled_time_ordering_holds_at_scale() {
    // the paper's core result shape: sketch ≈ gk-select ≪ full sort under
    // the EMR fabric model at meaningful n
    let mut cfg = cfg();
    cfg.network.enabled = true;
    let mut cluster = make_cluster(&cfg, 10);
    let data = Distribution::Uniform.generator(97).generate(&mut cluster, 2_000_000);

    let run = |cfg: &ReproConfig, c: AlgoChoice| {
        let mut engine = engine_for(cfg, c, 10).unwrap();
        engine
            .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
            .unwrap()
            .report
            .elapsed_secs
    };
    let t_select = run(&cfg, AlgoChoice::GkSelect);
    let t_sketch = run(&cfg, AlgoChoice::GkSketch);
    let t_sort = run(&cfg, AlgoChoice::FullSort);
    assert!(
        t_sort > t_select,
        "full sort ({t_sort:.4}s) must exceed GK Select ({t_select:.4}s)"
    );
    assert!(
        t_select < 3.0 * t_sketch + 0.05,
        "GK Select ({t_select:.4}s) should be sketch-level (sketch {t_sketch:.4}s)"
    );
}

#[test]
fn cluster_shape_sweep() {
    let cfg = cfg();
    for nodes in [1usize, 2, 7, 16] {
        let mut engine = engine_for(&cfg, AlgoChoice::GkSelect, nodes).unwrap();
        let data = Distribution::Uniform
            .generator(98)
            .generate(engine.cluster_mut(), 30_000);
        let truth = oracle_quantile(&data, 0.5).unwrap();
        let out = engine
            .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
            .unwrap();
        assert_eq!(out.value(), truth, "nodes={nodes}");
        assert_eq!(out.report.partitions, nodes * 4);
    }
}

#[test]
fn repeated_queries_are_deterministic() {
    let cfg = cfg();
    let mut engine = engine_for(&cfg, AlgoChoice::GkSelect, 4).unwrap();
    let data = Distribution::Zipf
        .generator(99)
        .generate(engine.cluster_mut(), 50_000);
    let a = engine
        .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
        .unwrap();
    let b = engine
        .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
        .unwrap();
    assert_eq!(a.value(), b.value());
    assert_eq!(a.report.rounds, b.report.rounds);
    assert_eq!(a.report.network_volume_bytes, b.report.network_volume_bytes);
}

#[test]
fn rank_and_multi_plans_cover_the_matrix() {
    // the typed plans the redesign added, against the oracle
    let cfg = cfg();
    let mut cluster = make_cluster(&cfg, 3);
    let data = Distribution::Bimodal.generator(90).generate(&mut cluster, 30_000);
    let n = data.len();
    let mut engine = engine_for(&cfg, AlgoChoice::GkSelect, 3).unwrap();

    // Rank(k) == the k-th order statistic
    let mut all = data.to_vec();
    all.sort_unstable();
    for k in [0, n / 4, n / 2, n - 1] {
        let out = engine
            .execute(Source::Dataset(&data), QuantileQuery::Rank(k))
            .unwrap();
        assert_eq!(out.value(), all[k as usize], "k={k}");
    }

    // Multi == the singles, one fused scan
    let qs = vec![0.1, 0.5, 0.9, 0.99];
    let multi = engine
        .execute(Source::Dataset(&data), QuantileQuery::Multi(qs.clone()))
        .unwrap();
    assert_eq!(multi.report.data_scans, 2, "batched quantiles share one scan");
    for (&q, &v) in qs.iter().zip(multi.values.iter()) {
        assert_eq!(v, oracle_quantile(&data, q).unwrap(), "q={q}");
    }
}
