//! Property tests for the concurrent multi-tenant [`QuantileService`]:
//! across random geometries, BOTH exec modes, and optional seeded
//! recoverable fault plans,
//!
//! * **snapshot isolation** — a query that pinned snapshot S answers
//!   bit-identically to a fresh single-threaded engine whose store was
//!   fed exactly S's epochs, even while K writer threads ingest into
//!   the same stream concurrently with the queries;
//! * **linearizability of seals** — once every concurrent ingest has
//!   returned (thread join = barrier), every subsequently submitted
//!   query observes all of them: the pinned count equals the running
//!   total, and each writer observes its own seal immediately;
//! * **multi-tenant isolation** — after concurrent per-stream writers
//!   finish, the registry's per-stream residency gauges equal each
//!   stream's Σ ingested records exactly, with no cross-stream bleed.
//!
//! The oracle engine always runs `ExecMode::Sequential` with no fault
//! plan; the service under test may run `Threads` under recoverable
//! chaos — recoverable plans are answer-preserving, so bit-equality
//! against the clean serialized oracle is the acceptance bar.

use gkselect::cluster::{ClusterConfig, ExecMode, FaultPlan};
use gkselect::engine::{
    AlgoChoice, EngineBuilder, QuantileEngine, QuantileQuery, Source,
};
use gkselect::obs::{MetricsMode, OpKind};
use gkselect::service::{Pinned, QuantileService};
use gkselect::stream::MicroBatch;
use gkselect::util::propkit::{check, Gen};
use gkselect::Key;

fn gen_geometry(g: &mut Gen) -> (usize, usize) {
    let executors = g.usize_in(1, 3);
    let partitions = executors * g.usize_in(1, 3);
    (executors, partitions)
}

fn gen_values(g: &mut Gen, min: usize) -> Vec<Key> {
    let n = g.usize_in(min, 600);
    (0..n).map(|_| g.i32_in(-500_000, 500_000)).collect()
}

fn gen_mode(g: &mut Gen) -> ExecMode {
    if g.bool() {
        ExecMode::Threads
    } else {
        ExecMode::Sequential
    }
}

/// Recoverable plan (mirrors `proptest_registry.rs`): every fault
/// retires within the default retry budget, straggler multipliers stay
/// off the 2.0 speculation boundary so outcomes are mode-independent.
fn gen_recoverable_plan(g: &mut Gen, partitions: usize) -> FaultPlan {
    let mut plan = FaultPlan::seeded(g.u64())
        .panics(g.f64_unit() * 0.2)
        .transients(g.f64_unit() * 0.25);
    if g.bool() {
        plan = plan.stragglers(g.f64_unit() * 0.4, 2.5 + g.f64_unit() * 2.0);
    }
    if g.bool() {
        plan = plan.panic_task(g.usize_in(0, 1) as u64, g.usize_in(0, partitions - 1));
    }
    plan
}

fn service(
    executors: usize,
    partitions: usize,
    mode: ExecMode,
    faults: Option<FaultPlan>,
) -> QuantileService {
    QuantileService::builder()
        .cluster(
            ClusterConfig::local(executors, partitions)
                .with_exec_mode(mode)
                .with_fault_plan(faults),
        )
        .metrics(MetricsMode::Memory)
        .build()
        .unwrap()
}

/// The independent oracle: a fresh sequential fault-free engine whose
/// store holds exactly the pinned snapshot's epochs, sealed in pin
/// order. Same epoch order → same tree merge → same plan → the answers
/// the service must reproduce bit-identically.
fn oracle_for(executors: usize, partitions: usize, pin: &Pinned) -> QuantileEngine {
    let mut oracle = EngineBuilder::new()
        .cluster(
            ClusterConfig::local(executors, partitions)
                .with_exec_mode(ExecMode::Sequential)
                .with_fault_plan(None),
        )
        .algorithm(AlgoChoice::GkSelect)
        .build()
        .unwrap();
    for epoch in pin.snapshot().epochs() {
        oracle
            .store_mut()
            .seal_epoch(pin.stream(), epoch.data.clone(), epoch.sketches.clone())
            .unwrap();
    }
    oracle
}

#[test]
fn snapshot_isolation_holds_under_concurrent_writers() {
    check("snapshot_isolation_holds_under_concurrent_writers", 12, |g| {
        let (executors, partitions) = gen_geometry(g);
        let mode = gen_mode(g);
        let faults = if g.bool() {
            Some(gen_recoverable_plan(g, partitions))
        } else {
            None
        };
        let svc = service(executors, partitions, mode, faults);

        // warm epochs that the pin will capture
        for _ in 0..g.usize_in(1, 3) {
            svc.ingest("hot", MicroBatch::new(gen_values(g, 1))).unwrap();
        }
        let pin = svc.pin("hot").unwrap();

        // pre-generate the concurrent writers' batches (Gen is not Sync)
        const WRITERS: usize = 3;
        let batches: Vec<Vec<Vec<Key>>> = (0..WRITERS)
            .map(|_| (0..g.usize_in(1, 3)).map(|_| gen_values(g, 1)).collect())
            .collect();
        let qs = [0.0, g.f64_unit(), 0.5, g.f64_unit(), 1.0];

        // queries against the pin race the writers' seals and compactions
        let svc_ref = &svc;
        let got: Vec<(f64, Key)> = std::thread::scope(|scope| {
            let handles: Vec<_> = batches
                .into_iter()
                .map(|mine| {
                    scope.spawn(move || {
                        for b in mine {
                            svc_ref.ingest("hot", MicroBatch::new(b)).unwrap();
                        }
                    })
                })
                .collect();
            let got = qs
                .iter()
                .map(|&q| {
                    let out = svc_ref
                        .query_pinned(&pin, &QuantileQuery::Single(q))
                        .unwrap();
                    assert!(out.report.exact, "pinned answer must stay exact");
                    (q, out.value())
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
            got
        });

        // the serialized oracle over exactly the pinned epochs
        let mut oracle = oracle_for(executors, partitions, &pin);
        for (q, served) in got {
            let want = oracle
                .execute(Source::Stream("hot"), QuantileQuery::Single(q))
                .unwrap();
            assert_eq!(
                served,
                want.value(),
                "snapshot isolation violated at q={q}: served {served}, \
                 oracle over the pinned epochs answers {}",
                want.value()
            );
        }

        // and the pin still answers identically now that all writers are
        // done — later seals must not have leaked into it
        let after = svc.query_pinned(&pin, &QuantileQuery::Single(0.5)).unwrap();
        let want = oracle
            .execute(Source::Stream("hot"), QuantileQuery::Single(0.5))
            .unwrap();
        assert_eq!(after.value(), want.value());
    });
}

#[test]
fn seals_are_linearizable_at_the_ingest_return() {
    check("seals_are_linearizable_at_the_ingest_return", 12, |g| {
        let (executors, partitions) = gen_geometry(g);
        let mode = gen_mode(g);
        let faults = if g.bool() {
            Some(gen_recoverable_plan(g, partitions))
        } else {
            None
        };
        let svc = service(executors, partitions, mode, faults);
        let mut total: u64 = 0;

        for _round in 0..g.usize_in(1, 3) {
            const WRITERS: usize = 4;
            let batches: Vec<Vec<Key>> =
                (0..WRITERS).map(|_| gen_values(g, 1)).collect();
            let round_records: u64 = batches.iter().map(|b| b.len() as u64).sum();

            let svc_ref = &svc;
            std::thread::scope(|scope| {
                for mine in batches {
                    scope.spawn(move || {
                        let n = mine.len() as u64;
                        let before = svc_ref
                            .pin("s")
                            .map(|p| p.snapshot().total_count())
                            .unwrap_or(0);
                        svc_ref.ingest("s", MicroBatch::new(mine)).unwrap();
                        // once MY ingest returned, a fresh pin must observe
                        // at least my batch on top of what I saw before
                        let after =
                            svc_ref.pin("s").unwrap().snapshot().total_count();
                        assert!(
                            after >= before + n,
                            "seal not observed by its own writer: \
                             {before} + {n} > {after}"
                        );
                    });
                }
            });
            total += round_records;

            // the join is a barrier: every ingest returned, so a query
            // submitted now observes ALL of them
            let pin = svc.pin("s").unwrap();
            assert_eq!(
                pin.snapshot().total_count(),
                total,
                "barrier-synced query missed a sealed ingest"
            );
            let served = svc.query_pinned(&pin, &QuantileQuery::Single(1.0)).unwrap();
            let mut oracle = oracle_for(executors, partitions, &pin);
            let want = oracle
                .execute(Source::Stream("s"), QuantileQuery::Single(1.0))
                .unwrap();
            assert_eq!(served.value(), want.value());
        }
    });
}

#[test]
fn tenants_stay_isolated_in_residency_and_totals() {
    check("tenants_stay_isolated_in_residency_and_totals", 12, |g| {
        let (executors, partitions) = gen_geometry(g);
        let mode = gen_mode(g);
        let faults = if g.bool() {
            Some(gen_recoverable_plan(g, partitions))
        } else {
            None
        };
        let svc = service(executors, partitions, mode, faults);

        const TENANTS: usize = 3;
        let batches: Vec<Vec<Vec<Key>>> = (0..TENANTS)
            .map(|_| (0..g.usize_in(1, 4)).map(|_| gen_values(g, 1)).collect())
            .collect();
        let expected: Vec<u64> = batches
            .iter()
            .map(|bs| bs.iter().map(|b| b.len() as u64).sum())
            .collect();

        let svc_ref = &svc;
        std::thread::scope(|scope| {
            for (t, mine) in batches.into_iter().enumerate() {
                scope.spawn(move || {
                    let id = format!("tenant-{t}");
                    for b in mine {
                        svc_ref.ingest(&id, MicroBatch::new(b)).unwrap();
                    }
                });
            }
        });

        let snap = svc.metrics_snapshot();
        for (t, want) in expected.iter().enumerate() {
            let id = format!("tenant-{t}");
            let residency = &snap
                .residency
                .iter()
                .find(|(name, _)| name == &id)
                .unwrap_or_else(|| panic!("no residency gauge for {id}"))
                .1;
            assert_eq!(
                residency.records, *want,
                "{id}: residency gauge {} != Σ ingested {want}",
                residency.records
            );
            let totals = snap.totals_for(OpKind::Ingest, &id).unwrap();
            assert_eq!(
                totals.records, *want,
                "{id}: ingest totals {} != Σ ingested {want}",
                totals.records
            );
            // and the store itself agrees with the gauges
            assert_eq!(
                svc.pin(&id).unwrap().snapshot().total_count(),
                *want,
                "{id}: pinned count disagrees with Σ ingested"
            );
        }
        assert_eq!(svc.streams().len(), TENANTS);
    });
}
