//! Deterministic concurrency integration suite for the multi-tenant
//! [`QuantileService`]: 8 client threads × 4 streams × 64 ops each, on a
//! seeded per-thread schedule (`Pcg64`, fixed seeds — every run replays
//! the same op mix). After the run the suite proves, against ledgers the
//! clients kept themselves:
//!
//! * **zero lost updates** — every stream's pinned count, residency
//!   gauge, and per-stream ingest totals all equal that stream's exact
//!   Σ of ingested records across all clients;
//! * **monotone sealed-epoch counts** — each client asserts, inline,
//!   that successive pins of the same stream never observe the sealed
//!   counter going backwards (the published-snapshot swap is ordered);
//! * **exact accounting** — the registry's grand totals and every
//!   `(kind, stream)` bin equal the field-by-field sum of the per-op
//!   reports the clients collected, u64 counters bit-exactly.
//!
//! Plus the stale-memo regression race: `Sketched` queries served from a
//! pinned snapshot's merged-sketch memo must stay bit-identical to the
//! serialized oracle while a writer seals and compacts the same stream
//! concurrently. Before the memo moved onto the immutable
//! [`StreamSnapshot`], a seal/compact could leave a query reading a
//! merged sketch built over a *different* epoch list than the one it
//! pinned; this test fails loudly if that ever regresses.
//!
//! [`StreamSnapshot`]: gkselect::stream::StreamSnapshot

use gkselect::cluster::metrics::MetricsReport;
use gkselect::cluster::ClusterConfig;
use gkselect::data::pcg::Pcg64;
use gkselect::engine::{QuantileQuery, Source};
use gkselect::obs::registry::OpTotals;
use gkselect::obs::{MetricsMode, OpKind};
use gkselect::service::QuantileService;
use gkselect::stream::{CompactionPolicy, MicroBatch};
use gkselect::Key;

const CLIENTS: usize = 8;
const STREAMS: usize = 4;
const OPS: u64 = 64;
const QS: [f64; 4] = [0.0, 0.5, 0.95, 1.0];

fn stream_id(s: usize) -> String {
    format!("tenant-{s}")
}

fn service() -> QuantileService {
    QuantileService::builder()
        .cluster(ClusterConfig::local(2, 4))
        .metrics(MetricsMode::Memory)
        .build()
        .unwrap()
}

fn batch(rng: &mut Pcg64, n: usize) -> Vec<Key> {
    (0..n)
        .map(|_| (rng.next_u64() % 1_000_001) as i32 - 500_000)
        .collect()
}

/// What one client brings back from its 64-op run: the per-op metrics
/// reports (keyed like the registry bins them) and its per-stream count
/// of ingested records.
struct ClientRun {
    ledger: Vec<((OpKind, String), MetricsReport)>,
    ingested: Vec<u64>,
}

fn client(svc: &QuantileService, c: usize) -> ClientRun {
    let mut rng = Pcg64::new(42, 0xC11E ^ c as u64);
    let mut ledger = Vec::with_capacity(OPS as usize);
    let mut ingested = vec![0u64; STREAMS];
    let mut last_sealed = vec![0u64; STREAMS];
    for op in 0..OPS {
        let s = (rng.next_u64() % STREAMS as u64) as usize;
        let id = stream_id(s);
        if op % 4 == 3 {
            let vals = batch(&mut rng, 16 + (rng.next_u64() % 48) as usize);
            ingested[s] += vals.len() as u64;
            let out = svc.ingest(&id, MicroBatch::new(vals)).unwrap();
            ledger.push(((OpKind::Ingest, id), out.report));
        } else {
            let pin = svc.pin(&id).unwrap();
            let sealed = pin.snapshot().sealed_epochs();
            assert!(
                sealed >= last_sealed[s],
                "sealed-epoch count went backwards on {id}: \
                 client {c} saw {} then {sealed}",
                last_sealed[s]
            );
            last_sealed[s] = sealed;
            let q = QS[(op % QS.len() as u64) as usize];
            let out = svc.query_pinned(&pin, &QuantileQuery::Single(q)).unwrap();
            assert!(out.report.exact, "served quantile must stay exact");
            ledger.push(((out.op_kind(), id), out.report));
        }
    }
    ClientRun { ledger, ingested }
}

/// Reference accumulator: sum reports into an [`OpTotals`] by hand,
/// field by field — the independent ledger the registry must match
/// (mirrors `proptest_registry.rs`).
fn sum_reports<'a>(reports: impl Iterator<Item = &'a MetricsReport>) -> OpTotals {
    let mut t = OpTotals::default();
    for r in reports {
        t.ops += 1;
        t.records += r.n;
        t.rounds += r.rounds;
        t.stage_boundaries += r.stage_boundaries;
        t.data_scans += r.data_scans;
        t.shuffles += r.shuffles;
        t.persists += r.persists;
        t.bytes_to_driver += r.bytes_to_driver;
        t.bytes_shuffled += r.bytes_shuffled;
        t.bytes_tree_reduced += r.bytes_tree_reduced;
        t.bytes_broadcast += r.bytes_broadcast;
        t.bytes_persisted += r.bytes_persisted;
        t.messages += r.messages;
        t.faults_injected += r.faults_injected;
        t.tasks_retried += r.tasks_retried;
        t.speculative_launched += r.speculative_launched;
        t.speculative_wins += r.speculative_wins;
        t.degraded_queries += r.degraded_queries;
        t.band_candidates += r.band_candidates;
        t.band_budget += r.band_budget;
        t.elapsed_secs += r.elapsed_secs;
        t.wall_stage_secs += r.wall_stage_secs;
    }
    t
}

/// u64 counters must match bit-exactly; the float sums only up to
/// associativity (the registry absorbed in interleave order, the ledger
/// sums in client order).
fn assert_totals_eq(got: &OpTotals, want: &OpTotals, what: &str) {
    let strip = |t: &OpTotals| OpTotals {
        elapsed_secs: 0.0,
        wall_stage_secs: 0.0,
        ..t.clone()
    };
    assert_eq!(strip(got), strip(want), "{what}: u64 counters must be the exact sum");
    assert!(
        (got.elapsed_secs - want.elapsed_secs).abs() <= 1e-9 * (1.0 + want.elapsed_secs.abs()),
        "{what}: elapsed_secs {} vs {}",
        got.elapsed_secs,
        want.elapsed_secs
    );
    assert!(
        (got.wall_stage_secs - want.wall_stage_secs).abs()
            <= 1e-9 * (1.0 + want.wall_stage_secs.abs()),
        "{what}: wall_stage_secs {} vs {}",
        got.wall_stage_secs,
        want.wall_stage_secs
    );
}

#[test]
fn eight_clients_four_streams_account_exactly() {
    let svc = service();

    // warm every stream so no client ever races an empty store, and
    // start the ledger with the warm-up ops — they count too
    let mut all: Vec<((OpKind, String), MetricsReport)> = Vec::new();
    let mut ingested = vec![0u64; STREAMS];
    let mut warm_rng = Pcg64::new(9, 0xA11CE);
    for (s, tally) in ingested.iter_mut().enumerate() {
        let vals = batch(&mut warm_rng, 64 + s * 7);
        *tally += vals.len() as u64;
        let out = svc.ingest(&stream_id(s), MicroBatch::new(vals)).unwrap();
        all.push(((OpKind::Ingest, stream_id(s)), out.report));
    }

    let svc_ref = &svc;
    let runs: Vec<ClientRun> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|c| scope.spawn(move || client(svc_ref, c)))
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for run in runs {
        all.extend(run.ledger);
        for (s, n) in run.ingested.into_iter().enumerate() {
            ingested[s] += n;
        }
    }

    let snap = svc.metrics_snapshot();

    // (a) zero lost updates: store, residency gauge, and ingest totals
    // all land on the exact per-stream sum
    for (s, want) in ingested.iter().enumerate() {
        let id = stream_id(s);
        assert_eq!(
            svc.pin(&id).unwrap().snapshot().total_count(),
            *want,
            "lost update: {id} store count != Σ ingested"
        );
        let residency = &snap
            .residency
            .iter()
            .find(|(name, _)| name == &id)
            .unwrap_or_else(|| panic!("no residency gauge for {id}"))
            .1;
        assert_eq!(
            residency.records, *want,
            "lost update: {id} residency gauge != Σ ingested"
        );
        assert_eq!(
            snap.totals_for(OpKind::Ingest, &id).unwrap().records,
            *want,
            "lost update: {id} ingest totals != Σ ingested"
        );
    }

    // (b) grand totals are the field-by-field sum of every per-op report
    assert_eq!(snap.ops, all.len() as u64, "one absorb per operation");
    assert_totals_eq(&snap.grand(), &sum_reports(all.iter().map(|(_, r)| r)), "grand");

    // (c) ... and so is every (kind, stream) bin the clients touched
    let mut keys: Vec<_> = all.iter().map(|(k, _)| k.clone()).collect();
    keys.sort();
    keys.dedup();
    for key in keys {
        let want = sum_reports(all.iter().filter(|(k, _)| k == &key).map(|(_, r)| r));
        let got = snap
            .totals_for(key.0, &key.1)
            .unwrap_or_else(|| panic!("no bin for {key:?}"));
        assert_totals_eq(got, &want, &format!("bin {key:?}"));
    }

    // quiesced: the live gauges drained back to zero
    assert_eq!(svc.in_flight_queries(), 0);
    assert_eq!(svc.ingest_queue_depth(), 0);
    assert_eq!(svc.streams().len(), STREAMS);
}

/// Regression: a `Sketched` query must never read a merged-sketch memo
/// that belongs to a different epoch list than the snapshot it pinned.
/// A writer seals (and, with this aggressive policy, compacts) the same
/// stream in a tight loop while the reader pins + queries; every served
/// answer must bit-match the serialized oracle over exactly the pinned
/// epochs.
#[test]
fn sketched_query_racing_seals_never_reads_a_stale_memo() {
    let svc = QuantileService::builder()
        .cluster(ClusterConfig::local(2, 4))
        .compaction(CompactionPolicy {
            compact_threshold: 3,
            max_live_epochs: 2,
        })
        .metrics(MetricsMode::Memory)
        .build()
        .unwrap();

    let mut rng = Pcg64::new(7, 0x5EA1);
    svc.ingest("race", MicroBatch::new(batch(&mut rng, 128))).unwrap();
    let writer_batches: Vec<Vec<Key>> = (0..24).map(|_| batch(&mut rng, 64)).collect();

    let svc_ref = &svc;
    std::thread::scope(|scope| {
        scope.spawn(move || {
            for vals in writer_batches {
                svc_ref.ingest("race", MicroBatch::new(vals)).unwrap();
            }
        });
        for i in 0..48u64 {
            let q = QS[(i % QS.len() as u64) as usize];
            let query = QuantileQuery::Sketched { q, eps: 0.05 };
            let pin = svc.pin("race").unwrap();
            let served = svc.query_pinned(&pin, &query).unwrap();
            let mut oracle = svc.oracle(&pin).unwrap();
            let want = oracle.execute(Source::Stream("race"), query).unwrap();
            assert_eq!(
                served.value(),
                want.value(),
                "stale merged-sketch memo: pinned snapshot (seal #{}) served {} \
                 but the oracle over the same epochs answers {}",
                pin.snapshot().sealed_epochs(),
                served.value(),
                want.value()
            );
        }
    });
}
