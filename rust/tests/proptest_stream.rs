//! Property tests for the streaming quantile service: incremental
//! ingest-time sketches keep the ε guarantee of a from-scratch sketch,
//! streamed engine queries are bit-identical to batch GK Select over the
//! concatenated data in both execution modes, and epoch compaction never
//! changes an answer. Queries go through `QuantileEngine::execute` with
//! `Source::Stream` / `Source::Dataset` sharing one call site.

use gkselect::algorithms::gk_select::default_candidate_budget;
use gkselect::algorithms::oracle_quantile;
use gkselect::cluster::dataset::Dataset;
use gkselect::cluster::{Cluster, ClusterConfig, ExecMode};
use gkselect::engine::{AlgoChoice, EngineBuilder, QuantileEngine, QuantileQuery, Source};
use gkselect::sketch::GkCore;
use gkselect::stream::{CompactionPolicy, MicroBatch, SketchStore, StreamIngestor};
use gkselect::util::propkit::{check, Gen};
use gkselect::Key;

/// K random micro-batches with per-batch shape drawn from the
/// acceptance matrix: wide-uniform, duplicate-heavy, sorted, or a
/// narrow shifted band (the non-stationary case cached sketches hate).
fn gen_batches(g: &mut Gen) -> Vec<Vec<Key>> {
    let k = g.usize_in(2, 6);
    (0..k)
        .map(|_| {
            let n = g.usize_in(1, 1500);
            match g.usize_in(0, 3) {
                0 => (0..n).map(|_| g.i32_in(-1_000_000, 1_000_000)).collect(),
                1 => (0..n).map(|_| g.i32_in(0, 8)).collect(),
                2 => {
                    let mut v: Vec<Key> =
                        (0..n).map(|_| g.i32_in(-50_000, 50_000)).collect();
                    v.sort_unstable();
                    v
                }
                _ => {
                    let base = g.i32_in(-900_000, 900_000);
                    (0..n).map(|_| base + g.i32_in(0, 1000)).collect()
                }
            }
        })
        .collect()
}

fn gen_q(g: &mut Gen) -> f64 {
    match g.usize_in(0, 9) {
        0 => 0.0,
        1 => 1.0,
        _ => g.f64_unit(),
    }
}

fn stream_engine(
    executors: usize,
    partitions: usize,
    mode: ExecMode,
    eps: f64,
) -> QuantileEngine {
    EngineBuilder::new()
        .cluster(ClusterConfig::local(executors, partitions).with_exec_mode(mode))
        .algorithm(AlgoChoice::GkSelect)
        .epsilon(eps)
        // high threshold: ingest never auto-compacts unless a test
        // triggers compaction itself
        .compaction(CompactionPolicy {
            compact_threshold: 1000,
            max_live_epochs: 4,
        })
        .build()
        .unwrap()
}

fn ingest_all(engine: &mut QuantileEngine, batches: &[Vec<Key>]) {
    for b in batches {
        engine.ingest("s", MicroBatch::new(b.clone())).unwrap();
    }
}

/// (a) After K random micro-batches the cached incremental sketches,
/// merged, bracket every true rank — like a from-scratch sketch over the
/// concatenation — and the open band they would extract stays within the
/// ε-derived candidate budget (the protocol's definition of "same ε
/// guarantee": the fused scan keeps its bounded-traffic contract).
#[test]
fn prop_incremental_sketches_keep_epsilon_guarantee() {
    check("incremental_sketch_guarantee", 40, |g| {
        let executors = g.usize_in(1, 3);
        let partitions = g.usize_in(executors, executors * 3);
        let mut cluster = Cluster::new(ClusterConfig::local(executors, partitions));
        let mut store = SketchStore::default();
        let eps = 0.005 + g.f64_unit() * 0.1;
        let batches = gen_batches(g);
        let ing = StreamIngestor::new(eps).unwrap();
        for b in &batches {
            ing.ingest(&mut cluster, &mut store, "s", MicroBatch::new(b.clone()))
                .unwrap();
        }

        let mut all: Vec<Key> = batches.iter().flatten().copied().collect();
        all.sort_unstable();
        let n = all.len() as u64;
        let merged = store.stream("s").unwrap().merged_sketch().unwrap();
        assert_eq!(merged.count, n, "cached partials must cover the stream");
        let scratch = GkCore::from_sorted(&all, eps);

        for pct in [1u64, 25, 50, 75, 99, 100] {
            let rank = (pct * n).div_ceil(100).clamp(1, n);
            let truth = all[(rank - 1) as usize];
            let (lo, hi) = merged.query_rank_bounds(rank).unwrap();
            assert!(
                lo <= truth && truth <= hi,
                "incremental band [{lo},{hi}] misses x({rank})={truth} (n={n}, eps={eps})"
            );
            let (slo, shi) = scratch.query_rank_bounds(rank).unwrap();
            assert!(slo <= truth && truth <= shi, "scratch band broken");
            // open-band volume within the ε-derived budget, same contract
            // the batch path's candidate_volume analysis pins
            let inner = all
                .partition_point(|&x| x < hi)
                .saturating_sub(all.partition_point(|&x| x <= lo));
            assert!(
                inner <= default_candidate_budget(eps, n),
                "open band {inner} exceeds budget {} (n={n}, eps={eps}, K={})",
                default_candidate_budget(eps, n),
                batches.len()
            );
        }
    });
}

/// (b) A streamed query equals batch GK Select over the concatenated
/// data — bit-identical values, both execution modes, arbitrary
/// geometries — and never exceeds the fallback cost envelope. One
/// engine, two `Source`s.
#[test]
fn prop_stream_query_matches_batch_gk_select_both_modes() {
    check("stream_matches_batch", 25, |g| {
        let batches = gen_batches(g);
        let q = gen_q(g);
        let executors = g.usize_in(1, 3);
        let partitions = g.usize_in(executors, executors * 3);
        let concat: Vec<Key> = batches.iter().flatten().copied().collect();
        let mut across_modes: Option<Key> = None;

        for mode in [ExecMode::Sequential, ExecMode::Threads] {
            let mut engine = stream_engine(executors, partitions, mode, 0.01);
            ingest_all(&mut engine, &batches);
            let out = engine
                .execute(Source::Stream("s"), QuantileQuery::Single(q))
                .unwrap();

            let data = Dataset::from_vec(concat.clone(), partitions).unwrap();
            let mut batch_engine = stream_engine(executors, partitions, mode, 0.01);
            let batch_out = batch_engine
                .execute(Source::Dataset(&data), QuantileQuery::Single(q))
                .unwrap();

            assert_eq!(
                out.value(),
                batch_out.value(),
                "stream vs batch disagree at q={q} ({} batches)",
                batches.len()
            );
            assert_eq!(out.value(), oracle_quantile(&data, q).unwrap(), "q={q}");
            // fast path is 1 round / 1 scan; an out-of-contract band may
            // cost the one fallback scan, never more
            assert!(out.report.rounds <= 2, "rounds = {}", out.report.rounds);
            assert!(out.report.data_scans <= 2);
            assert_eq!(out.report.shuffles, 0);
            assert_eq!(out.report.persists, 0);
            match across_modes {
                None => across_modes = Some(out.value()),
                Some(v) => assert_eq!(out.value(), v, "exec modes disagree at q={q}"),
            }
        }
    });
}

/// (c) Epoch compaction is invisible to queries: answers before and
/// after a forced compaction are identical (data is rewritten, never
/// dropped; merged partials stay in contract or the fallback absorbs
/// them).
#[test]
fn prop_compaction_never_changes_answers() {
    check("compaction_invariant", 25, |g| {
        let batches = gen_batches(g);
        let executors = g.usize_in(1, 2);
        let partitions = g.usize_in(executors, executors * 3);
        let max_live = g.usize_in(1, 3);
        // threshold high enough that ingest never auto-compacts: the
        // test owns the compaction point
        let mut engine = EngineBuilder::new()
            .cluster(ClusterConfig::local(executors, partitions))
            .epsilon(0.02)
            .compaction(CompactionPolicy {
                compact_threshold: 1000,
                max_live_epochs: max_live,
            })
            .build()
            .unwrap();
        ingest_all(&mut engine, &batches);
        let total = engine.store().stream("s").unwrap().total_count();

        let qs = [0.0, 0.25, 0.5, 0.9, 1.0];
        let before: Vec<Key> = qs
            .iter()
            .map(|&q| {
                engine
                    .execute(Source::Stream("s"), QuantileQuery::Single(q))
                    .unwrap()
                    .value()
            })
            .collect();

        let stats = engine.store_mut().compact("s").unwrap();
        if batches.len() > max_live {
            let s = stats.expect("above target ⇒ compaction fires");
            assert!(s.merged_epochs >= 2);
            assert_eq!(s.live_epochs, max_live);
        }
        assert_eq!(engine.store().stream("s").unwrap().total_count(), total);

        let after: Vec<Key> = qs
            .iter()
            .map(|&q| {
                engine
                    .execute(Source::Stream("s"), QuantileQuery::Single(q))
                    .unwrap()
                    .value()
            })
            .collect();
        assert_eq!(before, after, "compaction changed query answers");
    });
}
