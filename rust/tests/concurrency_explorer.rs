//! Deterministic interleaving exploration of the service layer
//! ([`gkselect::testing`]): every context switch happens at an
//! instrumented sync point, every schedule is a replayable decision
//! vector, and the suite proves three things the racing-thread tests
//! cannot:
//!
//! 1. **The 2-writer × 2-reader scenario holds its invariants on every
//!    explored schedule** — ≥ 100 distinct interleavings of
//!    `lock_writer` / `publish` / `pin` / memo-init / registry-absorb,
//!    each asserting snapshot isolation (a pin answers identically no
//!    matter what seals around it), seal linearizability (a writer's
//!    batch is pinned-visible the moment its ingest returns), memo
//!    freshness (the merged sketch counts exactly the pinned records),
//!    zero lost updates, and exact registry accounting.
//! 2. **The explorer catches the bug class** — a deliberately broken
//!    store double that caches its merged-sketch memo on mutable stream
//!    state (the shape PR 9's memo-on-snapshot design rules out) fails
//!    under exploration, and replaying the failing schedule's decision
//!    vector reproduces the failure deterministically; the fixed double
//!    (memo scoped to the pin) passes every schedule of the same tree.
//! 3. **The poisoning recovery contract survives the real ingest
//!    path** — a failpoint panics a writer at the publish point (token
//!    held, epoch sealed but unpublished); the stream stays usable, the
//!    published snapshot stays coherent, and the next ingest publishes
//!    the stranded epoch, exactly as `service/shard.rs` documents.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use gkselect::cluster::{ClusterConfig, FaultPlan};
use gkselect::engine::QuantileQuery;
use gkselect::obs::{MetricsMode, OpKind};
use gkselect::service::QuantileService;
use gkselect::stream::MicroBatch;
use gkselect::testing::{checkpoint, Explorer, TaskSet};
use gkselect::Key;

const STREAM: &str = "explored";
const WARM: u64 = 64;
const W1_BATCH: u64 = 48;
const W2_BATCH: u64 = 32;

fn service() -> QuantileService {
    QuantileService::builder()
        .cluster(ClusterConfig::local(2, 4))
        .metrics(MetricsMode::Memory)
        .build()
        .unwrap()
}

fn values(lo: i32, n: u64) -> Vec<Key> {
    (0..n as i32).map(|i| lo + i * 3).collect()
}

/// Silence the default panic hook around explorations that *expect*
/// failing schedules.
fn with_quiet_panics<T>(f: impl FnOnce() -> T) -> T {
    let hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let out = f();
    std::panic::set_hook(hook);
    out
}

/// The tentpole scenario: one stream, two writers, two readers, fresh
/// service per schedule. Writers assert seal linearizability at return;
/// readers assert snapshot isolation and memo freshness on their pin;
/// the final check asserts zero lost updates and exact accounting.
fn two_writers_two_readers(tasks: &mut TaskSet) {
    let svc = Arc::new(service());
    // Warm from the driver (unregistered: hooks no-op) so a reader can
    // never pin a stream with zero sealed epochs.
    svc.ingest(STREAM, MicroBatch::new(values(0, WARM))).unwrap();

    for (name, lo, n) in [("w1", 10_000, W1_BATCH), ("w2", 20_000, W2_BATCH)] {
        let svc = svc.clone();
        tasks.spawn(name, move || {
            let out = svc.ingest(STREAM, MicroBatch::new(values(lo, n))).unwrap();
            assert_eq!(out.batch_records, n, "{name}: batch sealed whole");
            // Seal linearizability: the published snapshot at ingest
            // return already contains this writer's batch.
            let pin = svc.pin(STREAM).unwrap();
            assert!(
                pin.snapshot().total_count() >= WARM + n,
                "{name}: pinned count {} misses the batch this ingest sealed",
                pin.snapshot().total_count()
            );
        });
    }

    for name in ["r1", "r2"] {
        let svc = svc.clone();
        tasks.spawn(name, move || {
            let pin = svc.pin(STREAM).unwrap();
            let pinned = pin.snapshot().total_count();
            assert!(pinned >= WARM, "{name}: pinned a pre-warm snapshot");
            // Memo freshness: the merged sketch summarizes exactly the
            // records of the pinned epoch list — never a later seal's.
            let merged = pin.snapshot().merged_sketch().expect("warmed stream");
            assert_eq!(merged.count, pinned, "{name}: merged-sketch memo is stale");
            // Snapshot isolation: the same pin answers identically no
            // matter how many seals the schedule interleaves between.
            let query = QuantileQuery::Sketched { q: 0.5, eps: 0.05 };
            let first = svc.query_pinned(&pin, &query).unwrap();
            let second = svc.query_pinned(&pin, &query).unwrap();
            assert_eq!(
                first.value(),
                second.value(),
                "{name}: one pin, two answers — snapshot isolation broken"
            );
            assert_eq!(pin.snapshot().total_count(), pinned, "{name}: pin mutated");
        });
    }

    tasks.check(move || {
        // Zero lost updates: both batches landed exactly once.
        let total = WARM + W1_BATCH + W2_BATCH;
        let pin = svc.pin(STREAM).unwrap();
        assert_eq!(pin.snapshot().total_count(), total, "lost update");
        assert_eq!(
            pin.snapshot().merged_sketch().unwrap().count,
            total,
            "final merged sketch misses records"
        );
        // Exact accounting: warm + 2 ingests + 2 readers × 2 queries.
        let snap = svc.metrics_snapshot();
        assert_eq!(snap.ops, 7, "one absorb per operation, no matter the schedule");
        assert_eq!(
            snap.totals_for(OpKind::Ingest, STREAM).unwrap().records,
            total,
            "ingest totals drifted from the store"
        );
        assert_eq!(svc.in_flight_queries(), 0);
        assert_eq!(svc.ingest_queue_depth(), 0);
    });
}

#[test]
fn service_invariants_hold_on_at_least_100_exhaustive_schedules() {
    let exploration = Explorer::exhaustive()
        .max_schedules(128)
        .explore(two_writers_two_readers);
    exploration.assert_no_failures();
    assert!(
        exploration.schedules >= 100,
        "only {} distinct schedules explored",
        exploration.schedules
    );
    assert!(
        !exploration.complete,
        "the 2w×2r tree is far larger than the cap; 'complete' means the \
         instrumentation stopped yielding"
    );
}

#[test]
fn service_invariants_hold_on_seeded_random_schedules() {
    let exploration = Explorer::random(0xD15C0, 48).explore(two_writers_two_readers);
    exploration.assert_no_failures();
    assert!(
        exploration.schedules >= 8,
        "seeded sampling collapsed to {} schedules",
        exploration.schedules
    );
}

// ---------------------------------------------------------------------
// The broken store double: proof the explorer catches the bug class.
// ---------------------------------------------------------------------

/// A minimal model of the serving read path with the PR 9 bug
/// deliberately reintroduced: epochs (record counts) live on the
/// stream, pins copy the epoch list, but the merged "sketch" (here just
/// the merged count) is cached on the *mutable stream state* and never
/// invalidated by a seal — so a reader can serve a memo built over a
/// different epoch list than the one it pinned.
struct MemoDouble {
    epochs: Mutex<Vec<u64>>,
    stream_memo: Mutex<Option<u64>>,
    /// True = the fixed design: the memo is computed per pin instead of
    /// served from stream state.
    memo_on_pin: bool,
}

impl MemoDouble {
    fn new(memo_on_pin: bool) -> Self {
        Self {
            epochs: Mutex::new(Vec::new()),
            stream_memo: Mutex::new(None),
            memo_on_pin,
        }
    }

    fn seal(&self, count: u64) {
        checkpoint("double_seal");
        self.epochs.lock().unwrap().push(count);
        // BUG (broken variant): no memo invalidation here.
    }

    fn pin(&self) -> Vec<u64> {
        checkpoint("double_pin");
        self.epochs.lock().unwrap().clone()
    }

    /// The read path: merged count for a pinned epoch list.
    fn merged(&self, pin: &[u64]) -> u64 {
        checkpoint("double_memo");
        if self.memo_on_pin {
            // Fixed shape: memo scoped to exactly the pinned list.
            return pin.iter().sum();
        }
        // Broken shape: first reader warms a stream-wide memo from the
        // *current* epoch list; everyone after serves the cache.
        let mut memo = self.stream_memo.lock().unwrap();
        *memo.get_or_insert_with(|| self.epochs.lock().unwrap().iter().sum())
    }
}

fn memo_scenario(memo_on_pin: bool) -> impl FnMut(&mut TaskSet) {
    move |tasks: &mut TaskSet| {
        let store = Arc::new(MemoDouble::new(memo_on_pin));
        {
            let store = store.clone();
            tasks.spawn("writer", move || {
                store.seal(100);
                store.seal(50);
            });
        }
        for name in ["r1", "r2"] {
            let store = store.clone();
            tasks.spawn(name, move || {
                let pin = store.pin();
                let served = store.merged(&pin);
                assert_eq!(
                    served,
                    pin.iter().sum::<u64>(),
                    "{name}: stale merged memo — served a sum over a different \
                     epoch list than the pinned one"
                );
            });
        }
    }
}

#[test]
fn explorer_catches_the_stale_memo_bug_and_replays_it_deterministically() {
    let exploration = with_quiet_panics(|| {
        Explorer::exhaustive()
            .max_schedules(400)
            .explore(memo_scenario(false))
    });
    assert!(
        !exploration.failures.is_empty(),
        "exploration must find the stale-memo interleaving"
    );
    assert!(
        exploration.failures.len() < exploration.schedules,
        "sequential schedules must still pass"
    );
    for failure in &exploration.failures {
        assert!(
            failure.messages.iter().any(|m| m.contains("stale merged memo")),
            "unexpected failure mode: {:?}",
            failure.messages
        );
    }

    // The failing schedule is a deterministic reproduction: replaying
    // its decision vector fails identically, run after run.
    let failing = &exploration.failures[0];
    for _ in 0..3 {
        let replayed = with_quiet_panics(|| {
            Explorer::exhaustive().replay(&failing.schedule, memo_scenario(false))
        });
        assert_eq!(replayed.failures, failing.messages, "replay diverged");
        assert_eq!(replayed.trace, failing.trace, "replay took a different path");
    }
}

#[test]
fn fixed_memo_double_passes_the_same_schedule_tree() {
    let exploration = Explorer::exhaustive().max_schedules(400).explore(memo_scenario(true));
    exploration.assert_no_failures();
    assert!(exploration.schedules > 1);
}

// ---------------------------------------------------------------------
// Poisoning recovery through the real ingest path.
// ---------------------------------------------------------------------

/// A writer panics at the publish sync point — writer token held, epoch
/// sealed but not yet published. The recovery contract in
/// `service/shard.rs` promises: the stream stays usable, the published
/// snapshot stays the last fully-built one, and the next successful
/// ingest publishes the stranded epoch.
#[test]
fn writer_panicking_at_publish_leaves_stream_usable_and_snapshot_coherent() {
    let svc = Arc::new(service());
    svc.ingest(STREAM, MicroBatch::new(values(0, WARM))).unwrap();

    let panicked = Arc::new(AtomicU64::new(0));
    let exploration = with_quiet_panics(|| {
        let svc = svc.clone();
        let panicked = panicked.clone();
        Explorer::exhaustive()
            .max_schedules(1)
            .failpoint("publish", 1)
            .explore(move |tasks| {
                let svc = svc.clone();
                let panicked = panicked.clone();
                tasks.spawn("doomed-writer", move || {
                    let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        svc.ingest(STREAM, MicroBatch::new(values(30_000, W1_BATCH)))
                    }));
                    assert!(r.is_err(), "the publish failpoint must fire");
                    panicked.fetch_add(1, Ordering::SeqCst);
                    // resume unwinding so the explorer records the task
                    // as failed with the injected panic
                    std::panic::resume_unwind(r.unwrap_err());
                });
            })
    });
    assert_eq!(panicked.load(Ordering::SeqCst), 1);
    assert_eq!(exploration.failures.len(), 1);
    assert!(
        exploration.failures[0].messages[0].contains("failpoint"),
        "got: {:?}",
        exploration.failures[0].messages
    );

    // Coherent: the published snapshot is still the pre-panic one — the
    // doomed batch sealed but never published.
    let pin = svc.pin(STREAM).unwrap();
    assert_eq!(pin.snapshot().total_count(), WARM);

    // Usable: the next ingest recovers the poisoned token and publishes
    // both its own epoch and the stranded one.
    let out = svc.ingest(STREAM, MicroBatch::new(values(40_000, W2_BATCH))).unwrap();
    assert_eq!(out.batch_records, W2_BATCH);
    let pin = svc.pin(STREAM).unwrap();
    assert_eq!(
        pin.snapshot().total_count(),
        WARM + W1_BATCH + W2_BATCH,
        "recovery ingest must publish the stranded sealed epoch too"
    );
    assert_eq!(
        pin.snapshot().merged_sketch().unwrap().count,
        WARM + W1_BATCH + W2_BATCH
    );
    let out = svc
        .query_pinned(&pin, &QuantileQuery::Single(0.5))
        .unwrap();
    assert!(out.report.exact, "served answers stay exact after recovery");
}

/// The pool-level fault path: a writer task that panics via `FaultPlan`
/// is caught *inside* the executor pool (retried, then surfaced as a
/// typed error), so a failed ingest returns `Err` without poisoning
/// anything — the stream entry stays usable and the published snapshot
/// untouched.
#[test]
fn fault_plan_panicking_writer_task_fails_cleanly_and_stream_recovers() {
    let svc = QuantileService::builder()
        .cluster(ClusterConfig::local(2, 4))
        .metrics(MetricsMode::Memory)
        .build()
        .unwrap();
    svc.ingest(STREAM, MicroBatch::new(values(0, WARM))).unwrap();

    // A second service handle can't swap cluster config per-op, so use
    // a dedicated service whose every task attempt panics: ingest must
    // exhaust retries and fail with a typed error, not a poison.
    let chaotic = QuantileService::builder()
        .cluster(
            ClusterConfig::local(2, 4)
                .with_fault_plan(Some(FaultPlan::seeded(11).panics(1.0).attempts(u32::MAX))),
        )
        .metrics(MetricsMode::Memory)
        .build()
        .unwrap();
    let err = chaotic.ingest(STREAM, MicroBatch::new(values(0, 16)));
    assert!(err.is_err(), "all-attempts-panic plan must fail the ingest");
    // The failed ingest never published: the stream either doesn't
    // exist yet or is empty — and a later ingest on the healthy service
    // keeps working (no cross-stream, no cross-service damage).
    assert!(chaotic.pin(STREAM).is_err(), "nothing published from a failed first ingest");
    let out = svc.ingest(STREAM, MicroBatch::new(values(50_000, 16))).unwrap();
    assert_eq!(out.stream_records, WARM + 16);
}
