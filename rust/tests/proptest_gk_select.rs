//! Property tests for the fused two-round GK Select protocol: the fused
//! band path, the budget-overflow fallback, and the eq-run exit all have
//! to agree with `oracle_quantile` for arbitrary
//! (distribution, n, q, ε) tuples — driven through the engine façade.

use gkselect::algorithms::oracle_quantile;
use gkselect::cluster::dataset::Dataset;
use gkselect::cluster::ClusterConfig;
use gkselect::engine::{AlgoChoice, EngineBuilder, QuantileEngine, QuantileQuery, Source};
use gkselect::util::propkit::{check, Gen};
use gkselect::Key;

/// Random dataset with a randomly chosen shape: wide-uniform,
/// duplicate-heavy, sorted, or bimodal — the distribution axis of the
/// acceptance matrix, without dragging the generators in.
fn gen_dataset(g: &mut Gen) -> (usize, usize, Dataset<Key>, u64) {
    let executors = g.usize_in(1, 3);
    let partitions = g.usize_in(executors, executors * 4);
    let n = g.usize_in(1, 4_000);
    let mut values: Vec<Key> = match g.usize_in(0, 3) {
        0 => (0..n).map(|_| g.i32_in(-1_000_000_000, 999_999_999)).collect(),
        1 => (0..n).map(|_| g.i32_in(0, 8)).collect(), // duplicate-heavy
        2 => {
            let mut v: Vec<Key> = (0..n).map(|_| g.i32_in(-50_000, 50_000)).collect();
            v.sort_unstable();
            v
        }
        _ => (0..n)
            .map(|_| {
                if g.bool() {
                    g.i32_in(-1_000_000, -900_000)
                } else {
                    g.i32_in(900_000, 1_000_000)
                }
            })
            .collect(),
    };
    if values.is_empty() {
        values.push(g.i32_in(-5, 5));
    }
    let len = values.len() as u64;
    (
        executors,
        partitions,
        Dataset::from_vec(values, partitions).unwrap(),
        len,
    )
}

fn gk_engine(
    executors: usize,
    partitions: usize,
    eps: f64,
    budget: Option<usize>,
) -> QuantileEngine {
    let mut b = EngineBuilder::new()
        .cluster(ClusterConfig::local(executors, partitions))
        .algorithm(AlgoChoice::GkSelect)
        .epsilon(eps);
    if let Some(budget) = budget {
        b = b.candidate_budget(budget);
    }
    b.build().unwrap()
}

fn gen_q(g: &mut Gen) -> f64 {
    match g.usize_in(0, 9) {
        0 => 0.0,
        1 => 1.0,
        _ => g.f64_unit(),
    }
}

fn gen_eps(g: &mut Gen) -> f64 {
    0.001 + g.f64_unit() * 0.3
}

#[test]
fn prop_fused_path_matches_oracle() {
    check("fused_matches_oracle", 60, |g| {
        let (executors, partitions, data, _n) = gen_dataset(g);
        let q = gen_q(g);
        let eps = gen_eps(g);
        let truth = oracle_quantile(&data, q).unwrap();
        let mut engine = gk_engine(executors, partitions, eps, None);
        let out = engine
            .execute(Source::Dataset(&data), QuantileQuery::Single(q))
            .unwrap();
        assert_eq!(out.value(), truth, "q={q} eps={eps}");
        assert!(out.report.rounds <= 3);
        assert_eq!(out.report.shuffles, 0);
        assert_eq!(out.report.persists, 0);
        // fused path: ≤ 2 scans; fallback adds exactly one more
        assert!(out.report.data_scans <= 3, "scans = {}", out.report.data_scans);
    });
}

#[test]
fn prop_band_overflow_fallback_stays_exact() {
    // budget 0 forces the fallback whenever the open band is nonempty;
    // across the sweep the 3-round path must fire and must stay exact
    let mut saw_fallback = false;
    check("overflow_fallback_exact", 40, |g| {
        let (executors, partitions, data, _n) = gen_dataset(g);
        let q = gen_q(g);
        let eps = gen_eps(g);
        let truth = oracle_quantile(&data, q).unwrap();
        let mut engine = gk_engine(executors, partitions, eps, Some(0));
        let out = engine
            .execute(Source::Dataset(&data), QuantileQuery::Single(q))
            .unwrap();
        assert_eq!(out.value(), truth, "fallback q={q} eps={eps}");
        assert!(out.report.rounds <= 3);
        if out.report.rounds == 3 {
            assert_eq!(out.report.data_scans, 3);
            saw_fallback = true;
        }
    });
    if std::env::var("PROPKIT_SEED").is_err() {
        assert!(saw_fallback, "sweep never exercised the 3-round fallback");
    }
}

#[test]
fn prop_eq_run_exit_in_two_rounds() {
    // constant datasets answer from the pivot's eq-run: 2 rounds, 1
    // post-sketch scan, regardless of ε or the candidate budget
    check("eq_run_two_rounds", 25, |g| {
        let n = g.usize_in(1, 2_000);
        let v = g.i32_in(-100, 100);
        let partitions = g.usize_in(1, 8);
        let data = Dataset::from_vec(vec![v; n], partitions).unwrap();
        let q = gen_q(g);
        let mut engine = gk_engine(1, partitions, gen_eps(g), Some(0));
        let out = engine
            .execute(Source::Dataset(&data), QuantileQuery::Single(q))
            .unwrap();
        assert_eq!(out.value(), v);
        assert_eq!(out.report.rounds, 2, "eq-run exit must stay 2 rounds");
        assert_eq!(out.report.data_scans, 2);
    });
}

#[test]
fn prop_multi_select_matches_oracle() {
    check("multi_select_matches_oracle", 30, |g| {
        let (executors, partitions, data, _n) = gen_dataset(g);
        let m = g.usize_in(1, 5);
        let qs: Vec<f64> = (0..m).map(|_| gen_q(g)).collect();
        let mut engine = gk_engine(executors, partitions, gen_eps(g), None);
        let out = engine
            .execute(Source::Dataset(&data), QuantileQuery::Multi(qs.clone()))
            .unwrap();
        for (&q, &v) in qs.iter().zip(out.values.iter()) {
            assert_eq!(v, oracle_quantile(&data, q).unwrap(), "q={q}");
        }
        assert!(out.report.rounds <= 3);
        assert!(out.report.data_scans <= 3);
        assert_eq!(out.report.shuffles, 0);
    });
}

#[test]
fn prop_rank_plans_match_single_plans() {
    // Rank(k) ↔ Single(q) consistency at k = target_rank(n, q), plus the
    // oracle, across random geometries
    check("rank_matches_single", 30, |g| {
        let (executors, partitions, data, n) = gen_dataset(g);
        let q = gen_q(g);
        let k = gkselect::target_rank(n, q);
        let mut engine = gk_engine(executors, partitions, gen_eps(g), None);
        let by_q = engine
            .execute(Source::Dataset(&data), QuantileQuery::Single(q))
            .unwrap();
        let by_k = engine
            .execute(Source::Dataset(&data), QuantileQuery::Rank(k))
            .unwrap();
        assert_eq!(by_q.value(), by_k.value(), "q={q} k={k} n={n}");
        assert_eq!(by_k.value(), oracle_quantile(&data, q).unwrap());
    });
}
