//! Runtime integration: the PJRT path (AOT Pallas kernels through the
//! XLA CPU client) against the native backend on real data, plus GK
//! Select running end-to-end on the PJRT backend.
//!
//! These tests are skipped (with a loud message) when `artifacts/` is
//! missing — run `make artifacts` first; `make test` does. The whole
//! file is additionally gated on the `pjrt` cargo feature (the default
//! build resolves offline and carries no XLA binding).

#![cfg(feature = "pjrt")]
// The GK Select run below deliberately drives the pre-redesign
// backend-owning shim with an explicit PjrtBackend; the supported path
// is `EngineBuilder::kernel_backend(Box::new(pjrt))`.
#![allow(deprecated)]

use gkselect::algorithms::gk_select::{GkSelect, GkSelectParams};
use gkselect::algorithms::oracle_quantile;
use gkselect::cluster::{Cluster, ClusterConfig};
use gkselect::data::pcg::Pcg64;
use gkselect::data::{DataGenerator, Distribution};
use gkselect::runtime::{KernelBackend, NativeBackend, PjrtBackend};
use gkselect::Key;
use std::path::Path;

fn pjrt() -> Option<PjrtBackend> {
    match PjrtBackend::load(Path::new("artifacts")) {
        Ok(b) => Some(b),
        Err(e) => {
            eprintln!("SKIP: PJRT artifacts unavailable — run `make artifacts` ({e:#})");
            None
        }
    }
}

fn random_keys(n: usize, seed: u64) -> Vec<Key> {
    let mut rng = Pcg64::new(seed, 5);
    (0..n).map(|_| rng.next_u64() as Key).collect()
}

#[test]
fn pjrt_count_pivot_matches_native() {
    let Some(pjrt) = pjrt() else { return };
    let native = NativeBackend::new();
    // sizes straddling the buffer length (131072): empty, tiny, exact,
    // one-over, multi-chunk
    for n in [0usize, 1, 1000, 131072, 131073, 400_000] {
        let data = random_keys(n, n as u64);
        for pivot in [Key::MIN, -1, 0, 42, Key::MAX] {
            let a = pjrt.count_pivot(&data, pivot);
            let b = native.count_pivot(&data, pivot);
            assert_eq!(a, b, "n={n} pivot={pivot}");
        }
    }
}

#[test]
fn pjrt_band_count_matches_native() {
    let Some(pjrt) = pjrt() else { return };
    let native = NativeBackend::new();
    let data = random_keys(300_000, 9);
    for (lo, hi) in [(-1000, 1000), (0, 0), (Key::MIN, Key::MAX), (500, 100)] {
        let a = pjrt.band_count(&data, lo, hi);
        let b = native.band_count(&data, lo, hi);
        assert_eq!(a, b, "band [{lo}, {hi}]");
    }
}

#[test]
fn pjrt_histogram_matches_native() {
    let Some(pjrt) = pjrt() else { return };
    let native = NativeBackend::new();
    let data = random_keys(200_000, 11);
    let lo = Key::MIN as i64;
    let width = (1u64 << 32) as i64 / 128 + 1;
    let a = pjrt.histogram(&data, lo, width, 128);
    let b = native.histogram(&data, lo, width, 128);
    assert_eq!(a, b);
    assert_eq!(a.iter().sum::<u64>(), 200_000);
}

#[test]
fn pjrt_minmax_matches_native() {
    let Some(pjrt) = pjrt() else { return };
    let native = NativeBackend::new();
    for n in [0usize, 1, 131072, 131073] {
        let data = random_keys(n, 13 + n as u64);
        assert_eq!(pjrt.minmax(&data), native.minmax(&data), "n={n}");
    }
}

#[test]
fn pjrt_band_extract_matches_native() {
    let Some(pjrt) = pjrt() else { return };
    let native = NativeBackend::new();
    // straddle the 131072 buffer length so multi-chunk accumulation and
    // per-chunk compaction both get exercised
    for n in [0usize, 1, 1000, 131072, 131073, 300_000] {
        let data = random_keys(n, 21 + n as u64);
        for (pivot, lo, hi) in [
            (0, -1_000_000, 1_000_000),
            (0, 0, 0),
            (42, Key::MIN, Key::MAX),
        ] {
            let budget = usize::MAX;
            let a = pjrt.band_extract(&data, pivot, lo, hi, budget);
            let b = native.band_extract(&data, pivot, lo, hi, budget);
            assert_eq!(a.pivot, b.pivot, "n={n} pivot counts");
            assert_eq!(a.band, b.band, "n={n} band stats");
            assert_eq!(a.overflow, b.overflow, "n={n} overflow");
            let (mut ac, mut bc) = (a.candidates, b.candidates);
            ac.sort_unstable();
            bc.sort_unstable();
            assert_eq!(ac, bc, "n={n} candidates");
        }
    }
}

#[test]
fn gk_select_exact_on_pjrt_backend() {
    let Some(pjrt) = pjrt() else { return };
    let mut cluster = Cluster::new(ClusterConfig::local(2, 8));
    let data = Distribution::Uniform.generator(17).generate(&mut cluster, 50_000);
    let truth = oracle_quantile(&data, 0.75).unwrap();
    let mut alg = GkSelect::with_backend(GkSelectParams::default(), Box::new(pjrt));
    let out = alg.quantile(&mut cluster, &data, 0.75).unwrap();
    assert_eq!(out.value, truth, "PJRT-backed GK Select must stay exact");
    assert_eq!(alg.backend_name(), "pjrt");
}
