//! Property-based tests (propkit) on the coordinator's invariants:
//! exactness of every exact algorithm under arbitrary data/partitioning,
//! GK sketch rank-error bounds, selection primitives vs sort, and
//! substrate conservation laws (routing preserves multisets).
//!
//! Replay a failing case with `PROPKIT_SEED=<seed> cargo test <name>`.

use gkselect::algorithms::oracle_quantile;
use gkselect::cluster::dataset::Dataset;
use gkselect::cluster::shuffle::shuffle_by_range;
use gkselect::cluster::{Cluster, ClusterConfig};
use gkselect::engine::{AlgoChoice, EngineBuilder, QuantileQuery, Source};
use gkselect::select::{bfprt_select, dutch_partition, floyd_rivest_select, select_kth};
use gkselect::sketch::classical::ClassicalGk;
use gkselect::sketch::QuantileSketch;
use gkselect::util::propkit::{check, Gen};

/// Arbitrary dataset: duplicate-heavy values over 2–8 partitions.
fn gen_dataset(g: &mut Gen) -> (Dataset<i32>, Vec<i32>, usize) {
    let values = g.vec_i32(1, 400, -1000, 1000);
    let p = g.usize_in(2, 8);
    (Dataset::from_vec(values.clone(), p).unwrap(), values, p)
}

#[test]
fn prop_gk_select_always_exact() {
    check("gk_select_exact", 64, |g| {
        let (data, _, p) = gen_dataset(g);
        let q = g.f64_unit();
        let truth = oracle_quantile(&data, q).unwrap();
        let mut engine = EngineBuilder::new()
            .cluster(ClusterConfig::local(2, p))
            .algorithm(AlgoChoice::GkSelect)
            .epsilon(0.05)
            .build()
            .unwrap();
        let out = engine
            .execute(Source::Dataset(&data), QuantileQuery::Single(q))
            .unwrap();
        assert_eq!(out.value(), truth, "q={q}");
        assert!(out.report.rounds <= 3);
        assert_eq!(out.report.shuffles, 0);
        assert_eq!(out.report.persists, 0);
    });
}

#[test]
fn prop_count_discard_always_exact() {
    check("count_discard_exact", 48, |g| {
        let (data, _, p) = gen_dataset(g);
        let q = g.f64_unit();
        let truth = oracle_quantile(&data, q).unwrap();
        for choice in [AlgoChoice::Afs, AlgoChoice::Jeffers] {
            let mut engine = EngineBuilder::new()
                .cluster(ClusterConfig::local(2, p))
                .algorithm(choice)
                .build()
                .unwrap();
            let out = engine
                .execute(Source::Dataset(&data), QuantileQuery::Single(q))
                .unwrap();
            assert_eq!(out.value(), truth, "{choice:?} q={q}");
        }
    });
}

#[test]
fn prop_histogram_select_always_exact() {
    check("hist_select_exact", 48, |g| {
        use gkselect::algorithms::histogram_select::{
            HistogramSelectParams, HistogramSelectStrategy,
        };
        use gkselect::algorithms::QuantileAlgorithm;
        use gkselect::engine::EngineCtx;
        use gkselect::runtime::NativeBackend;
        let (data, _, p) = gen_dataset(g);
        let q = g.f64_unit();
        let mut cluster = Cluster::new(ClusterConfig::local(2, p));
        let truth = oracle_quantile(&data, q).unwrap();
        let strategy = HistogramSelectStrategy::new(HistogramSelectParams {
            extract_cap: 64, // force several refinement rounds
            ..Default::default()
        });
        let backend = NativeBackend::new();
        let mut ctx = EngineCtx {
            cluster: &mut cluster,
            backend: &backend,
            data: &data,
        };
        let out = strategy
            .execute_plan(&mut ctx, &QuantileQuery::Single(q))
            .unwrap();
        assert_eq!(out.value(), truth);
    });
}

#[test]
fn prop_selection_primitives_agree_with_sort() {
    check("selection_vs_sort", 128, |g| {
        let values = g.vec_i32(1, 400, -10_000, 10_000);
        let k = g.usize_in(0, values.len() - 1);
        let mut sorted = values.clone();
        sorted.sort_unstable();
        let want = sorted[k];
        let mut a = values.clone();
        assert_eq!(select_kth(&mut a, k, g.u64()), want, "quickselect");
        let mut b = values.clone();
        assert_eq!(floyd_rivest_select(&mut b, k), want, "floyd-rivest");
        let mut c = values;
        assert_eq!(bfprt_select(&mut c, k), want, "bfprt");
    });
}

#[test]
fn prop_dutch_partition_structure() {
    check("dutch_structure", 128, |g| {
        let mut values = g.vec_i32(0, 300, -100, 100);
        let pivot = g.i32_in(-100, 100);
        let mut sorted_before = values.clone();
        sorted_before.sort_unstable();
        let s = dutch_partition(&mut values, pivot);
        assert!(values[..s.lt].iter().all(|&x| x < pivot));
        assert!(values[s.lt..s.gt].iter().all(|&x| x == pivot));
        assert!(values[s.gt..].iter().all(|&x| x > pivot));
        let mut sorted_after = values;
        sorted_after.sort_unstable();
        assert_eq!(sorted_before, sorted_after, "multiset changed");
    });
}

#[test]
fn prop_shuffle_preserves_multiset_and_ranges() {
    check("shuffle_multiset", 64, |g| {
        let values = g.vec_i32(1, 400, -1000, 1000);
        let mut splitters = g.vec_i32(0, 6, -1000, 1000);
        splitters.sort_unstable();
        splitters.dedup();
        let mut cluster = Cluster::new(ClusterConfig::local(2, 4));
        let data = Dataset::from_vec(values.clone(), 4).unwrap();
        let routed = shuffle_by_range(&mut cluster, &data, &splitters);
        let mut before = values;
        before.sort_unstable();
        let mut after = routed.to_vec();
        after.sort_unstable();
        assert_eq!(before, after, "shuffle lost/duplicated records");
        for b in 0..routed.num_partitions() {
            for &v in routed.partition(b) {
                assert_eq!(splitters.partition_point(|&s| s < v), b, "misrouted {v}");
            }
        }
    });
}

#[test]
fn prop_classical_gk_rank_error_bounded() {
    check("gk_rank_error", 48, |g| {
        let values = g.vec_i32(50, 2_000, -100_000, 100_000);
        let eps = 0.05;
        let mut sk = ClassicalGk::new(eps);
        for &v in &values {
            sk.insert(v);
        }
        sk.finalize();
        assert!(sk.core().invariant_holds(), "g+Δ ≤ ⌊2εn⌋ violated");
        let mut sorted = values.clone();
        sorted.sort_unstable();
        let n = sorted.len() as f64;
        for q in [0.1, 0.5, 0.9] {
            let got = sk.query(q).unwrap();
            let lo = sorted.partition_point(|&x| x < got) as f64;
            let hi = sorted.partition_point(|&x| x <= got) as f64;
            let target = (q * n).ceil().max(1.0);
            let err = if target < lo {
                lo - target
            } else if target > hi {
                target - hi
            } else {
                0.0
            };
            assert!(err <= (eps * n).ceil() + 1.0, "err {err} at q {q} (n={n})");
        }
    });
}

#[test]
fn prop_dataset_from_vec_is_balanced_partition_of_input() {
    check("dataset_partition", 128, |g| {
        let values = g.vec_i32(1, 500, i32::MIN / 2, i32::MAX / 2);
        let p = g.usize_in(1, 16);
        let d = Dataset::from_vec(values.clone(), p).unwrap();
        assert_eq!(d.len() as usize, values.len());
        assert_eq!(d.to_vec(), values);
        let sizes = d.partition_sizes();
        let max = sizes.iter().max().unwrap();
        let min = sizes.iter().min().unwrap();
        assert!(max - min <= 1, "unbalanced: {sizes:?}");
    });
}

#[test]
fn prop_gk_select_epsilon_sweep_stays_exact() {
    check("gk_select_eps_sweep", 32, |g| {
        let (data, _, p) = gen_dataset(g);
        let q = g.f64_unit();
        let eps = [0.2, 0.1, 0.01, 0.001][g.usize_in(0, 3)];
        let truth = oracle_quantile(&data, q).unwrap();
        let mut engine = EngineBuilder::new()
            .cluster(ClusterConfig::local(2, p))
            .algorithm(AlgoChoice::GkSelect)
            .epsilon(eps)
            .build()
            .unwrap();
        assert_eq!(
            engine
                .execute(Source::Dataset(&data), QuantileQuery::Single(q))
                .unwrap()
                .value(),
            truth,
            "eps={eps} q={q}"
        );
    });
}
