//! SIMD ≡ scalar: the explicit band-extract tile (`runtime/simd.rs`)
//! must be **bit-identical** to the portable scalar oracle — counts,
//! candidate sets *in data order*, and overflow points (the budget is
//! checked at the same 4096-key tile boundaries) — across random
//! geometries including unaligned tails, partitions smaller than one
//! vector, collapsed bands, and budgets that trip mid-stream. On
//! targets without a SIMD tile `ForceSimd` degrades to scalar and the
//! properties hold trivially.
//!
//! End-to-end, engine answers and round/scan shapes must not depend on
//! the dispatch, in both executor modes — the engines differ only in
//! the injected kernel backend (`EngineBuilder::kernel_backend`).

use gkselect::algorithms::oracle_quantile;
use gkselect::cluster::dataset::Dataset;
use gkselect::cluster::{ClusterConfig, ExecMode};
use gkselect::engine::{AlgoChoice, EngineBuilder, QuantileEngine, QuantileQuery, Source};
use gkselect::runtime::{KernelBackend, NativeBackend, SimdPolicy};
use gkselect::stream::MicroBatch;
use gkselect::util::propkit::{check, Gen};
use gkselect::Key;

fn backends() -> (NativeBackend, NativeBackend) {
    (
        NativeBackend::with_policy(SimdPolicy::ForceScalar),
        NativeBackend::with_policy(SimdPolicy::ForceSimd),
    )
}

fn engine_with_backend(
    executors: usize,
    partitions: usize,
    mode: ExecMode,
    eps: f64,
    backend: NativeBackend,
) -> QuantileEngine {
    EngineBuilder::new()
        .cluster(ClusterConfig::local(executors, partitions).with_exec_mode(mode))
        .algorithm(AlgoChoice::GkSelect)
        .epsilon(eps)
        .kernel_backend(Box::new(backend))
        .build()
        .unwrap()
}

/// Random scan geometry. Sizes deliberately straddle the lane widths
/// (4/8) and the 4096-key tile; values switch between a wide domain
/// (sparse bands) and a tiny one (duplicate-saturated, endpoint runs).
fn gen_geometry(g: &mut Gen) -> (Vec<Key>, Key, Key, Key) {
    let n = match g.usize_in(0, 5) {
        0 => g.usize_in(0, 7),           // below one AVX2 vector
        1 => g.usize_in(8, 64),          // a few vectors + tail
        2 => g.usize_in(65, 4_095),      // sub-tile, unaligned
        3 => 4_096,                      // exactly one tile
        4 => g.usize_in(4_097, 12_000),  // multiple tiles + tail
        _ => g.usize_in(1, 300),
    };
    let (vlo, vhi) = if g.bool() {
        (-1_000_000_000, 999_999_999)
    } else {
        (-40, 40) // duplicate-heavy: every comparison class is populated
    };
    let data: Vec<Key> = (0..n).map(|_| g.i32_in(vlo, vhi)).collect();
    // pivot and band may sit inside, at the edge of, or entirely outside
    // the data range
    let pivot = g.i32_in(vlo - 50, vhi + 50);
    let mut lo = g.i32_in(vlo - 50, vhi + 50);
    let mut hi = g.i32_in(vlo - 50, vhi + 50);
    if lo > hi {
        std::mem::swap(&mut lo, &mut hi);
    }
    if g.usize_in(0, 4) == 0 {
        hi = lo; // collapsed band
    }
    (data, pivot, lo, hi)
}

fn gen_budget(g: &mut Gen) -> usize {
    match g.usize_in(0, 3) {
        0 => 0,                       // overflow on the first candidate
        1 => g.usize_in(1, 64),       // trips mid-stream
        2 => g.usize_in(65, 6_000),   // may trip at a tile boundary
        _ => usize::MAX,              // never trips
    }
}

#[test]
fn prop_band_extract_bit_identical() {
    check("band_extract_simd_bit_identical", 150, |g| {
        let (scalar, simd) = backends();
        let (data, pivot, lo, hi) = gen_geometry(g);
        let budget = gen_budget(g);
        let a = scalar.band_extract(&data, pivot, lo, hi, budget);
        let b = simd.band_extract(&data, pivot, lo, hi, budget);
        // full structural equality: counts, candidates in data order,
        // overflow flag
        assert_eq!(
            a, b,
            "dispatch {} vs scalar at n={} pivot={pivot} band=[{lo},{hi}] budget={budget}",
            simd.dispatch().label(),
            data.len()
        );
        assert_eq!(a.band.total(), data.len() as u64);
        assert_eq!(a.pivot.total(), data.len() as u64);
    });
}

#[test]
fn prop_multi_band_extract_bit_identical() {
    check("multi_band_extract_simd_bit_identical", 80, |g| {
        let (scalar, simd) = backends();
        let (data, _, _, _) = gen_geometry(g);
        let m = g.usize_in(1, 4);
        let mut queries = Vec::with_capacity(m);
        for _ in 0..m {
            let (_, pivot, lo, hi) = gen_geometry(g);
            queries.push((pivot, lo, hi));
        }
        let budget = gen_budget(g);
        let a = scalar.multi_band_extract(&data, &queries, budget);
        let b = simd.multi_band_extract(&data, &queries, budget);
        assert_eq!(
            a,
            b,
            "dispatch {} vs scalar, {m} queries over n={}",
            simd.dispatch().label(),
            data.len()
        );
    });
}

#[test]
fn prop_gk_select_answers_unchanged_both_exec_modes() {
    check("gk_select_simd_end_to_end", 20, |g| {
        let executors = g.usize_in(1, 3);
        let partitions = g.usize_in(executors, executors * 3);
        let n = g.usize_in(1, 3_000);
        let values: Vec<Key> = (0..n).map(|_| g.i32_in(-100_000, 100_000)).collect();
        let q = g.f64_unit();
        let eps = 0.001 + g.f64_unit() * 0.2;
        for mode in [ExecMode::Sequential, ExecMode::Threads] {
            let data = Dataset::from_vec(values.clone(), partitions).unwrap();
            let (scalar, simd) = backends();
            let mut a = engine_with_backend(executors, partitions, mode, eps, scalar);
            let mut b = engine_with_backend(executors, partitions, mode, eps, simd);
            let oa = a
                .execute(Source::Dataset(&data), QuantileQuery::Single(q))
                .unwrap();
            let ob = b
                .execute(Source::Dataset(&data), QuantileQuery::Single(q))
                .unwrap();
            assert_eq!(oa.value(), ob.value(), "mode {mode:?} q={q} eps={eps}");
            assert_eq!(oa.value(), oracle_quantile(&data, q).unwrap());
            // identical protocol shape: the dispatch may not change
            // rounds, scans, or the overflow/fallback decision
            assert_eq!(oa.report.rounds, ob.report.rounds);
            assert_eq!(oa.report.data_scans, ob.report.data_scans);
            assert_eq!(oa.report.network_volume_bytes, ob.report.network_volume_bytes);
        }
    });
}

#[test]
fn prop_multi_select_answers_unchanged_both_exec_modes() {
    check("multi_select_simd_end_to_end", 12, |g| {
        let partitions = g.usize_in(2, 6);
        let n = g.usize_in(2, 2_000);
        let values: Vec<Key> = (0..n).map(|_| g.i32_in(-5_000, 5_000)).collect();
        let qs: Vec<f64> = (0..g.usize_in(1, 4)).map(|_| g.f64_unit()).collect();
        for mode in [ExecMode::Sequential, ExecMode::Threads] {
            let data = Dataset::from_vec(values.clone(), partitions).unwrap();
            let (scalar, simd) = backends();
            let mut a = engine_with_backend(2, partitions, mode, 0.01, scalar);
            let mut b = engine_with_backend(2, partitions, mode, 0.01, simd);
            let oa = a
                .execute(Source::Dataset(&data), QuantileQuery::Multi(qs.clone()))
                .unwrap();
            let ob = b
                .execute(Source::Dataset(&data), QuantileQuery::Multi(qs.clone()))
                .unwrap();
            assert_eq!(oa.values, ob.values, "mode {mode:?}");
            assert_eq!(oa.report.rounds, ob.report.rounds);
            assert_eq!(oa.report.data_scans, ob.report.data_scans);
            for (&q, &v) in qs.iter().zip(oa.values.iter()) {
                assert_eq!(v, oracle_quantile(&data, q).unwrap(), "q={q}");
            }
        }
    });
}

#[test]
fn prop_stream_query_answers_unchanged_both_exec_modes() {
    check("stream_query_simd_end_to_end", 10, |g| {
        for mode in [ExecMode::Sequential, ExecMode::Threads] {
            let batches: Vec<Vec<Key>> = (0..g.usize_in(2, 4))
                .map(|_| {
                    let len = g.usize_in(1, 800);
                    (0..len).map(|_| g.i32_in(-50_000, 50_000)).collect()
                })
                .collect();
            let q = g.f64_unit();
            let (scalar, simd) = backends();
            let mut ea = engine_with_backend(2, 4, mode, 0.01, scalar);
            let mut eb = engine_with_backend(2, 4, mode, 0.01, simd);
            for b in &batches {
                ea.ingest("s", MicroBatch::new(b.clone())).unwrap();
                eb.ingest("s", MicroBatch::new(b.clone())).unwrap();
            }
            let oa = ea
                .execute(Source::Stream("s"), QuantileQuery::Single(q))
                .unwrap();
            let ob = eb
                .execute(Source::Stream("s"), QuantileQuery::Single(q))
                .unwrap();
            assert_eq!(oa.value(), ob.value(), "mode {mode:?} q={q}");
            assert_eq!(oa.report.rounds, ob.report.rounds);
            assert_eq!(oa.report.data_scans, ob.report.data_scans);
            let data = ea.store().stream("s").unwrap().live_dataset().unwrap();
            assert_eq!(oa.value(), oracle_quantile(&data, q).unwrap());
        }
    });
}

/// The regression pin for the old `make_backend_report` footgun: the
/// engine stamps the backend's lane width on **every** outcome in one
/// place, so a forced-scalar engine reports 1 and a forced-SIMD engine
/// reports the resolved tile width — on every plan shape and both
/// sources.
#[test]
fn reports_carry_the_forced_lane_width_on_every_path() {
    let (scalar, simd) = backends();
    let expect_scalar = scalar.simd_lane_width();
    let expect_simd = simd.simd_lane_width();
    assert_eq!(expect_scalar, 1);

    let data = Dataset::from_vec((0..5_000).collect(), 4).unwrap();
    for (backend, want) in [(scalar, expect_scalar), (simd, expect_simd)] {
        let mut engine = engine_with_backend(2, 4, ExecMode::Sequential, 0.01, backend);
        engine
            .ingest("s", MicroBatch::new((0..2_000).collect()))
            .unwrap();
        let outs = [
            engine
                .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
                .unwrap(),
            engine
                .execute(Source::Dataset(&data), QuantileQuery::Rank(100))
                .unwrap(),
            engine
                .execute(Source::Dataset(&data), QuantileQuery::Multi(vec![0.1, 0.9]))
                .unwrap(),
            engine
                .execute(Source::Stream("s"), QuantileQuery::Single(0.5))
                .unwrap(),
            engine
                .execute(Source::Stream("s"), QuantileQuery::Multi(vec![0.5, 0.99]))
                .unwrap(),
        ];
        for out in outs {
            assert_eq!(
                out.report.simd_lane_width, want as u64,
                "lane width must be stamped centrally on every exit path"
            );
        }
    }
}
