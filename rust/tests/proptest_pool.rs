//! Property tests for the thread-parallel executor pool: for any random
//! cluster geometry (including partitions ≫ executors and the 1-executor
//! degenerate case) and any dataset shape, `ExecMode::Threads` must
//! produce bit-identical `PerPartition.values`, quantile results, and
//! round / scan / byte counters to `ExecMode::Sequential` — real
//! concurrency is allowed to change wall-clock and nothing else.
//! Quantile runs go through the engine façade.

use gkselect::algorithms::oracle_quantile;
use gkselect::cluster::dataset::Dataset;
use gkselect::cluster::metrics::MetricsReport;
use gkselect::cluster::{Cluster, ClusterConfig, ExecMode};
use gkselect::engine::{AlgoChoice, EngineBuilder, QuantileQuery, QueryOutcome, Source};
use gkselect::util::propkit::{check, Gen};
use gkselect::Key;

/// Random geometry stressing the pool: mostly partitions ≫ executors,
/// sometimes square, sometimes the 1-executor degenerate case.
fn gen_geometry(g: &mut Gen) -> (usize, usize) {
    let executors = match g.usize_in(0, 3) {
        0 => 1, // degenerate: the pool is one thread
        _ => g.usize_in(1, 6),
    };
    let partitions = match g.usize_in(0, 2) {
        0 => executors,                        // one partition per executor
        _ => executors * g.usize_in(2, 10),   // partitions ≫ executors
    };
    (executors, partitions)
}

fn gen_values(g: &mut Gen) -> Vec<Key> {
    // n ≥ 1: the algorithms reject empty datasets by contract
    let n = g.usize_in(1, 3_000);
    match g.usize_in(0, 2) {
        0 => (0..n).map(|_| g.i32_in(-1_000_000_000, 999_999_999)).collect(),
        1 => (0..n).map(|_| g.i32_in(0, 6)).collect(), // duplicate-heavy
        _ => {
            let mut v: Vec<Key> = (0..n).map(|_| g.i32_in(-40_000, 40_000)).collect();
            v.sort_unstable();
            v
        }
    }
}

fn cluster(executors: usize, partitions: usize, mode: ExecMode) -> Cluster {
    Cluster::new(ClusterConfig::local(executors, partitions).with_exec_mode(mode))
}

fn gk_run(
    executors: usize,
    partitions: usize,
    mode: ExecMode,
    eps: f64,
    budget: Option<usize>,
    data: &Dataset<Key>,
    query: QuantileQuery,
) -> QueryOutcome {
    let mut b = EngineBuilder::new()
        .cluster(ClusterConfig::local(executors, partitions).with_exec_mode(mode))
        .algorithm(AlgoChoice::GkSelect)
        .epsilon(eps);
    if let Some(budget) = budget {
        b = b.candidate_budget(budget);
    }
    let mut engine = b.build().unwrap();
    engine.execute(Source::Dataset(data), query).unwrap()
}

/// The counters that must be mode-independent (wall-clock ledgers and the
/// virtual clock's seconds are real-time measurements and may differ).
fn structural(r: &MetricsReport) -> (u64, u64, u64, u64, u64, u64, u64, u64, u64) {
    (
        r.rounds,
        r.stage_boundaries,
        r.data_scans,
        r.shuffles,
        r.persists,
        r.network_volume_bytes,
        r.bytes_to_driver,
        r.messages,
        r.tree_levels,
    )
}

#[test]
fn prop_map_partitions_values_bit_identical() {
    check("pool_map_partitions_identical", 50, |g| {
        let (executors, partitions) = gen_geometry(g);
        let values = gen_values(g);
        let data = Dataset::from_vec(values, partitions).unwrap();
        let run = |mode: ExecMode| {
            let mut c = cluster(executors, partitions, mode);
            let pending = c.map_partitions(&data, |part, ctx| {
                // value depends on data, partition id, and executor id, so
                // any misrouted or reordered partition shows up
                let sum: i64 = part.iter().map(|&x| x as i64).sum();
                (ctx.partition, ctx.executor, sum, part.to_vec())
            }).unwrap();
            (pending.values, c.metrics.data_scans)
        };
        let (seq, seq_scans) = run(ExecMode::Sequential);
        let (thr, thr_scans) = run(ExecMode::Threads);
        assert_eq!(seq, thr, "PerPartition.values must be bit-identical");
        assert_eq!(seq_scans, thr_scans);
    });
}

#[test]
fn prop_gk_select_equivalent_across_modes() {
    check("pool_gk_select_equivalent", 30, |g| {
        let (executors, partitions) = gen_geometry(g);
        let values = gen_values(g);
        let data = Dataset::from_vec(values, partitions).unwrap();
        let q = g.f64_unit();
        let eps = 0.002 + g.f64_unit() * 0.2;
        // random budget sometimes forces the 3-round fallback so the
        // fallback scan is exercised under real concurrency too
        let budget = if g.bool() { None } else { Some(g.usize_in(0, 64)) };
        let truth = oracle_quantile(&data, q).unwrap();

        let seq = gk_run(
            executors,
            partitions,
            ExecMode::Sequential,
            eps,
            budget,
            &data,
            QuantileQuery::Single(q),
        );
        let thr = gk_run(
            executors,
            partitions,
            ExecMode::Threads,
            eps,
            budget,
            &data,
            QuantileQuery::Single(q),
        );
        assert_eq!(seq.value(), truth, "sequential exactness q={q} eps={eps}");
        assert_eq!(thr.value(), truth, "threads exactness q={q} eps={eps}");
        assert_eq!(
            structural(&seq.report),
            structural(&thr.report),
            "round/scan/byte counters must be mode-independent"
        );
        // the threaded run populates the real-time ledger, one slot per
        // executor, one wall entry per data scan
        assert_eq!(thr.report.executor_busy_secs.len(), executors);
        assert_eq!(thr.report.stage_walls.len() as u64, thr.report.data_scans);
    });
}

/// The acceptance shape: GK Select on `emr(30)` (30 executors, 120
/// partitions, EMR fabric model) under `Threads` must match sequential
/// answers and rounds/data_scans/bytes exactly, while reporting a real
/// per-executor busy ledger.
#[test]
fn emr30_threads_matches_sequential() {
    let values: Vec<Key> = (0..120_000)
        .map(|i| (i * 2_654_435_761_u64 as i64) as Key)
        .collect();
    let data = Dataset::from_vec(values, 120).unwrap();
    let truth = oracle_quantile(&data, 0.75).unwrap();
    let run = |mode: ExecMode| {
        let mut engine = EngineBuilder::new()
            .cluster(ClusterConfig::emr(30).with_exec_mode(mode))
            .algorithm(AlgoChoice::GkSelect)
            .build()
            .unwrap();
        engine
            .execute(Source::Dataset(&data), QuantileQuery::Single(0.75))
            .unwrap()
    };
    let seq = run(ExecMode::Sequential);
    let thr = run(ExecMode::Threads);
    assert_eq!(seq.value(), truth);
    assert_eq!(thr.value(), truth);
    assert_eq!(structural(&seq.report), structural(&thr.report));
    assert_eq!(seq.report.rounds, 2, "fused path on uniform data");
    assert_eq!(seq.report.data_scans, 2);
    assert_eq!(thr.report.executor_busy_secs.len(), 30);
    assert_eq!(thr.report.stage_walls.len(), 2);
}

#[test]
fn prop_multi_select_equivalent_across_modes() {
    check("pool_multi_select_equivalent", 20, |g| {
        let (executors, partitions) = gen_geometry(g);
        let values = gen_values(g);
        let data = Dataset::from_vec(values, partitions).unwrap();
        let m = g.usize_in(1, 4);
        let qs: Vec<f64> = (0..m).map(|_| g.f64_unit()).collect();

        let seq = gk_run(
            executors,
            partitions,
            ExecMode::Sequential,
            0.01,
            None,
            &data,
            QuantileQuery::Multi(qs.clone()),
        );
        let thr = gk_run(
            executors,
            partitions,
            ExecMode::Threads,
            0.01,
            None,
            &data,
            QuantileQuery::Multi(qs.clone()),
        );
        assert_eq!(seq.values, thr.values, "batched answers must match");
        for (&q, &v) in qs.iter().zip(seq.values.iter()) {
            assert_eq!(v, oracle_quantile(&data, q).unwrap(), "q={q}");
        }
        assert_eq!(structural(&seq.report), structural(&thr.report));
    });
}
