//! API-equivalence pins for the `QuantileEngine` redesign: every
//! `AlgoChoice` × `QuantileQuery` variant executed through the engine
//! must be **bit-identical** to the pre-redesign direct entry points
//! (the `#[deprecated]` shims kept for one release), oracle-checked,
//! across random geometries and both execution modes — including
//! `Rank(k)` ↔ `Single(q)` consistency at `k = target_rank(n, q)`.
//!
//! This file is the one place in-tree that intentionally calls the
//! deprecated surface: it IS the old-vs-new comparison.
#![allow(deprecated)]

use gkselect::algorithms::afs::{Afs, AfsParams};
use gkselect::algorithms::approx_quantile::{
    ApproxQuantile, ApproxQuantileParams, MergeStrategy, SketchVariant,
};
use gkselect::algorithms::full_sort::FullSortQuantile;
use gkselect::algorithms::gk_select::{GkSelect, GkSelectParams};
use gkselect::algorithms::histogram_select::{HistogramSelect, HistogramSelectParams};
use gkselect::algorithms::jeffers::{Jeffers, JeffersParams};
use gkselect::algorithms::multi_select::MultiSelect;
use gkselect::algorithms::oracle_quantile;
use gkselect::cluster::dataset::Dataset;
use gkselect::cluster::{Cluster, ClusterConfig, ExecMode};
use gkselect::engine::{
    rank_to_quantile, AlgoChoice, EngineBuilder, QuantileEngine, QuantileQuery, Source,
};
use gkselect::util::propkit::{check, Gen};
use gkselect::Key;

const SEED: u64 = 0xDEC0DE; // the config default the engine resolves to
const EPS: f64 = 0.02;

fn gen_dataset(g: &mut Gen) -> (usize, usize, Dataset<Key>, u64) {
    let executors = g.usize_in(1, 3);
    let partitions = g.usize_in(executors, executors * 3);
    let n = g.usize_in(1, 2_000);
    let values: Vec<Key> = match g.usize_in(0, 2) {
        0 => (0..n).map(|_| g.i32_in(-1_000_000, 1_000_000)).collect(),
        1 => (0..n).map(|_| g.i32_in(0, 6)).collect(), // duplicate-heavy
        _ => {
            let mut v: Vec<Key> = (0..n).map(|_| g.i32_in(-40_000, 40_000)).collect();
            v.sort_unstable();
            v
        }
    };
    let len = values.len() as u64;
    (
        executors,
        partitions,
        Dataset::from_vec(values, partitions).unwrap(),
        len,
    )
}

fn gen_q(g: &mut Gen) -> f64 {
    match g.usize_in(0, 9) {
        0 => 0.0,
        1 => 1.0,
        _ => g.f64_unit(),
    }
}

fn engine(executors: usize, partitions: usize, mode: ExecMode, choice: AlgoChoice) -> QuantileEngine {
    EngineBuilder::new()
        .cluster(ClusterConfig::local(executors, partitions).with_exec_mode(mode))
        .algorithm(choice)
        .epsilon(EPS)
        .seed(SEED)
        .build()
        .unwrap()
}

fn cluster(executors: usize, partitions: usize, mode: ExecMode) -> Cluster {
    Cluster::new(ClusterConfig::local(executors, partitions).with_exec_mode(mode))
}

/// The pre-redesign direct call for one quantile, constructed exactly
/// the way the engine builds its strategies (same seeds, same knobs).
fn legacy_single(
    choice: AlgoChoice,
    c: &mut Cluster,
    data: &Dataset<Key>,
    q: f64,
) -> Key {
    match choice {
        AlgoChoice::GkSelect => {
            let mut alg = GkSelect::new(GkSelectParams {
                epsilon: EPS,
                ..Default::default()
            });
            alg.quantile(c, data, q).unwrap().value
        }
        AlgoChoice::Afs => {
            let mut alg = Afs::new(AfsParams {
                seed: SEED,
                tree_depth: None,
                ..Default::default()
            });
            alg.quantile(c, data, q).unwrap().value
        }
        AlgoChoice::Jeffers => {
            let mut alg = Jeffers::new(JeffersParams {
                seed: SEED,
                ..Default::default()
            });
            alg.quantile(c, data, q).unwrap().value
        }
        AlgoChoice::FullSort => {
            let mut alg = FullSortQuantile::default();
            alg.quantile(c, data, q).unwrap().value
        }
        AlgoChoice::GkSketch => {
            let mut alg = ApproxQuantile::new(ApproxQuantileParams {
                epsilon: EPS,
                variant: SketchVariant::Spark,
                merge: MergeStrategy::Fold,
            });
            alg.quantile(c, data, q).unwrap().value
        }
        AlgoChoice::HistSelect => {
            let mut alg = HistogramSelect::new(HistogramSelectParams {
                seed: SEED,
                ..Default::default()
            });
            alg.quantile(c, data, q).unwrap().value
        }
    }
}

#[test]
fn prop_single_plans_match_legacy_calls_all_choices_both_modes() {
    check("engine_single_vs_legacy", 12, |g| {
        let (executors, partitions, data, _n) = gen_dataset(g);
        let q = gen_q(g);
        let truth = oracle_quantile(&data, q).unwrap();
        for mode in [ExecMode::Sequential, ExecMode::Threads] {
            for choice in AlgoChoice::ALL {
                let mut e = engine(executors, partitions, mode, choice);
                let new = e
                    .execute(Source::Dataset(&data), QuantileQuery::Single(q))
                    .unwrap();
                let mut c = cluster(executors, partitions, mode);
                let old = legacy_single(choice, &mut c, &data, q);
                assert_eq!(
                    new.value(),
                    old,
                    "{choice:?} {mode:?} q={q}: engine must be bit-identical to the \
                     pre-redesign entry point"
                );
                if e.exact() {
                    assert_eq!(new.value(), truth, "{choice:?} {mode:?} oracle");
                }
            }
        }
    });
}

#[test]
fn prop_rank_plans_match_single_and_legacy() {
    check("engine_rank_vs_single", 10, |g| {
        let (executors, partitions, data, n) = gen_dataset(g);
        let q = gen_q(g);
        let k = gkselect::target_rank(n, q);
        for mode in [ExecMode::Sequential, ExecMode::Threads] {
            for choice in AlgoChoice::ALL {
                let mut e = engine(executors, partitions, mode, choice);
                let by_k = e
                    .execute(Source::Dataset(&data), QuantileQuery::Rank(k))
                    .unwrap();
                // the pre-redesign way to ask for a rank: quantile at the
                // rank-derived q
                let mut c = cluster(executors, partitions, mode);
                let old = legacy_single(choice, &mut c, &data, rank_to_quantile(k, n));
                assert_eq!(by_k.value(), old, "{choice:?} {mode:?} k={k}");
                if e.exact() {
                    // Rank(k) ↔ Single(q) consistency for exact strategies
                    let by_q = e
                        .execute(Source::Dataset(&data), QuantileQuery::Single(q))
                        .unwrap();
                    assert_eq!(by_k.value(), by_q.value(), "{choice:?} {mode:?} q={q} k={k}");
                    let mut sorted = data.to_vec();
                    sorted.sort_unstable();
                    assert_eq!(by_k.value(), sorted[k as usize], "{choice:?} oracle at k={k}");
                }
            }
        }
    });
}

#[test]
fn prop_multi_plans_match_legacy_calls() {
    check("engine_multi_vs_legacy", 10, |g| {
        let (executors, partitions, data, _n) = gen_dataset(g);
        let m = g.usize_in(1, 4);
        let qs: Vec<f64> = (0..m).map(|_| gen_q(g)).collect();
        for mode in [ExecMode::Sequential, ExecMode::Threads] {
            for choice in AlgoChoice::ALL {
                let mut e = engine(executors, partitions, mode, choice);
                let new = e
                    .execute(Source::Dataset(&data), QuantileQuery::Multi(qs.clone()))
                    .unwrap();
                // pre-redesign: GK Select had the fused MultiSelect batch
                // driver; every other algorithm answered batches by
                // repeated single-quantile calls
                let old: Vec<Key> = if choice == AlgoChoice::GkSelect {
                    let mut c = cluster(executors, partitions, mode);
                    let mut alg = MultiSelect::new(GkSelectParams {
                        epsilon: EPS,
                        ..Default::default()
                    });
                    alg.quantiles(&mut c, &data, &qs).unwrap().values
                } else {
                    qs.iter()
                        .map(|&q| {
                            let mut c = cluster(executors, partitions, mode);
                            legacy_single(choice, &mut c, &data, q)
                        })
                        .collect()
                };
                assert_eq!(new.values, old, "{choice:?} {mode:?} qs={qs:?}");
            }
        }
    });
}

#[test]
fn prop_sketched_plans_match_legacy_approx_for_every_strategy() {
    check("engine_sketched_vs_legacy", 10, |g| {
        let (executors, partitions, data, _n) = gen_dataset(g);
        let q = gen_q(g);
        let eps = 0.01 + g.f64_unit() * 0.2;
        // the pre-redesign direct call: ApproxQuantile at the requested ε
        let mut c = cluster(executors, partitions, ExecMode::Sequential);
        let mut alg = ApproxQuantile::new(ApproxQuantileParams {
            epsilon: eps,
            variant: SketchVariant::Spark,
            merge: MergeStrategy::Fold,
        });
        let old = alg.quantile(&mut c, &data, q).unwrap().value;
        // every strategy serves `Sketched` identically
        for choice in AlgoChoice::ALL {
            let mut e = engine(executors, partitions, ExecMode::Sequential, choice);
            let new = e
                .execute(Source::Dataset(&data), QuantileQuery::Sketched { q, eps })
                .unwrap();
            assert_eq!(new.value(), old, "{choice:?} q={q} eps={eps}");
            assert!(!new.report.exact);
        }
    });
}

#[test]
fn stream_plans_match_legacy_stream_query() {
    use gkselect::stream::{MicroBatch, SketchStore, StreamIngestor, StreamQuery};
    for mode in [ExecMode::Sequential, ExecMode::Threads] {
        let batches: Vec<Vec<Key>> = (0..3)
            .map(|t: i32| (0..4_000).map(|i| (i * 37 + t * 1_000_003) % 90_000).collect())
            .collect();

        // new surface: one engine, ingest + execute
        let mut e = engine(2, 6, mode, AlgoChoice::GkSelect);
        for b in &batches {
            e.ingest("s", MicroBatch::new(b.clone())).unwrap();
        }

        // old surface: StreamIngestor + SketchStore + StreamQuery
        let mut c = cluster(2, 6, mode);
        let mut store = SketchStore::default();
        let ing = StreamIngestor::new(EPS).unwrap();
        for b in &batches {
            ing.ingest(&mut c, &mut store, "s", MicroBatch::new(b.clone()))
                .unwrap();
        }
        let mut legacy = StreamQuery::new(GkSelectParams {
            epsilon: EPS,
            ..Default::default()
        });

        for q in [0.0, 0.25, 0.5, 0.95, 1.0] {
            let new = e
                .execute(Source::Stream("s"), QuantileQuery::Single(q))
                .unwrap();
            let old = legacy.quantile(&mut c, &store, "s", q).unwrap();
            assert_eq!(new.value(), old.value, "{mode:?} q={q}");
            assert_eq!(new.report.rounds, old.report.rounds, "{mode:?} q={q}");
            assert_eq!(new.report.data_scans, old.report.data_scans, "{mode:?} q={q}");
        }
        let qs = vec![0.5, 0.9, 0.99];
        let new = e
            .execute(Source::Stream("s"), QuantileQuery::Multi(qs.clone()))
            .unwrap();
        let old = legacy.quantiles(&mut c, &store, "s", &qs).unwrap();
        assert_eq!(new.values, old.values, "{mode:?} multi");
    }
}
