//! Integration pins for the streaming quantile service — the PR's
//! acceptance contract:
//!
//! * a `StreamQuery` after ≥ 2 micro-batches returns the bit-identical
//!   exact quantile as batch `GkSelect` over the concatenated data,
//! * while recording **rounds = 1 / data_scans = 1** for the query
//!   itself (the sketch work was amortized into ingest),
//! * in both execution modes,
//! * with the store footprint bounded by compaction and hostile
//!   (non-stationary) streams staying exact.

use gkselect::algorithms::gk_select::{GkSelect, GkSelectParams};
use gkselect::algorithms::oracle_quantile;
use gkselect::algorithms::QuantileAlgorithm;
use gkselect::cluster::dataset::Dataset;
use gkselect::cluster::{Cluster, ClusterConfig, ExecMode};
use gkselect::harness::StreamWorkload;
use gkselect::stream::{CompactionPolicy, MicroBatch, SketchStore, StreamIngestor, StreamQuery};
use gkselect::Key;

fn batch(seed: u64, tick: u64, len: usize, workload: StreamWorkload) -> Vec<Key> {
    workload.batch(seed, tick, len)
}

/// The headline acceptance criterion, pinned per execution mode.
fn acceptance_for_mode(mode: ExecMode) {
    let executors = 2;
    let partitions = 8;
    let mut cluster =
        Cluster::new(ClusterConfig::local(executors, partitions).with_exec_mode(mode));
    let mut store = SketchStore::default();
    let ing = StreamIngestor::new(0.01).unwrap();

    let mut concat: Vec<Key> = Vec::new();
    for tick in 0..4u64 {
        let values = batch(7, tick, 20_000, StreamWorkload::Uniform);
        concat.extend_from_slice(&values);
        let out = ing
            .ingest(&mut cluster, &mut store, "s", MicroBatch::new(values))
            .unwrap();
        // ingest itself is one round over the new records only
        assert_eq!(out.report.rounds, 1, "{mode:?} tick {tick}");
        assert_eq!(out.report.data_scans, 1, "{mode:?} tick {tick}");
    }

    let data = Dataset::from_vec(concat, partitions).unwrap();
    let mut engine = StreamQuery::new(GkSelectParams::default());
    for q in [0.25, 0.5, 0.75, 0.99] {
        let out = engine.quantile(&mut cluster, &store, "s", q).unwrap();

        let mut batch_cluster =
            Cluster::new(ClusterConfig::local(executors, partitions).with_exec_mode(mode));
        let mut alg = GkSelect::new(GkSelectParams::default());
        let batch_out = alg.quantile(&mut batch_cluster, &data, q).unwrap();

        assert_eq!(
            out.value, batch_out.value,
            "{mode:?} q={q}: stream must be bit-identical to batch"
        );
        assert_eq!(out.value, oracle_quantile(&data, q).unwrap(), "{mode:?} q={q}");
        // the query pays only the fused band-extract scan
        assert_eq!(out.report.rounds, 1, "{mode:?} q={q}");
        assert_eq!(out.report.data_scans, 1, "{mode:?} q={q}");
        assert_eq!(out.report.shuffles, 0);
        assert_eq!(out.report.persists, 0);
        assert!(out.report.exact);
        // the batch path pays the sketch scan every time
        assert_eq!(batch_out.report.rounds, 2, "{mode:?} q={q}");
        assert_eq!(batch_out.report.data_scans, 2, "{mode:?} q={q}");
    }
}

#[test]
fn stream_query_one_round_one_scan_sequential() {
    acceptance_for_mode(ExecMode::Sequential);
}

#[test]
fn stream_query_one_round_one_scan_threads() {
    acceptance_for_mode(ExecMode::Threads);
}

#[test]
fn multi_quantile_query_shares_the_scan() {
    let mut cluster = Cluster::new(ClusterConfig::local(2, 8));
    let mut store = SketchStore::default();
    let ing = StreamIngestor::new(0.01).unwrap();
    let mut concat: Vec<Key> = Vec::new();
    for tick in 0..3u64 {
        let values = batch(11, tick, 15_000, StreamWorkload::Zipf);
        concat.extend_from_slice(&values);
        ing.ingest(&mut cluster, &mut store, "s", MicroBatch::new(values))
            .unwrap();
    }
    let data = Dataset::from_vec(concat, 8).unwrap();
    let mut engine = StreamQuery::new(GkSelectParams::default());
    let qs = [0.5, 0.95, 0.99];
    let out = engine.quantiles(&mut cluster, &store, "s", &qs).unwrap();
    assert_eq!(out.report.rounds, 1);
    assert_eq!(out.report.data_scans, 1);
    for (&q, &v) in qs.iter().zip(out.values.iter()) {
        assert_eq!(v, oracle_quantile(&data, q).unwrap(), "q={q}");
    }
}

#[test]
fn store_footprint_stays_bounded_across_many_batches() {
    let mut cluster = Cluster::new(ClusterConfig::local(2, 4));
    let mut store = SketchStore::new(CompactionPolicy {
        compact_threshold: 4,
        max_live_epochs: 2,
    })
    .unwrap();
    let ing = StreamIngestor::new(0.02).unwrap();
    let mut peak_partials = 0usize;
    for tick in 0..32u64 {
        ing.ingest(
            &mut cluster,
            &mut store,
            "s",
            MicroBatch::new(batch(3, tick, 2_000, StreamWorkload::Uniform)),
        )
        .unwrap();
        peak_partials = peak_partials.max(store.stream("s").unwrap().sketch_partials());
    }
    let state = store.stream("s").unwrap();
    assert_eq!(state.total_count(), 64_000, "compaction never drops data");
    // live partials bounded by the policy (threshold+1 epochs × P at the
    // seal that triggers compaction), independent of the 32 batches
    assert!(peak_partials <= 5 * 4, "peak partials {peak_partials}");
    assert!(state.live_epochs() <= 4);
    assert!(state.compactions >= 1);

    // queries stay exact across all those compactions
    let data = state.live_dataset().unwrap();
    let mut engine = StreamQuery::new(GkSelectParams {
        epsilon: 0.02,
        ..Default::default()
    });
    for q in [0.1, 0.5, 0.9] {
        let out = engine.quantile(&mut cluster, &store, "s", q).unwrap();
        assert_eq!(out.value, oracle_quantile(&data, q).unwrap(), "q={q}");
    }
}

#[test]
fn hostile_nonstationary_stream_stays_exact() {
    // every batch shifts the global quantiles into a fresh band — cached
    // sketches always mispredict; exactness must come from measured
    // counts (fast path or one fallback scan, never a wrong answer)
    let mut cluster = Cluster::new(ClusterConfig::local(2, 4));
    let mut store = SketchStore::default();
    let ing = StreamIngestor::new(0.01).unwrap();
    let mut engine = StreamQuery::new(GkSelectParams::default());
    for tick in 0..6u64 {
        ing.ingest(
            &mut cluster,
            &mut store,
            "s",
            MicroBatch::new(batch(5, tick, 8_000, StreamWorkload::Hostile)),
        )
        .unwrap();
        let data = store.stream("s").unwrap().live_dataset().unwrap();
        for q in [0.01, 0.5, 0.99] {
            let out = engine.quantile(&mut cluster, &store, "s", q).unwrap();
            assert_eq!(out.value, oracle_quantile(&data, q).unwrap(), "tick {tick} q={q}");
            assert!(out.report.rounds <= 2, "tick {tick} q={q}");
            assert!(out.report.data_scans <= 2);
        }
    }
}

#[test]
fn drained_and_empty_streams_are_recoverable_errors() {
    let mut cluster = Cluster::new(ClusterConfig::local(1, 2));
    let mut store = SketchStore::default();
    let ing = StreamIngestor::new(0.01).unwrap();
    // empty batch: Err, no panic, store untouched
    assert!(ing
        .ingest(&mut cluster, &mut store, "s", MicroBatch::default())
        .is_err());
    assert!(store.stream("s").is_none());
    // querying a stream that never ingested: Err, no panic
    let mut engine = StreamQuery::new(GkSelectParams::default());
    assert!(engine.quantile(&mut cluster, &store, "s", 0.5).is_err());
    // after a real ingest everything works again on the same handles
    ing.ingest(&mut cluster, &mut store, "s", MicroBatch::new(vec![3, 1, 2]))
        .unwrap();
    let out = engine.quantile(&mut cluster, &store, "s", 0.5).unwrap();
    assert_eq!(out.value, 2);
}
