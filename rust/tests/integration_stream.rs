//! Integration pins for the streaming quantile service — the
//! acceptance contract:
//!
//! * a streamed engine query after ≥ 2 micro-batches returns the
//!   bit-identical exact quantile as batch GK Select over the
//!   concatenated data,
//! * while recording **rounds = 1 / data_scans = 1** for the query
//!   itself (the sketch work was amortized into ingest),
//! * in both execution modes,
//! * with the store footprint bounded by compaction and hostile
//!   (non-stationary) streams staying exact —
//!
//! batch and stream both served by `QuantileEngine::execute`, the one
//! call site the redesign promises.

use gkselect::cluster::dataset::Dataset;
use gkselect::cluster::{ClusterConfig, ExecMode};
use gkselect::harness::StreamWorkload;
use gkselect::prelude::*;
use gkselect::Key;

fn batch(seed: u64, tick: u64, len: usize, workload: StreamWorkload) -> Vec<Key> {
    workload.batch(seed, tick, len)
}

fn engine_with(
    executors: usize,
    partitions: usize,
    mode: ExecMode,
    policy: Option<CompactionPolicy>,
) -> QuantileEngine {
    let mut b = EngineBuilder::new()
        .cluster(ClusterConfig::local(executors, partitions).with_exec_mode(mode))
        .algorithm(AlgoChoice::GkSelect);
    if let Some(p) = policy {
        b = b.compaction(p);
    }
    b.build().unwrap()
}

/// The headline acceptance criterion, pinned per execution mode.
fn acceptance_for_mode(mode: ExecMode) {
    let executors = 2;
    let partitions = 8;
    let mut engine = engine_with(executors, partitions, mode, None);

    let mut concat: Vec<Key> = Vec::new();
    for tick in 0..4u64 {
        let values = batch(7, tick, 20_000, StreamWorkload::Uniform);
        concat.extend_from_slice(&values);
        let out = engine.ingest("s", MicroBatch::new(values)).unwrap();
        // ingest itself is one round over the new records only
        assert_eq!(out.report.rounds, 1, "{mode:?} tick {tick}");
        assert_eq!(out.report.data_scans, 1, "{mode:?} tick {tick}");
    }

    let data = Dataset::from_vec(concat, partitions).unwrap();
    for q in [0.25, 0.5, 0.75, 0.99] {
        let out = engine
            .execute(Source::Stream("s"), QuantileQuery::Single(q))
            .unwrap();

        let mut batch_engine = engine_with(executors, partitions, mode, None);
        let batch_out = batch_engine
            .execute(Source::Dataset(&data), QuantileQuery::Single(q))
            .unwrap();

        assert_eq!(
            out.value(),
            batch_out.value(),
            "{mode:?} q={q}: stream must be bit-identical to batch"
        );
        assert_eq!(out.value(), oracle_quantile(&data, q).unwrap(), "{mode:?} q={q}");
        // the query pays only the fused band-extract scan
        assert_eq!(out.report.rounds, 1, "{mode:?} q={q}");
        assert_eq!(out.report.data_scans, 1, "{mode:?} q={q}");
        assert_eq!(out.report.shuffles, 0);
        assert_eq!(out.report.persists, 0);
        assert!(out.report.exact);
        // the batch path pays the sketch scan every time
        assert_eq!(batch_out.report.rounds, 2, "{mode:?} q={q}");
        assert_eq!(batch_out.report.data_scans, 2, "{mode:?} q={q}");
    }
}

#[test]
fn stream_query_one_round_one_scan_sequential() {
    acceptance_for_mode(ExecMode::Sequential);
}

#[test]
fn stream_query_one_round_one_scan_threads() {
    acceptance_for_mode(ExecMode::Threads);
}

#[test]
fn multi_quantile_query_shares_the_scan() {
    let mut engine = engine_with(2, 8, ExecMode::Sequential, None);
    let mut concat: Vec<Key> = Vec::new();
    for tick in 0..3u64 {
        let values = batch(11, tick, 15_000, StreamWorkload::Zipf);
        concat.extend_from_slice(&values);
        engine.ingest("s", MicroBatch::new(values)).unwrap();
    }
    let data = Dataset::from_vec(concat, 8).unwrap();
    let qs = vec![0.5, 0.95, 0.99];
    let out = engine
        .execute(Source::Stream("s"), QuantileQuery::Multi(qs.clone()))
        .unwrap();
    assert_eq!(out.report.rounds, 1);
    assert_eq!(out.report.data_scans, 1);
    for (&q, &v) in qs.iter().zip(out.values.iter()) {
        assert_eq!(v, oracle_quantile(&data, q).unwrap(), "q={q}");
    }
}

#[test]
fn store_footprint_stays_bounded_across_many_batches() {
    let mut engine = EngineBuilder::new()
        .cluster(ClusterConfig::local(2, 4))
        .epsilon(0.02)
        .compaction(CompactionPolicy {
            compact_threshold: 4,
            max_live_epochs: 2,
        })
        .build()
        .unwrap();
    let mut peak_partials = 0usize;
    for tick in 0..32u64 {
        engine
            .ingest(
                "s",
                MicroBatch::new(batch(3, tick, 2_000, StreamWorkload::Uniform)),
            )
            .unwrap();
        peak_partials = peak_partials.max(engine.store().stream("s").unwrap().sketch_partials());
    }
    let state = engine.store().stream("s").unwrap();
    assert_eq!(state.total_count(), 64_000, "compaction never drops data");
    // live partials bounded by the policy (threshold+1 epochs × P at the
    // seal that triggers compaction), independent of the 32 batches
    assert!(peak_partials <= 5 * 4, "peak partials {peak_partials}");
    assert!(state.live_epochs() <= 4);
    assert!(state.compactions >= 1);

    // queries stay exact across all those compactions
    let data = state.live_dataset().unwrap();
    for q in [0.1, 0.5, 0.9] {
        let out = engine
            .execute(Source::Stream("s"), QuantileQuery::Single(q))
            .unwrap();
        assert_eq!(out.value(), oracle_quantile(&data, q).unwrap(), "q={q}");
    }
}

#[test]
fn hostile_nonstationary_stream_stays_exact() {
    // every batch shifts the global quantiles into a fresh band — cached
    // sketches always mispredict; exactness must come from measured
    // counts (fast path or one fallback scan, never a wrong answer)
    let mut engine = engine_with(2, 4, ExecMode::Sequential, None);
    for tick in 0..6u64 {
        engine
            .ingest(
                "s",
                MicroBatch::new(batch(5, tick, 8_000, StreamWorkload::Hostile)),
            )
            .unwrap();
        let data = engine.store().stream("s").unwrap().live_dataset().unwrap();
        for q in [0.01, 0.5, 0.99] {
            let out = engine
                .execute(Source::Stream("s"), QuantileQuery::Single(q))
                .unwrap();
            assert_eq!(out.value(), oracle_quantile(&data, q).unwrap(), "tick {tick} q={q}");
            assert!(out.report.rounds <= 2, "tick {tick} q={q}");
            assert!(out.report.data_scans <= 2);
        }
    }
}

#[test]
fn drained_and_empty_streams_are_recoverable_errors() {
    let mut engine = engine_with(1, 2, ExecMode::Sequential, None);
    // empty batch: Err, no panic, store untouched
    assert!(engine.ingest("s", MicroBatch::default()).is_err());
    assert!(engine.store().stream("s").is_none());
    // querying a stream that never ingested: a typed, recoverable error
    assert_eq!(
        engine
            .execute(Source::Stream("s"), QuantileQuery::Single(0.5))
            .unwrap_err(),
        EngineError::UnknownStream("s".into())
    );
    // after a real ingest everything works again on the same handle
    engine.ingest("s", MicroBatch::new(vec![3, 1, 2])).unwrap();
    let out = engine
        .execute(Source::Stream("s"), QuantileQuery::Single(0.5))
        .unwrap();
    assert_eq!(out.value(), 2);
}
