//! Sharded stream directory: stream id → [`StreamEntry`].
//!
//! A [`ShardMap`] spreads streams over a fixed set of shards so
//! concurrent lookups of *different* streams rarely contend on one
//! mutex, and each shard's lock is held only long enough to clone an
//! `Arc<StreamEntry>` out of (or insert one into) its map — never
//! across an ingest or a query.
//!
//! The entry itself carries the stream's two synchronization points:
//!
//! * the **writer token** — a mutex around the stream's private
//!   [`Cluster`] + single-stream [`SketchStore`]; holding it is what
//!   "being the stream's one writer" means, and writers of different
//!   streams never share it, so ingest pipelines run in parallel
//!   across streams;
//! * the **published snapshot pointer** — the epoch-list swap. Readers
//!   lock it only to clone the current `Arc<StreamSnapshot>`; writers
//!   lock it only to store the next one. Neither ever blocks on the
//!   other's actual work, which is how queries stay un-blocked by
//!   concurrent seals and compactions.
//!
//! # Poisoning and the recovery contract
//!
//! Every lock here is acquired through [`relock`], which recovers the
//! inner value from a poisoned mutex instead of propagating the
//! poison. That is sound because each critical section leaves
//! consistent state on **every** exit path, including unwinds:
//!
//! * A writer that panics mid-ingest drops its token with the store in
//!   one of two consistent states: nothing sealed (the batch simply
//!   never happened), or sealed-but-unpublished (seal is the store's
//!   atomic commit point; the publish swap only exposes it). In the
//!   second state the batch is durable in the writer's store and the
//!   **next successful ingest publishes it** along with its own epoch —
//!   readers never observe a half-sealed epoch either way.
//! * The published pointer is only ever replaced by a single store of
//!   an already-constructed `Arc`, so a panic can only happen before or
//!   after the swap, never inside a half-written snapshot.
//! * Shard maps only insert fully-built entries under their lock.
//!
//! So a panicking writer task cannot strand a stream: the entry stays
//! usable, later writers recover the token via [`relock`], and the
//! published snapshot is always one the writer fully built. This
//! contract is pinned by the poisoning tests below and by the
//! failpoint-injection tests in `tests/concurrency_explorer.rs`, which
//! panic a writer inside the real ingest path at the publish point and
//! then prove the stream still ingests, queries, and accounts exactly.
//!
//! Sync points here are instrumented for the deterministic
//! interleaving explorer ([`crate::testing`]): `lock_writer` is the one
//! lock held across yield points, so under an explorer schedule it
//! acquires via a `try_lock` loop that yields contention to the
//! scheduler instead of blocking the OS thread.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard, TryLockError};

use crate::cluster::{Cluster, ClusterConfig};
use crate::stream::store::{SketchStore, StreamSnapshot};
use crate::stream::CompactionPolicy;
use crate::testing::{self, SyncPoint};

/// Recover the inner value even if a panicking holder poisoned the
/// lock: every critical section here leaves consistent state on every
/// exit path (ingest is atomic-under-failure, publishes are single
/// stores), so poisoning carries no information we need to honor. See
/// the module doc's recovery contract.
fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Everything the stream's single writer owns: its private execution
/// substrate and its single-stream store. Lives behind
/// [`StreamEntry::writer`].
pub(crate) struct StreamWriter {
    pub cluster: Cluster,
    pub store: SketchStore,
}

/// One stream's slot in the directory.
pub(crate) struct StreamEntry {
    /// The single-writer token (see module doc).
    pub writer: Mutex<StreamWriter>,
    /// The currently published snapshot; swapped whole by writers.
    published: Mutex<Arc<StreamSnapshot>>,
}

impl StreamEntry {
    fn new(cfg: &ClusterConfig, policy: CompactionPolicy) -> Self {
        Self {
            writer: Mutex::new(StreamWriter {
                cluster: Cluster::new(cfg.clone()),
                store: SketchStore::new(policy).expect("policy validated at service build"),
            }),
            published: Mutex::new(Arc::new(StreamSnapshot::empty(cfg.partitions))),
        }
    }

    /// Lock the writer token (blocking until the previous writer of
    /// this stream finishes). Under an explorer schedule the blocking
    /// wait becomes a schedulable `try_lock` loop — the writer token is
    /// held across later yield points, so parking the OS thread here
    /// would deadlock the cooperative scheduler.
    pub fn lock_writer(&self) -> MutexGuard<'_, StreamWriter> {
        testing::yield_point(SyncPoint::LockWriter);
        if testing::scheduled() {
            loop {
                match self.writer.try_lock() {
                    Ok(guard) => return guard,
                    Err(TryLockError::Poisoned(e)) => return e.into_inner(),
                    Err(TryLockError::WouldBlock) => {
                        testing::yield_contended(SyncPoint::LockWriter)
                    }
                }
            }
        }
        relock(&self.writer)
    }

    /// Swap in the next snapshot. Pins already handed out keep their
    /// old `Arc`.
    pub fn publish(&self, snap: Arc<StreamSnapshot>) {
        testing::yield_point(SyncPoint::Publish);
        *relock(&self.published) = snap;
    }

    /// Clone the current snapshot out — the whole read-side critical
    /// section.
    pub fn pin(&self) -> Arc<StreamSnapshot> {
        testing::yield_point(SyncPoint::Pin);
        relock(&self.published).clone()
    }
}

struct Shard {
    streams: Mutex<BTreeMap<String, Arc<StreamEntry>>>,
}

/// The service's stream directory (see module doc).
pub(crate) struct ShardMap {
    shards: Vec<Shard>,
}

impl ShardMap {
    pub fn new(shards: usize) -> Self {
        let shards = shards.max(1);
        Self {
            shards: (0..shards)
                .map(|_| Shard {
                    streams: Mutex::new(BTreeMap::new()),
                })
                .collect(),
        }
    }

    fn shard(&self, stream: &str) -> &Shard {
        &self.shards[(fnv1a(stream) % self.shards.len() as u64) as usize]
    }

    /// Look up a stream's entry, if any ingest ever created it.
    pub fn get(&self, stream: &str) -> Option<Arc<StreamEntry>> {
        relock(&self.shard(stream).streams).get(stream).cloned()
    }

    /// Look up or create a stream's entry (first ingest creates).
    pub fn get_or_create(&self, stream: &str, cfg: &ClusterConfig, policy: CompactionPolicy) -> Arc<StreamEntry> {
        let mut map = relock(&self.shard(stream).streams);
        map.entry(stream.to_string())
            .or_insert_with(|| Arc::new(StreamEntry::new(cfg, policy)))
            .clone()
    }

    /// Every known stream id, sorted (stable across shard layouts).
    pub fn stream_ids(&self) -> Vec<String> {
        let mut ids: Vec<String> = self
            .shards
            .iter()
            .flat_map(|s| relock(&s.streams).keys().cloned().collect::<Vec<_>>())
            .collect();
        ids.sort_unstable();
        ids
    }
}

/// FNV-1a over the stream id — cheap, deterministic, dependency-free;
/// only shard balance rides on it, never correctness.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_or_create_is_idempotent_and_get_sees_it() {
        let map = ShardMap::new(4);
        let cfg = ClusterConfig::local(1, 2);
        assert!(map.get("s").is_none());
        let a = map.get_or_create("s", &cfg, CompactionPolicy::default());
        let b = map.get_or_create("s", &cfg, CompactionPolicy::default());
        assert!(Arc::ptr_eq(&a, &b));
        assert!(Arc::ptr_eq(&a, &map.get("s").unwrap()));
        assert_eq!(map.stream_ids(), vec!["s".to_string()]);
    }

    #[test]
    fn stream_ids_sorted_across_shards() {
        let map = ShardMap::new(3);
        let cfg = ClusterConfig::local(1, 2);
        for id in ["zeta", "alpha", "mid"] {
            map.get_or_create(id, &cfg, CompactionPolicy::default());
        }
        assert_eq!(map.stream_ids(), vec!["alpha", "mid", "zeta"]);
    }

    /// The recovery contract, writer side: a holder that panics
    /// mid-critical-section poisons the token, and the next
    /// `lock_writer` recovers it with the entry fully usable.
    #[test]
    fn poisoned_writer_token_recovers_and_entry_stays_usable() {
        let map = ShardMap::new(2);
        let cfg = ClusterConfig::local(1, 2);
        let e = map.get_or_create("s", &cfg, CompactionPolicy::default());

        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {})); // silence the expected unwind
        let died = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = e.lock_writer();
            panic!("writer dies holding the token");
        }));
        std::panic::set_hook(hook);
        assert!(died.is_err());
        assert!(e.writer.is_poisoned(), "the unwind must actually poison");

        // relock recovers the token; writer state is intact.
        let w = e.lock_writer();
        assert_eq!(w.store.stream_ids().count(), 0);
        drop(w);
        // The read/publish side never saw any of it.
        e.publish(Arc::new(StreamSnapshot::empty(4)));
        assert_eq!(e.pin().partitions(), 4);
    }

    /// The recovery contract, publish side: even a poisoned published
    /// pointer (holder panicked while cloning) still pins the snapshot
    /// the last writer fully built — the swap is a single store of a
    /// complete `Arc`, so poison carries no torn state.
    #[test]
    fn poisoned_published_pointer_still_pins_the_last_full_snapshot() {
        let map = ShardMap::new(2);
        let cfg = ClusterConfig::local(1, 2);
        let e = map.get_or_create("s", &cfg, CompactionPolicy::default());
        e.publish(Arc::new(StreamSnapshot::empty(8)));

        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {}));
        let died = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = relock(&e.published);
            panic!("reader dies holding the published lock");
        }));
        std::panic::set_hook(hook);
        assert!(died.is_err());
        assert!(e.published.is_poisoned());

        assert_eq!(e.pin().partitions(), 8, "pin recovers the full snapshot");
        e.publish(Arc::new(StreamSnapshot::empty(2)));
        assert_eq!(e.pin().partitions(), 2, "publish keeps working after poison");
    }

    #[test]
    fn publish_and_pin_swap_snapshots() {
        let map = ShardMap::new(2);
        let cfg = ClusterConfig::local(1, 2);
        let e = map.get_or_create("s", &cfg, CompactionPolicy::default());
        let empty = e.pin();
        assert_eq!(empty.total_count(), 0);
        e.publish(Arc::new(StreamSnapshot::empty(8)));
        let next = e.pin();
        assert!(!Arc::ptr_eq(&empty, &next));
        assert_eq!(next.partitions(), 8);
        // the old pin is untouched
        assert_eq!(empty.partitions(), 2);
    }
}
