//! Sharded stream directory: stream id → [`StreamEntry`].
//!
//! A [`ShardMap`] spreads streams over a fixed set of shards so
//! concurrent lookups of *different* streams rarely contend on one
//! mutex, and each shard's lock is held only long enough to clone an
//! `Arc<StreamEntry>` out of (or insert one into) its map — never
//! across an ingest or a query.
//!
//! The entry itself carries the stream's two synchronization points:
//!
//! * the **writer token** — a mutex around the stream's private
//!   [`Cluster`] + single-stream [`SketchStore`]; holding it is what
//!   "being the stream's one writer" means, and writers of different
//!   streams never share it, so ingest pipelines run in parallel
//!   across streams;
//! * the **published snapshot pointer** — the epoch-list swap. Readers
//!   lock it only to clone the current `Arc<StreamSnapshot>`; writers
//!   lock it only to store the next one. Neither ever blocks on the
//!   other's actual work, which is how queries stay un-blocked by
//!   concurrent seals and compactions.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard};

use crate::cluster::{Cluster, ClusterConfig};
use crate::stream::store::{SketchStore, StreamSnapshot};
use crate::stream::CompactionPolicy;

/// Recover the inner value even if a panicking holder poisoned the
/// lock: every critical section here leaves consistent state on every
/// exit path (ingest is atomic-under-failure, publishes are single
/// stores), so poisoning carries no information we need to honor.
fn relock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Everything the stream's single writer owns: its private execution
/// substrate and its single-stream store. Lives behind
/// [`StreamEntry::writer`].
pub(crate) struct StreamWriter {
    pub cluster: Cluster,
    pub store: SketchStore,
}

/// One stream's slot in the directory.
pub(crate) struct StreamEntry {
    /// The single-writer token (see module doc).
    pub writer: Mutex<StreamWriter>,
    /// The currently published snapshot; swapped whole by writers.
    published: Mutex<Arc<StreamSnapshot>>,
}

impl StreamEntry {
    fn new(cfg: &ClusterConfig, policy: CompactionPolicy) -> Self {
        Self {
            writer: Mutex::new(StreamWriter {
                cluster: Cluster::new(cfg.clone()),
                store: SketchStore::new(policy).expect("policy validated at service build"),
            }),
            published: Mutex::new(Arc::new(StreamSnapshot::empty(cfg.partitions))),
        }
    }

    /// Lock the writer token (blocking until the previous writer of
    /// this stream finishes).
    pub fn lock_writer(&self) -> MutexGuard<'_, StreamWriter> {
        relock(&self.writer)
    }

    /// Swap in the next snapshot. Pins already handed out keep their
    /// old `Arc`.
    pub fn publish(&self, snap: Arc<StreamSnapshot>) {
        *relock(&self.published) = snap;
    }

    /// Clone the current snapshot out — the whole read-side critical
    /// section.
    pub fn pin(&self) -> Arc<StreamSnapshot> {
        relock(&self.published).clone()
    }
}

struct Shard {
    streams: Mutex<BTreeMap<String, Arc<StreamEntry>>>,
}

/// The service's stream directory (see module doc).
pub(crate) struct ShardMap {
    shards: Vec<Shard>,
}

impl ShardMap {
    pub fn new(shards: usize) -> Self {
        let shards = shards.max(1);
        Self {
            shards: (0..shards)
                .map(|_| Shard {
                    streams: Mutex::new(BTreeMap::new()),
                })
                .collect(),
        }
    }

    fn shard(&self, stream: &str) -> &Shard {
        &self.shards[(fnv1a(stream) % self.shards.len() as u64) as usize]
    }

    /// Look up a stream's entry, if any ingest ever created it.
    pub fn get(&self, stream: &str) -> Option<Arc<StreamEntry>> {
        relock(&self.shard(stream).streams).get(stream).cloned()
    }

    /// Look up or create a stream's entry (first ingest creates).
    pub fn get_or_create(&self, stream: &str, cfg: &ClusterConfig, policy: CompactionPolicy) -> Arc<StreamEntry> {
        let mut map = relock(&self.shard(stream).streams);
        map.entry(stream.to_string())
            .or_insert_with(|| Arc::new(StreamEntry::new(cfg, policy)))
            .clone()
    }

    /// Every known stream id, sorted (stable across shard layouts).
    pub fn stream_ids(&self) -> Vec<String> {
        let mut ids: Vec<String> = self
            .shards
            .iter()
            .flat_map(|s| relock(&s.streams).keys().cloned().collect::<Vec<_>>())
            .collect();
        ids.sort_unstable();
        ids
    }
}

/// FNV-1a over the stream id — cheap, deterministic, dependency-free;
/// only shard balance rides on it, never correctness.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_or_create_is_idempotent_and_get_sees_it() {
        let map = ShardMap::new(4);
        let cfg = ClusterConfig::local(1, 2);
        assert!(map.get("s").is_none());
        let a = map.get_or_create("s", &cfg, CompactionPolicy::default());
        let b = map.get_or_create("s", &cfg, CompactionPolicy::default());
        assert!(Arc::ptr_eq(&a, &b));
        assert!(Arc::ptr_eq(&a, &map.get("s").unwrap()));
        assert_eq!(map.stream_ids(), vec!["s".to_string()]);
    }

    #[test]
    fn stream_ids_sorted_across_shards() {
        let map = ShardMap::new(3);
        let cfg = ClusterConfig::local(1, 2);
        for id in ["zeta", "alpha", "mid"] {
            map.get_or_create(id, &cfg, CompactionPolicy::default());
        }
        assert_eq!(map.stream_ids(), vec!["alpha", "mid", "zeta"]);
    }

    #[test]
    fn publish_and_pin_swap_snapshots() {
        let map = ShardMap::new(2);
        let cfg = ClusterConfig::local(1, 2);
        let e = map.get_or_create("s", &cfg, CompactionPolicy::default());
        let empty = e.pin();
        assert_eq!(empty.total_count(), 0);
        e.publish(Arc::new(StreamSnapshot::empty(8)));
        let next = e.pin();
        assert!(!Arc::ptr_eq(&empty, &next));
        assert_eq!(next.partitions(), 8);
        // the old pin is untouched
        assert_eq!(empty.partitions(), 2);
    }
}
