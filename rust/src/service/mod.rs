//! Concurrent multi-tenant serving layer: [`QuantileService`].
//!
//! [`crate::engine::QuantileEngine`] is one tenant deep and `&mut` at
//! the call site — ingest and queries serialize, one client at a time.
//! This module layers the same exact protocol into a shape that serves
//! many clients and many streams at once, without giving up a single
//! bit of the answers:
//!
//! ```text
//!                 ┌──────────── QuantileService (&self everywhere) ───────────┐
//!                 │  ShardMap: stream id ──hash──► shard ──► StreamEntry      │
//!   ingest ──────►│  StreamEntry ┬ writer token (Mutex<Cluster + store>)      │
//!   (per stream,  │              │   seal epoch → compact → publish ─┐        │
//!    serialized)  │              └ published: Mutex<Arc<Snapshot>> ◄─┘        │
//!   query ───────►│  pin = Arc-clone of published  (readers never wait        │
//!   (any thread)  │  on a writer's work — only on the pointer swap)           │
//!                 └───────────────────────────────────────────────────────────┘
//! ```
//!
//! * **Snapshot isolation** — a query pins the
//!   [`StreamSnapshot`](crate::stream::StreamSnapshot) published at
//!   submit time and computes entirely against it: the `Arc`-shared
//!   epoch list, its zero-copy `Dataset::concat` union, and the
//!   merged-sketch memo that lives *on the snapshot*. Concurrent seals
//!   and compactions publish new snapshots; they never mutate a pinned
//!   one.
//! * **Single-writer / many-reader per stream** — the writer token
//!   serializes ingest within a stream; different streams' writers run
//!   in parallel. Readers take no `RwLock`: the read path is one mutex
//!   acquisition to clone the published `Arc`, then lock-free.
//! * **Exactness** — every answer is bit-identical to a serialized
//!   [`QuantileEngine`](crate::engine::QuantileEngine) fed exactly the
//!   pinned epochs, because both paths execute the same crate-internal
//!   snapshot plan (`tests/proptest_service.rs` races writers against
//!   readers to pin this).
//!
//! What is linearizable and what is not: **seals are** — once `ingest`
//! returns, every subsequently submitted query (any thread) observes
//! the new epoch, because the snapshot is published before `ingest`
//! returns and pinning synchronizes on the same mutex. **Cross-stream
//! order is not** — queries of different streams pin independently, and
//! a query holding an old pin may answer after a newer seal lands;
//! that staleness is bounded by "the world as of submit time", which is
//! exactly the isolation contract.
//!
//! # Example
//!
//! ```
//! use gkselect::prelude::*;
//!
//! let svc = QuantileService::builder()
//!     .cluster(ClusterConfig::local(2, 4))
//!     .build()
//!     .unwrap();
//!
//! // ingest seals epochs; queries answer exactly, from a pinned snapshot
//! svc.ingest("events", MicroBatch::new((0..1_000).collect())).unwrap();
//! let out = svc.query("events", &QuantileQuery::Single(0.5)).unwrap();
//! assert_eq!(out.value(), 500);
//!
//! // a pin taken now is immune to later ingests…
//! let pin = svc.pin("events").unwrap();
//! svc.ingest("events", MicroBatch::new((1_000..2_000).collect())).unwrap();
//! let old = svc.query_pinned(&pin, &QuantileQuery::Single(1.0)).unwrap();
//! assert_eq!(old.value(), 999); // max of the pinned 1 000 records
//!
//! // …while a fresh query observes the seal (seals are linearizable)
//! let new = svc.query("events", &QuantileQuery::Single(1.0)).unwrap();
//! assert_eq!(new.value(), 1_999);
//! ```

mod shard;

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::algorithms::gk_select::GkSelectParams;
use crate::cluster::{Cluster, ClusterConfig, ExecMode};
use crate::engine::{
    snapshot_plan, EngineBuilder, EngineError, QuantileEngine, QuantileQuery, QueryOutcome,
};
use crate::obs::registry::{OpContext, StreamResidency};
use crate::obs::{MetricsMode, MetricsRegistry, MetricsSnapshot, OpKind};
use crate::runtime::{KernelBackend, NativeBackend};
use crate::stream::store::StreamSnapshot;
use crate::stream::{CompactionPolicy, IngestOutcome, MicroBatch, StreamIngestor};

use shard::ShardMap;

/// A pinned read view: one stream's [`StreamSnapshot`] captured at
/// submit time. Hold it as long as you like — concurrent seals and
/// compactions cannot change what it answers.
#[derive(Clone)]
pub struct Pinned {
    stream: String,
    snapshot: Arc<StreamSnapshot>,
}

impl Pinned {
    /// The stream this pin reads.
    pub fn stream(&self) -> &str {
        &self.stream
    }

    /// The immutable epoch view the pin holds.
    pub fn snapshot(&self) -> &StreamSnapshot {
        &self.snapshot
    }
}

/// Builder for [`QuantileService`] — the concurrent sibling of
/// [`EngineBuilder`], deliberately smaller: the service always runs the
/// GK fused stream protocol (the store is GK-shaped), so there is no
/// algorithm choice, and tracing stays per-engine.
pub struct ServiceBuilder {
    cluster: ClusterConfig,
    params: GkSelectParams,
    epsilon: Option<f64>,
    compaction: CompactionPolicy,
    shards: usize,
    metrics: MetricsMode,
    backend: Option<Arc<dyn KernelBackend>>,
}

impl Default for ServiceBuilder {
    fn default() -> Self {
        Self {
            cluster: ClusterConfig::local(2, 4),
            params: GkSelectParams::default(),
            epsilon: None,
            compaction: CompactionPolicy::default(),
            shards: 8,
            metrics: MetricsMode::Off,
            backend: None,
        }
    }
}

impl ServiceBuilder {
    /// Fresh builder: local 2×4 cluster, default GK parameters, default
    /// compaction, 8 shards, metrics off.
    pub fn new() -> Self {
        Self::default()
    }

    /// Cluster shape every per-stream writer and per-query scratch
    /// cluster is built from (executors, partitions, exec mode, fault
    /// plan, cost model).
    pub fn cluster(mut self, cc: ClusterConfig) -> Self {
        self.cluster = cc;
        self
    }

    /// GK parameters of the query protocol (ε, variant, merge, budget).
    pub fn params(mut self, params: GkSelectParams) -> Self {
        self.params = params;
        self
    }

    /// Ingest-time sketch precision (defaults to the query ε).
    pub fn ingest_epsilon(mut self, eps: f64) -> Self {
        self.epsilon = Some(eps);
        self
    }

    /// Per-stream epoch compaction policy.
    pub fn compaction(mut self, policy: CompactionPolicy) -> Self {
        self.compaction = policy;
        self
    }

    /// Shard count of the stream directory (contention knob only;
    /// clamped to ≥ 1).
    pub fn shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    /// Arm the service-lifetime metrics registry.
    pub fn metrics(mut self, mode: MetricsMode) -> Self {
        self.metrics = mode;
        self
    }

    /// Inject a kernel backend shared by every reader and writer
    /// (defaults to [`NativeBackend`] with auto SIMD dispatch).
    pub fn kernel_backend(mut self, backend: Arc<dyn KernelBackend>) -> Self {
        self.backend = Some(backend);
        self
    }

    pub fn build(self) -> Result<QuantileService, EngineError> {
        self.compaction
            .validate()
            .map_err(|e| EngineError::InvalidConfig(format!("{e:#}")))?;
        let eps = self.epsilon.unwrap_or(self.params.epsilon);
        let ingestor =
            StreamIngestor::new(eps).map_err(|e| EngineError::InvalidConfig(format!("{e:#}")))?;
        let ingestor = ingestor.with_variant(self.params.variant);
        let backend: Arc<dyn KernelBackend> =
            self.backend.unwrap_or_else(|| Arc::new(NativeBackend::new()));
        let registry = MetricsRegistry::new(
            self.metrics,
            self.cluster.exec_mode.label(),
            backend.simd_lane_width() as u64,
        );
        Ok(QuantileService {
            cfg: self.cluster,
            params: self.params,
            ingestor,
            policy: self.compaction,
            backend,
            shards: ShardMap::new(self.shards),
            registry: Mutex::new(registry),
            in_flight: AtomicU64::new(0),
            ingest_queue: AtomicU64::new(0),
        })
    }
}

/// The concurrent multi-tenant serving layer — see the module doc for
/// the concurrency model. Every method takes `&self`; share it across
/// client threads with an `Arc` (or `std::thread::scope` borrows).
pub struct QuantileService {
    cfg: ClusterConfig,
    params: GkSelectParams,
    ingestor: StreamIngestor,
    policy: CompactionPolicy,
    backend: Arc<dyn KernelBackend>,
    shards: ShardMap,
    registry: Mutex<MetricsRegistry>,
    /// Queries currently executing (the in-flight gauge).
    in_flight: AtomicU64,
    /// Ingests queued on a writer token or executing (the queue-depth
    /// gauge).
    ingest_queue: AtomicU64,
}

impl QuantileService {
    pub fn builder() -> ServiceBuilder {
        ServiceBuilder::default()
    }

    /// Seal one micro-batch into `stream`. Serialized per stream by the
    /// writer token, parallel across streams; the new snapshot is
    /// published before this returns, so every query submitted
    /// afterwards observes the batch. A failed ingest (typed error)
    /// publishes nothing and leaves the stream byte-identical.
    pub fn ingest(&self, stream: &str, batch: MicroBatch) -> Result<IngestOutcome, EngineError> {
        self.ingest_queue.fetch_add(1, Ordering::SeqCst);
        let result = self.ingest_locked(stream, batch);
        self.ingest_queue.fetch_sub(1, Ordering::SeqCst);
        result
    }

    fn ingest_locked(&self, stream: &str, batch: MicroBatch) -> Result<IngestOutcome, EngineError> {
        let entry = self.shards.get_or_create(stream, &self.cfg, self.policy);
        let mut w = entry.lock_writer();
        let out = self
            .ingestor
            .ingest(&mut w.cluster, &mut w.store, stream, batch)
            .map_err(EngineError::from)?;
        let snap = w
            .store
            .stream(stream)
            .expect("epoch just sealed")
            .snapshot();
        entry.publish(snap.clone());
        let residency = StreamResidency {
            live_epochs: snap.live_epochs() as u64,
            sealed_epochs: snap.sealed_epochs(),
            sketch_partials: snap.sketch_partials() as u64,
            sketch_bytes: snap.sketch_bytes(),
            data_bytes: snap.data_bytes(),
            records: snap.total_count(),
            compactions: snap.compactions(),
        };
        let ctx = OpContext {
            kind: OpKind::Ingest,
            stream: Some(stream),
            plan: "ingest",
            trace: None,
        };
        // absorb while still holding the writer token so this stream's
        // residency gauges are written in seal order — two ingests that
        // absorbed after unlocking could land inverted and leave a stale
        // (smaller) gauge as the final value. Lock order is writer →
        // registry; queries absorb without any writer, so no cycle.
        self.absorb(&ctx, &out.report, Some((stream.to_string(), residency)))?;
        drop(w);
        Ok(out)
    }

    /// Pin the snapshot currently published for `stream` — the view a
    /// query submitted *now* would answer over. Errors with
    /// [`EngineError::UnknownStream`] until a first ingest seals.
    pub fn pin(&self, stream: &str) -> Result<Pinned, EngineError> {
        let entry = self
            .shards
            .get(stream)
            .ok_or_else(|| EngineError::UnknownStream(stream.to_string()))?;
        let snapshot = entry.pin();
        if snapshot.sealed_epochs() == 0 {
            // entry exists but nothing ever sealed (first ingest failed):
            // same contract as the engine — the stream was never ingested
            return Err(EngineError::UnknownStream(stream.to_string()));
        }
        Ok(Pinned {
            stream: stream.to_string(),
            snapshot,
        })
    }

    /// Pin-and-answer: the common client call. Equivalent to
    /// [`Self::pin`] + [`Self::query_pinned`].
    pub fn query(
        &self,
        stream: &str,
        query: &QuantileQuery,
    ) -> Result<QueryOutcome, EngineError> {
        let pin = self.pin(stream)?;
        self.query_pinned(&pin, query)
    }

    /// Answer `query` over an explicit pin. Runs on a fresh scratch
    /// cluster (the service's cluster shape), shares the service's one
    /// kernel backend, and never touches any writer state — many of
    /// these run in parallel with each other and with ingest. The
    /// answer is bit-identical to a serialized engine over the same
    /// pinned epochs.
    pub fn query_pinned(
        &self,
        pin: &Pinned,
        query: &QuantileQuery,
    ) -> Result<QueryOutcome, EngineError> {
        self.in_flight.fetch_add(1, Ordering::SeqCst);
        let result = (|| {
            let mut cluster = Cluster::new(self.cfg.clone());
            let mut out = snapshot_plan(
                &mut cluster,
                self.backend.as_ref(),
                &self.params,
                &pin.snapshot,
                &pin.stream,
                query,
            )?;
            out.report.simd_lane_width = self.backend.simd_lane_width() as u64;
            Ok(out)
        })();
        self.in_flight.fetch_sub(1, Ordering::SeqCst);
        let out: QueryOutcome = result?;
        let ctx = OpContext {
            kind: out.op_kind(),
            stream: Some(&pin.stream),
            plan: query.label(),
            trace: None,
        };
        // no residency here: a pinned (possibly stale) snapshot must
        // never roll the monotone residency gauges backwards
        self.absorb(&ctx, &out.report, None)?;
        Ok(out)
    }

    /// Build the serialized oracle for a pin: a fresh sequential
    /// [`QuantileEngine`] whose store holds exactly the pinned epochs
    /// (`Arc`-cheap data clones). `oracle.execute(Source::Stream(..))`
    /// must answer bit-identically to [`Self::query_pinned`] on the
    /// same pin — `repro serve --verify` and the concurrency test suite
    /// cross-check every Nth response through this.
    pub fn oracle(&self, pin: &Pinned) -> Result<QuantileEngine, EngineError> {
        let mut builder = EngineBuilder::new()
            .cluster(self.cfg.clone())
            .exec_mode(ExecMode::Sequential)
            .epsilon(self.params.epsilon)
            .sketch_variant(self.params.variant)
            .sketch_merge(self.params.merge);
        if let Some(depth) = self.params.tree_depth {
            builder = builder.tree_depth(depth);
        }
        if let Some(budget) = self.params.candidate_budget {
            builder = builder.candidate_budget(budget);
        }
        let mut oracle = builder.build()?;
        for epoch in pin.snapshot.epochs() {
            oracle
                .store_mut()
                .seal_epoch(&pin.stream, epoch.data.clone(), epoch.sketches.clone())
                .map_err(EngineError::from)?;
        }
        Ok(oracle)
    }

    /// Every stream any ingest ever created, sorted.
    pub fn streams(&self) -> Vec<String> {
        self.shards.stream_ids()
    }

    /// Queries currently executing.
    pub fn in_flight_queries(&self) -> u64 {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Ingests queued on a writer token or executing.
    pub fn ingest_queue_depth(&self) -> u64 {
        self.ingest_queue.load(Ordering::SeqCst)
    }

    /// The shared backend's active SIMD lane width.
    pub fn simd_lane_width(&self) -> usize {
        self.backend.simd_lane_width()
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The cluster shape queries and writers run on.
    pub fn cluster_config(&self) -> &ClusterConfig {
        &self.cfg
    }

    /// A point-in-time copy of the service-lifetime registry (per-kind
    /// × per-stream totals, latency folds, residency, load gauges).
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        let mut reg = self.registry.lock().unwrap_or_else(|e| e.into_inner());
        // gauges are sampled at snapshot time too, so a scrape between
        // operations still sees live load
        let (inf, queue) = (
            self.in_flight.load(Ordering::SeqCst),
            self.ingest_queue.load(Ordering::SeqCst),
        );
        reg.set_service_gauges(inf, queue);
        reg.snapshot()
    }

    /// Render the registry in Prometheus text exposition format.
    pub fn render_prometheus(&self) -> String {
        let reg = self.registry.lock().unwrap_or_else(|e| e.into_inner());
        reg.render_prometheus()
    }

    /// The buffered qlog lines, in absorb order.
    pub fn qlog_lines(&self) -> Vec<String> {
        let reg = self.registry.lock().unwrap_or_else(|e| e.into_inner());
        reg.qlog_lines().to_vec()
    }

    fn absorb(
        &self,
        ctx: &OpContext<'_>,
        report: &crate::cluster::metrics::MetricsReport,
        residency: Option<(String, StreamResidency)>,
    ) -> Result<(), EngineError> {
        // Explorer sync point *before* the lock: the registry mutex is
        // never held across a yield, so contention on it needs no
        // schedulable acquisition path (unlike the writer token).
        crate::testing::yield_point(crate::testing::SyncPoint::RegistryAbsorb);
        let mut reg = self.registry.lock().unwrap_or_else(|e| e.into_inner());
        if !reg.is_enabled() {
            return Ok(());
        }
        reg.set_service_gauges(
            self.in_flight.load(Ordering::SeqCst),
            self.ingest_queue.load(Ordering::SeqCst),
        );
        reg.absorb_with(ctx, report, residency)
            .map_err(EngineError::from)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::Source;

    fn service() -> QuantileService {
        QuantileService::builder()
            .cluster(ClusterConfig::local(2, 4))
            .metrics(MetricsMode::Memory)
            .build()
            .unwrap()
    }

    #[test]
    fn service_and_pins_cross_threads() {
        // compile-time: the whole point of the service is &self from many
        // threads, and pins must travel to whichever thread answers
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<QuantileService>();
        assert_send_sync::<Pinned>();
    }

    #[test]
    fn ingest_then_query_is_exact() {
        let svc = service();
        svc.ingest("s", MicroBatch::new((0..1_000).collect())).unwrap();
        let out = svc.query("s", &QuantileQuery::Single(0.5)).unwrap();
        assert_eq!(out.value(), 500);
        assert_eq!((out.report.rounds, out.report.data_scans), (1, 1));
        assert!(out.report.exact);
    }

    #[test]
    fn unknown_stream_is_typed() {
        let svc = service();
        assert_eq!(
            svc.query("nope", &QuantileQuery::Single(0.5)).unwrap_err(),
            EngineError::UnknownStream("nope".into())
        );
        assert!(svc.pin("nope").is_err());
    }

    #[test]
    fn pinned_snapshot_ignores_later_ingests() {
        let svc = service();
        svc.ingest("s", MicroBatch::new((0..100).collect())).unwrap();
        let pin = svc.pin("s").unwrap();
        svc.ingest("s", MicroBatch::new((100..200).collect())).unwrap();
        let old = svc.query_pinned(&pin, &QuantileQuery::Single(1.0)).unwrap();
        assert_eq!(old.value(), 99);
        let new = svc.query("s", &QuantileQuery::Single(1.0)).unwrap();
        assert_eq!(new.value(), 199);
    }

    #[test]
    fn oracle_answers_match_the_service() {
        let svc = service();
        for b in 0..3i32 {
            let vals: Vec<i32> = (0..400).map(|i| (i * 37 + b * 101) % 5_000).collect();
            svc.ingest("s", MicroBatch::new(vals)).unwrap();
        }
        let pin = svc.pin("s").unwrap();
        let mut oracle = svc.oracle(&pin).unwrap();
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 1.0] {
            let got = svc.query_pinned(&pin, &QuantileQuery::Single(q)).unwrap();
            let want = oracle
                .execute(Source::Stream("s"), QuantileQuery::Single(q))
                .unwrap();
            assert_eq!(got.value(), want.value(), "q={q}");
        }
    }

    #[test]
    fn per_stream_totals_and_residency_are_isolated() {
        let svc = service();
        svc.ingest("a", MicroBatch::new((0..300).collect())).unwrap();
        svc.ingest("b", MicroBatch::new((0..700).collect())).unwrap();
        svc.query("a", &QuantileQuery::Single(0.5)).unwrap();
        let snap = svc.metrics_snapshot();
        let ra = &snap
            .residency
            .iter()
            .find(|(s, _)| s == "a")
            .expect("stream a sampled")
            .1;
        let rb = &snap
            .residency
            .iter()
            .find(|(s, _)| s == "b")
            .expect("stream b sampled")
            .1;
        assert_eq!(ra.records, 300);
        assert_eq!(rb.records, 700);
        assert_eq!(
            snap.totals_for(OpKind::Ingest, "a").unwrap().records,
            300
        );
        assert_eq!(
            snap.totals_for(OpKind::Ingest, "b").unwrap().records,
            700
        );
        assert!(snap.totals_for(OpKind::Stream, "b").is_none());
    }

    #[test]
    fn gauges_are_zero_at_rest_and_exported() {
        let svc = service();
        svc.ingest("s", MicroBatch::new((0..100).collect())).unwrap();
        assert_eq!(svc.in_flight_queries(), 0);
        assert_eq!(svc.ingest_queue_depth(), 0);
        let snap = svc.metrics_snapshot();
        assert_eq!(snap.in_flight_queries, 0);
        assert_eq!(snap.ingest_queue_depth, 0);
        assert!(svc
            .render_prometheus()
            .contains("gkselect_service_in_flight_queries"));
    }

    #[test]
    fn failed_ingest_publishes_nothing() {
        let svc = service();
        assert!(svc.ingest("s", MicroBatch::default()).is_err());
        assert_eq!(
            svc.pin("s").unwrap_err(),
            EngineError::UnknownStream("s".into())
        );
        // and a later good ingest brings the stream up normally
        svc.ingest("s", MicroBatch::new((0..10).collect())).unwrap();
        assert_eq!(svc.pin("s").unwrap().snapshot().total_count(), 10);
    }
}
