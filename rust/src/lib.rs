//! # gkselect — quick and exact distributed quantile computation
//!
//! Reproduction of *"A Quick and Exact Method for Distributed Quantile
//! Computation"* (Cao, Saloni, Harrison; IEEE BigData 2025): **GK Select**,
//! an exact distributed k-th order-statistic algorithm that uses a
//! Greenwald–Khanna sketch to pick a near-target pivot and finishes in a
//! constant number of rounds, plus every baseline the paper evaluates
//! (Spark-style full sort / PSRS, Al-Furaih Select, Jeffers Select, and
//! the Spark `approxQuantile` GK sketch).
//!
//! The crate is the L3 coordinator of a three-layer stack:
//!
//! * **L3 (this crate)** — a Spark-like execution substrate
//!   ([`cluster`]) with explicit rounds, stage boundaries, `treeReduce`,
//!   `TorrentBroadcast`, range-partition shuffle, and a calibrated
//!   network/compute cost model; the distributed quantile
//!   [`algorithms`]; the [`stream`] serving layer (micro-batch
//!   ingestion, cached sketch store, one-scan exact queries); and all
//!   the substrates they need ([`sketch`], [`select`], [`sort`],
//!   [`data`]).
//! * **L2/L1 (python, build-time only)** — a JAX pivot-pass pipeline
//!   whose hot loops are Pallas kernels, AOT-lowered to HLO text by
//!   `make artifacts` and executed from the L3 hot path through
//!   [`runtime`] (PJRT CPU client via the `xla` crate). Python never runs
//!   at request time.
//!
//! ## Quickstart
//!
//! ```no_run
//! use gkselect::prelude::*;
//!
//! let cfg = ClusterConfig::local(4, 16); // 4 executors, 16 partitions
//! let mut cluster = Cluster::new(cfg);
//! let data = UniformGen::new(42).generate(&mut cluster, 1_000_000);
//! let mut gk = GkSelect::new(GkSelectParams::default());
//! let outcome = gk.quantile(&mut cluster, &data, 0.5).unwrap();
//! println!("median = {} in {} rounds", outcome.value, outcome.report.rounds);
//! ```

pub mod algorithms;
pub mod cluster;
pub mod config;
pub mod data;
pub mod harness;
pub mod runtime;
pub mod select;
pub mod sketch;
pub mod sort;
pub mod stream;
pub mod util;

/// Convenience re-exports covering the public API surface used by the
/// examples and benches.
pub mod prelude {
    pub use crate::algorithms::{
        afs::{Afs, AfsParams},
        approx_quantile::{ApproxQuantile, ApproxQuantileParams},
        full_sort::FullSortQuantile,
        gk_select::{GkSelect, GkSelectParams},
        histogram_select::{HistogramSelect, HistogramSelectParams},
        jeffers::{Jeffers, JeffersParams},
        Outcome, QuantileAlgorithm,
    };
    pub use crate::cluster::{
        dataset::Dataset,
        metrics::{MetricsReport, RunMetrics},
        netmodel::NetworkModel,
        pool::{ExecMode, ExecutorPool},
        Cluster, ClusterConfig,
    };
    pub use crate::config::ReproConfig;
    pub use crate::data::{
        BimodalGen, DataGenerator, Distribution, SortedBandsGen, UniformGen, ZipfGen,
    };
    pub use crate::runtime::{KernelBackend, NativeBackend, SimdPolicy};
    pub use crate::sketch::{
        classical::ClassicalGk, modified::ModifiedGk, spark::SparkGk, QuantileSketch,
    };
    pub use crate::stream::{
        CompactionPolicy, MicroBatch, SketchStore, StreamIngestor, StreamQuery,
    };
}

/// Key type used throughout: the paper benchmarks 32-bit integers drawn
/// from `[-1e9, 1e9)`.
pub type Key = i32;

/// The inclusive value domain used by the paper's generators.
pub const KEY_LO: i64 = -1_000_000_000;
/// Exclusive upper bound of the paper's value domain.
pub const KEY_HI: i64 = 1_000_000_000;

/// Zero-based target rank for quantile `q` over `n` elements — the paper's
/// `trueRank` (`k = nq`, clamped to the last index).
pub fn target_rank(n: u64, q: f64) -> u64 {
    assert!((0.0..=1.0).contains(&q), "quantile out of range: {q}");
    if n == 0 {
        return 0;
    }
    let k = (q * n as f64).floor() as u64;
    k.min(n - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn target_rank_median_of_odd() {
        assert_eq!(target_rank(101, 0.5), 50);
    }

    #[test]
    fn target_rank_endpoints() {
        assert_eq!(target_rank(10, 0.0), 0);
        assert_eq!(target_rank(10, 1.0), 9);
        assert_eq!(target_rank(0, 0.5), 0);
    }

    #[test]
    fn target_rank_p99() {
        assert_eq!(target_rank(1000, 0.99), 990);
    }

    #[test]
    #[should_panic]
    fn target_rank_rejects_bad_q() {
        target_rank(10, 1.5);
    }
}
