//! # gkselect — quick and exact distributed quantile computation
//!
//! Reproduction of *"A Quick and Exact Method for Distributed Quantile
//! Computation"* (Cao, Saloni, Harrison; IEEE BigData 2025): **GK Select**,
//! an exact distributed k-th order-statistic algorithm that uses a
//! Greenwald–Khanna sketch to pick a near-target pivot and finishes in a
//! constant number of rounds, plus every baseline the paper evaluates
//! (Spark-style full sort / PSRS, Al-Furaih Select, Jeffers Select, and
//! the Spark `approxQuantile` GK sketch).
//!
//! The crate is the L3 coordinator of a three-layer stack:
//!
//! * **L3 (this crate)** — the [`engine`] serving façade
//!   ([`engine::QuantileEngine`]: one builder, typed query plans, one
//!   outcome across batch and stream) in front of a Spark-like
//!   execution substrate ([`cluster`]) with explicit rounds, stage
//!   boundaries, `treeReduce`, `TorrentBroadcast`, range-partition
//!   shuffle, and a calibrated network/compute cost model; the
//!   distributed quantile [`algorithms`] (stateless strategies behind
//!   the engine); the [`stream`] serving layer (micro-batch ingestion,
//!   cached sketch store, one-scan exact queries); the [`service`]
//!   concurrent multi-tenant layer (snapshot-isolated epochs,
//!   single-writer/many-reader streams); and all the
//!   substrates they need ([`sketch`], [`select`], [`sort`], [`data`]).
//! * **L2/L1 (python, build-time only)** — a JAX pivot-pass pipeline
//!   whose hot loops are Pallas kernels, AOT-lowered to HLO text by
//!   `make artifacts` and executed from the L3 hot path through
//!   [`runtime`] (PJRT CPU client via the `xla` crate). Python never runs
//!   at request time.
//!
//! ## Quickstart
//!
//! One engine answers every query shape over both batch datasets and
//! live streams:
//!
//! ```
//! use gkselect::prelude::*;
//!
//! let mut engine = EngineBuilder::new()
//!     .cluster(ClusterConfig::local(2, 8)) // 2 executors, 8 partitions
//!     .algorithm(AlgoChoice::GkSelect)
//!     .build()
//!     .unwrap();
//!
//! // batch: exact median in 2 fused rounds
//! let data = UniformGen::new(42).generate(engine.cluster_mut(), 100_000);
//! let out = engine.execute(Source::Dataset(&data), QuantileQuery::Single(0.5)).unwrap();
//! println!("median = {} in {} rounds", out.value(), out.report.rounds);
//!
//! // stream: ingest micro-batches, then serve exactly from cached sketches
//! engine.ingest("events", MicroBatch::new((0..1_000).collect())).unwrap();
//! let p99 = engine.execute(Source::Stream("events"), QuantileQuery::Single(0.99)).unwrap();
//! assert_eq!((p99.report.rounds, p99.report.data_scans), (1, 1));
//! ```

pub mod algorithms;
pub mod cluster;
pub mod config;
pub mod data;
pub mod engine;
pub mod harness;
pub mod obs;
pub mod runtime;
pub mod select;
pub mod service;
pub mod sketch;
pub mod sort;
pub mod stream;
pub mod testing;
pub mod util;

/// Convenience re-exports covering the public API surface used by the
/// examples and benches — the [`engine`] façade plus the substrate types
/// it is configured with. The pre-redesign per-algorithm drivers
/// (`GkSelect`, `MultiSelect`, `StreamQuery`, …) are deliberately *not*
/// re-exported here any more: they survive as `#[deprecated]` shims in
/// their modules for one release.
pub mod prelude {
    pub use crate::algorithms::{oracle_quantile, Outcome, QuantileAlgorithm};
    pub use crate::cluster::{
        dataset::Dataset,
        metrics::{MetricsReport, RunMetrics},
        netmodel::NetworkModel,
        pool::{ExecMode, ExecutorPool},
        Cluster, ClusterConfig, FaultPlan, RetryPolicy, StageError,
    };
    pub use crate::config::ReproConfig;
    pub use crate::data::{
        BimodalGen, DataGenerator, Distribution, SortedBandsGen, UniformGen, ZipfGen,
    };
    pub use crate::engine::{
        AlgoChoice, DegradePolicy, EngineBuilder, EngineCtx, EngineError, QuantileEngine,
        QuantileQuery, QueryOutcome, Source,
    };
    pub use crate::obs::{
        AttemptOutcome, MetricsMode, MetricsRegistry, MetricsSnapshot, OpKind, Span, SpanKind,
        StageStats, Trace, TraceMode, TraceSink,
    };
    pub use crate::runtime::{KernelBackend, NativeBackend, SimdPolicy};
    pub use crate::service::{Pinned, QuantileService, ServiceBuilder};
    pub use crate::sketch::{
        classical::ClassicalGk, modified::ModifiedGk, spark::SparkGk, QuantileSketch,
    };
    pub use crate::stream::{
        CompactionPolicy, IngestOutcome, MicroBatch, SketchStore, StreamIngestor,
    };
}

/// Key type used throughout: the paper benchmarks 32-bit integers drawn
/// from `[-1e9, 1e9)`.
pub type Key = i32;

/// The inclusive value domain used by the paper's generators.
pub const KEY_LO: i64 = -1_000_000_000;
/// Exclusive upper bound of the paper's value domain.
pub const KEY_HI: i64 = 1_000_000_000;

/// Zero-based target rank for quantile `q` over `n` elements — the paper's
/// `trueRank` (`k = nq`, clamped to the last index).
pub fn target_rank(n: u64, q: f64) -> u64 {
    assert!((0.0..=1.0).contains(&q), "quantile out of range: {q}");
    if n == 0 {
        return 0;
    }
    let k = (q * n as f64).floor() as u64;
    k.min(n - 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn target_rank_median_of_odd() {
        assert_eq!(target_rank(101, 0.5), 50);
    }

    #[test]
    fn target_rank_endpoints() {
        assert_eq!(target_rank(10, 0.0), 0);
        assert_eq!(target_rank(10, 1.0), 9);
        assert_eq!(target_rank(0, 0.5), 0);
    }

    #[test]
    fn target_rank_p99() {
        assert_eq!(target_rank(1000, 0.99), 990);
    }

    #[test]
    #[should_panic]
    fn target_rank_rejects_bad_q() {
        target_rank(10, 1.5);
    }
}
