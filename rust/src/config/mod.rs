//! Config system: TOML-subset file + programmatic defaults, overridable
//! from the CLI. One `ReproConfig` fully describes a run (cluster shape,
//! fabric, algorithm knobs, backend, artifact location) so every
//! experiment in EXPERIMENTS.md is reproducible from its config + seed.

use crate::cluster::netmodel::NetworkModel;
use crate::cluster::{ClusterConfig, ExecMode, FaultPlan, RetryPolicy};
use crate::engine::DegradePolicy;
use crate::obs::{MetricsMode, TraceMode};
use crate::runtime::{KernelBackend, SimdPolicy};
use crate::util::minitoml::{self, Document, Section, Value};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Cluster shape section.
#[derive(Debug, Clone)]
pub struct ClusterSection {
    /// Core nodes (the paper's unit of scaling).
    pub nodes: usize,
    /// Partitions per node (paper: 4 = vCPUs of m5.xlarge).
    pub partitions_per_node: usize,
    /// Measured-time → reference-core multiplier (from `repro calibrate`).
    pub compute_scale: f64,
    /// Driver slowdown factor (driver nodes are often smaller).
    pub driver_scale: f64,
    /// Execution mode for `map_partitions` stages: "sequential" |
    /// "threads". Empty = defer to the `GKSELECT_EXEC_MODE` env var
    /// (unset → sequential).
    pub exec_mode: String,
}

impl Default for ClusterSection {
    fn default() -> Self {
        Self {
            nodes: 10,
            partitions_per_node: 4,
            compute_scale: 1.0,
            driver_scale: 1.0,
            exec_mode: String::new(),
        }
    }
}

/// Algorithm knobs.
#[derive(Debug, Clone)]
pub struct AlgorithmSection {
    /// GK sketch relative error (the ablation sweeps this).
    pub epsilon: f64,
    /// treeReduce depth override (None → ⌈log₂P⌉).
    pub tree_depth: Option<usize>,
    /// Master seed for generators and pivot RNG.
    pub seed: u64,
    /// Sketch variant for GK paths: "classical" | "spark" | "modified".
    pub sketch: String,
    /// Driver-side sketch merge: "fold" (Spark's foldLeft) | "tree".
    pub sketch_merge: String,
}

impl Default for AlgorithmSection {
    fn default() -> Self {
        Self {
            epsilon: 0.01,
            tree_depth: None,
            seed: 0xDEC0DE,
            sketch: "bulk".into(),
            sketch_merge: "fold".into(),
        }
    }
}

/// Streaming-service section (converted into
/// [`crate::stream::CompactionPolicy`]).
#[derive(Debug, Clone)]
pub struct StreamSection {
    /// Live-epoch count that triggers store compaction at the next seal.
    pub compact_threshold: usize,
    /// Epochs retained after a compaction.
    pub max_live_epochs: usize,
}

impl Default for StreamSection {
    fn default() -> Self {
        let p = crate::stream::CompactionPolicy::default();
        Self {
            compact_threshold: p.compact_threshold,
            max_live_epochs: p.max_live_epochs,
        }
    }
}

impl StreamSection {
    pub fn to_policy(&self) -> Result<crate::stream::CompactionPolicy> {
        let policy = crate::stream::CompactionPolicy {
            compact_threshold: self.compact_threshold,
            max_live_epochs: self.max_live_epochs,
        };
        policy.validate()?;
        Ok(policy)
    }
}

/// Kernel-runtime section.
#[derive(Debug, Clone, Default)]
pub struct RuntimeSection {
    /// SIMD dispatch policy for the native backend's fused band scan:
    /// "auto" | "scalar" | "force". Empty = defer to the `GKSELECT_SIMD`
    /// env var (unset → auto). See [`crate::runtime::simd`] for the
    /// dispatch rules.
    pub simd: String,
}

/// Observability section (converted into a
/// [`crate::obs::TraceMode`] / [`crate::obs::MetricsMode`] pair on the
/// engine builder).
#[derive(Debug, Clone, Default)]
pub struct ObsSection {
    /// Trace sink in the [`crate::obs::TraceMode`] grammar:
    /// "off" | "memory" | "chrome:<path>" | a bare `*.json` path.
    /// Empty = defer to the `GKSELECT_TRACE` env var (unset → off).
    pub trace: String,
    /// Engine-lifetime metrics mode in the
    /// [`crate::obs::MetricsMode`] grammar:
    /// "off" | "memory" | "prom:<path>" | "qlog:<path>".
    /// Empty = defer to the `GKSELECT_METRICS` env var (unset → off).
    pub metrics: String,
}

/// Fault-injection and recovery section (converted into a
/// [`FaultPlan`] + [`RetryPolicy`] pair on the cluster config).
#[derive(Debug, Clone)]
pub struct FaultsSection {
    /// Seeded fault plan in the [`FaultPlan`] grammar
    /// (`"seed=N,panic=R,..."`). Empty = defer to the `GKSELECT_FAULTS`
    /// env var (unset → no injection).
    pub plan: String,
    /// Task attempts after the first before a stage fails (Spark:
    /// `spark.task.maxFailures - 1`).
    pub max_task_retries: u32,
    /// Modelled scheduler delay charged per retry, milliseconds.
    pub backoff_ms: f64,
    /// Re-launch straggler tasks speculatively (Spark:
    /// `spark.speculation`).
    pub speculation: bool,
    /// What a query does when a stage exhausts its retries: "fail"
    /// (typed error) | "sketch" (degrade to an ε-approximate answer).
    /// Empty = "fail".
    pub degrade: String,
}

impl Default for FaultsSection {
    fn default() -> Self {
        let r = RetryPolicy::default();
        Self {
            plan: String::new(),
            max_task_retries: r.max_task_retries,
            backoff_ms: r.backoff_secs * 1e3,
            speculation: r.speculation,
            degrade: String::new(),
        }
    }
}

impl FaultsSection {
    /// Materialize the recovery knobs (the plan itself is resolved
    /// separately so builder/env overrides can layer on top).
    pub fn to_retry_policy(&self) -> RetryPolicy {
        RetryPolicy {
            max_task_retries: self.max_task_retries,
            backoff_secs: self.backoff_ms * 1e-3,
            speculation: self.speculation,
        }
    }
}

/// Fabric section (converted into [`NetworkModel`]).
#[derive(Debug, Clone)]
pub struct NetworkSection {
    pub enabled: bool,
    pub latency_us: f64,
    pub bandwidth_gbps: f64,
    pub driver_bandwidth_gbps: f64,
    /// Shuffle-spill disk throughput (EMR gp2 EBS ≈ 250 MB/s).
    pub shuffle_disk_mbps: f64,
    /// Per-record shuffle serialization cost, nanoseconds per side.
    pub ser_ns_per_record: f64,
}

impl Default for NetworkSection {
    fn default() -> Self {
        Self {
            enabled: true,
            latency_us: 200.0,
            bandwidth_gbps: 10.0,
            driver_bandwidth_gbps: 10.0,
            shuffle_disk_mbps: 250.0,
            ser_ns_per_record: 100.0,
        }
    }
}

impl NetworkSection {
    pub fn to_model(&self) -> NetworkModel {
        if !self.enabled {
            return NetworkModel::zero();
        }
        NetworkModel {
            latency_s: self.latency_us * 1e-6,
            bandwidth_bps: self.bandwidth_gbps * 1e9 / 8.0,
            driver_bandwidth_bps: self.driver_bandwidth_gbps * 1e9 / 8.0,
            shuffle_disk_bps: self.shuffle_disk_mbps * 1e6,
            ser_s_per_record: self.ser_ns_per_record * 1e-9,
        }
    }
}

/// Top-level config.
#[derive(Debug, Clone)]
pub struct ReproConfig {
    pub cluster: ClusterSection,
    pub network: NetworkSection,
    pub algorithm: AlgorithmSection,
    pub stream: StreamSection,
    pub runtime: RuntimeSection,
    pub faults: FaultsSection,
    pub obs: ObsSection,
    /// Kernel backend: "native" | "pjrt".
    pub backend: String,
    /// Where `make artifacts` put the HLO text.
    pub artifacts_dir: PathBuf,
}

impl Default for ReproConfig {
    fn default() -> Self {
        Self {
            cluster: ClusterSection::default(),
            network: NetworkSection::default(),
            algorithm: AlgorithmSection::default(),
            stream: StreamSection::default(),
            runtime: RuntimeSection::default(),
            faults: FaultsSection::default(),
            obs: ObsSection::default(),
            backend: "native".into(),
            artifacts_dir: PathBuf::from("artifacts"),
        }
    }
}

impl ReproConfig {
    /// Parse from TOML-subset text (unknown keys are ignored; unknown
    /// *sections* too — forward compatibility for configs from newer
    /// versions).
    pub fn from_toml(text: &str) -> Result<Self> {
        let doc = minitoml::parse(text)?;
        let cfg = Self::from_document(&doc);
        // fail config loading on an invalid compaction policy, not the
        // first store construction
        cfg.stream.to_policy().context("[stream] section")?;
        if !cfg.cluster.exec_mode.is_empty() {
            // fail config loading, not the first cluster_config() call
            cfg.cluster
                .exec_mode
                .parse::<ExecMode>()
                .with_context(|| format!("[cluster] exec_mode = {:?}", cfg.cluster.exec_mode))?;
        }
        if !cfg.runtime.simd.is_empty() {
            // fail config loading, not the first backend construction
            cfg.runtime
                .simd
                .parse::<SimdPolicy>()
                .with_context(|| format!("[runtime] simd = {:?}", cfg.runtime.simd))?;
        }
        if !cfg.faults.plan.is_empty() {
            // fail config loading, not the first cluster_config() call
            cfg.faults
                .plan
                .parse::<FaultPlan>()
                .with_context(|| format!("[faults] plan = {:?}", cfg.faults.plan))?;
        }
        if !cfg.faults.degrade.is_empty() {
            cfg.faults
                .degrade
                .parse::<DegradePolicy>()
                .with_context(|| format!("[faults] degrade = {:?}", cfg.faults.degrade))?;
        }
        if !cfg.obs.trace.is_empty() {
            // fail config loading, not the first engine build
            cfg.obs
                .trace
                .parse::<TraceMode>()
                .with_context(|| format!("[obs] trace = {:?}", cfg.obs.trace))?;
        }
        if !cfg.obs.metrics.is_empty() {
            // fail config loading, not the first engine build
            cfg.obs
                .metrics
                .parse::<MetricsMode>()
                .with_context(|| format!("[obs] metrics = {:?}", cfg.obs.metrics))?;
        }
        Ok(cfg)
    }

    fn from_document(doc: &Document) -> Self {
        let d = Self::default();
        let root = Section(doc.get(""));
        let cluster = Section(doc.get("cluster"));
        let network = Section(doc.get("network"));
        let algorithm = Section(doc.get("algorithm"));
        let stream = Section(doc.get("stream"));
        let runtime = Section(doc.get("runtime"));
        let faults = Section(doc.get("faults"));
        let obs = Section(doc.get("obs"));
        Self {
            cluster: ClusterSection {
                nodes: cluster.int_or("nodes", d.cluster.nodes as i64) as usize,
                partitions_per_node: cluster
                    .int_or("partitions_per_node", d.cluster.partitions_per_node as i64)
                    as usize,
                compute_scale: cluster.float_or("compute_scale", d.cluster.compute_scale),
                driver_scale: cluster.float_or("driver_scale", d.cluster.driver_scale),
                exec_mode: cluster.str_or("exec_mode", &d.cluster.exec_mode),
            },
            network: NetworkSection {
                enabled: network.bool_or("enabled", d.network.enabled),
                latency_us: network.float_or("latency_us", d.network.latency_us),
                bandwidth_gbps: network.float_or("bandwidth_gbps", d.network.bandwidth_gbps),
                driver_bandwidth_gbps: network
                    .float_or("driver_bandwidth_gbps", d.network.driver_bandwidth_gbps),
                shuffle_disk_mbps: network
                    .float_or("shuffle_disk_mbps", d.network.shuffle_disk_mbps),
                ser_ns_per_record: network
                    .float_or("ser_ns_per_record", d.network.ser_ns_per_record),
            },
            algorithm: AlgorithmSection {
                epsilon: algorithm.float_or("epsilon", d.algorithm.epsilon),
                tree_depth: algorithm.int_opt("tree_depth").map(|v| v as usize),
                seed: algorithm.int_or("seed", d.algorithm.seed as i64) as u64,
                sketch: algorithm.str_or("sketch", &d.algorithm.sketch),
                sketch_merge: algorithm.str_or("sketch_merge", &d.algorithm.sketch_merge),
            },
            stream: StreamSection {
                compact_threshold: stream
                    .int_or("compact_threshold", d.stream.compact_threshold as i64)
                    as usize,
                max_live_epochs: stream
                    .int_or("max_live_epochs", d.stream.max_live_epochs as i64)
                    as usize,
            },
            runtime: RuntimeSection {
                simd: runtime.str_or("simd", &d.runtime.simd),
            },
            faults: FaultsSection {
                plan: faults.str_or("plan", &d.faults.plan),
                max_task_retries: faults
                    .int_or("max_task_retries", d.faults.max_task_retries as i64)
                    as u32,
                backoff_ms: faults.float_or("backoff_ms", d.faults.backoff_ms),
                speculation: faults.bool_or("speculation", d.faults.speculation),
                degrade: faults.str_or("degrade", &d.faults.degrade),
            },
            obs: ObsSection {
                trace: obs.str_or("trace", &d.obs.trace),
                metrics: obs.str_or("metrics", &d.obs.metrics),
            },
            backend: root.str_or("backend", &d.backend),
            artifacts_dir: PathBuf::from(
                root.str_or("artifacts_dir", d.artifacts_dir.to_str().unwrap_or("artifacts")),
            ),
        }
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        Self::from_toml(&text).with_context(|| format!("parsing config {}", path.display()))
    }

    /// Load if the file exists, defaults otherwise.
    pub fn load_or_default(path: Option<&Path>) -> Result<Self> {
        match path {
            Some(p) => Self::load(p),
            None => {
                let default = Path::new("repro.toml");
                if default.exists() {
                    Self::load(default)
                } else {
                    Ok(Self::default())
                }
            }
        }
    }

    /// The effective SIMD dispatch policy: `[runtime] simd` (or the
    /// `--simd` CLI flag, which writes it) when set, the `GKSELECT_SIMD`
    /// env var otherwise, `Auto` when neither is given.
    pub fn simd_policy(&self) -> SimdPolicy {
        match self.runtime.simd.as_str() {
            "" => SimdPolicy::from_env(),
            other => other
                .parse()
                .expect("runtime.simd must be 'auto', 'scalar', or 'force'"),
        }
    }

    /// Materialize the configured kernel backend (backend name +
    /// artifacts dir + SIMD policy). The native path never touches the
    /// artifacts dir, so it cannot fail.
    pub fn kernel_backend(&self) -> Result<Box<dyn KernelBackend>> {
        crate::runtime::backend_from_name(&self.backend, &self.artifacts_dir, self.simd_policy())
    }

    /// Materialize the cluster description. Empty `exec_mode` / `plan`
    /// strings defer to the `GKSELECT_EXEC_MODE` / `GKSELECT_FAULTS`
    /// env vars, read quietly (garbage → ignored — the engine builder
    /// is the loud validation boundary).
    pub fn cluster_config(&self) -> ClusterConfig {
        let exec_mode = match self.cluster.exec_mode.as_str() {
            "" => crate::engine::env::exec_mode()
                .ok()
                .flatten()
                .unwrap_or_default(),
            other => other
                .parse()
                .expect("cluster.exec_mode must be 'sequential' or 'threads'"),
        };
        let faults = match self.faults.plan.as_str() {
            "" => crate::engine::env::faults().ok().flatten(),
            other => Some(
                other
                    .parse()
                    .expect("faults.plan must use the FaultPlan grammar"),
            ),
        };
        ClusterConfig {
            executors: self.cluster.nodes,
            partitions: self.cluster.nodes * self.cluster.partitions_per_node,
            net: self.network.to_model(),
            compute_scale: self.cluster.compute_scale,
            driver_scale: self.cluster.driver_scale,
            exec_mode,
            faults,
            retry: self.faults.to_retry_policy(),
        }
    }

    pub fn to_toml(&self) -> String {
        let mut doc: Document = Default::default();
        let root = doc.entry(String::new()).or_default();
        root.insert("backend".into(), Value::Str(self.backend.clone()));
        root.insert(
            "artifacts_dir".into(),
            Value::Str(self.artifacts_dir.to_string_lossy().into_owned()),
        );
        let c = doc.entry("cluster".into()).or_default();
        c.insert("nodes".into(), Value::Int(self.cluster.nodes as i64));
        c.insert(
            "partitions_per_node".into(),
            Value::Int(self.cluster.partitions_per_node as i64),
        );
        c.insert(
            "compute_scale".into(),
            Value::Float(self.cluster.compute_scale),
        );
        c.insert("driver_scale".into(), Value::Float(self.cluster.driver_scale));
        if !self.cluster.exec_mode.is_empty() {
            c.insert("exec_mode".into(), Value::Str(self.cluster.exec_mode.clone()));
        }
        let n = doc.entry("network".into()).or_default();
        n.insert("enabled".into(), Value::Bool(self.network.enabled));
        n.insert("latency_us".into(), Value::Float(self.network.latency_us));
        n.insert(
            "bandwidth_gbps".into(),
            Value::Float(self.network.bandwidth_gbps),
        );
        n.insert(
            "driver_bandwidth_gbps".into(),
            Value::Float(self.network.driver_bandwidth_gbps),
        );
        n.insert(
            "shuffle_disk_mbps".into(),
            Value::Float(self.network.shuffle_disk_mbps),
        );
        n.insert(
            "ser_ns_per_record".into(),
            Value::Float(self.network.ser_ns_per_record),
        );
        let a = doc.entry("algorithm".into()).or_default();
        a.insert("epsilon".into(), Value::Float(self.algorithm.epsilon));
        if let Some(depth) = self.algorithm.tree_depth {
            a.insert("tree_depth".into(), Value::Int(depth as i64));
        }
        a.insert("seed".into(), Value::Int(self.algorithm.seed as i64));
        a.insert("sketch".into(), Value::Str(self.algorithm.sketch.clone()));
        a.insert(
            "sketch_merge".into(),
            Value::Str(self.algorithm.sketch_merge.clone()),
        );
        let s = doc.entry("stream".into()).or_default();
        s.insert(
            "compact_threshold".into(),
            Value::Int(self.stream.compact_threshold as i64),
        );
        s.insert(
            "max_live_epochs".into(),
            Value::Int(self.stream.max_live_epochs as i64),
        );
        if !self.runtime.simd.is_empty() {
            let r = doc.entry("runtime".into()).or_default();
            r.insert("simd".into(), Value::Str(self.runtime.simd.clone()));
        }
        let f = doc.entry("faults".into()).or_default();
        if !self.faults.plan.is_empty() {
            f.insert("plan".into(), Value::Str(self.faults.plan.clone()));
        }
        f.insert(
            "max_task_retries".into(),
            Value::Int(self.faults.max_task_retries as i64),
        );
        f.insert("backoff_ms".into(), Value::Float(self.faults.backoff_ms));
        f.insert("speculation".into(), Value::Bool(self.faults.speculation));
        if !self.faults.degrade.is_empty() {
            f.insert("degrade".into(), Value::Str(self.faults.degrade.clone()));
        }
        if !self.obs.trace.is_empty() || !self.obs.metrics.is_empty() {
            let o = doc.entry("obs".into()).or_default();
            if !self.obs.trace.is_empty() {
                o.insert("trace".into(), Value::Str(self.obs.trace.clone()));
            }
            if !self.obs.metrics.is_empty() {
                o.insert("metrics".into(), Value::Str(self.obs.metrics.clone()));
            }
        }
        minitoml::serialize(&doc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = ReproConfig::default();
        assert_eq!(c.cluster.nodes, 10);
        let cc = c.cluster_config();
        assert_eq!(cc.partitions, 40);
        assert_eq!(cc.executors, 10);
        assert_eq!(c.backend, "native");
    }

    #[test]
    fn roundtrips_through_toml() {
        let mut c = ReproConfig::default();
        c.algorithm.epsilon = 0.05;
        c.cluster.nodes = 30;
        c.algorithm.tree_depth = Some(4);
        c.backend = "pjrt".into();
        let text = c.to_toml();
        let back = ReproConfig::from_toml(&text).unwrap();
        assert_eq!(back.algorithm.epsilon, 0.05);
        assert_eq!(back.cluster.nodes, 30);
        assert_eq!(back.algorithm.tree_depth, Some(4));
        assert_eq!(back.backend, "pjrt");
    }

    #[test]
    fn partial_toml_fills_defaults() {
        let back = ReproConfig::from_toml("[cluster]\nnodes = 3\n").unwrap();
        assert_eq!(back.cluster.nodes, 3);
        assert_eq!(back.cluster.partitions_per_node, 4);
        assert_eq!(back.algorithm.epsilon, 0.01);
        assert_eq!(back.algorithm.tree_depth, None);
    }

    #[test]
    fn exec_mode_roundtrips_and_materializes() {
        let mut c = ReproConfig::default();
        c.cluster.exec_mode = "threads".into();
        let back = ReproConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(back.cluster.exec_mode, "threads");
        assert_eq!(back.cluster_config().exec_mode, ExecMode::Threads);
        // a bad mode fails at load time with context, not at first use
        let err = ReproConfig::from_toml("[cluster]\nexec_mode = \"turbo\"\n").unwrap_err();
        assert!(format!("{err:#}").contains("exec_mode"));
    }

    #[test]
    fn stream_section_roundtrips_and_validates() {
        let mut c = ReproConfig::default();
        assert_eq!(c.stream.compact_threshold, 8);
        assert_eq!(c.stream.max_live_epochs, 4);
        c.stream.compact_threshold = 16;
        c.stream.max_live_epochs = 2;
        let back = ReproConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(back.stream.compact_threshold, 16);
        assert_eq!(back.stream.max_live_epochs, 2);
        let policy = back.stream.to_policy().unwrap();
        assert_eq!(policy.compact_threshold, 16);
        // an inverted policy fails at load time with section context
        let err = ReproConfig::from_toml(
            "[stream]\ncompact_threshold = 2\nmax_live_epochs = 6\n",
        )
        .unwrap_err();
        assert!(format!("{err:#}").contains("stream"));
    }

    #[test]
    fn simd_policy_roundtrips_and_materializes() {
        let mut c = ReproConfig::default();
        assert_eq!(c.runtime.simd, "");
        c.runtime.simd = "scalar".into();
        let back = ReproConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(back.runtime.simd, "scalar");
        assert_eq!(back.simd_policy(), SimdPolicy::ForceScalar);
        let backend = back.kernel_backend().unwrap();
        assert_eq!(backend.simd_lane_width(), 1);
        // a bad policy fails at load time with section context
        let err = ReproConfig::from_toml("[runtime]\nsimd = \"turbo\"\n").unwrap_err();
        assert!(format!("{err:#}").contains("simd"));
        // force parses and resolves to whatever tile this CPU has
        let forced = ReproConfig::from_toml("[runtime]\nsimd = \"force\"\n").unwrap();
        assert_eq!(forced.simd_policy(), SimdPolicy::ForceSimd);
        assert!(forced.kernel_backend().unwrap().simd_lane_width() >= 1);
    }

    #[test]
    fn faults_section_roundtrips_and_materializes() {
        let mut c = ReproConfig::default();
        assert_eq!(c.faults.plan, "");
        assert_eq!(c.faults.max_task_retries, 3);
        assert!(c.faults.speculation);
        c.faults.plan = "seed=9,panic=0.1".into();
        c.faults.max_task_retries = 5;
        c.faults.backoff_ms = 10.0;
        c.faults.speculation = false;
        c.faults.degrade = "sketch".into();
        let back = ReproConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(back.faults.plan, "seed=9,panic=0.1");
        assert_eq!(back.faults.degrade, "sketch");
        let retry = back.faults.to_retry_policy();
        assert_eq!(retry.max_task_retries, 5);
        assert!((retry.backoff_secs - 0.01).abs() < 1e-12);
        assert!(!retry.speculation);
        let cc = back.cluster_config();
        assert_eq!(cc.faults.as_ref().unwrap().seed, 9);
        assert_eq!(cc.retry.max_task_retries, 5);
        // a bad plan or degrade policy fails at load time with context
        let err = ReproConfig::from_toml("[faults]\nplan = \"chaos\"\n").unwrap_err();
        assert!(format!("{err:#}").contains("plan"));
        let err = ReproConfig::from_toml("[faults]\ndegrade = \"explode\"\n").unwrap_err();
        assert!(format!("{err:#}").contains("degrade"));
    }

    #[test]
    fn obs_section_roundtrips_and_validates() {
        let mut c = ReproConfig::default();
        assert_eq!(c.obs.trace, "");
        assert_eq!(c.obs.metrics, "");
        // the empty defaults stay out of the serialized form
        assert!(!c.to_toml().contains("[obs]"));
        c.obs.trace = "chrome:out/t.json".into();
        c.obs.metrics = "prom:out/m.prom".into();
        let back = ReproConfig::from_toml(&c.to_toml()).unwrap();
        assert_eq!(back.obs.trace, "chrome:out/t.json");
        assert_eq!(
            back.obs.trace.parse::<TraceMode>().unwrap(),
            TraceMode::Chrome(PathBuf::from("out/t.json"))
        );
        assert_eq!(
            back.obs.metrics.parse::<MetricsMode>().unwrap(),
            MetricsMode::Prom(PathBuf::from("out/m.prom"))
        );
        // a bad mode fails at load time with section context
        let err = ReproConfig::from_toml("[obs]\ntrace = \"perfetto\"\n").unwrap_err();
        assert!(format!("{err:#}").contains("trace"));
        let err = ReproConfig::from_toml("[obs]\nmetrics = \"statsd\"\n").unwrap_err();
        assert!(format!("{err:#}").contains("metrics"));
        // metrics alone still emits the section
        let mut only = ReproConfig::default();
        only.obs.metrics = "memory".into();
        let back = ReproConfig::from_toml(&only.to_toml()).unwrap();
        assert_eq!(back.obs.metrics, "memory");
        assert_eq!(back.obs.trace, "");
    }

    #[test]
    fn network_disable_zeroes_model() {
        let n = NetworkSection {
            enabled: false,
            ..Default::default()
        };
        assert_eq!(n.to_model().latency_s, 0.0);
    }

    #[test]
    fn network_unit_conversion() {
        let n = NetworkSection::default();
        let m = n.to_model();
        assert!((m.latency_s - 200e-6).abs() < 1e-12);
        assert!((m.bandwidth_bps - 1.25e9).abs() < 1.0);
    }

    #[test]
    fn load_missing_file_errors_with_path() {
        let err = ReproConfig::load(Path::new("/nonexistent/x.toml"))
            .unwrap_err()
            .to_string();
        assert!(err.contains("/nonexistent/x.toml"));
    }
}
