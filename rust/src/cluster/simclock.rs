//! Virtual clock for the discrete-event timing model.
//!
//! Every distributed action advances this clock by the *modelled parallel
//! elapsed time* (max-over-executors compute + fabric cost), which is what
//! the paper's figures plot. Monotonic by construction.

/// Accumulated virtual elapsed time of one run.
#[derive(Debug, Clone, Default)]
pub struct SimClock {
    elapsed_s: f64,
}

impl SimClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Advance by `secs` of modelled elapsed time.
    pub fn advance(&mut self, secs: f64) {
        debug_assert!(secs >= 0.0, "clock cannot run backwards ({secs})");
        debug_assert!(secs.is_finite(), "non-finite clock advance");
        self.elapsed_s += secs.max(0.0);
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_zero_and_accumulates() {
        let mut c = SimClock::new();
        assert_eq!(c.elapsed_secs(), 0.0);
        c.advance(1.5);
        c.advance(0.25);
        assert!((c.elapsed_secs() - 1.75).abs() < 1e-12);
    }

    #[test]
    fn negative_advance_clamped_in_release() {
        let mut c = SimClock::new();
        // debug_assert fires in tests only via debug builds of deps;
        // behaviour contract: clamped to zero
        if !cfg!(debug_assertions) {
            c.advance(-1.0);
            assert_eq!(c.elapsed_secs(), 0.0);
        }
    }
}
