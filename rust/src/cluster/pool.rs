//! Thread-parallel executor pool: one OS thread per simulated executor.
//!
//! The substrate's sequential path *models* parallelism on the virtual
//! clock; this module makes it real. [`ExecutorPool::run_threaded`] runs
//! one scoped OS thread per executor (no `'static` bounds — the threads
//! borrow the dataset and the partition closure for the duration of the
//! stage), each draining its own work queue of partition indices in
//! round-robin locality order, exactly the partitions
//! [`super::ClusterConfig::executor_of`] assigns it.
//!
//! Both execution strategies live here so the substrate's bookkeeping is
//! mode-independent:
//!
//! * [`ExecutorPool::run_sequential`] — the deterministic default: every
//!   partition closure runs on the calling thread in partition order.
//! * [`ExecutorPool::run_threaded`] — real concurrency: partitions run on
//!   their owning executor's thread; results are gathered back into
//!   partition order, so `PerPartition.values` is bit-identical to the
//!   sequential path for any pure (`Fn`) partition closure.
//!
//! Either way a [`StageOutput`] carries the per-partition measured times
//! (the virtual clock's input — unchanged by the mode), the stage's real
//! wall-clock, and a per-executor busy-time ledger (utilization / skew).

use std::time::Instant;

use super::dataset::Dataset;
use super::PartitionCtx;

/// How `map_partitions` stages execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// Run every partition closure on the calling thread, in partition
    /// order. Deterministic wall-clock; the default for tests.
    #[default]
    Sequential,
    /// Dispatch partitions to one OS thread per executor (scoped threads
    /// spawned per stage). Values and the virtual clock's accounting are
    /// identical to `Sequential`; only the real wall-clock changes.
    Threads,
}

impl ExecMode {
    /// Mode requested by the `GKSELECT_EXEC_MODE` environment variable
    /// (`sequential` | `threads`; unset → `Sequential`). This is the CI
    /// toggle that re-runs the whole suite under real concurrency.
    /// Parsing lives in [`crate::engine::env`] — the one place env vars
    /// are read; builders that can report errors use that module
    /// directly instead of this panicking convenience.
    pub fn from_env() -> Self {
        crate::engine::env::exec_mode()
            .expect("GKSELECT_EXEC_MODE must be 'sequential' or 'threads'")
            .unwrap_or(ExecMode::Sequential)
    }

    pub fn label(self) -> &'static str {
        match self {
            ExecMode::Sequential => "sequential",
            ExecMode::Threads => "threads",
        }
    }
}

impl std::str::FromStr for ExecMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s {
            "sequential" | "seq" => Ok(Self::Sequential),
            "threads" | "thread" => Ok(Self::Threads),
            other => anyhow::bail!("unknown exec mode '{other}' (sequential|threads)"),
        }
    }
}

/// Raw result of one `mapPartitions` stage, before the substrate's
/// bookkeeping: values and measured compute times in partition order,
/// plus the stage's real timing.
#[derive(Debug)]
pub struct StageOutput<R> {
    /// One result per partition, in partition order (mode-independent).
    pub values: Vec<R>,
    /// Measured compute seconds per partition — what the virtual clock
    /// charges (max over executors of their partitions' sums).
    pub times: Vec<f64>,
    /// Real wall-clock seconds of the whole stage: the sum of all
    /// partition times (+ loop overhead) sequentially, the parallel
    /// elapsed time under threads.
    pub wall_secs: f64,
    /// Real seconds each executor spent inside partition closures, indexed
    /// by executor.
    pub busy_secs: Vec<f64>,
}

/// The executor pool: owns the per-executor work-queue construction and
/// both execution strategies. Threads are scoped per stage, so the pool
/// itself is just the executor count — cheap to hold on the `Cluster`.
#[derive(Debug, Clone)]
pub struct ExecutorPool {
    executors: usize,
}

impl ExecutorPool {
    pub fn new(executors: usize) -> Self {
        assert!(executors > 0, "pool needs at least one executor");
        Self { executors }
    }

    pub fn executors(&self) -> usize {
        self.executors
    }

    /// Per-executor work queues: partition indices in ascending order —
    /// the round-robin locality order `executor_of` induces, and the
    /// order the sequential path visits them in.
    fn queues(
        &self,
        num_partitions: usize,
        executor_of: impl Fn(usize) -> usize,
    ) -> Vec<Vec<usize>> {
        let mut queues: Vec<Vec<usize>> = vec![Vec::new(); self.executors];
        for p in 0..num_partitions {
            let e = executor_of(p);
            assert!(e < self.executors, "executor_of({p}) = {e} out of range");
            queues[e].push(p);
        }
        queues
    }

    /// Sequential strategy: run every partition on the calling thread, in
    /// partition order.
    pub fn run_sequential<T, R>(
        &self,
        data: &Dataset<T>,
        executor_of: impl Fn(usize) -> usize,
        f: impl Fn(&[T], PartitionCtx) -> R,
    ) -> StageOutput<R> {
        let num_partitions = data.num_partitions();
        let wall_start = Instant::now();
        let mut values = Vec::with_capacity(num_partitions);
        let mut times = Vec::with_capacity(num_partitions);
        let mut busy_secs = vec![0.0_f64; self.executors];
        for p in 0..num_partitions {
            let executor = executor_of(p);
            let ctx = PartitionCtx {
                partition: p,
                executor,
                num_partitions,
            };
            let start = Instant::now();
            values.push(f(data.partition(p), ctx));
            let dt = start.elapsed().as_secs_f64();
            times.push(dt);
            busy_secs[executor] += dt;
        }
        StageOutput {
            values,
            times,
            wall_secs: wall_start.elapsed().as_secs_f64(),
            busy_secs,
        }
    }

    /// Threaded strategy: one scoped OS thread per executor, each running
    /// its own queue's partitions in locality order. Results are scattered
    /// back into partition order, so for pure closures the output is
    /// bit-identical to [`Self::run_sequential`].
    pub fn run_threaded<T, R>(
        &self,
        data: &Dataset<T>,
        executor_of: impl Fn(usize) -> usize,
        f: impl Fn(&[T], PartitionCtx) -> R + Sync,
    ) -> StageOutput<R>
    where
        T: Send + Sync,
        R: Send,
    {
        let num_partitions = data.num_partitions();
        let queues = self.queues(num_partitions, executor_of);
        let wall_start = Instant::now();
        // (partition, value, secs) triples per executor, plus its busy sum
        let per_exec: Vec<(Vec<(usize, R, f64)>, f64)> = std::thread::scope(|scope| {
            let f = &f;
            let handles: Vec<_> = queues
                .iter()
                .enumerate()
                .map(|(executor, queue)| {
                    scope.spawn(move || {
                        let mut out = Vec::with_capacity(queue.len());
                        let mut busy = 0.0_f64;
                        for &p in queue {
                            let ctx = PartitionCtx {
                                partition: p,
                                executor,
                                num_partitions,
                            };
                            let start = Instant::now();
                            let value = f(data.partition(p), ctx);
                            let dt = start.elapsed().as_secs_f64();
                            busy += dt;
                            out.push((p, value, dt));
                        }
                        (out, busy)
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(v) => v,
                    Err(panic) => std::panic::resume_unwind(panic),
                })
                .collect()
        });
        let wall_secs = wall_start.elapsed().as_secs_f64();

        // scatter back into partition order
        let mut values: Vec<Option<R>> = Vec::with_capacity(num_partitions);
        values.resize_with(num_partitions, || None);
        let mut times = vec![0.0_f64; num_partitions];
        let mut busy_secs = Vec::with_capacity(self.executors);
        for (outs, busy) in per_exec {
            busy_secs.push(busy);
            for (p, value, dt) in outs {
                values[p] = Some(value);
                times[p] = dt;
            }
        }
        StageOutput {
            values: values
                .into_iter()
                .map(|v| v.expect("every partition executed exactly once"))
                .collect(),
            times,
            wall_secs,
            busy_secs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dataset() -> Dataset<i32> {
        Dataset::from_partitions(vec![
            vec![1, 2, 3],
            vec![4, 5],
            vec![6],
            vec![7, 8, 9, 10],
            vec![],
            vec![11],
            vec![12, 13],
        ])
        .unwrap()
    }

    #[test]
    fn threaded_values_match_sequential_in_partition_order() {
        let pool = ExecutorPool::new(3);
        let d = dataset();
        let f = |part: &[i32], ctx: PartitionCtx| {
            (ctx.partition, ctx.executor, part.iter().sum::<i32>())
        };
        let seq = pool.run_sequential(&d, |p| p % 3, f);
        let thr = pool.run_threaded(&d, |p| p % 3, f);
        assert_eq!(seq.values, thr.values);
        // partition order, correct executor assignment
        for (p, &(part, exec, _)) in thr.values.iter().enumerate() {
            assert_eq!(part, p);
            assert_eq!(exec, p % 3);
        }
    }

    #[test]
    fn ledgers_are_shaped_by_the_pool() {
        let pool = ExecutorPool::new(2);
        let d = dataset();
        let out = pool.run_threaded(&d, |p| p % 2, |part, _| part.len());
        assert_eq!(out.values.len(), 7);
        assert_eq!(out.times.len(), 7);
        assert_eq!(out.busy_secs.len(), 2);
        assert!(out.wall_secs >= 0.0);
        assert!(out.busy_secs.iter().all(|&b| b >= 0.0));
    }

    #[test]
    fn single_executor_degenerate_case() {
        let pool = ExecutorPool::new(1);
        let d = dataset();
        let seq = pool.run_sequential(&d, |_| 0, |part, _| part.to_vec());
        let thr = pool.run_threaded(&d, |_| 0, |part, _| part.to_vec());
        assert_eq!(seq.values, thr.values);
        assert_eq!(thr.busy_secs.len(), 1);
    }

    #[test]
    fn more_executors_than_populated_queues() {
        // 5 executors but only 2 partitions: three threads run empty queues
        let pool = ExecutorPool::new(5);
        let d = Dataset::from_partitions(vec![vec![1], vec![2, 3]]).unwrap();
        let thr = pool.run_threaded(&d, |p| p % 5, |part, _| part.len());
        assert_eq!(thr.values, vec![1, 2]);
        assert_eq!(thr.busy_secs.len(), 5);
    }

    #[test]
    fn exec_mode_parses() {
        assert_eq!("sequential".parse::<ExecMode>().unwrap(), ExecMode::Sequential);
        assert_eq!("threads".parse::<ExecMode>().unwrap(), ExecMode::Threads);
        assert!("turbo".parse::<ExecMode>().is_err());
        assert_eq!(ExecMode::Threads.label(), "threads");
        assert_eq!(ExecMode::default(), ExecMode::Sequential);
    }

    #[test]
    fn queues_follow_locality_order() {
        let pool = ExecutorPool::new(2);
        let queues = pool.queues(5, |p| p % 2);
        assert_eq!(queues, vec![vec![0, 2, 4], vec![1, 3]]);
    }
}
