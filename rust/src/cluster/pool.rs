//! Thread-parallel executor pool: one OS thread per simulated executor.
//!
//! The substrate's sequential path *models* parallelism on the virtual
//! clock; this module makes it real. [`ExecutorPool::run_threaded`] runs
//! one scoped OS thread per executor (no `'static` bounds — the threads
//! borrow the dataset and the partition closure for the duration of the
//! stage), each draining its own work queue of partition indices in
//! round-robin locality order, exactly the partitions
//! [`super::ClusterConfig::executor_of`] assigns it.
//!
//! Both execution strategies live here so the substrate's bookkeeping is
//! mode-independent:
//!
//! * [`ExecutorPool::run_sequential`] — the deterministic default: every
//!   partition closure runs on the calling thread in partition order.
//! * [`ExecutorPool::run_threaded`] — real concurrency: partitions run on
//!   their owning executor's thread; results are gathered back into
//!   partition order, so `PerPartition.values` is bit-identical to the
//!   sequential path for any pure (`Fn`) partition closure.
//!
//! Every task attempt runs through the fault model
//! ([`super::faults`]): the [`FaultInjector`] (if armed) is consulted
//! per `(stage, partition, attempt)`, injected and *real* panics are
//! caught with `catch_unwind` and retried under the stage's
//! [`RetryPolicy`], stragglers are mitigated by modelled speculative
//! duplicates, and a task that exhausts its retries fails the stage
//! with a typed [`StageError`] instead of unwinding the driver. Because
//! partition closures are pure, a retried or speculated task returns
//! the same value — recovery changes counters and modelled time, never
//! results.
//!
//! Either way a [`StageOutput`] carries the per-partition modelled times
//! (the virtual clock's input — unchanged by the mode), the stage's real
//! wall-clock, a per-executor busy-time ledger (utilization / skew),
//! and the stage's [`FaultLedger`].

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Instant;

use super::dataset::Dataset;
use super::faults::{FaultContext, FaultKind, FaultLedger, StageError, SPECULATION_THRESHOLD};
use super::PartitionCtx;
use crate::obs::{AttemptOutcome, AttemptRecord};

/// How `map_partitions` stages execute.
///
/// The `GKSELECT_EXEC_MODE` environment variable (`sequential` |
/// `threads`) selects the mode for env-built clusters; it is parsed in
/// [`crate::engine::env`] — the one place env vars are read — with
/// typed `InvalidEnv` errors at the engine/CLI boundary.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// Run every partition closure on the calling thread, in partition
    /// order. Deterministic wall-clock; the default for tests.
    #[default]
    Sequential,
    /// Dispatch partitions to one OS thread per executor (scoped threads
    /// spawned per stage). Values and the virtual clock's accounting are
    /// identical to `Sequential`; only the real wall-clock changes.
    Threads,
}

impl ExecMode {
    pub fn label(self) -> &'static str {
        match self {
            ExecMode::Sequential => "sequential",
            ExecMode::Threads => "threads",
        }
    }
}

impl std::str::FromStr for ExecMode {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s {
            "sequential" | "seq" => Ok(Self::Sequential),
            "threads" | "thread" => Ok(Self::Threads),
            other => anyhow::bail!("unknown exec mode '{other}' (sequential|threads)"),
        }
    }
}

/// Raw result of one `mapPartitions` stage, before the substrate's
/// bookkeeping: values and modelled compute times in partition order,
/// plus the stage's real timing and recovery tallies.
#[derive(Debug)]
pub struct StageOutput<R> {
    /// One result per partition, in partition order (mode-independent).
    pub values: Vec<R>,
    /// Modelled compute seconds per partition — what the virtual clock
    /// charges (max over executors of their partitions' sums). Equals
    /// the measured time except for straggled tasks, whose entry is the
    /// slowed-down (or speculation-capped) duration.
    pub times: Vec<f64>,
    /// Real wall-clock seconds of the whole stage: the sum of all
    /// partition times (+ loop overhead) sequentially, the parallel
    /// elapsed time under threads.
    pub wall_secs: f64,
    /// Real seconds each executor spent inside partition closures, indexed
    /// by executor.
    pub busy_secs: Vec<f64>,
    /// Injected-fault / retry / speculation tallies for this stage.
    pub faults: FaultLedger,
    /// Per-attempt records for the tracer (empty unless
    /// `FaultContext::trace` was set). Ordering across executors is
    /// unspecified; `Tracer::record_attempts` sorts before stitching.
    pub attempts: Vec<AttemptRecord>,
}

/// One task's fate after retries and speculation.
struct TaskOutcome<R> {
    value: R,
    /// Modelled seconds (straggler/speculation-adjusted).
    model_secs: f64,
    /// Measured seconds of the successful attempt (busy ledger).
    busy_secs: f64,
    ledger: FaultLedger,
    /// Every attempt this task ran (traced stages only).
    attempts: Vec<AttemptRecord>,
}

/// Run one partition task to completion (or retry exhaustion) under the
/// fault model. Pure closures make every attempt return the same value,
/// so recovery is invisible in `values`.
fn run_task<T, R, F>(
    f: &F,
    part: &[T],
    ctx: PartitionCtx,
    fx: &FaultContext<'_>,
) -> Result<TaskOutcome<R>, StageError>
where
    F: Fn(&[T], PartitionCtx) -> R,
{
    let mut ledger = FaultLedger::default();
    let mut attempts: Vec<AttemptRecord> = Vec::new();
    let mut attempt = 0u32;
    loop {
        let injected = fx
            .injector
            .and_then(|i| i.fault_for(fx.stage, ctx.partition, ctx.executor, attempt));
        if let Some(kind) = injected.filter(FaultKind::is_fatal) {
            ledger.faults_injected += 1;
            if fx.trace {
                attempts.push(AttemptRecord {
                    partition: ctx.partition,
                    executor: ctx.executor,
                    attempt,
                    outcome: match kind {
                        FaultKind::Transient => AttemptOutcome::Transient,
                        FaultKind::ExecutorLost => AttemptOutcome::Lost,
                        _ => AttemptOutcome::Panic,
                    },
                    model_secs: 0.0,
                    wall_secs: 0.0,
                    fault: Some(kind.failure_reason()),
                });
            }
            if attempt >= fx.retry.max_task_retries {
                return Err(StageError {
                    stage: fx.stage,
                    partition: ctx.partition,
                    attempts: attempt + 1,
                    reason: kind.failure_reason(),
                });
            }
            ledger.tasks_retried += 1;
            ledger.backoff_secs += fx.retry.backoff_secs;
            attempt += 1;
            continue;
        }
        let start = Instant::now();
        let run = catch_unwind(AssertUnwindSafe(|| f(part, ctx)));
        let dt = start.elapsed().as_secs_f64();
        match run {
            Ok(value) => {
                let mut record_outcome = AttemptOutcome::Ok;
                let mut record_model = dt;
                let mut record_fault: Option<String> = None;
                let mut duplicate: Option<AttemptRecord> = None;
                let model_secs = match injected {
                    Some(kind @ FaultKind::Straggler(mult)) => {
                        ledger.faults_injected += 1;
                        let launched_before = ledger.speculative_launched;
                        let wins_before = ledger.speculative_wins;
                        let model = straggled_secs(dt, mult, fx, &mut ledger);
                        // the straggled original runs (or would run) the
                        // full slowed duration, whatever the stage charges
                        record_model = dt * mult;
                        record_fault = Some(kind.failure_reason());
                        if fx.trace && ledger.speculative_launched > launched_before {
                            let dup_won = ledger.speculative_wins > wins_before;
                            record_outcome = if dup_won {
                                AttemptOutcome::SpeculativeLoss
                            } else {
                                AttemptOutcome::SpeculativeWin
                            };
                            duplicate = Some(AttemptRecord {
                                partition: ctx.partition,
                                executor: (ctx.executor + 1) % fx.executors,
                                attempt,
                                outcome: if dup_won {
                                    AttemptOutcome::SpeculativeWin
                                } else {
                                    AttemptOutcome::SpeculativeLoss
                                },
                                model_secs: 2.0 * dt,
                                wall_secs: dt,
                                fault: Some("speculative duplicate".to_string()),
                            });
                        }
                        model
                    }
                    _ => dt,
                };
                if fx.trace {
                    attempts.push(AttemptRecord {
                        partition: ctx.partition,
                        executor: ctx.executor,
                        attempt,
                        outcome: record_outcome,
                        model_secs: record_model,
                        wall_secs: dt,
                        fault: record_fault,
                    });
                    attempts.extend(duplicate);
                }
                return Ok(TaskOutcome {
                    value,
                    model_secs,
                    busy_secs: dt,
                    ledger,
                    attempts,
                });
            }
            Err(panic) => {
                if fx.trace {
                    attempts.push(AttemptRecord {
                        partition: ctx.partition,
                        executor: ctx.executor,
                        attempt,
                        outcome: AttemptOutcome::Panic,
                        model_secs: dt,
                        wall_secs: dt,
                        fault: Some(panic_message(panic.as_ref())),
                    });
                }
                if attempt >= fx.retry.max_task_retries {
                    return Err(StageError {
                        stage: fx.stage,
                        partition: ctx.partition,
                        attempts: attempt + 1,
                        reason: panic_message(panic.as_ref()),
                    });
                }
                ledger.tasks_retried += 1;
                ledger.backoff_secs += fx.retry.backoff_secs;
                attempt += 1;
            }
        }
    }
}

/// Modelled duration of a straggled task: `mult`× the measured time,
/// capped by a speculative duplicate when one can launch. The duplicate
/// is detected once the task overruns its expected duration (`dt`) and
/// then runs for `dt` itself, finishing at `2·dt`; the first finisher
/// wins — results are pure, so only the time and counters change.
fn straggled_secs(dt: f64, mult: f64, fx: &FaultContext<'_>, ledger: &mut FaultLedger) -> f64 {
    let slowed = dt * mult;
    if fx.retry.speculation && fx.executors > 1 && mult >= SPECULATION_THRESHOLD {
        ledger.speculative_launched += 1;
        let duplicate = 2.0 * dt;
        if duplicate < slowed {
            ledger.speculative_wins += 1;
            return duplicate;
        }
    }
    slowed
}

fn panic_message(panic: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = panic.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s.clone()
    } else {
        "task panicked".to_string()
    }
}

/// The executor pool: owns the per-executor work-queue construction and
/// both execution strategies. Threads are scoped per stage, so the pool
/// itself is just the executor count — cheap to hold on the `Cluster`.
#[derive(Debug, Clone)]
pub struct ExecutorPool {
    executors: usize,
}

impl ExecutorPool {
    pub fn new(executors: usize) -> Self {
        assert!(executors > 0, "pool needs at least one executor");
        Self { executors }
    }

    pub fn executors(&self) -> usize {
        self.executors
    }

    /// Per-executor work queues: partition indices in ascending order —
    /// the round-robin locality order `executor_of` induces, and the
    /// order the sequential path visits them in.
    fn queues(
        &self,
        num_partitions: usize,
        executor_of: impl Fn(usize) -> usize,
    ) -> Vec<Vec<usize>> {
        let mut queues: Vec<Vec<usize>> = vec![Vec::new(); self.executors];
        for p in 0..num_partitions {
            let e = executor_of(p);
            assert!(e < self.executors, "executor_of({p}) = {e} out of range");
            queues[e].push(p);
        }
        queues
    }

    /// Sequential strategy: run every partition on the calling thread, in
    /// partition order. Fails with the first (lowest-partition) task
    /// that exhausts its retries — the same error the threaded strategy
    /// reports for the same plan.
    pub fn run_sequential<T, R>(
        &self,
        data: &Dataset<T>,
        executor_of: impl Fn(usize) -> usize,
        f: impl Fn(&[T], PartitionCtx) -> R,
        fx: &FaultContext<'_>,
    ) -> Result<StageOutput<R>, StageError> {
        let num_partitions = data.num_partitions();
        let wall_start = Instant::now();
        let mut values = Vec::with_capacity(num_partitions);
        let mut times = Vec::with_capacity(num_partitions);
        let mut busy_secs = vec![0.0_f64; self.executors];
        let mut faults = FaultLedger::default();
        let mut attempts = Vec::new();
        for p in 0..num_partitions {
            let executor = executor_of(p);
            let ctx = PartitionCtx {
                partition: p,
                executor,
                num_partitions,
            };
            let task = run_task(&f, data.partition(p), ctx, fx)?;
            values.push(task.value);
            times.push(task.model_secs);
            busy_secs[executor] += task.busy_secs;
            faults.absorb(&task.ledger);
            attempts.extend(task.attempts);
        }
        Ok(StageOutput {
            values,
            times,
            wall_secs: wall_start.elapsed().as_secs_f64(),
            busy_secs,
            faults,
            attempts,
        })
    }

    /// Threaded strategy: one scoped OS thread per executor, each running
    /// its own queue's partitions in locality order. Results are scattered
    /// back into partition order, so for pure closures the output is
    /// bit-identical to [`Self::run_sequential`]. On retry exhaustion
    /// the reported `StageError` is the lowest-partition failure — the
    /// same one the sequential strategy stops at, because each queue is
    /// drained in ascending partition order.
    pub fn run_threaded<T, R>(
        &self,
        data: &Dataset<T>,
        executor_of: impl Fn(usize) -> usize,
        f: impl Fn(&[T], PartitionCtx) -> R + Sync,
        fx: &FaultContext<'_>,
    ) -> Result<StageOutput<R>, StageError>
    where
        T: Send + Sync,
        R: Send,
    {
        let num_partitions = data.num_partitions();
        let queues = self.queues(num_partitions, executor_of);
        let wall_start = Instant::now();
        // per executor: (partition, value, model secs) triples + busy sum
        // + fault ledger + attempt records, or the executor's first
        // stage failure
        type ExecResult<R> =
            Result<(Vec<(usize, R, f64)>, f64, FaultLedger, Vec<AttemptRecord>), StageError>;
        let per_exec: Vec<ExecResult<R>> = std::thread::scope(|scope| {
            let f = &f;
            let handles: Vec<_> = queues
                .iter()
                .enumerate()
                .map(|(executor, queue)| {
                    scope.spawn(move || -> ExecResult<R> {
                        let mut out = Vec::with_capacity(queue.len());
                        let mut busy = 0.0_f64;
                        let mut faults = FaultLedger::default();
                        let mut attempts = Vec::new();
                        for &p in queue {
                            let ctx = PartitionCtx {
                                partition: p,
                                executor,
                                num_partitions,
                            };
                            let task = run_task(f, data.partition(p), ctx, fx)?;
                            busy += task.busy_secs;
                            faults.absorb(&task.ledger);
                            attempts.extend(task.attempts);
                            out.push((p, task.value, task.model_secs));
                        }
                        Ok((out, busy, faults, attempts))
                    })
                })
                .collect();
            handles
                .into_iter()
                .map(|h| match h.join() {
                    Ok(v) => v,
                    // task panics are caught inside run_task; a worker
                    // unwind here is a pool bug, not a task fault
                    Err(panic) => std::panic::resume_unwind(panic),
                })
                .collect()
        });
        let wall_secs = wall_start.elapsed().as_secs_f64();

        // deterministic failure: the lowest failing partition wins, which
        // is exactly where the sequential strategy stops
        let mut results = Vec::with_capacity(per_exec.len());
        let mut first_failure: Option<StageError> = None;
        for r in per_exec {
            match r {
                Ok(ok) => results.push(ok),
                Err(e) => match &first_failure {
                    Some(cur) if cur.partition <= e.partition => {}
                    _ => first_failure = Some(e),
                },
            }
        }
        if let Some(err) = first_failure {
            return Err(err);
        }

        // scatter back into partition order
        let mut values: Vec<Option<R>> = Vec::with_capacity(num_partitions);
        values.resize_with(num_partitions, || None);
        let mut times = vec![0.0_f64; num_partitions];
        let mut busy_secs = Vec::with_capacity(self.executors);
        let mut faults = FaultLedger::default();
        let mut attempts = Vec::new();
        for (outs, busy, ledger, recs) in results {
            busy_secs.push(busy);
            faults.absorb(&ledger);
            attempts.extend(recs);
            for (p, value, dt) in outs {
                values[p] = Some(value);
                times[p] = dt;
            }
        }
        Ok(StageOutput {
            values: values
                .into_iter()
                .map(|v| v.expect("every partition executed exactly once"))
                .collect(),
            times,
            wall_secs,
            busy_secs,
            faults,
            attempts,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::super::faults::{FaultInjector, FaultPlan, RetryPolicy};
    use super::*;

    fn dataset() -> Dataset<i32> {
        Dataset::from_partitions(vec![
            vec![1, 2, 3],
            vec![4, 5],
            vec![6],
            vec![7, 8, 9, 10],
            vec![],
            vec![11],
            vec![12, 13],
        ])
        .unwrap()
    }

    fn fx_with<'a>(injector: &'a FaultInjector, retry: RetryPolicy) -> FaultContext<'a> {
        FaultContext {
            injector: Some(injector),
            retry,
            stage: 0,
            executors: 3,
            trace: false,
        }
    }

    #[test]
    fn threaded_values_match_sequential_in_partition_order() {
        let pool = ExecutorPool::new(3);
        let d = dataset();
        let f = |part: &[i32], ctx: PartitionCtx| {
            (ctx.partition, ctx.executor, part.iter().sum::<i32>())
        };
        let fx = FaultContext::none(3);
        let seq = pool.run_sequential(&d, |p| p % 3, f, &fx).unwrap();
        let thr = pool.run_threaded(&d, |p| p % 3, f, &fx).unwrap();
        assert_eq!(seq.values, thr.values);
        // partition order, correct executor assignment
        for (p, &(part, exec, _)) in thr.values.iter().enumerate() {
            assert_eq!(part, p);
            assert_eq!(exec, p % 3);
        }
    }

    #[test]
    fn ledgers_are_shaped_by_the_pool() {
        let pool = ExecutorPool::new(2);
        let d = dataset();
        let fx = FaultContext::none(2);
        let out = pool.run_threaded(&d, |p| p % 2, |part, _| part.len(), &fx).unwrap();
        assert_eq!(out.values.len(), 7);
        assert_eq!(out.times.len(), 7);
        assert_eq!(out.busy_secs.len(), 2);
        assert!(out.wall_secs >= 0.0);
        assert!(out.busy_secs.iter().all(|&b| b >= 0.0));
        assert_eq!(out.faults, FaultLedger::default());
    }

    #[test]
    fn single_executor_degenerate_case() {
        let pool = ExecutorPool::new(1);
        let d = dataset();
        let fx = FaultContext::none(1);
        let seq = pool.run_sequential(&d, |_| 0, |part, _| part.to_vec(), &fx).unwrap();
        let thr = pool.run_threaded(&d, |_| 0, |part, _| part.to_vec(), &fx).unwrap();
        assert_eq!(seq.values, thr.values);
        assert_eq!(thr.busy_secs.len(), 1);
    }

    #[test]
    fn more_executors_than_populated_queues() {
        // 5 executors but only 2 partitions: three threads run empty queues
        let pool = ExecutorPool::new(5);
        let d = Dataset::from_partitions(vec![vec![1], vec![2, 3]]).unwrap();
        let fx = FaultContext::none(5);
        let thr = pool.run_threaded(&d, |p| p % 5, |part, _| part.len(), &fx).unwrap();
        assert_eq!(thr.values, vec![1, 2]);
        assert_eq!(thr.busy_secs.len(), 5);
    }

    #[test]
    fn exec_mode_parses() {
        assert_eq!("sequential".parse::<ExecMode>().unwrap(), ExecMode::Sequential);
        assert_eq!("threads".parse::<ExecMode>().unwrap(), ExecMode::Threads);
        assert!("turbo".parse::<ExecMode>().is_err());
        assert_eq!(ExecMode::Threads.label(), "threads");
        assert_eq!(ExecMode::default(), ExecMode::Sequential);
    }

    #[test]
    fn queues_follow_locality_order() {
        let pool = ExecutorPool::new(2);
        let queues = pool.queues(5, |p| p % 2);
        assert_eq!(queues, vec![vec![0, 2, 4], vec![1, 3]]);
    }

    #[test]
    fn injected_panics_are_retried_to_identical_values() {
        let pool = ExecutorPool::new(3);
        let d = dataset();
        let f = |part: &[i32], ctx: PartitionCtx| (ctx.partition, part.iter().sum::<i32>());
        let clean = pool
            .run_sequential(&d, |p| p % 3, f, &FaultContext::none(3))
            .unwrap();

        let inj = FaultInjector::new(FaultPlan::seeded(5).panics(0.5).transients(0.3));
        let fx = fx_with(&inj, RetryPolicy::default());
        let seq = pool.run_sequential(&d, |p| p % 3, f, &fx).unwrap();
        let thr = pool.run_threaded(&d, |p| p % 3, f, &fx).unwrap();
        assert_eq!(seq.values, clean.values, "retries must not change values");
        assert_eq!(thr.values, clean.values);
        assert!(seq.faults.faults_injected > 0, "plan must actually fire");
        assert_eq!(seq.faults.tasks_retried, seq.faults.faults_injected);
        assert_eq!(seq.faults, thr.faults, "recovery tallies mode-identical");
        assert!(seq.faults.backoff_secs > 0.0);
    }

    #[test]
    fn retry_exhaustion_is_a_typed_stage_error_in_both_modes() {
        let pool = ExecutorPool::new(3);
        let d = dataset();
        let f = |part: &[i32], _: PartitionCtx| part.len();
        // persistent failure on partitions 2 and 5: the lowest wins
        let inj = FaultInjector::new(
            FaultPlan::seeded(0).panic_task(0, 5).panic_task(0, 2).attempts(99),
        );
        let fx = fx_with(&inj, RetryPolicy::default().with_max_task_retries(2));
        let seq = pool.run_sequential(&d, |p| p % 3, f, &fx).unwrap_err();
        let thr = pool.run_threaded(&d, |p| p % 3, f, &fx).unwrap_err();
        assert_eq!(seq, thr, "failure must be mode-identical");
        assert_eq!(seq.partition, 2);
        assert_eq!(seq.attempts, 3);
        assert_eq!(seq.stage, 0);
    }

    #[test]
    fn real_panics_are_caught_retried_and_typed() {
        let pool = ExecutorPool::new(2);
        let d = dataset();
        // a deterministic closure panics on every attempt → typed error
        let f = |part: &[i32], ctx: PartitionCtx| {
            if ctx.partition == 1 {
                panic!("boom in partition 1");
            }
            part.len()
        };
        let fx = FaultContext {
            injector: None,
            retry: RetryPolicy::default().with_max_task_retries(1),
            stage: 7,
            executors: 2,
            trace: false,
        };
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {})); // silence expected unwinds
        let seq = pool.run_sequential(&d, |p| p % 2, f, &fx).unwrap_err();
        let thr = pool.run_threaded(&d, |p| p % 2, f, &fx).unwrap_err();
        std::panic::set_hook(hook);
        assert_eq!(seq, thr);
        assert_eq!(seq.partition, 1);
        assert_eq!(seq.attempts, 2, "one retry consumed before failing");
        assert!(seq.reason.contains("boom"), "reason = {}", seq.reason);
    }

    #[test]
    fn stragglers_charge_model_time_and_speculate() {
        let pool = ExecutorPool::new(3);
        let d = dataset();
        let f = |part: &[i32], _: PartitionCtx| {
            // enough work that the measured time is nonzero
            part.iter().map(|&x| x as i64).sum::<i64>()
        };
        let inj = FaultInjector::new(FaultPlan::seeded(2).stragglers(1.0, 8.0));
        let fx = fx_with(&inj, RetryPolicy::default());
        let out = pool.run_sequential(&d, |p| p % 3, f, &fx).unwrap();
        let n = d.num_partitions() as u64;
        assert_eq!(out.faults.faults_injected, n, "every task straggles");
        assert_eq!(out.faults.speculative_launched, n);
        assert_eq!(out.faults.speculative_wins, n, "8x loses to the 2x duplicate");
        assert_eq!(out.faults.tasks_retried, 0);

        // no speculation on a single-executor cluster: full 8x charged
        let fx1 = FaultContext {
            injector: Some(&inj),
            retry: RetryPolicy::default(),
            stage: 0,
            executors: 1,
            trace: false,
        };
        let pool1 = ExecutorPool::new(1);
        let out1 = pool1.run_sequential(&d, |_| 0, f, &fx1).unwrap();
        assert_eq!(out1.faults.speculative_launched, 0);
        assert!(out1.times.iter().sum::<f64>() >= out.times.iter().sum::<f64>());
    }
}
