//! Range-partition shuffle — the expensive primitive behind Spark's full
//! sort (PSRS step 4, §IV-A).
//!
//! Every record is routed to the bucket whose splitter range contains it;
//! all but the locally-retained fraction crosses the fabric. This is the
//! paper's "second stage boundary" and the reason full sort is
//! communication-bound: `O(n)` network volume versus the sketch methods'
//! `O(P·poly(1/ε, log))`.

use super::dataset::Dataset;
use super::Cluster;
use crate::Key;
use std::time::Instant;

/// Route `data` into `splitters.len() + 1` range buckets (splitters
/// ascending; bucket `i` holds keys in `(splitters[i-1], splitters[i]]`
/// boundary-wise like Spark's `RangePartitioner` lower-bound search).
///
/// Charges: one stage boundary, `bytes_shuffled` for every record that
/// changes executor, and the fabric's all-to-all cost. Does **not** end a
/// round — the downstream action does.
pub fn shuffle_by_range(
    cluster: &mut Cluster,
    data: &Dataset<Key>,
    splitters: &[Key],
) -> Dataset<Key> {
    let out_parts = splitters.len() + 1;
    let start = Instant::now();

    let mut buckets: Vec<Vec<Key>> = vec![Vec::new(); out_parts];
    let mut moved_bytes = 0u64;
    let key_bytes = std::mem::size_of::<Key>() as u64;

    for p in 0..data.num_partitions() {
        let src_exec = cluster.cfg.executor_of(p);
        for &v in data.partition(p) {
            // lower-bound bucket search (binary, like RangePartitioner)
            let b = splitters.partition_point(|&s| s < v);
            buckets[b].push(v);
            let dst_exec = cluster.cfg.executor_of(b % cluster.cfg.partitions.max(1));
            if dst_exec != src_exec {
                moved_bytes += key_bytes;
            }
        }
    }

    let compute = start.elapsed().as_secs_f64();
    // Bucketing runs in parallel across executors; modelled as the
    // measured sequential scan divided evenly (each executor scans only
    // its own partitions).
    let parallel_compute =
        compute / cluster.cfg.executors as f64 * cluster.cfg.compute_scale;
    let net = cluster
        .cfg
        .net
        .shuffle_cost(cluster.cfg.executors, moved_bytes, data.len());
    cluster.clock.advance(parallel_compute + net);

    cluster.metrics.stage_boundaries += 1;
    cluster.metrics.shuffles += 1;
    cluster.metrics.bytes_shuffled += moved_bytes;
    cluster.metrics.messages += (cluster.cfg.executors * cluster.cfg.executors) as u64;

    Dataset::from_partitions(buckets).expect("one bucket per partition")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;

    fn cluster() -> Cluster {
        Cluster::new(ClusterConfig::local(2, 4))
    }

    #[test]
    fn routes_by_range_and_preserves_multiset() {
        let mut c = cluster();
        let data = Dataset::from_vec(vec![5, 1, 9, 3, 7, 2, 8, 4, 6, 0], 4).unwrap();
        let out = shuffle_by_range(&mut c, &data, &[3, 6]);
        assert_eq!(out.num_partitions(), 3);
        // bucket 0: <=3, bucket 1: (3,6], bucket 2: >6
        let mut b0 = out.partition(0).to_vec();
        b0.sort_unstable();
        assert_eq!(b0, vec![0, 1, 2, 3]);
        let mut b1 = out.partition(1).to_vec();
        b1.sort_unstable();
        assert_eq!(b1, vec![4, 5, 6]);
        let mut all = out.to_vec();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn counts_stage_boundary_and_shuffle() {
        let mut c = cluster();
        let data = Dataset::from_vec((0..100).collect(), 4).unwrap();
        shuffle_by_range(&mut c, &data, &[25, 50, 75]);
        assert_eq!(c.metrics.shuffles, 1);
        assert_eq!(c.metrics.stage_boundaries, 1);
        assert!(c.metrics.bytes_shuffled > 0);
        // shuffle alone does not end a round
        assert_eq!(c.metrics.rounds, 0);
    }

    #[test]
    fn empty_splitters_single_bucket() {
        let mut c = cluster();
        let data = Dataset::from_vec((0..10).collect(), 4).unwrap();
        let out = shuffle_by_range(&mut c, &data, &[]);
        assert_eq!(out.num_partitions(), 1);
        assert_eq!(out.len(), 10);
    }

    #[test]
    fn duplicate_heavy_input_survives() {
        let mut c = cluster();
        let data = Dataset::from_vec(vec![7; 1000], 4).unwrap();
        let out = shuffle_by_range(&mut c, &data, &[3, 7, 11]);
        assert_eq!(out.len(), 1000);
        // all 7s land in bucket with upper bound 7 (lower-bound search: first splitter >= 7)
        assert_eq!(out.partition(1).len(), 1000);
    }
}
