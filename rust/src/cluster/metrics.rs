//! Run metrics: the counters behind the paper's Table V.
//!
//! Every synchronization and byte the substrate moves is tallied here, so
//! `repro bench table5` can print *measured* rounds / shuffles / persists /
//! network volume per algorithm instead of asymptotic claims.

use crate::obs::stats::stage_stats;
use crate::obs::StageStats;

/// Raw counters accumulated by the substrate during one run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunMetrics {
    /// Driver synchronization barriers (BSP supersteps).
    pub rounds: u64,
    /// Shuffle/collect points where all executors must quiesce.
    pub stage_boundaries: u64,
    /// Linear passes over a dataset's partitions (`mapPartitions`
    /// stages). Rounds count synchronizations; this counts *reads of the
    /// data* — the fused GK Select path drops post-sketch scans from 2
    /// to 1 while keeping rounds ≤ 2, and only this counter can see it.
    pub data_scans: u64,
    /// Full range-partition shuffles.
    pub shuffles: u64,
    /// Explicit persists of intermediate datasets.
    pub persists: u64,
    /// Bytes funneled into the driver (collects + treeReduce roots).
    pub bytes_to_driver: u64,
    /// Bytes moved by range-partition shuffles.
    pub bytes_shuffled: u64,
    /// Bytes moved between executors inside treeReduce levels.
    pub bytes_tree_reduced: u64,
    /// Bytes fanned out by TorrentBroadcast (payload × receivers).
    pub bytes_broadcast: u64,
    /// Bytes written by persists.
    pub bytes_persisted: u64,
    /// Messages sent on the fabric.
    pub messages: u64,
    /// Modelled driver-side compute seconds.
    pub driver_compute_secs: f64,
    /// treeReduce merge levels actually executed (pairwise: ⌈log₂ P⌉ per
    /// reduce; Spark-style `depth` overrides squash this — the only
    /// counter that can tell the two tree shapes apart, since total
    /// messages are `P − 1` either way).
    pub tree_levels: u64,
    /// Real wall-clock seconds of each `map_partitions` stage, in
    /// execution order: the parallel elapsed time under
    /// `ExecMode::Threads`, the single-core elapsed time sequentially.
    /// Real time, not the virtual clock — the two are compared, never
    /// mixed.
    pub stage_walls: Vec<f64>,
    /// Σ `stage_walls`.
    pub wall_stage_secs: f64,
    /// Real seconds each executor spent inside partition closures,
    /// accumulated across stages and indexed by executor — the
    /// utilization / skew ledger.
    pub executor_busy_secs: Vec<f64>,
    /// Modelled per-task durations (µs) of each `map_partitions` stage,
    /// one inner vector per stage in execution order — the raw input of
    /// the [`StageStats`] latency sketches. Virtual-clock µs, so the
    /// values are deterministic and mode-independent.
    pub stage_attempt_us: Vec<Vec<u32>>,
    /// Injected faults that actually fired (panics, transients,
    /// executor losses, stragglers — real caught panics don't count).
    pub faults_injected: u64,
    /// Task re-launches after a failed attempt (real or injected).
    pub tasks_retried: u64,
    /// Speculative duplicates launched against stragglers.
    pub speculative_launched: u64,
    /// Speculative duplicates that finished before the straggler.
    pub speculative_wins: u64,
    /// Engine queries answered from the sketch after a stage failure
    /// (`DegradePolicy::SketchAnswer`); incremented by the engine, not
    /// the substrate.
    pub degraded_queries: u64,
    /// Band candidates actually shipped to the driver by GK Select's
    /// fused band extract (Σ over band-extract scans). Together with
    /// [`Self::band_budget`] this makes the paper's no-full-shuffle
    /// claim observable: shipped / budget ≤ 1.0 always, because the
    /// extract truncates at the budget.
    pub band_candidates: u64,
    /// Σ of the 16εn+64 candidate budgets those extracts ran under
    /// (`default_candidate_budget`, or the caller's explicit override).
    pub band_budget: u64,
}

impl RunMetrics {
    /// Bytes that crossed the network fabric — driver collects, shuffles,
    /// treeReduce hops, and broadcasts. Deliberately **excludes**
    /// [`Self::bytes_persisted`]: persists are local storage writes, not
    /// traffic, and the paper's Table V "Network volume" column counts
    /// movement only. Use [`Self::bytes_total`] when the storage ledger
    /// must be included.
    pub fn bytes_moved(&self) -> u64 {
        self.bytes_to_driver + self.bytes_shuffled + self.bytes_tree_reduced + self.bytes_broadcast
    }

    /// Every byte the substrate touched on behalf of the run:
    /// [`Self::bytes_moved`] plus [`Self::bytes_persisted`] — the
    /// all-five-ledgers total the metrics registry accumulates.
    pub fn bytes_total(&self) -> u64 {
        self.bytes_moved() + self.bytes_persisted
    }

    /// Take a per-operation snapshot marker at the ledger's current
    /// position. O(E): copies the scalar counters and the (bounded,
    /// per-executor) busy ledger, but **not** the ever-growing
    /// `stage_walls` vector — on a long-lived streaming cluster that
    /// vector grows with every ingest/query for the process lifetime,
    /// and cloning it per operation would be quadratic in total.
    pub fn mark(&self) -> MetricsMark {
        MetricsMark {
            rounds: self.rounds,
            stage_boundaries: self.stage_boundaries,
            data_scans: self.data_scans,
            shuffles: self.shuffles,
            persists: self.persists,
            bytes_to_driver: self.bytes_to_driver,
            bytes_shuffled: self.bytes_shuffled,
            bytes_tree_reduced: self.bytes_tree_reduced,
            bytes_broadcast: self.bytes_broadcast,
            bytes_persisted: self.bytes_persisted,
            messages: self.messages,
            driver_compute_secs: self.driver_compute_secs,
            tree_levels: self.tree_levels,
            stage_walls_len: self.stage_walls.len(),
            stage_attempt_us_len: self.stage_attempt_us.len(),
            wall_stage_secs: self.wall_stage_secs,
            executor_busy_secs: self.executor_busy_secs.clone(),
            faults_injected: self.faults_injected,
            tasks_retried: self.tasks_retried,
            speculative_launched: self.speculative_launched,
            speculative_wins: self.speculative_wins,
            degraded_queries: self.degraded_queries,
            band_candidates: self.band_candidates,
            band_budget: self.band_budget,
        }
    }

    /// Per-operation snapshot delta: the counters accumulated since
    /// `base` was [`RunMetrics::mark`]ed off the live ledger. The
    /// streaming service interleaves ingests and queries on one
    /// long-lived cluster, so a single operation's cost is the
    /// difference between two marks — `reset_run` would wipe the ingest
    /// ledger mid-stream.
    ///
    /// `base` must be an earlier mark of the *same* run: counters are
    /// monotone, `stage_walls` of the delta is the suffix of stages run
    /// since, and `executor_busy_secs` subtracts elementwise.
    pub fn since(&self, base: &MetricsMark) -> RunMetrics {
        debug_assert!(self.rounds >= base.rounds, "mark from a different run");
        debug_assert!(self.stage_walls.len() >= base.stage_walls_len);
        RunMetrics {
            rounds: self.rounds - base.rounds,
            stage_boundaries: self.stage_boundaries - base.stage_boundaries,
            data_scans: self.data_scans - base.data_scans,
            shuffles: self.shuffles - base.shuffles,
            persists: self.persists - base.persists,
            bytes_to_driver: self.bytes_to_driver - base.bytes_to_driver,
            bytes_shuffled: self.bytes_shuffled - base.bytes_shuffled,
            bytes_tree_reduced: self.bytes_tree_reduced - base.bytes_tree_reduced,
            bytes_broadcast: self.bytes_broadcast - base.bytes_broadcast,
            bytes_persisted: self.bytes_persisted - base.bytes_persisted,
            messages: self.messages - base.messages,
            driver_compute_secs: self.driver_compute_secs - base.driver_compute_secs,
            tree_levels: self.tree_levels - base.tree_levels,
            stage_walls: self.stage_walls[base.stage_walls_len..].to_vec(),
            stage_attempt_us: self.stage_attempt_us[base.stage_attempt_us_len..].to_vec(),
            wall_stage_secs: self.wall_stage_secs - base.wall_stage_secs,
            executor_busy_secs: self
                .executor_busy_secs
                .iter()
                .enumerate()
                .map(|(e, &busy)| busy - base.executor_busy_secs.get(e).copied().unwrap_or(0.0))
                .collect(),
            faults_injected: self.faults_injected - base.faults_injected,
            tasks_retried: self.tasks_retried - base.tasks_retried,
            speculative_launched: self.speculative_launched - base.speculative_launched,
            speculative_wins: self.speculative_wins - base.speculative_wins,
            degraded_queries: self.degraded_queries - base.degraded_queries,
            band_candidates: self.band_candidates - base.band_candidates,
            band_budget: self.band_budget - base.band_budget,
        }
    }

    /// Fraction of available executor-seconds spent computing across the
    /// run's `map_partitions` stages: Σ busy / (E × Σ wall). 0.0 before
    /// any stage ran. Only meaningful under `ExecMode::Threads` (the
    /// sequential path's wall is the serialized sum, so utilization reads
    /// as ≈ 1/E there).
    pub fn executor_utilization(&self) -> f64 {
        let denom = self.executor_busy_secs.len() as f64 * self.wall_stage_secs;
        if denom <= 0.0 {
            return 0.0;
        }
        self.executor_busy_secs.iter().sum::<f64>() / denom
    }

    /// Busy-time skew: max executor busy time over the mean (1.0 =
    /// perfectly balanced, larger = stragglers). 0.0 before any stage ran.
    pub fn busy_skew(&self) -> f64 {
        if self.executor_busy_secs.is_empty() {
            return 0.0;
        }
        let sum: f64 = self.executor_busy_secs.iter().sum();
        if sum <= 0.0 {
            return 0.0;
        }
        let mean = sum / self.executor_busy_secs.len() as f64;
        let max = self.executor_busy_secs.iter().fold(0.0_f64, |a, &b| a.max(b));
        max / mean
    }
}

/// Position marker into a live [`RunMetrics`] ledger (see
/// [`RunMetrics::mark`]): every scalar counter by value, the walls only
/// by length.
#[derive(Debug, Clone)]
pub struct MetricsMark {
    rounds: u64,
    stage_boundaries: u64,
    data_scans: u64,
    shuffles: u64,
    persists: u64,
    bytes_to_driver: u64,
    bytes_shuffled: u64,
    bytes_tree_reduced: u64,
    bytes_broadcast: u64,
    bytes_persisted: u64,
    messages: u64,
    driver_compute_secs: f64,
    tree_levels: u64,
    stage_walls_len: usize,
    stage_attempt_us_len: usize,
    wall_stage_secs: f64,
    executor_busy_secs: Vec<f64>,
    faults_injected: u64,
    tasks_retried: u64,
    speculative_launched: u64,
    speculative_wins: u64,
    degraded_queries: u64,
    band_candidates: u64,
    band_budget: u64,
}

/// One algorithm's end-of-run report: metrics + modelled elapsed time.
#[derive(Debug, Clone)]
pub struct MetricsReport {
    pub algorithm: String,
    pub n: u64,
    pub partitions: usize,
    pub executors: usize,
    pub elapsed_secs: f64,
    pub rounds: u64,
    pub stage_boundaries: u64,
    pub data_scans: u64,
    pub shuffles: u64,
    pub persists: u64,
    /// Network traffic only — [`RunMetrics::bytes_moved`]; excludes
    /// `bytes_persisted` (see [`Self::bytes_total`]).
    pub network_volume_bytes: u64,
    pub bytes_to_driver: u64,
    pub bytes_shuffled: u64,
    pub bytes_tree_reduced: u64,
    pub bytes_broadcast: u64,
    /// Bytes written by persists — storage, not traffic; the fifth
    /// ledger, carried separately so the registry never conflates the
    /// two (see [`RunMetrics::bytes_moved`] vs
    /// [`RunMetrics::bytes_total`]).
    pub bytes_persisted: u64,
    pub messages: u64,
    pub tree_levels: u64,
    /// Real wall-clock per `map_partitions` stage (see
    /// [`RunMetrics::stage_walls`]).
    pub stage_walls: Vec<f64>,
    /// Σ `stage_walls` — the run's real parallel elapsed stage time under
    /// `ExecMode::Threads`.
    pub wall_stage_secs: f64,
    /// Real per-executor busy seconds (utilization / skew ledger).
    pub executor_busy_secs: Vec<f64>,
    /// Per-stage task-latency summaries (p50/p95/p99/max, virtual-clock
    /// µs) sketched with our own GK core from
    /// [`RunMetrics::stage_attempt_us`] — one entry per
    /// `map_partitions` stage.
    pub stage_stats: Vec<StageStats>,
    /// The raw per-task durations behind `stage_stats`, one inner vector
    /// per stage. Carried on the report so the engine-lifetime
    /// [`crate::obs::registry::MetricsRegistry`] can fold true samples
    /// into its per-kind latency sketches instead of re-sketching
    /// percentiles of percentiles.
    pub stage_attempt_us: Vec<Vec<u32>>,
    /// Σ busy / (E × Σ wall), from [`RunMetrics::executor_utilization`].
    pub executor_utilization: f64,
    /// max busy / mean busy, from [`RunMetrics::busy_skew`].
    pub busy_skew: f64,
    /// Keys per vector of the kernel backend's band-scan tile (8 = AVX2,
    /// 4 = SSE2, 1 = scalar or a non-SIMD backend) — so every recorded
    /// wall time says which dispatch produced it. Algorithms that own a
    /// kernel backend stamp this via [`Self::with_simd_lane_width`];
    /// default 1.
    pub simd_lane_width: u64,
    /// Injected faults that fired during the run.
    pub faults_injected: u64,
    /// Task re-launches after failed attempts.
    pub tasks_retried: u64,
    /// Speculative duplicates launched against stragglers.
    pub speculative_launched: u64,
    /// Speculative duplicates that won.
    pub speculative_wins: u64,
    /// Queries answered from the sketch after a stage failure.
    pub degraded_queries: u64,
    /// Band candidates shipped by the run's band-extract scans.
    pub band_candidates: u64,
    /// Σ candidate budgets (16εn+64 bound) those scans ran under.
    pub band_budget: u64,
    pub exact: bool,
}

impl MetricsReport {
    pub fn from_metrics(
        algorithm: &str,
        n: u64,
        partitions: usize,
        executors: usize,
        elapsed_secs: f64,
        m: &RunMetrics,
        exact: bool,
    ) -> Self {
        Self {
            algorithm: algorithm.to_string(),
            n,
            partitions,
            executors,
            elapsed_secs,
            rounds: m.rounds,
            stage_boundaries: m.stage_boundaries,
            data_scans: m.data_scans,
            shuffles: m.shuffles,
            persists: m.persists,
            network_volume_bytes: m.bytes_moved(),
            bytes_to_driver: m.bytes_to_driver,
            bytes_shuffled: m.bytes_shuffled,
            bytes_tree_reduced: m.bytes_tree_reduced,
            bytes_broadcast: m.bytes_broadcast,
            bytes_persisted: m.bytes_persisted,
            messages: m.messages,
            tree_levels: m.tree_levels,
            stage_walls: m.stage_walls.clone(),
            wall_stage_secs: m.wall_stage_secs,
            executor_busy_secs: m.executor_busy_secs.clone(),
            stage_stats: stage_stats(&m.stage_attempt_us),
            stage_attempt_us: m.stage_attempt_us.clone(),
            executor_utilization: m.executor_utilization(),
            busy_skew: m.busy_skew(),
            simd_lane_width: 1,
            faults_injected: m.faults_injected,
            tasks_retried: m.tasks_retried,
            speculative_launched: m.speculative_launched,
            speculative_wins: m.speculative_wins,
            degraded_queries: m.degraded_queries,
            band_candidates: m.band_candidates,
            band_budget: m.band_budget,
            exact,
        }
    }

    /// Network traffic plus the persist ledger —
    /// [`RunMetrics::bytes_total`] at report granularity. The registry
    /// accumulates this as `bytes_total`; `network_volume_bytes` stays
    /// the Table V movement-only column.
    pub fn bytes_total(&self) -> u64 {
        self.network_volume_bytes + self.bytes_persisted
    }

    /// Band efficiency: candidates actually shipped over the 16εn+64
    /// budget they were allowed — the paper's no-full-shuffle claim as
    /// a ratio. Structurally ≤ 1.0 (the extract truncates at the
    /// budget); 0.0 when the run performed no band extract.
    pub fn band_efficiency(&self) -> f64 {
        if self.band_budget == 0 {
            0.0
        } else {
            self.band_candidates as f64 / self.band_budget as f64
        }
    }

    /// Stamp the kernel backend's active SIMD lane width onto the
    /// report (builder-style; the engine is the one caller).
    pub fn with_simd_lane_width(mut self, lanes: usize) -> Self {
        self.simd_lane_width = lanes as u64;
        self
    }

    /// Fold another report's ledgers into this one — the aggregate cost
    /// of a query batch answered by consecutive runs (the engine's
    /// `Multi` plan on strategies without a native batched scan).
    /// Counters and clocks sum; the real-time ledgers concatenate /
    /// add elementwise and the derived ratios are recomputed; `exact`
    /// stays true only if every constituent run was exact.
    pub fn absorb(&mut self, other: &MetricsReport) {
        self.elapsed_secs += other.elapsed_secs;
        self.rounds += other.rounds;
        self.stage_boundaries += other.stage_boundaries;
        self.data_scans += other.data_scans;
        self.shuffles += other.shuffles;
        self.persists += other.persists;
        self.network_volume_bytes += other.network_volume_bytes;
        self.bytes_to_driver += other.bytes_to_driver;
        self.bytes_shuffled += other.bytes_shuffled;
        self.bytes_tree_reduced += other.bytes_tree_reduced;
        self.bytes_broadcast += other.bytes_broadcast;
        self.bytes_persisted += other.bytes_persisted;
        self.messages += other.messages;
        self.tree_levels += other.tree_levels;
        self.faults_injected += other.faults_injected;
        self.tasks_retried += other.tasks_retried;
        self.speculative_launched += other.speculative_launched;
        self.speculative_wins += other.speculative_wins;
        self.degraded_queries += other.degraded_queries;
        self.band_candidates += other.band_candidates;
        self.band_budget += other.band_budget;
        self.stage_walls.extend_from_slice(&other.stage_walls);
        // concatenate stage stats, renumbering the absorbed run's stages
        // to follow this one's
        let offset = self.stage_stats.len() as u64;
        self.stage_stats.extend(other.stage_stats.iter().map(|s| StageStats {
            stage: offset + s.stage,
            ..*s
        }));
        self.stage_attempt_us
            .extend(other.stage_attempt_us.iter().cloned());
        self.wall_stage_secs += other.wall_stage_secs;
        for (i, &busy) in other.executor_busy_secs.iter().enumerate() {
            if i < self.executor_busy_secs.len() {
                self.executor_busy_secs[i] += busy;
            } else {
                self.executor_busy_secs.push(busy);
            }
        }
        let busy_total: f64 = self.executor_busy_secs.iter().sum();
        let denom = self.executor_busy_secs.len() as f64 * self.wall_stage_secs;
        self.executor_utilization = if denom > 0.0 { busy_total / denom } else { 0.0 };
        self.busy_skew = if self.executor_busy_secs.is_empty() || busy_total <= 0.0 {
            0.0
        } else {
            let mean = busy_total / self.executor_busy_secs.len() as f64;
            let max = self
                .executor_busy_secs
                .iter()
                .fold(0.0_f64, |a, &b| a.max(b));
            max / mean
        };
        self.exact = self.exact && other.exact;
    }

    /// One row in the Table V layout.
    pub fn table5_row(&self) -> String {
        format!(
            "{:<16} {:>14} {:>8} {:>7} {:>8} {:>10}",
            self.algorithm,
            human_bytes(self.network_volume_bytes),
            self.shuffles,
            self.rounds,
            self.persists,
            if self.exact { "Exact" } else { "Approx." },
        )
    }

    pub fn table5_header() -> String {
        format!(
            "{:<16} {:>14} {:>8} {:>7} {:>8} {:>10}",
            "Algorithm", "Net volume", "Shuffles", "Rounds", "Persists", "E/A"
        )
    }
}

/// Human-readable byte count (reporting only).
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_moved_excludes_persists_and_bytes_total_includes_them() {
        let m = RunMetrics {
            bytes_to_driver: 10,
            bytes_shuffled: 20,
            bytes_tree_reduced: 30,
            bytes_broadcast: 40,
            bytes_persisted: 7,
            ..Default::default()
        };
        // movement only: the four network ledgers, never the persist one
        assert_eq!(m.bytes_moved(), 100);
        // the all-five total the registry accumulates
        assert_eq!(m.bytes_total(), 107);
        let r = MetricsReport::from_metrics("GK Select", 100, 4, 2, 0.5, &m, true);
        assert_eq!(r.network_volume_bytes, 100, "Table V column = movement");
        assert_eq!(r.bytes_tree_reduced, 30);
        assert_eq!(r.bytes_persisted, 7);
        assert_eq!(r.bytes_total(), 107);
    }

    #[test]
    fn band_counters_flow_through_marks_reports_and_absorb() {
        let m = RunMetrics {
            band_candidates: 120,
            band_budget: 200,
            ..Default::default()
        };
        let d = m.since(&RunMetrics::default().mark());
        assert_eq!(d.band_candidates, 120);
        assert_eq!(d.band_budget, 200);
        let mut r = MetricsReport::from_metrics("GK Select", 100, 4, 2, 0.5, &m, true);
        assert_eq!(r.band_candidates, 120);
        assert!((r.band_efficiency() - 0.6).abs() < 1e-12);
        let other = MetricsReport::from_metrics("GK Select", 100, 4, 2, 0.5, &m, true);
        r.absorb(&other);
        assert_eq!(r.band_candidates, 240);
        assert_eq!(r.band_budget, 400);
        assert!((r.band_efficiency() - 0.6).abs() < 1e-12);
        // no band extract ran: the ratio degrades to 0, never NaN
        let empty = MetricsReport::from_metrics("sort", 0, 1, 1, 0.0, &RunMetrics::default(), true);
        assert_eq!(empty.band_efficiency(), 0.0);
        // a fresh mark zeroes the delta
        let z = m.since(&m.mark());
        assert_eq!(z.band_candidates, 0);
        assert_eq!(z.band_budget, 0);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn report_carries_data_scans() {
        let m = RunMetrics {
            data_scans: 2,
            ..Default::default()
        };
        let r = MetricsReport::from_metrics("GK Select", 100, 4, 2, 0.5, &m, true);
        assert_eq!(r.data_scans, 2);
    }

    #[test]
    fn utilization_and_skew_arithmetic() {
        let m = RunMetrics {
            wall_stage_secs: 2.0,
            stage_walls: vec![2.0],
            executor_busy_secs: vec![2.0, 1.0],
            ..Default::default()
        };
        // 3 busy seconds over 2 executors × 2 wall seconds
        assert!((m.executor_utilization() - 0.75).abs() < 1e-12);
        // max 2.0 over mean 1.5
        assert!((m.busy_skew() - 4.0 / 3.0).abs() < 1e-12);
        // empty ledger: both degrade to 0
        let empty = RunMetrics::default();
        assert_eq!(empty.executor_utilization(), 0.0);
        assert_eq!(empty.busy_skew(), 0.0);
    }

    #[test]
    fn report_carries_real_time_ledgers() {
        let m = RunMetrics {
            tree_levels: 3,
            wall_stage_secs: 1.0,
            stage_walls: vec![0.25, 0.75],
            executor_busy_secs: vec![0.5, 0.5],
            ..Default::default()
        };
        let r = MetricsReport::from_metrics("GK Select", 100, 4, 2, 0.5, &m, true);
        assert_eq!(r.tree_levels, 3);
        assert_eq!(r.stage_walls, vec![0.25, 0.75]);
        assert_eq!(r.wall_stage_secs, 1.0);
        assert_eq!(r.executor_busy_secs.len(), 2);
        assert!((r.executor_utilization - 0.5).abs() < 1e-12);
        assert!((r.busy_skew - 1.0).abs() < 1e-12);
    }

    #[test]
    fn since_subtracts_counters_and_slices_walls() {
        let start = RunMetrics {
            rounds: 2,
            data_scans: 3,
            bytes_to_driver: 100,
            messages: 10,
            driver_compute_secs: 0.5,
            stage_walls: vec![0.1, 0.2, 0.3],
            wall_stage_secs: 0.6,
            executor_busy_secs: vec![0.3, 0.3],
            ..Default::default()
        };
        let base = start.mark();
        let mut now = start.clone();
        now.rounds = 3;
        now.data_scans = 4;
        now.bytes_to_driver = 150;
        now.messages = 14;
        now.driver_compute_secs = 0.75;
        now.stage_walls.push(0.4);
        now.wall_stage_secs = 1.0;
        now.executor_busy_secs = vec![0.5, 0.4, 0.1];
        let d = now.since(&base);
        assert_eq!(d.rounds, 1);
        assert_eq!(d.data_scans, 1);
        assert_eq!(d.bytes_to_driver, 50);
        assert_eq!(d.messages, 4);
        assert!((d.driver_compute_secs - 0.25).abs() < 1e-12);
        assert_eq!(d.stage_walls, vec![0.4]);
        assert!((d.wall_stage_secs - 0.4).abs() < 1e-12);
        // elementwise; executors first seen after the snapshot keep full time
        assert_eq!(d.executor_busy_secs.len(), 3);
        assert!((d.executor_busy_secs[0] - 0.2).abs() < 1e-12);
        assert!((d.executor_busy_secs[2] - 0.1).abs() < 1e-12);
        // delta of a ledger against its own fresh mark is all-zero
        let z = now.since(&now.mark());
        assert_eq!(z.rounds, 0);
        assert!(z.stage_walls.is_empty());
    }

    #[test]
    fn absorb_sums_counters_and_recomputes_ratios() {
        let m = RunMetrics {
            rounds: 2,
            data_scans: 2,
            bytes_to_driver: 100,
            stage_walls: vec![1.0],
            wall_stage_secs: 1.0,
            executor_busy_secs: vec![1.0, 0.5],
            ..Default::default()
        };
        let mut a = MetricsReport::from_metrics("GK Select", 100, 4, 2, 0.5, &m, true);
        let b = MetricsReport::from_metrics("GK Select", 100, 4, 2, 0.25, &m, true);
        a.absorb(&b);
        assert_eq!(a.rounds, 4);
        assert_eq!(a.data_scans, 4);
        assert_eq!(a.bytes_to_driver, 200);
        assert!((a.elapsed_secs - 0.75).abs() < 1e-12);
        assert_eq!(a.stage_walls, vec![1.0, 1.0]);
        assert!((a.wall_stage_secs - 2.0).abs() < 1e-12);
        assert_eq!(a.executor_busy_secs, vec![2.0, 1.0]);
        // 3 busy seconds over 2 executors × 2 wall seconds
        assert!((a.executor_utilization - 0.75).abs() < 1e-12);
        assert!(a.exact);
        // one approximate constituent poisons exactness
        let approx = MetricsReport::from_metrics("GK Sketch", 100, 4, 2, 0.1, &m, false);
        a.absorb(&approx);
        assert!(!a.exact);
    }

    #[test]
    fn fault_counters_flow_through_marks_reports_and_absorb() {
        let m = RunMetrics {
            faults_injected: 4,
            tasks_retried: 3,
            speculative_launched: 2,
            speculative_wins: 1,
            degraded_queries: 1,
            ..Default::default()
        };
        let base = RunMetrics::default().mark();
        let d = m.since(&base);
        assert_eq!(d.faults_injected, 4);
        assert_eq!(d.tasks_retried, 3);
        let mut r = MetricsReport::from_metrics("GK Select", 100, 4, 2, 0.5, &m, true);
        assert_eq!(r.speculative_launched, 2);
        assert_eq!(r.speculative_wins, 1);
        assert_eq!(r.degraded_queries, 1);
        let other = MetricsReport::from_metrics("GK Select", 100, 4, 2, 0.5, &m, true);
        r.absorb(&other);
        assert_eq!(r.faults_injected, 8);
        assert_eq!(r.tasks_retried, 6);
        assert_eq!(r.degraded_queries, 2);
        // and a fresh mark zeroes the delta
        let z = m.since(&m.mark());
        assert_eq!(z.faults_injected, 0);
        assert_eq!(z.tasks_retried, 0);
    }

    #[test]
    fn stage_stats_flow_through_reports_since_and_absorb() {
        let m = RunMetrics {
            stage_attempt_us: vec![vec![100, 200], vec![300]],
            ..Default::default()
        };
        let mut r = MetricsReport::from_metrics("GK Select", 100, 4, 2, 0.5, &m, true);
        assert_eq!(r.stage_stats.len(), 2);
        assert_eq!(r.stage_stats[1].max_us, 300);
        // the raw samples ride the report for the registry's folds
        assert_eq!(r.stage_attempt_us, m.stage_attempt_us);
        let other = MetricsReport::from_metrics("GK Select", 100, 4, 2, 0.5, &m, true);
        r.absorb(&other);
        assert_eq!(r.stage_stats.len(), 4);
        assert_eq!(r.stage_stats[2].stage, 2, "absorbed stages renumber");
        assert_eq!(r.stage_attempt_us.len(), 4, "raw ledger concatenates too");
        // since() slices the per-stage suffix like stage_walls
        let base = m.mark();
        let mut now = m.clone();
        now.stage_attempt_us.push(vec![400]);
        let d = now.since(&base);
        assert_eq!(d.stage_attempt_us, vec![vec![400]]);
    }

    #[test]
    fn report_stamps_simd_lane_width() {
        let m = RunMetrics::default();
        let r = MetricsReport::from_metrics("GK Select", 100, 4, 2, 0.5, &m, true);
        assert_eq!(r.simd_lane_width, 1, "default is scalar");
        let r = r.with_simd_lane_width(8);
        assert_eq!(r.simd_lane_width, 8);
    }

    #[test]
    fn report_row_mentions_exactness() {
        let m = RunMetrics::default();
        let r = MetricsReport::from_metrics("GK Select", 100, 4, 2, 0.5, &m, true);
        assert!(r.table5_row().contains("Exact"));
        let r = MetricsReport::from_metrics("GK Sketch", 100, 4, 2, 0.5, &m, false);
        assert!(r.table5_row().contains("Approx."));
    }
}
