//! Run metrics: the counters behind the paper's Table V.
//!
//! Every synchronization and byte the substrate moves is tallied here, so
//! `repro bench table5` can print *measured* rounds / shuffles / persists /
//! network volume per algorithm instead of asymptotic claims.

/// Raw counters accumulated by the substrate during one run.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RunMetrics {
    /// Driver synchronization barriers (BSP supersteps).
    pub rounds: u64,
    /// Shuffle/collect points where all executors must quiesce.
    pub stage_boundaries: u64,
    /// Linear passes over a dataset's partitions (`mapPartitions`
    /// stages). Rounds count synchronizations; this counts *reads of the
    /// data* — the fused GK Select path drops post-sketch scans from 2
    /// to 1 while keeping rounds ≤ 2, and only this counter can see it.
    pub data_scans: u64,
    /// Full range-partition shuffles.
    pub shuffles: u64,
    /// Explicit persists of intermediate datasets.
    pub persists: u64,
    /// Bytes funneled into the driver (collects + treeReduce roots).
    pub bytes_to_driver: u64,
    /// Bytes moved by range-partition shuffles.
    pub bytes_shuffled: u64,
    /// Bytes moved between executors inside treeReduce levels.
    pub bytes_tree_reduced: u64,
    /// Bytes fanned out by TorrentBroadcast (payload × receivers).
    pub bytes_broadcast: u64,
    /// Bytes written by persists.
    pub bytes_persisted: u64,
    /// Messages sent on the fabric.
    pub messages: u64,
    /// Modelled driver-side compute seconds.
    pub driver_compute_secs: f64,
}

impl RunMetrics {
    /// Total network volume — the paper's Table V "Network volume" column.
    pub fn network_volume(&self) -> u64 {
        self.bytes_to_driver + self.bytes_shuffled + self.bytes_tree_reduced + self.bytes_broadcast
    }
}

/// One algorithm's end-of-run report: metrics + modelled elapsed time.
#[derive(Debug, Clone)]
pub struct MetricsReport {
    pub algorithm: String,
    pub n: u64,
    pub partitions: usize,
    pub executors: usize,
    pub elapsed_secs: f64,
    pub rounds: u64,
    pub stage_boundaries: u64,
    pub data_scans: u64,
    pub shuffles: u64,
    pub persists: u64,
    pub network_volume_bytes: u64,
    pub bytes_to_driver: u64,
    pub bytes_shuffled: u64,
    pub bytes_broadcast: u64,
    pub messages: u64,
    pub exact: bool,
}

impl MetricsReport {
    pub fn from_metrics(
        algorithm: &str,
        n: u64,
        partitions: usize,
        executors: usize,
        elapsed_secs: f64,
        m: &RunMetrics,
        exact: bool,
    ) -> Self {
        Self {
            algorithm: algorithm.to_string(),
            n,
            partitions,
            executors,
            elapsed_secs,
            rounds: m.rounds,
            stage_boundaries: m.stage_boundaries,
            data_scans: m.data_scans,
            shuffles: m.shuffles,
            persists: m.persists,
            network_volume_bytes: m.network_volume(),
            bytes_to_driver: m.bytes_to_driver,
            bytes_shuffled: m.bytes_shuffled,
            bytes_broadcast: m.bytes_broadcast,
            messages: m.messages,
            exact,
        }
    }

    /// One row in the Table V layout.
    pub fn table5_row(&self) -> String {
        format!(
            "{:<16} {:>14} {:>8} {:>7} {:>8} {:>10}",
            self.algorithm,
            human_bytes(self.network_volume_bytes),
            self.shuffles,
            self.rounds,
            self.persists,
            if self.exact { "Exact" } else { "Approx." },
        )
    }

    pub fn table5_header() -> String {
        format!(
            "{:<16} {:>14} {:>8} {:>7} {:>8} {:>10}",
            "Algorithm", "Net volume", "Shuffles", "Rounds", "Persists", "E/A"
        )
    }
}

/// Human-readable byte count (reporting only).
pub fn human_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn network_volume_sums_components() {
        let m = RunMetrics {
            bytes_to_driver: 10,
            bytes_shuffled: 20,
            bytes_tree_reduced: 30,
            bytes_broadcast: 40,
            ..Default::default()
        };
        assert_eq!(m.network_volume(), 100);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn report_carries_data_scans() {
        let m = RunMetrics {
            data_scans: 2,
            ..Default::default()
        };
        let r = MetricsReport::from_metrics("GK Select", 100, 4, 2, 0.5, &m, true);
        assert_eq!(r.data_scans, 2);
    }

    #[test]
    fn report_row_mentions_exactness() {
        let m = RunMetrics::default();
        let r = MetricsReport::from_metrics("GK Select", 100, 4, 2, 0.5, &m, true);
        assert!(r.table5_row().contains("Exact"));
        let r = MetricsReport::from_metrics("GK Sketch", 100, 4, 2, 0.5, &m, false);
        assert!(r.table5_row().contains("Approx."));
    }
}
