//! Spark-like execution substrate with explicit round / stage-boundary
//! accounting and a calibrated cost model.
//!
//! The paper's analysis (§III) is phrased entirely in terms of:
//!
//! * **rounds** — units of parallel work bounded by a driver
//!   synchronization barrier (BSP supersteps / CGM rounds),
//! * **stage boundaries** — shuffle or collect points where no executor
//!   can proceed until all upstream writes finish,
//! * **network volume** — bytes crossing the cluster fabric,
//! * **per-partition executor work**.
//!
//! This module reproduces those semantics in-process. Every distributed
//! primitive the paper names is implemented with the same synchronization
//! shape as Spark's:
//!
//! | Spark                  | Here                         | round? | stage boundary? |
//! |------------------------|------------------------------|--------|-----------------|
//! | `mapPartitions`        | [`Cluster::map_partitions`]  | no (lazy) | no           |
//! | `collect`              | [`Cluster::collect`]         | yes    | yes             |
//! | `reduce`               | [`Cluster::reduce`]          | yes    | yes             |
//! | `treeReduce`           | [`Cluster::tree_reduce`]     | yes    | yes             |
//! | `TorrentBroadcast`     | [`Cluster::broadcast`]       | no     | no              |
//! | range-partition shuffle| [`shuffle::shuffle_by_range`]| no     | yes             |
//! | `persist`              | [`dataset::Dataset::persist`]| no     | no              |
//!
//! ## Timing model
//!
//! The box running this reproduction has one core, so real parallel
//! speed-up cannot materialize locally. Instead the substrate runs every
//! partition closure sequentially, *measures* its wall time, and charges a
//! **virtual clock** with the parallel elapsed time: the max over
//! executors of the sum of their partitions' measured times, plus the
//! network model's cost for the messages actually sent. This keeps
//! compute costs honest (they come from real execution over real data)
//! while modelling an EMR-like cluster's parallelism and fabric — the
//! substitution DESIGN.md §2 documents.

pub mod dataset;
pub mod metrics;
pub mod netmodel;
pub mod shuffle;
pub mod simclock;

use std::time::Instant;

use dataset::Dataset;
use metrics::RunMetrics;
use netmodel::{NetSize, NetworkModel};
use simclock::SimClock;

/// Static description of the simulated cluster.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of executor processes (the paper's "core nodes" × executors
    /// per node; EMR m5.xlarge runs one 4-core executor per node).
    pub executors: usize,
    /// Number of data partitions (paper: 4 × core nodes).
    pub partitions: usize,
    /// Fabric model used to price messages.
    pub net: NetworkModel,
    /// Multiplier applied to measured closure time before charging the
    /// virtual clock: maps this box's core to the reference core
    /// (m5.xlarge vCPU). Calibrated by `repro calibrate`; 1.0 = this box.
    pub compute_scale: f64,
    /// Multiplier applied to driver-side measured time (driver nodes are
    /// often less endowed than executors — paper §V-6).
    pub driver_scale: f64,
}

impl ClusterConfig {
    /// A local test cluster with a zero-cost network (pure wall-clock
    /// semantics; rounds and volumes are still counted).
    pub fn local(executors: usize, partitions: usize) -> Self {
        Self {
            executors,
            partitions,
            net: NetworkModel::zero(),
            compute_scale: 1.0,
            driver_scale: 1.0,
        }
    }

    /// An EMR-like cluster: `nodes` m5.xlarge core nodes, 4 partitions per
    /// node, 10 Gbit fabric with 200 µs message latency (the paper's
    /// testbed shape).
    pub fn emr(nodes: usize) -> Self {
        Self {
            executors: nodes,
            partitions: nodes * 4,
            net: NetworkModel::emr_like(),
            compute_scale: 1.0,
            driver_scale: 1.0,
        }
    }

    /// Executor index owning partition `p` (Spark-style round-robin
    /// locality).
    pub fn executor_of(&self, p: usize) -> usize {
        p % self.executors
    }
}

/// Per-partition results of a `mapPartitions`, pending an action.
///
/// Carries the measured compute time of each partition closure so the
/// consuming action can charge the virtual clock with the *parallel*
/// elapsed time of the stage.
#[derive(Debug)]
pub struct PerPartition<R> {
    pub values: Vec<R>,
    /// Seconds of measured compute per partition.
    times: Vec<f64>,
}

impl<R> PerPartition<R> {
    /// Map the carried values without touching the time ledger (driver-side
    /// relabeling, free in the model).
    pub fn map_values<S>(self, f: impl FnMut(R) -> S) -> PerPartition<S> {
        PerPartition {
            values: self.values.into_iter().map(f).collect(),
            times: self.times,
        }
    }
}

impl<A, B> PerPartition<(A, B)> {
    /// Split a pair-producing stage into two pendings. The measured
    /// compute time rides with the **first** half (charge once: the
    /// second half stays executor-resident, e.g. AFS's retained
    /// partitions while only counts travel).
    pub fn unzip(self) -> (PerPartition<A>, PerPartition<B>) {
        let (a, b): (Vec<A>, Vec<B>) = self.values.into_iter().unzip();
        let zero = vec![0.0; a.len()];
        (
            PerPartition {
                values: a,
                times: self.times,
            },
            PerPartition {
                values: b,
                times: zero,
            },
        )
    }
}

/// Context handed to every partition closure.
#[derive(Debug, Clone, Copy)]
pub struct PartitionCtx {
    /// Partition index within the dataset.
    pub partition: usize,
    /// Executor that owns this partition.
    pub executor: usize,
    /// Total number of partitions.
    pub num_partitions: usize,
}

/// The simulated cluster: driver + executors + fabric + clocks.
pub struct Cluster {
    pub cfg: ClusterConfig,
    pub clock: SimClock,
    pub metrics: RunMetrics,
}

impl Cluster {
    pub fn new(cfg: ClusterConfig) -> Self {
        assert!(cfg.executors > 0, "cluster needs at least one executor");
        assert!(
            cfg.partitions >= cfg.executors,
            "need at least one partition per executor"
        );
        Self {
            cfg,
            clock: SimClock::new(),
            metrics: RunMetrics::default(),
        }
    }

    /// Reset clocks and metrics between trials (data stays put).
    pub fn reset_run(&mut self) {
        self.clock = SimClock::new();
        self.metrics = RunMetrics::default();
    }

    /// Lazily-scheduled narrow transformation: run `f` over every
    /// partition, measuring compute time per partition. No round, no
    /// stage boundary — those are charged by the consuming action, like
    /// Spark's lazy evaluation.
    pub fn map_partitions<T, R>(
        &mut self,
        data: &Dataset<T>,
        mut f: impl FnMut(&[T], PartitionCtx) -> R,
    ) -> PerPartition<R> {
        let num_partitions = data.num_partitions();
        // one mapPartitions stage = one linear read of the dataset; the
        // consuming action charges the round, but the scan happens here
        self.metrics.data_scans += 1;
        let mut values = Vec::with_capacity(num_partitions);
        let mut times = Vec::with_capacity(num_partitions);
        for p in 0..num_partitions {
            let ctx = PartitionCtx {
                partition: p,
                executor: self.cfg.executor_of(p),
                num_partitions,
            };
            let start = Instant::now();
            values.push(f(data.partition(p), ctx));
            times.push(start.elapsed().as_secs_f64());
        }
        PerPartition { values, times }
    }

    /// Parallel elapsed time of a stage: max over executors of the summed
    /// measured times of their partitions, scaled to the reference core.
    fn stage_elapsed(&self, times: &[f64]) -> f64 {
        let mut per_exec = vec![0.0_f64; self.cfg.executors];
        for (p, t) in times.iter().enumerate() {
            per_exec[self.cfg.executor_of(p)] += t;
        }
        per_exec.into_iter().fold(0.0, f64::max) * self.cfg.compute_scale
    }

    /// `collect`: gather per-partition results at the driver. First stage
    /// boundary of the consuming job; ends a round.
    pub fn collect<R: NetSize>(&mut self, pending: PerPartition<R>) -> Vec<R> {
        let compute = self.stage_elapsed(&pending.times);
        let bytes: u64 = pending.values.iter().map(NetSize::net_bytes).sum();
        let net = self.cfg.net.collect_cost(self.cfg.executors, bytes);
        self.clock.advance(compute + net);
        self.metrics.rounds += 1;
        self.metrics.stage_boundaries += 1;
        self.metrics.bytes_to_driver += bytes;
        self.metrics.messages += self.cfg.partitions as u64;
        pending.values
    }

    /// `reduce`: collect-shaped aggregation (Spark's `RDD.reduce` ships
    /// partial results to the driver and folds there). Ends a round.
    pub fn reduce<R: NetSize>(
        &mut self,
        pending: PerPartition<R>,
        f: impl FnMut(R, R) -> R,
    ) -> Option<R> {
        let parts = self.collect(pending);
        let start = Instant::now();
        let out = parts.into_iter().reduce(f);
        self.charge_driver(start.elapsed().as_secs_f64());
        out
    }

    /// `treeReduce`: log-depth aggregation over the executors; only the
    /// final partial reaches the driver. Ends a round.
    ///
    /// `depth` overrides the tree depth (Spark defaults to 2; `None`
    /// computes ⌈log₂ P⌉ like the paper's `O(log P)` analysis).
    pub fn tree_reduce<R: NetSize>(
        &mut self,
        pending: PerPartition<R>,
        depth: Option<usize>,
        mut f: impl FnMut(R, R) -> R,
    ) -> Option<R> {
        let compute = self.stage_elapsed(&pending.times);
        self.clock.advance(compute);

        let mut level: Vec<R> = pending.values;
        if level.is_empty() {
            self.metrics.rounds += 1;
            self.metrics.stage_boundaries += 1;
            return None;
        }
        let natural_depth = (usize::BITS - (level.len().max(2) - 1).leading_zeros()) as usize;
        let _requested = depth.unwrap_or(natural_depth); // shape is pairwise either way

        // Pairwise merge level by level. Merges within a level run in
        // parallel across executors: charge max(merge time) + one message
        // exchange of the largest partial per level.
        while level.len() > 1 {
            let mut next = Vec::with_capacity(level.len().div_ceil(2));
            let mut level_compute = 0.0_f64;
            let mut level_max_bytes = 0_u64;
            let mut level_bytes = 0_u64;
            let mut it = level.into_iter();
            while let Some(a) = it.next() {
                match it.next() {
                    Some(b) => {
                        let moved = b.net_bytes();
                        level_bytes += moved;
                        level_max_bytes = level_max_bytes.max(moved);
                        let start = Instant::now();
                        let merged = f(a, b);
                        level_compute =
                            level_compute.max(start.elapsed().as_secs_f64());
                        next.push(merged);
                        self.metrics.messages += 1;
                    }
                    None => next.push(a),
                }
            }
            self.metrics.bytes_tree_reduced += level_bytes;
            self.clock.advance(
                level_compute * self.cfg.compute_scale
                    + self.cfg.net.message_cost(level_max_bytes),
            );
            level = next;
        }

        let root = level.pop();
        // Final partial lands on the driver.
        if let Some(ref r) = root {
            let bytes = r.net_bytes();
            self.metrics.bytes_to_driver += bytes;
            self.clock.advance(self.cfg.net.message_cost(bytes));
        }
        self.metrics.rounds += 1;
        self.metrics.stage_boundaries += 1;
        root
    }

    /// `TorrentBroadcast`: BitTorrent-style log-depth fan-out from the
    /// driver. Adds latency but **no** stage boundary and no round — the
    /// paper is explicit about this (§IV-B).
    pub fn broadcast<B: NetSize>(&mut self, value: &B) {
        let bytes = value.net_bytes();
        let hops = (usize::BITS - (self.cfg.executors.max(2) - 1).leading_zeros()) as u64;
        self.clock
            .advance(hops as f64 * self.cfg.net.message_cost(bytes));
        self.metrics.bytes_broadcast += bytes * self.cfg.executors as u64;
        self.metrics.messages += self.cfg.executors as u64;
    }

    /// Charge driver-side compute (merging sketches, folding counts, the
    /// final candidate scan) at the driver's calibrated speed.
    pub fn charge_driver(&mut self, measured_secs: f64) {
        self.clock.advance(measured_secs * self.cfg.driver_scale);
        self.metrics.driver_compute_secs += measured_secs * self.cfg.driver_scale;
    }

    /// Run a driver-side closure, measuring and charging its time.
    pub fn driver<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let start = Instant::now();
        let out = f();
        self.charge_driver(start.elapsed().as_secs_f64());
        out
    }

    /// Record a persist of `bytes` (AFS/Jeffers re-materialize the
    /// retained side every round; GK Select persists nothing — Table V).
    pub fn persist_bytes(&mut self, bytes: u64) {
        self.metrics.persists += 1;
        self.metrics.bytes_persisted += bytes;
    }

    /// Virtual elapsed seconds since the run started.
    pub fn elapsed_secs(&self) -> f64 {
        self.clock.elapsed_secs()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> (Cluster, Dataset<i32>) {
        let cluster = Cluster::new(ClusterConfig::local(2, 4));
        let data = Dataset::from_partitions(vec![
            vec![1, 2, 3],
            vec![4, 5],
            vec![6],
            vec![7, 8, 9, 10],
        ]);
        (cluster, data)
    }

    #[test]
    fn map_partitions_sees_every_partition() {
        let (mut c, d) = tiny();
        let lens = c.map_partitions(&d, |part, ctx| (ctx.partition, part.len()));
        assert_eq!(lens.values, vec![(0, 3), (1, 2), (2, 1), (3, 4)]);
        // lazy: no round yet, but the data was read once
        assert_eq!(c.metrics.rounds, 0);
        assert_eq!(c.metrics.data_scans, 1);
    }

    #[test]
    fn collect_ends_a_round_and_counts_bytes() {
        let (mut c, d) = tiny();
        let counts = c.map_partitions(&d, |part, _| part.len() as u64);
        let got = c.collect(counts);
        assert_eq!(got.iter().sum::<u64>(), 10);
        assert_eq!(c.metrics.rounds, 1);
        assert_eq!(c.metrics.stage_boundaries, 1);
        assert_eq!(c.metrics.bytes_to_driver, 4 * 8);
    }

    #[test]
    fn reduce_folds_on_driver() {
        let (mut c, d) = tiny();
        let sums = c.map_partitions(&d, |part, _| part.iter().map(|&x| x as i64).sum::<i64>());
        let total = c.reduce(sums, |a, b| a + b).unwrap();
        assert_eq!(total, 55);
        assert_eq!(c.metrics.rounds, 1);
    }

    #[test]
    fn tree_reduce_matches_reduce() {
        let (mut c, d) = tiny();
        let sums = c.map_partitions(&d, |part, _| part.iter().map(|&x| x as i64).sum::<i64>());
        let total = c.tree_reduce(sums, None, |a, b| a + b).unwrap();
        assert_eq!(total, 55);
        assert_eq!(c.metrics.rounds, 1);
        assert_eq!(c.metrics.stage_boundaries, 1);
        assert!(c.metrics.bytes_tree_reduced > 0);
    }

    #[test]
    fn tree_reduce_empty_is_none() {
        let mut c = Cluster::new(ClusterConfig::local(1, 1));
        let pending: PerPartition<i64> = PerPartition {
            values: vec![],
            times: vec![],
        };
        assert!(c.tree_reduce(pending, None, |a, b| a + b).is_none());
    }

    #[test]
    fn broadcast_adds_no_round() {
        let (mut c, _) = tiny();
        c.broadcast(&42_i64);
        assert_eq!(c.metrics.rounds, 0);
        assert_eq!(c.metrics.stage_boundaries, 0);
        assert_eq!(c.metrics.bytes_broadcast, 8 * 2);
    }

    #[test]
    fn executor_assignment_round_robin() {
        let cfg = ClusterConfig::local(3, 7);
        assert_eq!(cfg.executor_of(0), 0);
        assert_eq!(cfg.executor_of(4), 1);
        assert_eq!(cfg.executor_of(5), 2);
    }

    #[test]
    fn reset_run_clears_ledger() {
        let (mut c, d) = tiny();
        let xs = c.map_partitions(&d, |p, _| p.len() as u64);
        c.collect(xs);
        c.reset_run();
        assert_eq!(c.metrics.rounds, 0);
        assert_eq!(c.elapsed_secs(), 0.0);
    }

    #[test]
    #[should_panic]
    fn rejects_more_executors_than_partitions() {
        Cluster::new(ClusterConfig::local(8, 4));
    }
}
