//! Spark-like execution substrate with explicit round / stage-boundary
//! accounting and a calibrated cost model.
//!
//! The paper's analysis (§III) is phrased entirely in terms of:
//!
//! * **rounds** — units of parallel work bounded by a driver
//!   synchronization barrier (BSP supersteps / CGM rounds),
//! * **stage boundaries** — shuffle or collect points where no executor
//!   can proceed until all upstream writes finish,
//! * **network volume** — bytes crossing the cluster fabric,
//! * **per-partition executor work**.
//!
//! This module reproduces those semantics in-process. Every distributed
//! primitive the paper names is implemented with the same synchronization
//! shape as Spark's:
//!
//! | Spark                  | Here                         | round? | stage boundary? |
//! |------------------------|------------------------------|--------|-----------------|
//! | `mapPartitions`        | [`Cluster::map_partitions`]  | no (lazy) | no           |
//! | `collect`              | [`Cluster::collect`]         | yes    | yes             |
//! | `reduce`               | [`Cluster::reduce`]          | yes    | yes             |
//! | `treeReduce`           | [`Cluster::tree_reduce`]     | yes    | yes             |
//! | `TorrentBroadcast`     | [`Cluster::broadcast`]       | no     | no              |
//! | range-partition shuffle| [`shuffle::shuffle_by_range`]| no     | yes             |
//! | `persist`              | [`dataset::Dataset::persist`]| no     | no              |
//!
//! ## Timing model — two execution modes
//!
//! The substrate executes partition closures in one of two modes
//! ([`pool::ExecMode`], selected by `ClusterConfig::exec_mode`, the
//! `GKSELECT_EXEC_MODE` env var, or `[cluster] exec_mode` in the config):
//!
//! * **`Sequential`** (default) — every partition closure runs on the
//!   calling thread in partition order. Deterministic; what tests pin.
//! * **`Threads`** — the [`pool::ExecutorPool`] dispatches each partition
//!   to a scoped OS thread owned by its executor (one thread per
//!   simulated executor, partitions in round-robin locality order), so
//!   wall-clock tracks real parallelism and real contention.
//!
//! In **both** modes the **virtual clock stays authoritative**: each
//! closure's wall time is *measured* per partition and the clock is
//! charged with the modelled parallel elapsed time — the max over
//! executors of the sum of their partitions' measured times, plus the
//! network model's cost for the messages actually sent. This keeps
//! compute costs honest (they come from real execution over real data)
//! while modelling an EMR-like cluster's parallelism and fabric — the
//! substitution DESIGN.md §2 documents. Partition closures are therefore
//! required to be pure per partition (`Fn + Sync`): results, quantile
//! answers, and all round/scan/byte counters are bit-identical across
//! modes. The *numeric value* of the virtual clock is not: under
//! `Threads` the measured per-partition times include real scheduling
//! and contention (executors can outnumber cores), which is exactly what
//! the mode exists to expose — quote modelled figures from a
//! `Sequential` run and real wall-clock from a `Threads` run.
//!
//! What the modes *add* to [`metrics::RunMetrics`] is real-time
//! observability of each `mapPartitions` stage:
//!
//! | field                 | meaning                                          |
//! |-----------------------|--------------------------------------------------|
//! | `stage_walls`         | real wall-clock seconds, one entry per stage     |
//! | `wall_stage_secs`     | Σ `stage_walls` — real parallel elapsed (threads) or single-core elapsed (sequential) |
//! | `executor_busy_secs`  | real seconds each executor spent in closures     |
//! | `tree_levels`         | treeReduce merge levels actually executed        |
//!
//! `executor_busy_secs` against `stage_walls` gives utilization and skew
//! ([`metrics::RunMetrics::executor_utilization`] /
//! [`metrics::RunMetrics::busy_skew`]); under `Threads` the gap between
//! `wall_stage_secs` and the virtual clock's compute term is the real
//! scheduling + contention cost the sequential model cannot see.
//!
//! ## Failure semantics — retries, speculation, and the clock
//!
//! Every `map_partitions` task attempt runs under the fault model
//! ([`faults`]): a seeded [`faults::FaultPlan`] (from
//! `ClusterConfig::faults`, the `[faults]` config section, or the
//! `GKSELECT_FAULTS` env var) may inject panics, transient errors,
//! straggler slowdowns, or whole-executor loss; real closure panics are
//! caught by the same `catch_unwind` net. Recovery follows
//! [`faults::RetryPolicy`] and is charged to the virtual clock like so:
//!
//! * **Retry backoff** — each retry adds `backoff_secs` of re-launch
//!   latency. It is charged by `map_partitions` itself (immediately,
//!   additively, never overlapped with other executors' work): a
//!   retried task sits on the stage's critical path exactly like
//!   Spark's re-queued task. Failed attempts consume no modelled
//!   compute — injected faults kill the attempt before it runs, and a
//!   real panicked attempt's partial work is lost, not charged.
//! * **Stragglers** — an injected straggler multiplies the task's
//!   *measured* time by `mult` in the `times` ledger the consuming
//!   action charges (max-over-executors), leaving the real busy ledger
//!   untouched: slowdown is a model effect, observability stays real.
//! * **Speculative duplicates** — a straggler at ≥
//!   [`faults::SPECULATION_THRESHOLD`] with an idle executor available
//!   (`executors > 1`, `RetryPolicy::speculation`) launches a modelled
//!   duplicate once the task overruns its expected duration `dt`; the
//!   duplicate finishes at `2·dt`, so the charged time is
//!   `min(mult·dt, 2·dt)`. Results are pure, the first finisher wins,
//!   and values stay bit-identical — only time and counters move.
//! * **Retry exhaustion** — a task that fails more than
//!   `max_task_retries` times fails the whole stage with a typed
//!   [`faults::StageError`] (deterministically the lowest failing
//!   partition in both exec modes); `map_partitions` returns `Err` and
//!   the engine maps it to `EngineError::StageFailed` or degrades.
//!
//! The recovery tallies land in [`metrics::RunMetrics`] (and every
//! [`metrics::MetricsReport`]):
//!
//! | field                  | meaning                                       |
//! |------------------------|-----------------------------------------------|
//! | `faults_injected`      | injected faults that actually fired           |
//! | `tasks_retried`        | task re-launches after a (real or injected) failure |
//! | `speculative_launched` | speculative duplicates launched for stragglers |
//! | `speculative_wins`     | duplicates that beat the original             |
//! | `degraded_queries`     | engine queries answered from the sketch after a stage failure |
//!
//! Injection decisions are pure functions of
//! `(plan seed, stage, partition)` — never of thread timing — so
//! `Sequential` and `Threads` inject identically and stay bit-identical
//! in values and counters under any plan.

pub mod dataset;
pub mod faults;
pub mod metrics;
pub mod netmodel;
pub mod pool;
pub mod shuffle;
pub mod simclock;

use std::time::Instant;

use dataset::Dataset;
pub use faults::{FaultInjector, FaultPlan, RetryPolicy, StageError};
use faults::FaultContext;
use metrics::RunMetrics;
use netmodel::{NetSize, NetworkModel};
pub use pool::ExecMode;
use pool::ExecutorPool;
use simclock::SimClock;

use crate::obs::{SpanKind, Tracer};

/// Static description of the simulated cluster.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Number of executor processes (the paper's "core nodes" × executors
    /// per node; EMR m5.xlarge runs one 4-core executor per node).
    pub executors: usize,
    /// Number of data partitions (paper: 4 × core nodes).
    pub partitions: usize,
    /// Fabric model used to price messages.
    pub net: NetworkModel,
    /// Multiplier applied to measured closure time before charging the
    /// virtual clock: maps this box's core to the reference core
    /// (m5.xlarge vCPU). Calibrated by `repro calibrate`; 1.0 = this box.
    pub compute_scale: f64,
    /// Multiplier applied to driver-side measured time (driver nodes are
    /// often less endowed than executors — paper §V-6).
    pub driver_scale: f64,
    /// How `map_partitions` stages execute: sequentially on the calling
    /// thread (deterministic default) or on one OS thread per executor.
    /// Constructors honor the `GKSELECT_EXEC_MODE` env var so CI can run
    /// the whole suite under real concurrency.
    pub exec_mode: ExecMode,
    /// Seeded fault-injection schedule consulted on every task attempt.
    /// `None` disables the injector entirely; `Some` (even a no-op plan)
    /// keeps the hooks live so their overhead can be benchmarked.
    /// Constructors honor the `GKSELECT_FAULTS` env var so CI can run the
    /// whole suite under injection.
    pub faults: Option<FaultPlan>,
    /// Task retry / speculative-execution policy (Spark's
    /// `spark.task.maxFailures` + `spark.speculation` analogue).
    pub retry: RetryPolicy,
}

impl ClusterConfig {
    /// A local test cluster with a zero-cost network (pure wall-clock
    /// semantics; rounds and volumes are still counted).
    ///
    /// Honors `GKSELECT_EXEC_MODE` / `GKSELECT_FAULTS` quietly: an unset,
    /// empty, or unparsable var falls back to the default here, while the
    /// engine builder and CLI — which re-read the same vars through
    /// [`crate::engine::env`] — reject garbage loudly with a typed error.
    pub fn local(executors: usize, partitions: usize) -> Self {
        Self {
            executors,
            partitions,
            net: NetworkModel::zero(),
            compute_scale: 1.0,
            driver_scale: 1.0,
            exec_mode: env_exec_mode(),
            faults: env_fault_plan(),
            retry: RetryPolicy::default(),
        }
    }

    /// An EMR-like cluster: `nodes` m5.xlarge core nodes, 4 partitions per
    /// node, 10 Gbit fabric with 200 µs message latency (the paper's
    /// testbed shape). Same quiet env fallback as [`ClusterConfig::local`].
    pub fn emr(nodes: usize) -> Self {
        Self {
            executors: nodes,
            partitions: nodes * 4,
            net: NetworkModel::emr_like(),
            compute_scale: 1.0,
            driver_scale: 1.0,
            exec_mode: env_exec_mode(),
            faults: env_fault_plan(),
            retry: RetryPolicy::default(),
        }
    }

    /// Override the execution mode (builder-style).
    pub fn with_exec_mode(mut self, mode: ExecMode) -> Self {
        self.exec_mode = mode;
        self
    }

    /// Override the fault-injection schedule (builder-style). `None`
    /// removes the injector, including one picked up from the env.
    pub fn with_fault_plan(mut self, plan: Option<FaultPlan>) -> Self {
        self.faults = plan;
        self
    }

    /// Override the retry / speculation policy (builder-style).
    pub fn with_retry_policy(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    /// Executor index owning partition `p` (Spark-style round-robin
    /// locality).
    pub fn executor_of(&self, p: usize) -> usize {
        p % self.executors
    }
}

/// Quiet `GKSELECT_EXEC_MODE` read for raw cluster constructors: unset,
/// empty, or invalid → `Sequential`. Loud validation happens at the
/// engine / CLI boundary via [`crate::engine::env::exec_mode`].
fn env_exec_mode() -> ExecMode {
    crate::engine::env::exec_mode().ok().flatten().unwrap_or_default()
}

/// Quiet `GKSELECT_FAULTS` read for raw cluster constructors: unset,
/// empty, or invalid → no injector.
fn env_fault_plan() -> Option<FaultPlan> {
    crate::engine::env::faults().ok().flatten()
}

/// Per-partition results of a `mapPartitions`, pending an action.
///
/// Carries the measured compute time of each partition closure so the
/// consuming action can charge the virtual clock with the *parallel*
/// elapsed time of the stage.
#[derive(Debug)]
pub struct PerPartition<R> {
    pub values: Vec<R>,
    /// Seconds of measured compute per partition.
    times: Vec<f64>,
}

impl<R> PerPartition<R> {
    /// Map the carried values without touching the time ledger (driver-side
    /// relabeling, free in the model).
    pub fn map_values<S>(self, f: impl FnMut(R) -> S) -> PerPartition<S> {
        PerPartition {
            values: self.values.into_iter().map(f).collect(),
            times: self.times,
        }
    }
}

impl<A, B> PerPartition<(A, B)> {
    /// Split a pair-producing stage into two pendings. The measured
    /// compute time rides with the **first** half (charge once: the
    /// second half stays executor-resident, e.g. AFS's retained
    /// partitions while only counts travel).
    pub fn unzip(self) -> (PerPartition<A>, PerPartition<B>) {
        let (a, b): (Vec<A>, Vec<B>) = self.values.into_iter().unzip();
        let zero = vec![0.0; a.len()];
        (
            PerPartition {
                values: a,
                times: self.times,
            },
            PerPartition {
                values: b,
                times: zero,
            },
        )
    }
}

/// Context handed to every partition closure.
#[derive(Debug, Clone, Copy)]
pub struct PartitionCtx {
    /// Partition index within the dataset.
    pub partition: usize,
    /// Executor that owns this partition.
    pub executor: usize,
    /// Total number of partitions.
    pub num_partitions: usize,
}

/// The simulated cluster: driver + executors + fabric + clocks.
pub struct Cluster {
    pub cfg: ClusterConfig,
    pub clock: SimClock,
    pub metrics: RunMetrics,
    /// Span collector — disabled (all hooks no-ops) until the engine
    /// arms it for a non-`Null` [`crate::obs::TraceSink`].
    pub tracer: Tracer,
    /// Executor pool behind `map_partitions` (both execution strategies).
    pool: ExecutorPool,
    /// Fault injector built from `cfg.faults`; consulted per task attempt.
    injector: Option<FaultInjector>,
}

impl Cluster {
    pub fn new(cfg: ClusterConfig) -> Self {
        assert!(cfg.executors > 0, "cluster needs at least one executor");
        assert!(
            cfg.partitions >= cfg.executors,
            "need at least one partition per executor"
        );
        let pool = ExecutorPool::new(cfg.executors);
        let injector = cfg.faults.clone().map(FaultInjector::new);
        Self {
            cfg,
            clock: SimClock::new(),
            metrics: RunMetrics::default(),
            tracer: Tracer::disabled(),
            pool,
            injector,
        }
    }

    /// Reset clocks and metrics between trials (data stays put; the
    /// tracer keeps its arming and any open trace).
    pub fn reset_run(&mut self) {
        self.clock = SimClock::new();
        self.metrics = RunMetrics::default();
    }

    /// Lazily-scheduled narrow transformation: run `f` over every
    /// partition, measuring compute time per partition. No round, no
    /// stage boundary — those are charged by the consuming action, like
    /// Spark's lazy evaluation.
    ///
    /// `f` must be pure per partition (`Fn + Sync`): under
    /// [`ExecMode::Threads`] it runs concurrently on one OS thread per
    /// executor, and the two modes are required to produce bit-identical
    /// values. Either way the stage's real wall-clock and per-executor
    /// busy times land in [`RunMetrics`]; the virtual clock is charged
    /// from the measured per-partition times by the consuming action,
    /// exactly as in the sequential-only substrate.
    ///
    /// Tasks run under the fault model (module docs, "Failure
    /// semantics"): injected and real panics are caught and retried per
    /// `cfg.retry`, with retry backoff charged to the virtual clock here
    /// (the re-launch latency is on the stage's critical path regardless
    /// of which action consumes it). A task that exhausts its retries
    /// fails the whole stage with a typed [`StageError`] — deterministic
    /// in both exec modes.
    pub fn map_partitions<T, R>(
        &mut self,
        data: &Dataset<T>,
        f: impl Fn(&[T], PartitionCtx) -> R + Sync,
    ) -> Result<PerPartition<R>, StageError>
    where
        T: Send + Sync,
        R: Send,
    {
        // one mapPartitions stage = one linear read of the dataset; the
        // consuming action charges the round, but the scan happens here.
        // The pre-increment scan count doubles as the stage index faults
        // are keyed on (0-based from the last `reset_run`).
        let stage_index = self.metrics.data_scans;
        self.metrics.data_scans += 1;
        let sid = self.tracer.open(
            SpanKind::Stage,
            format!("stage {stage_index}"),
            self.clock.elapsed_secs(),
        );
        self.tracer.set_stage(sid, stage_index);
        let executor_of = |p: usize| self.cfg.executor_of(p);
        let fx = FaultContext {
            injector: self.injector.as_ref(),
            retry: self.cfg.retry,
            stage: stage_index,
            executors: self.cfg.executors,
            trace: self.tracer.is_enabled(),
        };
        let run = match self.cfg.exec_mode {
            ExecMode::Sequential => self.pool.run_sequential(data, executor_of, &f, &fx),
            ExecMode::Threads => self.pool.run_threaded(data, executor_of, &f, &fx),
        };
        let stage = match run {
            Ok(stage) => stage,
            Err(err) => {
                self.tracer.close(sid, self.clock.elapsed_secs());
                return Err(err);
            }
        };
        self.metrics.wall_stage_secs += stage.wall_secs;
        self.metrics.stage_walls.push(stage.wall_secs);
        if self.metrics.executor_busy_secs.len() < stage.busy_secs.len() {
            self.metrics.executor_busy_secs.resize(stage.busy_secs.len(), 0.0);
        }
        for (ledger, busy) in self
            .metrics
            .executor_busy_secs
            .iter_mut()
            .zip(stage.busy_secs)
        {
            *ledger += busy;
        }
        self.metrics.faults_injected += stage.faults.faults_injected;
        self.metrics.tasks_retried += stage.faults.tasks_retried;
        self.metrics.speculative_launched += stage.faults.speculative_launched;
        self.metrics.speculative_wins += stage.faults.speculative_wins;
        // per-task modelled durations (µs) feed the StageStats latency
        // sketches — always on, independent of tracing
        self.metrics
            .stage_attempt_us
            .push(stage.times.iter().map(|&t| (t * 1e6).round() as u32).collect());
        // retry re-launch latency: serial, on the critical path, charged
        // now rather than deferred to the consuming action
        self.clock.advance(stage.faults.backoff_secs);
        self.tracer.record_attempts(sid, &stage.attempts);
        self.tracer.close(sid, self.clock.elapsed_secs());
        Ok(PerPartition {
            values: stage.values,
            times: stage.times,
        })
    }

    /// Parallel elapsed time of a stage: max over executors of the summed
    /// measured times of their partitions, scaled to the reference core.
    fn stage_elapsed(&self, times: &[f64]) -> f64 {
        let mut per_exec = vec![0.0_f64; self.cfg.executors];
        for (p, t) in times.iter().enumerate() {
            per_exec[self.cfg.executor_of(p)] += t;
        }
        per_exec.into_iter().fold(0.0, f64::max) * self.cfg.compute_scale
    }

    /// `collect`: gather per-partition results at the driver. First stage
    /// boundary of the consuming job; ends a round.
    pub fn collect<R: NetSize>(&mut self, pending: PerPartition<R>) -> Vec<R> {
        let compute = self.stage_elapsed(&pending.times);
        let bytes: u64 = pending.values.iter().map(NetSize::net_bytes).sum();
        let net = self.cfg.net.collect_cost(self.cfg.executors, bytes);
        self.clock.advance(compute + net);
        self.metrics.rounds += 1;
        self.metrics.stage_boundaries += 1;
        self.metrics.bytes_to_driver += bytes;
        self.metrics.messages += self.cfg.partitions as u64;
        pending.values
    }

    /// `reduce`: collect-shaped aggregation (Spark's `RDD.reduce` ships
    /// partial results to the driver and folds there). Ends a round.
    pub fn reduce<R: NetSize>(
        &mut self,
        pending: PerPartition<R>,
        f: impl FnMut(R, R) -> R,
    ) -> Option<R> {
        let parts = self.collect(pending);
        let start = Instant::now();
        let out = parts.into_iter().reduce(f);
        self.charge_driver(start.elapsed().as_secs_f64());
        out
    }

    /// `treeReduce`: log-depth aggregation over the executors; only the
    /// final partial reaches the driver. Ends a round.
    ///
    /// `depth` overrides the tree depth the way Spark's
    /// `RDD.treeReduce(f, depth)` does (default 2 there): `P` partials are
    /// squashed in at most `depth` levels by merging groups of
    /// `⌈P^(1/depth)⌉` per level. `None` keeps the pairwise tree —
    /// ⌈log₂ P⌉ levels, the paper's `O(log P)` analysis. The number of
    /// levels actually executed lands in `RunMetrics::tree_levels`.
    pub fn tree_reduce<R: NetSize>(
        &mut self,
        pending: PerPartition<R>,
        depth: Option<usize>,
        mut f: impl FnMut(R, R) -> R,
    ) -> Option<R> {
        let rid = self.tracer.open(
            SpanKind::Reduce,
            "tree-reduce",
            self.clock.elapsed_secs(),
        );
        self.tracer.attr(rid, "partials", pending.values.len());
        let compute = self.stage_elapsed(&pending.times);
        self.clock.advance(compute);

        let mut level: Vec<R> = pending.values;
        if level.is_empty() {
            self.metrics.rounds += 1;
            self.metrics.stage_boundaries += 1;
            self.tracer.close(rid, self.clock.elapsed_secs());
            return None;
        }
        let branch = branch_factor(level.len(), depth);

        // Merge groups of `branch` partials level by level. Groups within
        // a level run in parallel across executors (charge the max summed
        // merge time over groups); merges *within* a group are sequential
        // on the receiving executor. One message per moved partial; the
        // level's fabric charge is its largest single partial.
        while level.len() > 1 {
            self.metrics.tree_levels += 1;
            let mut next = Vec::with_capacity(level.len().div_ceil(branch));
            let mut level_compute = 0.0_f64;
            let mut level_max_bytes = 0_u64;
            let mut level_bytes = 0_u64;
            let mut it = level.into_iter();
            while let Some(mut acc) = it.next() {
                let mut group_compute = 0.0_f64;
                for _ in 1..branch {
                    match it.next() {
                        Some(b) => {
                            let moved = b.net_bytes();
                            level_bytes += moved;
                            level_max_bytes = level_max_bytes.max(moved);
                            let start = Instant::now();
                            acc = f(acc, b);
                            group_compute += start.elapsed().as_secs_f64();
                            self.metrics.messages += 1;
                        }
                        None => break,
                    }
                }
                level_compute = level_compute.max(group_compute);
                next.push(acc);
            }
            self.metrics.bytes_tree_reduced += level_bytes;
            self.clock.advance(
                level_compute * self.cfg.compute_scale
                    + self.cfg.net.message_cost(level_max_bytes),
            );
            level = next;
        }

        let root = level.pop();
        // Final partial lands on the driver.
        if let Some(ref r) = root {
            let bytes = r.net_bytes();
            self.metrics.bytes_to_driver += bytes;
            self.clock.advance(self.cfg.net.message_cost(bytes));
        }
        self.metrics.rounds += 1;
        self.metrics.stage_boundaries += 1;
        self.tracer.attr(rid, "levels", self.metrics.tree_levels);
        self.tracer.close(rid, self.clock.elapsed_secs());
        root
    }

    /// `TorrentBroadcast`: BitTorrent-style log-depth fan-out from the
    /// driver. Adds latency but **no** stage boundary and no round — the
    /// paper is explicit about this (§IV-B).
    pub fn broadcast<B: NetSize>(&mut self, value: &B) {
        let bytes = value.net_bytes();
        let hops = (usize::BITS - (self.cfg.executors.max(2) - 1).leading_zeros()) as u64;
        self.clock
            .advance(hops as f64 * self.cfg.net.message_cost(bytes));
        self.metrics.bytes_broadcast += bytes * self.cfg.executors as u64;
        self.metrics.messages += self.cfg.executors as u64;
    }

    /// Charge driver-side compute (merging sketches, folding counts, the
    /// final candidate scan) at the driver's calibrated speed.
    pub fn charge_driver(&mut self, measured_secs: f64) {
        self.clock.advance(measured_secs * self.cfg.driver_scale);
        self.metrics.driver_compute_secs += measured_secs * self.cfg.driver_scale;
    }

    /// Run a driver-side closure, measuring and charging its time.
    pub fn driver<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let start = Instant::now();
        let out = f();
        self.charge_driver(start.elapsed().as_secs_f64());
        out
    }

    /// Record a persist of `bytes` (AFS/Jeffers re-materialize the
    /// retained side every round; GK Select persists nothing — Table V).
    pub fn persist_bytes(&mut self, bytes: u64) {
        self.metrics.persists += 1;
        self.metrics.bytes_persisted += bytes;
    }

    /// Virtual elapsed seconds since the run started.
    pub fn elapsed_secs(&self) -> f64 {
        self.clock.elapsed_secs()
    }
}

/// treeReduce branching factor: smallest `b ≥ 2` with `b^depth ≥ p`
/// (Spark's `scale = max(⌈P^(1/depth)⌉, 2)`, computed in integers to
/// dodge `powf` rounding at exact powers). `None` → pairwise.
fn branch_factor(p: usize, depth: Option<usize>) -> usize {
    let Some(d) = depth else { return 2 };
    let d = d.max(1) as u32;
    let mut b = 2_usize;
    while (b as u128).pow(d) < p as u128 {
        b += 1;
    }
    b
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> (Cluster, Dataset<i32>) {
        let cluster = Cluster::new(ClusterConfig::local(2, 4));
        let data = Dataset::from_partitions(vec![
            vec![1, 2, 3],
            vec![4, 5],
            vec![6],
            vec![7, 8, 9, 10],
        ])
        .unwrap();
        (cluster, data)
    }

    #[test]
    fn map_partitions_sees_every_partition() {
        let (mut c, d) = tiny();
        let lens = c
            .map_partitions(&d, |part, ctx| (ctx.partition, part.len()))
            .unwrap();
        assert_eq!(lens.values, vec![(0, 3), (1, 2), (2, 1), (3, 4)]);
        // lazy: no round yet, but the data was read once
        assert_eq!(c.metrics.rounds, 0);
        assert_eq!(c.metrics.data_scans, 1);
    }

    #[test]
    fn collect_ends_a_round_and_counts_bytes() {
        let (mut c, d) = tiny();
        let counts = c.map_partitions(&d, |part, _| part.len() as u64).unwrap();
        let got = c.collect(counts);
        assert_eq!(got.iter().sum::<u64>(), 10);
        assert_eq!(c.metrics.rounds, 1);
        assert_eq!(c.metrics.stage_boundaries, 1);
        assert_eq!(c.metrics.bytes_to_driver, 4 * 8);
    }

    #[test]
    fn reduce_folds_on_driver() {
        let (mut c, d) = tiny();
        let sums = c
            .map_partitions(&d, |part, _| part.iter().map(|&x| x as i64).sum::<i64>())
            .unwrap();
        let total = c.reduce(sums, |a, b| a + b).unwrap();
        assert_eq!(total, 55);
        assert_eq!(c.metrics.rounds, 1);
    }

    #[test]
    fn tree_reduce_matches_reduce() {
        let (mut c, d) = tiny();
        let sums = c
            .map_partitions(&d, |part, _| part.iter().map(|&x| x as i64).sum::<i64>())
            .unwrap();
        let total = c.tree_reduce(sums, None, |a, b| a + b).unwrap();
        assert_eq!(total, 55);
        assert_eq!(c.metrics.rounds, 1);
        assert_eq!(c.metrics.stage_boundaries, 1);
        assert!(c.metrics.bytes_tree_reduced > 0);
    }

    #[test]
    fn tree_reduce_empty_is_none() {
        let mut c = Cluster::new(ClusterConfig::local(1, 1));
        let pending: PerPartition<i64> = PerPartition {
            values: vec![],
            times: vec![],
        };
        assert!(c.tree_reduce(pending, None, |a, b| a + b).is_none());
    }

    #[test]
    fn broadcast_adds_no_round() {
        let (mut c, _) = tiny();
        c.broadcast(&42_i64);
        assert_eq!(c.metrics.rounds, 0);
        assert_eq!(c.metrics.stage_boundaries, 0);
        assert_eq!(c.metrics.bytes_broadcast, 8 * 2);
    }

    #[test]
    fn executor_assignment_round_robin() {
        let cfg = ClusterConfig::local(3, 7);
        assert_eq!(cfg.executor_of(0), 0);
        assert_eq!(cfg.executor_of(4), 1);
        assert_eq!(cfg.executor_of(5), 2);
    }

    #[test]
    fn reset_run_clears_ledger() {
        let (mut c, d) = tiny();
        let xs = c.map_partitions(&d, |p, _| p.len() as u64).unwrap();
        c.collect(xs);
        c.reset_run();
        assert_eq!(c.metrics.rounds, 0);
        assert_eq!(c.elapsed_secs(), 0.0);
    }

    #[test]
    #[should_panic]
    fn rejects_more_executors_than_partitions() {
        Cluster::new(ClusterConfig::local(8, 4));
    }

    #[test]
    fn branch_factor_shapes() {
        // pairwise when unspecified
        assert_eq!(branch_factor(8, None), 2);
        // Spark default depth 2: ⌈√P⌉
        assert_eq!(branch_factor(8, Some(2)), 3);
        assert_eq!(branch_factor(16, Some(2)), 4);
        assert_eq!(branch_factor(40, Some(2)), 7);
        // depth 1 collapses in one level
        assert_eq!(branch_factor(8, Some(1)), 8);
        // depth ≥ log₂P degenerates to pairwise
        assert_eq!(branch_factor(8, Some(3)), 2);
        assert_eq!(branch_factor(8, Some(10)), 2);
        assert_eq!(branch_factor(1, Some(2)), 2);
    }

    fn level_count(depth: Option<usize>) -> (i64, u64) {
        let mut c = Cluster::new(ClusterConfig::local(2, 8));
        let data = Dataset::from_vec((0..64).collect::<Vec<i32>>(), 8).unwrap();
        let sums = c
            .map_partitions(&data, |part, _| {
                part.iter().map(|&x| x as i64).sum::<i64>()
            })
            .unwrap();
        let total = c.tree_reduce(sums, depth, |a, b| a + b).unwrap();
        (total, c.metrics.tree_levels)
    }

    #[test]
    fn tree_reduce_honors_depth() {
        // 8 partials: pairwise runs ⌈log₂8⌉ = 3 levels; Spark's default
        // depth-2 tree groups by ⌈√8⌉ = 3 → 8 → 3 → 1 in 2 levels;
        // depth 1 is a single 8-way fold. Same answer everywhere.
        let (t_nat, l_nat) = level_count(None);
        let (t_d2, l_d2) = level_count(Some(2));
        let (t_d1, l_d1) = level_count(Some(1));
        assert_eq!(t_nat, (0..64).sum::<i64>());
        assert_eq!(t_nat, t_d2);
        assert_eq!(t_nat, t_d1);
        assert_eq!(l_nat, 3, "pairwise levels");
        assert_eq!(l_d2, 2, "depth-2 levels");
        assert_eq!(l_d1, 1, "depth-1 levels");
    }

    #[test]
    fn threads_mode_matches_sequential_values_and_counters() {
        let run = |mode: ExecMode| {
            let mut c = Cluster::new(ClusterConfig::local(3, 7).with_exec_mode(mode));
            let data = Dataset::from_vec((0..1000).collect::<Vec<i32>>(), 7).unwrap();
            let pending = c
                .map_partitions(&data, |part, ctx| {
                    (ctx.partition, ctx.executor, part.iter().map(|&x| x as i64).sum::<i64>())
                })
                .unwrap();
            let values = pending.values.clone();
            let got = c.collect(pending);
            (values, got, c.metrics.clone())
        };
        let (sv, sc, sm) = run(ExecMode::Sequential);
        let (tv, tc, tm) = run(ExecMode::Threads);
        assert_eq!(sv, tv, "PerPartition.values must be bit-identical");
        assert_eq!(sc, tc);
        assert_eq!(sm.rounds, tm.rounds);
        assert_eq!(sm.data_scans, tm.data_scans);
        assert_eq!(sm.bytes_to_driver, tm.bytes_to_driver);
        assert_eq!(sm.messages, tm.messages);
        // the threaded run fills the real-time ledgers
        assert_eq!(tm.executor_busy_secs.len(), 3);
        assert_eq!(tm.stage_walls.len(), 1);
        assert_eq!(tm.wall_stage_secs, tm.stage_walls.iter().sum::<f64>());
    }

    #[test]
    fn retries_charge_backoff_and_land_in_metrics() {
        let plan = FaultPlan::seeded(7).panic_task(0, 2);
        for mode in [ExecMode::Sequential, ExecMode::Threads] {
            let mut c = Cluster::new(
                ClusterConfig::local(2, 4)
                    .with_exec_mode(mode)
                    .with_fault_plan(Some(plan.clone())),
            );
            let d = Dataset::from_vec((0..40).collect::<Vec<i32>>(), 4).unwrap();
            let xs = c.map_partitions(&d, |p, _| p.len() as u64).unwrap();
            let got = c.collect(xs);
            assert_eq!(got.iter().sum::<u64>(), 40, "values survive the retry");
            assert_eq!(c.metrics.faults_injected, 1);
            assert_eq!(c.metrics.tasks_retried, 1);
            // the retry's re-launch latency reached the virtual clock
            assert!(c.elapsed_secs() >= c.cfg.retry.backoff_secs);
        }
    }

    #[test]
    fn exhausted_retries_surface_a_typed_stage_error() {
        // a persistent fault (attempts window beyond the retry budget) on
        // the SECOND stage: the first scan is clean, the second fails
        let plan = FaultPlan::seeded(7).panic_task(1, 0).attempts(99);
        let mut c = Cluster::new(
            ClusterConfig::local(2, 4).with_fault_plan(Some(plan)),
        );
        let d = Dataset::from_vec((0..40).collect::<Vec<i32>>(), 4).unwrap();
        let ok = c.map_partitions(&d, |p, _| p.len() as u64).unwrap();
        c.collect(ok);
        let err = c.map_partitions(&d, |p, _| p.len() as u64).unwrap_err();
        assert_eq!(err.stage, 1);
        assert_eq!(err.partition, 0);
        assert_eq!(err.attempts, c.cfg.retry.max_task_retries + 1);
        // stage indices restart at 0 after reset_run, so the same plan
        // leaves stage 0 clean again and kills stage 1 again
        c.reset_run();
        assert!(c.map_partitions(&d, |p, _| p.len() as u64).is_ok());
        assert!(c.map_partitions(&d, |p, _| p.len() as u64).is_err());
    }

    #[test]
    fn reset_run_clears_wall_ledgers() {
        let mut c = Cluster::new(ClusterConfig::local(2, 4).with_exec_mode(ExecMode::Threads));
        let d = Dataset::from_vec((0..100).collect::<Vec<i32>>(), 4).unwrap();
        let xs = c.map_partitions(&d, |p, _| p.len() as u64).unwrap();
        c.collect(xs);
        assert!(!c.metrics.stage_walls.is_empty());
        c.reset_run();
        assert!(c.metrics.stage_walls.is_empty());
        assert_eq!(c.metrics.wall_stage_secs, 0.0);
        assert!(c.metrics.executor_busy_secs.is_empty());
        assert_eq!(c.metrics.tree_levels, 0);
    }
}
