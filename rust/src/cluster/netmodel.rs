//! Fabric cost model + message sizing.
//!
//! Prices every message the substrate sends: `cost = latency + bytes /
//! bandwidth`. Defaults model the paper's testbed (EMR m5.xlarge, ~10 Gbit
//! NIC ≈ 1.25 GB/s, a few hundred µs per message round-trip). The model is
//! deliberately simple — the paper's results are driven by *how many*
//! synchronizations and *how many bytes*, both of which we count exactly;
//! the model only converts them to seconds.

/// Sizes a value as it would appear on the wire (Spark task results are
/// serialized; we charge payload bytes plus a small framing overhead for
/// containers).
pub trait NetSize {
    fn net_bytes(&self) -> u64;
}

/// Marker for fixed-width scalar payloads.
pub trait FixedWire: Copy {
    const WIRE_BYTES: u64;
}

macro_rules! fixed_wire {
    ($($t:ty => $b:expr),* $(,)?) => {
        $(impl FixedWire for $t { const WIRE_BYTES: u64 = $b; })*
    };
}

fixed_wire!(
    i8 => 1, u8 => 1, i16 => 2, u16 => 2,
    i32 => 4, u32 => 4, f32 => 4,
    i64 => 8, u64 => 8, f64 => 8, usize => 8,
);

impl<A: FixedWire, B: FixedWire> FixedWire for (A, B) {
    const WIRE_BYTES: u64 = A::WIRE_BYTES + B::WIRE_BYTES;
}

impl<A: FixedWire, B: FixedWire, C: FixedWire> FixedWire for (A, B, C) {
    const WIRE_BYTES: u64 = A::WIRE_BYTES + B::WIRE_BYTES + C::WIRE_BYTES;
}

impl<T: FixedWire> NetSize for T {
    fn net_bytes(&self) -> u64 {
        T::WIRE_BYTES
    }
}

/// Framing overhead charged per serialized container (task result
/// envelope).
pub const CONTAINER_OVERHEAD: u64 = 16;

impl<T: FixedWire> NetSize for Vec<T> {
    fn net_bytes(&self) -> u64 {
        CONTAINER_OVERHEAD + self.len() as u64 * T::WIRE_BYTES
    }
}

impl<T: FixedWire> NetSize for &[T] {
    fn net_bytes(&self) -> u64 {
        CONTAINER_OVERHEAD + self.len() as u64 * T::WIRE_BYTES
    }
}

impl<T: NetSize> NetSize for Option<T> {
    fn net_bytes(&self) -> u64 {
        1 + self.as_ref().map_or(0, NetSize::net_bytes)
    }
}

/// Latency/bandwidth fabric model, plus the two shuffle-only costs Spark
/// always pays on EMR: shuffle files spill through local EBS volumes, and
/// every shuffled record crosses the JVM serializer twice.
#[derive(Debug, Clone)]
pub struct NetworkModel {
    /// Per-message setup latency, seconds.
    pub latency_s: f64,
    /// Point-to-point bandwidth, bytes/second.
    pub bandwidth_bps: f64,
    /// Driver ingest bandwidth, bytes/second (collect funnels through one
    /// NIC).
    pub driver_bandwidth_bps: f64,
    /// Local shuffle-spill disk throughput, bytes/second (EMR m5.xlarge:
    /// 15 GiB gp2 EBS ≈ 250 MB/s burst). Shuffle data is written by the
    /// mapper and read by the reducer.
    pub shuffle_disk_bps: f64,
    /// Per-record serialization cost, seconds, paid on each side of a
    /// shuffle (Spark's serializer + partitioner bookkeeping per record).
    pub ser_s_per_record: f64,
}

impl NetworkModel {
    /// Free fabric (unit tests / pure wall-clock mode).
    pub fn zero() -> Self {
        Self {
            latency_s: 0.0,
            bandwidth_bps: f64::INFINITY,
            driver_bandwidth_bps: f64::INFINITY,
            shuffle_disk_bps: f64::INFINITY,
            ser_s_per_record: 0.0,
        }
    }

    /// EMR-like defaults: 10 Gbit NIC, 200 µs message latency, gp2 EBS
    /// shuffle volumes, ~100 ns/record serializer.
    pub fn emr_like() -> Self {
        Self {
            latency_s: 200e-6,
            bandwidth_bps: 1.25e9,
            driver_bandwidth_bps: 1.25e9,
            shuffle_disk_bps: 250e6,
            ser_s_per_record: 100e-9,
        }
    }

    /// Cost of one point-to-point message of `bytes`.
    pub fn message_cost(&self, bytes: u64) -> f64 {
        self.latency_s + bytes as f64 / self.bandwidth_bps
    }

    /// Cost of a collect: `executors` concurrent senders funneling
    /// `total_bytes` through the driver NIC; latencies overlap, transfer
    /// serializes on the driver link.
    pub fn collect_cost(&self, _executors: usize, total_bytes: u64) -> f64 {
        self.latency_s + total_bytes as f64 / self.driver_bandwidth_bps
    }

    /// Cost of an all-to-all shuffle: `total_records` pass through the
    /// serializer on both sides, shuffle files traverse the local spill
    /// disk on both sides, and `moved_bytes` cross the fabric — all
    /// parallel across `executors`.
    pub fn shuffle_cost(&self, executors: usize, moved_bytes: u64, total_records: u64) -> f64 {
        let e = executors.max(1) as f64;
        let per_link = moved_bytes as f64 / e;
        let net = self.latency_s * e + 2.0 * per_link / self.bandwidth_bps;
        // every record is shuffle-written locally even when it stays on
        // the same executor (Spark writes map outputs before reducing)
        let per_exec_bytes = moved_bytes as f64 / e;
        let disk = 2.0 * per_exec_bytes / self.shuffle_disk_bps;
        let ser = 2.0 * (total_records as f64 / e) * self.ser_s_per_record;
        net + disk + ser
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_sizes() {
        assert_eq!(42_i32.net_bytes(), 4);
        assert_eq!(42_u64.net_bytes(), 8);
        assert_eq!((1_i32, 2_u64).net_bytes(), 12);
        assert_eq!((1_u64, 2_u64, 3_u64).net_bytes(), 24);
    }

    #[test]
    fn vec_includes_overhead() {
        let v = vec![1_i32; 10];
        assert_eq!(v.net_bytes(), CONTAINER_OVERHEAD + 40);
    }

    #[test]
    fn option_sizes() {
        assert_eq!(Option::<i32>::None.net_bytes(), 1);
        assert_eq!(Some(1_i32).net_bytes(), 5);
    }

    #[test]
    fn zero_model_is_free() {
        let m = NetworkModel::zero();
        assert_eq!(m.message_cost(1 << 30), 0.0);
        assert_eq!(m.collect_cost(8, 1 << 30), 0.0);
        assert_eq!(m.shuffle_cost(8, 1 << 30, 1 << 28), 0.0);
    }

    #[test]
    fn shuffle_includes_disk_and_serialization() {
        let m = NetworkModel::emr_like();
        let bytes = 4_000_000_000u64; // 1e9 i32 keys
        let records = 1_000_000_000u64;
        let cost = m.shuffle_cost(30, bytes, records);
        // serialization alone: 2 × (1e9/30) × 100ns ≈ 6.7s
        assert!(cost > 6.0, "shuffle at 1e9 records must cost seconds, got {cost}");
        // and it dwarfs a sketch-sized collect
        assert!(cost > 100.0 * m.collect_cost(30, 10_000_000));
    }

    #[test]
    fn emr_costs_scale_with_bytes() {
        let m = NetworkModel::emr_like();
        let small = m.message_cost(1_000);
        let big = m.message_cost(1_000_000_000);
        assert!(big > small);
        assert!((big - 1e9 / 1.25e9 - 200e-6).abs() < 1e-9);
    }

    #[test]
    fn shuffle_parallelism_helps() {
        let m = NetworkModel::emr_like();
        // same bytes over more executors should not be slower per link
        let few = m.shuffle_cost(2, 1 << 30, 1 << 28);
        let many = m.shuffle_cost(32, 1 << 30, 1 << 28);
        // transfer part shrinks even though latency part grows
        assert!(many < few);
    }
}
