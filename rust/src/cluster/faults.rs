//! Deterministic fault injection for the simulated cluster substrate.
//!
//! Spark's resilience story — task retry, speculative execution, lineage
//! re-execution — is what lets the paper run exact quantiles on a real
//! 30-core EMR cluster without babysitting stragglers and lost
//! containers. This module gives the simulated substrate the same
//! adversary: a seeded [`FaultPlan`] describes *which* task attempts
//! fail (panics, transient errors), *which* tasks run slow (straggler
//! multipliers), and *which* executors disappear at a given stage; a
//! [`FaultInjector`] is consulted by [`ExecutorPool`] for every
//! `(stage, partition, attempt)` and answers identically in both
//! execution modes — injection is a pure function of the plan, never of
//! thread timing, so `Sequential` and `Threads` runs see the same
//! faults and produce bit-identical values.
//!
//! Recovery semantics live in [`RetryPolicy`]: failed attempts are
//! retried up to `max_task_retries` with `backoff_secs` of virtual
//! latency charged per retry; stragglers past the detection threshold
//! get a speculative duplicate on an idle executor (first pure result
//! wins — bit-identical by construction, so only the modelled time and
//! the `speculative_*` counters change). A task that exhausts its
//! retries fails the whole stage with a typed [`StageError`], which the
//! engine surfaces as `EngineError::StageFailed` or absorbs under a
//! degrade policy.
//!
//! [`ExecutorPool`]: super::pool::ExecutorPool

use crate::select::SplitMix64;
use std::fmt;

/// What the injector decided for one `(stage, partition, attempt)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// Abrupt task death (the simulated analogue of a task panic).
    Panic,
    /// Transient task error (fetch failure, lost heartbeat) — same
    /// retry path as a panic, tracked separately only in the reason.
    Transient,
    /// The task completes but `mult`× slower than measured.
    Straggler(f64),
    /// The task's executor disappeared at this stage; every task it
    /// owns dies once and is re-run on the replacement.
    ExecutorLost,
}

impl FaultKind {
    fn reason(&self) -> &'static str {
        match self {
            FaultKind::Panic => "injected task panic",
            FaultKind::Transient => "injected transient task error",
            FaultKind::Straggler(_) => "injected straggler",
            FaultKind::ExecutorLost => "injected executor loss",
        }
    }
}

/// Builder-composable, seeded schedule of injected faults.
///
/// Rates are per-task probabilities decided by hashing
/// `(seed, stage, partition)` — never by a shared mutable RNG — so the
/// schedule is identical across execution modes and across retries of
/// the same stage. An injected panic/transient repeats for
/// [`fault_attempts`](Self::fault_attempts) consecutive attempts of the
/// same task: with the default of 1 the first retry always succeeds;
/// raise it past `RetryPolicy::max_task_retries` to force a
/// `StageError`.
///
/// ```
/// use gkselect::cluster::faults::FaultPlan;
///
/// let plan = FaultPlan::seeded(7)
///     .panics(0.05)
///     .stragglers(0.03, 4.0)
///     .lose_executor(1, 0);
/// let rt: FaultPlan = plan.to_string().parse().unwrap();
/// assert_eq!(rt, plan);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    /// Seed for the per-task fault hashes.
    pub seed: u64,
    /// Probability a task attempt dies abruptly.
    pub panic_rate: f64,
    /// Probability a task attempt fails with a transient error.
    pub transient_rate: f64,
    /// Probability a task runs slow (by `straggler_mult`).
    pub straggler_rate: f64,
    /// Slowdown factor applied to a straggling task's measured time.
    pub straggler_mult: f64,
    /// Consecutive attempts an injected panic/transient repeats for.
    pub fault_attempts: u32,
    /// `(stage, executor)` pairs: every task on that executor dies once
    /// at that stage.
    pub lost_executors: Vec<(u64, usize)>,
    /// Explicit `(stage, partition)` task panics (repeat for
    /// `fault_attempts` like the hashed ones).
    pub task_panics: Vec<(u64, usize)>,
}

impl Default for FaultPlan {
    fn default() -> Self {
        Self {
            seed: 0,
            panic_rate: 0.0,
            transient_rate: 0.0,
            straggler_rate: 0.0,
            straggler_mult: 4.0,
            fault_attempts: 1,
            lost_executors: Vec::new(),
            task_panics: Vec::new(),
        }
    }
}

impl FaultPlan {
    /// An empty plan with the given hash seed.
    pub fn seeded(seed: u64) -> Self {
        Self {
            seed,
            ..Self::default()
        }
    }

    /// Inject abrupt task death with this per-task probability.
    pub fn panics(mut self, rate: f64) -> Self {
        self.panic_rate = rate;
        self
    }

    /// Inject transient task errors with this per-task probability.
    pub fn transients(mut self, rate: f64) -> Self {
        self.transient_rate = rate;
        self
    }

    /// Slow tasks down by `mult`× with this per-task probability.
    pub fn stragglers(mut self, rate: f64, mult: f64) -> Self {
        self.straggler_rate = rate;
        self.straggler_mult = mult;
        self
    }

    /// Make every injected panic/transient repeat for `k` consecutive
    /// attempts of the same task (`k = 1`: first retry succeeds).
    pub fn attempts(mut self, k: u32) -> Self {
        self.fault_attempts = k;
        self
    }

    /// Kill executor `executor` at stage `stage` (0-based stage index,
    /// counted per `map_partitions` since the cluster's last
    /// `reset_run`).
    pub fn lose_executor(mut self, stage: u64, executor: usize) -> Self {
        self.lost_executors.push((stage, executor));
        self
    }

    /// Panic the task for `partition` at stage `stage`, persistently
    /// for `fault_attempts` attempts.
    pub fn panic_task(mut self, stage: u64, partition: usize) -> Self {
        self.task_panics.push((stage, partition));
        self
    }

    /// True when the plan can never inject anything.
    pub fn is_noop(&self) -> bool {
        self.panic_rate <= 0.0
            && self.transient_rate <= 0.0
            && self.straggler_rate <= 0.0
            && self.lost_executors.is_empty()
            && self.task_panics.is_empty()
    }
}

/// The `GKSELECT_FAULTS` grammar: comma-separated `key=value` items.
///
/// | item                     | meaning                                  |
/// |--------------------------|------------------------------------------|
/// | `seed=N`                 | hash seed                                |
/// | `panic=R`                | per-task panic probability               |
/// | `transient=R`            | per-task transient-error probability     |
/// | `straggler=RxM`          | probability `R` of an `M`× slowdown      |
/// | `attempts=K`             | injected faults persist for K attempts   |
/// | `lose=S:E`               | executor `E` dies at stage `S`           |
/// | `panic_at=S:P`           | partition `P`'s task panics at stage `S` |
impl std::str::FromStr for FaultPlan {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        let mut plan = FaultPlan::default();
        for item in s.split(',').map(str::trim).filter(|i| !i.is_empty()) {
            let (key, val) = item
                .split_once('=')
                .ok_or_else(|| format!("fault item '{item}' is not key=value"))?;
            let bad = |what: &str| format!("fault item '{item}': bad {what}");
            match key {
                "seed" => plan.seed = val.parse().map_err(|_| bad("seed"))?,
                "panic" => plan.panic_rate = parse_rate(val).ok_or_else(|| bad("rate"))?,
                "transient" => plan.transient_rate = parse_rate(val).ok_or_else(|| bad("rate"))?,
                "straggler" => {
                    let (rate, mult) = val
                        .split_once('x')
                        .ok_or_else(|| bad("RATExMULT straggler"))?;
                    plan.straggler_rate = parse_rate(rate).ok_or_else(|| bad("rate"))?;
                    plan.straggler_mult = mult
                        .parse::<f64>()
                        .ok()
                        .filter(|m| *m >= 1.0 && m.is_finite())
                        .ok_or_else(|| bad("multiplier (must be >= 1)"))?;
                }
                "attempts" => {
                    plan.fault_attempts = val
                        .parse::<u32>()
                        .ok()
                        .filter(|k| *k >= 1)
                        .ok_or_else(|| bad("attempts (must be >= 1)"))?;
                }
                "lose" => plan.lost_executors.push(parse_pair(val).ok_or_else(|| bad("S:E"))?),
                "panic_at" => plan.task_panics.push(parse_pair(val).ok_or_else(|| bad("S:P"))?),
                other => return Err(format!("unknown fault item '{other}'")),
            }
        }
        Ok(plan)
    }
}

fn parse_rate(s: &str) -> Option<f64> {
    s.parse::<f64>()
        .ok()
        .filter(|r| (0.0..=1.0).contains(r) && r.is_finite())
}

fn parse_pair(s: &str) -> Option<(u64, usize)> {
    let (a, b) = s.split_once(':')?;
    Some((a.parse().ok()?, b.parse().ok()?))
}

impl fmt::Display for FaultPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut items: Vec<String> = vec![format!("seed={}", self.seed)];
        if self.panic_rate > 0.0 {
            items.push(format!("panic={}", self.panic_rate));
        }
        if self.transient_rate > 0.0 {
            items.push(format!("transient={}", self.transient_rate));
        }
        if self.straggler_rate > 0.0 {
            items.push(format!("straggler={}x{}", self.straggler_rate, self.straggler_mult));
        }
        if self.fault_attempts != 1 {
            items.push(format!("attempts={}", self.fault_attempts));
        }
        for &(s, e) in &self.lost_executors {
            items.push(format!("lose={s}:{e}"));
        }
        for &(s, p) in &self.task_panics {
            items.push(format!("panic_at={s}:{p}"));
        }
        write!(f, "{}", items.join(","))
    }
}

/// Task-level recovery knobs — the simulated analogue of
/// `spark.task.maxFailures` / `spark.speculation`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Retries per task before the stage fails (attempts = retries + 1).
    pub max_task_retries: u32,
    /// Virtual seconds charged to the clock per retry (re-launch
    /// latency; never overlapped with other work).
    pub backoff_secs: f64,
    /// Launch a speculative duplicate for detected stragglers when the
    /// cluster has more than one executor.
    pub speculation: bool,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_task_retries: 3,
            backoff_secs: 0.05,
            speculation: true,
        }
    }
}

impl RetryPolicy {
    /// No retries, no speculation — a failed task fails the stage.
    pub fn none() -> Self {
        Self {
            max_task_retries: 0,
            backoff_secs: 0.0,
            speculation: false,
        }
    }

    pub fn with_max_task_retries(mut self, retries: u32) -> Self {
        self.max_task_retries = retries;
        self
    }

    pub fn with_backoff_secs(mut self, secs: f64) -> Self {
        self.backoff_secs = secs;
        self
    }

    pub fn with_speculation(mut self, on: bool) -> Self {
        self.speculation = on;
        self
    }
}

/// A straggler this many × slower than its measured time triggers a
/// speculative duplicate (Spark's `speculation.multiplier` analogue).
pub const SPECULATION_THRESHOLD: f64 = 1.5;

/// Consulted by the executor pool for every `(stage, partition,
/// attempt)`; pure function of the plan, identical in both exec modes.
#[derive(Debug, Clone)]
pub struct FaultInjector {
    plan: FaultPlan,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> Self {
        Self { plan }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// The fault (if any) injected into this task attempt. Failure
    /// kinds repeat for `fault_attempts` attempts (executor loss: one
    /// attempt — the replacement executor is healthy); the straggler
    /// decision is attempt-independent so it applies to whichever
    /// attempt finally runs.
    pub fn fault_for(
        &self,
        stage: u64,
        partition: usize,
        executor: usize,
        attempt: u32,
    ) -> Option<FaultKind> {
        let p = &self.plan;
        if attempt < p.fault_attempts && p.task_panics.contains(&(stage, partition)) {
            return Some(FaultKind::Panic);
        }
        if attempt == 0 && p.lost_executors.contains(&(stage, executor)) {
            return Some(FaultKind::ExecutorLost);
        }
        if attempt < p.fault_attempts {
            if self.decide(stage, partition, 1, p.panic_rate) {
                return Some(FaultKind::Panic);
            }
            if self.decide(stage, partition, 2, p.transient_rate) {
                return Some(FaultKind::Transient);
            }
        }
        if self.decide(stage, partition, 3, p.straggler_rate) {
            return Some(FaultKind::Straggler(p.straggler_mult));
        }
        None
    }

    fn decide(&self, stage: u64, partition: usize, salt: u64, rate: f64) -> bool {
        if rate <= 0.0 {
            return false;
        }
        let mix = self
            .plan
            .seed
            .wrapping_mul(0x9E37_79B9_7F4A_7C15)
            .wrapping_add(stage.wrapping_mul(0xBF58_476D_1CE4_E5B9))
            .wrapping_add((partition as u64).wrapping_mul(0x94D0_49BB_1331_11EB))
            .wrapping_add(salt);
        let r = SplitMix64::new(mix).next_u64();
        (r as f64 / u64::MAX as f64) < rate
    }
}

/// Typed failure of one `map_partitions` stage: some task exhausted its
/// retries. Carries enough to surface `EngineError::StageFailed{stage,
/// attempts}` and a human-readable cause.
#[derive(Debug, Clone, PartialEq)]
pub struct StageError {
    /// 0-based stage index (per `map_partitions` since `reset_run`).
    pub stage: u64,
    /// The partition whose task exhausted its retries.
    pub partition: usize,
    /// Attempts consumed (retries + 1).
    pub attempts: u32,
    /// Last failure cause (injected kind or real panic payload).
    pub reason: String,
}

impl fmt::Display for StageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "stage {} failed: partition {} task died after {} attempts ({})",
            self.stage, self.partition, self.attempts, self.reason
        )
    }
}

impl std::error::Error for StageError {}

/// Per-stage recovery tallies produced by the pool and folded into
/// `RunMetrics` by `Cluster::map_partitions`.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct FaultLedger {
    pub faults_injected: u64,
    pub tasks_retried: u64,
    pub speculative_launched: u64,
    pub speculative_wins: u64,
    /// Virtual retry-backoff latency to charge to the clock.
    pub backoff_secs: f64,
}

impl FaultLedger {
    pub fn absorb(&mut self, other: &FaultLedger) {
        self.faults_injected += other.faults_injected;
        self.tasks_retried += other.tasks_retried;
        self.speculative_launched += other.speculative_launched;
        self.speculative_wins += other.speculative_wins;
        self.backoff_secs += other.backoff_secs;
    }
}

/// Everything the pool needs to run one stage's tasks under the fault
/// model: the injector (if any), the retry policy, the stage index, and
/// the executor count (speculation needs an idle executor to exist).
#[derive(Debug, Clone, Copy)]
pub struct FaultContext<'a> {
    pub injector: Option<&'a FaultInjector>,
    pub retry: RetryPolicy,
    pub stage: u64,
    pub executors: usize,
    /// Collect per-attempt [`AttemptRecord`](crate::obs::AttemptRecord)s
    /// for the tracer (off by default — records cost allocations).
    pub trace: bool,
}

impl FaultContext<'static> {
    /// Fault-free context (unit tests, probes).
    pub fn none(executors: usize) -> Self {
        Self {
            injector: None,
            retry: RetryPolicy::default(),
            stage: 0,
            executors,
            trace: false,
        }
    }
}

impl FaultKind {
    /// Whether this fault kills the attempt (vs. slowing it down).
    pub(crate) fn is_fatal(&self) -> bool {
        !matches!(self, FaultKind::Straggler(_))
    }

    pub(crate) fn failure_reason(&self) -> String {
        self.reason().to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grammar_roundtrips() {
        let plan = FaultPlan::seeded(42)
            .panics(0.2)
            .transients(0.1)
            .stragglers(0.05, 8.0)
            .attempts(5)
            .lose_executor(1, 2)
            .panic_task(0, 3);
        let rt: FaultPlan = plan.to_string().parse().unwrap();
        assert_eq!(rt, plan);
    }

    #[test]
    fn grammar_rejects_garbage() {
        for bad in [
            "panic",
            "panic=2.0",
            "straggler=0.5",
            "straggler=0.5x0.5",
            "attempts=0",
            "lose=1",
            "wat=1",
        ] {
            assert!(bad.parse::<FaultPlan>().is_err(), "accepted '{bad}'");
        }
    }

    #[test]
    fn empty_string_is_noop_plan() {
        let plan: FaultPlan = "".parse().unwrap();
        assert!(plan.is_noop());
    }

    #[test]
    fn decisions_are_deterministic_and_attempt_windowed() {
        let inj = FaultInjector::new(FaultPlan::seeded(9).panics(0.5).attempts(2));
        for stage in 0..4u64 {
            for part in 0..16usize {
                let a0 = inj.fault_for(stage, part, 0, 0);
                assert_eq!(a0, inj.fault_for(stage, part, 0, 0), "not deterministic");
                assert_eq!(a0, inj.fault_for(stage, part, 3, 1), "attempt 1 in window");
                // past the window the task must succeed
                assert_eq!(inj.fault_for(stage, part, 0, 2), None);
            }
        }
        // 0.5 rate over 64 tasks: some but not all fault
        let hits = (0..4u64)
            .flat_map(|s| (0..16usize).map(move |p| (s, p)))
            .filter(|&(s, p)| inj.fault_for(s, p, 0, 0).is_some())
            .count();
        assert!(hits > 8 && hits < 56, "hits = {hits}");
    }

    #[test]
    fn executor_loss_hits_only_its_stage_and_executor_once() {
        let inj = FaultInjector::new(FaultPlan::seeded(1).lose_executor(2, 1));
        assert_eq!(inj.fault_for(2, 5, 1, 0), Some(FaultKind::ExecutorLost));
        assert_eq!(inj.fault_for(2, 5, 1, 1), None, "replacement is healthy");
        assert_eq!(inj.fault_for(2, 5, 0, 0), None, "other executor fine");
        assert_eq!(inj.fault_for(1, 5, 1, 0), None, "other stage fine");
    }

    #[test]
    fn explicit_task_panic_persists_for_attempts_window() {
        let inj = FaultInjector::new(FaultPlan::seeded(0).panic_task(0, 2).attempts(10));
        for attempt in 0..10 {
            assert_eq!(inj.fault_for(0, 2, 0, attempt), Some(FaultKind::Panic));
        }
        assert_eq!(inj.fault_for(0, 2, 0, 10), None);
        assert_eq!(inj.fault_for(0, 1, 0, 0), None);
    }

    #[test]
    fn straggler_is_attempt_independent() {
        let inj = FaultInjector::new(FaultPlan::seeded(3).stragglers(1.0, 4.0));
        assert_eq!(inj.fault_for(0, 0, 0, 0), Some(FaultKind::Straggler(4.0)));
        assert_eq!(inj.fault_for(0, 0, 0, 7), Some(FaultKind::Straggler(4.0)));
    }

    #[test]
    fn stage_error_display() {
        let e = StageError {
            stage: 1,
            partition: 3,
            attempts: 4,
            reason: "injected task panic".into(),
        };
        assert!(e.to_string().contains("stage 1"));
        assert!(e.to_string().contains("4 attempts"));
    }
}
