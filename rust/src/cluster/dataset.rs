//! Immutable partitioned datasets — the RDD stand-in.
//!
//! Spark RDDs are immutable: algorithms that re-partition data (AFS /
//! Jeffers count-and-discard, PSRS shuffle) must create *new* datasets,
//! which is exactly what the paper charges them for (persists, copies).
//! `Dataset` mirrors that: it is cheap to read, and every structural
//! change constructs a fresh `Dataset`.

use std::sync::Arc;

/// An immutable, partitioned collection of keys.
#[derive(Debug, Clone)]
pub struct Dataset<T> {
    partitions: Vec<Arc<Vec<T>>>,
}

impl<T> Dataset<T> {
    /// Build from explicit partitions.
    pub fn from_partitions(parts: Vec<Vec<T>>) -> Self {
        assert!(!parts.is_empty(), "dataset needs at least one partition");
        Self {
            partitions: parts.into_iter().map(Arc::new).collect(),
        }
    }

    /// Evenly split one vector across `p` partitions (generator helper).
    pub fn from_vec(data: Vec<T>, p: usize) -> Self {
        assert!(p > 0);
        let n = data.len();
        let base = n / p;
        let extra = n % p;
        let mut parts: Vec<Vec<T>> = Vec::with_capacity(p);
        let mut it = data.into_iter();
        for i in 0..p {
            let take = base + usize::from(i < extra);
            parts.push(it.by_ref().take(take).collect());
        }
        Self::from_partitions(parts)
    }

    pub fn num_partitions(&self) -> usize {
        self.partitions.len()
    }

    pub fn partition(&self, p: usize) -> &[T] {
        &self.partitions[p]
    }

    /// Total number of records.
    pub fn len(&self) -> u64 {
        self.partitions.iter().map(|p| p.len() as u64).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.partitions.iter().all(|p| p.is_empty())
    }

    /// Per-partition record counts.
    pub fn partition_sizes(&self) -> Vec<usize> {
        self.partitions.iter().map(|p| p.len()).collect()
    }

    /// Iterate over all records in partition order (test/oracle helper —
    /// a real driver never does this).
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.partitions.iter().flat_map(|p| p.iter())
    }
}

impl<T: Clone> Dataset<T> {
    /// Flatten to a single vector (oracle helper).
    pub fn to_vec(&self) -> Vec<T> {
        self.iter().cloned().collect()
    }
}

impl Dataset<i32> {
    /// Payload bytes held by this dataset (for persist accounting).
    pub fn data_bytes(&self) -> u64 {
        self.len() * std::mem::size_of::<i32>() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_balances_with_remainder() {
        let d = Dataset::from_vec((0..10).collect::<Vec<i32>>(), 3);
        assert_eq!(d.partition_sizes(), vec![4, 3, 3]);
        assert_eq!(d.len(), 10);
        assert_eq!(d.to_vec(), (0..10).collect::<Vec<i32>>());
    }

    #[test]
    fn from_vec_more_partitions_than_records() {
        let d = Dataset::from_vec(vec![1, 2], 4);
        assert_eq!(d.partition_sizes(), vec![1, 1, 0, 0]);
        assert!(!d.is_empty());
    }

    #[test]
    fn empty_partitions_allowed() {
        let d: Dataset<i32> = Dataset::from_partitions(vec![vec![], vec![]]);
        assert!(d.is_empty());
        assert_eq!(d.len(), 0);
    }

    #[test]
    fn clone_is_shallow() {
        let d = Dataset::from_vec((0..1000).collect::<Vec<i32>>(), 4);
        let e = d.clone();
        assert_eq!(
            d.partition(0).as_ptr(),
            e.partition(0).as_ptr(),
            "clones must share partition storage"
        );
    }

    #[test]
    fn data_bytes_counts_payload() {
        let d = Dataset::from_vec(vec![1i32; 100], 4);
        assert_eq!(d.data_bytes(), 400);
    }

    #[test]
    #[should_panic]
    fn rejects_zero_partitions() {
        Dataset::<i32>::from_partitions(vec![]);
    }
}
