//! Immutable partitioned datasets — the RDD stand-in — and their
//! streaming extension: **epochs**.
//!
//! Spark RDDs are immutable: algorithms that re-partition data (AFS /
//! Jeffers count-and-discard, PSRS shuffle) must create *new* datasets,
//! which is exactly what the paper charges them for (persists, copies).
//! `Dataset` mirrors that: it is cheap to read, and every structural
//! change constructs a fresh `Dataset`.
//!
//! The streaming service ([`crate::stream`]) leans on the same
//! immutability for its micro-batch append path. Each ingested batch is
//! sealed into an **epoch**: a fresh `Dataset` with its own partitions,
//! never mutated again. Because partitions are `Arc`-shared,
//!
//! * [`Dataset::concat`] builds the "all live epochs" view a streaming
//!   query scans — one logical dataset over every epoch's partitions,
//!   O(#partitions) to construct, **zero data copied**;
//! * [`Dataset::union_partitionwise`] is the compaction primitive: it
//!   physically merges aligned partitions of several epochs into one
//!   sealed epoch (this one *does* copy — it is the store's equivalent of
//!   a persist, and the ingest path charges it as such).
//!
//! Construction is fallible ([`Dataset::from_partitions`] /
//! [`Dataset::from_vec`] return `Result`): an empty micro-batch or a
//! drained stream must surface as a recoverable error at the ingest
//! boundary, not an executor abort.

use std::sync::Arc;

use anyhow::{ensure, Result};

/// An immutable, partitioned collection of keys.
#[derive(Debug, Clone)]
pub struct Dataset<T> {
    partitions: Vec<Arc<Vec<T>>>,
}

impl<T> Dataset<T> {
    /// Build from explicit partitions. Errors on a partitionless dataset
    /// (an unrepresentable cluster layout — the recoverable shape of the
    /// old `assert!`).
    pub fn from_partitions(parts: Vec<Vec<T>>) -> Result<Self> {
        ensure!(!parts.is_empty(), "dataset needs at least one partition");
        Ok(Self {
            partitions: parts.into_iter().map(Arc::new).collect(),
        })
    }

    /// Evenly split one vector across `p` partitions (generator helper).
    /// Errors when `p == 0`.
    pub fn from_vec(data: Vec<T>, p: usize) -> Result<Self> {
        ensure!(p > 0, "dataset needs at least one partition");
        let n = data.len();
        let base = n / p;
        let extra = n % p;
        let mut parts: Vec<Vec<T>> = Vec::with_capacity(p);
        let mut it = data.into_iter();
        for i in 0..p {
            let take = base + usize::from(i < extra);
            parts.push(it.by_ref().take(take).collect());
        }
        Self::from_partitions(parts)
    }

    /// Union of several datasets as one logical dataset: the partitions of
    /// each input, in order, **shared** (`Arc` clones — no data copied).
    /// This is the streaming query path's view over all live epochs: one
    /// `map_partitions` over the result is one scan of every epoch.
    pub fn concat(epochs: &[Dataset<T>]) -> Result<Self> {
        ensure!(!epochs.is_empty(), "concat of zero datasets");
        Ok(Self {
            partitions: epochs
                .iter()
                .flat_map(|d| d.partitions.iter().cloned())
                .collect(),
        })
    }

    pub fn num_partitions(&self) -> usize {
        self.partitions.len()
    }

    pub fn partition(&self, p: usize) -> &[T] {
        &self.partitions[p]
    }

    /// Total number of records.
    pub fn len(&self) -> u64 {
        self.partitions.iter().map(|p| p.len() as u64).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.partitions.iter().all(|p| p.is_empty())
    }

    /// Per-partition record counts.
    pub fn partition_sizes(&self) -> Vec<usize> {
        self.partitions.iter().map(|p| p.len()).collect()
    }

    /// Iterate over all records in partition order (test/oracle helper —
    /// a real driver never does this).
    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.partitions.iter().flat_map(|p| p.iter())
    }
}

impl<T: Clone> Dataset<T> {
    /// Flatten to a single vector (oracle helper).
    pub fn to_vec(&self) -> Vec<T> {
        self.iter().cloned().collect()
    }

    /// Physically merge aligned partitions across datasets: partition `i`
    /// of the result is the concatenation of partition `i` of every
    /// input. All inputs must share a partition count. This is epoch
    /// compaction's data move — unlike [`Dataset::concat`] it copies, so
    /// the caller accounts for it (a persist in the cost model).
    pub fn union_partitionwise(epochs: &[&Dataset<T>]) -> Result<Self> {
        ensure!(!epochs.is_empty(), "union of zero datasets");
        let p = epochs[0].num_partitions();
        ensure!(
            epochs.iter().all(|d| d.num_partitions() == p),
            "partition-count mismatch in union: {:?}",
            epochs.iter().map(|d| d.num_partitions()).collect::<Vec<_>>()
        );
        let parts: Vec<Vec<T>> = (0..p)
            .map(|i| {
                let mut out =
                    Vec::with_capacity(epochs.iter().map(|d| d.partition(i).len()).sum());
                for d in epochs {
                    out.extend_from_slice(d.partition(i));
                }
                out
            })
            .collect();
        Self::from_partitions(parts)
    }
}

impl Dataset<i32> {
    /// Payload bytes held by this dataset (for persist accounting).
    pub fn data_bytes(&self) -> u64 {
        self.len() * std::mem::size_of::<i32>() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_balances_with_remainder() {
        let d = Dataset::from_vec((0..10).collect::<Vec<i32>>(), 3).unwrap();
        assert_eq!(d.partition_sizes(), vec![4, 3, 3]);
        assert_eq!(d.len(), 10);
        assert_eq!(d.to_vec(), (0..10).collect::<Vec<i32>>());
    }

    #[test]
    fn from_vec_more_partitions_than_records() {
        let d = Dataset::from_vec(vec![1, 2], 4).unwrap();
        assert_eq!(d.partition_sizes(), vec![1, 1, 0, 0]);
        assert!(!d.is_empty());
    }

    #[test]
    fn empty_partitions_allowed() {
        let d: Dataset<i32> = Dataset::from_partitions(vec![vec![], vec![]]).unwrap();
        assert!(d.is_empty());
        assert_eq!(d.len(), 0);
    }

    #[test]
    fn clone_is_shallow() {
        let d = Dataset::from_vec((0..1000).collect::<Vec<i32>>(), 4).unwrap();
        let e = d.clone();
        assert_eq!(
            d.partition(0).as_ptr(),
            e.partition(0).as_ptr(),
            "clones must share partition storage"
        );
    }

    #[test]
    fn data_bytes_counts_payload() {
        let d = Dataset::from_vec(vec![1i32; 100], 4).unwrap();
        assert_eq!(d.data_bytes(), 400);
    }

    #[test]
    fn rejects_zero_partitions_recoverably() {
        // a drained stream / empty micro-batch is an Err, not an abort
        assert!(Dataset::<i32>::from_partitions(vec![]).is_err());
        assert!(Dataset::<i32>::from_vec(vec![1, 2], 0).is_err());
    }

    #[test]
    fn concat_shares_partitions() {
        let a = Dataset::from_vec(vec![1, 2, 3, 4], 2).unwrap();
        let b = Dataset::from_vec(vec![5, 6], 2).unwrap();
        let u = Dataset::concat(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(u.num_partitions(), 4);
        assert_eq!(u.len(), 6);
        assert_eq!(u.to_vec(), vec![1, 2, 3, 4, 5, 6]);
        // epoch partitions are shared, not copied
        assert_eq!(u.partition(0).as_ptr(), a.partition(0).as_ptr());
        assert_eq!(u.partition(3).as_ptr(), b.partition(1).as_ptr());
        assert!(Dataset::<i32>::concat(&[]).is_err());
    }

    #[test]
    fn union_partitionwise_merges_aligned() {
        let a = Dataset::from_vec(vec![1, 2, 3, 4], 2).unwrap();
        let b = Dataset::from_vec(vec![5, 6], 2).unwrap();
        let u = Dataset::union_partitionwise(&[&a, &b]).unwrap();
        assert_eq!(u.num_partitions(), 2);
        assert_eq!(u.partition(0), &[1, 2, 5]);
        assert_eq!(u.partition(1), &[3, 4, 6]);
        // mismatched partition counts are a recoverable error
        let c = Dataset::from_vec(vec![7], 3).unwrap();
        assert!(Dataset::union_partitionwise(&[&a, &c]).is_err());
    }
}
