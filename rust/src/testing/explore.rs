//! The explorer's scheduler core: cooperative token passing, schedule
//! recording/replay, bounded-DFS enumeration, and seeded-random
//! sampling. See the module doc of [`crate::testing`] for the model.

use std::collections::BTreeMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use crate::data::pcg::Pcg64;

use super::SyncPoint;

/// Panic payload used to unwind parked tasks when a run is aborted
/// (deadlock, livelock guard, schedule cap). Task wrappers recognize it
/// and do not double-report; the abort reason itself is recorded once.
const ABORT_MSG: &str = "gkselect-explorer: schedule aborted";

/// Hard cap on scheduler grants per run — a livelock backstop far above
/// any real schedule (tasks yield a handful of times each).
const MAX_GRANTS: usize = 100_000;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Status {
    /// Parked at a yield (or not yet granted its first slice); eligible.
    Ready,
    /// Holds the run token.
    Running,
    /// Parked after a failed `try_lock`; eligible only when no task is
    /// `Ready`.
    Contended,
    /// Closure returned or unwound.
    Done,
}

struct FailPoint {
    label: String,
    /// 1-based arrival count at `label` (across all tasks) that panics.
    hit: u64,
}

struct SchedState {
    names: Vec<String>,
    status: Vec<Status>,
    registered: usize,
    current: Option<usize>,
    /// Prescribed decisions (replay / DFS prefix); beyond it the mode
    /// decides (DFS: first candidate; random: seeded pick).
    cursor: Vec<usize>,
    /// Index of the next decision to take from `cursor`.
    step: usize,
    /// `(chosen, candidates)` at every branch point (>1 candidate).
    decisions: Vec<(usize, usize)>,
    /// Human-readable arrival log: `task@point`, in execution order.
    trace: Vec<String>,
    grants: usize,
    /// Consecutive grants to `Contended` tasks with no intervening
    /// progress; exceeding the task count means real deadlock.
    contended_spins: usize,
    rng: Option<Pcg64>,
    failpoint: Option<FailPoint>,
    /// Arrival counts per sync-point label (failpoint bookkeeping).
    hits: BTreeMap<String, u64>,
    aborted: Option<String>,
}

pub(super) struct Core {
    state: Mutex<SchedState>,
    cv: Condvar,
}

fn relock<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

impl Core {
    fn new(
        names: Vec<String>,
        cursor: Vec<usize>,
        rng: Option<Pcg64>,
        failpoint: Option<FailPoint>,
    ) -> Self {
        let n = names.len();
        Self {
            state: Mutex::new(SchedState {
                names,
                status: vec![Status::Ready; n],
                registered: 0,
                current: None,
                cursor,
                step: 0,
                decisions: Vec::new(),
                trace: Vec::new(),
                grants: 0,
                contended_spins: 0,
                rng,
                failpoint,
                hits: BTreeMap::new(),
                aborted: None,
            }),
            cv: Condvar::new(),
        }
    }

    /// Choose the next token holder among eligible tasks (ascending id,
    /// `Ready` before `Contended`), consuming one prescribed decision if
    /// the cursor still covers this branch point. Single-candidate picks
    /// are forced and not recorded, so a schedule is exactly its branch
    /// decisions.
    fn pick_next(&self, st: &mut SchedState) {
        st.grants += 1;
        if st.grants > MAX_GRANTS {
            self.abort(st, "livelock: grant cap exceeded".to_string());
            return;
        }
        let ready: Vec<usize> = (0..st.status.len())
            .filter(|&i| st.status[i] == Status::Ready)
            .collect();
        let candidates = if ready.is_empty() {
            let contended: Vec<usize> = (0..st.status.len())
                .filter(|&i| st.status[i] == Status::Contended)
                .collect();
            if contended.is_empty() {
                st.current = None; // every task Done: run complete
                return;
            }
            if st.contended_spins > st.status.len() + 1 {
                let why = format!(
                    "deadlock: all live tasks contended: {:?}",
                    contended
                        .iter()
                        .map(|&i| st.names[i].as_str())
                        .collect::<Vec<_>>()
                );
                self.abort(st, why);
                return;
            }
            st.contended_spins += 1;
            contended
        } else {
            candidates_progress(st);
            ready
        };
        let chosen = if candidates.len() == 1 {
            candidates[0]
        } else {
            let idx = if st.step < st.cursor.len() {
                st.cursor[st.step].min(candidates.len() - 1)
            } else if let Some(rng) = &mut st.rng {
                (rng.next_u64() % candidates.len() as u64) as usize
            } else {
                0
            };
            st.decisions.push((idx, candidates.len()));
            st.step += 1;
            candidates[idx]
        };
        st.current = Some(chosen);
    }

    fn abort(&self, st: &mut SchedState, why: String) {
        if st.aborted.is_none() {
            st.aborted = Some(why);
        }
        st.current = None;
    }

    /// Task-thread entry: mark registered and park until first granted.
    fn register_and_wait(&self, id: usize) {
        let mut st = relock(&self.state);
        st.registered += 1;
        self.cv.notify_all();
        self.wait_for_token(st, id);
    }

    /// Driver: wait for all tasks to register, then grant the first
    /// token (the first branch point: which task starts).
    fn start(&self) {
        let mut st = relock(&self.state);
        while st.registered < st.status.len() {
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        self.pick_next(&mut st);
        self.cv.notify_all();
    }

    fn wait_for_token(&self, mut st: MutexGuard<'_, SchedState>, id: usize) {
        loop {
            if st.aborted.is_some() {
                drop(st);
                panic!("{ABORT_MSG}");
            }
            if st.current == Some(id) {
                st.status[id] = Status::Running;
                return;
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    pub(super) fn yield_at(&self, id: usize, point: SyncPoint, contended: bool) {
        let mut st = relock(&self.state);
        if st.aborted.is_some() {
            drop(st);
            panic!("{ABORT_MSG}");
        }
        let fire = {
            // Reborrow the guard once: field-disjoint access below.
            let s = &mut *st;
            let entry = format!(
                "{}@{}{}",
                s.names[id],
                point.label(),
                if contended { "!" } else { "" }
            );
            s.trace.push(entry);
            if contended {
                false
            } else {
                // Failpoints count real arrivals, not contention retries.
                let count = s.hits.entry(point.label().to_string()).or_insert(0);
                *count += 1;
                let count = *count;
                s.failpoint
                    .as_ref()
                    .is_some_and(|fp| fp.label == point.label() && fp.hit == count)
            }
        };
        if fire {
            let label = point.label();
            drop(st);
            panic!("failpoint: injected panic at {label}");
        }
        st.status[id] = if contended { Status::Contended } else { Status::Ready };
        self.pick_next(&mut st);
        self.cv.notify_all();
        self.wait_for_token(st, id);
    }

    /// Task wrapper epilogue: the task is Done (returned or unwound);
    /// hand the token onward if it held one.
    fn finish(&self, id: usize) {
        let mut st = relock(&self.state);
        {
            let s = &mut *st;
            s.status[id] = Status::Done;
            let entry = format!("{}@done", s.names[id]);
            s.trace.push(entry);
            s.contended_spins = 0;
        }
        if st.current == Some(id) && st.aborted.is_none() {
            self.pick_next(&mut st);
        }
        self.cv.notify_all();
    }
}

/// Any grant to a `Ready` task is progress: reset the deadlock counter.
fn candidates_progress(st: &mut SchedState) {
    st.contended_spins = 0;
}

/// A task's registration handle, stored in the thread-local
/// [`super::PARTICIPANT`] slot for the closure's lifetime.
#[derive(Clone)]
pub(crate) struct Participant {
    core: Arc<Core>,
    id: usize,
}

impl Participant {
    pub(super) fn yield_at(&self, point: SyncPoint, contended: bool) {
        self.core.yield_at(self.id, point, contended);
    }
}

/// One schedule's task roster, filled by the scenario setup closure.
/// Each run gets a fresh roster (and fresh captured state), so runs are
/// independent and replay is exact.
#[derive(Default)]
pub struct TaskSet {
    tasks: Vec<(String, Box<dyn FnOnce() + Send>)>,
    checks: Vec<Box<dyn FnOnce()>>,
}

impl TaskSet {
    /// Add a participating task. Its yield points (service sync points
    /// and [`super::checkpoint`]s) become the schedule's switch sites.
    pub fn spawn(&mut self, name: &str, f: impl FnOnce() + Send + 'static) {
        self.tasks.push((name.to_string(), Box::new(f)));
    }

    /// Add a final-state assertion, run on the driver after every task
    /// finished. Panics are recorded as schedule failures.
    pub fn check(&mut self, f: impl FnOnce() + 'static) {
        self.checks.push(Box::new(f));
    }
}

/// One run's full record: the branch decisions taken (the replayable
/// schedule), each branch's candidate count (DFS bookkeeping), the
/// arrival trace, and any failures (task/check panics, aborts).
pub struct RunOutcome {
    pub decisions: Vec<usize>,
    pub counts: Vec<usize>,
    pub trace: Vec<String>,
    pub failures: Vec<String>,
}

/// A failing schedule, replayable verbatim via [`Explorer::replay`].
pub struct ScheduleFailure {
    /// The branch decisions to feed back to [`Explorer::replay`].
    pub schedule: Vec<usize>,
    /// Panic messages from tasks and checks (abort reasons included).
    pub messages: Vec<String>,
    /// Arrival trace (`task@point`, `!` marks contention retries).
    pub trace: Vec<String>,
}

/// Result of [`Explorer::explore`].
pub struct Exploration {
    /// Distinct schedules run.
    pub schedules: usize,
    /// Exhaustive mode only: the whole schedule tree fit under the cap.
    pub complete: bool,
    pub failures: Vec<ScheduleFailure>,
}

impl Exploration {
    /// Assert every explored schedule passed, printing the first
    /// failing schedule's decisions and trace otherwise.
    pub fn assert_no_failures(&self) {
        if let Some(f) = self.failures.first() {
            panic!(
                "{} of {} schedules failed; first: schedule {:?}\n  messages: {:#?}\n  trace: {:?}",
                self.failures.len(),
                self.schedules,
                f.schedule,
                f.messages,
                f.trace
            );
        }
    }
}

enum Mode {
    /// Bounded DFS over the schedule tree, first candidate first.
    Exhaustive,
    /// Seeded random sampling: `schedules` independent runs.
    Random { seed: u64, schedules: usize },
}

/// Schedule exploration driver. See [`crate::testing`] for the model
/// and an end-to-end example.
pub struct Explorer {
    mode: Mode,
    max_schedules: usize,
    failpoint: Option<(String, u64)>,
}

impl Explorer {
    /// Bounded-DFS exhaustive exploration (default cap: 1000 schedules;
    /// see [`Self::max_schedules`]).
    pub fn exhaustive() -> Self {
        Self {
            mode: Mode::Exhaustive,
            max_schedules: 1000,
            failpoint: None,
        }
    }

    /// Seeded random sampling of `schedules` runs. Distinct decision
    /// vectors are counted once in [`Exploration::schedules`].
    pub fn random(seed: u64, schedules: usize) -> Self {
        Self {
            mode: Mode::Random { seed, schedules },
            max_schedules: schedules,
            failpoint: None,
        }
    }

    /// Cap on schedules run in exhaustive mode (the tree is usually far
    /// larger than any budget; `complete` reports whether it fit).
    pub fn max_schedules(mut self, cap: usize) -> Self {
        self.max_schedules = cap.max(1);
        self
    }

    /// Panic at the `hit`-th arrival (1-based, across tasks) of the
    /// sync point labeled `label` — see [`SyncPoint::label`].
    pub fn failpoint(mut self, label: &str, hit: u64) -> Self {
        self.failpoint = Some((label.to_string(), hit));
        self
    }

    /// Run one schedule: prescribed `cursor` decisions first, then
    /// mode-default picks. `setup` builds the roster fresh.
    fn run_once(
        &self,
        cursor: &[usize],
        rng: Option<Pcg64>,
        setup: &mut impl FnMut(&mut TaskSet),
    ) -> RunOutcome {
        let mut ts = TaskSet::default();
        setup(&mut ts);
        let TaskSet { tasks, checks } = ts;
        assert!(!tasks.is_empty(), "explorer scenario spawned no tasks");
        let names: Vec<String> = tasks.iter().map(|(n, _)| n.clone()).collect();
        let failpoint = self
            .failpoint
            .as_ref()
            .map(|(label, hit)| FailPoint { label: label.clone(), hit: *hit });
        let core = Arc::new(Core::new(names, cursor.to_vec(), rng, failpoint));
        let failures = Mutex::new(Vec::new());

        super::active_explorers().fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        std::thread::scope(|scope| {
            for (id, (name, f)) in tasks.into_iter().enumerate() {
                let core = &core;
                let failures = &failures;
                scope.spawn(move || {
                    super::set_participant(Some(Participant { core: core.clone(), id }));
                    let result = catch_unwind(AssertUnwindSafe(|| {
                        core.register_and_wait(id);
                        f();
                    }));
                    super::set_participant(None);
                    core.finish(id);
                    if let Err(payload) = result {
                        let msg = panic_message(payload.as_ref());
                        if msg != ABORT_MSG {
                            relock(failures).push(format!("task {name}: {msg}"));
                        }
                    }
                });
            }
            core.start();
        });
        super::active_explorers().fetch_sub(1, std::sync::atomic::Ordering::SeqCst);

        let mut failures = relock(&failures).drain(..).collect::<Vec<_>>();
        for check in checks {
            if let Err(payload) = catch_unwind(AssertUnwindSafe(check)) {
                failures.push(format!("check: {}", panic_message(payload.as_ref())));
            }
        }
        let st = relock(&core.state);
        if let Some(why) = &st.aborted {
            failures.push(format!("aborted: {why}"));
        }
        RunOutcome {
            decisions: st.decisions.iter().map(|&(chosen, _)| chosen).collect(),
            counts: st.decisions.iter().map(|&(_, count)| count).collect(),
            trace: st.trace.clone(),
            failures,
        }
    }

    /// Explore schedules of the scenario `setup` per the mode; every
    /// failing schedule comes back replayable.
    pub fn explore(&self, mut setup: impl FnMut(&mut TaskSet)) -> Exploration {
        let mut failures = Vec::new();
        match self.mode {
            Mode::Exhaustive => {
                let mut cursor: Vec<usize> = Vec::new();
                let mut schedules = 0;
                let mut complete = false;
                loop {
                    if schedules >= self.max_schedules {
                        break;
                    }
                    let out = self.run_once(&cursor, None, &mut setup);
                    schedules += 1;
                    if !out.failures.is_empty() {
                        failures.push(ScheduleFailure {
                            schedule: out.decisions.clone(),
                            messages: out.failures,
                            trace: out.trace,
                        });
                    }
                    // Backtrack: bump the deepest branch with an
                    // untaken sibling; none left ⇒ the tree is spent.
                    let next = (0..out.decisions.len()).rev().find_map(|i| {
                        (out.decisions[i] + 1 < out.counts[i]).then(|| {
                            let mut c = out.decisions[..i].to_vec();
                            c.push(out.decisions[i] + 1);
                            c
                        })
                    });
                    match next {
                        Some(c) => cursor = c,
                        None => {
                            complete = true;
                            break;
                        }
                    }
                }
                Exploration { schedules, complete, failures }
            }
            Mode::Random { seed, schedules } => {
                let mut distinct = std::collections::BTreeSet::new();
                for k in 0..schedules {
                    let rng = Pcg64::new(seed, 0x5EED ^ k as u64);
                    let out = self.run_once(&[], Some(rng), &mut setup);
                    distinct.insert(out.decisions.clone());
                    if !out.failures.is_empty() {
                        failures.push(ScheduleFailure {
                            schedule: out.decisions.clone(),
                            messages: out.failures,
                            trace: out.trace,
                        });
                    }
                }
                Exploration {
                    schedules: distinct.len(),
                    complete: false,
                    failures,
                }
            }
        }
    }

    /// Replay one schedule verbatim: the recorded decisions drive every
    /// branch point (forced picks replay implicitly). Deterministic for
    /// deterministic task bodies — the reproduction path for failures
    /// found by [`Self::explore`].
    pub fn replay(
        &self,
        schedule: &[usize],
        mut setup: impl FnMut(&mut TaskSet),
    ) -> RunOutcome {
        self.run_once(schedule, None, &mut setup)
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::super::checkpoint;
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    /// Silence the default panic hook around explorations that *expect*
    /// failing schedules (same pattern as the pool's panic tests).
    fn with_quiet_panics<T>(f: impl FnOnce() -> T) -> T {
        let hook = std::panic::take_hook();
        std::panic::set_hook(Box::new(|_| {})); // silence expected unwinds
        let out = f();
        std::panic::set_hook(hook);
        out
    }

    fn two_step_tasks(tasks: &mut TaskSet, log: &Arc<Mutex<Vec<&'static str>>>) {
        for name in ["a", "b"] {
            let log = log.clone();
            tasks.spawn(name, move || {
                relock(&log).push(name);
                checkpoint("mid");
                relock(&log).push(name);
            });
        }
    }

    #[test]
    fn exhaustive_enumerates_all_interleavings_of_two_two_step_tasks() {
        // Two tasks × two segments each: 4!/(2!·2!) = 6 interleavings.
        let mut seen = std::collections::BTreeSet::new();
        let explorer = Explorer::exhaustive();
        let exploration = explorer.explore(|tasks| {
            let log = Arc::new(Mutex::new(Vec::new()));
            two_step_tasks(tasks, &log);
            let log = log.clone();
            tasks.check(move || {
                assert_eq!(relock(&log).len(), 4);
            });
        });
        exploration.assert_no_failures();
        assert!(exploration.complete, "tiny tree must be fully explored");
        assert_eq!(exploration.schedules, 6);

        // Re-drive each schedule via replay and collect the actual
        // segment orders: all 6 must be distinct.
        let mut cursor: Vec<usize> = Vec::new();
        loop {
            let log = Arc::new(Mutex::new(Vec::new()));
            let out = explorer.replay(&cursor, |tasks| two_step_tasks(tasks, &log));
            assert!(out.failures.is_empty());
            seen.insert(relock(&log).clone());
            let next = (0..out.decisions.len()).rev().find_map(|i| {
                (out.decisions[i] + 1 < out.counts[i]).then(|| {
                    let mut c = out.decisions[..i].to_vec();
                    c.push(out.decisions[i] + 1);
                    c
                })
            });
            match next {
                Some(c) => cursor = c,
                None => break,
            }
        }
        assert_eq!(seen.len(), 6, "every schedule is a distinct interleaving");
    }

    #[test]
    fn explorer_finds_and_replays_a_lost_update() {
        let scenario = |tasks: &mut TaskSet| {
            let x = Arc::new(AtomicU64::new(0));
            for name in ["w1", "w2"] {
                let x = x.clone();
                tasks.spawn(name, move || {
                    let seen = x.load(Ordering::SeqCst);
                    checkpoint("rmw"); // the race window
                    x.store(seen + 1, Ordering::SeqCst);
                });
            }
            let x = x.clone();
            tasks.check(move || {
                assert_eq!(x.load(Ordering::SeqCst), 2, "lost update");
            });
        };
        let exploration = with_quiet_panics(|| Explorer::exhaustive().explore(scenario));
        assert!(
            !exploration.failures.is_empty(),
            "exhaustive exploration must find the lost update"
        );
        assert!(
            exploration.failures.len() < exploration.schedules,
            "some schedules (run-to-completion orders) must pass"
        );
        // The failing schedule is a replayable artifact: driving the
        // recorded decisions again fails the same way, every time.
        let failing = &exploration.failures[0];
        for _ in 0..3 {
            let replayed =
                with_quiet_panics(|| Explorer::exhaustive().replay(&failing.schedule, scenario));
            assert_eq!(replayed.failures, failing.messages);
            assert_eq!(replayed.trace, failing.trace);
        }
    }

    #[test]
    fn random_mode_is_deterministic_per_seed() {
        let scenario = |tasks: &mut TaskSet| {
            let log = Arc::new(Mutex::new(Vec::new()));
            two_step_tasks(tasks, &log);
        };
        let a = Explorer::random(7, 12).explore(scenario);
        let b = Explorer::random(7, 12).explore(scenario);
        assert_eq!(a.schedules, b.schedules);
        assert!(a.failures.is_empty() && b.failures.is_empty());
        assert!(a.schedules >= 2, "12 seeded runs of a 6-leaf tree hit ≥ 2 schedules");
    }

    #[test]
    fn failpoint_injects_a_panic_at_the_named_arrival() {
        let reached = Arc::new(AtomicU64::new(0));
        let reached_in = reached.clone();
        let out = with_quiet_panics(|| {
            Explorer::exhaustive()
                .max_schedules(1)
                .failpoint("fp", 2)
                .explore(move |tasks| {
                    let reached = reached_in.clone();
                    tasks.spawn("t", move || {
                        checkpoint("fp");
                        reached.fetch_add(1, Ordering::SeqCst);
                        checkpoint("fp"); // second arrival: panics here
                        reached.fetch_add(1, Ordering::SeqCst);
                    });
                })
        });
        assert_eq!(out.failures.len(), 1);
        assert!(
            out.failures[0].messages[0].contains("failpoint"),
            "got: {:?}",
            out.failures[0].messages
        );
        assert_eq!(reached.load(Ordering::SeqCst), 1, "panic fired between the arrivals");
    }

    #[test]
    fn contended_tasks_are_schedulable_not_deadlocks() {
        // Two tasks fight over one real mutex held across a yield —
        // the writer-token shape. Every schedule must complete.
        let exploration = Explorer::exhaustive().explore(|tasks| {
            let m = Arc::new(Mutex::new(0u64));
            for name in ["w1", "w2"] {
                let m = m.clone();
                tasks.spawn(name, move || {
                    let mut guard = loop {
                        match m.try_lock() {
                            Ok(g) => break g,
                            Err(std::sync::TryLockError::Poisoned(e)) => break e.into_inner(),
                            Err(std::sync::TryLockError::WouldBlock) => {
                                super::super::yield_contended(SyncPoint::Checkpoint("lock"))
                            }
                        }
                    };
                    *guard += 1;
                    checkpoint("held"); // token yielded while holding the lock
                    *guard += 1;
                });
            }
            let m = m.clone();
            tasks.check(move || assert_eq!(*relock(&m), 4));
        });
        exploration.assert_no_failures();
        assert!(exploration.complete);
        assert!(exploration.schedules >= 2);
    }
}
