//! Deterministic interleaving explorer for the concurrent service
//! layer.
//!
//! PR 9 made the repo genuinely concurrent: [`crate::service`]
//! publishes `Arc<StreamSnapshot>`s across threads, shards guard writer
//! tokens with mutexes, and the merged-sketch memo lives in an
//! `OnceLock`. A handful of racing-thread tests exercise a handful of
//! schedules; this module makes the schedule itself the test input.
//!
//! The model is cooperative token passing over real OS threads: every
//! participating task parks until the scheduler hands it the **run
//! token**, executes until its next instrumented synchronization point
//! ([`SyncPoint`]), and yields the token back. Exactly one task runs at
//! a time, every context switch happens at an instrumented point, and
//! each switch target is a recorded **decision** — so a whole run is
//! reduced to a vector of small integers that can be enumerated
//! exhaustively (bounded DFS over the schedule tree), sampled
//! seed-randomly, or replayed verbatim. A failing schedule is a
//! first-class artifact: [`ScheduleFailure::schedule`] fed back through
//! [`Explorer::replay`] reproduces the exact interleaving, every time.
//!
//! The service layer's synchronization points — `lock_writer`,
//! `publish`, snapshot `pin`, the `OnceLock` memo init, the registry
//! absorb — call [`yield_point`] inline. The hook is two relaxed loads
//! when no explorer is armed and a no-op for unregistered threads (the
//! executor pool's internal workers, unrelated tests running in the
//! same binary), so production and ordinary test paths pay nothing.
//!
//! Writer tokens are the only lock *held across* yield points, so
//! [`StreamEntry::lock_writer`] acquires with a `try_lock` loop that
//! reports contention via the crate-internal `yield_contended`: a
//! blocked task is
//! deprioritized (never granted while any other task can run), which
//! turns would-be deadlocks into schedulable waiting. Every other
//! instrumented lock is released before the next yield, so a plain
//! pre-acquisition yield point is sound for them.
//!
//! Failure injection: [`Explorer::failpoint`] arms a panic at the Nth
//! arrival of a named sync point, which is how the mutex-poisoning
//! recovery contract of the service shard layer is tested through the
//! real ingest path.
//!
//! [`StreamEntry::lock_writer`]: crate::service
//!
//! ```no_run
//! use gkselect::testing::{checkpoint, Explorer};
//! use std::sync::atomic::{AtomicU64, Ordering};
//! use std::sync::Arc;
//!
//! let found = Explorer::exhaustive().explore(|tasks| {
//!     let x = Arc::new(AtomicU64::new(0));
//!     for name in ["a", "b"] {
//!         let x = x.clone();
//!         tasks.spawn(name, move || {
//!             let seen = x.load(Ordering::SeqCst);
//!             checkpoint("between-read-and-write"); // racy on purpose
//!             x.store(seen + 1, Ordering::SeqCst);
//!         });
//!     }
//!     let x = x.clone();
//!     tasks.check(move || assert_eq!(x.load(Ordering::SeqCst), 2));
//! });
//! assert!(!found.failures.is_empty(), "explorer must find the lost update");
//! ```

mod explore;

pub use explore::{Exploration, Explorer, RunOutcome, ScheduleFailure, TaskSet};

use std::cell::RefCell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// The service layer's instrumented synchronization points. Each
/// variant marks one acquisition/initialization site; the explorer may
/// switch tasks at any of them.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncPoint {
    /// `StreamEntry::lock_writer` — acquiring the single-writer token.
    LockWriter,
    /// `StreamEntry::publish` — swapping the published snapshot pointer.
    Publish,
    /// `StreamEntry::pin` — cloning the published snapshot out.
    Pin,
    /// `StreamSnapshot::merged_sketch` — the `OnceLock` memo init.
    MemoInit,
    /// `QuantileService::absorb` — taking the registry lock for
    /// `absorb_with`.
    RegistryAbsorb,
    /// A test-defined checkpoint (see [`checkpoint`]); the label names
    /// it in traces and failpoints.
    Checkpoint(&'static str),
}

impl SyncPoint {
    /// Stable label used in schedule traces and failpoint matching.
    pub fn label(&self) -> &'static str {
        match self {
            SyncPoint::LockWriter => "lock_writer",
            SyncPoint::Publish => "publish",
            SyncPoint::Pin => "pin",
            SyncPoint::MemoInit => "memo_init",
            SyncPoint::RegistryAbsorb => "registry_absorb",
            SyncPoint::Checkpoint(label) => label,
        }
    }
}

/// Count of explorers currently mid-run, across all threads. The fast
/// path of every hook: one relaxed load, zero when nothing explores.
static ACTIVE_EXPLORERS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// The current thread's registration with a running explorer, if
    /// any. Set by the task wrapper for the closure's whole lifetime.
    static PARTICIPANT: RefCell<Option<explore::Participant>> = const { RefCell::new(None) };
}

pub(crate) fn set_participant(p: Option<explore::Participant>) {
    PARTICIPANT.with(|slot| *slot.borrow_mut() = p);
}

pub(crate) fn active_explorers() -> &'static AtomicUsize {
    &ACTIVE_EXPLORERS
}

fn current_participant() -> Option<explore::Participant> {
    if ACTIVE_EXPLORERS.load(Ordering::Relaxed) == 0 {
        return None;
    }
    PARTICIPANT.with(|slot| slot.borrow().clone())
}

/// True iff the calling thread is a registered task of a running
/// explorer — the signal for instrumented sites to switch to their
/// explorable acquisition path (e.g. the `try_lock` loop in
/// `lock_writer`).
pub(crate) fn scheduled() -> bool {
    current_participant().is_some()
}

/// Instrumented synchronization point: if the calling thread is a
/// registered explorer task, yield the run token here (the scheduler
/// picks who runs next — possibly this task again); otherwise do
/// nothing. Sites must not hold any lock across this call unless the
/// contended acquisition of that lock also yields (today only the
/// writer token does, via the crate-internal `yield_contended`).
pub fn yield_point(point: SyncPoint) {
    if let Some(p) = current_participant() {
        p.yield_at(point, false);
    }
}

/// Contention yield: the calling task failed a `try_lock` on an
/// instrumented lock. The scheduler marks it blocked — it is granted
/// the token again (to retry) only when no unblocked task can run —
/// and detects genuine deadlock if every live task ends up here.
pub(crate) fn yield_contended(point: SyncPoint) {
    if let Some(p) = current_participant() {
        p.yield_at(point, true);
    }
}

/// Test-defined yield point, for instrumenting doubles and fixtures
/// outside the service layer (e.g. the deliberately broken memo store
/// the explorer self-test catches). No-op outside explorer tasks.
pub fn checkpoint(label: &'static str) {
    yield_point(SyncPoint::Checkpoint(label));
}
