//! Kernel backend trait + the native reference implementation.
//!
//! The fused hot path is [`KernelBackend::band_extract`]: one chunked
//! pass that classifies every key against the broadcast pivot **and**
//! the sketch-derived candidate band `[lo, hi]`, collecting the open-band
//! values as it goes. Endpoint runs are *counted*, never materialized, so
//! duplicate-heavy data (zipf) cannot blow the candidate buffer — the
//! extracted set is `{x : lo < x < hi}`, whose size the GK invariant
//! bounds by O(εn) regardless of duplication.
//!
//! # Scalar oracle vs SIMD tile
//!
//! [`NativeBackend`] carries two interchangeable implementations of the
//! fused scan and picks one **once, at construction**:
//!
//! * the portable scalar tile body ([`BandExtract::tally`] per element,
//!   run by [`super::simd`]'s shared tile walker) — the authoritative
//!   oracle, the default on targets without a SIMD tile, and the
//!   `ForceScalar` pin;
//! * the explicit SIMD tile in [`super::simd`] — AVX2 (8 × i32) or SSE2
//!   (4 × i32) via `std::arch`, selected by
//!   `is_x86_feature_detected!` at runtime, vectorizing the six-counter
//!   classification with compare + accumulate and compressing the
//!   open-band mask into the candidate buffer.
//!
//! # Dispatch rules
//!
//! Resolution happens in [`SimdDispatch::resolve`] from a
//! [`SimdPolicy`], looked up in this order (first hit wins):
//!
//! 1. `--simd auto|scalar|force` on the `repro` CLI;
//! 2. `[runtime] simd = "..."` in repro.toml;
//! 3. the `GKSELECT_SIMD` environment variable (the CI pin);
//! 4. default: `Auto` — the widest tile this CPU supports.
//!
//! Both paths are bit-identical — counts, candidate order, overflow
//! points (the budget is checked at the same [`BAND_CHUNK`] tile
//! boundaries) — property-tested in `tests/proptest_simd.rs` and pinned
//! by the `GKSELECT_SIMD={scalar,force}` CI matrix. The active lane
//! width is reported through [`KernelBackend::simd_lane_width`] into
//! `MetricsReport` and the `BENCH_gk_select.json` records.

use super::simd::{self, SimdDispatch, SimdPolicy};
use crate::cluster::netmodel::{NetSize, CONTAINER_OVERHEAD};
use crate::Key;

/// Keys per tile of the fused scan: counts vectorize within a tile while
/// the (rare) extraction appends stay L1-resident. The scalar and SIMD
/// paths share this constant so candidate-budget overflow trips at the
/// same point in the stream on both.
pub const BAND_CHUNK: usize = 4096;

/// Three-way pivot classification counts (lt, eq, gt).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PivotCounts {
    pub lt: u64,
    pub eq: u64,
    pub gt: u64,
}

impl PivotCounts {
    pub fn total(&self) -> u64 {
        self.lt + self.eq + self.gt
    }

    pub fn add(&mut self, other: PivotCounts) {
        self.lt += other.lt;
        self.eq += other.eq;
        self.gt += other.gt;
    }
}

/// Band classification counts (below lo, inside [lo, hi], above hi).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct BandCounts {
    pub below: u64,
    pub band: u64,
    pub above: u64,
}

/// Five-way classification against the band `[lo, hi]`, with endpoint
/// runs split out so duplicates are counted instead of copied.
///
/// When `lo == hi` the two endpoint counters would alias; `eq_hi` is
/// defined to be 0 in that case so the five buckets always partition the
/// input.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct BandStats {
    /// `|{x < lo}|`.
    pub below: u64,
    /// `|{x == lo}|`.
    pub eq_lo: u64,
    /// `|{lo < x < hi}|` — the extracted candidates.
    pub inner: u64,
    /// `|{x == hi}|` (0 when `lo == hi`).
    pub eq_hi: u64,
    /// `|{x > hi}|`.
    pub above: u64,
}

impl BandStats {
    pub fn total(&self) -> u64 {
        self.below + self.eq_lo + self.inner + self.eq_hi + self.above
    }

    pub fn add(&mut self, other: BandStats) {
        self.below += other.below;
        self.eq_lo += other.eq_lo;
        self.inner += other.inner;
        self.eq_hi += other.eq_hi;
        self.above += other.above;
    }
}

/// Result of one fused `band_extract` pass: pivot counts, band counts,
/// and the materialized open-band candidates.
///
/// `overflow` marks a pass (or merge) whose candidate set exceeded the
/// caller's budget: candidates are dropped to keep memory and traffic
/// bounded, but **all counts stay complete**, so the caller can still
/// take the eq-run exit or fall back to a second extraction round.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct BandExtract {
    pub pivot: PivotCounts,
    pub band: BandStats,
    pub candidates: Vec<Key>,
    pub overflow: bool,
}

impl BandExtract {
    /// One element of the fused classification: accumulate the pivot and
    /// band counters (except `inner`) and report whether `v` lies in the
    /// open band. Shared by every scan loop so the native single/multi
    /// and count-only/extracting variants cannot drift apart.
    #[inline(always)]
    pub fn tally(&mut self, v: Key, pivot: Key, lo: Key, hi: Key) -> bool {
        self.pivot.lt += u64::from(v < pivot);
        self.pivot.eq += u64::from(v == pivot);
        self.band.below += u64::from(v < lo);
        self.band.eq_lo += u64::from(v == lo);
        self.band.eq_hi += u64::from(v == hi);
        v > lo && v < hi
    }

    /// Derive the arithmetic counters after a full pass over `n` keys:
    /// `gt`/`above` from the partition identity, and the `lo == hi`
    /// normalization (the endpoint counters alias one run; keep `eq_lo`).
    pub fn finalize(&mut self, n: u64, lo: Key, hi: Key) {
        self.pivot.gt = n - self.pivot.lt - self.pivot.eq;
        if lo == hi {
            self.band.eq_hi = 0;
        }
        self.band.above =
            n - self.band.below - self.band.eq_lo - self.band.inner - self.band.eq_hi;
    }

    /// treeReduce combiner: counts add; candidates concatenate unless
    /// either side (or the merged total) blew the budget.
    pub fn merge(mut self, other: BandExtract, budget: usize) -> BandExtract {
        self.pivot.add(other.pivot);
        self.band.add(other.band);
        if self.overflow || other.overflow {
            self.overflow = true;
            self.candidates = Vec::new();
        } else {
            self.candidates.extend_from_slice(&other.candidates);
            if self.candidates.len() > budget {
                self.overflow = true;
                self.candidates = Vec::new();
            }
        }
        self
    }
}

impl NetSize for BandExtract {
    fn net_bytes(&self) -> u64 {
        // 8 u64 counters + overflow flag + candidate payload
        CONTAINER_OVERHEAD
            + 8 * 8
            + 1
            + CONTAINER_OVERHEAD
            + std::mem::size_of::<Key>() as u64 * self.candidates.len() as u64
    }
}

/// The executor-side compute hot spots, as implemented by either the
/// AOT/PJRT path or native rust. All counts are over the full slice.
///
/// Methods take `&self` and the trait requires `Send + Sync`: one
/// backend instance is shared by every executor thread of the pool
/// (`ExecMode::Threads` runs partition closures concurrently) and, in
/// the serving layer, by every client thread of a
/// [`crate::service::QuantileService`] (one `Arc<dyn KernelBackend>`
/// serves all readers and writers), so any backend-internal scratch
/// state must use interior mutability.
pub trait KernelBackend: Send + Sync {
    /// `[|{x < pivot}|, |{x == pivot}|, |{x > pivot}|]`.
    fn count_pivot(&self, data: &[Key], pivot: Key) -> PivotCounts;

    /// `[|{x < lo}|, |{lo <= x <= hi}|, |{x > hi}|]`.
    fn band_count(&self, data: &[Key], lo: Key, hi: Key) -> BandCounts;

    /// Equi-width histogram over `[lo, lo + nbins*width)`, out-of-range
    /// clamped into the edge bins.
    fn histogram(&self, data: &[Key], lo: i64, width: i64, nbins: usize) -> Vec<u64>;

    /// `(min, max)` or `None` when empty.
    fn minmax(&self, data: &[Key]) -> Option<(Key, Key)>;

    /// Fused scan: pivot counts + band counts + open-band extraction in
    /// one pass (requires `lo ≤ hi`). At most `budget` candidates are
    /// collected; past that the pass keeps counting but stops extracting
    /// and sets `overflow`.
    ///
    /// ```
    /// use gkselect::runtime::{KernelBackend, NativeBackend};
    ///
    /// let backend = NativeBackend::new();
    /// let e = backend.band_extract(&[1, 2, 3, 4, 5, 6], 4, 2, 5, 16);
    /// assert_eq!((e.pivot.lt, e.pivot.eq, e.pivot.gt), (3, 1, 2));
    /// assert_eq!(e.band.inner, 2);          // {3, 4} lie in the open band (2, 5)
    /// assert_eq!(e.candidates, vec![3, 4]); // extracted in data order
    /// assert!(!e.overflow);
    /// ```
    fn band_extract(&self, data: &[Key], pivot: Key, lo: Key, hi: Key, budget: usize)
        -> BandExtract;

    /// Batched form for MultiSelect: one result per `(pivot, lo, hi)`
    /// query. The default delegates to [`Self::band_extract`] per query;
    /// backends that can share a single read of `data` across all
    /// queries (the native one does) should override.
    fn multi_band_extract(
        &self,
        data: &[Key],
        queries: &[(Key, Key, Key)],
        budget: usize,
    ) -> Vec<BandExtract> {
        queries
            .iter()
            .map(|&(pivot, lo, hi)| self.band_extract(data, pivot, lo, hi, budget))
            .collect()
    }

    /// Backend label for reports.
    fn name(&self) -> &'static str;

    /// Keys per vector of the active band-scan tile; 1 = scalar. The
    /// value lands in `MetricsReport::simd_lane_width` and the
    /// `BENCH_gk_select.json` records so perf numbers always say which
    /// path produced them.
    fn simd_lane_width(&self) -> usize {
        1
    }
}

/// Plain-rust reference backend (also the fastest on this CPU-only box —
/// see EXPERIMENTS.md §Perf for the measured comparison). Holds the
/// SIMD dispatch decision, resolved once at construction — the module
/// docs above list the dispatch rules.
#[derive(Debug, Clone)]
pub struct NativeBackend {
    policy: SimdPolicy,
    dispatch: SimdDispatch,
}

impl Default for NativeBackend {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeBackend {
    /// Backend with the ambient policy: `GKSELECT_SIMD` if set, `Auto`
    /// otherwise. Config/CLI overrides construct via
    /// [`Self::with_policy`] instead.
    pub fn new() -> Self {
        Self::with_policy(SimdPolicy::from_env())
    }

    /// Backend with an explicit dispatch policy (resolved against this
    /// CPU immediately; no per-call feature detection).
    pub fn with_policy(policy: SimdPolicy) -> Self {
        Self {
            policy,
            dispatch: SimdDispatch::resolve(policy),
        }
    }

    /// The policy this backend was built with.
    pub fn policy(&self) -> SimdPolicy {
        self.policy
    }

    /// The resolved implementation the fused scans actually run.
    pub fn dispatch(&self) -> SimdDispatch {
        self.dispatch
    }
}

impl KernelBackend for NativeBackend {
    fn count_pivot(&self, data: &[Key], pivot: Key) -> PivotCounts {
        // branchless accumulation: the compiler vectorizes the compares
        let mut lt = 0u64;
        let mut eq = 0u64;
        for &v in data {
            lt += u64::from(v < pivot);
            eq += u64::from(v == pivot);
        }
        PivotCounts {
            lt,
            eq,
            gt: data.len() as u64 - lt - eq,
        }
    }

    fn band_count(&self, data: &[Key], lo: Key, hi: Key) -> BandCounts {
        let mut below = 0u64;
        let mut band = 0u64;
        for &v in data {
            below += u64::from(v < lo);
            band += u64::from(v >= lo && v <= hi);
        }
        BandCounts {
            below,
            band,
            above: data.len() as u64 - below - band,
        }
    }

    fn histogram(&self, data: &[Key], lo: i64, width: i64, nbins: usize) -> Vec<u64> {
        assert!(width > 0 && nbins > 0);
        let mut hist = vec![0u64; nbins];
        let top = (nbins - 1) as i64;
        for &v in data {
            let b = ((v as i64 - lo).div_euclid(width)).clamp(0, top) as usize;
            hist[b] += 1;
        }
        hist
    }

    fn minmax(&self, data: &[Key]) -> Option<(Key, Key)> {
        data.iter()
            .fold(None, |acc, &v| match acc {
                None => Some((v, v)),
                Some((lo, hi)) => Some((lo.min(v), hi.max(v))),
            })
    }

    fn band_extract(
        &self,
        data: &[Key],
        pivot: Key,
        lo: Key,
        hi: Key,
        budget: usize,
    ) -> BandExtract {
        // one driver for every dispatch: with `Scalar` the tile body is
        // the shared `tally` loop, so the oracle and the SIMD tile can
        // never disagree on tiling, budget boundaries, or finalize
        simd::band_extract(self.dispatch, data, pivot, lo, hi, budget)
    }

    /// One read of `data` serving every query: the m-way classification
    /// runs tile by tile so the partition streams through cache once
    /// (MultiSelect's "m quantiles, one scan").
    fn multi_band_extract(
        &self,
        data: &[Key],
        queries: &[(Key, Key, Key)],
        budget: usize,
    ) -> Vec<BandExtract> {
        simd::multi_band_extract(self.dispatch, data, queries, budget)
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn simd_lane_width(&self) -> usize {
        self.dispatch.lane_width()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::SplitMix64;

    #[test]
    fn count_pivot_basic() {
        let b = NativeBackend::new();
        let c = b.count_pivot(&[1, 2, 3, 3, 4, 5], 3);
        assert_eq!(c, PivotCounts { lt: 2, eq: 2, gt: 2 });
        assert_eq!(c.total(), 6);
    }

    #[test]
    fn count_pivot_empty() {
        let b = NativeBackend::new();
        assert_eq!(b.count_pivot(&[], 0).total(), 0);
    }

    #[test]
    fn band_count_partition_of_input() {
        let b = NativeBackend::new();
        let mut rng = SplitMix64::new(1);
        let data: Vec<Key> = (0..10_000).map(|_| (rng.next_u64() % 1000) as Key).collect();
        let c = b.band_count(&data, 200, 700);
        assert_eq!(c.below + c.band + c.above, 10_000);
        assert_eq!(c.below, data.iter().filter(|&&v| v < 200).count() as u64);
    }

    #[test]
    fn histogram_mass_and_clamping() {
        let b = NativeBackend::new();
        let h = b.histogram(&[-100, 0, 5, 9, 100], 0, 5, 2);
        // bins: [0,5) and [5,10); -100 clamps to 0, 100 clamps to 1
        assert_eq!(h, vec![2, 3]);
    }

    #[test]
    fn histogram_negative_lo_div_euclid() {
        let b = NativeBackend::new();
        // lo=-10, width=10, bins over [-10, 10): -1 is in bin 0, 1 in bin 1
        let h = b.histogram(&[-1, 1], -10, 10, 2);
        assert_eq!(h, vec![1, 1]);
    }

    #[test]
    fn minmax_extremes() {
        let b = NativeBackend::new();
        assert_eq!(b.minmax(&[]), None);
        assert_eq!(b.minmax(&[5]), Some((5, 5)));
        assert_eq!(
            b.minmax(&[Key::MAX, 0, Key::MIN]),
            Some((Key::MIN, Key::MAX))
        );
    }

    #[test]
    fn pivot_counts_add() {
        let mut a = PivotCounts { lt: 1, eq: 2, gt: 3 };
        a.add(PivotCounts { lt: 10, eq: 20, gt: 30 });
        assert_eq!(a, PivotCounts { lt: 11, eq: 22, gt: 33 });
    }

    /// Oracle for the fused scan, by definition.
    fn band_oracle(data: &[Key], pivot: Key, lo: Key, hi: Key) -> (PivotCounts, BandStats, Vec<Key>) {
        let count = |f: &dyn Fn(Key) -> bool| data.iter().filter(|&&v| f(v)).count() as u64;
        let pc = PivotCounts {
            lt: count(&|v| v < pivot),
            eq: count(&|v| v == pivot),
            gt: count(&|v| v > pivot),
        };
        let bs = BandStats {
            below: count(&|v| v < lo),
            eq_lo: count(&|v| v == lo),
            inner: count(&|v| v > lo && v < hi),
            eq_hi: if lo == hi { 0 } else { count(&|v| v == hi) },
            above: count(&|v| v > hi),
        };
        let cands: Vec<Key> = data.iter().copied().filter(|&v| v > lo && v < hi).collect();
        (pc, bs, cands)
    }

    #[test]
    fn band_extract_matches_oracle() {
        let b = NativeBackend::new();
        let mut rng = SplitMix64::new(3);
        let data: Vec<Key> = (0..20_000).map(|_| (rng.next_u64() % 500) as Key).collect();
        for (pivot, lo, hi) in [(250, 200, 300), (0, 0, 499), (250, 250, 250), (600, 501, 700)] {
            let got = b.band_extract(&data, pivot, lo, hi, usize::MAX);
            let (pc, bs, mut cands) = band_oracle(&data, pivot, lo, hi);
            assert_eq!(got.pivot, pc, "pivot counts at ({pivot},{lo},{hi})");
            assert_eq!(got.band, bs, "band stats at ({pivot},{lo},{hi})");
            assert!(!got.overflow);
            let mut got_c = got.candidates.clone();
            got_c.sort_unstable();
            cands.sort_unstable();
            assert_eq!(got_c, cands, "candidates at ({pivot},{lo},{hi})");
            assert_eq!(got.band.total(), data.len() as u64);
            assert_eq!(got.pivot.total(), data.len() as u64);
        }
    }

    #[test]
    fn band_extract_collapsed_band_counts_once() {
        let b = NativeBackend::new();
        let data = vec![1, 2, 2, 2, 3];
        let got = b.band_extract(&data, 2, 2, 2, 100);
        assert_eq!(got.band.below, 1);
        assert_eq!(got.band.eq_lo, 3);
        assert_eq!(got.band.eq_hi, 0);
        assert_eq!(got.band.inner, 0);
        assert_eq!(got.band.above, 1);
        assert_eq!(got.band.total(), 5);
    }

    #[test]
    fn band_extract_overflow_keeps_counts_complete() {
        let b = NativeBackend::new();
        let data: Vec<Key> = (0..10_000).collect();
        let got = b.band_extract(&data, 5_000, 1_000, 9_000, 10);
        assert!(got.overflow);
        assert!(got.candidates.is_empty());
        // counts unaffected by the overflow
        assert_eq!(got.pivot.lt, 5_000);
        assert_eq!(got.pivot.eq, 1);
        assert_eq!(got.band.below, 1_000);
        assert_eq!(got.band.inner, 7_999);
        assert_eq!(got.band.total(), 10_000);
    }

    #[test]
    fn band_extract_merge_accumulates_and_overflows() {
        let b = NativeBackend::new();
        let a = b.band_extract(&[1, 5, 9], 5, 2, 8, 100);
        let c = b.band_extract(&[4, 6, 20], 5, 2, 8, 100);
        let m = a.clone().merge(c.clone(), 100);
        assert_eq!(m.band.total(), 6);
        assert_eq!(m.pivot.total(), 6);
        assert_eq!(m.candidates.len(), 3); // {5, 4, 6}
        assert!(!m.overflow);
        // budget violation at merge time drops candidates but keeps counts
        let m2 = a.clone().merge(c.clone(), 2);
        assert!(m2.overflow);
        assert!(m2.candidates.is_empty());
        assert_eq!(m2.band.total(), 6);
        // overflow is sticky
        let m3 = m2.merge(a, 1_000);
        assert!(m3.overflow);
        assert_eq!(m3.band.total(), 9);
    }

    #[test]
    fn multi_band_extract_matches_single() {
        let b = NativeBackend::new();
        let mut rng = SplitMix64::new(9);
        let data: Vec<Key> = (0..5_000).map(|_| (rng.next_u64() % 1_000) as Key).collect();
        let queries = [(100, 50, 150), (500, 500, 500), (900, 850, 999)];
        let multi = b.multi_band_extract(&data, &queries, usize::MAX);
        assert_eq!(multi.len(), 3);
        for (got, &(pivot, lo, hi)) in multi.iter().zip(queries.iter()) {
            let single = b.band_extract(&data, pivot, lo, hi, usize::MAX);
            assert_eq!(got.pivot, single.pivot);
            assert_eq!(got.band, single.band);
            let (mut a, mut c) = (got.candidates.clone(), single.candidates);
            a.sort_unstable();
            c.sort_unstable();
            assert_eq!(a, c);
        }
    }

    #[test]
    fn band_extract_empty_input() {
        let b = NativeBackend::new();
        let got = b.band_extract(&[], 0, -5, 5, 10);
        assert_eq!(got, BandExtract::default());
    }

    /// Both dispatch pins, so every edge case below is pinned on the
    /// scalar oracle AND the SIMD tile (which degrades to scalar on
    /// targets without one — the assertions still hold).
    fn pinned_backends() -> [(&'static str, NativeBackend); 2] {
        [
            ("scalar", NativeBackend::with_policy(SimdPolicy::ForceScalar)),
            ("simd", NativeBackend::with_policy(SimdPolicy::ForceSimd)),
        ]
    }

    #[test]
    fn edge_empty_partition_both_paths() {
        for (label, b) in pinned_backends() {
            assert_eq!(b.band_extract(&[], 0, -5, 5, 10), BandExtract::default(), "{label}");
            let multi = b.multi_band_extract(&[], &[(0, -5, 5), (1, 1, 1)], 10);
            assert_eq!(multi, vec![BandExtract::default(); 2], "{label}");
        }
    }

    #[test]
    fn edge_zero_budget_both_paths() {
        let data: Vec<Key> = (0..1000).collect();
        for (label, b) in pinned_backends() {
            let got = b.band_extract(&data, 500, 100, 900, 0);
            // one in-band element already exceeds budget 0 → overflow,
            // candidates dropped, every count still complete
            assert!(got.overflow, "{label}");
            assert!(got.candidates.is_empty(), "{label}");
            assert_eq!(got.band.inner, 799, "{label}");
            assert_eq!(got.band.total(), 1000, "{label}");
            assert_eq!(got.pivot.total(), 1000, "{label}");
        }
    }

    #[test]
    fn edge_pivot_outside_data_range_both_paths() {
        let data: Vec<Key> = (0..500).collect();
        for (label, b) in pinned_backends() {
            // pivot and band entirely above the data
            let hi_side = b.band_extract(&data, 10_000, 9_000, 11_000, 64);
            assert_eq!(hi_side.pivot, PivotCounts { lt: 500, eq: 0, gt: 0 }, "{label}");
            assert_eq!(hi_side.band.below, 500, "{label}");
            assert_eq!(hi_side.band.inner, 0, "{label}");
            assert!(hi_side.candidates.is_empty() && !hi_side.overflow, "{label}");
            // pivot below the data, band straddling its low edge
            let lo_side = b.band_extract(&data, -7, -10, 3, 64);
            assert_eq!(lo_side.pivot, PivotCounts { lt: 0, eq: 0, gt: 500 }, "{label}");
            assert_eq!(lo_side.band.inner, 3, "{label}"); // {0, 1, 2}
            assert_eq!(lo_side.candidates, vec![0, 1, 2], "{label}");
        }
    }

    #[test]
    fn edge_collapsed_band_both_paths() {
        // lo == hi == pivot: the endpoint counters would alias; eq_hi is
        // normalized to 0 and nothing is ever extracted
        let data = vec![1, 2, 2, 2, 3];
        for (label, b) in pinned_backends() {
            let got = b.band_extract(&data, 2, 2, 2, 100);
            assert_eq!(got.band.below, 1, "{label}");
            assert_eq!(got.band.eq_lo, 3, "{label}");
            assert_eq!(got.band.eq_hi, 0, "{label}");
            assert_eq!(got.band.inner, 0, "{label}");
            assert_eq!(got.band.above, 1, "{label}");
            assert!(got.candidates.is_empty() && !got.overflow, "{label}");
            assert_eq!(got.pivot, PivotCounts { lt: 1, eq: 3, gt: 1 }, "{label}");
        }
    }

    #[test]
    fn edge_duplicate_saturated_zipf_both_paths() {
        use crate::data::{DataGenerator, ZipfGen};
        let mut data: Vec<Key> = Vec::new();
        ZipfGen::new(7, 2.5).fill_partition(0, 1, 30_000, &mut data);
        let (pivot, lo, hi) = {
            let (mut lo, mut hi) = (data[0], data[0]);
            for &v in &data {
                lo = lo.min(v);
                hi = hi.max(v);
            }
            (data[0], lo, hi)
        };
        for (label, b) in pinned_backends() {
            let got = b.band_extract(&data, pivot, lo, hi, usize::MAX);
            let (pc, bs, mut cands) = band_oracle(&data, pivot, lo, hi);
            assert_eq!(got.pivot, pc, "{label}");
            assert_eq!(got.band, bs, "{label}");
            let mut got_c = got.candidates.clone();
            got_c.sort_unstable();
            cands.sort_unstable();
            assert_eq!(got_c, cands, "{label}");
            // endpoint runs are counted, never extracted: the heavy
            // hitters at the band edges cannot blow the buffer
            assert_eq!(got.band.total(), 30_000, "{label}");
        }
    }

    #[test]
    fn simd_lane_width_is_reported() {
        let scalar = NativeBackend::with_policy(SimdPolicy::ForceScalar);
        assert_eq!(scalar.simd_lane_width(), 1);
        assert_eq!(scalar.policy(), SimdPolicy::ForceScalar);
        let forced = NativeBackend::with_policy(SimdPolicy::ForceSimd);
        assert_eq!(forced.simd_lane_width(), forced.dispatch().lane_width());
        #[cfg(target_arch = "x86_64")]
        assert!(forced.simd_lane_width() >= 4);
    }

    #[test]
    fn band_extract_net_bytes_tracks_candidates() {
        let b = NativeBackend::new();
        let data: Vec<Key> = (0..100).collect();
        let got = b.band_extract(&data, 50, 40, 60, 1_000);
        assert_eq!(got.candidates.len(), 19);
        assert_eq!(
            got.net_bytes(),
            crate::cluster::netmodel::CONTAINER_OVERHEAD * 2 + 65 + 19 * 4
        );
    }
}
