//! Kernel backend trait + the native reference implementation.

use crate::Key;

/// Three-way pivot classification counts (lt, eq, gt).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PivotCounts {
    pub lt: u64,
    pub eq: u64,
    pub gt: u64,
}

impl PivotCounts {
    pub fn total(&self) -> u64 {
        self.lt + self.eq + self.gt
    }

    pub fn add(&mut self, other: PivotCounts) {
        self.lt += other.lt;
        self.eq += other.eq;
        self.gt += other.gt;
    }
}

/// Band classification counts (below lo, inside [lo, hi], above hi).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct BandCounts {
    pub below: u64,
    pub band: u64,
    pub above: u64,
}

/// The executor-side compute hot spots, as implemented by either the
/// AOT/PJRT path or native rust. All counts are over the full slice.
pub trait KernelBackend {
    /// `[|{x < pivot}|, |{x == pivot}|, |{x > pivot}|]`.
    fn count_pivot(&mut self, data: &[Key], pivot: Key) -> PivotCounts;

    /// `[|{x < lo}|, |{lo <= x <= hi}|, |{x > hi}|]`.
    fn band_count(&mut self, data: &[Key], lo: Key, hi: Key) -> BandCounts;

    /// Equi-width histogram over `[lo, lo + nbins*width)`, out-of-range
    /// clamped into the edge bins.
    fn histogram(&mut self, data: &[Key], lo: i64, width: i64, nbins: usize) -> Vec<u64>;

    /// `(min, max)` or `None` when empty.
    fn minmax(&mut self, data: &[Key]) -> Option<(Key, Key)>;

    /// Backend label for reports.
    fn name(&self) -> &'static str;
}

/// Plain-rust reference backend (also the fastest on this CPU-only box —
/// see EXPERIMENTS.md §Perf for the measured comparison).
#[derive(Debug, Default, Clone)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> Self {
        Self
    }
}

impl KernelBackend for NativeBackend {
    fn count_pivot(&mut self, data: &[Key], pivot: Key) -> PivotCounts {
        // branchless accumulation: the compiler vectorizes the compares
        let mut lt = 0u64;
        let mut eq = 0u64;
        for &v in data {
            lt += u64::from(v < pivot);
            eq += u64::from(v == pivot);
        }
        PivotCounts {
            lt,
            eq,
            gt: data.len() as u64 - lt - eq,
        }
    }

    fn band_count(&mut self, data: &[Key], lo: Key, hi: Key) -> BandCounts {
        let mut below = 0u64;
        let mut band = 0u64;
        for &v in data {
            below += u64::from(v < lo);
            band += u64::from(v >= lo && v <= hi);
        }
        BandCounts {
            below,
            band,
            above: data.len() as u64 - below - band,
        }
    }

    fn histogram(&mut self, data: &[Key], lo: i64, width: i64, nbins: usize) -> Vec<u64> {
        assert!(width > 0 && nbins > 0);
        let mut hist = vec![0u64; nbins];
        let top = (nbins - 1) as i64;
        for &v in data {
            let b = ((v as i64 - lo).div_euclid(width)).clamp(0, top) as usize;
            hist[b] += 1;
        }
        hist
    }

    fn minmax(&mut self, data: &[Key]) -> Option<(Key, Key)> {
        data.iter()
            .fold(None, |acc, &v| match acc {
                None => Some((v, v)),
                Some((lo, hi)) => Some((lo.min(v), hi.max(v))),
            })
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::SplitMix64;

    #[test]
    fn count_pivot_basic() {
        let mut b = NativeBackend::new();
        let c = b.count_pivot(&[1, 2, 3, 3, 4, 5], 3);
        assert_eq!(c, PivotCounts { lt: 2, eq: 2, gt: 2 });
        assert_eq!(c.total(), 6);
    }

    #[test]
    fn count_pivot_empty() {
        let mut b = NativeBackend::new();
        assert_eq!(b.count_pivot(&[], 0).total(), 0);
    }

    #[test]
    fn band_count_partition_of_input() {
        let mut b = NativeBackend::new();
        let mut rng = SplitMix64::new(1);
        let data: Vec<Key> = (0..10_000).map(|_| (rng.next_u64() % 1000) as Key).collect();
        let c = b.band_count(&data, 200, 700);
        assert_eq!(c.below + c.band + c.above, 10_000);
        assert_eq!(c.below, data.iter().filter(|&&v| v < 200).count() as u64);
    }

    #[test]
    fn histogram_mass_and_clamping() {
        let mut b = NativeBackend::new();
        let h = b.histogram(&[-100, 0, 5, 9, 100], 0, 5, 2);
        // bins: [0,5) and [5,10); -100 clamps to 0, 100 clamps to 1
        assert_eq!(h, vec![2, 3]);
    }

    #[test]
    fn histogram_negative_lo_div_euclid() {
        let mut b = NativeBackend::new();
        // lo=-10, width=10, bins over [-10, 10): -1 is in bin 0, 1 in bin 1
        let h = b.histogram(&[-1, 1], -10, 10, 2);
        assert_eq!(h, vec![1, 1]);
    }

    #[test]
    fn minmax_extremes() {
        let mut b = NativeBackend::new();
        assert_eq!(b.minmax(&[]), None);
        assert_eq!(b.minmax(&[5]), Some((5, 5)));
        assert_eq!(
            b.minmax(&[Key::MAX, 0, Key::MIN]),
            Some((Key::MIN, Key::MAX))
        );
    }

    #[test]
    fn pivot_counts_add() {
        let mut a = PivotCounts { lt: 1, eq: 2, gt: 3 };
        a.add(PivotCounts { lt: 10, eq: 20, gt: 30 });
        assert_eq!(a, PivotCounts { lt: 11, eq: 22, gt: 33 });
    }
}
