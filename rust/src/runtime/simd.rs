//! Explicit SIMD tile for the fused band-extract scan, with runtime
//! dispatch.
//!
//! GK Select's entire executor-side cost is one linear pass per
//! partition, so the per-element throughput of
//! [`super::KernelBackend::band_extract`] bounds everything the paper's
//! 10.5x claim rests on. The portable loops in [`super::kernels`] lean
//! on autovectorization, which survives the count-only tiles but dies at
//! the data-dependent candidate append. This module vectorizes the whole
//! classification explicitly:
//!
//! * **six-counter classification** — each lane is compared against the
//!   broadcast `pivot`, `lo`, and `hi` (`v < π`, `v == π`, `v < lo`,
//!   `v == lo`, `v == hi`, `lo < v < hi`); compare masks accumulate into
//!   per-lane i32 counters (`acc -= mask`, since true is −1), summed
//!   horizontally once per 4096-key tile. No popcount in the inner loop.
//! * **bitmask-compress extraction** — only when a tile is still under
//!   the candidate budget, the open-band mask is `movemask`ed to one bit
//!   per lane and the (rare) set bits are walked LSB-first, appending
//!   candidates in data order — bit-identical to the scalar append.
//!
//! Three dispatch targets, resolved once at backend construction by
//! [`SimdDispatch::resolve`]:
//!
//! | target | lanes | availability |
//! |---|---|---|
//! | AVX2   | 8 × i32 | `is_x86_feature_detected!("avx2")` |
//! | SSE2   | 4 × i32 | any `x86_64` (baseline feature) |
//! | scalar | 1       | everywhere — the authoritative oracle |
//!
//! [`SimdPolicy`] picks between them: `Auto` takes the widest available
//! tile, `ForceScalar` pins the portable oracle, `ForceSimd` pins the
//! SIMD tile (degrading to scalar where no tile exists, e.g. non-x86).
//! CI runs the whole suite under both pins via the `GKSELECT_SIMD`
//! environment variable; `[runtime] simd` in repro.toml and the `--simd`
//! CLI flag override it per run. `proptest_simd` asserts the tile and
//! the oracle are bit-identical — counts, candidate order, overflow
//! points — across random geometries including unaligned tails and
//! partitions smaller than one vector.
//!
//! Budget semantics are shared with the scalar path by construction:
//! both walk the same [`BAND_CHUNK`]-key tiles and check the candidate
//! budget at the same tile boundaries, so an overflow flips to the
//! count-only loop at exactly the same point in the stream. Tail
//! elements (and the whole tile on non-SIMD targets) go through
//! [`BandExtract::tally`] — the same per-element classification the
//! scalar backend runs — so the arithmetic exists in exactly one place.
//!
//! [`BAND_CHUNK`]: super::kernels::BAND_CHUNK

use super::kernels::{BandExtract, BAND_CHUNK};
use crate::Key;

// The intrinsics below hard-code 32-bit lanes; a Key width change must
// revisit this module.
const _: () = assert!(std::mem::size_of::<Key>() == 4, "SIMD tile assumes 32-bit keys");

/// How the native backend picks its band-extract implementation.
///
/// ```
/// use gkselect::runtime::{KernelBackend, NativeBackend, SimdPolicy};
///
/// let scalar = NativeBackend::with_policy(SimdPolicy::ForceScalar);
/// assert_eq!(scalar.simd_lane_width(), 1);
/// // Auto resolves to the widest tile this CPU offers (8 on AVX2,
/// // 4 on pre-AVX2 x86_64, 1 elsewhere)
/// let auto = NativeBackend::with_policy(SimdPolicy::Auto);
/// assert!(auto.simd_lane_width() >= 1);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimdPolicy {
    /// Use the widest SIMD tile the CPU supports (scalar where none).
    #[default]
    Auto,
    /// Pin the portable scalar path — the correctness oracle, and the
    /// CI leg that keeps it honest.
    ForceScalar,
    /// Pin the SIMD tile; degrades to scalar (lane width 1) on targets
    /// without one, so forcing is always safe.
    ForceSimd,
}

impl SimdPolicy {
    /// Policy requested by the `GKSELECT_SIMD` environment variable
    /// (`auto` | `scalar` | `force`; unset → `Auto`). This is the CI
    /// toggle that re-runs the whole suite under each dispatch pin.
    /// Parsing lives in [`crate::engine::env`] — the one place env vars
    /// are read; builders that can report errors use that module
    /// directly instead of this panicking convenience.
    pub fn from_env() -> Self {
        crate::engine::env::simd_policy()
            .expect("GKSELECT_SIMD must be 'auto', 'scalar', or 'force'")
            .unwrap_or(SimdPolicy::Auto)
    }

    pub fn label(self) -> &'static str {
        match self {
            SimdPolicy::Auto => "auto",
            SimdPolicy::ForceScalar => "scalar",
            SimdPolicy::ForceSimd => "force",
        }
    }
}

impl std::str::FromStr for SimdPolicy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s {
            "auto" => Ok(Self::Auto),
            "scalar" | "force-scalar" => Ok(Self::ForceScalar),
            "force" | "simd" | "force-simd" => Ok(Self::ForceSimd),
            other => anyhow::bail!("unknown simd policy '{other}' (auto|scalar|force)"),
        }
    }
}

/// The resolved implementation a backend actually runs — one probe at
/// construction, no per-call feature detection on the hot path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdDispatch {
    /// Portable loops ([`super::kernels`]), the authoritative oracle.
    Scalar,
    /// 4 × i32 tile — x86_64 baseline, no runtime probe needed.
    Sse2,
    /// 8 × i32 tile behind `is_x86_feature_detected!("avx2")`.
    Avx2,
}

impl SimdDispatch {
    /// Resolve a policy against this CPU.
    pub fn resolve(policy: SimdPolicy) -> Self {
        match policy {
            SimdPolicy::ForceScalar => Self::Scalar,
            SimdPolicy::Auto | SimdPolicy::ForceSimd => Self::best_available(),
        }
    }

    #[cfg(target_arch = "x86_64")]
    fn best_available() -> Self {
        // Miri interprets MIR and cannot execute vendor intrinsics:
        // route every policy (including ForceSimd) to the scalar
        // oracle so the whole suite runs under `cargo miri test`.
        // Bit-identity of the tiles vs. the oracle is proptested
        // natively (`proptest_simd.rs`), so Miri loses no coverage.
        if cfg!(miri) {
            return Self::Scalar;
        }
        if is_x86_feature_detected!("avx2") {
            Self::Avx2
        } else {
            Self::Sse2
        }
    }

    #[cfg(not(target_arch = "x86_64"))]
    fn best_available() -> Self {
        Self::Scalar
    }

    /// Keys per vector of the active tile; 1 = scalar.
    pub fn lane_width(self) -> usize {
        match self {
            Self::Scalar => 1,
            Self::Sse2 => 4,
            Self::Avx2 => 8,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Self::Scalar => "scalar",
            Self::Sse2 => "sse2",
            Self::Avx2 => "avx2",
        }
    }

    /// Classify one tile (≤ [`BAND_CHUNK`] keys) into `out`'s counters
    /// and, when `extracting`, append the open-band values to
    /// `out.candidates` in data order. Never touches `out.pivot.gt` /
    /// `out.band.above` — those are derived by `finalize`.
    fn classify_chunk(
        self,
        chunk: &[Key],
        pivot: Key,
        lo: Key,
        hi: Key,
        out: &mut BandExtract,
        extracting: bool,
    ) {
        match self {
            Self::Scalar => classify_chunk_scalar(chunk, pivot, lo, hi, out, extracting),
            #[cfg(target_arch = "x86_64")]
            // SAFETY: SSE2 is part of the x86_64 baseline — every CPU
            // this arm compiles for executes it.
            Self::Sse2 => unsafe {
                x86::classify_chunk_sse2(chunk, pivot, lo, hi, out, extracting)
            },
            #[cfg(target_arch = "x86_64")]
            // SAFETY: `Avx2` is only ever constructed after
            // `is_x86_feature_detected!("avx2")` succeeded in
            // `best_available`, so the target feature is present.
            Self::Avx2 => unsafe {
                x86::classify_chunk_avx2(chunk, pivot, lo, hi, out, extracting)
            },
            #[cfg(not(target_arch = "x86_64"))]
            _ => classify_chunk_scalar(chunk, pivot, lo, hi, out, extracting),
        }
    }
}

/// Scalar tile body — the SIMD kernels' tail loop and the non-x86
/// fallback. Runs [`BandExtract::tally`] per element, exactly like the
/// scalar backend's loops, so the classification arithmetic lives in
/// one place only.
fn classify_chunk_scalar(
    chunk: &[Key],
    pivot: Key,
    lo: Key,
    hi: Key,
    out: &mut BandExtract,
    extracting: bool,
) {
    if extracting {
        for &v in chunk {
            if out.tally(v, pivot, lo, hi) {
                out.band.inner += 1;
                out.candidates.push(v);
            }
        }
    } else {
        for &v in chunk {
            let in_band = out.tally(v, pivot, lo, hi);
            out.band.inner += u64::from(in_band);
        }
    }
}

/// The fused single-query scan through the resolved tile. Semantics
/// (counts, candidate order, overflow points) are bit-identical to the
/// scalar `NativeBackend` path — asserted by `proptest_simd`.
pub(crate) fn band_extract(
    dispatch: SimdDispatch,
    data: &[Key],
    pivot: Key,
    lo: Key,
    hi: Key,
    budget: usize,
) -> BandExtract {
    debug_assert!(lo <= hi, "band [{lo}, {hi}] inverted");
    let mut out = BandExtract {
        candidates: Vec::with_capacity(budget.min(data.len())),
        ..Default::default()
    };
    for chunk in data.chunks(BAND_CHUNK) {
        let extracting = !out.overflow;
        dispatch.classify_chunk(chunk, pivot, lo, hi, &mut out, extracting);
        if extracting && out.candidates.len() > budget {
            out.overflow = true;
            out.candidates = Vec::new();
        }
    }
    out.finalize(data.len() as u64, lo, hi);
    out
}

/// The batched multi-query scan: one read of `data` serving every
/// `(pivot, lo, hi)` triple, tile by tile, mirroring the scalar
/// `multi_band_extract` (including its per-query overflow points).
pub(crate) fn multi_band_extract(
    dispatch: SimdDispatch,
    data: &[Key],
    queries: &[(Key, Key, Key)],
    budget: usize,
) -> Vec<BandExtract> {
    debug_assert!(
        queries.iter().all(|&(_, lo, hi)| lo <= hi),
        "inverted band in {queries:?}"
    );
    let mut outs: Vec<BandExtract> = queries.iter().map(|_| BandExtract::default()).collect();
    for chunk in data.chunks(BAND_CHUNK) {
        for (out, &(pivot, lo, hi)) in outs.iter_mut().zip(queries) {
            let extracting = !out.overflow;
            dispatch.classify_chunk(chunk, pivot, lo, hi, out, extracting);
            if extracting && out.candidates.len() > budget {
                out.overflow = true;
                out.candidates = Vec::new();
            }
        }
    }
    let n = data.len() as u64;
    for (out, &(_, lo, hi)) in outs.iter_mut().zip(queries) {
        out.finalize(n, lo, hi);
    }
    outs
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    //! The x86_64 tiles. Compare masks are all-ones on true, so
    //! `acc = sub(acc, mask)` counts matches per lane; one horizontal
    //! sum per tile (≤ 4096 keys ⇒ per-lane counts ≤ 1024 < i32::MAX)
    //! moves them into the output counters. The sub-vector tail of each
    //! tile goes through `classify_chunk_scalar`, i.e. the shared
    //! `BandExtract::tally` arithmetic.

    use super::{classify_chunk_scalar, BandExtract};
    use crate::Key;
    use std::arch::x86_64::*;

    /// The six vector-accumulated counters of one tile, merged into the
    /// running [`BandExtract`] in one place (the vector counterpart of
    /// `PivotCounts::add`/`BandStats::add`).
    struct ChunkTally {
        lt_pivot: u64,
        eq_pivot: u64,
        below_lo: u64,
        eq_lo: u64,
        eq_hi: u64,
        inner: u64,
    }

    impl ChunkTally {
        fn apply(self, out: &mut BandExtract) {
            out.pivot.lt += self.lt_pivot;
            out.pivot.eq += self.eq_pivot;
            out.band.below += self.below_lo;
            out.band.eq_lo += self.eq_lo;
            out.band.eq_hi += self.eq_hi;
            out.band.inner += self.inner;
        }
    }

    /// # Safety
    /// Caller must have verified AVX2 support (the store is an
    /// unaligned-safe `storeu` into a stack buffer of exactly one
    /// vector, so feature presence is the only obligation).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_epi32_256(v: __m256i) -> u64 {
        let mut buf = [0i32; 8];
        _mm256_storeu_si256(buf.as_mut_ptr() as *mut __m256i, v);
        buf.iter().map(|&x| x as u64).sum()
    }

    /// # Safety
    /// SSE2 is the x86_64 baseline; the `storeu` writes exactly one
    /// vector into a stack buffer of the same size, so this is safe to
    /// call from any x86_64 context.
    #[inline]
    unsafe fn hsum_epi32_128(v: __m128i) -> u64 {
        let mut buf = [0i32; 4];
        _mm_storeu_si128(buf.as_mut_ptr() as *mut __m128i, v);
        buf.iter().map(|&x| x as u64).sum()
    }

    /// # Safety
    /// Caller must have verified AVX2 support (`SimdDispatch::resolve`).
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn classify_chunk_avx2(
        chunk: &[Key],
        pivot: Key,
        lo: Key,
        hi: Key,
        out: &mut BandExtract,
        extracting: bool,
    ) {
        const LANES: usize = 8;
        let n = chunk.len();
        let ptr = chunk.as_ptr();
        let pv = _mm256_set1_epi32(pivot);
        let lov = _mm256_set1_epi32(lo);
        let hiv = _mm256_set1_epi32(hi);
        let mut acc_lt = _mm256_setzero_si256();
        let mut acc_eq = _mm256_setzero_si256();
        let mut acc_below = _mm256_setzero_si256();
        let mut acc_eqlo = _mm256_setzero_si256();
        let mut acc_eqhi = _mm256_setzero_si256();
        let mut acc_inner = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + LANES <= n {
            let v = _mm256_loadu_si256(ptr.add(i) as *const __m256i);
            let lt = _mm256_cmpgt_epi32(pv, v); // v < pivot (signed)
            let eq = _mm256_cmpeq_epi32(v, pv);
            let below = _mm256_cmpgt_epi32(lov, v); // v < lo
            let eqlo = _mm256_cmpeq_epi32(v, lov);
            let eqhi = _mm256_cmpeq_epi32(v, hiv);
            let inner = _mm256_and_si256(
                _mm256_cmpgt_epi32(v, lov), // v > lo
                _mm256_cmpgt_epi32(hiv, v), // v < hi
            );
            acc_lt = _mm256_sub_epi32(acc_lt, lt);
            acc_eq = _mm256_sub_epi32(acc_eq, eq);
            acc_below = _mm256_sub_epi32(acc_below, below);
            acc_eqlo = _mm256_sub_epi32(acc_eqlo, eqlo);
            acc_eqhi = _mm256_sub_epi32(acc_eqhi, eqhi);
            acc_inner = _mm256_sub_epi32(acc_inner, inner);
            if extracting {
                // bitmask-compress: one bit per lane, walked LSB-first
                // so candidates land in data order
                let mut m = _mm256_movemask_ps(_mm256_castsi256_ps(inner)) as u32;
                while m != 0 {
                    let j = m.trailing_zeros() as usize;
                    out.candidates.push(*ptr.add(i + j));
                    m &= m - 1;
                }
            }
            i += LANES;
        }
        ChunkTally {
            lt_pivot: hsum_epi32_256(acc_lt),
            eq_pivot: hsum_epi32_256(acc_eq),
            below_lo: hsum_epi32_256(acc_below),
            eq_lo: hsum_epi32_256(acc_eqlo),
            eq_hi: hsum_epi32_256(acc_eqhi),
            inner: hsum_epi32_256(acc_inner),
        }
        .apply(out);
        // unaligned tail: the shared tally arithmetic, same append order
        classify_chunk_scalar(&chunk[i..], pivot, lo, hi, out, extracting);
    }

    /// # Safety
    /// SSE2 is part of the x86_64 baseline; callers only need the raw
    /// loads to stay in-bounds, which the `i + LANES <= n` guard gives.
    pub(super) unsafe fn classify_chunk_sse2(
        chunk: &[Key],
        pivot: Key,
        lo: Key,
        hi: Key,
        out: &mut BandExtract,
        extracting: bool,
    ) {
        const LANES: usize = 4;
        let n = chunk.len();
        let ptr = chunk.as_ptr();
        let pv = _mm_set1_epi32(pivot);
        let lov = _mm_set1_epi32(lo);
        let hiv = _mm_set1_epi32(hi);
        let mut acc_lt = _mm_setzero_si128();
        let mut acc_eq = _mm_setzero_si128();
        let mut acc_below = _mm_setzero_si128();
        let mut acc_eqlo = _mm_setzero_si128();
        let mut acc_eqhi = _mm_setzero_si128();
        let mut acc_inner = _mm_setzero_si128();
        let mut i = 0usize;
        while i + LANES <= n {
            let v = _mm_loadu_si128(ptr.add(i) as *const __m128i);
            let lt = _mm_cmpgt_epi32(pv, v);
            let eq = _mm_cmpeq_epi32(v, pv);
            let below = _mm_cmpgt_epi32(lov, v);
            let eqlo = _mm_cmpeq_epi32(v, lov);
            let eqhi = _mm_cmpeq_epi32(v, hiv);
            let inner = _mm_and_si128(_mm_cmpgt_epi32(v, lov), _mm_cmpgt_epi32(hiv, v));
            acc_lt = _mm_sub_epi32(acc_lt, lt);
            acc_eq = _mm_sub_epi32(acc_eq, eq);
            acc_below = _mm_sub_epi32(acc_below, below);
            acc_eqlo = _mm_sub_epi32(acc_eqlo, eqlo);
            acc_eqhi = _mm_sub_epi32(acc_eqhi, eqhi);
            acc_inner = _mm_sub_epi32(acc_inner, inner);
            if extracting {
                let mut m = _mm_movemask_ps(_mm_castsi128_ps(inner)) as u32;
                while m != 0 {
                    let j = m.trailing_zeros() as usize;
                    out.candidates.push(*ptr.add(i + j));
                    m &= m - 1;
                }
            }
            i += LANES;
        }
        ChunkTally {
            lt_pivot: hsum_epi32_128(acc_lt),
            eq_pivot: hsum_epi32_128(acc_eq),
            below_lo: hsum_epi32_128(acc_below),
            eq_lo: hsum_epi32_128(acc_eqlo),
            eq_hi: hsum_epi32_128(acc_eqhi),
            inner: hsum_epi32_128(acc_inner),
        }
        .apply(out);
        classify_chunk_scalar(&chunk[i..], pivot, lo, hi, out, extracting);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parses_and_labels() {
        assert_eq!("auto".parse::<SimdPolicy>().unwrap(), SimdPolicy::Auto);
        assert_eq!("scalar".parse::<SimdPolicy>().unwrap(), SimdPolicy::ForceScalar);
        assert_eq!("force".parse::<SimdPolicy>().unwrap(), SimdPolicy::ForceSimd);
        assert_eq!("simd".parse::<SimdPolicy>().unwrap(), SimdPolicy::ForceSimd);
        assert!("turbo".parse::<SimdPolicy>().is_err());
        assert_eq!(SimdPolicy::default(), SimdPolicy::Auto);
        assert_eq!(SimdPolicy::ForceSimd.label(), "force");
    }

    #[test]
    fn dispatch_resolution_is_sane() {
        assert_eq!(SimdDispatch::resolve(SimdPolicy::ForceScalar), SimdDispatch::Scalar);
        let auto = SimdDispatch::resolve(SimdPolicy::Auto);
        let forced = SimdDispatch::resolve(SimdPolicy::ForceSimd);
        // Auto and ForceSimd agree: both take the widest available tile
        assert_eq!(auto, forced);
        assert!(auto.lane_width() >= 1);
        #[cfg(target_arch = "x86_64")]
        assert!(auto.lane_width() >= 4, "x86_64 always has the SSE2 tile");
        assert_eq!(SimdDispatch::Scalar.lane_width(), 1);
        assert_eq!(SimdDispatch::Avx2.lane_width(), 8);
        assert_eq!(SimdDispatch::Sse2.label(), "sse2");
    }

    #[test]
    fn classify_chunk_matches_scalar_for_every_available_tile() {
        // direct tile-level check on a deliberately awkward length (not
        // a multiple of any lane width); the backend-level equivalence
        // lives in tests/proptest_simd.rs
        let dispatches = [
            SimdDispatch::Scalar,
            SimdDispatch::resolve(SimdPolicy::ForceSimd),
        ];
        let data: Vec<Key> = (0..1037).map(|i| (i * 37 % 101) - 50).collect();
        let mut oracle = BandExtract::default();
        classify_chunk_scalar(&data, 0, -10, 10, &mut oracle, true);
        for d in dispatches {
            let mut got = BandExtract::default();
            d.classify_chunk(&data, 0, -10, 10, &mut got, true);
            assert_eq!(got, oracle, "{d:?}");
            assert_eq!(got.candidates.len() as u64, got.band.inner, "{d:?}");
            let expect: Vec<Key> = data.iter().copied().filter(|&v| v > -10 && v < 10).collect();
            assert_eq!(got.candidates, expect, "{d:?}: candidates must keep data order");
        }
    }
}
