//! `artifacts/manifest.json` — geometry contract between `aot.py` and the
//! rust loader. The python side writes it next to the HLO text files so
//! the rust side never hard-codes buffer shapes.

use crate::util::minijson::{self, Json};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// One artifact entry.
#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub file: String,
    pub bytes: u64,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Keys per executable call (the static HLO buffer length).
    pub buf_len: usize,
    /// Keys per VMEM tile in the Pallas grid.
    pub chunk: usize,
    /// Tile used by the histogram kernel.
    pub hist_chunk: usize,
    /// Histogram bins.
    pub nbins: usize,
    /// Key dtype tag (always "i32" today).
    pub dtype: String,
    pub artifacts: HashMap<String, ArtifactEntry>,
    pub dir: PathBuf,
}

fn field_u64(j: &Json, key: &str) -> Result<u64> {
    j.get(key)
        .and_then(Json::as_u64)
        .with_context(|| format!("manifest missing integer field '{key}'"))
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
        let j = minijson::parse(&text)
            .with_context(|| format!("parsing {}", path.display()))?;

        let mut artifacts = HashMap::new();
        let arts = j
            .get("artifacts")
            .and_then(Json::as_obj)
            .context("manifest missing 'artifacts' object")?;
        for (kind, entry) in arts {
            artifacts.insert(
                kind.clone(),
                ArtifactEntry {
                    file: entry
                        .get("file")
                        .and_then(Json::as_str)
                        .with_context(|| format!("artifact '{kind}' missing 'file'"))?
                        .to_string(),
                    bytes: entry.get("bytes").and_then(Json::as_u64).unwrap_or(0),
                },
            );
        }

        let m = Manifest {
            buf_len: field_u64(&j, "buf_len")? as usize,
            chunk: field_u64(&j, "chunk")? as usize,
            hist_chunk: field_u64(&j, "hist_chunk")? as usize,
            nbins: field_u64(&j, "nbins")? as usize,
            dtype: j
                .get("dtype")
                .and_then(Json::as_str)
                .context("manifest missing 'dtype'")?
                .to_string(),
            artifacts,
            dir: dir.to_path_buf(),
        };
        anyhow::ensure!(
            m.buf_len > 0 && m.chunk > 0 && m.buf_len % m.chunk == 0,
            "bad geometry: buf_len={} chunk={}",
            m.buf_len,
            m.chunk
        );
        anyhow::ensure!(m.dtype == "i32", "unsupported key dtype {}", m.dtype);
        Ok(m)
    }

    /// Absolute path of one artifact's HLO text.
    pub fn artifact_path(&self, kind: &str) -> Result<PathBuf> {
        let e = self
            .artifacts
            .get(kind)
            .with_context(|| format!("artifact '{kind}' missing from manifest"))?;
        Ok(self.dir.join(&e.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    #[test]
    fn parses_valid_manifest() {
        let dir = std::env::temp_dir().join("gkselect_manifest_ok");
        write_manifest(
            &dir,
            r#"{"buf_len":131072,"chunk":16384,"hist_chunk":4096,"nbins":128,
                "dtype":"i32","artifacts":{"count_pivot":{"file":"count_pivot.hlo.txt","bytes":10}}}"#,
        );
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.buf_len, 131072);
        assert_eq!(m.nbins, 128);
        assert!(m
            .artifact_path("count_pivot")
            .unwrap()
            .ends_with("count_pivot.hlo.txt"));
        assert!(m.artifact_path("nope").is_err());
    }

    #[test]
    fn rejects_bad_geometry() {
        let dir = std::env::temp_dir().join("gkselect_manifest_bad");
        write_manifest(
            &dir,
            r#"{"buf_len":100,"chunk":64,"hist_chunk":64,"nbins":8,"dtype":"i32","artifacts":{}}"#,
        );
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn rejects_wrong_dtype() {
        let dir = std::env::temp_dir().join("gkselect_manifest_dtype");
        write_manifest(
            &dir,
            r#"{"buf_len":128,"chunk":64,"hist_chunk":64,"nbins":8,"dtype":"f64","artifacts":{}}"#,
        );
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn missing_file_is_actionable() {
        let dir = std::env::temp_dir().join("gkselect_manifest_none");
        let _ = std::fs::remove_dir_all(&dir);
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(
            err.contains("make artifacts"),
            "error should tell the user what to run: {err}"
        );
    }
}
