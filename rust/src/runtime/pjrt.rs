//! PJRT backend: load `artifacts/*.hlo.txt`, compile once on the CPU
//! client, stream partitions through the executables.
//!
//! Interchange is HLO text (`HloModuleProto::from_text_file`): jax ≥ 0.5
//! serialized protos carry 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md and python/compile/aot.py).
//!
//! HLO shapes are static, so each executable consumes exactly `buf_len`
//! keys; the wrapper pads the tail and passes the live length in the
//! `valid` scalar — the kernels mask everything past it.

use super::kernels::{BandCounts, KernelBackend, PivotCounts};
use super::manifest::Manifest;
use crate::Key;
use anyhow::{Context, Result};
use std::path::Path;

/// Compiled artifact handles + reusable staging buffer.
pub struct PjrtBackend {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    count_pivot: xla::PjRtLoadedExecutable,
    band_count: xla::PjRtLoadedExecutable,
    histogram: xla::PjRtLoadedExecutable,
    minmax: xla::PjRtLoadedExecutable,
    buf_len: usize,
    nbins: usize,
    /// Staging buffer reused across calls (avoids a BUF_LEN alloc per
    /// chunk — §Perf iteration 1).
    stage: Vec<Key>,
}

impl PjrtBackend {
    /// Load + compile every artifact listed in `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let compile = |kind: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = manifest.artifact_path(kind)?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compiling {kind}"))
        };
        Ok(Self {
            count_pivot: compile("count_pivot")?,
            band_count: compile("band_count")?,
            histogram: compile("histogram")?,
            minmax: compile("minmax")?,
            buf_len: manifest.buf_len,
            nbins: manifest.nbins,
            stage: vec![0; manifest.buf_len],
            client,
        })
    }

    /// Stage `chunk` into the fixed-size buffer (pad tail with zeros —
    /// masked off by `valid`) and return the literal plus live length.
    fn stage_chunk(&mut self, chunk: &[Key]) -> (xla::Literal, i64) {
        let n = chunk.len().min(self.buf_len);
        self.stage[..n].copy_from_slice(&chunk[..n]);
        self.stage[n..].fill(0);
        (xla::Literal::vec1(&self.stage), n as i64)
    }

    fn run1(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<xla::Literal> {
        let result = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?)
    }
}

impl KernelBackend for PjrtBackend {
    fn count_pivot(&mut self, data: &[Key], pivot: Key) -> PivotCounts {
        let mut acc = PivotCounts::default();
        for chunk in data.chunks(self.buf_len.max(1)) {
            let (x, n) = self.stage_chunk(chunk);
            let out = Self::run1(
                &self.count_pivot,
                &[x, xla::Literal::vec1(&[pivot]), xla::Literal::vec1(&[n])],
            )
            .expect("count_pivot execution failed");
            let v = out.to_vec::<i64>().expect("count_pivot output");
            acc.add(PivotCounts {
                lt: v[0] as u64,
                eq: v[1] as u64,
                gt: v[2] as u64,
            });
        }
        acc
    }

    fn band_count(&mut self, data: &[Key], lo: Key, hi: Key) -> BandCounts {
        let mut acc = BandCounts::default();
        for chunk in data.chunks(self.buf_len.max(1)) {
            let (x, n) = self.stage_chunk(chunk);
            let out = Self::run1(
                &self.band_count,
                &[
                    x,
                    xla::Literal::vec1(&[lo]),
                    xla::Literal::vec1(&[hi]),
                    xla::Literal::vec1(&[n]),
                ],
            )
            .expect("band_count execution failed");
            let v = out.to_vec::<i64>().expect("band_count output");
            acc.below += v[0] as u64;
            acc.band += v[1] as u64;
            acc.above += v[2] as u64;
        }
        acc
    }

    fn histogram(&mut self, data: &[Key], lo: i64, width: i64, nbins: usize) -> Vec<u64> {
        assert_eq!(
            nbins, self.nbins,
            "artifact compiled for {} bins, caller wants {nbins}",
            self.nbins
        );
        let mut hist = vec![0u64; nbins];
        for chunk in data.chunks(self.buf_len.max(1)) {
            let (x, n) = self.stage_chunk(chunk);
            let out = Self::run1(
                &self.histogram,
                &[
                    x,
                    xla::Literal::vec1(&[lo]),
                    xla::Literal::vec1(&[width]),
                    xla::Literal::vec1(&[n]),
                ],
            )
            .expect("histogram execution failed");
            let v = out.to_vec::<i64>().expect("histogram output");
            for (h, add) in hist.iter_mut().zip(v) {
                *h += add as u64;
            }
        }
        hist
    }

    fn minmax(&mut self, data: &[Key]) -> Option<(Key, Key)> {
        if data.is_empty() {
            return None;
        }
        let mut lo = Key::MAX;
        let mut hi = Key::MIN;
        for chunk in data.chunks(self.buf_len.max(1)) {
            let (x, n) = self.stage_chunk(chunk);
            let out = Self::run1(&self.minmax, &[x, xla::Literal::vec1(&[n])])
                .expect("minmax execution failed");
            let v = out.to_vec::<Key>().expect("minmax output");
            lo = lo.min(v[0]);
            hi = hi.max(v[1]);
        }
        Some((lo, hi))
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
