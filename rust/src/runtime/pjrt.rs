//! PJRT backend: load `artifacts/*.hlo.txt`, compile once on the CPU
//! client, stream partitions through the executables.
//!
//! Interchange is HLO text (`HloModuleProto::from_text_file`): jax ≥ 0.5
//! serialized protos carry 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids (see
//! /opt/xla-example/README.md and python/compile/aot.py).
//!
//! HLO shapes are static, so each executable consumes exactly `buf_len`
//! keys; the wrapper pads the tail and passes the live length in the
//! `valid` scalar — the kernels mask everything past it.

use super::kernels::{BandCounts, BandExtract, KernelBackend, PivotCounts};
use super::manifest::Manifest;
use crate::Key;
use anyhow::{Context, Result};
use std::path::Path;
use std::sync::Mutex;

/// Compiled artifact handles + reusable staging buffer.
pub struct PjrtBackend {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    count_pivot: xla::PjRtLoadedExecutable,
    band_count: xla::PjRtLoadedExecutable,
    histogram: xla::PjRtLoadedExecutable,
    minmax: xla::PjRtLoadedExecutable,
    /// Fused pivot+band counting/compaction kernel. `None` for artifact
    /// directories lowered before the fused two-round protocol — the
    /// wrapper then composes the split kernels + a native compaction of
    /// the staged chunk (same result, one extra chunk read).
    band_extract: Option<xla::PjRtLoadedExecutable>,
    buf_len: usize,
    nbins: usize,
    /// Staging buffer reused across calls (avoids a BUF_LEN alloc per
    /// chunk — §Perf iteration 1). Behind a mutex because `KernelBackend`
    /// methods take `&self` (the thread pool shares one backend); the
    /// lock is held for a whole kernel call, so executions through this
    /// backend serialize — the PJRT CPU client is a correctness vehicle,
    /// not the parallel perf path.
    stage: Mutex<Vec<Key>>,
}

// SAFETY: every kernel call takes the `stage` lock for its full
// duration, so the client/executable handles are never used from two
// threads at once; the handles themselves are only *moved* across
// threads, which PJRT's C API permits.
unsafe impl Send for PjrtBackend {}
// SAFETY: shared references only reach the handles through the `stage`
// mutex (see the `Send` justification above), so concurrent `&self`
// access serializes on the lock and never aliases a kernel call.
unsafe impl Sync for PjrtBackend {}

impl PjrtBackend {
    /// Load + compile every artifact listed in `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let compile = |kind: &str| -> Result<xla::PjRtLoadedExecutable> {
            let path = manifest.artifact_path(kind)?;
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 artifact path")?,
            )
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compiling {kind}"))
        };
        let band_extract = if manifest.artifacts.contains_key("band_extract") {
            Some(compile("band_extract")?)
        } else {
            None
        };
        Ok(Self {
            count_pivot: compile("count_pivot")?,
            band_count: compile("band_count")?,
            histogram: compile("histogram")?,
            minmax: compile("minmax")?,
            band_extract,
            buf_len: manifest.buf_len,
            nbins: manifest.nbins,
            stage: Mutex::new(vec![0; manifest.buf_len]),
            client,
        })
    }

    /// Stage `chunk` into the fixed-size buffer (pad tail with zeros —
    /// masked off by `valid`) and return the literal plus live length.
    fn stage_chunk(&self, stage: &mut [Key], chunk: &[Key]) -> (xla::Literal, i64) {
        let n = chunk.len().min(self.buf_len);
        stage[..n].copy_from_slice(&chunk[..n]);
        stage[n..].fill(0);
        (xla::Literal::vec1(stage), n as i64)
    }

    fn run1(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<xla::Literal> {
        let result = exe.execute::<xla::Literal>(args)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple1()?)
    }
}

impl KernelBackend for PjrtBackend {
    fn count_pivot(&self, data: &[Key], pivot: Key) -> PivotCounts {
        let mut acc = PivotCounts::default();
        let mut stage = self.stage.lock().expect("stage lock poisoned");
        for chunk in data.chunks(self.buf_len.max(1)) {
            let (x, n) = self.stage_chunk(&mut stage, chunk);
            let out = Self::run1(
                &self.count_pivot,
                &[x, xla::Literal::vec1(&[pivot]), xla::Literal::vec1(&[n])],
            )
            .expect("count_pivot execution failed");
            let v = out.to_vec::<i64>().expect("count_pivot output");
            acc.add(PivotCounts {
                lt: v[0] as u64,
                eq: v[1] as u64,
                gt: v[2] as u64,
            });
        }
        acc
    }

    fn band_count(&self, data: &[Key], lo: Key, hi: Key) -> BandCounts {
        let mut acc = BandCounts::default();
        let mut stage = self.stage.lock().expect("stage lock poisoned");
        for chunk in data.chunks(self.buf_len.max(1)) {
            let (x, n) = self.stage_chunk(&mut stage, chunk);
            let out = Self::run1(
                &self.band_count,
                &[
                    x,
                    xla::Literal::vec1(&[lo]),
                    xla::Literal::vec1(&[hi]),
                    xla::Literal::vec1(&[n]),
                ],
            )
            .expect("band_count execution failed");
            let v = out.to_vec::<i64>().expect("band_count output");
            acc.below += v[0] as u64;
            acc.band += v[1] as u64;
            acc.above += v[2] as u64;
        }
        acc
    }

    fn histogram(&self, data: &[Key], lo: i64, width: i64, nbins: usize) -> Vec<u64> {
        assert_eq!(
            nbins, self.nbins,
            "artifact compiled for {} bins, caller wants {nbins}",
            self.nbins
        );
        let mut hist = vec![0u64; nbins];
        let mut stage = self.stage.lock().expect("stage lock poisoned");
        for chunk in data.chunks(self.buf_len.max(1)) {
            let (x, n) = self.stage_chunk(&mut stage, chunk);
            let out = Self::run1(
                &self.histogram,
                &[
                    x,
                    xla::Literal::vec1(&[lo]),
                    xla::Literal::vec1(&[width]),
                    xla::Literal::vec1(&[n]),
                ],
            )
            .expect("histogram execution failed");
            let v = out.to_vec::<i64>().expect("histogram output");
            for (h, add) in hist.iter_mut().zip(v) {
                *h += add as u64;
            }
        }
        hist
    }

    fn minmax(&self, data: &[Key]) -> Option<(Key, Key)> {
        if data.is_empty() {
            return None;
        }
        let mut lo = Key::MAX;
        let mut hi = Key::MIN;
        let mut stage = self.stage.lock().expect("stage lock poisoned");
        for chunk in data.chunks(self.buf_len.max(1)) {
            let (x, n) = self.stage_chunk(&mut stage, chunk);
            let out = Self::run1(&self.minmax, &[x, xla::Literal::vec1(&[n])])
                .expect("minmax execution failed");
            let v = out.to_vec::<Key>().expect("minmax output");
            lo = lo.min(v[0]);
            hi = hi.max(v[1]);
        }
        Some((lo, hi))
    }

    fn band_extract(
        &self,
        data: &[Key],
        pivot: Key,
        lo: Key,
        hi: Key,
        budget: usize,
    ) -> BandExtract {
        debug_assert!(lo <= hi, "band [{lo}, {hi}] inverted");
        let mut out = BandExtract::default();
        let mut stage = self.stage.lock().expect("stage lock poisoned");
        for chunk in data.chunks(self.buf_len.max(1)) {
            let (x, n) = self.stage_chunk(&mut stage, chunk);
            if let Some(exe) = &self.band_extract {
                // fused artifact: [lt, eq, below, eq_lo, inner, eq_hi]
                // followed by the compacted open-band values
                let run = Self::run1(
                    exe,
                    &[
                        x,
                        xla::Literal::vec1(&[pivot]),
                        xla::Literal::vec1(&[lo]),
                        xla::Literal::vec1(&[hi]),
                        xla::Literal::vec1(&[n]),
                    ],
                )
                .expect("band_extract execution failed");
                let v = run.to_vec::<i64>().expect("band_extract output");
                out.pivot.lt += v[0] as u64;
                out.pivot.eq += v[1] as u64;
                out.band.below += v[2] as u64;
                out.band.eq_lo += v[3] as u64;
                out.band.inner += v[4] as u64;
                out.band.eq_hi += v[5] as u64;
                if !out.overflow {
                    out.candidates
                        .extend(v[6..6 + v[4] as usize].iter().map(|&k| k as Key));
                }
            } else {
                // pre-fusion artifacts: split executable for the pivot
                // counts, native compaction of the chunk
                let run = Self::run1(
                    &self.count_pivot,
                    &[x, xla::Literal::vec1(&[pivot]), xla::Literal::vec1(&[n])],
                )
                .expect("count_pivot execution failed");
                let pc = run.to_vec::<i64>().expect("count_pivot output");
                out.pivot.lt += pc[0] as u64;
                out.pivot.eq += pc[1] as u64;
                for &v in chunk {
                    out.band.below += u64::from(v < lo);
                    out.band.eq_lo += u64::from(v == lo);
                    out.band.eq_hi += u64::from(v == hi);
                    if v > lo && v < hi {
                        out.band.inner += 1;
                        if !out.overflow {
                            out.candidates.push(v);
                        }
                    }
                }
            }
            if out.candidates.len() > budget {
                out.overflow = true;
                out.candidates = Vec::new();
            }
        }
        out.finalize(data.len() as u64, lo, hi);
        out
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}
