//! Runtime layer: AOT-compiled XLA artifacts on the L3 hot path.
//!
//! `make artifacts` lowers the L2 jax pipeline (whose bodies are the L1
//! Pallas kernels) to HLO **text** under `artifacts/`; [`PjrtRuntime`]
//! loads them through the PJRT CPU client (`xla` crate) at startup and
//! exposes typed entry points. Python never runs at request time.
//!
//! Two interchangeable backends implement [`KernelBackend`]:
//!
//! * [`PjrtBackend`] — streams partitions through the compiled
//!   executables `BUF_LEN` keys at a time (static HLO shapes; the live
//!   prefix length travels in the `valid` scalar).
//! * [`NativeBackend`] — native rust, bit-identical results; the
//!   correctness oracle for the PJRT path and the perf comparison point
//!   (interpret-mode Pallas on CPU is a correctness vehicle, not a speed
//!   one — DESIGN.md §Perf). Its fused band scan carries an explicit
//!   SIMD tile (AVX2/SSE2) behind runtime dispatch — see [`simd`].

pub mod kernels;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod simd;

pub use kernels::{BandCounts, BandExtract, BandStats, KernelBackend, NativeBackend, PivotCounts};
pub use manifest::Manifest;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtBackend;
pub use simd::{SimdDispatch, SimdPolicy};

use anyhow::Result;
use std::path::Path;

/// Pick a backend by name ("native" or "pjrt"), loading artifacts from
/// `dir` for the pjrt path. `simd` governs the native backend's
/// band-scan dispatch (see [`simd`]); the PJRT path ignores it — its
/// vectorization happens in XLA.
pub fn backend_from_name(
    name: &str,
    dir: &Path,
    simd: SimdPolicy,
) -> Result<Box<dyn KernelBackend>> {
    match name {
        "native" => Ok(Box::new(NativeBackend::with_policy(simd))),
        #[cfg(feature = "pjrt")]
        "pjrt" => Ok(Box::new(PjrtBackend::load(dir)?)),
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => {
            let _ = dir;
            anyhow::bail!(
                "backend 'pjrt' requires building with `--features pjrt` \
                 (and the `xla` crate — see Cargo.toml)"
            )
        }
        other => anyhow::bail!("unknown backend '{other}' (expected native|pjrt)"),
    }
}
