//! LSD radix sort for `i32` keys — the sketch-flush hot loop (§Perf L3.3).
//!
//! Every GK flush sorts a head buffer of a few thousand to 50 000 keys;
//! comparison sorting pays `log B` passes where two 16-bit-digit counting
//! passes suffice. Signed order falls out of XOR-ing the sign bit. Falls
//! back to `sort_unstable` below the size where the 2×64Ki counter tables
//! stop paying for themselves.

/// Size below which `sort_unstable` wins (counter-table setup dominates).
pub const RADIX_CUTOFF: usize = 4096;

/// Sort `a` ascending. Allocation: one scratch buffer of `a.len()` plus
/// two 64Ki counter tables.
pub fn radix_sort_i32(a: &mut [i32]) {
    if a.len() < RADIX_CUTOFF {
        a.sort_unstable();
        return;
    }
    let n = a.len();
    let mut scratch: Vec<i32> = vec![0; n];

    // pass 1: low 16 bits (stable)
    let mut counts = vec![0u32; 1 << 16];
    for &v in a.iter() {
        counts[(v as u32 & 0xFFFF) as usize] += 1;
    }
    let mut sum = 0u32;
    for c in counts.iter_mut() {
        let t = *c;
        *c = sum;
        sum += t;
    }
    for &v in a.iter() {
        let d = (v as u32 & 0xFFFF) as usize;
        scratch[counts[d] as usize] = v;
        counts[d] += 1;
    }

    // pass 2: high 16 bits with the sign bit flipped (signed order)
    let mut counts = vec![0u32; 1 << 16];
    for &v in scratch.iter() {
        counts[(((v as u32) ^ 0x8000_0000) >> 16) as usize] += 1;
    }
    let mut sum = 0u32;
    for c in counts.iter_mut() {
        let t = *c;
        *c = sum;
        sum += t;
    }
    for &v in scratch.iter() {
        let d = (((v as u32) ^ 0x8000_0000) >> 16) as usize;
        a[counts[d] as usize] = v;
        counts[d] += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::pcg::Pcg64;

    fn check(mut v: Vec<i32>) {
        let mut want = v.clone();
        want.sort_unstable();
        radix_sort_i32(&mut v);
        assert_eq!(v, want);
    }

    #[test]
    fn below_cutoff_small() {
        check(vec![]);
        check(vec![5]);
        check(vec![3, -1, 2, -7, 0]);
    }

    #[test]
    fn above_cutoff_random_signed() {
        let mut rng = Pcg64::new(3, 3);
        let v: Vec<i32> = (0..100_000).map(|_| rng.next_u64() as i32).collect();
        check(v);
    }

    #[test]
    fn extremes_and_duplicates() {
        let mut rng = Pcg64::new(4, 4);
        let mut v: Vec<i32> = (0..20_000).map(|_| (rng.next_u64() % 5) as i32 - 2).collect();
        v.extend([i32::MIN, i32::MAX, 0, i32::MIN, i32::MAX]);
        check(v);
    }

    #[test]
    fn already_sorted_and_reversed() {
        check((0..50_000).collect());
        check((0..50_000).rev().collect());
    }
}
