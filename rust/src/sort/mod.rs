//! Distributed sorting substrate.

pub mod psrs;
pub mod radix;

pub use psrs::{psrs_sort, PsrsParams, SortedDataset};
pub use radix::radix_sort_i32;
