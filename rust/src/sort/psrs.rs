//! Spark Full Sort: PSRS-style range-partition sort (§IV-A).
//!
//! The five steps the paper spells out, with the same synchronization
//! shape as Spark's `orderBy`:
//!
//! 1. each partition samples `r` keys (reservoir, like
//!    `RangePartitioner.sketch`);
//! 2. the driver `collect`s the samples — **first stage boundary**;
//! 3. the driver sorts the samples, picks `P − 1` splitters at even
//!    quantiles and `TorrentBroadcast`s them (no stage boundary);
//! 4. executors route every record to its splitter range — the global
//!    shuffle, **second stage boundary**;
//! 5. each executor sorts its bucket locally (`sort_unstable`, the stand-
//!    in for `UnsafeExternalSorter`'s in-memory path).
//!
//! `orderBy` itself is one round (one job): the collect of samples is an
//! internal action of `RangePartitioner`, so the paper's Table V counts
//! rounds = 1 with a `†`. We count the sample collect's synchronization
//! as a stage boundary and fold the whole pipeline into a single round to
//! match the corrected table.

use crate::cluster::dataset::Dataset;
use crate::cluster::shuffle::shuffle_by_range;
use crate::cluster::{Cluster, StageError};
use crate::select::SplitMix64;
use crate::Key;

/// Tuning knobs for PSRS.
#[derive(Debug, Clone)]
pub struct PsrsParams {
    /// Samples per partition (`r` in Table I; Spark samples ~20/partition
    /// scaled by size).
    pub samples_per_partition: usize,
    pub seed: u64,
}

impl Default for PsrsParams {
    fn default() -> Self {
        Self {
            samples_per_partition: 20,
            seed: 0xC0FFEE,
        }
    }
}

/// A globally range-partitioned, locally sorted dataset: bucket `i` holds
/// keys ≤ bucket `i+1`'s, each bucket ascending.
#[derive(Debug)]
pub struct SortedDataset {
    pub data: Dataset<Key>,
    pub splitters: Vec<Key>,
}

impl SortedDataset {
    /// Global rank lookup: the k-th smallest key (0-based) by walking
    /// bucket sizes — how Spark answers an exact quantile after `orderBy`.
    pub fn kth(&self, k: u64) -> Option<Key> {
        let mut remaining = k;
        for p in 0..self.data.num_partitions() {
            let part = self.data.partition(p);
            if (remaining as usize) < part.len() {
                return Some(part[remaining as usize]);
            }
            remaining -= part.len() as u64;
        }
        None
    }
}

/// Run the full PSRS pipeline, charging the substrate for every
/// synchronization and byte. Fallible like any multi-stage job: a stage
/// that exhausts its task retries under the fault model surfaces as a
/// typed [`StageError`].
pub fn psrs_sort(
    cluster: &mut Cluster,
    data: &Dataset<Key>,
    params: &PsrsParams,
) -> Result<SortedDataset, StageError> {
    let p = cluster.cfg.partitions;

    // 1. per-partition reservoir sample
    let seed = params.seed;
    let spp = params.samples_per_partition;
    let samples = cluster.map_partitions(data, |part, ctx| {
        let mut rng = SplitMix64::new(seed ^ (ctx.partition as u64) << 1);
        let mut res: Vec<Key> = Vec::with_capacity(spp);
        for (i, &v) in part.iter().enumerate() {
            if res.len() < spp {
                res.push(v);
            } else {
                let j = rng.below(i + 1);
                if j < spp {
                    res[j] = v;
                }
            }
        }
        res
    })?;

    // 2. collect samples (first stage boundary). This is an internal
    // action of RangePartitioner: we count its stage boundary but merge
    // the round into the single orderBy job (Table V note †).
    let collected = cluster.collect(samples);
    cluster.metrics.rounds -= 1; // internal action, not a user-visible round

    // 3. driver: sort samples, choose P-1 splitters, broadcast
    let splitters = cluster.driver(|| {
        let mut all: Vec<Key> = collected.into_iter().flatten().collect();
        all.sort_unstable();
        if all.is_empty() {
            return Vec::new();
        }
        (1..p)
            .map(|i| all[(i * all.len()) / p])
            .collect::<Vec<Key>>()
    });
    cluster.broadcast(&splitters);

    // 4. range-partition shuffle (second stage boundary)
    let routed = shuffle_by_range(cluster, data, &splitters);

    // 5. local sort per bucket; the job's action ends the (single) round.
    // Spark's `orderBy` leaves sorted buckets on executors — the driver
    // only sees task metadata, so the final action's network charge is
    // ~8 bytes per bucket, not the payload.
    let sorted = cluster.map_partitions(&routed, |part, _| {
        let mut v = part.to_vec();
        v.sort_unstable();
        SizedOnly(v)
    })?;
    let parts: Vec<Vec<Key>> = cluster
        .collect(sorted)
        .into_iter()
        .map(|SizedOnly(v)| v)
        .collect();

    Ok(SortedDataset {
        data: Dataset::from_partitions(parts).expect("shuffle preserves partition count"),
        splitters,
    })
}

/// Wrapper so the final action charges only task-status bytes: the sorted
/// payload stays executor-resident.
struct SizedOnly(Vec<Key>);

impl crate::cluster::netmodel::NetSize for SizedOnly {
    fn net_bytes(&self) -> u64 {
        8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;
    use crate::data::{DataGenerator, Distribution};

    fn sort_n(n: u64, dist: Distribution) -> (Cluster, SortedDataset, Vec<Key>) {
        let mut c = Cluster::new(ClusterConfig::local(2, 8));
        let data = dist.generator(11).generate(&mut c, n);
        let mut oracle = data.to_vec();
        oracle.sort_unstable();
        let sorted = psrs_sort(&mut c, &data, &PsrsParams::default()).unwrap();
        (c, sorted, oracle)
    }

    #[test]
    fn produces_globally_sorted_permutation() {
        let (_, sorted, oracle) = sort_n(50_000, Distribution::Uniform);
        let flat = sorted.data.to_vec();
        assert_eq!(flat, oracle);
    }

    #[test]
    fn kth_matches_oracle() {
        let (_, sorted, oracle) = sort_n(10_000, Distribution::Uniform);
        for &k in &[0u64, 1, 4_999, 5_000, 9_998, 9_999] {
            assert_eq!(sorted.kth(k), Some(oracle[k as usize]));
        }
        assert_eq!(sorted.kth(10_000), None);
    }

    #[test]
    fn skewed_data_still_sorted() {
        let (_, sorted, oracle) = sort_n(30_000, Distribution::Zipf);
        assert_eq!(sorted.data.to_vec(), oracle);
    }

    #[test]
    fn presorted_data_still_sorted() {
        let (_, sorted, oracle) = sort_n(30_000, Distribution::Sorted);
        assert_eq!(sorted.data.to_vec(), oracle);
    }

    #[test]
    fn charges_one_shuffle_one_round_two_stage_boundaries_plus_action() {
        let (c, _, _) = sort_n(10_000, Distribution::Uniform);
        assert_eq!(c.metrics.shuffles, 1);
        // sample collect + shuffle + final action = 3 stage boundaries
        assert_eq!(c.metrics.stage_boundaries, 3);
        // sample-collect round folded in; final action ends the 1 round
        assert_eq!(c.metrics.rounds, 1);
        assert!(c.metrics.bytes_shuffled > 0, "sort must move data");
    }

    #[test]
    fn network_volume_is_order_n() {
        let (c, _, _) = sort_n(40_000, Distribution::Uniform);
        let payload = 40_000 * 4;
        // with E executors a uniform shuffle moves ≈ (E-1)/E of the data;
        // E = 2 here ⇒ expect ≈ payload/2 (allow sampling noise)
        assert!(
            c.metrics.bytes_shuffled as f64 > 0.4 * payload as f64,
            "moved only {} of {payload}",
            c.metrics.bytes_shuffled
        );
    }

    #[test]
    fn tiny_input_fewer_records_than_partitions() {
        let mut c = Cluster::new(ClusterConfig::local(2, 8));
        let data = Dataset::from_vec(vec![3, 1, 2], 8).unwrap();
        let sorted = psrs_sort(&mut c, &data, &PsrsParams::default()).unwrap();
        assert_eq!(sorted.data.to_vec(), vec![1, 2, 3]);
    }
}
