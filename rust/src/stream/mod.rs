//! Streaming quantile service: micro-batch ingestion, a per-partition
//! sketch store, and one-scan exact queries.
//!
//! The batch reproduction answers every query from scratch: a sketch
//! pass plus the fused band-extract pass — 2 rounds, 2 data scans, even
//! when the data barely changed since the last query. In a serving
//! setting (accumulating telemetry, many queries per ingest) the sketch
//! pass is pure waste. This subsystem decouples the two:
//!
//! * [`ingest`] — [`StreamIngestor`] seals each [`MicroBatch`] as a new
//!   immutable epoch (fresh partitions; sealed epochs are never
//!   mutated) and folds the batch into per-partition [`GkCore`]
//!   partials on the executor pool. **Ingest pays the sketch scan, once
//!   per batch.**
//! * [`store`] — [`SketchStore`] keys the partials by stream id ×
//!   epoch. Epoch compaction folds old epochs (sketch merge + aligned
//!   partition rewrite) so the cached-sketch footprint stays `O(P/ε)`
//!   no matter how many batches ever arrived.
//! * [`query`] — [`StreamQuery`] answers exact quantile /
//!   multi-quantile queries by tree-merging the *cached* partials on
//!   the driver (no data scan) and running only the fused band-extract
//!   scan over the zero-copy union of live epochs.
//!
//! Cost shape, measured by the per-operation metrics snapshots every
//! outcome carries:
//!
//! | operation            | rounds | data scans | scanned records |
//! |----------------------|--------|------------|-----------------|
//! | batch `GkSelect`     | 2      | 2          | 2n per query    |
//! | stream ingest        | 1      | 1          | batch only      |
//! | stream query         | 1      | 1          | n, once         |
//!
//! Exactness is inherited, not re-proven: the query path reuses the
//! batch GK Select / Multi-Select fused protocol, whose answer is
//! checked against *measured* counts and backed by the classic
//! extraction fallback — a stale or hostile sketch costs one extra
//! scan, never correctness.
//!
//! Ingest is **atomic under stage failure**: the epoch's partitions and
//! sketch partials are built entirely on the executor pool *before* the
//! store seals anything, so an ingest whose sketch stage exhausts its
//! retry budget (`EngineError::StageFailed`) leaves the [`SketchStore`]
//! byte-identical — no half-sealed epoch, no count drift — and the
//! stream keeps answering exactly from the batches that did land
//! (`tests/proptest_faults.rs` pins this in both exec modes).
//!
//! # Example
//!
//! Streams flow through the engine: `ingest` seals micro-batches,
//! `execute(Source::Stream(..), ..)` answers exactly from the cached
//! sketches — one round, one data scan — through the same call site as
//! every batch query:
//!
//! ```
//! use gkselect::prelude::*;
//!
//! let mut engine = EngineBuilder::new()
//!     .cluster(ClusterConfig::local(2, 4))
//!     .build()
//!     .unwrap();
//!
//! // each ingest scans only its own batch (1 round / 1 scan)
//! engine.ingest("s", MicroBatch::new((0..600).collect())).unwrap();
//! engine.ingest("s", MicroBatch::new((600..1_000).collect())).unwrap();
//!
//! // the query tree-merges cached partials (no scan) and pays one
//! // fused band-extract pass over the live epochs
//! let out = engine.execute(Source::Stream("s"), QuantileQuery::Single(0.5)).unwrap();
//! assert_eq!(out.value(), 500); // exact over all 1000 live records
//! assert_eq!((out.report.rounds, out.report.data_scans), (1, 1));
//! ```
//!
//! [`GkCore`]: crate::sketch::GkCore
//! [`StreamQuery`]: query::StreamQuery

pub mod ingest;
pub mod query;
pub mod store;

pub use ingest::{IngestOutcome, MicroBatch, StreamIngestor};
pub use query::StreamQuery;
pub use store::{
    CompactionPolicy, CompactionStats, Epoch, SketchStore, StreamSnapshot, StreamState,
};
