//! Micro-batch ingestion: the append path of the streaming service.
//!
//! One [`StreamIngestor::ingest`] call is the sketch round the batch
//! path pays *per query*, moved to ingest time and paid **once per
//! batch**: the batch is partitioned into a fresh epoch (sealed datasets
//! are never mutated) and each partition builds its
//! [`crate::sketch::GkCore`] partial with the batch path's own
//! per-partition construction
//! ([`crate::algorithms::approx_quantile::sketch_partition`]; `Bulk` by
//! default — radix sort + zero-slack `from_sorted`, or any streamed GK
//! variant via [`StreamIngestor::with_variant`]) — running on the
//! executor pool like any `mapPartitions` stage — and the epoch lands in
//! the [`SketchStore`]. Incremental growth happens by *merging*, never
//! rebuilding: the store folds epochs with `GkCore::merge_with` at
//! compaction, charged as a persist — the only time streamed data is
//! ever rewritten.
//!
//! Cost per batch: **1 round, 1 data scan over the new records only** —
//! queries then reuse the cached partials for free.

use anyhow::{ensure, Result};

use super::store::SketchStore;
use crate::algorithms::approx_quantile::{sketch_partition, SketchVariant};
use crate::cluster::dataset::Dataset;
use crate::cluster::metrics::MetricsReport;
use crate::cluster::Cluster;
use crate::obs::{SpanKind, Trace};
use crate::Key;

/// One ingestion unit: the records that arrived since the last tick.
#[derive(Debug, Clone, Default)]
pub struct MicroBatch {
    pub values: Vec<Key>,
}

impl MicroBatch {
    pub fn new(values: Vec<Key>) -> Self {
        Self { values }
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

/// The append path: owns the sketch precision and variant (the store
/// owns the data, the cluster owns the execution).
#[derive(Debug, Clone, Copy)]
pub struct StreamIngestor {
    /// GK relative error of the cached partials. The query engine
    /// budgets against the looser of its own ε and the cached sketch's,
    /// so a mismatch costs band width, never correctness.
    pub epsilon: f64,
    /// Which GK construction runs per partition (default: `Bulk`, the
    /// radix-sort + zero-slack `from_sorted` fast path — §Perf L3.4).
    pub variant: SketchVariant,
}

/// Receipt for one ingested micro-batch.
#[derive(Debug, Clone)]
pub struct IngestOutcome {
    /// Epoch id the batch was sealed as.
    pub epoch: u64,
    /// Records in this batch.
    pub batch_records: u64,
    /// Live records across the whole stream after the seal.
    pub stream_records: u64,
    /// Live epochs after the seal (and possible compaction).
    pub live_epochs: usize,
    /// Epochs folded by a triggered compaction (0 = none fired).
    pub compacted_epochs: usize,
    /// Payload bytes the compaction rewrote (charged as a persist).
    pub bytes_rewritten: u64,
    /// Store footprint (cached sketches + payload) after the seal.
    pub store_bytes: u64,
    /// The ingest's own cost: metrics delta for exactly this call.
    pub report: MetricsReport,
    /// The ingest's span tree, filled in by the engine when it drains a
    /// span-collecting sink; `None` for standalone ingestor use or the
    /// default `TraceSink::Null`.
    pub trace: Option<Trace>,
}

impl StreamIngestor {
    pub fn new(epsilon: f64) -> Result<Self> {
        ensure!(
            epsilon > 0.0 && epsilon < 1.0,
            "epsilon must be in (0,1), got {epsilon}"
        );
        Ok(Self {
            epsilon,
            variant: SketchVariant::Bulk,
        })
    }

    /// Override the per-partition sketch construction (builder-style).
    pub fn with_variant(mut self, variant: SketchVariant) -> Self {
        self.variant = variant;
        self
    }

    /// Ingest `batch` into `stream`: seal a new epoch with its
    /// per-partition sketch partials, compacting the store if the policy
    /// says so. An empty batch is a recoverable error — the stream stays
    /// untouched.
    pub fn ingest(
        &self,
        cluster: &mut Cluster,
        store: &mut SketchStore,
        stream: &str,
        batch: MicroBatch,
    ) -> Result<IngestOutcome> {
        ensure!(
            !batch.is_empty(),
            "empty micro-batch for stream '{stream}'"
        );
        if let Some(state) = store.stream(stream) {
            ensure!(
                state.partitions() == cluster.cfg.partitions,
                "stream '{stream}' is partitioned {}-way, cluster runs {} partitions",
                state.partitions(),
                cluster.cfg.partitions
            );
        }
        let base = cluster.metrics.mark();
        let clock0 = cluster.elapsed_secs();

        let data = Dataset::from_vec(batch.values, cluster.cfg.partitions)?;
        let batch_records = data.len();
        let eps = self.epsilon;
        let variant = self.variant;
        let iid = cluster
            .tracer
            .open(SpanKind::Ingest, format!("ingest {stream}"), clock0);
        cluster.tracer.attr(iid, "stream", stream);
        cluster.tracer.attr(iid, "records", batch_records);
        cluster.tracer.attr(iid, "epsilon", eps);
        // the ingest-time sketch pass: same per-partition construction as
        // the batch path's round 1 (Bulk = radix sort + zero-slack
        // from_sorted), one O(1/ε) summary per partition
        // a stage failure propagates here BEFORE seal_epoch runs, so a
        // failed micro-batch leaves the store exactly unchanged — no
        // partially sealed epoch to poison later queries
        let pending =
            match cluster.map_partitions(&data, |part, _| sketch_partition(variant, eps, part)) {
                Ok(p) => p,
                Err(e) => {
                    let now = cluster.elapsed_secs();
                    cluster.tracer.close(iid, now);
                    return Err(e.into());
                }
            };
        let sketches = cluster.collect(pending);

        let epoch = store.seal_epoch(stream, data, sketches)?;
        let (compacted_epochs, bytes_rewritten) = if store.needs_compaction(stream) {
            // driver-side fold of cached partials + partition-aligned
            // data rewrite; the rewrite is the persist the cost model
            // charges
            let stats = cluster.driver(|| store.compact(stream))?;
            match stats {
                Some(s) => {
                    cluster.persist_bytes(s.bytes_rewritten);
                    (s.merged_epochs, s.bytes_rewritten)
                }
                None => (0, 0),
            }
        } else {
            (0, 0)
        };

        let state = store.stream(stream).expect("epoch just sealed");
        {
            let now = cluster.elapsed_secs();
            cluster.tracer.attr(iid, "epoch", epoch);
            cluster.tracer.close(iid, now);
        }
        let delta = cluster.metrics.since(&base);
        let report = MetricsReport::from_metrics(
            "Stream Ingest",
            batch_records,
            cluster.cfg.partitions,
            cluster.cfg.executors,
            cluster.elapsed_secs() - clock0,
            &delta,
            true,
        );
        Ok(IngestOutcome {
            epoch,
            batch_records,
            stream_records: state.total_count(),
            live_epochs: state.live_epochs(),
            compacted_epochs,
            bytes_rewritten,
            store_bytes: state.store_bytes(),
            report,
            trace: None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::ClusterConfig;

    fn cluster() -> Cluster {
        Cluster::new(ClusterConfig::local(2, 4))
    }

    #[test]
    fn ingest_seals_epoch_with_one_round_one_scan() {
        let mut c = cluster();
        let mut store = SketchStore::default();
        let ing = StreamIngestor::new(0.02).unwrap();
        let out = ing
            .ingest(&mut c, &mut store, "s", MicroBatch::new((0..1000).collect()))
            .unwrap();
        assert_eq!(out.epoch, 0);
        assert_eq!(out.batch_records, 1000);
        assert_eq!(out.stream_records, 1000);
        assert_eq!(out.live_epochs, 1);
        assert_eq!(out.report.rounds, 1, "ingest = the sketch round");
        assert_eq!(out.report.data_scans, 1, "only the new records are read");
        assert_eq!(out.report.shuffles, 0);
        assert_eq!(out.report.persists, 0);
        assert!(out.store_bytes > 0);
        let st = store.stream("s").unwrap();
        assert_eq!(st.sketch_partials(), 4);
        assert_eq!(st.merged_sketch().unwrap().count, 1000);
    }

    #[test]
    fn second_ingest_scans_only_its_own_batch() {
        let mut c = cluster();
        let mut store = SketchStore::default();
        let ing = StreamIngestor::new(0.02).unwrap();
        ing.ingest(&mut c, &mut store, "s", MicroBatch::new((0..500).collect()))
            .unwrap();
        let out = ing
            .ingest(&mut c, &mut store, "s", MicroBatch::new((500..800).collect()))
            .unwrap();
        // the per-call delta sees one round/scan even though the cluster
        // ledger now carries two
        assert_eq!(out.report.rounds, 1);
        assert_eq!(out.report.data_scans, 1);
        assert_eq!(out.batch_records, 300);
        assert_eq!(out.stream_records, 800);
        assert_eq!(c.metrics.data_scans, 2);
    }

    #[test]
    fn empty_batch_is_recoverable_and_stream_untouched() {
        let mut c = cluster();
        let mut store = SketchStore::default();
        let ing = StreamIngestor::new(0.02).unwrap();
        ing.ingest(&mut c, &mut store, "s", MicroBatch::new(vec![1, 2, 3]))
            .unwrap();
        let err = ing.ingest(&mut c, &mut store, "s", MicroBatch::default());
        assert!(err.is_err());
        assert_eq!(store.stream("s").unwrap().total_count(), 3);
        // a bad ε is also an Err, not an abort
        assert!(StreamIngestor::new(0.0).is_err());
    }

    #[test]
    fn threshold_crossing_triggers_compaction_and_charges_persist() {
        let mut c = cluster();
        let mut store = SketchStore::new(crate::stream::CompactionPolicy {
            compact_threshold: 3,
            max_live_epochs: 2,
        })
        .unwrap();
        let ing = StreamIngestor::new(0.05).unwrap();
        let mut last = None;
        for b in 0..4i32 {
            let vals: Vec<Key> = (b * 100..b * 100 + 100).collect();
            last = Some(
                ing.ingest(&mut c, &mut store, "s", MicroBatch::new(vals))
                    .unwrap(),
            );
        }
        let out = last.unwrap();
        // 4th seal crossed threshold 3 → oldest 3 folded into 1
        assert_eq!(out.compacted_epochs, 3);
        assert_eq!(out.live_epochs, 2);
        assert_eq!(out.bytes_rewritten, 3 * 100 * 4);
        assert_eq!(out.report.persists, 1);
        assert_eq!(store.stream("s").unwrap().total_count(), 400);
        // partials bounded by max_live × partitions
        assert_eq!(store.stream("s").unwrap().sketch_partials(), 8);
    }
}
