//! The sketch store: per-stream epoch registry + compaction.
//!
//! Every micro-batch a stream ingests is sealed into an [`Epoch`]: the
//! batch's immutable `Dataset` plus one mergeable [`GkCore`] partial per
//! partition, built at ingest time. Queries never rebuild sketches — the
//! store *is* the cache, keyed by stream id × epoch.
//!
//! Without compaction the store would hold `K × P` sketch partials after
//! `K` batches. [`SketchStore::compact`] folds the oldest epochs into
//! one (datasets merged partition-wise, partials merged with
//! [`GkCore::merge_with`]), so the live-sketch footprint stays
//! `O(P/ε)` — independent of how many batches ever arrived — while the
//! payload data is only ever rewritten, never dropped: queries stay
//! exact across compactions.
//!
//! # Snapshots
//!
//! Epochs are `Arc`-shared and the queryable view of a stream is an
//! immutable [`StreamSnapshot`]: the epoch list plus its own
//! merged-sketch memo. [`StreamState::snapshot`] hands out the current
//! one (cheap `Arc` clone); seal and compaction *replace* it rather than
//! mutating it. A pinned snapshot therefore keeps answering over exactly
//! the epoch set it captured — readers are never blocked by, and never
//! observe, a concurrent seal or fold. Memoizing the merged sketch *on
//! the snapshot* (not on the mutable stream state) is what makes the
//! cache un-stale-able: the memo lives and dies with the epoch list it
//! summarizes, so no invalidation protocol can be missed on any write
//! path. The serving layer ([`crate::service`]) builds its whole
//! single-writer/many-reader read path out of this.

use std::cell::OnceCell;
use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock};

use anyhow::{ensure, Result};

use crate::cluster::dataset::Dataset;
use crate::cluster::netmodel::NetSize;
use crate::sketch::modified::tree_merge;
use crate::sketch::GkCore;
use crate::Key;

/// When and how far the store folds old epochs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompactionPolicy {
    /// Live-epoch count that triggers a compaction at the next seal.
    pub compact_threshold: usize,
    /// Epochs retained after a compaction (the oldest
    /// `live − max_live_epochs + 1` fold into one).
    pub max_live_epochs: usize,
}

impl Default for CompactionPolicy {
    fn default() -> Self {
        Self {
            compact_threshold: 8,
            max_live_epochs: 4,
        }
    }
}

impl CompactionPolicy {
    pub fn validate(&self) -> Result<()> {
        ensure!(self.max_live_epochs >= 1, "max_live_epochs must be ≥ 1");
        ensure!(
            self.compact_threshold >= self.max_live_epochs,
            "compact_threshold ({}) below max_live_epochs ({})",
            self.compact_threshold,
            self.max_live_epochs
        );
        Ok(())
    }
}

/// One sealed micro-batch: immutable data + its cached sketch partials.
#[derive(Debug, Clone)]
pub struct Epoch {
    /// Monotone per-stream id (compaction keeps the oldest id of the
    /// epochs it folds, so ids stay ordered).
    pub id: u64,
    /// The batch's records, partitioned like the ingesting cluster.
    pub data: Dataset<Key>,
    /// One mergeable GK partial per partition, built at ingest.
    pub sketches: Vec<GkCore>,
    /// Records in this epoch.
    pub count: u64,
}

impl Epoch {
    /// Serialized size of the cached partials (store-accounting).
    pub fn sketch_bytes(&self) -> u64 {
        self.sketches.iter().map(NetSize::net_bytes).sum()
    }
}

/// An immutable, shareable view of one stream at one seal point: the
/// `Arc`-shared epoch list plus a merged-sketch memo scoped to exactly
/// that list. This is the unit of snapshot isolation — a query that
/// pinned a snapshot keeps reading it bit-identically no matter how many
/// seals or compactions land afterwards, and the memo can never be newer
/// or older than the epochs it summarizes because they are one object.
#[derive(Debug)]
pub struct StreamSnapshot {
    epochs: Vec<Arc<Epoch>>,
    seal_seq: u64,
    partitions: usize,
    compactions: u64,
    /// Merged-sketch memo, filled by the first reader of this snapshot.
    /// `OnceLock` (not `OnceCell`) because pinned snapshots cross
    /// threads in the serving layer.
    merged: OnceLock<Option<GkCore>>,
}

impl StreamSnapshot {
    fn new(epochs: Vec<Arc<Epoch>>, seal_seq: u64, partitions: usize, compactions: u64) -> Self {
        Self {
            epochs,
            seal_seq,
            partitions,
            compactions,
            merged: OnceLock::new(),
        }
    }

    /// An empty snapshot (a stream nobody has ingested into yet).
    pub fn empty(partitions: usize) -> Self {
        Self::new(Vec::new(), 0, partitions, 0)
    }

    /// The epochs this snapshot pins, oldest first.
    pub fn epochs(&self) -> &[Arc<Epoch>] {
        &self.epochs
    }

    /// Live epochs in this snapshot.
    pub fn live_epochs(&self) -> usize {
        self.epochs.len()
    }

    /// Epochs sealed over the stream's lifetime up to this snapshot
    /// (monotone across the snapshots of one stream).
    pub fn sealed_epochs(&self) -> u64 {
        self.seal_seq
    }

    /// Partition count every epoch carries.
    pub fn partitions(&self) -> usize {
        self.partitions
    }

    /// Compactions run up to this snapshot.
    pub fn compactions(&self) -> u64 {
        self.compactions
    }

    /// Total records across the pinned epochs.
    pub fn total_count(&self) -> u64 {
        self.epochs.iter().map(|e| e.count).sum()
    }

    /// Cached sketch partials held (`live_epochs × partitions`).
    pub fn sketch_partials(&self) -> usize {
        self.epochs.iter().map(|e| e.sketches.len()).sum()
    }

    /// Serialized size of all cached partials.
    pub fn sketch_bytes(&self) -> u64 {
        self.epochs.iter().map(|e| e.sketch_bytes()).sum()
    }

    /// Payload bytes across the pinned epochs.
    pub fn data_bytes(&self) -> u64 {
        self.epochs.iter().map(|e| e.data.data_bytes()).sum()
    }

    /// Store footprint: cached sketches + payload.
    pub fn store_bytes(&self) -> u64 {
        self.sketch_bytes() + self.data_bytes()
    }

    /// Zero-copy union over every pinned epoch — the dataset a streamed
    /// query's single fused scan reads.
    pub fn live_dataset(&self) -> Result<Dataset<Key>> {
        let views: Vec<Dataset<Key>> = self.epochs.iter().map(|e| e.data.clone()).collect();
        Dataset::concat(&views)
    }

    /// Pairwise tree-merge of every cached partial into the global
    /// sketch — pure driver compute over `O(P/ε)` summaries, **no data
    /// scan** — memoized on this snapshot, so repeat queries against the
    /// same pin (the serving pattern: p50/p95/p99 every tick) pay only
    /// the fused scan, not a re-merge. `None` when the snapshot holds no
    /// records.
    pub fn merged_sketch(&self) -> Option<GkCore> {
        // Explorer sync point: a schedule may interleave a seal between
        // a reader's pin and this memo init — the stale-memo bug class
        // this memo's placement on the immutable snapshot rules out.
        crate::testing::yield_point(crate::testing::SyncPoint::MemoInit);
        let core = self.merged.get_or_init(|| {
            if self.epochs.is_empty() {
                return None;
            }
            Some(
                tree_merge(
                    self.epochs
                        .iter()
                        .flat_map(|e| e.sketches.iter().cloned())
                        .collect(),
                )
                .expect("nonempty epochs"),
            )
        });
        core.as_ref()
            .filter(|c| c.count > 0)
            .cloned()
    }
}

/// All live state of one stream.
#[derive(Debug, Clone)]
pub struct StreamState {
    next_epoch: u64,
    partitions: usize,
    epochs: Vec<Arc<Epoch>>,
    /// The current snapshot, built lazily on first read and *replaced*
    /// (never mutated) by seal/compaction. The merged-sketch memo rides
    /// on the snapshot itself — see [`StreamSnapshot::merged_sketch`].
    current: OnceCell<Arc<StreamSnapshot>>,
    /// Compactions performed over the stream's lifetime.
    pub compactions: u64,
}

impl StreamState {
    fn new(partitions: usize) -> Self {
        Self {
            next_epoch: 0,
            partitions,
            epochs: Vec::new(),
            current: OnceCell::new(),
            compactions: 0,
        }
    }

    /// The current snapshot: an immutable pin of the live epoch set,
    /// cheap to clone and safe to carry across threads while this
    /// stream keeps sealing.
    pub fn snapshot(&self) -> Arc<StreamSnapshot> {
        self.current
            .get_or_init(|| {
                Arc::new(StreamSnapshot::new(
                    self.epochs.clone(),
                    self.next_epoch,
                    self.partitions,
                    self.compactions,
                ))
            })
            .clone()
    }

    /// Drop the cached snapshot after a state change — the next reader
    /// builds a fresh pin over the new epoch list. Pins already handed
    /// out keep their old (still-correct-for-them) view.
    fn invalidate_snapshot(&mut self) {
        self.current = OnceCell::new();
    }

    pub fn epochs(&self) -> &[Arc<Epoch>] {
        &self.epochs
    }

    pub fn live_epochs(&self) -> usize {
        self.epochs.len()
    }

    /// Epochs sealed over the stream's lifetime (monotone — compaction
    /// folds live epochs but never rewinds this). Paired with
    /// [`Self::live_epochs`] it is the registry's residency gauge: the
    /// gap between the two is exactly what compaction reclaimed.
    pub fn sealed_epochs(&self) -> u64 {
        self.next_epoch
    }

    /// Partition count every epoch of this stream carries (pinned at
    /// first ingest).
    pub fn partitions(&self) -> usize {
        self.partitions
    }

    /// Total records across live epochs.
    pub fn total_count(&self) -> u64 {
        self.epochs.iter().map(|e| e.count).sum()
    }

    /// Cached sketch partials currently held (`live_epochs × partitions`;
    /// what compaction keeps bounded).
    pub fn sketch_partials(&self) -> usize {
        self.epochs.iter().map(|e| e.sketches.len()).sum()
    }

    /// Serialized size of all cached partials.
    pub fn sketch_bytes(&self) -> u64 {
        self.epochs.iter().map(|e| e.sketch_bytes()).sum()
    }

    /// Payload bytes across live epochs.
    pub fn data_bytes(&self) -> u64 {
        self.epochs.iter().map(|e| e.data.data_bytes()).sum()
    }

    /// Store footprint: cached sketches + payload.
    pub fn store_bytes(&self) -> u64 {
        self.sketch_bytes() + self.data_bytes()
    }

    /// Zero-copy union over every live epoch — the dataset a streamed
    /// query's single fused scan reads.
    pub fn live_dataset(&self) -> Result<Dataset<Key>> {
        self.snapshot().live_dataset()
    }

    /// The current snapshot's merged sketch (memoized per snapshot, so
    /// the single-threaded engine keeps the old repeat-query economics).
    /// `None` when the stream holds no records.
    pub fn merged_sketch(&self) -> Option<GkCore> {
        self.snapshot().merged_sketch()
    }
}

/// Registry of streams: the serving layer's only persistent state.
#[derive(Debug, Clone, Default)]
pub struct SketchStore {
    pub policy: CompactionPolicy,
    streams: BTreeMap<String, StreamState>,
}

/// What one compaction moved (the ingest path charges the rewrite as a
/// persist).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CompactionStats {
    /// Epochs folded into one.
    pub merged_epochs: usize,
    /// Payload bytes physically rewritten.
    pub bytes_rewritten: u64,
    /// Live epochs after the fold.
    pub live_epochs: usize,
}

impl SketchStore {
    pub fn new(policy: CompactionPolicy) -> Result<Self> {
        policy.validate()?;
        Ok(Self {
            policy,
            streams: BTreeMap::new(),
        })
    }

    pub fn stream(&self, id: &str) -> Option<&StreamState> {
        self.streams.get(id)
    }

    pub fn stream_ids(&self) -> impl Iterator<Item = &str> {
        self.streams.keys().map(String::as_str)
    }

    /// Seal one ingested micro-batch as a new epoch of `stream`,
    /// creating the stream on first use. The epoch's geometry must match
    /// the stream's (sketches are per-partition and compaction aligns
    /// partitions across epochs).
    pub fn seal_epoch(
        &mut self,
        stream: &str,
        data: Dataset<Key>,
        sketches: Vec<GkCore>,
    ) -> Result<u64> {
        ensure!(
            data.num_partitions() == sketches.len(),
            "epoch geometry mismatch: {} partitions vs {} sketches",
            data.num_partitions(),
            sketches.len()
        );
        let count = data.len();
        ensure!(count > 0, "cannot seal an empty epoch for stream '{stream}'");
        let sketched: u64 = sketches.iter().map(|s| s.count).sum();
        ensure!(
            sketched == count,
            "cached sketches cover {sketched} records, epoch holds {count}"
        );
        let state = self
            .streams
            .entry(stream.to_string())
            .or_insert_with(|| StreamState::new(data.num_partitions()));
        ensure!(
            data.num_partitions() == state.partitions,
            "stream '{stream}' is partitioned {}-way, batch arrived {}-way",
            state.partitions,
            data.num_partitions()
        );
        let id = state.next_epoch;
        state.next_epoch += 1;
        state.epochs.push(Arc::new(Epoch {
            id,
            data,
            sketches,
            count,
        }));
        state.invalidate_snapshot();
        Ok(id)
    }

    /// Whether `stream` has crossed the policy's compaction trigger.
    pub fn needs_compaction(&self, stream: &str) -> bool {
        self.stream(stream)
            .map(|s| s.live_epochs() > self.policy.compact_threshold)
            .unwrap_or(false)
    }

    /// Fold the oldest epochs of `stream` down to
    /// `policy.max_live_epochs` live epochs: aligned partitions merge
    /// physically, cached partials merge with `GkCore::merge_with`.
    /// Returns `None` when the stream is already at or under the target.
    /// Pure state transformation — the caller accounts for the data
    /// rewrite (a persist in the cost model). Snapshots pinned before
    /// the fold keep the pre-fold epochs alive (`Arc`-shared) and stay
    /// exact.
    pub fn compact(&mut self, stream: &str) -> Result<Option<CompactionStats>> {
        let state = self
            .streams
            .get_mut(stream)
            .ok_or_else(|| anyhow::anyhow!("unknown stream '{stream}'"))?;
        let target = self.policy.max_live_epochs;
        if state.epochs.len() <= target {
            return Ok(None);
        }
        let fold = state.epochs.len() - target + 1;
        let rest = state.epochs.split_off(fold);
        let old = std::mem::take(&mut state.epochs);

        let views: Vec<&Dataset<Key>> = old.iter().map(|e| &e.data).collect();
        let data = Dataset::union_partitionwise(&views)?;
        let bytes_rewritten = data.data_bytes();
        // per-partition pairwise tree-merge (not a sequential fold): a
        // fold accumulates merge slack linearly in the number of epochs,
        // and whatever slack a compaction bakes into the cached partials
        // is permanent — the tree keeps it logarithmic, same reason
        // `merged_sketch` trees
        let mut sketches: Vec<GkCore> = Vec::with_capacity(state.partitions);
        for p in 0..state.partitions {
            let merged = tree_merge(old.iter().map(|e| e.sketches[p].clone()).collect())
                .expect("fold of ≥2 epochs");
            sketches.push(merged);
        }
        let merged = Epoch {
            id: old[0].id,
            count: old.iter().map(|e| e.count).sum(),
            data,
            sketches,
        };
        state.epochs.push(Arc::new(merged));
        state.epochs.extend(rest);
        state.invalidate_snapshot();
        state.compactions += 1;
        Ok(Some(CompactionStats {
            merged_epochs: fold,
            bytes_rewritten,
            live_epochs: state.epochs.len(),
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn epoch_inputs(lo: Key, n: usize, p: usize, eps: f64) -> (Dataset<Key>, Vec<GkCore>) {
        let data = Dataset::from_vec((lo..lo + n as Key).collect(), p).unwrap();
        let sketches = (0..p)
            .map(|i| {
                let mut sorted = data.partition(i).to_vec();
                sorted.sort_unstable();
                GkCore::from_sorted(&sorted, eps)
            })
            .collect();
        (data, sketches)
    }

    #[test]
    fn seal_assigns_monotone_ids_and_counts() {
        let mut store = SketchStore::default();
        let (d, s) = epoch_inputs(0, 100, 4, 0.05);
        assert_eq!(store.seal_epoch("s", d, s).unwrap(), 0);
        let (d, s) = epoch_inputs(100, 50, 4, 0.05);
        assert_eq!(store.seal_epoch("s", d, s).unwrap(), 1);
        let st = store.stream("s").unwrap();
        assert_eq!(st.live_epochs(), 2);
        assert_eq!(st.sealed_epochs(), 2);
        assert_eq!(st.total_count(), 150);
        assert_eq!(st.sketch_partials(), 8);
        assert!(st.sketch_bytes() > 0);
        assert_eq!(st.data_bytes(), 150 * 4);
    }

    #[test]
    fn seal_rejects_geometry_and_count_mismatches() {
        let mut store = SketchStore::default();
        let (d, s) = epoch_inputs(0, 100, 4, 0.05);
        store.seal_epoch("s", d, s).unwrap();
        // wrong partition count
        let (d, s) = epoch_inputs(0, 100, 2, 0.05);
        assert!(store.seal_epoch("s", d, s).is_err());
        // sketches not covering the data
        let (d, _) = epoch_inputs(0, 100, 4, 0.05);
        let bad = vec![GkCore::new(0.05); 4];
        assert!(store.seal_epoch("s", d, bad).is_err());
        // empty epoch is a recoverable error
        let d = Dataset::from_partitions(vec![vec![], vec![]]).unwrap();
        assert!(store.seal_epoch("t", d, vec![GkCore::new(0.05); 2]).is_err());
    }

    #[test]
    fn live_dataset_and_merged_sketch_cover_all_epochs() {
        let mut store = SketchStore::default();
        for b in 0..3 {
            let (d, s) = epoch_inputs(b * 1000, 300, 3, 0.02);
            store.seal_epoch("s", d, s).unwrap();
        }
        let st = store.stream("s").unwrap();
        let all = st.live_dataset().unwrap();
        assert_eq!(all.len(), 900);
        assert_eq!(all.num_partitions(), 9);
        let sk = st.merged_sketch().unwrap();
        assert_eq!(sk.count, 900);
    }

    #[test]
    fn compaction_folds_oldest_and_bounds_partials() {
        let mut store = SketchStore::new(CompactionPolicy {
            compact_threshold: 4,
            max_live_epochs: 2,
        })
        .unwrap();
        for b in 0..5 {
            let (d, s) = epoch_inputs(b * 100, 60, 3, 0.05);
            store.seal_epoch("s", d, s).unwrap();
        }
        assert!(store.needs_compaction("s"));
        let stats = store.compact("s").unwrap().unwrap();
        assert_eq!(stats.merged_epochs, 4);
        assert_eq!(stats.live_epochs, 2);
        assert_eq!(stats.bytes_rewritten, 4 * 60 * 4);
        let st = store.stream("s").unwrap();
        assert_eq!(st.live_epochs(), 2);
        assert_eq!(st.sealed_epochs(), 5, "compaction never rewinds the seal count");
        assert_eq!(st.sketch_partials(), 6);
        assert_eq!(st.total_count(), 300);
        assert_eq!(st.compactions, 1);
        // ids stay ordered: folded epoch keeps the oldest id
        assert_eq!(st.epochs()[0].id, 0);
        assert_eq!(st.epochs()[1].id, 4);
        // data preserved exactly
        let mut v = st.live_dataset().unwrap().to_vec();
        v.sort_unstable();
        let mut want: Vec<Key> = (0..5).flat_map(|b| b * 100..b * 100 + 60).collect();
        want.sort_unstable();
        assert_eq!(v, want);
        // under target: no-op
        assert!(store.compact("s").unwrap().is_none());
    }

    #[test]
    fn merged_sketch_cache_invalidates_on_seal_and_compact() {
        let mut store = SketchStore::new(CompactionPolicy {
            compact_threshold: 8,
            max_live_epochs: 2,
        })
        .unwrap();
        let (d, s) = epoch_inputs(0, 200, 2, 0.05);
        store.seal_epoch("s", d, s).unwrap();
        assert_eq!(store.stream("s").unwrap().merged_sketch().unwrap().count, 200);
        // a second seal must not serve the stale cached merge
        let (d, s) = epoch_inputs(200, 100, 2, 0.05);
        store.seal_epoch("s", d, s).unwrap();
        assert_eq!(store.stream("s").unwrap().merged_sketch().unwrap().count, 300);
        // warm the cache, compact, and the merge must still cover all
        let (d, s) = epoch_inputs(300, 100, 2, 0.05);
        store.seal_epoch("s", d, s).unwrap();
        let _ = store.stream("s").unwrap().merged_sketch();
        store.compact("s").unwrap().unwrap();
        assert_eq!(store.stream("s").unwrap().merged_sketch().unwrap().count, 400);
    }

    #[test]
    fn pinned_snapshot_survives_seal_and_compact() {
        let mut store = SketchStore::new(CompactionPolicy {
            compact_threshold: 3,
            max_live_epochs: 2,
        })
        .unwrap();
        let (d, s) = epoch_inputs(0, 200, 2, 0.05);
        store.seal_epoch("s", d, s).unwrap();
        let pin = store.stream("s").unwrap().snapshot();
        assert_eq!(pin.total_count(), 200);
        assert_eq!(pin.sealed_epochs(), 1);
        // warm the pin's memo, then mutate the stream underneath it
        assert_eq!(pin.merged_sketch().unwrap().count, 200);
        for b in 1..5 {
            let (d, s) = epoch_inputs(b * 200, 200, 2, 0.05);
            store.seal_epoch("s", d, s).unwrap();
        }
        store.compact("s").unwrap().unwrap();
        // the pin still sees exactly what it pinned, memo included
        assert_eq!(pin.live_epochs(), 1);
        assert_eq!(pin.total_count(), 200);
        assert_eq!(pin.merged_sketch().unwrap().count, 200);
        assert_eq!(pin.live_dataset().unwrap().len(), 200);
        // while a fresh snapshot sees the post-compaction world
        let now = store.stream("s").unwrap().snapshot();
        assert_eq!(now.sealed_epochs(), 5);
        assert_eq!(now.total_count(), 1000);
        assert_eq!(now.compactions(), 1);
        assert_eq!(now.merged_sketch().unwrap().count, 1000);
    }

    #[test]
    fn snapshot_is_cached_until_the_next_state_change() {
        let mut store = SketchStore::default();
        let (d, s) = epoch_inputs(0, 100, 2, 0.05);
        store.seal_epoch("s", d, s).unwrap();
        let a = store.stream("s").unwrap().snapshot();
        let b = store.stream("s").unwrap().snapshot();
        assert!(Arc::ptr_eq(&a, &b), "repeat pins share one snapshot");
        let (d, s) = epoch_inputs(100, 100, 2, 0.05);
        store.seal_epoch("s", d, s).unwrap();
        let c = store.stream("s").unwrap().snapshot();
        assert!(!Arc::ptr_eq(&a, &c), "a seal publishes a fresh snapshot");
        assert!(
            Arc::ptr_eq(&a.epochs()[0], &c.epochs()[0]),
            "unchanged epochs are shared, not copied"
        );
    }

    #[test]
    fn policy_validation() {
        assert!(SketchStore::new(CompactionPolicy {
            compact_threshold: 2,
            max_live_epochs: 4
        })
        .is_err());
        assert!(SketchStore::new(CompactionPolicy {
            compact_threshold: 1,
            max_live_epochs: 0
        })
        .is_err());
    }
}
