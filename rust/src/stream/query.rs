//! One-scan exact queries over a live stream.
//!
//! A batch `GkSelect` query pays two data scans: the sketch pass plus
//! the fused band-extract pass. A streamed query skips the first one
//! entirely — the per-partition sketches were cached at ingest — so it
//! costs:
//!
//! 1. **driver-side tree-merge** of the store's `O(P/ε)` cached partials
//!    (no round, no data scan, pure driver compute);
//! 2. **one fused band-extract scan** over the zero-copy union of all
//!    live epochs ([`crate::cluster::dataset::Dataset::concat`]) — the
//!    same exactness machinery as the batch path
//!    ([`GkSelect::select_with_sketch`]), so the answer is bit-identical
//!    to running batch GK Select over the concatenated data.
//!
//! Net: **rounds = 1, data_scans = 1 per query** (2/2 for the batch
//! path), asserted by the per-query metrics snapshot every outcome
//! carries. Exactness never rests on sketch freshness: the fused pass
//! re-checks measured counts against the band and falls back to the
//! classic extraction round if a hostile stream pushed the sketch out of
//! contract — still exact, one extra scan.

use anyhow::{ensure, Result};

use super::store::SketchStore;
use crate::algorithms::gk_select::{GkSelect, GkSelectParams};
use crate::algorithms::multi_select::{MultiOutcome, MultiSelect};
use crate::algorithms::Outcome;
use crate::cluster::dataset::Dataset;
use crate::cluster::metrics::{MetricsMark, MetricsReport};
use crate::cluster::Cluster;
use crate::runtime::KernelBackend;
use crate::sketch::GkCore;
use crate::Key;

/// The query engine: batch GK Select's fused protocol, fed from the
/// sketch store instead of a fresh sketch round.
pub struct StreamQuery {
    select: GkSelect,
    multi: MultiSelect,
}

impl StreamQuery {
    /// Native-backend engine. The candidate budget is derived from the
    /// looser of `params.epsilon` and the cached sketch's ε, so an
    /// ingestor/engine precision mismatch costs band width, not
    /// correctness (and not the fast path).
    pub fn new(params: GkSelectParams) -> Self {
        Self {
            select: GkSelect::new(params.clone()),
            multi: MultiSelect::new(params),
        }
    }

    /// Run the fused scans through specific kernel backends — one for
    /// the single-quantile engine, one for the batched engine (boxed
    /// backends are not cloneable).
    pub fn with_backends(
        params: GkSelectParams,
        single: Box<dyn KernelBackend>,
        multi: Box<dyn KernelBackend>,
    ) -> Self {
        Self {
            select: GkSelect::with_backend(params.clone(), single),
            multi: MultiSelect::with_backend(params, multi),
        }
    }

    /// Exact quantile `q` over every live record of `stream`. The
    /// outcome's report covers exactly this query (per-query snapshot):
    /// rounds = 1, data_scans = 1 on the cached-sketch fast path.
    pub fn quantile(
        &mut self,
        cluster: &mut Cluster,
        store: &SketchStore,
        stream: &str,
        q: f64,
    ) -> Result<Outcome> {
        let base = cluster.metrics.mark();
        let clock0 = cluster.elapsed_secs();
        let (data, sketch) = query_view(cluster, store, stream)?;
        let out = self.select.select_with_sketch(cluster, &data, &sketch, q)?;
        let report = delta_report("Stream Query", cluster, &base, clock0, data.len(), &data)
            .with_simd_lane_width(self.select.simd_lane_width());
        Ok(Outcome {
            value: out.value,
            report,
        })
    }

    /// Exact values for every quantile in `qs`, all sharing the single
    /// fused scan (the m-quantile serving shape: p50/p95/p99 per tick).
    pub fn quantiles(
        &mut self,
        cluster: &mut Cluster,
        store: &SketchStore,
        stream: &str,
        qs: &[f64],
    ) -> Result<MultiOutcome> {
        ensure!(!qs.is_empty(), "no quantiles requested");
        let base = cluster.metrics.mark();
        let clock0 = cluster.elapsed_secs();
        let (data, sketch) = query_view(cluster, store, stream)?;
        let out = self
            .multi
            .quantiles_with_sketch(cluster, &data, &sketch, qs)?;
        let report = delta_report("Stream Query", cluster, &base, clock0, data.len(), &data)
            .with_simd_lane_width(self.multi.simd_lane_width());
        Ok(MultiOutcome {
            values: out.values,
            report,
        })
    }
}

/// The cached view a query runs against: the zero-copy union of all live
/// epochs plus the driver-merged global sketch. No executor touches data
/// here — the merge is driver compute over cached summaries.
fn query_view(
    cluster: &mut Cluster,
    store: &SketchStore,
    stream: &str,
) -> Result<(Dataset<Key>, GkCore)> {
    let state = store
        .stream(stream)
        .ok_or_else(|| anyhow::anyhow!("unknown stream '{stream}'"))?;
    ensure!(
        state.total_count() > 0,
        "stream '{stream}' is drained (no live records)"
    );
    let data = state.live_dataset()?;
    let sketch = cluster
        .driver(|| state.merged_sketch())
        .ok_or_else(|| anyhow::anyhow!("stream '{stream}' has no cached sketches"))?;
    Ok((data, sketch))
}

/// Per-query report: the metrics delta since `base`, shaped like any
/// algorithm report so the harness prints it uniformly.
fn delta_report(
    name: &str,
    cluster: &Cluster,
    base: &MetricsMark,
    clock0: f64,
    n: u64,
    data: &Dataset<Key>,
) -> MetricsReport {
    let delta = cluster.metrics.since(base);
    MetricsReport::from_metrics(
        name,
        n,
        data.num_partitions(),
        cluster.cfg.executors,
        cluster.elapsed_secs() - clock0,
        &delta,
        true,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::oracle_quantile;
    use crate::cluster::ClusterConfig;
    use crate::stream::{MicroBatch, StreamIngestor};

    fn ingest_batches(c: &mut Cluster, store: &mut SketchStore, batches: &[Vec<Key>]) {
        let ing = StreamIngestor::new(0.01).unwrap();
        for b in batches {
            ing.ingest(c, store, "s", MicroBatch::new(b.clone())).unwrap();
        }
    }

    #[test]
    fn query_is_exact_and_costs_one_round_one_scan() {
        let mut c = Cluster::new(ClusterConfig::local(2, 4));
        let mut store = SketchStore::default();
        let b0: Vec<Key> = (0..4000).map(|i| (i * 37) % 5000).collect();
        let b1: Vec<Key> = (0..3000).map(|i| -(i * 13) % 4000).collect();
        ingest_batches(&mut c, &mut store, &[b0.clone(), b1.clone()]);

        let mut all: Vec<Key> = b0.iter().chain(b1.iter()).copied().collect();
        all.sort_unstable();
        let mut q = StreamQuery::new(GkSelectParams::default());
        for quant in [0.0, 0.25, 0.5, 0.9, 1.0] {
            let out = q.quantile(&mut c, &store, "s", quant).unwrap();
            let truth = all[crate::target_rank(all.len() as u64, quant) as usize];
            assert_eq!(out.value, truth, "q={quant}");
            assert_eq!(out.report.rounds, 1, "q={quant}: cached sketch → 1 round");
            assert_eq!(out.report.data_scans, 1, "q={quant}: single fused scan");
            assert_eq!(out.report.shuffles, 0);
            assert_eq!(out.report.persists, 0);
            assert!(out.report.exact);
        }
    }

    #[test]
    fn multi_quantile_shares_the_single_scan() {
        let mut c = Cluster::new(ClusterConfig::local(2, 4));
        let mut store = SketchStore::default();
        let b0: Vec<Key> = (0..2500).map(|i| (i * 7919) % 100_000).collect();
        let b1: Vec<Key> = (0..2500).map(|i| (i * 104_729) % 100_000).collect();
        ingest_batches(&mut c, &mut store, &[b0.clone(), b1.clone()]);
        let data = store.stream("s").unwrap().live_dataset().unwrap();

        let mut q = StreamQuery::new(GkSelectParams::default());
        let qs = [0.5, 0.95, 0.99];
        let out = q.quantiles(&mut c, &store, "s", &qs).unwrap();
        assert_eq!(out.report.rounds, 1);
        assert_eq!(out.report.data_scans, 1);
        for (&quant, &v) in qs.iter().zip(out.values.iter()) {
            assert_eq!(v, oracle_quantile(&data, quant).unwrap(), "q={quant}");
        }
    }

    #[test]
    fn unknown_and_missing_streams_are_recoverable() {
        let mut c = Cluster::new(ClusterConfig::local(1, 2));
        let store = SketchStore::default();
        let mut q = StreamQuery::new(GkSelectParams::default());
        assert!(q.quantile(&mut c, &store, "nope", 0.5).is_err());
        assert!(q.quantiles(&mut c, &store, "nope", &[]).is_err());
    }
}
