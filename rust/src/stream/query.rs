//! One-scan exact queries over a live stream.
//!
//! A batch GK Select query pays two data scans: the sketch pass plus
//! the fused band-extract pass. A streamed query skips the first one
//! entirely — the per-partition sketches were cached at ingest — so it
//! costs:
//!
//! 1. **driver-side tree-merge** of the store's `O(P/ε)` cached partials
//!    (no round, no data scan, pure driver compute);
//! 2. **one fused band-extract scan** over the zero-copy union of all
//!    live epochs ([`crate::cluster::dataset::Dataset::concat`]) — the
//!    same exactness machinery as the batch path
//!    ([`crate::algorithms::gk_select`]'s fused protocol), so the answer
//!    is bit-identical to running batch GK Select over the concatenated
//!    data.
//!
//! Net: **rounds = 1, data_scans = 1 per query** (2/2 for the batch
//! path), asserted by the per-query metrics snapshot every outcome
//! carries. Exactness never rests on sketch freshness: the fused pass
//! re-checks measured counts against the band and falls back to the
//! classic extraction round if a hostile stream pushed the sketch out of
//! contract — still exact, one extra scan.
//!
//! The engine is the entry point: `Source::Stream(id)` plans land on
//! the crate-internal free functions here (`quantile_with` /
//! `quantiles_with` / `sketched_with`); the backend-owning
//! [`StreamQuery`] struct remains as a deprecated shim.

use super::store::{SketchStore, StreamSnapshot};
use crate::algorithms::gk_select::{self, GkSelectParams};
use crate::algorithms::multi_select::{self, MultiOutcome};
use crate::algorithms::Outcome;
use crate::cluster::dataset::Dataset;
use crate::cluster::metrics::{MetricsMark, MetricsReport};
use crate::cluster::Cluster;
use crate::engine::EngineError;
use crate::runtime::{KernelBackend, NativeBackend};
use crate::sketch::GkCore;
use crate::Key;

/// Exact quantile `q` over every live record of `stream`. The outcome's
/// report covers exactly this query (per-query snapshot): rounds = 1,
/// data_scans = 1 on the cached-sketch fast path.
pub(crate) fn quantile_with(
    cluster: &mut Cluster,
    backend: &dyn KernelBackend,
    params: &GkSelectParams,
    store: &SketchStore,
    stream: &str,
    q: f64,
) -> Result<Outcome, EngineError> {
    let snap = pin(store, stream)?;
    quantile_snapshot_with(cluster, backend, params, &snap, stream, q)
}

/// Exact values for every quantile in `qs`, all sharing the single
/// fused scan (the m-quantile serving shape: p50/p95/p99 per tick).
pub(crate) fn quantiles_with(
    cluster: &mut Cluster,
    backend: &dyn KernelBackend,
    params: &GkSelectParams,
    store: &SketchStore,
    stream: &str,
    qs: &[f64],
) -> Result<MultiOutcome, EngineError> {
    let snap = pin(store, stream)?;
    quantiles_snapshot_with(cluster, backend, params, &snap, stream, qs)
}

/// ε-approximate quantile straight from the cached merged sketch — no
/// data scan, no round, pure driver compute. Errors with
/// [`EngineError::SketchTooCoarse`] if the caller wants a tighter ε than
/// the ingest-time sketches carry.
pub(crate) fn sketched_with(
    cluster: &mut Cluster,
    store: &SketchStore,
    stream: &str,
    q: f64,
    eps: f64,
) -> Result<Outcome, EngineError> {
    let snap = pin(store, stream)?;
    sketched_snapshot_with(cluster, &snap, stream, q, eps)
}

/// Pin the current snapshot of `stream` (the engine's serialized path
/// pins and answers in one call — the service pins at submit time and
/// may answer much later, against the same immutable view).
fn pin(
    store: &SketchStore,
    stream: &str,
) -> Result<std::sync::Arc<StreamSnapshot>, EngineError> {
    let state = store
        .stream(stream)
        .ok_or_else(|| EngineError::UnknownStream(stream.to_string()))?;
    Ok(state.snapshot())
}

/// [`quantile_with`] against an explicit pinned snapshot — the shared
/// body of the engine's serialized path and the service's concurrent
/// read path; identical inputs make the two bit-identical by
/// construction.
pub(crate) fn quantile_snapshot_with(
    cluster: &mut Cluster,
    backend: &dyn KernelBackend,
    params: &GkSelectParams,
    snap: &StreamSnapshot,
    stream: &str,
    q: f64,
) -> Result<Outcome, EngineError> {
    let base = cluster.metrics.mark();
    let clock0 = cluster.elapsed_secs();
    let (data, sketch) = snapshot_view(cluster, snap, stream)?;
    let out = gk_select::select_with_sketch_with(cluster, backend, params, &data, &sketch, q)?;
    let report = delta_report("Stream Query", cluster, &base, clock0, data.len(), &data, true);
    Ok(Outcome {
        value: out.value,
        report,
    })
}

/// [`quantiles_with`] against an explicit pinned snapshot.
pub(crate) fn quantiles_snapshot_with(
    cluster: &mut Cluster,
    backend: &dyn KernelBackend,
    params: &GkSelectParams,
    snap: &StreamSnapshot,
    stream: &str,
    qs: &[f64],
) -> Result<MultiOutcome, EngineError> {
    if qs.is_empty() {
        return Err(EngineError::NoQuantiles);
    }
    let base = cluster.metrics.mark();
    let clock0 = cluster.elapsed_secs();
    let (data, sketch) = snapshot_view(cluster, snap, stream)?;
    let out = multi_select::quantiles_with_sketch_with(
        cluster, backend, params, &data, &sketch, qs,
    )?;
    let report = delta_report("Stream Query", cluster, &base, clock0, data.len(), &data, true);
    Ok(MultiOutcome {
        values: out.values,
        report,
    })
}

/// [`sketched_with`] against an explicit pinned snapshot.
pub(crate) fn sketched_snapshot_with(
    cluster: &mut Cluster,
    snap: &StreamSnapshot,
    stream: &str,
    q: f64,
    eps: f64,
) -> Result<Outcome, EngineError> {
    let base = cluster.metrics.mark();
    let clock0 = cluster.elapsed_secs();
    // no snapshot_view here: a sketched answer never touches the data, so
    // don't even assemble the epoch-union dataset — cached summaries only
    if snap.total_count() == 0 {
        return Err(EngineError::DrainedStream(stream.to_string()));
    }
    let sketch = cluster
        .driver(|| snap.merged_sketch())
        .ok_or_else(|| EngineError::DrainedStream(stream.to_string()))?;
    if eps < sketch.epsilon {
        return Err(EngineError::SketchTooCoarse {
            requested: eps,
            available: sketch.epsilon,
        });
    }
    let value = cluster
        .driver(|| sketch.query_quantile(q))
        .ok_or_else(|| EngineError::DrainedStream(stream.to_string()))?;
    let delta = cluster.metrics.since(&base);
    let report = MetricsReport::from_metrics(
        "Stream Query",
        snap.total_count(),
        snap.partitions(),
        cluster.cfg.executors,
        cluster.elapsed_secs() - clock0,
        &delta,
        false,
    );
    Ok(Outcome { value, report })
}

/// The pinned view a query runs against: the zero-copy union of the
/// snapshot's epochs plus the snapshot-memoized global sketch. No
/// executor touches data here — the merge is driver compute over cached
/// summaries.
fn snapshot_view(
    cluster: &mut Cluster,
    snap: &StreamSnapshot,
    stream: &str,
) -> Result<(Dataset<Key>, GkCore), EngineError> {
    if snap.total_count() == 0 {
        return Err(EngineError::DrainedStream(stream.to_string()));
    }
    let data = snap.live_dataset()?;
    let sketch = cluster
        .driver(|| snap.merged_sketch())
        .ok_or_else(|| EngineError::DrainedStream(stream.to_string()))?;
    Ok((data, sketch))
}

/// Per-query report: the metrics delta since `base`, shaped like any
/// algorithm report so the harness prints it uniformly.
#[allow(clippy::too_many_arguments)]
fn delta_report(
    name: &str,
    cluster: &Cluster,
    base: &MetricsMark,
    clock0: f64,
    n: u64,
    data: &Dataset<Key>,
    exact: bool,
) -> MetricsReport {
    let delta = cluster.metrics.since(base);
    MetricsReport::from_metrics(
        name,
        n,
        data.num_partitions(),
        cluster.cfg.executors,
        cluster.elapsed_secs() - clock0,
        &delta,
        exact,
    )
}

/// The pre-redesign query engine, owning its own kernel backends. Kept
/// as a thin shim for one release — route stream queries through
/// `QuantileEngine::execute(Source::Stream(..), ..)` instead (the engine
/// owns the store and the backend).
pub struct StreamQuery {
    params: GkSelectParams,
    single: Box<dyn KernelBackend>,
    multi: Box<dyn KernelBackend>,
}

impl StreamQuery {
    /// Native-backend engine.
    #[deprecated(
        since = "0.2.0",
        note = "build a `QuantileEngine`, `ingest`, then `execute(Source::Stream(..), ..)`"
    )]
    pub fn new(params: GkSelectParams) -> Self {
        Self {
            params,
            single: Box::new(NativeBackend::new()),
            multi: Box::new(NativeBackend::new()),
        }
    }

    /// Run the fused scans through specific kernel backends — one for
    /// the single-quantile path, one for the batched path (boxed
    /// backends are not cloneable).
    #[deprecated(
        since = "0.2.0",
        note = "use `EngineBuilder::kernel_backend` — the engine's one backend serves both paths"
    )]
    pub fn with_backends(
        params: GkSelectParams,
        single: Box<dyn KernelBackend>,
        multi: Box<dyn KernelBackend>,
    ) -> Self {
        Self {
            params,
            single,
            multi,
        }
    }

    /// Exact quantile `q` over every live record of `stream`. Stamps
    /// this shim's own backend lane width to preserve the old report
    /// contract (engine outcomes are stamped centrally instead).
    #[deprecated(
        since = "0.2.0",
        note = "use `QuantileEngine::execute(Source::Stream(..), QuantileQuery::Single(q))`"
    )]
    pub fn quantile(
        &mut self,
        cluster: &mut Cluster,
        store: &SketchStore,
        stream: &str,
        q: f64,
    ) -> anyhow::Result<Outcome> {
        let mut out = quantile_with(
            cluster,
            self.single.as_ref(),
            &self.params,
            store,
            stream,
            q,
        )?;
        out.report.simd_lane_width = self.single.simd_lane_width() as u64;
        Ok(out)
    }

    /// Exact values for every quantile in `qs`.
    #[deprecated(
        since = "0.2.0",
        note = "use `QuantileEngine::execute(Source::Stream(..), QuantileQuery::Multi(..))`"
    )]
    pub fn quantiles(
        &mut self,
        cluster: &mut Cluster,
        store: &SketchStore,
        stream: &str,
        qs: &[f64],
    ) -> anyhow::Result<MultiOutcome> {
        let mut out = quantiles_with(
            cluster,
            self.multi.as_ref(),
            &self.params,
            store,
            stream,
            qs,
        )?;
        out.report.simd_lane_width = self.multi.simd_lane_width() as u64;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::oracle_quantile;
    use crate::cluster::ClusterConfig;
    use crate::stream::{MicroBatch, StreamIngestor};

    fn ingest_batches(c: &mut Cluster, store: &mut SketchStore, batches: &[Vec<Key>]) {
        let ing = StreamIngestor::new(0.01).unwrap();
        for b in batches {
            ing.ingest(c, store, "s", MicroBatch::new(b.clone())).unwrap();
        }
    }

    fn backend() -> NativeBackend {
        NativeBackend::new()
    }

    #[test]
    fn query_is_exact_and_costs_one_round_one_scan() {
        let mut c = Cluster::new(ClusterConfig::local(2, 4));
        let mut store = SketchStore::default();
        let b0: Vec<Key> = (0..4000).map(|i| (i * 37) % 5000).collect();
        let b1: Vec<Key> = (0..3000).map(|i| -(i * 13) % 4000).collect();
        ingest_batches(&mut c, &mut store, &[b0.clone(), b1.clone()]);

        let mut all: Vec<Key> = b0.iter().chain(b1.iter()).copied().collect();
        all.sort_unstable();
        let be = backend();
        let params = GkSelectParams::default();
        for quant in [0.0, 0.25, 0.5, 0.9, 1.0] {
            let out = quantile_with(&mut c, &be, &params, &store, "s", quant).unwrap();
            let truth = all[crate::target_rank(all.len() as u64, quant) as usize];
            assert_eq!(out.value, truth, "q={quant}");
            assert_eq!(out.report.rounds, 1, "q={quant}: cached sketch → 1 round");
            assert_eq!(out.report.data_scans, 1, "q={quant}: single fused scan");
            assert_eq!(out.report.shuffles, 0);
            assert_eq!(out.report.persists, 0);
            assert!(out.report.exact);
        }
    }

    #[test]
    fn multi_quantile_shares_the_single_scan() {
        let mut c = Cluster::new(ClusterConfig::local(2, 4));
        let mut store = SketchStore::default();
        let b0: Vec<Key> = (0..2500).map(|i| (i * 7919) % 100_000).collect();
        let b1: Vec<Key> = (0..2500).map(|i| (i * 104_729) % 100_000).collect();
        ingest_batches(&mut c, &mut store, &[b0.clone(), b1.clone()]);
        let data = store.stream("s").unwrap().live_dataset().unwrap();

        let be = backend();
        let qs = [0.5, 0.95, 0.99];
        let out = quantiles_with(&mut c, &be, &GkSelectParams::default(), &store, "s", &qs)
            .unwrap();
        assert_eq!(out.report.rounds, 1);
        assert_eq!(out.report.data_scans, 1);
        for (&quant, &v) in qs.iter().zip(out.values.iter()) {
            assert_eq!(v, oracle_quantile(&data, quant).unwrap(), "q={quant}");
        }
    }

    #[test]
    fn sketched_query_needs_no_scan_and_respects_cached_epsilon() {
        let mut c = Cluster::new(ClusterConfig::local(2, 4));
        let mut store = SketchStore::default();
        let b: Vec<Key> = (0..5_000).collect();
        ingest_batches(&mut c, &mut store, &[b]);

        let out = sketched_with(&mut c, &store, "s", 0.5, 0.05).unwrap();
        assert!(!out.report.exact);
        assert_eq!(out.report.data_scans, 0, "answered from the cached sketch");
        assert_eq!(out.report.rounds, 0);
        // within the cached ε band of the true median
        assert!((out.value - 2_500).unsigned_abs() <= (0.05 * 2.0 * 5_000.0) as u32 + 2);

        // asking for tighter precision than ingest cached is a typed error
        let err = sketched_with(&mut c, &store, "s", 0.5, 0.0001).unwrap_err();
        assert!(matches!(err, EngineError::SketchTooCoarse { .. }));
    }

    #[test]
    fn unknown_and_missing_streams_are_recoverable() {
        let mut c = Cluster::new(ClusterConfig::local(1, 2));
        let store = SketchStore::default();
        let be = backend();
        let params = GkSelectParams::default();
        assert_eq!(
            quantile_with(&mut c, &be, &params, &store, "nope", 0.5).unwrap_err(),
            EngineError::UnknownStream("nope".into())
        );
        assert_eq!(
            quantiles_with(&mut c, &be, &params, &store, "nope", &[]).unwrap_err(),
            EngineError::NoQuantiles
        );
    }
}
