//! `repro` — the gkselect launcher.
//!
//! Subcommands cover the paper's full evaluation surface; every figure and
//! table in EXPERIMENTS.md names the exact invocation that regenerated it.
//! Every measured run routes through the `gkselect::engine` façade
//! (`EngineBuilder` → `QuantileEngine::execute`), so the CLI's global
//! flags are just builder inputs resolved with the engine's documented
//! precedence (flag > config file > env var).
//!
//! ```text
//! repro quantile  --algorithm gk-select --n 1e8 --q 0.5 --distribution uniform [--verify]
//! repro bench fig      --nodes 10 --max-exp 8 --trials 3
//! repro bench dist     --n 1e8 --nodes 30 --trials 20
//! repro bench table4   --nodes 10
//! repro bench table5   --n 4e6 --nodes 10
//! repro bench ablation --n 8e6 --nodes 10
//! repro bench json     --n 4e6 --out .
//! repro stream         --batches 16 --batch-n 250000 --workload zipf --queries 0.5,0.95,0.99
//! repro serve          --clients 8 --streams 4 --ops 64 --batch-n 50000 --verify
//! repro chaos          --n 2e6 --plan "seed=7,panic=0.02,straggler=0.1x4" --verify
//! repro trace batch    --n 2e5 --out trace.json
//! repro metrics        --n 2e5 --out metrics-out
//! repro calibrate
//! repro validate --n 2e5
//! repro config
//! ```
//!
//! Global flags: `--config <path>` (TOML), `--backend native|pjrt`,
//! `--exec-mode sequential|threads`, `--simd auto|scalar|force`,
//! `--faults <plan>` (seeded fault-injection for any command),
//! `--trace off|memory|chrome:<path>` (span capture for any command),
//! `--metrics off|memory|prom:<path>|qlog:<path>` (lifetime metrics
//! registry for any command).

use anyhow::{bail, Result};
use gkselect::cluster::FaultPlan;
use gkselect::config::ReproConfig;
use gkselect::data::Distribution;
use gkselect::harness::{self, AlgoChoice};
use gkselect::util::cli::Args;
use std::path::{Path, PathBuf};

const USAGE: &str = "\
repro — GK Select: quick and exact distributed quantile computation

USAGE:
  repro <command> [flags]

COMMANDS:
  quantile   run one algorithm on generated data and print its report
             --algorithm gk-select|afs|jeffers|full-sort|gk-sketch|hist-select
             --n <count> --q <quantile> --distribution uniform|zipf|bimodal|sorted
             --nodes <count> --verify
  bench fig       Figs. 1–2: runtime vs n   (--nodes --max-exp --trials)
  bench dist      Figs. 3–4: distribution CIs (--n --nodes --trials)
  bench table4    Table IV: scaling exponents (--nodes)
  bench table5    Table V: measured counters  (--n --nodes)
  bench ablation  ε sweep                     (--n --nodes)
  bench json      emit the BENCH_*.json family (--n --out <dir>)
  stream     replay interleaved micro-batch ingests + exact quantile
             queries through the streaming service
             --batches <count> --batch-n <records> --workload uniform|zipf|hostile
             --queries 0.5,0.95,0.99 --query-every <ticks> --nodes <count> --verify
  serve      closed-loop concurrent workload against the multi-tenant
             QuantileService: client threads share streams under a seeded
             mixed ingest/query schedule; prints real qps + p50/p99 query
             latency and checks residency/no-lost-updates; --verify
             replays every Nth query through a serialized sequential
             oracle over the pinned snapshot (bit-identical or fail)
             --clients <count> --streams <count> --ops <per-client>
             --batch-n <records> --queries 0.5,0.95,0.99 --nodes <count>
             --seed <n> --verify [--verify-every <n>]
  chaos      replay batch + stream queries under seeded fault injection and
             report what the recovery layer did (retries, speculation,
             degradations); --verify pins answers against a fault-free run
             --n <count> --nodes <count> --seed <n> (canned plan)
             --plan \"seed=7,panic=0.02,transient=0.05,straggler=0.1x4\"
             --degrade fail|sketch --verify
  trace      run a small traced workload and write a Perfetto-loadable
             Chrome-trace file of its span tree
             trace batch|stream|chaos --n <count> --out <file.json> --nodes <count>
  metrics    run a mixed batch/stream/chaos workload with the lifetime
             metrics registry armed and dump both exports: a Prometheus
             text-exposition scrape (early + final, for monotonicity
             checks) and the structured JSON-lines query log
             --n <count> --out <dir> --nodes <count>
  calibrate  measure this box's per-element costs
  validate   cross-check all algorithms vs the oracle (--n)
  config     print the effective config

GLOBAL FLAGS:
  --config <path>    TOML config (default ./repro.toml if present)
  --backend <name>   native | pjrt (pjrt needs `make artifacts`)
  --exec-mode <m>    sequential | threads (real OS-thread executor pool;
                     GKSELECT_EXEC_MODE=threads does the same)
  --simd <policy>    auto | scalar | force — band-scan SIMD dispatch for
                     the native backend (GKSELECT_SIMD does the same)
  --faults <plan>    seeded fault-injection plan armed for any command
                     (GKSELECT_FAULTS does the same; see `repro chaos`
                     for the plan grammar)
  --trace <mode>     off | memory | chrome:<path> (or a bare *.json path)
                     — per-query span capture for any command
                     (GKSELECT_TRACE does the same)
  --metrics <mode>   off | memory | prom:<path> | qlog:<path> — engine-
                     lifetime metrics registry for any command
                     (GKSELECT_METRICS does the same)
";

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    if args.path.is_empty() || args.has("help") {
        print!("{USAGE}");
        return Ok(());
    }

    let cfg_path = args.str_opt("config").map(PathBuf::from);
    let mut cfg = ReproConfig::load_or_default(cfg_path.as_deref().map(Path::new))?;
    if let Some(b) = args.str_opt("backend") {
        cfg.backend = b.to_string();
    }
    if let Some(m) = args.str_opt("exec-mode") {
        // validated here so a typo fails before any work runs
        let _: gkselect::cluster::ExecMode = m.parse()?;
        cfg.cluster.exec_mode = m.to_string();
    }
    if let Some(sp) = args.str_opt("simd") {
        // validated here so a typo fails before any work runs
        let _: gkselect::runtime::SimdPolicy = sp.parse()?;
        cfg.runtime.simd = sp.to_string();
    }
    if let Some(fp) = args.str_opt("faults") {
        // validated here so a typo fails before any work runs
        fp.parse::<FaultPlan>().map_err(anyhow::Error::msg)?;
        cfg.faults.plan = fp.to_string();
    }
    if let Some(tm) = args.str_opt("trace") {
        // validated here so a typo fails before any work runs
        tm.parse::<gkselect::obs::TraceMode>()?;
        cfg.obs.trace = tm.to_string();
    }
    if let Some(mm) = args.str_opt("metrics") {
        // validated here so a typo fails before any work runs
        mm.parse::<gkselect::obs::MetricsMode>()?;
        cfg.obs.metrics = mm.to_string();
    }

    match args.path[0].as_str() {
        "quantile" => {
            args.ensure_known(&[
                "config", "backend", "exec-mode", "simd", "faults", "trace", "metrics", "algorithm", "n", "q",
                "distribution", "nodes", "verify",
            ])?;
            let algorithm: AlgoChoice = args.str_or("algorithm", "gk-select").parse()?;
            let n = args.u64_or("n", 1_000_000)?;
            let q = args.f64_or("q", 0.5)?;
            let dist: Distribution = args.str_or("distribution", "uniform").parse()?;
            if let Some(nodes) = args.str_opt("nodes") {
                cfg.cluster.nodes = nodes.parse()?;
            }
            harness::run_quantile(&cfg, algorithm, n, q, dist, args.has("verify"))
        }
        "bench" => {
            let which = args.path.get(1).map(String::as_str).unwrap_or("");
            match which {
                "fig" => {
                    args.ensure_known(&[
                        "config", "backend", "exec-mode", "simd", "faults", "trace", "metrics", "nodes", "max-exp",
                        "trials",
                    ])?;
                    harness::bench_fig(
                        &cfg,
                        args.usize_or("nodes", 10)?,
                        args.u64_or("max-exp", 8)? as u32,
                        args.u64_or("trials", 3)? as u32,
                    )
                }
                "dist" => {
                    args.ensure_known(&[
                        "config", "backend", "exec-mode", "simd", "faults", "trace", "metrics", "n", "nodes", "trials",
                    ])?;
                    harness::bench_dist(
                        &cfg,
                        args.u64_or("n", 100_000_000)?,
                        args.usize_or("nodes", 30)?,
                        args.u64_or("trials", 20)? as u32,
                    )
                }
                "table4" => {
                    args.ensure_known(&[
                        "config", "backend", "exec-mode", "simd", "faults", "trace", "metrics", "nodes",
                    ])?;
                    harness::bench_table4(&cfg, args.usize_or("nodes", 10)?)
                }
                "table5" => {
                    args.ensure_known(&[
                        "config", "backend", "exec-mode", "simd", "faults", "trace", "metrics", "n", "nodes",
                    ])?;
                    harness::bench_table5(
                        &cfg,
                        args.u64_or("n", 4_000_000)?,
                        args.usize_or("nodes", 10)?,
                    )
                }
                "ablation" => {
                    args.ensure_known(&[
                        "config", "backend", "exec-mode", "simd", "faults", "trace", "metrics", "n", "nodes",
                    ])?;
                    harness::bench_ablation(
                        &cfg,
                        args.u64_or("n", 8_000_000)?,
                        args.usize_or("nodes", 10)?,
                    )
                }
                "json" => {
                    args.ensure_known(&[
                        "config", "backend", "exec-mode", "simd", "faults", "trace", "metrics", "n", "out",
                    ])?;
                    harness::write_bench_json(
                        Path::new(&args.str_or("out", ".")),
                        args.u64_or("n", 4_000_000)?,
                        cfg.simd_policy(),
                    )
                }
                other => bail!("unknown bench '{other}' (fig|dist|table4|table5|ablation|json)"),
            }
        }
        "stream" => {
            args.ensure_known(&[
                "config",
                "backend",
                "exec-mode",
                "simd",
                "faults",
                "trace",
                "metrics",
                "batches",
                "batch-n",
                "workload",
                "queries",
                "query-every",
                "nodes",
                "verify",
            ])?;
            if let Some(nodes) = args.str_opt("nodes") {
                cfg.cluster.nodes = nodes.parse()?;
            }
            let workload: harness::StreamWorkload = args.str_or("workload", "zipf").parse()?;
            let qs: Vec<f64> = args
                .str_or("queries", "0.5,0.95,0.99")
                .split(',')
                .map(|s| {
                    let q: f64 = s.trim().parse()?;
                    anyhow::ensure!((0.0..=1.0).contains(&q), "quantile out of range: {q}");
                    Ok(q)
                })
                .collect::<Result<_>>()?;
            harness::run_stream(
                &cfg,
                args.u64_or("batches", 16)?,
                args.u64_or("batch-n", 250_000)?,
                workload,
                &qs,
                args.u64_or("query-every", 1)?,
                args.has("verify"),
            )
        }
        "serve" => {
            args.ensure_known(&[
                "config",
                "backend",
                "exec-mode",
                "simd",
                "faults",
                "trace",
                "metrics",
                "clients",
                "streams",
                "ops",
                "batch-n",
                "queries",
                "nodes",
                "seed",
                "verify",
                "verify-every",
            ])?;
            if let Some(nodes) = args.str_opt("nodes") {
                cfg.cluster.nodes = nodes.parse()?;
            }
            if let Some(seed) = args.str_opt("seed") {
                cfg.algorithm.seed = seed.parse()?;
            }
            let qs: Vec<f64> = args
                .str_or("queries", "0.5,0.95,0.99")
                .split(',')
                .map(|s| {
                    let q: f64 = s.trim().parse()?;
                    anyhow::ensure!((0.0..=1.0).contains(&q), "quantile out of range: {q}");
                    Ok(q)
                })
                .collect::<Result<_>>()?;
            // --verify-every N oracle-checks every Nth query per client;
            // bare --verify defaults to every 8th
            let verify_every = match args.str_opt("verify-every") {
                Some(v) => v.parse()?,
                None if args.has("verify") => 8,
                None => 0,
            };
            harness::run_serve(
                &cfg,
                args.u64_or("clients", 8)? as usize,
                args.u64_or("streams", 4)? as usize,
                args.u64_or("ops", 64)?,
                args.u64_or("batch-n", 50_000)?,
                &qs,
                verify_every,
            )
        }
        "chaos" => {
            args.ensure_known(&[
                "config", "backend", "exec-mode", "simd", "faults", "trace", "metrics", "n", "nodes", "plan", "seed",
                "degrade", "verify",
            ])?;
            if let Some(nodes) = args.str_opt("nodes") {
                cfg.cluster.nodes = nodes.parse()?;
            }
            if let Some(d) = args.str_opt("degrade") {
                // validated here so a typo fails before any work runs
                let _: gkselect::engine::DegradePolicy = d.parse()?;
                cfg.faults.degrade = d.to_string();
            }
            // --plan wins; --seed seeds a canned mixed plan; --faults /
            // [faults] plan / GKSELECT_FAULTS are the usual fallback
            let plan: FaultPlan = match args.str_opt("plan") {
                Some(p) => p.parse().map_err(anyhow::Error::msg)?,
                None if !cfg.faults.plan.is_empty() && !args.has("seed") => {
                    cfg.faults.plan.parse().map_err(anyhow::Error::msg)?
                }
                None => FaultPlan::seeded(args.u64_or("seed", 7)?)
                    .panics(0.02)
                    .transients(0.05)
                    .stragglers(0.10, 4.0),
            };
            harness::run_chaos(&cfg, args.u64_or("n", 2_000_000)?, plan, args.has("verify"))
        }
        "trace" => {
            args.ensure_known(&[
                "config", "backend", "exec-mode", "simd", "faults", "trace", "metrics", "n", "nodes", "out",
            ])?;
            if let Some(nodes) = args.str_opt("nodes") {
                cfg.cluster.nodes = nodes.parse()?;
            }
            let workload = args.path.get(1).map(String::as_str).unwrap_or("batch");
            harness::run_trace(
                &cfg,
                workload,
                args.u64_or("n", 200_000)?,
                Path::new(&args.str_or("out", "trace.json")),
            )
        }
        "metrics" => {
            args.ensure_known(&[
                "config", "backend", "exec-mode", "simd", "faults", "trace", "metrics", "n",
                "nodes", "out",
            ])?;
            if let Some(nodes) = args.str_opt("nodes") {
                cfg.cluster.nodes = nodes.parse()?;
            }
            harness::run_metrics(
                &cfg,
                args.u64_or("n", 200_000)?,
                Path::new(&args.str_or("out", "metrics-out")),
            )
        }
        "calibrate" => {
            args.ensure_known(&["config", "backend", "exec-mode", "simd", "faults", "trace", "metrics"])?;
            harness::calibrate(&cfg)
        }
        "validate" => {
            args.ensure_known(&["config", "backend", "exec-mode", "simd", "faults", "trace", "metrics", "n"])?;
            harness::validate(&cfg, args.u64_or("n", 200_000)?)
        }
        "config" => {
            print!("{}", cfg.to_toml());
            Ok(())
        }
        other => bail!("unknown command '{other}'\n\n{USAGE}"),
    }
}
