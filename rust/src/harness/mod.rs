//! Experiment harness: the runners behind `repro bench ...` and the
//! criterion benches. Every paper figure/table maps to one function here
//! (DESIGN.md §5), so the CLI, the benches, and EXPERIMENTS.md all share
//! one implementation — and since the API redesign they all share one
//! entry point too: every measured run goes through
//! [`QuantileEngine::execute`].

pub mod stats;

use crate::algorithms::oracle_quantile;
use crate::cluster::dataset::Dataset;
use crate::cluster::{Cluster, ExecMode, FaultPlan};
use crate::config::ReproConfig;
use crate::data::{DataGenerator, Distribution};
use crate::engine::{EngineBuilder, QuantileEngine, QuantileQuery, QueryOutcome, Source};
use crate::runtime::{NativeBackend, SimdDispatch, SimdPolicy};
use crate::sketch::modified::ModifiedGk;
use crate::util::benchkit::{write_json, JsonVal};
use crate::Key;
use anyhow::{ensure, Result};
use std::path::Path;
use std::time::Instant;

pub use crate::engine::AlgoChoice;

/// Input shapes for the streaming replay (`repro stream`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamWorkload {
    /// i.i.d. uniform batches — the stationary baseline.
    Uniform,
    /// Zipf(2.5) batches — heavy hitters, stresses endpoint-run counting.
    Zipf,
    /// Adversarially non-stationary: every batch lands in its own narrow
    /// value band, hash-scattered across the key space, with a 25%
    /// duplicate run at the band edge. Each batch maximally shifts the
    /// global quantiles, so sketches cached from old epochs always
    /// mispredict — the worst case a cached-sketch design must absorb
    /// (exactness holds; a band miss costs one fallback scan).
    Hostile,
}

impl std::str::FromStr for StreamWorkload {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "uniform" => Ok(Self::Uniform),
            "zipf" => Ok(Self::Zipf),
            "hostile" => Ok(Self::Hostile),
            other => anyhow::bail!("unknown stream workload '{other}' (uniform|zipf|hostile)"),
        }
    }
}

impl StreamWorkload {
    pub fn label(self) -> &'static str {
        match self {
            Self::Uniform => "uniform",
            Self::Zipf => "zipf",
            Self::Hostile => "hostile",
        }
    }

    /// The records arriving at tick `tick` (deterministic per seed).
    pub fn batch(self, seed: u64, tick: u64, len: usize) -> Vec<crate::Key> {
        use crate::data::{UniformGen, ZipfGen};
        let mut out = Vec::with_capacity(len);
        match self {
            Self::Uniform => {
                UniformGen::new(seed).fill_partition(tick as usize, 1, len, &mut out)
            }
            Self::Zipf => {
                ZipfGen::new(seed, 2.5).fill_partition(tick as usize, 1, len, &mut out)
            }
            Self::Hostile => {
                let mut rng = crate::data::pcg::Pcg64::new(seed, 0xB10C ^ tick);
                const BANDS: u64 = 64;
                let band = tick.wrapping_mul(0x9E37_79B9_7F4A_7C15) % BANDS;
                let span = ((crate::KEY_HI - crate::KEY_LO) as u64 / BANDS).max(1);
                let lo = crate::KEY_LO + (band * span) as i64;
                for _ in 0..len {
                    let v = if rng.next_u64() % 4 == 0 {
                        lo // duplicate run pinned at the band edge
                    } else {
                        lo + (rng.next_u64() % span) as i64
                    };
                    out.push(v as crate::Key);
                }
            }
        }
        out
    }
}

/// One engine per the config: `choice` strategy, `nodes` core nodes,
/// everything else (backend, SIMD policy, ε, sketch knobs, stream
/// compaction) resolved by the builder's documented precedence.
pub fn engine_for(cfg: &ReproConfig, choice: AlgoChoice, nodes: usize) -> Result<QuantileEngine> {
    Ok(EngineBuilder::new()
        .config(cfg.clone())
        .nodes(nodes)
        .algorithm(choice)
        .build()?)
}

/// Build an EMR-shaped cluster from the config with `nodes` core nodes —
/// for generating shared datasets outside any engine.
pub fn make_cluster(cfg: &ReproConfig, nodes: usize) -> Cluster {
    let mut cc = cfg.cluster_config();
    cc.executors = nodes;
    cc.partitions = nodes * cfg.cluster.partitions_per_node;
    Cluster::new(cc)
}

/// One measured run; returns the outcome and the wall-clock seconds spent.
pub fn timed_run(
    engine: &mut QuantileEngine,
    data: &Dataset<Key>,
    query: QuantileQuery,
) -> Result<(QueryOutcome, f64)> {
    let start = Instant::now();
    let out = engine.execute(Source::Dataset(data), query)?;
    Ok((out, start.elapsed().as_secs_f64()))
}

// ---------------------------------------------------------------------------
// CLI runners
// ---------------------------------------------------------------------------

/// `repro quantile`: one algorithm, one query, full report.
pub fn run_quantile(
    cfg: &ReproConfig,
    choice: AlgoChoice,
    n: u64,
    q: f64,
    dist: Distribution,
    verify: bool,
) -> Result<()> {
    let mut engine = engine_for(cfg, choice, cfg.cluster.nodes)?;
    println!(
        "generating {n} {} keys across {} partitions ({} nodes)...",
        dist.label(),
        engine.cluster().cfg.partitions,
        engine.cluster().cfg.executors
    );
    let data = dist
        .generator(cfg.algorithm.seed)
        .generate(engine.cluster_mut(), n);
    let (out, wall) = timed_run(&mut engine, &data, QuantileQuery::Single(q))?;

    println!("\n{} q={q} over n={n} ({}):", out.report.algorithm, dist.label());
    println!("  value            = {}", out.value());
    println!("  modelled elapsed = {:.4}s (wall {:.2}s on this box)", out.report.elapsed_secs, wall);
    println!("  rounds           = {}", out.report.rounds);
    println!("  stage boundaries = {}", out.report.stage_boundaries);
    println!("  shuffles         = {}", out.report.shuffles);
    println!("  persists         = {}", out.report.persists);
    println!(
        "  network volume   = {}",
        crate::cluster::metrics::human_bytes(out.report.network_volume_bytes)
    );
    println!("  exact            = {}", out.report.exact);

    if verify {
        let truth = oracle_quantile(&data, q).expect("nonempty");
        if out.report.exact {
            ensure!(
                out.value() == truth,
                "EXACTNESS VIOLATION: got {} want {truth}",
                out.value()
            );
            println!("  verified         = exact match with oracle ({truth})");
        } else {
            let mut all = data.to_vec();
            all.sort_unstable();
            let lo = all.partition_point(|&x| x < out.value()) as f64;
            let hi = all.partition_point(|&x| x <= out.value()) as f64;
            let target = q * n as f64;
            let err = if target < lo {
                (lo - target) / n as f64
            } else if target > hi {
                (target - hi) / n as f64
            } else {
                0.0
            };
            println!("  verified         = approx, rank error {:.4} (ε = {})", err, cfg.algorithm.epsilon);
        }
    }
    Ok(())
}

/// Figs. 1–2: runtime vs n per algorithm at a fixed node count.
pub fn bench_fig(cfg: &ReproConfig, nodes: usize, max_exp: u32, trials: u32) -> Result<()> {
    println!(
        "# Fig. {} reproduction — {} core nodes ({} partitions), modelled EMR fabric",
        if nodes >= 30 { 2 } else { 1 },
        nodes,
        nodes * cfg.cluster.partitions_per_node
    );
    println!(
        "{:<12} {:>12} {:>14} {:>14} {:>8}",
        "algorithm", "n", "mean model s", "wall s/run", "rounds"
    );
    for exp in 6..=max_exp {
        let n = 10u64.pow(exp);
        let mut cluster = make_cluster(cfg, nodes);
        let data = Distribution::Uniform
            .generator(cfg.algorithm.seed)
            .generate(&mut cluster, n);
        for choice in AlgoChoice::PAPER_SET {
            // the paper's AFS/Jeffers curves stop before the largest n
            // (resource limits); we cap their wall-clock the same way
            if matches!(choice, AlgoChoice::Afs | AlgoChoice::Jeffers) && n > 10_000_000 {
                println!("{:<12} {:>12} {:>14} {:>14} {:>8}", choice.label(), n, "—", "—", "—");
                continue;
            }
            let mut engine = engine_for(cfg, choice, nodes)?;
            let mut elapsed = Vec::new();
            let mut walls = Vec::new();
            let mut rounds = 0;
            for _ in 0..trials {
                let (out, wall) = timed_run(&mut engine, &data, QuantileQuery::Single(0.5))?;
                elapsed.push(out.report.elapsed_secs);
                walls.push(wall);
                rounds = out.report.rounds;
            }
            println!(
                "{:<12} {:>12} {:>14.4} {:>14.2} {:>8}",
                choice.label(),
                n,
                stats::mean(&elapsed),
                stats::mean(&walls),
                rounds
            );
        }
    }
    Ok(())
}

/// Figs. 3–4: GK Select runtime CIs across distributions.
pub fn bench_dist(cfg: &ReproConfig, n: u64, nodes: usize, trials: u32) -> Result<()> {
    println!(
        "# Fig. {} reproduction — n = {n}, {nodes} nodes, {trials} trials, 95% CI (t-dist)",
        if n >= 1_000_000_000 { 4 } else { 3 }
    );
    println!(
        "{:<22} {:>14} {:>22}",
        "configuration", "mean model s", "95% CI"
    );
    for dist in [
        Distribution::Uniform,
        Distribution::Zipf,
        Distribution::Bimodal,
        Distribution::Sorted,
    ] {
        let mut cluster = make_cluster(cfg, nodes);
        let data = dist.generator(cfg.algorithm.seed).generate(&mut cluster, n);
        for (qlabel, q) in [("50", 0.5), ("99", 0.99)] {
            let mut engine = engine_for(cfg, AlgoChoice::GkSelect, nodes)?;
            let mut xs = Vec::new();
            for _ in 0..trials {
                let (out, _) = timed_run(&mut engine, &data, QuantileQuery::Single(q))?;
                xs.push(out.report.elapsed_secs);
            }
            let (lo, hi) = stats::ci95(&xs);
            println!(
                "{:<22} {:>14.4} {:>10.4} – {:>8.4}",
                format!("{} GKSelect{qlabel}", dist.label()),
                stats::mean(&xs),
                lo,
                hi
            );
        }
    }
    Ok(())
}

/// Table IV: empirical scaling — log-log slope of modelled time vs n.
pub fn bench_table4(cfg: &ReproConfig, nodes: usize) -> Result<()> {
    println!("# Table IV reproduction — empirical executor-side scaling exponents");
    println!("(slope of log T vs log n; linear work ⇒ ≈1.0, n log n ⇒ slightly above)");
    // large enough that executor compute dominates the fixed round
    // latencies — the asymptotic regime Table IV describes
    let ns = [2_000_000u64, 4_000_000, 8_000_000, 16_000_000, 32_000_000];
    println!(
        "{:<12} {:>10} {:>28} {}",
        "algorithm", "slope", "paper executor time", ""
    );
    let claims = [
        (AlgoChoice::FullSort, "O((n/P) log(n/P))"),
        (AlgoChoice::Afs, "O(n/P)"),
        (AlgoChoice::Jeffers, "O(n/P)"),
        (AlgoChoice::GkSketch, "O((n/P) log B + ...)"),
        (AlgoChoice::GkSelect, "O((n/P)(log 1/e + loglog(e n/P)))"),
        (AlgoChoice::HistSelect, "O((n/P) * rounds)"),
    ];
    for (choice, claim) in claims {
        let mut pts = Vec::new();
        for &n in &ns {
            let mut cluster = make_cluster(cfg, nodes);
            let data = Distribution::Uniform
                .generator(cfg.algorithm.seed)
                .generate(&mut cluster, n);
            let mut engine = engine_for(cfg, choice, nodes)?;
            // median of 3 to de-noise
            let mut xs = Vec::new();
            for _ in 0..3 {
                let (out, _) = timed_run(&mut engine, &data, QuantileQuery::Single(0.5))?;
                xs.push(out.report.elapsed_secs);
            }
            xs.sort_by(f64::total_cmp);
            pts.push((n as f64, xs[1]));
        }
        let slope = stats::loglog_slope(&pts);
        println!("{:<12} {:>10.3} {:>28}", choice.label(), slope, claim);
    }
    Ok(())
}

/// Table V: measured communication/synchronization counters per algorithm.
pub fn bench_table5(cfg: &ReproConfig, n: u64, nodes: usize) -> Result<()> {
    println!("# Table V reproduction — measured counters at n = {n}, {nodes} nodes");
    println!("{}", crate::cluster::metrics::MetricsReport::table5_header());
    for choice in AlgoChoice::ALL {
        let mut cluster = make_cluster(cfg, nodes);
        let data = Distribution::Uniform
            .generator(cfg.algorithm.seed)
            .generate(&mut cluster, n);
        let mut engine = engine_for(cfg, choice, nodes)?;
        let (out, _) = timed_run(&mut engine, &data, QuantileQuery::Single(0.5))?;
        println!("{}", out.report.table5_row());
    }
    Ok(())
}

/// ε ablation (§V-6): candidate volume, driver bytes, and latency vs ε,
/// fold- vs tree-merged sketches.
pub fn bench_ablation(cfg: &ReproConfig, n: u64, nodes: usize) -> Result<()> {
    println!("# ε ablation — GK Select at n = {n}, {nodes} nodes");
    println!(
        "{:<10} {:<6} {:>14} {:>14} {:>12} {:>8}",
        "epsilon", "merge", "model s", "driver bytes", "net volume", "rounds"
    );
    for &eps in &[0.05, 0.02, 0.01, 0.005, 0.001] {
        for merge in ["fold", "tree"] {
            let mut cfg2 = cfg.clone();
            cfg2.algorithm.epsilon = eps;
            cfg2.algorithm.sketch_merge = merge.into();
            let mut engine = engine_for(&cfg2, AlgoChoice::GkSelect, nodes)?;
            let data = Distribution::Uniform
                .generator(cfg2.algorithm.seed)
                .generate(engine.cluster_mut(), n);
            let (out, _) = timed_run(&mut engine, &data, QuantileQuery::Single(0.5))?;
            println!(
                "{:<10} {:<6} {:>14.4} {:>14} {:>12} {:>8}",
                eps,
                merge,
                out.report.elapsed_secs,
                out.report.bytes_to_driver,
                crate::cluster::metrics::human_bytes(out.report.network_volume_bytes),
                out.report.rounds
            );
        }
    }
    Ok(())
}

/// Measure this box's per-element costs (plain scan, fused band scan,
/// sort, sketch insert) and print a `[cluster]` section with the
/// derived compute_scale. The fused band-scan measurement goes through
/// the configured SIMD policy (`--simd` / `[runtime] simd` /
/// `GKSELECT_SIMD`), and the printed dispatch line labels exactly that
/// measurement — `count_pivot` and the sort/sketch costs are not
/// SIMD-dispatched.
pub fn calibrate(cfg: &ReproConfig) -> Result<()> {
    use crate::runtime::KernelBackend;
    let n = 20_000_000usize;
    let mut rng = crate::data::pcg::Pcg64::new(1, 1);
    let data: Vec<crate::Key> = (0..n).map(|_| rng.next_u64() as crate::Key).collect();

    let backend = NativeBackend::with_policy(cfg.simd_policy());
    let t = Instant::now();
    let counts = backend.count_pivot(&data, 0);
    let scan = t.elapsed().as_secs_f64() / n as f64;
    ensure!(counts.total() == n as u64);

    // the SIMD-dispatched hot path: same geometry as the hotpath bench
    let span = (u32::MAX as f64 * 0.005) as crate::Key;
    let t = Instant::now();
    let ext = backend.band_extract(&data, 0, -span, span, n / 10);
    let band_scan = t.elapsed().as_secs_f64() / n as f64;
    ensure!(ext.band.total() == n as u64);

    let mut copy = data[..4_000_000].to_vec();
    let t = Instant::now();
    copy.sort_unstable();
    let sort = t.elapsed().as_secs_f64() / 4_000_000.0;

    let t = Instant::now();
    let mut sk = ModifiedGk::new(0.01);
    for &v in &data[..4_000_000] {
        use crate::sketch::QuantileSketch;
        sk.insert(v);
    }
    let sketch = t.elapsed().as_secs_f64() / 4_000_000.0;

    println!("# measured per-element costs on this box");
    println!("scan (count_pivot): {:.2} ns/key", scan * 1e9);
    println!(
        "band_extract scan:  {:.2} ns/key  [{} dispatch, lane width {}]",
        band_scan * 1e9,
        backend.dispatch().label(),
        backend.simd_lane_width()
    );
    println!("local sort:         {:.2} ns/key", sort * 1e9);
    println!("mSGK insert:        {:.2} ns/key", sketch * 1e9);
    // m5.xlarge single-core scan reference ≈ 0.6 ns/key (memory-bound);
    // compute_scale maps measured → reference
    let reference_scan = 0.6e-9;
    println!("\n# suggested repro.toml section");
    println!("[cluster]");
    println!("compute_scale = {:.3}", reference_scan / scan);
    Ok(())
}

/// Exactness cross-check of every algorithm vs the oracle.
pub fn validate(cfg: &ReproConfig, n: u64) -> Result<()> {
    let mut failures = 0u32;
    let mut checks = 0u32;
    for dist in [
        Distribution::Uniform,
        Distribution::Zipf,
        Distribution::Bimodal,
        Distribution::Sorted,
    ] {
        let mut cluster = make_cluster(cfg, cfg.cluster.nodes);
        let data = dist.generator(cfg.algorithm.seed).generate(&mut cluster, n);
        for q in [0.0, 0.01, 0.25, 0.5, 0.75, 0.99, 1.0] {
            let truth = oracle_quantile(&data, q).expect("nonempty");
            for choice in AlgoChoice::ALL {
                let mut engine = engine_for(cfg, choice, cfg.cluster.nodes)?;
                let (out, _) = timed_run(&mut engine, &data, QuantileQuery::Single(q))?;
                checks += 1;
                if out.report.exact && out.value() != truth {
                    failures += 1;
                    println!(
                        "FAIL {} {} q={q}: got {} want {}",
                        choice.label(),
                        dist.label(),
                        out.value(),
                        truth
                    );
                } else if !out.report.exact {
                    // rank error = distance from the target rank to the
                    // value's rank interval (duplicates span many ranks —
                    // zipf's heavy hitter covers most of them)
                    let mut all = data.to_vec();
                    all.sort_unstable();
                    let lo = all.partition_point(|&x| x < out.value()) as f64;
                    let hi = all.partition_point(|&x| x <= out.value()) as f64;
                    let target = q * n as f64;
                    let err = if target < lo {
                        (lo - target) / n as f64
                    } else if target > hi {
                        (target - hi) / n as f64
                    } else {
                        0.0
                    };
                    // merged sketches: allow a few ε of slack
                    if err > 5.0 * cfg.algorithm.epsilon {
                        failures += 1;
                        println!(
                            "FAIL {} {} q={q}: rank error {err:.4} > 5ε",
                            choice.label(),
                            dist.label()
                        );
                    }
                }
            }
        }
    }
    println!("validate: {checks} checks, {failures} failures");
    ensure!(failures == 0, "{failures} validation failures");
    Ok(())
}

/// `repro stream`: replay an interleaved ingest/query workload against
/// the streaming service and print the amortization the store buys —
/// ingest throughput, per-query rounds/scans/latency, store footprint.
#[allow(clippy::too_many_arguments)]
pub fn run_stream(
    cfg: &ReproConfig,
    batches: u64,
    batch_n: u64,
    workload: StreamWorkload,
    qs: &[f64],
    query_every: u64,
    verify: bool,
) -> Result<()> {
    use crate::stream::MicroBatch;
    ensure!(batches > 0 && batch_n > 0, "need at least one nonempty batch");
    ensure!(!qs.is_empty(), "need at least one quantile");
    let query_every = query_every.max(1);
    // one engine carries the whole replay: ingestor ε/variant, store
    // compaction, kernel backend, and cluster shape all resolved by the
    // builder from the same config the rest of the CLI uses
    let mut engine = engine_for(cfg, AlgoChoice::GkSelect, cfg.cluster.nodes)?;
    println!(
        "# streaming replay — {} workload, {batches} batches × {batch_n} records, \
         {} nodes, ε = {}, compaction {}→{}",
        workload.label(),
        engine.cluster().cfg.executors,
        cfg.algorithm.epsilon,
        engine.store().policy.compact_threshold,
        engine.store().policy.max_live_epochs,
    );
    let stream = "replay";
    for tick in 1..=batches {
        let values = workload.batch(cfg.algorithm.seed, tick, batch_n as usize);
        let t = Instant::now();
        let ing = engine.ingest(stream, MicroBatch::new(values))?;
        let wall = t.elapsed().as_secs_f64();
        println!(
            "tick {tick:>3} ingest: {:>9} keys in {:>7.2} ms ({:>6.1} Mkeys/s)  \
             epochs {:>2}{}  store {}",
            ing.batch_records,
            wall * 1e3,
            ing.batch_records as f64 / wall / 1e6,
            ing.live_epochs,
            if ing.compacted_epochs > 0 {
                format!(" (compacted {})", ing.compacted_epochs)
            } else {
                String::new()
            },
            crate::cluster::metrics::human_bytes(ing.store_bytes),
        );
        if tick % query_every == 0 {
            let t = Instant::now();
            let out = engine.execute(Source::Stream(stream), QuantileQuery::Multi(qs.to_vec()))?;
            let wall = t.elapsed().as_secs_f64();
            let vals: Vec<String> = qs
                .iter()
                .zip(out.values.iter())
                .map(|(&q, &v)| format!("p{}={v}", q * 100.0))
                .collect();
            println!(
                "tick {tick:>3}  query: {:<40} rounds {} scans {} model {:.4}s wall {:.2} ms",
                vals.join(" "),
                out.report.rounds,
                out.report.data_scans,
                out.report.elapsed_secs,
                wall * 1e3,
            );
            if verify {
                let data = engine
                    .store()
                    .stream(stream)
                    .expect("stream exists")
                    .live_dataset()?;
                for (&q, &v) in qs.iter().zip(out.values.iter()) {
                    let truth = oracle_quantile(&data, q).expect("nonempty");
                    ensure!(
                        v == truth,
                        "EXACTNESS VIOLATION at tick {tick} q={q}: got {v} want {truth}"
                    );
                }
                println!("tick {tick:>3} verify: all {} quantiles exact", qs.len());
            }
        }
    }
    Ok(())
}

/// Value at quantile `p` of an ascending-sorted latency sample
/// (nearest-rank; 0.0 on an empty sample).
fn latency_percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// `repro serve`: closed-loop concurrent workload against the
/// multi-tenant [`crate::service::QuantileService`] — `clients` client
/// threads share `streams` streams under seeded per-thread schedules
/// (one ingest per four ops, queries otherwise), measuring REAL query
/// latency (p50/p99) and throughput at the offered load. With
/// `verify_every > 0`, every Nth query each client answers is replayed
/// through a fresh serialized sequential engine holding exactly the
/// pinned snapshot's epochs ([`crate::service::QuantileService::oracle`])
/// and must match bit-identically — snapshot isolation checked live,
/// under real concurrency. After the run, the registry's per-stream
/// residency gauges must equal each stream's Σ ingested records (no
/// lost updates) and the grand op total must equal the ops the clients
/// actually ran.
pub fn run_serve(
    cfg: &ReproConfig,
    clients: usize,
    streams: usize,
    ops: u64,
    batch_n: u64,
    qs: &[f64],
    verify_every: u64,
) -> Result<()> {
    use crate::algorithms::gk_select::GkSelectParams;
    use crate::obs::MetricsMode;
    use crate::service::QuantileService;
    use crate::stream::MicroBatch;

    ensure!(
        clients > 0 && streams > 0 && ops > 0 && batch_n > 0,
        "need at least one client, stream, op, and record per batch"
    );
    ensure!(!qs.is_empty(), "need at least one quantile");
    let seed = cfg.algorithm.seed;
    let params = GkSelectParams {
        epsilon: cfg.algorithm.epsilon,
        ..GkSelectParams::default()
    };
    let svc = QuantileService::builder()
        .cluster(cfg.cluster_config())
        .params(params)
        .compaction(cfg.stream.to_policy()?)
        .kernel_backend(std::sync::Arc::from(cfg.kernel_backend()?))
        .metrics(MetricsMode::Memory)
        .build()?;
    println!(
        "# serve — {clients} clients × {streams} streams, {ops} ops/client, \
         batch {batch_n}, {} {} (simd ×{}), ε = {}",
        svc.cluster_config().exec_mode.label(),
        svc.backend_name(),
        svc.simd_lane_width(),
        cfg.algorithm.epsilon,
    );

    // warm every stream with one sealed epoch so no query races the
    // very first seal of its stream
    for s in 0..streams {
        let values = StreamWorkload::Uniform.batch(seed ^ s as u64, 0, batch_n as usize);
        svc.ingest(&format!("tenant-{s}"), MicroBatch::new(values))?;
    }

    #[derive(Default)]
    struct ClientStats {
        query_lat: Vec<f64>,
        ingests: u64,
        ingest_wall: f64,
        records_by_stream: std::collections::BTreeMap<usize, u64>,
        verified: u64,
    }

    let svc_ref = &svc;
    let t0 = Instant::now();
    let results: Vec<Result<ClientStats>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                scope.spawn(move || -> Result<ClientStats> {
                    let mut rng = crate::data::pcg::Pcg64::new(seed, 0x5E21 ^ c as u64);
                    let mut stats = ClientStats::default();
                    for op in 0..ops {
                        let s = (rng.next_u64() % streams as u64) as usize;
                        let id = format!("tenant-{s}");
                        if op % 4 == 3 {
                            let values = StreamWorkload::Uniform.batch(
                                seed ^ ((c as u64) << 20) ^ (op << 8),
                                op,
                                batch_n as usize,
                            );
                            let t = Instant::now();
                            let ing = svc_ref.ingest(&id, MicroBatch::new(values))?;
                            stats.ingest_wall += t.elapsed().as_secs_f64();
                            stats.ingests += 1;
                            *stats.records_by_stream.entry(s).or_default() +=
                                ing.batch_records;
                        } else {
                            let q = qs[(op % qs.len() as u64) as usize];
                            let t = Instant::now();
                            let pin = svc_ref.pin(&id)?;
                            let out =
                                svc_ref.query_pinned(&pin, &QuantileQuery::Single(q))?;
                            stats.query_lat.push(t.elapsed().as_secs_f64());
                            ensure!(
                                out.report.exact,
                                "serve answered inexactly at client {c} op {op}"
                            );
                            if verify_every > 0
                                && stats.query_lat.len() as u64 % verify_every == 0
                            {
                                let mut oracle = svc_ref.oracle(&pin)?;
                                let want = oracle
                                    .execute(Source::Stream(&id), QuantileQuery::Single(q))?;
                                ensure!(
                                    out.value() == want.value(),
                                    "SNAPSHOT VIOLATION client {c} op {op} {id} q={q}: \
                                     served {} but the serialized oracle over the pinned \
                                     epochs answers {}",
                                    out.value(),
                                    want.value()
                                );
                                stats.verified += 1;
                            }
                        }
                    }
                    Ok(stats)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("serve client thread panicked"))
            .collect()
    });
    let elapsed = t0.elapsed().as_secs_f64();

    let mut lats = Vec::new();
    let mut ingests = 0u64;
    let mut ingest_wall = 0.0f64;
    let mut verified = 0u64;
    let mut by_stream = vec![0u64; streams];
    for r in results {
        let s = r?;
        lats.extend(s.query_lat);
        ingests += s.ingests;
        ingest_wall += s.ingest_wall;
        verified += s.verified;
        for (stream, records) in s.records_by_stream {
            by_stream[stream] += records;
        }
    }
    lats.sort_by(f64::total_cmp);
    let queries = lats.len() as u64;
    println!(
        "serve: {queries} queries in {elapsed:.3} s → {:.1} qps  \
         (p50 {:.2} ms, p99 {:.2} ms)",
        queries as f64 / elapsed.max(1e-12),
        latency_percentile(&lats, 0.50) * 1e3,
        latency_percentile(&lats, 0.99) * 1e3,
    );
    let ingested: u64 = by_stream.iter().sum();
    println!(
        "serve: {ingests} ingests, {ingested} records in {ingest_wall:.3} s ingest-wall \
         ({:.2} Mkeys/s)",
        ingested as f64 / ingest_wall.max(1e-12) / 1e6,
    );

    // no lost updates: the registry's residency gauge for each stream
    // must equal exactly what was ingested into it (warmup + clients)
    let snap = svc.metrics_snapshot();
    for (s, client_records) in by_stream.iter().enumerate() {
        let id = format!("tenant-{s}");
        let expect = batch_n + client_records;
        let got = snap
            .residency
            .iter()
            .find(|(name, _)| name == &id)
            .map(|(_, r)| r.records)
            .unwrap_or(0);
        ensure!(
            got == expect,
            "LOST UPDATE on {id}: residency gauge {got} != ingested {expect}"
        );
    }
    let expected_ops = streams as u64 + ingests + queries;
    ensure!(
        snap.grand().ops == expected_ops,
        "registry absorbed {} ops, clients ran {expected_ops}",
        snap.grand().ops
    );
    println!("serve: residency check OK ({streams} streams, no lost updates)");
    if verify_every > 0 {
        println!(
            "serve: verified {verified}/{queries} responses bit-identical to the \
             serialized oracle over their pinned snapshots"
        );
    }
    Ok(())
}

/// Rank error of `value` as an answer for quantile `q` over `sorted`
/// (0.0 when the value's duplicate run covers the target rank) — the
/// acceptance metric for degraded ε-approximate answers.
fn rank_error(sorted: &[Key], q: f64, value: Key) -> f64 {
    let n = sorted.len() as f64;
    let lo = sorted.partition_point(|&x| x < value) as f64;
    let hi = sorted.partition_point(|&x| x <= value) as f64;
    let target = q * n;
    if target < lo {
        (lo - target) / n
    } else if target > hi {
        (target - hi) / n
    } else {
        0.0
    }
}

/// `repro chaos`: replay a fault-injected workload end-to-end — batch
/// queries and a stream ingest/query interleave under the seeded plan —
/// and print what the recovery layer did about each stage (retries,
/// speculative wins, backoff charged to the virtual clock, degradations,
/// typed failures). With `verify`, every exact answer is checked
/// bit-identical against a fault-free engine of the same shape, and
/// every degraded answer against the 5ε rank-error contract — the
/// acceptance bar: under any plan, never a panic, never a silently
/// wrong exact value.
pub fn run_chaos(cfg: &ReproConfig, n: u64, plan: FaultPlan, verify: bool) -> Result<()> {
    use crate::engine::EngineError;
    use crate::stream::MicroBatch;
    ensure!(n > 0, "need a nonempty workload");
    let retry = cfg.faults.to_retry_policy();
    println!(
        "# chaos replay — plan [{plan}] over n = {n}, {} nodes",
        cfg.cluster.nodes
    );
    println!(
        "# recovery: {} retries/task, backoff {:.0} ms, speculation {}, degrade = {}",
        retry.max_task_retries,
        retry.backoff_secs * 1e3,
        if retry.speculation { "on" } else { "off" },
        if cfg.faults.degrade.is_empty() { "fail" } else { &cfg.faults.degrade },
    );

    // the chaos engine runs the plan; the reference engine runs the same
    // shape with the injector armed but idle (seed-0 plan, zero rates),
    // so both answers flow through the identical fault-aware code path
    let chaos_builder = |p: FaultPlan| -> Result<QuantileEngine> {
        Ok(EngineBuilder::new()
            .config(cfg.clone())
            .algorithm(AlgoChoice::GkSelect)
            .fault_plan(p)
            .build()?)
    };
    let mut chaos = chaos_builder(plan)?;
    let mut clean = chaos_builder(FaultPlan::seeded(0))?;

    // cumulative chaos-side totals: every batch query resets the run
    // ledger, so fold each outcome's report into local counters
    let (mut faults, mut retried, mut spec, mut spec_wins) = (0u64, 0u64, 0u64, 0u64);
    let (mut degraded, mut failed) = (0u64, 0u64);
    let mut absorb = |r: &crate::cluster::metrics::MetricsReport| {
        faults += r.faults_injected;
        retried += r.tasks_retried;
        spec += r.speculative_launched;
        spec_wins += r.speculative_wins;
        degraded += r.degraded_queries;
    };

    // --- batch phase -------------------------------------------------------
    let data = Distribution::Uniform
        .generator(cfg.algorithm.seed)
        .generate(clean.cluster_mut(), n);
    let sorted = if verify {
        let mut all = data.to_vec();
        all.sort_unstable();
        all
    } else {
        Vec::new()
    };
    let queries: [(&str, QuantileQuery); 3] = [
        ("median", QuantileQuery::Single(0.5)),
        ("p99", QuantileQuery::Single(0.99)),
        ("multi", QuantileQuery::Multi(vec![0.25, 0.5, 0.75, 0.95])),
    ];
    for (label, query) in queries {
        match chaos.execute(Source::Dataset(&data), query.clone()) {
            Ok(out) => {
                absorb(&out.report);
                println!(
                    "batch {label:<7} values {:?}  rounds {} scans {} model {:.4}s  \
                     faults {} retried {} spec {}/{}{}",
                    out.values,
                    out.report.rounds,
                    out.report.data_scans,
                    out.report.elapsed_secs,
                    out.report.faults_injected,
                    out.report.tasks_retried,
                    out.report.speculative_wins,
                    out.report.speculative_launched,
                    if out.degraded { "  [DEGRADED: ε-approximate]" } else { "" },
                );
                if verify {
                    if out.degraded {
                        let qs = query.quantiles(n);
                        for (&q, &v) in qs.iter().zip(out.values.iter()) {
                            let err = rank_error(&sorted, q, v);
                            ensure!(
                                err <= 5.0 * cfg.algorithm.epsilon,
                                "DEGRADED ANSWER OUT OF CONTRACT at {label} q={q}: \
                                 rank error {err:.4} > 5ε"
                            );
                        }
                        println!("batch {label:<7} verify: degraded answers within 5ε");
                    } else {
                        let want = clean.execute(Source::Dataset(&data), query.clone())?;
                        ensure!(
                            out.values == want.values,
                            "EXACTNESS VIOLATION at {label}: chaos {:?} vs clean {:?}",
                            out.values,
                            want.values
                        );
                        println!("batch {label:<7} verify: bit-identical with fault-free run");
                    }
                }
            }
            Err(e @ EngineError::StageFailed { .. }) => {
                failed += 1;
                println!("batch {label:<7} failed typed after retries: {e}");
            }
            Err(e) => return Err(e.into()),
        }
    }

    // --- stream phase ------------------------------------------------------
    let batches = 8u64;
    let per = (n / batches).max(1) as usize;
    let mut mirrored = false;
    for tick in 0..batches {
        let values = StreamWorkload::Uniform.batch(cfg.algorithm.seed ^ 0xC4A05, tick, per);
        match chaos.ingest("chaos", MicroBatch::new(values.clone())) {
            Ok(ing) => {
                absorb(&ing.report);
                println!(
                    "tick {tick} ingest: {:>8} keys, epochs {:>2}  faults {} retried {}",
                    ing.batch_records,
                    ing.live_epochs,
                    ing.report.faults_injected,
                    ing.report.tasks_retried,
                );
                // mirror only the batches the chaos store actually kept,
                // so both stores hold the same records
                clean.ingest("chaos", MicroBatch::new(values))?;
                mirrored = true;
            }
            Err(e @ EngineError::StageFailed { .. }) => {
                failed += 1;
                println!("tick {tick} ingest failed typed ({e}) — store unchanged, batch dropped");
            }
            Err(e) => return Err(e.into()),
        }
    }
    if mirrored {
        for q in [0.5, 0.95] {
            match chaos.execute(Source::Stream("chaos"), QuantileQuery::Single(q)) {
                Ok(out) => {
                    absorb(&out.report);
                    println!(
                        "stream q={q}  value {}  rounds {} scans {}{}",
                        out.value(),
                        out.report.rounds,
                        out.report.data_scans,
                        if out.degraded { "  [DEGRADED: ε-approximate]" } else { "" },
                    );
                    if verify && !out.degraded {
                        let want = clean.execute(Source::Stream("chaos"), QuantileQuery::Single(q))?;
                        ensure!(
                            out.values == want.values,
                            "EXACTNESS VIOLATION at stream q={q}: chaos {:?} vs clean {:?}",
                            out.values,
                            want.values
                        );
                        println!("stream q={q}  verify: bit-identical with fault-free run");
                    }
                }
                Err(e @ EngineError::StageFailed { .. }) => {
                    failed += 1;
                    println!("stream q={q}  failed typed after retries: {e}");
                }
                Err(e) => return Err(e.into()),
            }
        }
    } else {
        println!("stream queries skipped: every ingest failed under the plan");
    }

    println!("\n# chaos totals");
    println!("faults injected      = {faults}");
    println!("tasks retried        = {retried}");
    println!("speculative launched = {spec} (won {spec_wins})");
    println!("queries degraded     = {degraded}");
    println!("stages failed typed  = {failed}");
    if verify {
        println!("verify: every answer exact (bit-identical) or within the ε contract");
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Machine-readable perf trajectory: the BENCH_*.json family
// ---------------------------------------------------------------------------

/// The self-sketched per-stage task-latency summaries of a report, as a
/// JSON array for the BENCH records (see [`crate::obs::stats`]).
fn stage_stats_json(report: &crate::cluster::metrics::MetricsReport) -> JsonVal {
    JsonVal::Arr(
        report
            .stage_stats
            .iter()
            .map(|s| {
                JsonVal::obj(vec![
                    ("stage", JsonVal::U64(s.stage)),
                    ("tasks", JsonVal::U64(s.tasks)),
                    ("p50_us", JsonVal::U64(s.p50_us as u64)),
                    ("p95_us", JsonVal::U64(s.p95_us as u64)),
                    ("p99_us", JsonVal::U64(s.p99_us as u64)),
                    ("max_us", JsonVal::U64(s.max_us as u64)),
                ])
            })
            .collect(),
    )
}

/// One GK Select run on the paper's `emr(30)` shape → a JSON record:
/// round/scan/byte counters, the modelled (virtual-clock) seconds, and
/// the *real* wall-clock of every `map_partitions` stage — on the fused
/// path, stage index 1 is the fused band-extract scan, recorded
/// separately as `band_scan_wall_s`.
pub fn gk_select_bench_record(
    label: &str,
    dist: Distribution,
    n: u64,
    budget: Option<usize>,
    mode: ExecMode,
    simd: SimdPolicy,
) -> Result<JsonVal> {
    let mut builder = EngineBuilder::new()
        .cluster(crate::cluster::ClusterConfig::emr(30).with_exec_mode(mode))
        .algorithm(AlgoChoice::GkSelect)
        .simd(simd);
    if let Some(b) = budget {
        builder = builder.candidate_budget(b);
    }
    let mut engine = builder.build()?;
    let dataset = dist.generator(42).generate(engine.cluster_mut(), n);
    let out = engine.execute(Source::Dataset(&dataset), QuantileQuery::Single(0.75))?;
    let band_scan_wall = out.report.stage_walls.get(1).copied().unwrap_or(0.0);
    println!(
        "bench gk_select_emr30/{label:<24} {:<10} rounds {} scans {} model {:>9.4}s \
         wall {:>8.4}s band-scan {:>8.4}s util {:.2} skew {:.2}",
        mode.label(),
        out.report.rounds,
        out.report.data_scans,
        out.report.elapsed_secs,
        out.report.wall_stage_secs,
        band_scan_wall,
        out.report.executor_utilization,
        out.report.busy_skew,
    );
    Ok(JsonVal::obj(vec![
        ("algorithm", JsonVal::Str(format!("gk_select_{label}"))),
        ("distribution", JsonVal::Str(dist.label().to_string())),
        ("exec_mode", JsonVal::Str(mode.label().to_string())),
        ("n", JsonVal::U64(n)),
        ("q", JsonVal::F64(0.75)),
        ("rounds", JsonVal::U64(out.report.rounds)),
        ("data_scans", JsonVal::U64(out.report.data_scans)),
        ("stage_boundaries", JsonVal::U64(out.report.stage_boundaries)),
        ("shuffles", JsonVal::U64(out.report.shuffles)),
        ("persists", JsonVal::U64(out.report.persists)),
        (
            "network_volume_bytes",
            JsonVal::U64(out.report.network_volume_bytes),
        ),
        ("elapsed_model_s", JsonVal::F64(out.report.elapsed_secs)),
        ("wall_stage_secs", JsonVal::F64(out.report.wall_stage_secs)),
        ("band_scan_wall_s", JsonVal::F64(band_scan_wall)),
        (
            "stage_walls",
            JsonVal::Arr(out.report.stage_walls.iter().map(|&w| JsonVal::F64(w)).collect()),
        ),
        (
            "executor_utilization",
            JsonVal::F64(out.report.executor_utilization),
        ),
        ("busy_skew", JsonVal::F64(out.report.busy_skew)),
        (
            "simd",
            JsonVal::Str(SimdDispatch::resolve(simd).label().into()),
        ),
        ("simd_lane_width", JsonVal::U64(out.report.simd_lane_width)),
        ("stage_stats", stage_stats_json(&out.report)),
        ("band_candidates", JsonVal::U64(out.report.band_candidates)),
        ("band_budget", JsonVal::U64(out.report.band_budget)),
        ("band_efficiency", JsonVal::F64(out.report.band_efficiency())),
        ("exact", JsonVal::Bool(out.report.exact)),
    ]))
}

/// One streamed query on the paper's `emr(30)` shape after `batches`
/// uniform micro-batches → a JSON record. The serving hot path: the
/// query's only stage is the fused band-extract scan over the live
/// epochs (stage index 0 — the sketch pass happened at ingest), so
/// `band_scan_wall_s` is directly comparable with the batch records'.
pub fn stream_query_bench_record(
    label: &str,
    n: u64,
    batches: u64,
    mode: ExecMode,
    simd: SimdPolicy,
) -> Result<JsonVal> {
    use crate::stream::MicroBatch;
    let mut engine = EngineBuilder::new()
        .cluster(crate::cluster::ClusterConfig::emr(30).with_exec_mode(mode))
        .algorithm(AlgoChoice::GkSelect)
        .simd(simd)
        .build()?;
    let per = (n / batches).max(1);
    let mut ingest_wall = 0.0;
    for tick in 0..batches {
        let values = StreamWorkload::Uniform.batch(42, tick, per as usize);
        let t = Instant::now();
        engine.ingest("bench", MicroBatch::new(values))?;
        ingest_wall += t.elapsed().as_secs_f64();
    }
    let out = engine.execute(Source::Stream("bench"), QuantileQuery::Single(0.75))?;
    let band_scan_wall = out.report.stage_walls.first().copied().unwrap_or(0.0);
    let state = engine.store().stream("bench").expect("ingested");
    println!(
        "bench gk_select_emr30/{label:<24} {:<10} rounds {} scans {} model {:>9.4}s \
         wall {:>8.4}s band-scan {:>8.4}s util {:.2} skew {:.2}",
        mode.label(),
        out.report.rounds,
        out.report.data_scans,
        out.report.elapsed_secs,
        out.report.wall_stage_secs,
        band_scan_wall,
        out.report.executor_utilization,
        out.report.busy_skew,
    );
    Ok(JsonVal::obj(vec![
        ("algorithm", JsonVal::Str(label.to_string())),
        ("distribution", JsonVal::Str("uniform".into())),
        ("exec_mode", JsonVal::Str(mode.label().to_string())),
        ("n", JsonVal::U64(out.report.n)),
        ("micro_batches", JsonVal::U64(batches)),
        ("q", JsonVal::F64(0.75)),
        ("rounds", JsonVal::U64(out.report.rounds)),
        ("data_scans", JsonVal::U64(out.report.data_scans)),
        ("stage_boundaries", JsonVal::U64(out.report.stage_boundaries)),
        ("shuffles", JsonVal::U64(out.report.shuffles)),
        ("persists", JsonVal::U64(out.report.persists)),
        (
            "network_volume_bytes",
            JsonVal::U64(out.report.network_volume_bytes),
        ),
        ("elapsed_model_s", JsonVal::F64(out.report.elapsed_secs)),
        ("wall_stage_secs", JsonVal::F64(out.report.wall_stage_secs)),
        ("band_scan_wall_s", JsonVal::F64(band_scan_wall)),
        (
            "stage_walls",
            JsonVal::Arr(out.report.stage_walls.iter().map(|&w| JsonVal::F64(w)).collect()),
        ),
        (
            "executor_utilization",
            JsonVal::F64(out.report.executor_utilization),
        ),
        ("busy_skew", JsonVal::F64(out.report.busy_skew)),
        (
            "simd",
            JsonVal::Str(SimdDispatch::resolve(simd).label().into()),
        ),
        ("simd_lane_width", JsonVal::U64(out.report.simd_lane_width)),
        ("stage_stats", stage_stats_json(&out.report)),
        ("band_candidates", JsonVal::U64(out.report.band_candidates)),
        ("band_budget", JsonVal::U64(out.report.band_budget)),
        ("band_efficiency", JsonVal::F64(out.report.band_efficiency())),
        ("live_epochs", JsonVal::U64(state.live_epochs() as u64)),
        ("store_bytes", JsonVal::U64(state.store_bytes())),
        ("ingest_wall_s_total", JsonVal::F64(ingest_wall)),
        ("exact", JsonVal::Bool(out.report.exact)),
    ]))
}

/// Concurrent serving throughput: `clients` closed-loop client threads
/// against one [`crate::service::QuantileService`] (4 streams warmed
/// with `n` records total, mixed 1-ingest-per-8-ops schedule), vs the
/// identical query sequence run serially through one `QuantileEngine`
/// over the same store contents → a JSON record with real qps, p50/p99
/// query latency, and the concurrency speedup. The per-query protocol
/// stays the serving hot path (rounds=1 / data_scans=1, exact), pinned
/// structurally from a sampled outcome; the service's scratch-cluster
/// queries run `ExecMode::Sequential`, so all parallelism in the
/// concurrent leg comes from clients — which is exactly what the
/// record measures.
pub fn serve_throughput_bench_record(n: u64, clients: usize, simd: SimdPolicy) -> Result<JsonVal> {
    use crate::service::QuantileService;
    use crate::stream::MicroBatch;

    const STREAMS: usize = 4;
    const WARM_BATCHES: u64 = 8;
    const TOTAL_OPS: u64 = 128;
    let per = (n / (STREAMS as u64 * WARM_BATCHES)).max(1) as usize;
    let per_client = (TOTAL_OPS / clients as u64).max(1);
    let mut cc = crate::cluster::ClusterConfig::local(4, 8);
    cc.exec_mode = ExecMode::Sequential;
    cc.faults = None;

    let svc = QuantileService::builder()
        .cluster(cc.clone())
        .kernel_backend(std::sync::Arc::new(NativeBackend::with_policy(simd)))
        .build()?;
    for s in 0..STREAMS {
        for tick in 0..WARM_BATCHES {
            let values = StreamWorkload::Uniform.batch(42 ^ s as u64, tick, per);
            svc.ingest(&format!("bench-{s}"), MicroBatch::new(values))?;
        }
    }

    let svc_ref = &svc;
    let t0 = Instant::now();
    let per_thread: Vec<Result<Vec<f64>>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..clients)
            .map(|c| {
                scope.spawn(move || -> Result<Vec<f64>> {
                    let mut rng = crate::data::pcg::Pcg64::new(42, 0xBE9C ^ c as u64);
                    let mut lats = Vec::new();
                    for op in 0..per_client {
                        let s = (rng.next_u64() % STREAMS as u64) as usize;
                        let id = format!("bench-{s}");
                        if op % 8 == 7 {
                            let values = StreamWorkload::Uniform
                                .batch(7 ^ ((c as u64) << 16) ^ op, op, per);
                            svc_ref.ingest(&id, MicroBatch::new(values))?;
                        } else {
                            let q = if op % 2 == 0 { 0.5 } else { 0.99 };
                            let t = Instant::now();
                            let out = svc_ref.query(&id, &QuantileQuery::Single(q))?;
                            lats.push(t.elapsed().as_secs_f64());
                            ensure!(out.report.exact, "serve bench answered inexactly");
                        }
                    }
                    Ok(lats)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("serve bench client panicked"))
            .collect()
    });
    let concurrent_wall = t0.elapsed().as_secs_f64();
    let mut lats = Vec::new();
    for r in per_thread {
        lats.extend(r?);
    }
    lats.sort_by(f64::total_cmp);
    let queries = lats.len() as u64;
    let qps = queries as f64 / concurrent_wall.max(1e-12);

    // serialized baseline: the same number of queries over the same
    // per-stream record volume, one at a time through one engine
    let mut engine = EngineBuilder::new()
        .cluster(cc)
        .algorithm(AlgoChoice::GkSelect)
        .simd(simd)
        .build()?;
    for s in 0..STREAMS {
        for tick in 0..WARM_BATCHES {
            let values = StreamWorkload::Uniform.batch(42 ^ s as u64, tick, per);
            engine.ingest(&format!("bench-{s}"), MicroBatch::new(values))?;
        }
    }
    let t1 = Instant::now();
    let mut sample = None;
    for i in 0..queries {
        let s = (i % STREAMS as u64) as usize;
        let q = if i % 2 == 0 { 0.5 } else { 0.99 };
        let id = format!("bench-{s}");
        sample = Some(engine.execute(Source::Stream(&id), QuantileQuery::Single(q))?);
    }
    let serial_wall = t1.elapsed().as_secs_f64();
    let serial_qps = queries as f64 / serial_wall.max(1e-12);
    let speedup = qps / serial_qps.max(1e-12);
    let sample = sample.expect("at least one query ran");

    println!(
        "bench gk_select_serve/serve_throughput    {:>2} clients  {:>7.1} qps \
         (p50 {:>6.2} ms p99 {:>6.2} ms)  serialized {:>7.1} qps  speedup {:.2}x",
        clients,
        qps,
        latency_percentile(&lats, 0.50) * 1e3,
        latency_percentile(&lats, 0.99) * 1e3,
        serial_qps,
        speedup,
    );
    Ok(JsonVal::obj(vec![
        ("algorithm", JsonVal::Str("serve_throughput".into())),
        ("exec_mode", JsonVal::Str(format!("clients_{clients}"))),
        ("n", JsonVal::U64(n)),
        ("clients", JsonVal::U64(clients as u64)),
        ("streams", JsonVal::U64(STREAMS as u64)),
        ("queries", JsonVal::U64(queries)),
        ("serve_qps", JsonVal::F64(qps)),
        ("serve_p50_s", JsonVal::F64(latency_percentile(&lats, 0.50))),
        ("serve_p99_s", JsonVal::F64(latency_percentile(&lats, 0.99))),
        ("serialized_qps", JsonVal::F64(serial_qps)),
        ("concurrent_speedup", JsonVal::F64(speedup)),
        ("rounds", JsonVal::U64(sample.report.rounds)),
        ("data_scans", JsonVal::U64(sample.report.data_scans)),
        (
            "simd",
            JsonVal::Str(SimdDispatch::resolve(simd).label().into()),
        ),
        (
            "simd_lane_width",
            JsonVal::U64(SimdDispatch::resolve(simd).lane_width() as u64),
        ),
        ("exact", JsonVal::Bool(sample.report.exact)),
    ]))
}

/// Single-thread fused band-scan throughput, SIMD tile vs the scalar
/// oracle, on the hotpath bench's geometry (uniform keys, an ε-sized
/// band around the median pivot, generous budget) → a JSON record. This
/// is the per-thread scan rate the thread pool multiplies; on AVX2 the
/// acceptance bar is ≥ 1.5x, and the record degrades gracefully to
/// `simd_lane_width = 1` (speedup ≈ 1.0) on targets without a tile.
pub fn simd_vs_scalar_bench_record(n: u64) -> Result<JsonVal> {
    use crate::runtime::KernelBackend;
    let mut rng = crate::data::pcg::Pcg64::new(42, 7);
    let xs: Vec<crate::Key> = (0..n).map(|_| rng.next_u64() as crate::Key).collect();
    let span = (u32::MAX as f64 * 0.005) as crate::Key;
    let (pivot, lo, hi) = (0, -span, span);
    let budget = (n as usize) / 10;

    let scalar = NativeBackend::with_policy(SimdPolicy::ForceScalar);
    let forced = NativeBackend::with_policy(SimdPolicy::ForceSimd);
    let best_wall = |b: &NativeBackend| -> f64 {
        let mut best = f64::INFINITY;
        for _ in 0..5 {
            let t = Instant::now();
            std::hint::black_box(b.band_extract(&xs, pivot, lo, hi, budget));
            best = best.min(t.elapsed().as_secs_f64());
        }
        best
    };
    let scalar_wall = best_wall(&scalar);
    let simd_wall = best_wall(&forced);
    let speedup = scalar_wall / simd_wall.max(1e-12);
    let dispatch = forced.dispatch();
    println!(
        "bench gk_select_simd/simd_vs_scalar       {:<10} scalar {:>7.1} Mkeys/s  \
         {} (x{}) {:>7.1} Mkeys/s  speedup {:.2}x",
        "1-thread",
        n as f64 / scalar_wall / 1e6,
        dispatch.label(),
        dispatch.lane_width(),
        n as f64 / simd_wall / 1e6,
        speedup,
    );
    Ok(JsonVal::obj(vec![
        ("algorithm", JsonVal::Str("simd_vs_scalar".into())),
        ("exec_mode", JsonVal::Str("single_thread".into())),
        ("n", JsonVal::U64(n)),
        ("simd", JsonVal::Str(dispatch.label().into())),
        ("simd_lane_width", JsonVal::U64(dispatch.lane_width() as u64)),
        ("scalar_mkeys_per_s", JsonVal::F64(n as f64 / scalar_wall / 1e6)),
        ("simd_mkeys_per_s", JsonVal::F64(n as f64 / simd_wall / 1e6)),
        ("simd_speedup", JsonVal::F64(speedup)),
    ]))
}

/// What the fault layer costs when armed but idle: the fused GK Select
/// run with a seeded no-op plan (injector consulted per task attempt,
/// nothing ever fires) against the identical run with no injector at
/// all, both pinned to `faults = None` / `Some(noop)` explicitly so
/// `GKSELECT_FAULTS` cannot perturb the measurement → a JSON record
/// with the overhead ratio. Guards the tentpole's "free when off"
/// claim; answers must stay bit-identical.
pub fn fault_overhead_bench_record(n: u64, simd: SimdPolicy) -> Result<JsonVal> {
    let mut run = |faults: Option<FaultPlan>| -> Result<(f64, QueryOutcome)> {
        let mut cc = crate::cluster::ClusterConfig::emr(30);
        cc.exec_mode = ExecMode::Sequential;
        cc.faults = faults;
        let mut engine = EngineBuilder::new()
            .cluster(cc)
            .algorithm(AlgoChoice::GkSelect)
            .simd(simd)
            .build()?;
        let data = Distribution::Uniform.generator(42).generate(engine.cluster_mut(), n);
        let mut best = f64::INFINITY;
        let mut last = None;
        for _ in 0..3 {
            let t = Instant::now();
            let out = engine.execute(Source::Dataset(&data), QuantileQuery::Single(0.75))?;
            best = best.min(t.elapsed().as_secs_f64());
            last = Some(out);
        }
        Ok((best, last.expect("three timed runs")))
    };
    let (baseline_wall, baseline) = run(None)?;
    let (idle_wall, idle) = run(Some(FaultPlan::seeded(0)))?;
    ensure!(
        idle.values == baseline.values && idle.report.faults_injected == 0,
        "idle fault hooks must not change the answer or inject anything"
    );
    let ratio = idle_wall / baseline_wall.max(1e-12);
    println!(
        "bench gk_select_emr30/fault_overhead          sequential rounds {} scans {} \
         baseline {:>8.4}s idle-hooks {:>8.4}s overhead x{:.3}",
        idle.report.rounds, idle.report.data_scans, baseline_wall, idle_wall, ratio,
    );
    Ok(JsonVal::obj(vec![
        ("algorithm", JsonVal::Str("fault_overhead".into())),
        ("distribution", JsonVal::Str("uniform".into())),
        ("exec_mode", JsonVal::Str("sequential".into())),
        ("n", JsonVal::U64(n)),
        ("q", JsonVal::F64(0.75)),
        ("rounds", JsonVal::U64(idle.report.rounds)),
        ("data_scans", JsonVal::U64(idle.report.data_scans)),
        ("faults_injected", JsonVal::U64(idle.report.faults_injected)),
        ("tasks_retried", JsonVal::U64(idle.report.tasks_retried)),
        ("baseline_wall_s", JsonVal::F64(baseline_wall)),
        ("idle_faults_wall_s", JsonVal::F64(idle_wall)),
        ("fault_overhead_ratio", JsonVal::F64(ratio)),
        ("exact", JsonVal::Bool(idle.report.exact)),
    ]))
}

/// What span collection costs when off vs fully on: the fused GK Select
/// run under the default `Null` sink against the identical run writing
/// a Chrome trace file, both pinned explicitly so `GKSELECT_TRACE`
/// cannot perturb the measurement → a JSON record with the overhead
/// ratio. Guards the tentpole's measured-zero-overhead claim for the
/// disabled tracer; answers must stay bit-identical.
pub fn trace_overhead_bench_record(n: u64, simd: SimdPolicy) -> Result<JsonVal> {
    use crate::obs::TraceMode;
    let mut run = |mode: TraceMode| -> Result<(f64, QueryOutcome)> {
        let mut cc = crate::cluster::ClusterConfig::emr(30);
        cc.exec_mode = ExecMode::Sequential;
        cc.faults = None;
        let mut engine = EngineBuilder::new()
            .cluster(cc)
            .algorithm(AlgoChoice::GkSelect)
            .simd(simd)
            .trace(mode)
            .build()?;
        let data = Distribution::Uniform.generator(42).generate(engine.cluster_mut(), n);
        let mut best = f64::INFINITY;
        let mut last = None;
        for _ in 0..3 {
            let t = Instant::now();
            let out = engine.execute(Source::Dataset(&data), QuantileQuery::Single(0.75))?;
            best = best.min(t.elapsed().as_secs_f64());
            last = Some(out);
        }
        Ok((best, last.expect("three timed runs")))
    };
    let chrome_path = std::env::temp_dir().join("gkselect_trace_overhead.json");
    let (off_wall, off) = run(TraceMode::Off)?;
    let (chrome_wall, chrome) = run(TraceMode::Chrome(chrome_path.clone()))?;
    let _ = std::fs::remove_file(&chrome_path);
    ensure!(
        off.values == chrome.values,
        "span collection must not change the answer"
    );
    ensure!(
        off.trace().is_none() && chrome.trace().is_some(),
        "sink wiring: Null surfaces no trace, Chrome surfaces one"
    );
    let spans = chrome.trace().map(|t| t.spans.len() as u64).unwrap_or(0);
    let ratio = chrome_wall / off_wall.max(1e-12);
    println!(
        "bench gk_select_emr30/trace_overhead          sequential rounds {} scans {} \
         null-sink {:>8.4}s chrome-sink {:>8.4}s ({spans} spans) overhead x{:.3}",
        off.report.rounds, off.report.data_scans, off_wall, chrome_wall, ratio,
    );
    Ok(JsonVal::obj(vec![
        ("algorithm", JsonVal::Str("trace_overhead".into())),
        ("distribution", JsonVal::Str("uniform".into())),
        ("exec_mode", JsonVal::Str("sequential".into())),
        ("n", JsonVal::U64(n)),
        ("q", JsonVal::F64(0.75)),
        ("rounds", JsonVal::U64(off.report.rounds)),
        ("data_scans", JsonVal::U64(off.report.data_scans)),
        ("spans", JsonVal::U64(spans)),
        ("null_sink_wall_s", JsonVal::F64(off_wall)),
        ("chrome_sink_wall_s", JsonVal::F64(chrome_wall)),
        ("trace_overhead_ratio", JsonVal::F64(ratio)),
        ("exact", JsonVal::Bool(off.report.exact)),
    ]))
}

/// `repro trace <workload>`: run a small named workload with the
/// Chrome-trace sink armed and leave the Perfetto-loadable span file at
/// `out_path`. Workloads: `batch` (one fused GK Select query — 2 stage
/// spans, 2 scans), `stream` (one ingest + one served query — 1 stage
/// each), `chaos` (the batch query under a seeded plan with a retried
/// panic and a speculated straggler, so the trace shows retry and
/// speculative attempt spans).
pub fn run_trace(cfg: &ReproConfig, workload: &str, n: u64, out_path: &Path) -> Result<()> {
    use crate::obs::{SpanKind, TraceMode};
    use crate::stream::MicroBatch;
    ensure!(n > 0, "need a nonempty workload");
    ensure!(
        matches!(workload, "batch" | "stream" | "chaos"),
        "unknown trace workload '{workload}' (batch|stream|chaos)"
    );
    let mut builder = EngineBuilder::new()
        .config(cfg.clone())
        .algorithm(AlgoChoice::GkSelect)
        .trace(TraceMode::Chrome(out_path.to_path_buf()));
    if workload == "chaos" {
        // one retried panic + every task straggling hard enough to
        // speculate: the trace must show both attempt-span shapes
        builder = builder.fault_plan(FaultPlan::seeded(7).panic_task(0, 0).stragglers(1.0, 8.0));
    }
    let mut engine = builder.build()?;
    ensure!(
        workload != "chaos" || engine.cluster().cfg.executors > 1,
        "chaos trace needs > 1 executor for speculation"
    );
    match workload {
        "stream" => {
            let values = StreamWorkload::Uniform.batch(cfg.algorithm.seed, 0, n as usize);
            let ing = engine.ingest("trace", MicroBatch::new(values))?;
            let out = engine.execute(Source::Stream("trace"), QuantileQuery::Single(0.5))?;
            let trace = out.trace().expect("chrome sink collects spans");
            println!(
                "trace stream: value {}  ingest {} spans, query {} spans \
                 ({} stages, {} attempts)",
                out.value(),
                ing.trace.as_ref().map(|t| t.spans.len()).unwrap_or(0),
                trace.spans.len(),
                trace.spans_of_kind(SpanKind::Stage).count(),
                trace.spans_of_kind(SpanKind::Attempt).count(),
            );
        }
        _ => {
            let data = Distribution::Uniform
                .generator(cfg.algorithm.seed)
                .generate(engine.cluster_mut(), n);
            let out = engine.execute(Source::Dataset(&data), QuantileQuery::Single(0.5))?;
            let trace = out.trace().expect("chrome sink collects spans");
            println!(
                "trace {workload}: value {}  {} spans ({} stages, {} attempts)  \
                 retried {} spec {}/{}",
                out.value(),
                trace.spans.len(),
                trace.spans_of_kind(SpanKind::Stage).count(),
                trace.spans_of_kind(SpanKind::Attempt).count(),
                out.report.tasks_retried,
                out.report.speculative_wins,
                out.report.speculative_launched,
            );
        }
    }
    println!("wrote {}", out_path.display());
    Ok(())
}

/// The `repro metrics` workload: one engine with a Prometheus-file
/// metrics mode runs a mixed batch / stream / chaos sequence, dumping
/// both registry exports into `out_dir`:
///
/// * `prom_early.prom` — a scrape copied mid-workload;
/// * `metrics.prom` — the final scrape (the engine rewrites it after
///   every absorb, so the file is always complete);
/// * `qlog.jsonl` — the structured query log, one line per operation.
///
/// The early/final scrape pair is what `scripts/check_prom.py` feeds its
/// monotone-counter check. Chaos: when neither the config nor
/// `GKSELECT_FAULTS` arms a fault plan, a canned recoverable one (one
/// planned panic + mild stragglers) is injected so the retry counters
/// and attempt-latency sketches are exercised on every run; an
/// env/config plan wins so the CI chaos leg measures exactly its plan.
pub fn run_metrics(cfg: &ReproConfig, n: u64, out_dir: &Path) -> Result<()> {
    use crate::obs::MetricsMode;
    use crate::stream::MicroBatch;
    use anyhow::Context;
    ensure!(n > 0, "need a nonempty workload");
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating metrics output dir {}", out_dir.display()))?;
    let prom_path = out_dir.join("metrics.prom");
    let early_path = out_dir.join("prom_early.prom");
    let qlog_path = out_dir.join("qlog.jsonl");

    let mut builder = EngineBuilder::new()
        .config(cfg.clone())
        .algorithm(AlgoChoice::GkSelect)
        .metrics(MetricsMode::Prom(prom_path.clone()));
    let env_faults = crate::engine::env::faults()?;
    let chaos_armed = !cfg.faults.plan.is_empty() || env_faults.is_some();
    if !chaos_armed {
        builder = builder.fault_plan(FaultPlan::seeded(7).panic_task(0, 0).stragglers(0.2, 4.0));
    }
    let mut engine = builder.build()?;

    // batch phase: every plan shape, exact and sketched
    let data = Distribution::Uniform
        .generator(cfg.algorithm.seed)
        .generate(engine.cluster_mut(), n);
    engine.execute(Source::Dataset(&data), QuantileQuery::Single(0.5))?;
    engine.execute(
        Source::Dataset(&data),
        QuantileQuery::Multi(vec![0.25, 0.5, 0.95]),
    )?;
    engine.execute(Source::Dataset(&data), QuantileQuery::Rank(n / 2))?;
    engine.execute(
        Source::Dataset(&data),
        QuantileQuery::Sketched { q: 0.9, eps: 0.05 },
    )?;
    // mid-workload scrape: every counter here must be <= the final one
    std::fs::copy(&prom_path, &early_path)
        .with_context(|| format!("copying early scrape to {}", early_path.display()))?;

    // stream phase: ingests interleaved with exact + sketched serving
    let per = (n / 8).max(1) as usize;
    for tick in 0..8u64 {
        let values = StreamWorkload::Uniform.batch(cfg.algorithm.seed, tick, per);
        engine.ingest("metrics", MicroBatch::new(values))?;
        if tick % 2 == 1 {
            engine.execute(Source::Stream("metrics"), QuantileQuery::Single(0.95))?;
        }
    }
    engine.execute(
        Source::Stream("metrics"),
        QuantileQuery::Sketched { q: 0.5, eps: 0.05 },
    )?;

    // the qlog buffer is kept in every armed mode — dump it whole
    let mut qlog = String::new();
    for line in engine.registry().qlog_lines() {
        qlog.push_str(line);
        qlog.push('\n');
    }
    std::fs::write(&qlog_path, qlog)
        .with_context(|| format!("writing {}", qlog_path.display()))?;

    let snap = engine.metrics_snapshot();
    println!(
        "metrics: {} ops absorbed ({} exec, simd lane {})",
        snap.ops, snap.exec_mode, snap.simd_lane_width
    );
    for ((kind, stream), t) in &snap.totals {
        println!(
            "  {:<8} {:<8} ops {:<3} rounds {:<3} scans {:<3} moved {} band-eff {:.3}",
            kind.label(),
            if stream.is_empty() { "-" } else { stream },
            t.ops,
            t.rounds,
            t.data_scans,
            crate::cluster::metrics::human_bytes(t.bytes_moved()),
            t.band_efficiency(),
        );
    }
    let g = snap.grand();
    println!(
        "  grand: faults {} retried {} spec {}/{}  band {}/{} (eff {:.3})",
        g.faults_injected,
        g.tasks_retried,
        g.speculative_wins,
        g.speculative_launched,
        g.band_candidates,
        g.band_budget,
        g.band_efficiency(),
    );
    for (id, r) in &snap.residency {
        println!(
            "  store {:<8} live {}/{} epochs, {} partials, {} (compactions {})",
            id,
            r.live_epochs,
            r.sealed_epochs,
            r.sketch_partials,
            crate::cluster::metrics::human_bytes(r.store_bytes()),
            r.compactions,
        );
    }
    println!(
        "wrote {} + {} + {}",
        prom_path.display(),
        early_path.display(),
        qlog_path.display()
    );
    Ok(())
}

/// Build the `BENCH_gk_select.json` document: the fused two-round path on
/// the acceptance distributions, a threads-vs-sequential pair on the same
/// uniform workload (so the file carries modelled *and* real parallel
/// wall time for the fused band-extract scan on `emr(30)`), and the
/// seed-shaped three-round baseline.
pub fn gk_select_bench_doc(n: u64, simd: SimdPolicy) -> Result<JsonVal> {
    let records = vec![
        // the fused two-round path, acceptance distributions
        gk_select_bench_record(
            "fused",
            Distribution::Uniform,
            n,
            None,
            ExecMode::Sequential,
            simd,
        )?,
        gk_select_bench_record(
            "fused_zipf",
            Distribution::Zipf,
            n,
            None,
            ExecMode::Sequential,
            simd,
        )?,
        gk_select_bench_record(
            "fused_bimodal",
            Distribution::Bimodal,
            n,
            None,
            ExecMode::Sequential,
            simd,
        )?,
        gk_select_bench_record(
            "fused_sorted",
            Distribution::Sorted,
            n,
            None,
            ExecMode::Sequential,
            simd,
        )?,
        // same workload through the thread pool: real parallel wall-clock
        gk_select_bench_record(
            "fused_threads",
            Distribution::Uniform,
            n,
            None,
            ExecMode::Threads,
            simd,
        )?,
        // the seed path's round/scan shape, same workload: budget 0 forces
        // the overflow fallback, reproducing the seed's 3 rounds and 3
        // data scans (sketch + count + secondPass). Caveat: the middle
        // scan here is the fused six-counter kernel where the seed ran
        // plain count_pivot, so this baseline is marginally costlier per
        // scanned key than the true seed; the 3→2 round and scan
        // accounting, which dominates on the EMR fabric model, is
        // structural and exact. See `note` in the JSON.
        gk_select_bench_record(
            "three_round_baseline",
            Distribution::Uniform,
            n,
            Some(0),
            ExecMode::Sequential,
            simd,
        )?,
        // the serving hot path: one streamed query after 32 micro-batches
        // — its only data scan is the fused band-extract pass (rounds=1 /
        // scans=1; the sketch work was paid at ingest), sequential and
        // through the thread pool
        stream_query_bench_record("stream_query", n, 32, ExecMode::Sequential, simd)?,
        stream_query_bench_record("stream_query_threads", n, 32, ExecMode::Threads, simd)?,
        // the concurrent serving layer: closed-loop clients against one
        // QuantileService vs the same queries serialized through one
        // engine — real qps and p50/p99 at three offered loads
        serve_throughput_bench_record(n, 1, simd)?,
        serve_throughput_bench_record(n, 8, simd)?,
        serve_throughput_bench_record(n, 32, simd)?,
        // the kernel dispatch itself: single-thread band-scan rate of the
        // SIMD tile vs the scalar oracle (what ExecMode::Threads multiplies)
        simd_vs_scalar_bench_record(n)?,
        // the recovery layer armed-but-idle vs absent: "free when off"
        fault_overhead_bench_record(n, simd)?,
        // the tracing layer disabled vs Chrome export: "free when off"
        trace_overhead_bench_record(n, simd)?,
    ];
    Ok(JsonVal::obj(vec![
        ("bench", JsonVal::Str("gk_select".into())),
        ("cluster", JsonVal::Str("emr(30)".into())),
        // real measured walls: a committed baseline regenerated by this
        // function arms the perf gates (the checked-in structural-only
        // skeleton says false and gates only counters)
        ("calibrated", JsonVal::Bool(true)),
        (
            "note",
            JsonVal::Str(
                "three_round_baseline replays the seed path's 3-round/3-scan \
                 shape via a zero candidate budget; its middle scan is the \
                 fused kernel (slightly costlier than the seed's count_pivot), \
                 so the time improvement vs this baseline may be slightly \
                 overstated by that compute delta — the 3->2 round and 3->2 \
                 scan reduction is structural and exact. fused_threads runs \
                 the identical workload through the OS-thread executor pool: \
                 wall_stage_secs / band_scan_wall_s are real parallel \
                 wall-clock; its elapsed_model_s absorbs real scheduling \
                 contention (per-partition times are measured on \
                 oversubscribed threads), so read modelled time from the \
                 sequential `fused` record and real time from this one. \
                 stream_query[_threads] measure the serving hot path: one \
                 exact query answered from cached ingest-time sketches \
                 after 32 micro-batches — rounds=1/data_scans=1, the only \
                 stage being the fused band-extract scan. simd_vs_scalar \
                 pins the kernel dispatch itself: single-thread fused \
                 band-scan throughput of the explicit SIMD tile (simd / \
                 simd_lane_width say which tile) against the forced \
                 scalar oracle on identical data; every other record also \
                 carries the simd/simd_lane_width it ran with. \
                 fault_overhead pins the recovery layer's enabled-but-idle \
                 cost: the same fused run with a seeded no-op FaultPlan \
                 (injector consulted per task attempt, nothing fires) vs no \
                 injector at all — answers bit-identical, \
                 fault_overhead_ratio should stay ~1.0. trace_overhead \
                 pins the tracing layer the same way: the default Null \
                 sink (tracer disarmed, hooks no-op) vs a Chrome-trace \
                 export of every span — answers bit-identical, \
                 trace_overhead_ratio should stay ~1.0. stage_stats on \
                 each run are the self-sketched per-stage task-latency \
                 percentiles (virtual-clock us through our own GK sketch; \
                 deterministic, mode-independent). serve_throughput \
                 [clients_1|8|32] measures the concurrent multi-tenant \
                 QuantileService: closed-loop client threads running a \
                 mixed ingest/query schedule over 4 streams vs the same \
                 query count serialized through one engine — serve_qps, \
                 real p50/p99 query latency, and concurrent_speedup \
                 (clients_1 pins the service's per-query overhead near \
                 1.0x; 8 and 32 must scale). Every served answer is \
                 exact and snapshot-isolated; rounds/data_scans stay \
                 the 1/1 serving hot path"
                    .into(),
            ),
        ),
        ("runs", JsonVal::Arr(records)),
    ]))
}

/// Emit the `BENCH_*.json` family (today: `BENCH_gk_select.json`) — the
/// shared implementation behind `repro bench json` and the tail of
/// `benches/hotpath.rs`.
pub fn write_bench_json(out_dir: &Path, n: u64, simd: SimdPolicy) -> Result<()> {
    use anyhow::Context;
    std::fs::create_dir_all(out_dir)
        .with_context(|| format!("creating bench output dir {}", out_dir.display()))?;
    let doc = gk_select_bench_doc(n, simd)?;
    let path = out_dir.join("BENCH_gk_select.json");
    write_json(&path, &doc).with_context(|| format!("writing {}", path.display()))?;
    println!("wrote {}", path.display());
    Ok(())
}
