//! Small statistics helpers for the experiment reports: means, 95%
//! t-distribution confidence intervals (Figs. 3–4), and log-log slope
//! fits (Table IV scaling exponents).

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n−1); 0 when fewer than 2 points.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Two-sided 95% critical value of Student's t for `df` degrees of
/// freedom (table lookup, converging to 1.96).
pub fn t95(df: usize) -> f64 {
    const TABLE: [f64; 30] = [
        12.706, 4.303, 3.182, 2.776, 2.571, 2.447, 2.365, 2.306, 2.262, 2.228, 2.201, 2.179,
        2.160, 2.145, 2.131, 2.120, 2.110, 2.101, 2.093, 2.086, 2.080, 2.074, 2.069, 2.064,
        2.060, 2.056, 2.052, 2.048, 2.045, 2.042,
    ];
    if df == 0 {
        return f64::NAN;
    }
    if df <= TABLE.len() {
        TABLE[df - 1]
    } else if df <= 60 {
        2.000
    } else {
        1.96
    }
}

/// 95% confidence interval of the mean via the t-distribution (what the
/// paper plots in Figs. 3–4).
pub fn ci95(xs: &[f64]) -> (f64, f64) {
    let m = mean(xs);
    if xs.len() < 2 {
        return (m, m);
    }
    let half = t95(xs.len() - 1) * std_dev(xs) / (xs.len() as f64).sqrt();
    (m - half, m + half)
}

/// Least-squares slope of `log y` vs `log x` — the empirical scaling
/// exponent over (n, time) points.
pub fn loglog_slope(pts: &[(f64, f64)]) -> f64 {
    let logged: Vec<(f64, f64)> = pts
        .iter()
        .filter(|(x, y)| *x > 0.0 && *y > 0.0)
        .map(|(x, y)| (x.ln(), y.ln()))
        .collect();
    if logged.len() < 2 {
        return f64::NAN;
    }
    let n = logged.len() as f64;
    let sx: f64 = logged.iter().map(|(x, _)| x).sum();
    let sy: f64 = logged.iter().map(|(_, y)| y).sum();
    let sxx: f64 = logged.iter().map(|(x, _)| x * x).sum();
    let sxy: f64 = logged.iter().map(|(x, y)| x * y).sum();
    (n * sxy - sx * sy) / (n * sxx - sx * sx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((std_dev(&[2.0, 4.0]) - std::f64::consts::SQRT_2).abs() < 1e-12);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }

    #[test]
    fn t95_values() {
        assert!((t95(1) - 12.706).abs() < 1e-9);
        assert!((t95(30) - 2.042).abs() < 1e-9);
        assert!((t95(99) - 1.96).abs() < 1e-9);
        assert!(t95(0).is_nan());
    }

    #[test]
    fn ci_contains_mean() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        let (lo, hi) = ci95(&xs);
        assert!(lo < 3.0 && 3.0 < hi);
        let (lo, hi) = ci95(&[7.0]);
        assert_eq!((lo, hi), (7.0, 7.0));
    }

    #[test]
    fn slope_of_powers() {
        // y = x^2 → slope 2
        let pts: Vec<(f64, f64)> = (1..10).map(|i| (i as f64, (i * i) as f64)).collect();
        assert!((loglog_slope(&pts) - 2.0).abs() < 1e-9);
        // y = 3x → slope 1
        let pts: Vec<(f64, f64)> = (1..10).map(|i| (i as f64, 3.0 * i as f64)).collect();
        assert!((loglog_slope(&pts) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn slope_degenerate() {
        assert!(loglog_slope(&[(1.0, 1.0)]).is_nan());
        assert!(loglog_slope(&[]).is_nan());
    }
}
