//! Floyd–Rivest SELECT ([3], [5]): sampling-refined pivots.
//!
//! On large ranges, SELECT recursively selects bracketing pivots from a
//! `O(n^{2/3})` sample so the subsequent partition isolates the target
//! rank inside a tiny window — the classical "better pivots collapse the
//! search" insight that GK Select lifts to the distributed setting with a
//! sketch instead of a sample (paper §II-B2).
//!
//! Faithful port of the published Algorithm 489 control flow (signed
//! indices: the inner partition walks `j` below `left`).

const SAMPLE_CUTOFF: isize = 600; // published constant: sample only above this

fn fr_select<T: Ord + Copy>(a: &mut [T], mut left: isize, mut right: isize, k: isize) {
    while right > left {
        if right - left > SAMPLE_CUTOFF {
            let n = (right - left + 1) as f64;
            let i = (k - left + 1) as f64;
            let z = n.ln();
            let s = 0.5 * (2.0 * z / 3.0).exp();
            let sd = 0.5 * (z * s * (n - s) / n).sqrt() * (i - n / 2.0).signum();
            let new_left = (left as f64).max((k as f64 - i * s / n + sd).floor()) as isize;
            let new_right =
                (right as f64).min((k as f64 + (n - i) * s / n + sd).floor()) as isize;
            fr_select(a, new_left, new_right, k);
        }
        let t = a[k as usize];
        let mut i = left;
        let mut j = right;
        a.swap(left as usize, k as usize);
        if a[right as usize] > t {
            a.swap(right as usize, left as usize);
        }
        while i < j {
            a.swap(i as usize, j as usize);
            i += 1;
            j -= 1;
            while a[i as usize] < t {
                i += 1;
            }
            while a[j as usize] > t {
                j -= 1;
            }
        }
        if a[left as usize] == t {
            a.swap(left as usize, j as usize);
        } else {
            j += 1;
            a.swap(j as usize, right as usize);
        }
        if j <= k {
            left = j + 1;
        }
        if k <= j {
            right = j - 1;
        }
    }
}

/// Floyd–Rivest selection: the k-th smallest element of `a` (0-based).
pub fn floyd_rivest_select<T: Ord + Copy>(a: &mut [T], k: usize) -> T {
    assert!(k < a.len(), "rank {k} out of bounds for len {}", a.len());
    let hi = (a.len() - 1) as isize;
    fr_select(a, 0, hi, k as isize);
    a[k]
}

/// Guarded entry point used by the algorithms: tiny slices go through the
/// Dutch-based quickselect (FR's sampling machinery has no payoff there).
pub fn floyd_rivest_with_fallback<T: Ord + Copy>(a: &mut [T], k: usize, seed: u64) -> T {
    if a.len() < 32 {
        return super::quickselect::select_kth(a, k, seed);
    }
    floyd_rivest_select(a, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::SplitMix64;

    fn oracle(mut v: Vec<i64>, k: usize) -> i64 {
        v.sort_unstable();
        v[k]
    }

    #[test]
    fn selects_every_rank_small() {
        let base: Vec<i64> = vec![9, 1, 8, 2, 7, 3, 6, 4, 5, 0];
        for k in 0..base.len() {
            let mut a = base.clone();
            assert_eq!(floyd_rivest_select(&mut a, k), oracle(base.clone(), k));
        }
    }

    #[test]
    fn large_random_matches_sort() {
        let mut rng = SplitMix64::new(11);
        let v: Vec<i64> = (0..50_000).map(|_| rng.next_u64() as i64).collect();
        for &k in &[0, 1, 25_000, 49_998, 49_999] {
            let mut a = v.clone();
            assert_eq!(floyd_rivest_select(&mut a, k), oracle(v.clone(), k));
        }
    }

    #[test]
    fn sorted_and_reversed() {
        let v: Vec<i64> = (0..10_000).collect();
        let mut a = v.clone();
        assert_eq!(floyd_rivest_select(&mut a, 5_000), 5_000);
        let mut a: Vec<i64> = (0..10_000).rev().collect();
        assert_eq!(floyd_rivest_select(&mut a, 123), 123);
    }

    #[test]
    fn duplicates() {
        let v: Vec<i64> = vec![7; 10_000];
        let mut a = v.clone();
        assert_eq!(floyd_rivest_with_fallback(&mut a, 9_999, 1), 7);
        let mut mixed: Vec<i64> = (0..5_000).map(|i| i % 3).collect();
        let want = oracle(mixed.clone(), 2_500);
        assert_eq!(floyd_rivest_select(&mut mixed, 2_500), want);
    }

    #[test]
    fn randomized_against_sort() {
        let mut rng = SplitMix64::new(777);
        for _ in 0..20 {
            let n = rng.below(5_000) + 2;
            let v: Vec<i64> = (0..n).map(|_| (rng.next_u64() % 1000) as i64).collect();
            let k = rng.below(n);
            let mut a = v.clone();
            assert_eq!(
                floyd_rivest_with_fallback(&mut a, k, rng.next_u64()),
                oracle(v, k)
            );
        }
    }
}
