//! BFPRT median-of-medians ([2]): deterministic worst-case `O(n)`
//! selection.
//!
//! Groups of five, median of the group medians as pivot — guarantees a
//! 30/70 split. Constants are large (the paper notes randomized variants
//! win in practice), so this is the *baseline* the select benches compare
//! quickselect/Floyd–Rivest against, and the fallback for adversarial
//! inputs.

use super::dutch::dutch_partition;

fn median_of_five<T: Ord + Copy>(a: &mut [T]) -> T {
    // insertion sort of at most 5 elements
    for i in 1..a.len() {
        let mut j = i;
        while j > 0 && a[j - 1] > a[j] {
            a.swap(j - 1, j);
            j -= 1;
        }
    }
    a[a.len() / 2]
}

fn mom_pivot<T: Ord + Copy>(a: &mut [T]) -> T {
    if a.len() <= 5 {
        return median_of_five(a);
    }
    let mut medians: Vec<T> = a.chunks_mut(5).map(median_of_five).collect();
    let mid = medians.len() / 2;
    bfprt_select(&mut medians, mid)
}

/// Deterministic selection of the k-th smallest (0-based), worst-case
/// linear time.
pub fn bfprt_select<T: Ord + Copy>(a: &mut [T], k: usize) -> T {
    assert!(k < a.len(), "rank {k} out of bounds for len {}", a.len());
    let mut lo = 0usize;
    let mut hi = a.len();
    loop {
        if hi - lo <= 5 {
            let sub = &mut a[lo..hi];
            for i in 1..sub.len() {
                let mut j = i;
                while j > 0 && sub[j - 1] > sub[j] {
                    sub.swap(j - 1, j);
                    j -= 1;
                }
            }
            return a[k];
        }
        // pivot from a scratch copy: mom_pivot reorders its input and we
        // only need the value
        let mut scratch = a[lo..hi].to_vec();
        let pivot = mom_pivot(&mut scratch);
        let split = dutch_partition(&mut a[lo..hi], pivot);
        let (plt, pgt) = (lo + split.lt, lo + split.gt);
        if k < plt {
            hi = plt;
        } else if k >= pgt {
            lo = pgt;
        } else {
            return pivot;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::SplitMix64;

    fn oracle(mut v: Vec<i32>, k: usize) -> i32 {
        v.sort_unstable();
        v[k]
    }

    #[test]
    fn selects_every_rank_small() {
        let base = vec![3, 1, 4, 1, 5, 9, 2, 6, 5, 3, 5];
        for k in 0..base.len() {
            let mut a = base.clone();
            assert_eq!(bfprt_select(&mut a, k), oracle(base.clone(), k));
        }
    }

    #[test]
    fn worst_case_inputs() {
        let mut a: Vec<i32> = (0..2_000).collect();
        assert_eq!(bfprt_select(&mut a, 1_000), 1_000);
        let mut a: Vec<i32> = (0..2_000).rev().collect();
        assert_eq!(bfprt_select(&mut a, 0), 0);
        let mut a = vec![1; 999];
        assert_eq!(bfprt_select(&mut a, 500), 1);
    }

    #[test]
    fn randomized_against_sort() {
        let mut rng = SplitMix64::new(31);
        for _ in 0..20 {
            let n = rng.below(3_000) + 1;
            let v: Vec<i32> = (0..n).map(|_| (rng.next_u64() % 500) as i32).collect();
            let k = rng.below(n);
            let mut a = v.clone();
            assert_eq!(bfprt_select(&mut a, k), oracle(v, k));
        }
    }
}
