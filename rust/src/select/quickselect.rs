//! Hoare QuickSelect (FIND, [1]) with random pivots.
//!
//! Matches the paper's appendix `quickSelect`: in-place, expected linear,
//! leaves the slice partitioned so that `a[k]` is the k-th smallest and
//! everything before/after is ≤/≥ it — which is exactly what `secondPass`
//! relies on to slice out the candidate band without a sort.

use super::dutch::dutch_partition;
use super::SplitMix64;

/// Rearrange `a` so `a[k]` is the k-th smallest (0-based); elements below
/// index `k` are ≤ `a[k]`, elements above are ≥ `a[k]`.
pub fn quickselect<T: Ord + Copy>(a: &mut [T], k: usize, rng: &mut SplitMix64) {
    assert!(k < a.len(), "rank {k} out of bounds for len {}", a.len());
    let mut lo = 0usize;
    let mut hi = a.len();
    // invariant: target index k lies in a[lo..hi]
    loop {
        if hi - lo <= 1 {
            return;
        }
        let pivot = a[lo + rng.below(hi - lo)];
        let split = dutch_partition(&mut a[lo..hi], pivot);
        let (plt, pgt) = (lo + split.lt, lo + split.gt);
        if k < plt {
            hi = plt;
        } else if k >= pgt {
            lo = pgt;
        } else {
            return; // k falls in the == pivot run
        }
    }
}

/// Return the k-th smallest of `a` (0-based) — convenience wrapper.
pub fn select_kth<T: Ord + Copy>(a: &mut [T], k: usize, seed: u64) -> T {
    let mut rng = SplitMix64::new(seed);
    quickselect(a, k, &mut rng);
    a[k]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn oracle(mut v: Vec<i32>, k: usize) -> i32 {
        v.sort_unstable();
        v[k]
    }

    #[test]
    fn selects_every_rank_small() {
        let base = vec![9, 1, 8, 2, 7, 3, 6, 4, 5, 0];
        for k in 0..base.len() {
            let mut a = base.clone();
            assert_eq!(select_kth(&mut a, k, 42), oracle(base.clone(), k));
        }
    }

    #[test]
    fn duplicates() {
        let base = vec![5, 5, 5, 1, 1, 9, 9, 5];
        for k in 0..base.len() {
            let mut a = base.clone();
            assert_eq!(select_kth(&mut a, k, 7), oracle(base.clone(), k));
        }
    }

    #[test]
    fn partitions_around_result() {
        let mut a: Vec<i32> = (0..500).rev().collect();
        let mut rng = SplitMix64::new(3);
        quickselect(&mut a, 250, &mut rng);
        assert_eq!(a[250], 250);
        assert!(a[..250].iter().all(|&x| x <= 250));
        assert!(a[251..].iter().all(|&x| x >= 250));
    }

    #[test]
    fn singleton() {
        assert_eq!(select_kth(&mut [42], 0, 0), 42);
    }

    #[test]
    fn adversarial_sorted_input() {
        let mut a: Vec<i32> = (0..10_000).collect();
        assert_eq!(select_kth(&mut a, 9_999, 5), 9_999);
        let mut a: Vec<i32> = (0..10_000).collect();
        assert_eq!(select_kth(&mut a, 0, 5), 0);
    }

    #[test]
    #[should_panic]
    fn rank_out_of_bounds_panics() {
        select_kth(&mut [1, 2, 3], 3, 0);
    }

    #[test]
    fn randomized_against_sort() {
        let mut rng = SplitMix64::new(2024);
        for _ in 0..30 {
            let n = rng.below(1000) + 1;
            let v: Vec<i32> = (0..n).map(|_| (rng.next_u64() % 100) as i32).collect();
            let k = rng.below(n);
            let mut a = v.clone();
            assert_eq!(select_kth(&mut a, k, rng.next_u64()), oracle(v, k));
        }
    }
}
