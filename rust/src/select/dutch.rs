//! Dutch national flag (three-way) partition.
//!
//! One linear pass rearranges the slice into `[< pivot | == pivot |
//! > pivot]` and reports the two boundaries. This is the executor-side
//! workhorse: AFS/Jeffers run it every round to count and discard, and
//! GK Select's `secondPass` runs it once before extracting the `|Δk|`
//! candidate band (paper appendix, Fig. 5).

/// Boundaries of a three-way partition: `lt` = index one past the last
/// `< pivot` element, `gt` = index of the first `> pivot` element.
/// Elements in `a[lt..gt]` equal the pivot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DutchSplit {
    pub lt: usize,
    pub gt: usize,
}

impl DutchSplit {
    /// Count of elements strictly below the pivot.
    pub fn below(&self) -> usize {
        self.lt
    }

    /// Count of elements equal to the pivot.
    pub fn equal(&self) -> usize {
        self.gt - self.lt
    }
}

/// Partition `a` in place around `pivot`; single pass, no allocation.
pub fn dutch_partition<T: Ord + Copy>(a: &mut [T], pivot: T) -> DutchSplit {
    let mut lo = 0usize;
    let mut mid = 0usize;
    let mut hi = a.len();
    while mid < hi {
        if a[mid] < pivot {
            a.swap(lo, mid);
            lo += 1;
            mid += 1;
        } else if a[mid] > pivot {
            hi -= 1;
            a.swap(mid, hi);
        } else {
            mid += 1;
        }
    }
    DutchSplit { lt: lo, gt: hi }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::select::SplitMix64;

    fn check(a: &mut [i32], pivot: i32) -> DutchSplit {
        let mut sorted = a.to_vec();
        sorted.sort_unstable();
        let s = dutch_partition(a, pivot);
        // structural invariants
        assert!(a[..s.lt].iter().all(|&x| x < pivot));
        assert!(a[s.lt..s.gt].iter().all(|&x| x == pivot));
        assert!(a[s.gt..].iter().all(|&x| x > pivot));
        // permutation preserved
        let mut after = a.to_vec();
        after.sort_unstable();
        assert_eq!(after, sorted);
        s
    }

    #[test]
    fn basic_three_way() {
        let mut a = vec![5, 1, 5, 9, 5, 3, 7];
        let s = check(&mut a, 5);
        assert_eq!(s.below(), 2);
        assert_eq!(s.equal(), 3);
    }

    #[test]
    fn pivot_absent() {
        let mut a = vec![1, 9, 3, 7];
        let s = check(&mut a, 5);
        assert_eq!(s.below(), 2);
        assert_eq!(s.equal(), 0);
    }

    #[test]
    fn all_equal() {
        let mut a = vec![4; 100];
        let s = check(&mut a, 4);
        assert_eq!(s.below(), 0);
        assert_eq!(s.equal(), 100);
    }

    #[test]
    fn empty_and_singleton() {
        let mut a: Vec<i32> = vec![];
        let s = dutch_partition(&mut a, 5);
        assert_eq!(s, DutchSplit { lt: 0, gt: 0 });
        let mut a = vec![3];
        let s = check(&mut a, 3);
        assert_eq!(s.equal(), 1);
    }

    #[test]
    fn pivot_below_all_and_above_all() {
        let mut a = vec![5, 6, 7];
        let s = check(&mut a, 1);
        assert_eq!((s.lt, s.gt), (0, 0));
        let mut a = vec![5, 6, 7];
        let s = check(&mut a, 100);
        assert_eq!((s.lt, s.gt), (3, 3));
    }

    #[test]
    fn randomized_stress() {
        let mut rng = SplitMix64::new(99);
        for _ in 0..50 {
            let n = rng.below(200) + 1;
            let mut a: Vec<i32> = (0..n).map(|_| (rng.next_u64() % 50) as i32 - 25).collect();
            let pivot = a[rng.below(n)];
            check(&mut a, pivot);
        }
    }

    #[test]
    fn extremes() {
        let mut a = vec![i32::MIN, i32::MAX, 0, i32::MIN, i32::MAX];
        let s = check(&mut a, 0);
        assert_eq!(s.below(), 2);
        assert_eq!(s.equal(), 1);
    }
}
