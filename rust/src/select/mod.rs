//! Sequential selection substrates (§II-A1): the in-partition primitives
//! every distributed algorithm composes.
//!
//! * [`dutch::dutch_partition`] — three-way (Dutch national flag)
//!   partition around a pivot, the local pass of AFS/Jeffers rounds and
//!   GK Select's `secondPass`.
//! * [`quickselect::quickselect`] — Hoare FIND with random pivots,
//!   expected linear time.
//! * [`floyd_rivest::floyd_rivest_select`] — SELECT with sampled pivots,
//!   expected linear with small constants (the classical analogue of the
//!   sketch-guided pivot idea).
//! * [`median_of_medians::bfprt_select`] — BFPRT, worst-case `O(n)`.
//!
//! All operate on `&mut [T]`, mirroring the paper's appendix code which
//! materializes the partition iterator into an array inside
//! `mapPartitions`.

pub mod dutch;
pub mod floyd_rivest;
pub mod median_of_medians;
pub mod quickselect;

pub use dutch::{dutch_partition, DutchSplit};
pub use floyd_rivest::floyd_rivest_select;
pub use median_of_medians::bfprt_select;
pub use quickselect::{quickselect, select_kth};

/// Deterministic xorshift64* used for pivot choice — no external RNG
/// dependency, reproducible runs.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self {
            state: seed.wrapping_add(0x9E3779B97F4A7C15),
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`; `bound` must be nonzero.
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        (self.next_u64() % bound as u64) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(7);
        let mut b = SplitMix64::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = SplitMix64::new(1);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = SplitMix64::new(1);
        let mut b = SplitMix64::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
