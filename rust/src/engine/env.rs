//! The single place process environment variables are read and parsed.
//!
//! Before the engine existed, `GKSELECT_EXEC_MODE` and `GKSELECT_SIMD`
//! were parsed ad hoc in three places (`ExecMode::from_env`,
//! `SimdPolicy::from_env`, and the config layer), each with its own
//! panic message and its own idea of what an empty value means. All of
//! them now delegate here, so the parsing rules exist exactly once:
//!
//! * unset or empty → `Ok(None)` — the caller falls through to its
//!   default (the builder > config file > env precedence is resolved in
//!   [`super::EngineBuilder`]);
//! * a valid value → `Ok(Some(..))`;
//! * an unparseable value → a typed [`EngineError::InvalidEnv`] naming
//!   the variable, the offending value, and the accepted grammar —
//!   never a silent fallback.
//!
//! Centralization is enforced: `scripts/lint_repo.py` (rule GK-I2, see
//! docs/INVARIANTS.md) fails CI on any `env::var` read outside this
//! module, so a stray `GKSELECT_*` read can't create configuration
//! that bypasses validation and the run manifest.

use super::EngineError;
use crate::cluster::{ExecMode, FaultPlan};
use crate::obs::{MetricsMode, TraceMode};
use crate::runtime::SimdPolicy;

/// Environment variable selecting the executor pool mode
/// (`sequential` | `threads`) — the CI toggle that re-runs the whole
/// suite under real concurrency.
pub const EXEC_MODE_VAR: &str = "GKSELECT_EXEC_MODE";

/// Environment variable selecting the band-scan SIMD dispatch policy
/// (`auto` | `scalar` | `force`) — the CI toggle pinning each side of
/// the kernel dispatch.
pub const SIMD_VAR: &str = "GKSELECT_SIMD";

/// Environment variable carrying a seeded fault-injection plan in
/// [`FaultPlan`]'s `key=value` grammar (e.g.
/// `seed=7,panic=0.02,straggler=0.1x4`) — the CI toggle that re-runs
/// the whole suite under injection.
pub const FAULTS_VAR: &str = "GKSELECT_FAULTS";

/// Environment variable selecting the trace sink
/// (`off` | `memory` | `chrome:<path>` | a bare `*.json` path) — lets
/// CI or a shell capture Perfetto traces from any `repro` invocation
/// without touching flags.
pub const TRACE_VAR: &str = "GKSELECT_TRACE";

/// Environment variable selecting the engine-lifetime metrics mode
/// (`off` | `memory` | `prom:<path>` | `qlog:<path>`) — lets CI or a
/// shell scrape any `repro` invocation without touching flags.
pub const METRICS_VAR: &str = "GKSELECT_METRICS";

/// Parse an execution mode from a raw variable value. Pure — the
/// testable core of [`exec_mode`].
pub fn parse_exec_mode(raw: Option<&str>) -> Result<Option<ExecMode>, EngineError> {
    match raw {
        None => Ok(None),
        Some("") => Ok(None),
        Some(v) => v.parse::<ExecMode>().map(Some).map_err(|_| EngineError::InvalidEnv {
            var: EXEC_MODE_VAR,
            value: v.to_string(),
            expected: "sequential|threads",
        }),
    }
}

/// Parse a SIMD policy from a raw variable value. Pure — the testable
/// core of [`simd_policy`].
pub fn parse_simd_policy(raw: Option<&str>) -> Result<Option<SimdPolicy>, EngineError> {
    match raw {
        None => Ok(None),
        Some("") => Ok(None),
        Some(v) => v.parse::<SimdPolicy>().map(Some).map_err(|_| EngineError::InvalidEnv {
            var: SIMD_VAR,
            value: v.to_string(),
            expected: "auto|scalar|force",
        }),
    }
}

/// Parse a fault plan from a raw variable value. Pure — the testable
/// core of [`faults`].
pub fn parse_faults(raw: Option<&str>) -> Result<Option<FaultPlan>, EngineError> {
    match raw {
        None => Ok(None),
        Some("") => Ok(None),
        Some(v) => v.parse::<FaultPlan>().map(Some).map_err(|_| EngineError::InvalidEnv {
            var: FAULTS_VAR,
            value: v.to_string(),
            expected: "seed=N[,panic=R][,transient=R][,straggler=RxM][,attempts=K][,lose=S:E][,panic_at=S:P]",
        }),
    }
}

/// Parse a trace mode from a raw variable value. Pure — the testable
/// core of [`trace`].
pub fn parse_trace(raw: Option<&str>) -> Result<Option<TraceMode>, EngineError> {
    match raw {
        None => Ok(None),
        Some("") => Ok(None),
        Some(v) => v.parse::<TraceMode>().map(Some).map_err(|_| EngineError::InvalidEnv {
            var: TRACE_VAR,
            value: v.to_string(),
            expected: "off|memory|chrome:<path>|<path>.json",
        }),
    }
}

/// Parse a metrics mode from a raw variable value. Pure — the testable
/// core of [`metrics`].
pub fn parse_metrics(raw: Option<&str>) -> Result<Option<MetricsMode>, EngineError> {
    match raw {
        None => Ok(None),
        Some("") => Ok(None),
        Some(v) => v.parse::<MetricsMode>().map(Some).map_err(|_| EngineError::InvalidEnv {
            var: METRICS_VAR,
            value: v.to_string(),
            expected: "off|memory|prom:<path>|qlog:<path>",
        }),
    }
}

/// Read `GKSELECT_EXEC_MODE` from the process environment.
pub fn exec_mode() -> Result<Option<ExecMode>, EngineError> {
    let raw = std::env::var(EXEC_MODE_VAR).ok();
    parse_exec_mode(raw.as_deref())
}

/// Read `GKSELECT_SIMD` from the process environment.
pub fn simd_policy() -> Result<Option<SimdPolicy>, EngineError> {
    let raw = std::env::var(SIMD_VAR).ok();
    parse_simd_policy(raw.as_deref())
}

/// Read `GKSELECT_FAULTS` from the process environment.
pub fn faults() -> Result<Option<FaultPlan>, EngineError> {
    let raw = std::env::var(FAULTS_VAR).ok();
    parse_faults(raw.as_deref())
}

/// Read `GKSELECT_TRACE` from the process environment.
pub fn trace() -> Result<Option<TraceMode>, EngineError> {
    let raw = std::env::var(TRACE_VAR).ok();
    parse_trace(raw.as_deref())
}

/// Read `GKSELECT_METRICS` from the process environment.
pub fn metrics() -> Result<Option<MetricsMode>, EngineError> {
    let raw = std::env::var(METRICS_VAR).ok();
    parse_metrics(raw.as_deref())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unset_and_empty_mean_none() {
        assert_eq!(parse_exec_mode(None).unwrap(), None);
        assert_eq!(parse_exec_mode(Some("")).unwrap(), None);
        assert_eq!(parse_simd_policy(None).unwrap(), None);
        assert_eq!(parse_simd_policy(Some("")).unwrap(), None);
        assert_eq!(parse_faults(None).unwrap(), None);
        assert_eq!(parse_faults(Some("")).unwrap(), None);
        assert_eq!(parse_trace(None).unwrap(), None);
        assert_eq!(parse_trace(Some("")).unwrap(), None);
        assert_eq!(parse_metrics(None).unwrap(), None);
        assert_eq!(parse_metrics(Some("")).unwrap(), None);
    }

    #[test]
    fn metrics_modes_parse_and_reject() {
        use std::path::PathBuf;
        assert_eq!(parse_metrics(Some("off")).unwrap(), Some(MetricsMode::Off));
        assert_eq!(
            parse_metrics(Some("memory")).unwrap(),
            Some(MetricsMode::Memory)
        );
        assert_eq!(
            parse_metrics(Some("prom:/tmp/m.prom")).unwrap(),
            Some(MetricsMode::Prom(PathBuf::from("/tmp/m.prom")))
        );
        assert_eq!(
            parse_metrics(Some("qlog:q.jsonl")).unwrap(),
            Some(MetricsMode::Qlog(PathBuf::from("q.jsonl")))
        );
        let err = parse_metrics(Some("statsd")).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains(METRICS_VAR), "{msg}");
        assert!(msg.contains("statsd"), "{msg}");
        assert!(msg.contains("prom:<path>"), "{msg}");
    }

    #[test]
    fn trace_modes_parse_and_reject() {
        use std::path::PathBuf;
        assert_eq!(parse_trace(Some("off")).unwrap(), Some(TraceMode::Off));
        assert_eq!(parse_trace(Some("memory")).unwrap(), Some(TraceMode::Memory));
        assert_eq!(
            parse_trace(Some("chrome:/tmp/t.json")).unwrap(),
            Some(TraceMode::Chrome(PathBuf::from("/tmp/t.json")))
        );
        assert_eq!(
            parse_trace(Some("trace.json")).unwrap(),
            Some(TraceMode::Chrome(PathBuf::from("trace.json")))
        );
        let err = parse_trace(Some("perfetto")).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains(TRACE_VAR), "{msg}");
        assert!(msg.contains("perfetto"), "{msg}");
        assert!(msg.contains("chrome:<path>"), "{msg}");
    }

    #[test]
    fn fault_plans_parse_and_reject() {
        let plan = parse_faults(Some("seed=7,panic=0.25,straggler=0.5x4"))
            .unwrap()
            .unwrap();
        assert_eq!(plan.seed, 7);
        assert_eq!(plan.panic_rate, 0.25);
        assert_eq!(plan.straggler_mult, 4.0);

        let err = parse_faults(Some("panic=lots")).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains(FAULTS_VAR), "{msg}");
        assert!(msg.contains("panic=lots"), "{msg}");
        assert!(msg.contains("seed=N"), "{msg}");
    }

    #[test]
    fn valid_values_parse() {
        assert_eq!(parse_exec_mode(Some("threads")).unwrap(), Some(ExecMode::Threads));
        assert_eq!(
            parse_exec_mode(Some("sequential")).unwrap(),
            Some(ExecMode::Sequential)
        );
        assert_eq!(
            parse_simd_policy(Some("scalar")).unwrap(),
            Some(SimdPolicy::ForceScalar)
        );
        assert_eq!(
            parse_simd_policy(Some("force")).unwrap(),
            Some(SimdPolicy::ForceSimd)
        );
        assert_eq!(parse_simd_policy(Some("auto")).unwrap(), Some(SimdPolicy::Auto));
    }

    #[test]
    fn garbage_is_a_typed_error_naming_the_variable() {
        let err = parse_exec_mode(Some("turbo")).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains(EXEC_MODE_VAR), "{msg}");
        assert!(msg.contains("turbo"), "{msg}");
        assert!(msg.contains("sequential|threads"), "{msg}");

        let err = parse_simd_policy(Some("warp")).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains(SIMD_VAR), "{msg}");
        assert!(msg.contains("auto|scalar|force"), "{msg}");
    }
}
