//! The serving front door: one engine, one builder, typed query plans,
//! one outcome type across batch and stream.
//!
//! Before this module the public API was a loose federation: each
//! algorithm exposed its own `quantile` method, multi-quantile and
//! pre-merged-sketch entry points lived outside the trait, the stream
//! layer was a third surface, and every consumer (CLI, harness, benches,
//! examples) re-derived the backend / SIMD / exec-mode wiring by hand.
//! [`QuantileEngine`] replaces all of that with a single call site:
//!
//! ```text
//!   EngineBuilder ──► QuantileEngine ──► execute(Source, QuantileQuery)
//!     (resolves          owns Cluster,        │
//!      builder >         KernelBackend,       ▼
//!      config file >     SketchStore)     QueryOutcome
//!      env, once)                         (values + per-query report,
//!                                          SIMD lane width stamped in
//!                                          exactly one place)
//! ```
//!
//! * [`Source::Dataset`] routes through the [`AlgoChoice`]-selected
//!   strategy (the reworked [`QuantileAlgorithm`] trait — stateless
//!   plan executors borrowing the engine's backend through
//!   [`EngineCtx`]).
//! * [`Source::Stream`] serves the query from the engine's
//!   [`SketchStore`] via the GK fused protocol — cached ingest-time
//!   sketches, one band-extract scan, exact — regardless of the batch
//!   strategy (the store is GK-shaped).
//! * Every failure at this boundary is a typed [`EngineError`], not a
//!   stringly `anyhow` chain.
//!
//! # Example
//!
//! ```
//! use gkselect::prelude::*;
//!
//! let mut engine = EngineBuilder::new()
//!     .cluster(ClusterConfig::local(2, 4))
//!     .algorithm(AlgoChoice::GkSelect)
//!     .build()
//!     .unwrap();
//! let data = Dataset::from_vec((0..1_000).collect(), 4).unwrap();
//!
//! // one entry point for every query shape
//! let median = engine.execute(Source::Dataset(&data), QuantileQuery::Single(0.5)).unwrap();
//! assert_eq!(median.value(), 500); // exact order statistic
//!
//! let tail = engine
//!     .execute(Source::Dataset(&data), QuantileQuery::Multi(vec![0.9, 0.99]))
//!     .unwrap();
//! assert_eq!(tail.values, vec![900, 990]);
//! ```

pub mod env;

use crate::algorithms::afs::{Afs, AfsParams};
use crate::algorithms::approx_quantile::{
    ApproxQuantile, ApproxQuantileParams, MergeStrategy, SketchVariant,
};
use crate::algorithms::full_sort::FullSortQuantile;
use crate::algorithms::gk_select::{GkSelectParams, GkSelectStrategy};
use crate::algorithms::histogram_select::{HistogramSelectParams, HistogramSelectStrategy};
use crate::algorithms::jeffers::{Jeffers, JeffersParams};
use crate::algorithms::multi_select::MultiOutcome;
use crate::algorithms::{Outcome, QuantileAlgorithm};
use crate::cluster::dataset::Dataset;
use crate::cluster::metrics::MetricsReport;
use crate::cluster::{Cluster, ClusterConfig, ExecMode, FaultPlan, RetryPolicy, StageError};
use crate::config::ReproConfig;
use crate::obs::registry::OpContext;
use crate::obs::{
    MetricsMode, MetricsRegistry, MetricsSnapshot, OpKind, SpanKind, Trace, TraceMode, TraceSink,
};
use crate::runtime::{backend_from_name, KernelBackend, SimdPolicy};
use crate::stream::store::StreamSnapshot;
use crate::stream::{CompactionPolicy, IngestOutcome, MicroBatch, SketchStore, StreamIngestor};
use crate::Key;

// ---------------------------------------------------------------------------
// Typed errors
// ---------------------------------------------------------------------------

/// Every way a query can fail at the engine boundary. Replaces the
/// stringly `anyhow` chains the old per-algorithm entry points returned.
#[derive(Debug, Clone, PartialEq)]
pub enum EngineError {
    /// The source dataset or stream holds no records.
    EmptyInput,
    /// A requested quantile fell outside `[0, 1]`.
    BadQuantile(f64),
    /// A requested rank `k` is out of range for an input of `n` records.
    BadRank { k: u64, n: u64 },
    /// A `Multi` query carried no quantiles.
    NoQuantiles,
    /// A sketch precision outside `(0, 1)`.
    BadEpsilon(f64),
    /// Candidate extraction overflowed its budget and the run could not
    /// resolve the target rank; `fallback_used` says whether the classic
    /// extraction round was attempted before giving up.
    BudgetOverflow { fallback_used: bool },
    /// The query addressed a stream id the store has never ingested.
    UnknownStream(String),
    /// The stream exists but holds no live records.
    DrainedStream(String),
    /// A `Sketched` stream query asked for a tighter ε than the cached
    /// ingest-time sketch can honor.
    SketchTooCoarse { requested: f64, available: f64 },
    /// A `map_partitions` stage exhausted its task retries (see
    /// [`crate::cluster::faults`]). Under [`DegradePolicy::SketchAnswer`]
    /// the engine converts this into a degraded sketch answer instead.
    StageFailed { stage: u64, attempts: u32 },
    /// An environment variable held an unparseable value.
    InvalidEnv {
        var: &'static str,
        value: String,
        expected: &'static str,
    },
    /// A builder or config knob failed validation.
    InvalidConfig(String),
    /// The kernel backend could not be constructed.
    Backend(String),
    /// An internal substrate failure (flattened error chain).
    Execution(String),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::EmptyInput => write!(f, "empty input: no records to query"),
            Self::BadQuantile(q) => write!(f, "quantile out of range: {q} (expected [0, 1])"),
            Self::BadRank { k, n } => {
                write!(f, "rank {k} out of range for {n} records (expected k < n)")
            }
            Self::NoQuantiles => write!(f, "no quantiles requested"),
            Self::BadEpsilon(e) => write!(f, "epsilon out of range: {e} (expected (0, 1))"),
            Self::BudgetOverflow { fallback_used } => write!(
                f,
                "candidate budget overflow left the target rank unresolved (fallback {})",
                if *fallback_used { "exhausted" } else { "not taken" }
            ),
            Self::UnknownStream(id) => write!(f, "unknown stream '{id}' (never ingested)"),
            Self::DrainedStream(id) => write!(f, "stream '{id}' is drained (no live records)"),
            Self::SketchTooCoarse {
                requested,
                available,
            } => write!(
                f,
                "sketched query wants eps={requested} but the cached sketch only \
                 offers eps={available}"
            ),
            Self::StageFailed { stage, attempts } => write!(
                f,
                "stage {stage} failed: a task died {attempts} times (retries exhausted)"
            ),
            Self::InvalidEnv {
                var,
                value,
                expected,
            } => write!(f, "{var}={value:?} is invalid (expected {expected})"),
            Self::InvalidConfig(msg) => write!(f, "invalid engine config: {msg}"),
            Self::Backend(msg) => write!(f, "kernel backend unavailable: {msg}"),
            Self::Execution(msg) => write!(f, "execution failed: {msg}"),
        }
    }
}

impl std::error::Error for EngineError {}

impl From<anyhow::Error> for EngineError {
    fn from(e: anyhow::Error) -> Self {
        // a StageFailed that crossed an anyhow boundary (the sketch
        // builder, stream ingest) stays typed rather than stringly
        match e.downcast::<StageError>() {
            Ok(se) => se.into(),
            Err(e) => EngineError::Execution(format!("{e:#}")),
        }
    }
}

impl From<StageError> for EngineError {
    fn from(e: StageError) -> Self {
        EngineError::StageFailed {
            stage: e.stage,
            attempts: e.attempts,
        }
    }
}

/// What `execute` does when a stage exhausts its retries.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DegradePolicy {
    /// Surface the typed [`EngineError::StageFailed`] (default).
    #[default]
    Fail,
    /// Serve the query from the GK sketch instead — the cached merged
    /// sketch for streams, a freshly built one for datasets — with the
    /// [`QueryOutcome`] explicitly marked degraded (ε-approximate, never
    /// silently wrong).
    SketchAnswer,
}

impl std::str::FromStr for DegradePolicy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s {
            "fail" => Ok(Self::Fail),
            "sketch" | "sketch-answer" => Ok(Self::SketchAnswer),
            other => anyhow::bail!("unknown degrade policy '{other}' (fail|sketch)"),
        }
    }
}

impl std::fmt::Display for DegradePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Self::Fail => "fail",
            Self::SketchAnswer => "sketch",
        })
    }
}

// ---------------------------------------------------------------------------
// Query plans, sources, outcomes
// ---------------------------------------------------------------------------

/// A typed query plan — what to compute, decoupled from how and from
/// where the records live.
///
/// ```
/// use gkselect::prelude::*;
///
/// let mut engine = EngineBuilder::new()
///     .cluster(ClusterConfig::local(1, 2))
///     .build()
///     .unwrap();
/// let data = Dataset::from_vec((0..100).collect(), 2).unwrap();
///
/// // Rank(k) and Single(q) agree at k = target_rank(n, q)
/// let by_q = engine.execute(Source::Dataset(&data), QuantileQuery::Single(0.25)).unwrap();
/// let k = gkselect::target_rank(100, 0.25);
/// let by_k = engine.execute(Source::Dataset(&data), QuantileQuery::Rank(k)).unwrap();
/// assert_eq!(by_q.value(), by_k.value());
///
/// // a malformed plan is a typed error, not a panic
/// let err = engine
///     .execute(Source::Dataset(&data), QuantileQuery::Single(1.5))
///     .unwrap_err();
/// assert_eq!(err, EngineError::BadQuantile(1.5));
/// ```
#[derive(Debug, Clone, PartialEq)]
pub enum QuantileQuery {
    /// One exact quantile `q ∈ [0, 1]`.
    Single(f64),
    /// A batch of exact quantiles sharing one scan where the strategy
    /// supports it (GK Select's fused multi-band protocol).
    Multi(Vec<f64>),
    /// The exact `k`-th order statistic (0-based, `k < n`).
    Rank(u64),
    /// An ε-approximate quantile from a GK sketch built (batch) or
    /// cached (stream) at the requested precision. Always served by the
    /// Spark-default sketch path regardless of the engine's strategy.
    Sketched { q: f64, eps: f64 },
}

impl QuantileQuery {
    /// Validate the plan against an input of `n` records.
    pub fn validate(&self, n: u64) -> Result<(), EngineError> {
        fn check_q(q: f64) -> Result<(), EngineError> {
            if (0.0..=1.0).contains(&q) {
                Ok(())
            } else {
                Err(EngineError::BadQuantile(q))
            }
        }
        match self {
            Self::Single(q) => check_q(*q),
            Self::Multi(qs) => {
                if qs.is_empty() {
                    return Err(EngineError::NoQuantiles);
                }
                qs.iter().try_for_each(|&q| check_q(q))
            }
            Self::Rank(k) => {
                if *k < n {
                    Ok(())
                } else {
                    Err(EngineError::BadRank { k: *k, n })
                }
            }
            Self::Sketched { q, eps } => {
                check_q(*q)?;
                if *eps > 0.0 && *eps < 1.0 {
                    Ok(())
                } else {
                    Err(EngineError::BadEpsilon(*eps))
                }
            }
        }
    }

    /// Expand a validated plan to the quantiles it answers, in output
    /// order — the positions of [`QueryOutcome::values`]. `Rank(k)`
    /// plans need the input size `n` for the rank→quantile mapping.
    pub fn quantiles(&self, n: u64) -> Vec<f64> {
        match self {
            Self::Single(q) | Self::Sketched { q, .. } => vec![*q],
            Self::Multi(qs) => qs.clone(),
            Self::Rank(k) => vec![rank_to_quantile(*k, n)],
        }
    }

    /// Short plan-shape label for trace root spans and logs.
    pub fn label(&self) -> &'static str {
        match self {
            Self::Single(_) => "single",
            Self::Multi(_) => "multi",
            Self::Rank(_) => "rank",
            Self::Sketched { .. } => "sketched",
        }
    }
}

/// A quantile `q` whose [`crate::target_rank`] is exactly `k` — how
/// `Rank(k)` plans reuse the quantile-shaped strategy internals.
/// The half-offset keeps `⌊q·n⌋ = k` bit-exact for every `n < 2^52`
/// (verified exhaustively for small n and by sweep up to that bound) —
/// f64 rank spacing only breaks the roundtrip past ~4.5e15 records,
/// orders of magnitude beyond what a [`Dataset`] of 4-byte keys can
/// hold.
///
/// # Panics
///
/// Panics if `k >= n`. Engine plans never reach this — `Rank(k)` is
/// validated into a typed [`EngineError::BadRank`] first — so the check
/// only guards direct callers of this helper.
pub fn rank_to_quantile(k: u64, n: u64) -> f64 {
    assert!(k < n, "rank {k} out of range for n={n}");
    debug_assert!(n < (1 << 52), "rank/quantile roundtrip needs n < 2^52");
    (k as f64 + 0.5) / n as f64
}

/// Where the records live: a materialized dataset, or a live stream in
/// the engine's sketch store.
#[derive(Debug, Clone, Copy)]
pub enum Source<'a> {
    /// A partitioned in-memory dataset (the batch path).
    Dataset(&'a Dataset<Key>),
    /// A stream previously fed through [`QuantileEngine::ingest`],
    /// addressed by id (the serving path: cached sketches, one scan).
    Stream(&'a str),
}

/// The one result type every query produces: the answer values (one per
/// requested quantile, in request order) plus the per-query measured
/// report. The engine stamps the backend's SIMD lane width onto the
/// report in exactly one place ([`QuantileEngine::execute`]), so no exit
/// path can mislabel the dispatch.
#[derive(Debug, Clone)]
pub struct QueryOutcome {
    /// Exact (or, for `Sketched`, ε-approximate) values, one per
    /// requested quantile / rank, in request order.
    pub values: Vec<Key>,
    /// The measured cost of exactly this query.
    pub report: MetricsReport,
    /// True when a stage failure forced the engine to answer from the
    /// sketch under [`DegradePolicy::SketchAnswer`]: the values are
    /// ε-approximate, the report says `exact: false`, and the caller is
    /// told so explicitly rather than discovering it from a wrong exact
    /// value.
    pub degraded: bool,
    /// The span tree of exactly this query, present when the engine was
    /// built with a span-collecting sink ([`TraceMode::Memory`] or
    /// [`TraceMode::Chrome`]); `None` under the default
    /// [`TraceSink::Null`], which leaves the rest of the outcome
    /// byte-identical to a tracing-disabled run.
    pub trace: Option<Trace>,
}

impl QueryOutcome {
    /// The first (for single-value plans: the only) answer.
    pub fn value(&self) -> Key {
        self.values[0]
    }

    /// The query's span tree, when the engine collects one.
    pub fn trace(&self) -> Option<&Trace> {
        self.trace.as_ref()
    }

    /// How the engine-lifetime [`MetricsRegistry`] classifies this
    /// outcome — derived from the report's algorithm name, exactness,
    /// and the degraded flag through the same [`OpKind::classify`] the
    /// engine's absorb hook uses, so an outcome always lands in the
    /// registry row its own accessor names.
    pub fn op_kind(&self) -> OpKind {
        OpKind::classify(&self.report.algorithm, self.report.exact, self.degraded)
    }
}

impl From<Outcome> for QueryOutcome {
    fn from(o: Outcome) -> Self {
        Self {
            values: vec![o.value],
            report: o.report,
            degraded: false,
            trace: None,
        }
    }
}

impl From<MultiOutcome> for QueryOutcome {
    fn from(o: MultiOutcome) -> Self {
        Self {
            values: o.values,
            report: o.report,
            degraded: false,
            trace: None,
        }
    }
}

/// What a strategy sees while executing a plan: the engine's cluster,
/// its kernel backend, and the source dataset. Strategies are stateless
/// — everything environmental comes through here.
pub struct EngineCtx<'a> {
    pub cluster: &'a mut Cluster,
    pub backend: &'a dyn KernelBackend,
    pub data: &'a Dataset<Key>,
}

// ---------------------------------------------------------------------------
// Algorithm choice
// ---------------------------------------------------------------------------

/// Which strategy answers `Source::Dataset` plans. (Stream plans are
/// always served by the GK fused protocol — the sketch store caches GK
/// partials.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AlgoChoice {
    GkSelect,
    Afs,
    Jeffers,
    FullSort,
    GkSketch,
    HistSelect,
}

impl std::str::FromStr for AlgoChoice {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<Self> {
        match s {
            "gk-select" | "gkselect" => Ok(Self::GkSelect),
            "afs" => Ok(Self::Afs),
            "jeffers" => Ok(Self::Jeffers),
            "full-sort" | "fullsort" | "sort" => Ok(Self::FullSort),
            "gk-sketch" | "gksketch" | "approx" => Ok(Self::GkSketch),
            "hist-select" | "histselect" | "hist" => Ok(Self::HistSelect),
            other => anyhow::bail!(
                "unknown algorithm '{other}' (gk-select|afs|jeffers|full-sort|gk-sketch|hist-select)"
            ),
        }
    }
}

impl AlgoChoice {
    pub const ALL: [AlgoChoice; 6] = [
        AlgoChoice::GkSelect,
        AlgoChoice::Afs,
        AlgoChoice::Jeffers,
        AlgoChoice::FullSort,
        AlgoChoice::GkSketch,
        AlgoChoice::HistSelect,
    ];

    /// The paper's comparison set (Figs. 1–2).
    pub const PAPER_SET: [AlgoChoice; 5] = [
        AlgoChoice::FullSort,
        AlgoChoice::Afs,
        AlgoChoice::Jeffers,
        AlgoChoice::GkSketch,
        AlgoChoice::GkSelect,
    ];

    pub fn label(self) -> &'static str {
        match self {
            AlgoChoice::GkSelect => "GK Select",
            AlgoChoice::Afs => "AFS",
            AlgoChoice::Jeffers => "Jeffers",
            AlgoChoice::FullSort => "Full Sort",
            AlgoChoice::GkSketch => "GK Sketch",
            AlgoChoice::HistSelect => "Hist Select",
        }
    }
}

// ---------------------------------------------------------------------------
// Builder
// ---------------------------------------------------------------------------

/// Builds a [`QuantileEngine`], resolving every knob with one documented
/// precedence: **builder setter > config file ([`ReproConfig`]) > env
/// var (`GKSELECT_EXEC_MODE` / `GKSELECT_SIMD`) > default**.
///
/// ```
/// use gkselect::prelude::*;
///
/// // defaults: native backend, GK Select, ε = 0.01, 10-node cluster
/// let engine = EngineBuilder::new().build().unwrap();
/// assert_eq!(engine.algorithm(), AlgoChoice::GkSelect);
/// assert_eq!(engine.cluster().cfg.partitions, 40);
///
/// // builder setters win over everything
/// let engine = EngineBuilder::new()
///     .cluster(ClusterConfig::local(2, 8))
///     .algorithm(AlgoChoice::FullSort)
///     .epsilon(0.02)
///     .simd(SimdPolicy::ForceScalar)
///     .build()
///     .unwrap();
/// assert_eq!(engine.simd_lane_width(), 1);
/// ```
#[derive(Default)]
pub struct EngineBuilder {
    config: Option<ReproConfig>,
    cluster: Option<ClusterConfig>,
    nodes: Option<usize>,
    exec_mode: Option<ExecMode>,
    simd: Option<SimdPolicy>,
    backend_name: Option<String>,
    backend: Option<Box<dyn KernelBackend>>,
    algorithm: Option<AlgoChoice>,
    epsilon: Option<f64>,
    variant: Option<SketchVariant>,
    merge: Option<MergeStrategy>,
    tree_depth: Option<usize>,
    candidate_budget: Option<usize>,
    seed: Option<u64>,
    compaction: Option<CompactionPolicy>,
    faults: Option<FaultPlan>,
    retry: Option<RetryPolicy>,
    degrade: Option<DegradePolicy>,
    trace: Option<TraceMode>,
    metrics: Option<MetricsMode>,
}

impl EngineBuilder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Supply the config-file layer of the precedence (usually a parsed
    /// `repro.toml`). Builder setters still win over it.
    pub fn config(mut self, cfg: ReproConfig) -> Self {
        self.config = Some(cfg);
        self
    }

    /// Take the full cluster shape as given (tests, bench pins). The
    /// file and env exec-mode layers are not consulted for the shape —
    /// an explicit shape wins, with [`Self::exec_mode`] still overriding
    /// on top. Note that `build` still *parses* `GKSELECT_EXEC_MODE`
    /// and the config's `exec_mode` first, so an unparseable value is a
    /// loud [`EngineError::InvalidEnv`] / [`EngineError::InvalidConfig`]
    /// rather than something an explicit shape can silently mask.
    pub fn cluster(mut self, cc: ClusterConfig) -> Self {
        self.cluster = Some(cc);
        self
    }

    /// Override the core-node count (partitions follow the config's
    /// partitions-per-node).
    pub fn nodes(mut self, nodes: usize) -> Self {
        self.nodes = Some(nodes);
        self
    }

    pub fn exec_mode(mut self, mode: ExecMode) -> Self {
        self.exec_mode = Some(mode);
        self
    }

    pub fn simd(mut self, policy: SimdPolicy) -> Self {
        self.simd = Some(policy);
        self
    }

    /// Select the kernel backend by name (`"native"` | `"pjrt"`).
    pub fn backend_name(mut self, name: &str) -> Self {
        self.backend_name = Some(name.to_string());
        self
    }

    /// Inject a ready-made kernel backend (tests pinning a dispatch, a
    /// pre-loaded PJRT runtime). Wins over [`Self::backend_name`], and
    /// carries its own already-resolved SIMD dispatch — the file/env
    /// SIMD layers don't apply to it, and combining it with an explicit
    /// [`Self::simd`] call is rejected at `build` time so a forced
    /// policy can never be silently ignored.
    pub fn kernel_backend(mut self, backend: Box<dyn KernelBackend>) -> Self {
        self.backend = Some(backend);
        self
    }

    pub fn algorithm(mut self, choice: AlgoChoice) -> Self {
        self.algorithm = Some(choice);
        self
    }

    pub fn epsilon(mut self, epsilon: f64) -> Self {
        self.epsilon = Some(epsilon);
        self
    }

    pub fn sketch_variant(mut self, variant: SketchVariant) -> Self {
        self.variant = Some(variant);
        self
    }

    pub fn sketch_merge(mut self, merge: MergeStrategy) -> Self {
        self.merge = Some(merge);
        self
    }

    pub fn tree_depth(mut self, depth: usize) -> Self {
        self.tree_depth = Some(depth);
        self
    }

    /// Cap extracted open-band candidates (GK Select); `0` forces the
    /// classic 3-round fallback, the bench baseline shape.
    pub fn candidate_budget(mut self, budget: usize) -> Self {
        self.candidate_budget = Some(budget);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    /// Stream-store compaction policy for [`QuantileEngine::ingest`].
    pub fn compaction(mut self, policy: CompactionPolicy) -> Self {
        self.compaction = Some(policy);
        self
    }

    /// Inject a seeded fault plan (chaos runs, robustness tests). Wins
    /// over the `[faults]` config section and `GKSELECT_FAULTS`.
    pub fn fault_plan(mut self, plan: FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Override the task retry / speculation policy.
    pub fn retry_policy(mut self, retry: RetryPolicy) -> Self {
        self.retry = Some(retry);
        self
    }

    /// What `execute` does when a stage exhausts its retries: fail typed
    /// (default) or degrade to a sketch answer.
    pub fn degrade_policy(mut self, policy: DegradePolicy) -> Self {
        self.degrade = Some(policy);
        self
    }

    /// Select the trace sink: where per-query span trees go. Wins over
    /// the `[obs]` config section and `GKSELECT_TRACE`; the default
    /// ([`TraceMode::Off`]) keeps the tracer disarmed so queries pay
    /// nothing.
    pub fn trace(mut self, mode: TraceMode) -> Self {
        self.trace = Some(mode);
        self
    }

    /// Select the engine-lifetime metrics mode: whether every
    /// `execute`/`ingest` report is absorbed into the cumulative
    /// [`MetricsRegistry`], and where its exports go. Wins over the
    /// `[obs]` config section and `GKSELECT_METRICS`; the default
    /// ([`MetricsMode::Off`]) keeps the registry inert so operations pay
    /// nothing.
    pub fn metrics(mut self, mode: MetricsMode) -> Self {
        self.metrics = Some(mode);
        self
    }

    pub fn build(self) -> Result<QuantileEngine, EngineError> {
        let env_exec = env::exec_mode()?;
        let env_simd = env::simd_policy()?;
        let env_faults = env::faults()?;
        let env_trace = env::trace()?;
        let env_metrics = env::metrics()?;
        self.build_resolved(env_exec, env_simd, env_faults, env_trace, env_metrics)
    }

    /// [`Self::build`] with the env layer injected — the pure core the
    /// precedence tests drive without touching process state.
    fn build_resolved(
        self,
        env_exec: Option<ExecMode>,
        env_simd: Option<SimdPolicy>,
        env_faults: Option<FaultPlan>,
        env_trace: Option<TraceMode>,
        env_metrics: Option<MetricsMode>,
    ) -> Result<QuantileEngine, EngineError> {
        let cfg = self.config.unwrap_or_default();

        let simd = resolve_simd(self.simd, &cfg.runtime.simd, env_simd)?;
        let exec = resolve_exec_mode(self.exec_mode, &cfg.cluster.exec_mode, env_exec)?;
        let faults = resolve_faults(self.faults.clone(), &cfg.faults.plan, env_faults)?;
        let trace = resolve_trace(self.trace.clone(), &cfg.obs.trace, env_trace)?;
        let metrics = resolve_metrics(self.metrics.clone(), &cfg.obs.metrics, env_metrics)?;
        let retry = self.retry.unwrap_or_else(|| cfg.faults.to_retry_policy());
        let degrade = match self.degrade {
            Some(d) => d,
            None => {
                if cfg.faults.degrade.is_empty() {
                    DegradePolicy::Fail
                } else {
                    cfg.faults.degrade.parse::<DegradePolicy>().map_err(|e| {
                        EngineError::InvalidConfig(format!("[faults] degrade: {e:#}"))
                    })?
                }
            }
        };

        let cc = if let Some(mut cc) = self.cluster {
            if let Some(mode) = self.exec_mode {
                cc.exec_mode = mode;
            }
            // an explicit shape keeps its own fault wiring (it read the
            // env itself); explicit builder knobs still win on top
            if let Some(plan) = self.faults {
                cc.faults = Some(plan);
            }
            if let Some(r) = self.retry {
                cc.retry = r;
            }
            cc
        } else {
            let nodes = self.nodes.unwrap_or(cfg.cluster.nodes);
            ClusterConfig {
                executors: nodes,
                partitions: nodes * cfg.cluster.partitions_per_node,
                net: cfg.network.to_model(),
                compute_scale: cfg.cluster.compute_scale,
                driver_scale: cfg.cluster.driver_scale,
                exec_mode: exec.unwrap_or(ExecMode::Sequential),
                faults,
                retry,
            }
        };

        let epsilon = self.epsilon.unwrap_or(cfg.algorithm.epsilon);
        if !(epsilon > 0.0 && epsilon < 1.0) {
            return Err(EngineError::BadEpsilon(epsilon));
        }
        let variant = match self.variant {
            Some(v) => v,
            None => cfg
                .algorithm
                .sketch
                .parse::<SketchVariant>()
                .map_err(|e| EngineError::InvalidConfig(format!("{e:#}")))?,
        };
        let merge = match self.merge {
            Some(m) => m,
            None => cfg
                .algorithm
                .sketch_merge
                .parse::<MergeStrategy>()
                .map_err(|e| EngineError::InvalidConfig(format!("{e:#}")))?,
        };
        let tree_depth = self.tree_depth.or(cfg.algorithm.tree_depth);
        let seed = self.seed.unwrap_or(cfg.algorithm.seed);
        let gk_params = GkSelectParams {
            epsilon,
            variant,
            merge,
            tree_depth,
            candidate_budget: self.candidate_budget,
        };

        let choice = self.algorithm.unwrap_or(AlgoChoice::GkSelect);
        let strategy: Box<dyn QuantileAlgorithm> = match choice {
            AlgoChoice::GkSelect => Box::new(GkSelectStrategy {
                params: gk_params.clone(),
            }),
            AlgoChoice::Afs => Box::new(Afs::new(AfsParams {
                seed,
                tree_depth,
                ..Default::default()
            })),
            AlgoChoice::Jeffers => Box::new(Jeffers::new(JeffersParams {
                seed,
                ..Default::default()
            })),
            AlgoChoice::FullSort => Box::new(FullSortQuantile::default()),
            AlgoChoice::GkSketch => Box::new(ApproxQuantile::new(ApproxQuantileParams {
                epsilon,
                variant: SketchVariant::Spark,
                merge: MergeStrategy::Fold,
            })),
            AlgoChoice::HistSelect => Box::new(HistogramSelectStrategy {
                params: HistogramSelectParams {
                    seed,
                    ..Default::default()
                },
            }),
        };

        let backend = match self.backend {
            Some(b) => {
                // an injected backend was constructed with its own
                // dispatch policy; silently ignoring an explicit simd()
                // would be the dispatch-mislabel footgun all over again
                if self.simd.is_some() {
                    return Err(EngineError::InvalidConfig(
                        "kernel_backend() and simd() are mutually exclusive: the \
                         injected backend already carries its own dispatch policy"
                            .to_string(),
                    ));
                }
                b
            }
            None => {
                let name = self.backend_name.unwrap_or_else(|| cfg.backend.clone());
                backend_from_name(&name, &cfg.artifacts_dir, simd)
                    .map_err(|e| EngineError::Backend(format!("{e:#}")))?
            }
        };

        let policy = match self.compaction {
            Some(p) => {
                p.validate()
                    .map_err(|e| EngineError::InvalidConfig(format!("{e:#}")))?;
                p
            }
            None => cfg
                .stream
                .to_policy()
                .map_err(|e| EngineError::InvalidConfig(format!("{e:#}")))?,
        };
        let store =
            SketchStore::new(policy).map_err(|e| EngineError::InvalidConfig(format!("{e:#}")))?;
        let ingestor = StreamIngestor::new(epsilon)
            .map_err(|e| EngineError::InvalidConfig(format!("{e:#}")))?
            .with_variant(variant);

        let sink = TraceSink::from_mode(trace);
        let mut cluster = Cluster::new(cc);
        cluster.tracer.set_enabled(sink.wants_spans());
        let registry = MetricsRegistry::new(
            metrics,
            cluster.cfg.exec_mode.label(),
            backend.simd_lane_width() as u64,
        );

        Ok(QuantileEngine {
            choice,
            strategy,
            cluster,
            backend,
            store,
            ingestor,
            gk_params,
            degrade,
            sink,
            trace_seq: 0,
            registry,
        })
    }
}

/// Builder > config file > env for the fault plan; `None` (no injector)
/// when nothing speaks.
fn resolve_faults(
    builder: Option<FaultPlan>,
    file: &str,
    env: Option<FaultPlan>,
) -> Result<Option<FaultPlan>, EngineError> {
    if let Some(p) = builder {
        return Ok(Some(p));
    }
    if !file.is_empty() {
        return file
            .parse::<FaultPlan>()
            .map(Some)
            .map_err(|e| EngineError::InvalidConfig(format!("[faults] plan: {e}")));
    }
    Ok(env)
}

/// Builder > config file > env for the SIMD policy; `Auto` when nothing
/// speaks.
fn resolve_simd(
    builder: Option<SimdPolicy>,
    file: &str,
    env: Option<SimdPolicy>,
) -> Result<SimdPolicy, EngineError> {
    if let Some(p) = builder {
        return Ok(p);
    }
    if !file.is_empty() {
        return file
            .parse::<SimdPolicy>()
            .map_err(|e| EngineError::InvalidConfig(format!("[runtime] simd: {e:#}")));
    }
    Ok(env.unwrap_or(SimdPolicy::Auto))
}

/// Builder > config file > env for the trace sink; `Off` when nothing
/// speaks.
fn resolve_trace(
    builder: Option<TraceMode>,
    file: &str,
    env: Option<TraceMode>,
) -> Result<TraceMode, EngineError> {
    if let Some(m) = builder {
        return Ok(m);
    }
    if !file.is_empty() {
        return file
            .parse::<TraceMode>()
            .map_err(|e| EngineError::InvalidConfig(format!("[obs] trace: {e:#}")));
    }
    Ok(env.unwrap_or(TraceMode::Off))
}

/// Builder > config file > env for the metrics mode; `Off` when nothing
/// speaks.
fn resolve_metrics(
    builder: Option<MetricsMode>,
    file: &str,
    env: Option<MetricsMode>,
) -> Result<MetricsMode, EngineError> {
    if let Some(m) = builder {
        return Ok(m);
    }
    if !file.is_empty() {
        return file
            .parse::<MetricsMode>()
            .map_err(|e| EngineError::InvalidConfig(format!("[obs] metrics: {e:#}")));
    }
    Ok(env.unwrap_or(MetricsMode::Off))
}

/// Builder > config file > env for the exec mode; `None` when nothing
/// speaks (the caller's cluster default applies).
fn resolve_exec_mode(
    builder: Option<ExecMode>,
    file: &str,
    env: Option<ExecMode>,
) -> Result<Option<ExecMode>, EngineError> {
    if let Some(m) = builder {
        return Ok(Some(m));
    }
    if !file.is_empty() {
        return file
            .parse::<ExecMode>()
            .map(Some)
            .map_err(|e| EngineError::InvalidConfig(format!("[cluster] exec_mode: {e:#}")));
    }
    Ok(env)
}

// ---------------------------------------------------------------------------
// The engine
// ---------------------------------------------------------------------------

/// The unified quantile-serving façade: owns the execution substrate
/// ([`Cluster`]), the kernel backend, and the stream [`SketchStore`];
/// answers typed [`QuantileQuery`] plans over datasets and streams
/// through one [`Self::execute`] entry point.
pub struct QuantileEngine {
    choice: AlgoChoice,
    strategy: Box<dyn QuantileAlgorithm>,
    cluster: Cluster,
    backend: Box<dyn KernelBackend>,
    store: SketchStore,
    ingestor: StreamIngestor,
    gk_params: GkSelectParams,
    degrade: DegradePolicy,
    /// Where finished span trees go (`Null` unless tracing was enabled).
    sink: TraceSink,
    /// Monotone id stamped onto each root span's `trace` attribute.
    trace_seq: u64,
    /// Engine-lifetime metric totals (inert under [`MetricsMode::Off`]).
    registry: MetricsRegistry,
}

impl QuantileEngine {
    pub fn builder() -> EngineBuilder {
        EngineBuilder::new()
    }

    /// Answer one query plan. Batch sources route through the configured
    /// strategy; stream sources are served from cached ingest-time
    /// sketches by the GK fused protocol. The outcome's report carries
    /// the backend's SIMD lane width, stamped here and only here.
    ///
    /// A stage that exhausts its retries surfaces as a typed
    /// [`EngineError::StageFailed`] — or, under
    /// [`DegradePolicy::SketchAnswer`], is answered from the GK sketch
    /// with the outcome explicitly marked [`QueryOutcome::degraded`].
    /// Either way a faulted query never panics and never returns a
    /// silently wrong exact value.
    pub fn execute(
        &mut self,
        source: Source<'_>,
        query: QuantileQuery,
    ) -> Result<QueryOutcome, EngineError> {
        // re-arm every call: callers can swap the cluster wholesale
        // through cluster_mut(), and a fresh Cluster starts disarmed
        self.cluster.tracer.set_enabled(self.sink.wants_spans());
        self.trace_seq += 1;
        let kind = match source {
            Source::Dataset(_) => SpanKind::Query,
            Source::Stream(_) => SpanKind::StreamQuery,
        };
        let now = self.cluster.clock.elapsed_secs();
        let root = self
            .cluster
            .tracer
            .open(kind, format!("query {}", self.trace_seq), now);
        self.cluster.tracer.attr(root, "trace", self.trace_seq);
        self.cluster.tracer.attr(root, "plan", query.label());
        let source_label = match source {
            Source::Dataset(_) => "dataset".to_string(),
            Source::Stream(id) => format!("stream:{id}"),
        };
        self.cluster.tracer.attr(root, "source", source_label);
        self.cluster.tracer.attr(root, "algorithm", self.choice.label());
        self.cluster.tracer.attr(root, "epsilon", self.gk_params.epsilon);
        self.cluster.tracer.attr(root, "backend", self.backend.name());
        self.cluster
            .tracer
            .attr(root, "simd_lane_width", self.backend.simd_lane_width());

        let result = match self.execute_exact(source, &query) {
            Err(EngineError::StageFailed { .. })
                if self.degrade == DegradePolicy::SketchAnswer =>
            {
                match self.degraded_answer(source, &query) {
                    Ok(mut out) => {
                        out.degraded = true;
                        out.report.exact = false;
                        out.report.degraded_queries += 1;
                        self.cluster.metrics.degraded_queries += 1;
                        self.cluster.tracer.attr(root, "degraded", true);
                        Ok(out)
                    }
                    Err(e) => Err(e),
                }
            }
            other => other,
        };
        self.cluster.tracer.close(root, self.cluster.clock.elapsed_secs());
        match result {
            Ok(mut out) => {
                // THE stamping point: every outcome says which band-scan
                // dispatch the engine's backend runs, no per-exit-path
                // stamping to forget (the old make_report /
                // make_backend_report footgun).
                out.report.simd_lane_width = self.backend.simd_lane_width() as u64;
                out.trace = self
                    .sink
                    .drain(&mut self.cluster.tracer)
                    .map_err(EngineError::from)?;
                let ctx = OpContext {
                    kind: out.op_kind(),
                    stream: match source {
                        Source::Stream(id) => Some(id),
                        Source::Dataset(_) => None,
                    },
                    plan: query.label(),
                    // the qlog join key: present exactly when a span
                    // tree with the matching root attr was collected
                    trace: self.sink.wants_spans().then_some(self.trace_seq),
                };
                self.registry
                    .absorb(&ctx, &out.report, &self.store)
                    .map_err(EngineError::from)?;
                Ok(out)
            }
            Err(e) => {
                // a failed query leaves no spans behind — they would
                // otherwise leak into the next query's tree
                let _ = self.cluster.tracer.take();
                Err(e)
            }
        }
    }

    /// The fault-free query path `execute` wraps.
    fn execute_exact(
        &mut self,
        source: Source<'_>,
        query: &QuantileQuery,
    ) -> Result<QueryOutcome, EngineError> {
        match source {
            Source::Dataset(data) => {
                let strategy = &*self.strategy;
                let mut ctx = EngineCtx {
                    cluster: &mut self.cluster,
                    backend: self.backend.as_ref(),
                    data,
                };
                strategy.execute_plan(&mut ctx, query)
            }
            Source::Stream(id) => self.execute_stream(id, query),
        }
    }

    /// Serve a plan from the GK sketch after a stage failure: the cached
    /// merged sketch for streams (zero further scans — immune to the
    /// injected faults that killed the exact path), a freshly built one
    /// at the engine's ε for datasets. The sketch build itself runs
    /// under the same fault model, so its failure is still a typed
    /// error, never a panic.
    fn degraded_answer(
        &mut self,
        source: Source<'_>,
        query: &QuantileQuery,
    ) -> Result<QueryOutcome, EngineError> {
        let eps = self.gk_params.epsilon;
        let n = match source {
            Source::Dataset(data) => {
                if data.is_empty() {
                    return Err(EngineError::EmptyInput);
                }
                data.len()
            }
            Source::Stream(id) => {
                let state = self
                    .store
                    .stream(id)
                    .ok_or_else(|| EngineError::UnknownStream(id.to_string()))?;
                state.total_count()
            }
        };
        query.validate(n)?;
        let qs = query.quantiles(n);
        let mut agg: Option<QueryOutcome> = None;
        for q in qs {
            let out: QueryOutcome = match source {
                Source::Stream(id) => crate::stream::query::sketched_with(
                    &mut self.cluster,
                    &self.store,
                    id,
                    q,
                    eps,
                )?
                .into(),
                Source::Dataset(data) => {
                    let params = ApproxQuantileParams {
                        epsilon: eps,
                        variant: SketchVariant::Spark,
                        merge: MergeStrategy::Fold,
                    };
                    crate::algorithms::approx_quantile::sketch_quantile_with(
                        &mut self.cluster,
                        data,
                        &params,
                        q,
                    )?
                    .into()
                }
            };
            match &mut agg {
                None => agg = Some(out),
                Some(acc) => {
                    acc.values.extend_from_slice(&out.values);
                    acc.report.absorb(&out.report);
                }
            }
        }
        Ok(agg.expect("validated plans carry at least one quantile"))
    }

    fn execute_stream(
        &mut self,
        id: &str,
        query: &QuantileQuery,
    ) -> Result<QueryOutcome, EngineError> {
        let snap = self
            .store
            .stream(id)
            .ok_or_else(|| EngineError::UnknownStream(id.to_string()))?
            .snapshot();
        snapshot_plan(
            &mut self.cluster,
            self.backend.as_ref(),
            &self.gk_params,
            &snap,
            id,
            query,
        )
    }

    /// Answer `query` over an explicitly pinned [`StreamSnapshot`]
    /// without touching the engine's own cluster, store, tracer, or
    /// registry — the `&self` read path concurrent callers build on
    /// (the serving layer runs many of these in parallel against one
    /// engine configuration while a writer keeps ingesting). The caller
    /// supplies the scratch `cluster` the fused scan runs on; the
    /// answer is bit-identical to `execute(Source::Stream(id), query)`
    /// over the same snapshot because both run the same plan body. The
    /// outcome's report carries the backend's SIMD lane width, like
    /// every [`Self::execute`] outcome.
    pub fn query_snapshot(
        &self,
        cluster: &mut Cluster,
        snap: &StreamSnapshot,
        stream: &str,
        query: &QuantileQuery,
    ) -> Result<QueryOutcome, EngineError> {
        let mut out = snapshot_plan(
            cluster,
            self.backend.as_ref(),
            &self.gk_params,
            snap,
            stream,
            query,
        )?;
        out.report.simd_lane_width = self.backend.simd_lane_width() as u64;
        Ok(out)
    }

    /// Seal one micro-batch into `stream`'s epoch store (the streaming
    /// append path: one round, one scan over the new records only).
    pub fn ingest(
        &mut self,
        stream: &str,
        batch: MicroBatch,
    ) -> Result<IngestOutcome, EngineError> {
        // see execute(): re-arm in case the cluster was swapped
        self.cluster.tracer.set_enabled(self.sink.wants_spans());
        self.trace_seq += 1;
        match self
            .ingestor
            .ingest(&mut self.cluster, &mut self.store, stream, batch)
        {
            Ok(mut out) => {
                // stamp the qlog join id onto the ingest root before the
                // drain: the tracer is empty at every operation start
                // (drained or cleared by the previous one), and the
                // ingestor opens its root first, so the root is span 1;
                // with the tracer disarmed this is a no-op
                self.cluster.tracer.attr(1, "trace", self.trace_seq);
                out.trace = self
                    .sink
                    .drain(&mut self.cluster.tracer)
                    .map_err(EngineError::from)?;
                let ctx = OpContext {
                    kind: OpKind::Ingest,
                    stream: Some(stream),
                    plan: "ingest",
                    trace: self.sink.wants_spans().then_some(self.trace_seq),
                };
                self.registry
                    .absorb(&ctx, &out.report, &self.store)
                    .map_err(EngineError::from)?;
                Ok(out)
            }
            Err(e) => {
                let _ = self.cluster.tracer.take();
                Err(EngineError::from(e))
            }
        }
    }

    /// The strategy answering `Source::Dataset` plans.
    pub fn algorithm(&self) -> AlgoChoice {
        self.choice
    }

    /// Whether dataset plans return exact order statistics.
    pub fn exact(&self) -> bool {
        self.strategy.exact()
    }

    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// Mutable substrate access — data generators partition into the
    /// engine's cluster shape through this.
    pub fn cluster_mut(&mut self) -> &mut Cluster {
        &mut self.cluster
    }

    pub fn store(&self) -> &SketchStore {
        &self.store
    }

    pub fn store_mut(&mut self) -> &mut SketchStore {
        &mut self.store
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Active SIMD lane width of the backend's fused band scan (1 =
    /// scalar) — the value stamped onto every outcome's report.
    pub fn simd_lane_width(&self) -> usize {
        self.backend.simd_lane_width()
    }

    /// What `execute` does when a stage exhausts its retries.
    pub fn degrade_policy(&self) -> DegradePolicy {
        self.degrade
    }

    /// The engine-lifetime metrics registry. Always present — under the
    /// default [`MetricsMode::Off`] it absorbs nothing and renders empty
    /// exports, so callers never branch.
    pub fn registry(&self) -> &MetricsRegistry {
        &self.registry
    }

    /// A point-in-time copy of the engine-lifetime totals: per-kind
    /// counters, task-latency summaries, and store-residency gauges.
    pub fn metrics_snapshot(&self) -> MetricsSnapshot {
        self.registry.snapshot()
    }
}

/// The one stream-plan body: validate against the snapshot's count,
/// then dispatch each query shape onto the snapshot-based fused
/// protocol. `execute_stream` (the serialized `&mut` path) and
/// [`QuantileEngine::query_snapshot`] / the serving layer (the
/// concurrent `&self` path) both land here — bit-identical answers
/// over the same pinned epochs are guaranteed by sharing this body,
/// not by a test alone.
pub(crate) fn snapshot_plan(
    cluster: &mut Cluster,
    backend: &dyn KernelBackend,
    params: &GkSelectParams,
    snap: &StreamSnapshot,
    stream: &str,
    query: &QuantileQuery,
) -> Result<QueryOutcome, EngineError> {
    let n = snap.total_count();
    if n == 0 {
        return Err(EngineError::DrainedStream(stream.to_string()));
    }
    query.validate(n)?;
    match query {
        QuantileQuery::Single(q) => Ok(crate::stream::query::quantile_snapshot_with(
            cluster, backend, params, snap, stream, *q,
        )?
        .into()),
        QuantileQuery::Rank(k) => Ok(crate::stream::query::quantile_snapshot_with(
            cluster,
            backend,
            params,
            snap,
            stream,
            rank_to_quantile(*k, n),
        )?
        .into()),
        QuantileQuery::Multi(qs) => Ok(crate::stream::query::quantiles_snapshot_with(
            cluster, backend, params, snap, stream, qs,
        )?
        .into()),
        QuantileQuery::Sketched { q, eps } => Ok(crate::stream::query::sketched_snapshot_with(
            cluster, snap, stream, *q, *eps,
        )?
        .into()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeBackend;

    fn small_engine(choice: AlgoChoice) -> QuantileEngine {
        EngineBuilder::new()
            .cluster(ClusterConfig::local(2, 4))
            .algorithm(choice)
            .build()
            .unwrap()
    }

    fn data_1k() -> Dataset<Key> {
        Dataset::from_vec((0..1_000).collect(), 4).unwrap()
    }

    #[test]
    fn single_and_rank_agree_for_exact_strategies() {
        for choice in [AlgoChoice::GkSelect, AlgoChoice::FullSort, AlgoChoice::HistSelect] {
            let mut engine = small_engine(choice);
            let data = data_1k();
            let by_q = engine
                .execute(Source::Dataset(&data), QuantileQuery::Single(0.75))
                .unwrap();
            let k = crate::target_rank(1_000, 0.75);
            let by_k = engine
                .execute(Source::Dataset(&data), QuantileQuery::Rank(k))
                .unwrap();
            assert_eq!(by_q.value(), by_k.value(), "{choice:?}");
            assert_eq!(by_q.value(), 750, "{choice:?}");
        }
    }

    #[test]
    fn multi_matches_singles() {
        let mut engine = small_engine(AlgoChoice::GkSelect);
        let data = data_1k();
        let multi = engine
            .execute(
                Source::Dataset(&data),
                QuantileQuery::Multi(vec![0.1, 0.5, 0.9]),
            )
            .unwrap();
        for (&q, &v) in [0.1, 0.5, 0.9].iter().zip(multi.values.iter()) {
            let single = engine
                .execute(Source::Dataset(&data), QuantileQuery::Single(q))
                .unwrap();
            assert_eq!(single.value(), v, "q={q}");
        }
    }

    #[test]
    fn typed_errors_at_the_boundary() {
        let mut engine = small_engine(AlgoChoice::GkSelect);
        let data = data_1k();
        assert_eq!(
            engine
                .execute(Source::Dataset(&data), QuantileQuery::Single(1.5))
                .unwrap_err(),
            EngineError::BadQuantile(1.5)
        );
        assert_eq!(
            engine
                .execute(Source::Dataset(&data), QuantileQuery::Rank(1_000))
                .unwrap_err(),
            EngineError::BadRank { k: 1_000, n: 1_000 }
        );
        assert_eq!(
            engine
                .execute(Source::Dataset(&data), QuantileQuery::Multi(vec![]))
                .unwrap_err(),
            EngineError::NoQuantiles
        );
        let empty = Dataset::from_partitions(vec![vec![]]).unwrap();
        assert_eq!(
            engine
                .execute(Source::Dataset(&empty), QuantileQuery::Single(0.5))
                .unwrap_err(),
            EngineError::EmptyInput
        );
        assert_eq!(
            engine
                .execute(Source::Stream("nope"), QuantileQuery::Single(0.5))
                .unwrap_err(),
            EngineError::UnknownStream("nope".into())
        );
    }

    #[test]
    fn stream_and_batch_share_the_call_site() {
        let mut engine = small_engine(AlgoChoice::GkSelect);
        engine
            .ingest("s", MicroBatch::new((0..600).collect()))
            .unwrap();
        engine
            .ingest("s", MicroBatch::new((600..1_000).collect()))
            .unwrap();
        let stream_out = engine
            .execute(Source::Stream("s"), QuantileQuery::Single(0.5))
            .unwrap();
        assert_eq!(stream_out.value(), 500);
        assert_eq!(stream_out.report.rounds, 1, "cached sketch → 1 round");
        assert_eq!(stream_out.report.data_scans, 1);

        let data = data_1k();
        let batch_out = engine
            .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
            .unwrap();
        assert_eq!(batch_out.value(), stream_out.value());
        assert_eq!(batch_out.report.data_scans, 2, "batch pays the sketch scan");
    }

    #[test]
    fn lane_width_stamped_centrally_on_every_path() {
        // forced-scalar engine: every outcome must say lane width 1
        let mut scalar = EngineBuilder::new()
            .cluster(ClusterConfig::local(2, 4))
            .simd(SimdPolicy::ForceScalar)
            .build()
            .unwrap();
        // forced-SIMD engine: every outcome must say the resolved width
        let forced_width = NativeBackend::with_policy(SimdPolicy::ForceSimd).simd_lane_width();
        let mut forced = EngineBuilder::new()
            .cluster(ClusterConfig::local(2, 4))
            .simd(SimdPolicy::ForceSimd)
            .build()
            .unwrap();
        assert_eq!(scalar.simd_lane_width(), 1);
        assert_eq!(forced.simd_lane_width(), forced_width);

        let data = data_1k();
        for (engine, want) in [(&mut scalar, 1), (&mut forced, forced_width)] {
            engine.ingest("s", MicroBatch::new((0..500).collect())).unwrap();
            let outs = [
                engine
                    .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
                    .unwrap(),
                engine
                    .execute(Source::Dataset(&data), QuantileQuery::Multi(vec![0.25, 0.75]))
                    .unwrap(),
                engine
                    .execute(Source::Dataset(&data), QuantileQuery::Rank(10))
                    .unwrap(),
                engine
                    .execute(
                        Source::Dataset(&data),
                        QuantileQuery::Sketched { q: 0.5, eps: 0.05 },
                    )
                    .unwrap(),
                engine
                    .execute(Source::Stream("s"), QuantileQuery::Single(0.5))
                    .unwrap(),
                engine
                    .execute(Source::Stream("s"), QuantileQuery::Multi(vec![0.5, 0.9]))
                    .unwrap(),
            ];
            for out in outs {
                assert_eq!(
                    out.report.simd_lane_width, want as u64,
                    "every exit path must carry the engine backend's lane width"
                );
            }
        }
    }

    #[test]
    fn precedence_builder_beats_file_beats_env() {
        // exec mode: builder > file > env
        assert_eq!(
            resolve_exec_mode(Some(ExecMode::Sequential), "threads", Some(ExecMode::Threads))
                .unwrap(),
            Some(ExecMode::Sequential)
        );
        assert_eq!(
            resolve_exec_mode(None, "threads", Some(ExecMode::Sequential)).unwrap(),
            Some(ExecMode::Threads)
        );
        assert_eq!(
            resolve_exec_mode(None, "", Some(ExecMode::Threads)).unwrap(),
            Some(ExecMode::Threads)
        );
        assert_eq!(resolve_exec_mode(None, "", None).unwrap(), None);
        assert!(resolve_exec_mode(None, "turbo", None).is_err());

        // simd: builder > file > env > Auto
        assert_eq!(
            resolve_simd(
                Some(SimdPolicy::ForceScalar),
                "force",
                Some(SimdPolicy::ForceSimd)
            )
            .unwrap(),
            SimdPolicy::ForceScalar
        );
        assert_eq!(
            resolve_simd(None, "force", Some(SimdPolicy::ForceScalar)).unwrap(),
            SimdPolicy::ForceSimd
        );
        assert_eq!(
            resolve_simd(None, "", Some(SimdPolicy::ForceScalar)).unwrap(),
            SimdPolicy::ForceScalar
        );
        assert_eq!(resolve_simd(None, "", None).unwrap(), SimdPolicy::Auto);
        assert!(resolve_simd(None, "warp", None).is_err());
    }

    #[test]
    fn file_layer_reaches_the_built_engine() {
        let mut cfg = ReproConfig::default();
        cfg.cluster.exec_mode = "threads".into();
        cfg.cluster.nodes = 3;
        let engine = EngineBuilder::new()
            .config(cfg.clone())
            .build_resolved(None, None, None, None, None)
            .unwrap();
        assert_eq!(engine.cluster().cfg.exec_mode, ExecMode::Threads);
        assert_eq!(engine.cluster().cfg.executors, 3);
        // builder wins over the same file
        let engine = EngineBuilder::new()
            .config(cfg)
            .exec_mode(ExecMode::Sequential)
            .nodes(5)
            .build_resolved(None, None, None, None, None)
            .unwrap();
        assert_eq!(engine.cluster().cfg.exec_mode, ExecMode::Sequential);
        assert_eq!(engine.cluster().cfg.executors, 5);
        // env reaches the engine when builder and file are silent
        let engine = EngineBuilder::new()
            .build_resolved(Some(ExecMode::Threads), None, None, None, None)
            .unwrap();
        assert_eq!(engine.cluster().cfg.exec_mode, ExecMode::Threads);
    }

    #[test]
    fn trace_precedence_and_default_off() {
        use std::path::PathBuf;
        // builder > file > env > Off
        assert_eq!(
            resolve_trace(Some(TraceMode::Memory), "off", Some(TraceMode::Off)).unwrap(),
            TraceMode::Memory
        );
        assert_eq!(
            resolve_trace(None, "chrome:t.json", Some(TraceMode::Memory)).unwrap(),
            TraceMode::Chrome(PathBuf::from("t.json"))
        );
        assert_eq!(
            resolve_trace(None, "", Some(TraceMode::Memory)).unwrap(),
            TraceMode::Memory
        );
        assert_eq!(resolve_trace(None, "", None).unwrap(), TraceMode::Off);
        assert!(resolve_trace(None, "perfetto", None).is_err());

        // the default engine collects nothing and surfaces no trace
        let mut engine = small_engine(AlgoChoice::GkSelect);
        assert!(!engine.cluster().tracer.is_enabled());
        let out = engine
            .execute(Source::Dataset(&data_1k()), QuantileQuery::Single(0.5))
            .unwrap();
        assert!(out.trace().is_none());
    }

    #[test]
    fn metrics_precedence_and_default_off() {
        use std::path::PathBuf;
        // builder > file > env > Off
        assert_eq!(
            resolve_metrics(Some(MetricsMode::Memory), "off", Some(MetricsMode::Off)).unwrap(),
            MetricsMode::Memory
        );
        assert_eq!(
            resolve_metrics(None, "prom:m.prom", Some(MetricsMode::Memory)).unwrap(),
            MetricsMode::Prom(PathBuf::from("m.prom"))
        );
        assert_eq!(
            resolve_metrics(None, "", Some(MetricsMode::Memory)).unwrap(),
            MetricsMode::Memory
        );
        assert_eq!(resolve_metrics(None, "", None).unwrap(), MetricsMode::Off);
        assert!(resolve_metrics(None, "statsd", None).is_err());

        // the default engine's registry is inert: nothing absorbed, an
        // empty snapshot, headers-only exposition
        let mut engine = small_engine(AlgoChoice::GkSelect);
        assert!(!engine.registry().is_enabled());
        engine
            .execute(Source::Dataset(&data_1k()), QuantileQuery::Single(0.5))
            .unwrap();
        let snap = engine.metrics_snapshot();
        assert_eq!(snap.ops, 0);
        assert!(snap.totals.is_empty());
        assert!(engine.registry().qlog_lines().is_empty());
    }

    #[test]
    fn registry_absorbs_batch_stream_and_ingest_rows() {
        let mut engine = EngineBuilder::new()
            .cluster(ClusterConfig::local(2, 4))
            .metrics(MetricsMode::Memory)
            .build_resolved(None, None, None, None, None)
            .unwrap();
        let data = data_1k();
        let out = engine
            .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
            .unwrap();
        assert_eq!(out.op_kind(), OpKind::Batch);
        engine
            .ingest("s", MicroBatch::new((0..500).collect()))
            .unwrap();
        let sout = engine
            .execute(Source::Stream("s"), QuantileQuery::Single(0.5))
            .unwrap();
        assert_eq!(sout.op_kind(), OpKind::Stream);

        let snap = engine.metrics_snapshot();
        assert_eq!(snap.ops, 3);
        let batch = snap.totals_for(OpKind::Batch, "").expect("batch row");
        assert_eq!(batch.ops, 1);
        assert_eq!((batch.rounds, batch.data_scans), (2, 2));
        assert!(batch.band_efficiency() <= 1.0);
        let ing = snap.totals_for(OpKind::Ingest, "s").expect("ingest row");
        assert_eq!(ing.records, 500);
        let stream = snap.totals_for(OpKind::Stream, "s").expect("stream row");
        assert_eq!((stream.rounds, stream.data_scans), (1, 1));
        // residency gauges sampled live from the store at absorb time
        let (sid, res) = &snap.residency[0];
        assert_eq!(sid, "s");
        assert_eq!(res.records, 500);
        assert!(res.sealed_epochs >= 1);
        // one qlog line per absorbed operation, even in memory mode
        assert_eq!(engine.registry().qlog_lines().len(), 3);
    }

    #[test]
    fn memory_traces_ride_the_outcome() {
        let mut engine = EngineBuilder::new()
            .cluster(ClusterConfig::local(2, 4))
            .trace(TraceMode::Memory)
            .build_resolved(None, None, None, None, None)
            .unwrap();
        let data = data_1k();
        let out = engine
            .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
            .unwrap();
        let trace = out.trace().expect("memory sink surfaces the trace");
        assert!(trace.is_well_formed());
        let roots: Vec<_> = trace.roots().collect();
        assert_eq!(roots.len(), 1, "one root per query");
        assert_eq!(roots[0].kind, SpanKind::Query);
        assert!(roots[0].attrs.iter().any(|(k, v)| k == "plan" && v == "single"));
        // GK Select fused protocol: 2 stages (sketch + band extract)
        assert_eq!(trace.spans_of_kind(SpanKind::Stage).count(), 2);
        // a second query starts a fresh tree, ids restarting at 1
        let again = engine
            .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
            .unwrap();
        let trace2 = again.trace().unwrap();
        assert_eq!(trace2.spans[0].id, 1);
        assert!(trace2.roots().all(|r| r.kind == SpanKind::Query));
    }

    #[test]
    fn rank_to_quantile_roundtrips_target_rank() {
        for n in [1u64, 2, 3, 10, 101, 1_000, 999_983] {
            for k in [0, n / 3, n / 2, n - 1] {
                let q = rank_to_quantile(k, n);
                assert_eq!(crate::target_rank(n, q), k, "k={k} n={n}");
            }
        }
    }

    #[test]
    fn sketched_runs_the_sketch_path_for_any_strategy() {
        let data = data_1k();
        let mut values = Vec::new();
        for choice in AlgoChoice::ALL {
            let mut engine = small_engine(choice);
            let out = engine
                .execute(
                    Source::Dataset(&data),
                    QuantileQuery::Sketched { q: 0.5, eps: 0.05 },
                )
                .unwrap();
            assert!(!out.report.exact, "{choice:?}: sketched answers are approximate");
            values.push(out.value());
        }
        assert!(
            values.windows(2).all(|w| w[0] == w[1]),
            "sketched answers must be strategy-independent: {values:?}"
        );
    }

    #[test]
    fn retries_keep_faulted_answers_bit_identical() {
        let data = data_1k();
        let clean = small_engine(AlgoChoice::GkSelect)
            .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
            .unwrap();
        // one injected panic per stage, inside the retry budget
        let mut engine = EngineBuilder::new()
            .cluster(ClusterConfig::local(2, 4))
            .fault_plan(
                FaultPlan::seeded(11)
                    .panic_task(0, 1)
                    .panic_task(1, 3)
                    .stragglers(0.5, 4.0),
            )
            .build_resolved(None, None, None, None, None)
            .unwrap();
        let out = engine
            .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
            .unwrap();
        assert_eq!(out.value(), clean.value(), "retried run must stay exact");
        assert!(!out.degraded);
        assert!(out.report.exact);
        assert_eq!(out.report.tasks_retried, 2);
        assert_eq!(out.report.rounds, clean.report.rounds);
        assert_eq!(out.report.data_scans, clean.report.data_scans);
    }

    #[test]
    fn exhausted_retries_fail_typed_or_degrade_to_the_sketch() {
        let data = data_1k();
        // a fault that outlives any retry budget on the sketch stage
        let plan = FaultPlan::seeded(3).panic_task(0, 0).attempts(u32::MAX);

        let mut failing = EngineBuilder::new()
            .cluster(ClusterConfig::local(2, 4))
            .fault_plan(plan.clone())
            .build_resolved(None, None, None, None, None)
            .unwrap();
        let err = failing
            .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
            .unwrap_err();
        assert!(
            matches!(err, EngineError::StageFailed { stage: 0, attempts } if attempts > 0),
            "{err}"
        );

        // same plan under SketchAnswer: the sketch rebuild runs at later
        // stage indices the plan doesn't touch, so the query degrades
        let mut degrading = EngineBuilder::new()
            .cluster(ClusterConfig::local(2, 4))
            .fault_plan(plan)
            .degrade_policy(DegradePolicy::SketchAnswer)
            .build_resolved(None, None, None, None, None)
            .unwrap();
        let out = degrading
            .execute(Source::Dataset(&data), QuantileQuery::Single(0.5))
            .unwrap();
        assert!(out.degraded, "fallback answers must be marked");
        assert!(!out.report.exact);
        assert_eq!(out.report.degraded_queries, 1);
        // ε-approximate: rank error bounded by ε·n = 10
        assert!((out.value() - 500).unsigned_abs() <= 10, "got {}", out.value());
        assert_eq!(degrading.cluster().metrics.degraded_queries, 1);
    }

    #[test]
    fn stream_queries_degrade_to_the_cached_sketch_without_a_scan() {
        // fail every post-ingest stage persistently: the exact stream
        // query (which scans the epoch partitions) cannot survive, but
        // the cached merged sketch answers without any scan at all
        let mut engine = EngineBuilder::new()
            .cluster(ClusterConfig::local(2, 4))
            .degrade_policy(DegradePolicy::SketchAnswer)
            .build_resolved(None, None, None, None, None)
            .unwrap();
        engine
            .ingest("s", MicroBatch::new((0..1_000).collect()))
            .unwrap();
        // arm the faults only after ingest by rebuilding the injector
        let mut cc = engine.cluster().cfg.clone();
        cc.faults = Some(FaultPlan::seeded(5).panics(1.0).attempts(u32::MAX));
        *engine.cluster_mut() = Cluster::new(cc);
        let out = engine
            .execute(Source::Stream("s"), QuantileQuery::Single(0.5))
            .unwrap();
        assert!(out.degraded);
        assert!(!out.report.exact);
        assert!((out.value() - 500).unsigned_abs() <= 10, "got {}", out.value());
    }

    #[test]
    fn bad_builder_knobs_are_typed_errors() {
        assert!(matches!(
            EngineBuilder::new().epsilon(0.0).build_resolved(None, None, None, None, None),
            Err(EngineError::BadEpsilon(_))
        ));
        let mut cfg = ReproConfig::default();
        cfg.backend = "warp-drive".into();
        assert!(matches!(
            EngineBuilder::new().config(cfg).build_resolved(None, None, None, None, None),
            Err(EngineError::Backend(_))
        ));
        // an injected backend carries its own dispatch: an explicit
        // simd() on top is a conflict, never silently ignored
        assert!(matches!(
            EngineBuilder::new()
                .kernel_backend(Box::new(NativeBackend::new()))
                .simd(SimdPolicy::ForceScalar)
                .build_resolved(None, None, None, None, None),
            Err(EngineError::InvalidConfig(_))
        ));
    }
}
