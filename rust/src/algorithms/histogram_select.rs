//! Histogram Select — the extension sketched in the paper's trade-off
//! discussion (§V-6): push rank-narrowing into the cluster instead of the
//! driver, keeping per-round state `O(bins)` regardless of ε.
//!
//! Rounds: one min/max pass seeds the value range; then each round every
//! executor histograms its partition over the current range (the AOT
//! histogram kernel), the driver locates the bin containing the target
//! rank and zooms in. The i32 key domain guarantees
//! `⌈32 / log₂(nbins)⌉` refinement rounds worst-case (5 at 128 bins);
//! once the surviving band is small (≤ `extract_cap` keys), a final
//! extraction pass ships it to the driver for exact selection.
//!
//! Compared to GK Select: no sketch, slightly more rounds, but driver
//! space is `O(bins + band)` instead of `O((P/ε)log(εn/P) + εn)` — the
//! regime the paper worries about when ε must be tiny.

use super::{drive_plan, run_report, Outcome, QuantileAlgorithm};
use crate::cluster::dataset::Dataset;
use crate::cluster::Cluster;
use crate::engine::{EngineCtx, EngineError, QuantileQuery, QueryOutcome};
use crate::runtime::{KernelBackend, NativeBackend};
use crate::select::{quickselect, SplitMix64};
use crate::{target_rank, Key};
use anyhow::Result;

/// Histogram Select knobs.
#[derive(Debug, Clone)]
pub struct HistogramSelectParams {
    /// Bins per refinement round (must match the AOT artifact when the
    /// PJRT backend is used).
    pub nbins: usize,
    /// Stop refining once the candidate band is at most this many keys;
    /// ship and select exactly.
    pub extract_cap: u64,
    pub seed: u64,
    /// Safety valve (domain/bins bound the real count).
    pub max_rounds: u64,
}

impl Default for HistogramSelectParams {
    fn default() -> Self {
        Self {
            nbins: 128,
            extract_cap: 1 << 20,
            seed: 0x0157_0652,
            max_rounds: 64,
        }
    }
}

/// The iterative histogram-refinement protocol through an explicit
/// kernel backend. Resets the run ledger.
pub(crate) fn histogram_quantile_with(
    cluster: &mut Cluster,
    backend: &dyn KernelBackend,
    params: &HistogramSelectParams,
    data: &Dataset<Key>,
    q: f64,
) -> Result<Outcome, EngineError> {
    if data.is_empty() {
        return Err(EngineError::EmptyInput);
    }
    if params.nbins < 2 {
        return Err(EngineError::InvalidConfig(
            "histogram select needs at least 2 bins".to_string(),
        ));
    }
    cluster.reset_run();
    let n = data.len();
    let mut k = target_rank(n, q);

    // Round 1: global min/max seeds the value range
    let pending = cluster.map_partitions(data, |part, _| backend.minmax(part))?;
    let bounds = cluster
        .reduce(pending, |a, b| match (a, b) {
            (None, x) | (x, None) => x,
            (Some((alo, ahi)), Some((blo, bhi))) => Some((alo.min(blo), ahi.max(bhi))),
        })
        .flatten();
    let (mut lo, mut hi) = bounds.ok_or(EngineError::EmptyInput)?;

    // Refinement rounds: histogram over [lo, hi], zoom into the bin
    // holding rank k (k rebased as mass below the band is discarded)
    let nbins = params.nbins;
    let mut band_count = n;
    for _ in 0..params.max_rounds {
        if lo == hi || band_count <= params.extract_cap {
            break;
        }
        let span = hi as i64 - lo as i64 + 1;
        let width = (span + nbins as i64 - 1) / nbins as i64; // ceil
        let lo_i = lo as i64;
        let pending = cluster.map_partitions(data, |part, _| {
            // restrict to the live band, then bucket
            let banded: Vec<Key> = part
                .iter()
                .copied()
                .filter(|&v| v >= lo && v <= hi)
                .collect();
            backend.histogram(&banded, lo_i, width, nbins)
        })?;
        let hist = cluster
            .reduce(pending, |mut a, b| {
                for (x, y) in a.iter_mut().zip(b) {
                    *x += y;
                }
                a
            })
            .expect("nonempty");

        // locate the bin containing rank k within the band
        let mut acc = 0u64;
        let mut found = None;
        for (b, &c) in hist.iter().enumerate() {
            if acc + c > k {
                found = Some((b, acc, c));
                break;
            }
            acc += c;
        }
        let (bin, below, in_bin) = found.ok_or_else(|| {
            EngineError::Execution(format!("rank {k} beyond band mass"))
        })?;
        k -= below;
        band_count = in_bin;
        let new_lo = lo_i + bin as i64 * width;
        let new_hi = (new_lo + width - 1).min(hi as i64);
        lo = new_lo.max(lo as i64) as Key;
        hi = new_hi as Key;
    }

    if lo == hi {
        // band collapsed to a single value — it is the answer
        return Ok(finish(cluster, n, lo));
    }
    if band_count > params.extract_cap {
        // the refinement budget ran out with the band still too large to
        // ship — the histogram analogue of a candidate-budget overflow
        return Err(EngineError::BudgetOverflow {
            fallback_used: false,
        });
    }

    // Final round: extract the band and select exactly on the driver
    let (blo, bhi) = (lo, hi);
    let pending = cluster.map_partitions(data, |part, _| {
        part.iter()
            .copied()
            .filter(|&v| v >= blo && v <= bhi)
            .collect::<Vec<Key>>()
    })?;
    let slices = cluster.collect(pending);
    let seed = params.seed;
    let value = cluster.driver(move || {
        let mut band: Vec<Key> = slices.into_iter().flatten().collect();
        debug_assert!((k as usize) < band.len());
        let mut rng = SplitMix64::new(seed);
        quickselect(&mut band, k as usize, &mut rng);
        band[k as usize]
    });
    Ok(finish(cluster, n, value))
}

fn finish(cluster: &Cluster, n: u64, value: Key) -> Outcome {
    Outcome {
        value,
        report: run_report("Hist Select", true, cluster, n),
    }
}

/// The stateless histogram-refinement strategy behind
/// `AlgoChoice::HistSelect`.
#[derive(Debug, Clone, Default)]
pub struct HistogramSelectStrategy {
    pub params: HistogramSelectParams,
}

impl HistogramSelectStrategy {
    pub fn new(params: HistogramSelectParams) -> Self {
        Self { params }
    }
}

impl QuantileAlgorithm for HistogramSelectStrategy {
    fn name(&self) -> &'static str {
        "Hist Select"
    }

    fn exact(&self) -> bool {
        true
    }

    fn execute_plan(
        &self,
        ctx: &mut EngineCtx<'_>,
        query: &QuantileQuery,
    ) -> Result<QueryOutcome, EngineError> {
        let backend = ctx.backend;
        let data = ctx.data;
        drive_plan(ctx.cluster, data, query, |cluster, q| {
            histogram_quantile_with(cluster, backend, &self.params, data, q)
        })
    }
}

/// The pre-redesign backend-owning driver. Kept as a thin shim for one
/// release — route queries through `QuantileEngine::execute` instead.
pub struct HistogramSelect {
    pub params: HistogramSelectParams,
    backend: Box<dyn KernelBackend>,
}

impl HistogramSelect {
    #[deprecated(
        since = "0.2.0",
        note = "build a `QuantileEngine` with `AlgoChoice::HistSelect` and call `execute`"
    )]
    pub fn new(params: HistogramSelectParams) -> Self {
        Self {
            params,
            backend: Box::new(NativeBackend::new()),
        }
    }

    #[deprecated(
        since = "0.2.0",
        note = "use `EngineBuilder::kernel_backend` / `backend_name` instead"
    )]
    pub fn with_backend(params: HistogramSelectParams, backend: Box<dyn KernelBackend>) -> Self {
        Self { params, backend }
    }

    /// One exact quantile — the pre-redesign entry point. Stamps this
    /// shim's own backend lane width to preserve the old report
    /// contract.
    #[deprecated(
        since = "0.2.0",
        note = "use `QuantileEngine::execute` with `AlgoChoice::HistSelect`"
    )]
    pub fn quantile(&mut self, cluster: &mut Cluster, data: &Dataset<Key>, q: f64) -> Result<Outcome> {
        let mut out =
            histogram_quantile_with(cluster, self.backend.as_ref(), &self.params, data, q)?;
        out.report.simd_lane_width = self.backend.simd_lane_width() as u64;
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::oracle_quantile;
    use crate::cluster::ClusterConfig;
    use crate::data::{DataGenerator, Distribution};

    fn check(dist: Distribution, n: u64, q: f64, cap: u64) -> Outcome {
        let mut c = Cluster::new(ClusterConfig::local(2, 8));
        let data = dist.generator(44).generate(&mut c, n);
        let truth = oracle_quantile(&data, q).unwrap();
        let backend = NativeBackend::new();
        let params = HistogramSelectParams {
            extract_cap: cap,
            ..Default::default()
        };
        let out = histogram_quantile_with(&mut c, &backend, &params, &data, q).unwrap();
        assert_eq!(out.value, truth, "{} q={q}", dist.label());
        out
    }

    #[test]
    fn exact_on_all_distributions() {
        for dist in [
            Distribution::Uniform,
            Distribution::Zipf,
            Distribution::Bimodal,
            Distribution::Sorted,
        ] {
            check(dist, 30_000, 0.5, 4_000);
            check(dist, 30_000, 0.99, 4_000);
        }
    }

    #[test]
    fn rounds_bounded_by_domain_refinement() {
        let out = check(Distribution::Uniform, 100_000, 0.5, 1_000);
        // minmax + ≤⌈32/7⌉ refinements + extract ≤ 7 rounds
        assert!(
            out.report.rounds <= 7,
            "rounds = {} exceeds domain bound",
            out.report.rounds
        );
        assert_eq!(out.report.shuffles, 0);
    }

    #[test]
    fn duplicate_spike_collapses_band() {
        // heavy spike: the refinement can't split a single value's mass,
        // band collapse (lo == hi) must exit exactly
        let mut c = Cluster::new(ClusterConfig::local(2, 4));
        let mut vals = vec![7; 50_000];
        vals.extend(0..100);
        let data = Dataset::from_vec(vals, 4).unwrap();
        let truth = oracle_quantile(&data, 0.5).unwrap();
        let backend = NativeBackend::new();
        let params = HistogramSelectParams {
            extract_cap: 100, // force refinement into the spike
            ..Default::default()
        };
        let out = histogram_quantile_with(&mut c, &backend, &params, &data, 0.5).unwrap();
        assert_eq!(out.value, truth);
    }

    #[test]
    fn extremes() {
        check(Distribution::Uniform, 10_000, 0.0, 2_000);
        check(Distribution::Uniform, 10_000, 1.0, 2_000);
    }
}
