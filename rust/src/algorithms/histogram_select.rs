//! Histogram Select — the extension sketched in the paper's trade-off
//! discussion (§V-6): push rank-narrowing into the cluster instead of the
//! driver, keeping per-round state `O(bins)` regardless of ε.
//!
//! Rounds: one min/max pass seeds the value range; then each round every
//! executor histograms its partition over the current range (the AOT
//! histogram kernel), the driver locates the bin containing the target
//! rank and zooms in. The i32 key domain guarantees
//! `⌈32 / log₂(nbins)⌉` refinement rounds worst-case (5 at 128 bins);
//! once the surviving band is small (≤ `extract_cap` keys), a final
//! extraction pass ships it to the driver for exact selection.
//!
//! Compared to GK Select: no sketch, slightly more rounds, but driver
//! space is `O(bins + band)` instead of `O((P/ε)log(εn/P) + εn)` — the
//! regime the paper worries about when ε must be tiny.

use super::{make_backend_report, Outcome, QuantileAlgorithm};
use crate::cluster::dataset::Dataset;
use crate::cluster::Cluster;
use crate::runtime::{KernelBackend, NativeBackend};
use crate::select::{quickselect, SplitMix64};
use crate::{target_rank, Key};
use anyhow::{bail, ensure, Result};

/// Histogram Select knobs.
#[derive(Debug, Clone)]
pub struct HistogramSelectParams {
    /// Bins per refinement round (must match the AOT artifact when the
    /// PJRT backend is used).
    pub nbins: usize,
    /// Stop refining once the candidate band is at most this many keys;
    /// ship and select exactly.
    pub extract_cap: u64,
    pub seed: u64,
    /// Safety valve (domain/bins bound the real count).
    pub max_rounds: u64,
}

impl Default for HistogramSelectParams {
    fn default() -> Self {
        Self {
            nbins: 128,
            extract_cap: 1 << 20,
            seed: 0x0157_0652,
            max_rounds: 64,
        }
    }
}

/// Iterative histogram-refinement exact selection.
pub struct HistogramSelect {
    pub params: HistogramSelectParams,
    backend: Box<dyn KernelBackend>,
}

impl HistogramSelect {
    pub fn new(params: HistogramSelectParams) -> Self {
        Self {
            params,
            backend: Box::new(NativeBackend::new()),
        }
    }

    pub fn with_backend(params: HistogramSelectParams, backend: Box<dyn KernelBackend>) -> Self {
        Self { params, backend }
    }

    /// [`make_backend_report`] with this engine's name and backend.
    fn finish(&self, cluster: &Cluster, n: u64, value: Key) -> Outcome {
        make_backend_report(self.name(), true, cluster, n, value, self.backend.as_ref())
    }
}

impl QuantileAlgorithm for HistogramSelect {
    fn name(&self) -> &'static str {
        "Hist Select"
    }

    fn exact(&self) -> bool {
        true
    }

    fn quantile(&mut self, cluster: &mut Cluster, data: &Dataset<Key>, q: f64) -> Result<Outcome> {
        ensure!(!data.is_empty(), "empty dataset");
        ensure!(self.params.nbins >= 2, "need at least 2 bins");
        cluster.reset_run();
        let n = data.len();
        let mut k = target_rank(n, q);

        // Round 1: global min/max seeds the value range
        let backend = self.backend.as_ref();
        let pending = cluster.map_partitions(data, |part, _| backend.minmax(part));
        let bounds = cluster
            .reduce(pending, |a, b| match (a, b) {
                (None, x) | (x, None) => x,
                (Some((alo, ahi)), Some((blo, bhi))) => Some((alo.min(blo), ahi.max(bhi))),
            })
            .flatten();
        let (mut lo, mut hi) = bounds.ok_or_else(|| anyhow::anyhow!("empty dataset"))?;

        // Refinement rounds: histogram over [lo, hi], zoom into the bin
        // holding rank k (k rebased as mass below the band is discarded)
        let nbins = self.params.nbins;
        let mut band_count = n;
        for _ in 0..self.params.max_rounds {
            if lo == hi || band_count <= self.params.extract_cap {
                break;
            }
            let span = hi as i64 - lo as i64 + 1;
            let width = (span + nbins as i64 - 1) / nbins as i64; // ceil
            let backend = self.backend.as_ref();
            let lo_i = lo as i64;
            let pending = cluster.map_partitions(data, |part, _| {
                // restrict to the live band, then bucket
                let banded: Vec<Key> = part
                    .iter()
                    .copied()
                    .filter(|&v| v >= lo && v <= hi)
                    .collect();
                backend.histogram(&banded, lo_i, width, nbins)
            });
            let hist = cluster
                .reduce(pending, |mut a, b| {
                    for (x, y) in a.iter_mut().zip(b) {
                        *x += y;
                    }
                    a
                })
                .expect("nonempty");

            // locate the bin containing rank k within the band
            let mut acc = 0u64;
            let mut found = None;
            for (b, &c) in hist.iter().enumerate() {
                if acc + c > k {
                    found = Some((b, acc, c));
                    break;
                }
                acc += c;
            }
            let (bin, below, in_bin) =
                found.ok_or_else(|| anyhow::anyhow!("rank {k} beyond band mass"))?;
            k -= below;
            band_count = in_bin;
            let new_lo = lo_i + bin as i64 * width;
            let new_hi = (new_lo + width - 1).min(hi as i64);
            lo = new_lo.max(lo as i64) as Key;
            hi = new_hi as Key;
        }

        if lo == hi {
            // band collapsed to a single value — it is the answer
            return Ok(self.finish(cluster, n, lo));
        }
        if band_count > self.params.extract_cap {
            bail!(
                "band still holds {band_count} keys after {} rounds",
                self.params.max_rounds
            );
        }

        // Final round: extract the band and select exactly on the driver
        let (blo, bhi) = (lo, hi);
        let pending = cluster.map_partitions(data, |part, _| {
            part.iter()
                .copied()
                .filter(|&v| v >= blo && v <= bhi)
                .collect::<Vec<Key>>()
        });
        let slices = cluster.collect(pending);
        let seed = self.params.seed;
        let value = cluster.driver(move || {
            let mut band: Vec<Key> = slices.into_iter().flatten().collect();
            debug_assert!((k as usize) < band.len());
            let mut rng = SplitMix64::new(seed);
            quickselect(&mut band, k as usize, &mut rng);
            band[k as usize]
        });
        Ok(self.finish(cluster, n, value))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::oracle_quantile;
    use crate::cluster::ClusterConfig;
    use crate::data::{DataGenerator, Distribution};

    fn check(dist: Distribution, n: u64, q: f64, cap: u64) -> Outcome {
        let mut c = Cluster::new(ClusterConfig::local(2, 8));
        let data = dist.generator(44).generate(&mut c, n);
        let truth = oracle_quantile(&data, q).unwrap();
        let mut alg = HistogramSelect::new(HistogramSelectParams {
            extract_cap: cap,
            ..Default::default()
        });
        let out = alg.quantile(&mut c, &data, q).unwrap();
        assert_eq!(out.value, truth, "{} q={q}", dist.label());
        out
    }

    #[test]
    fn exact_on_all_distributions() {
        for dist in [
            Distribution::Uniform,
            Distribution::Zipf,
            Distribution::Bimodal,
            Distribution::Sorted,
        ] {
            check(dist, 30_000, 0.5, 4_000);
            check(dist, 30_000, 0.99, 4_000);
        }
    }

    #[test]
    fn rounds_bounded_by_domain_refinement() {
        let out = check(Distribution::Uniform, 100_000, 0.5, 1_000);
        // minmax + ≤⌈32/7⌉ refinements + extract ≤ 7 rounds
        assert!(
            out.report.rounds <= 7,
            "rounds = {} exceeds domain bound",
            out.report.rounds
        );
        assert_eq!(out.report.shuffles, 0);
    }

    #[test]
    fn duplicate_spike_collapses_band() {
        // heavy spike: the refinement can't split a single value's mass,
        // band collapse (lo == hi) must exit exactly
        let mut c = Cluster::new(ClusterConfig::local(2, 4));
        let mut vals = vec![7; 50_000];
        vals.extend(0..100);
        let data = Dataset::from_vec(vals, 4).unwrap();
        let truth = oracle_quantile(&data, 0.5).unwrap();
        let mut alg = HistogramSelect::new(HistogramSelectParams {
            extract_cap: 100, // force refinement into the spike
            ..Default::default()
        });
        let out = alg.quantile(&mut c, &data, 0.5).unwrap();
        assert_eq!(out.value, truth);
    }

    #[test]
    fn extremes() {
        check(Distribution::Uniform, 10_000, 0.0, 2_000);
        check(Distribution::Uniform, 10_000, 1.0, 2_000);
    }
}
