//! Al-Furaih Select for Spark (§IV-B): "serial pivot, parallel count"
//! with per-round `treeReduce` of counts + candidate pivots.

use super::count_discard::{AggMode, CountDiscardParams, CountDiscardSelect};
use super::{Outcome, QuantileAlgorithm};
use crate::cluster::dataset::Dataset;
use crate::cluster::Cluster;
use crate::engine::{EngineCtx, EngineError, QuantileQuery, QueryOutcome};
use crate::Key;
use anyhow::Result;

/// AFS parameters (count-discard knobs).
pub type AfsParams = CountDiscardParams;

/// Al-Furaih Select: `O(log n)` rounds, each ending in a treeReduce —
/// the stateless strategy behind `AlgoChoice::Afs`.
pub struct Afs {
    inner: CountDiscardSelect,
}

impl Afs {
    pub fn new(params: AfsParams) -> Self {
        Self {
            inner: CountDiscardSelect::new("AFS", AggMode::TreeReduce, params),
        }
    }

    /// One exact quantile — the pre-redesign entry point.
    #[deprecated(
        since = "0.2.0",
        note = "use `QuantileEngine::execute` with `AlgoChoice::Afs`"
    )]
    pub fn quantile(&mut self, cluster: &mut Cluster, data: &Dataset<Key>, q: f64) -> Result<Outcome> {
        Ok(self.inner.quantile_with(cluster, data, q)?)
    }
}

impl QuantileAlgorithm for Afs {
    fn name(&self) -> &'static str {
        "AFS"
    }

    fn exact(&self) -> bool {
        true
    }

    fn execute_plan(
        &self,
        ctx: &mut EngineCtx<'_>,
        query: &QuantileQuery,
    ) -> Result<QueryOutcome, EngineError> {
        self.inner.execute_plan(ctx, query)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{oracle_quantile, plan_single};
    use crate::cluster::ClusterConfig;
    use crate::data::{DataGenerator, Distribution};

    #[test]
    fn afs_is_exact_and_labeled() {
        let mut c = Cluster::new(ClusterConfig::local(2, 8));
        let data = Distribution::Bimodal.generator(2).generate(&mut c, 20_000);
        let truth = oracle_quantile(&data, 0.25).unwrap();
        let alg = Afs::new(AfsParams::default());
        let out = plan_single(&alg, &mut c, &data, 0.25).unwrap();
        assert_eq!(out.value(), truth);
        assert_eq!(out.report.algorithm, "AFS");
        assert!(out.report.exact);
    }

    #[test]
    fn afs_uses_tree_reduce_traffic() {
        let mut c = Cluster::new(ClusterConfig::local(2, 8));
        let data = Distribution::Uniform.generator(3).generate(&mut c, 50_000);
        let alg = Afs::new(AfsParams::default());
        let out = plan_single(&alg, &mut c, &data, 0.5).unwrap();
        // per-round messages are tiny: total volume must stay well below data size
        assert!(out.report.network_volume_bytes < 50_000);
    }
}
