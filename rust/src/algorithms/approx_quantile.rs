//! `approxQuantile` — the GK Sketch path (§IV-D): per-partition sketches,
//! driver-side merge, one round, approximate answer.
//!
//! This is both the paper's approximate baseline and GK Select's Round 1
//! (the pivot source), so the sketch-building helpers live here and are
//! shared.

use super::{drive_plan, run_report, Outcome, QuantileAlgorithm};
use crate::cluster::dataset::Dataset;
use crate::cluster::Cluster;
use crate::engine::{EngineCtx, EngineError, QuantileQuery, QueryOutcome};
use crate::sketch::classical::ClassicalGk;
use crate::sketch::modified::{fold_merge, tree_merge, ModifiedGk};
use crate::sketch::spark::SparkGk;
use crate::sketch::{GkCore, QuantileSketch};
use crate::Key;
use anyhow::Result;

/// Which GK implementation executors run (§IV-D/E).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SketchVariant {
    /// Per-insert Greenwald–Khanna.
    Classical,
    /// Spark 3.5.5 head-buffered (B = 50 000).
    Spark,
    /// The paper's mSGK (adaptive buffer).
    Modified,
    /// Bulk construction from a radix-sorted partition copy (§IV-D's
    /// "all the data ahead of time" construction; §Perf L3.4). Valid
    /// whenever the executor owns the partition — which GK Select's own
    /// `secondPass` already assumes.
    Bulk,
}

impl std::str::FromStr for SketchVariant {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "classical" => Ok(Self::Classical),
            "spark" => Ok(Self::Spark),
            "modified" => Ok(Self::Modified),
            "bulk" => Ok(Self::Bulk),
            other => anyhow::bail!("unknown sketch variant '{other}' (classical|spark|modified|bulk)"),
        }
    }
}

/// Driver-side merge strategy (§IV-E2 vs §IV-E3 change 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MergeStrategy {
    /// Spark's sequential `foldLeft`.
    Fold,
    /// Recursive pairwise tree (mSGK).
    Tree,
}

impl std::str::FromStr for MergeStrategy {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "fold" => Ok(Self::Fold),
            "tree" => Ok(Self::Tree),
            other => anyhow::bail!("unknown merge strategy '{other}'"),
        }
    }
}

/// Build one partition's sketch and surrender its summary.
pub fn sketch_partition(variant: SketchVariant, epsilon: f64, part: &[Key]) -> GkCore {
    match variant {
        SketchVariant::Classical => {
            let mut sk = ClassicalGk::new(epsilon);
            for &v in part {
                sk.insert(v);
            }
            sk.finalize();
            sk.into_core()
        }
        SketchVariant::Spark => {
            let mut sk = SparkGk::new(epsilon);
            for &v in part {
                sk.insert(v);
            }
            sk.finalize();
            sk.into_core()
        }
        SketchVariant::Modified => {
            let mut sk = ModifiedGk::new(epsilon);
            for &v in part {
                sk.insert(v);
            }
            sk.finalize();
            sk.into_core()
        }
        SketchVariant::Bulk => {
            let mut copy = part.to_vec();
            crate::sort::radix::radix_sort_i32(&mut copy);
            GkCore::from_sorted(&copy, epsilon)
        }
    }
}

/// Shared Round-1 body: executor sketches → collect → driver merge →
/// global sketch. Charges exactly one round.
pub fn build_global_sketch(
    cluster: &mut Cluster,
    data: &Dataset<Key>,
    variant: SketchVariant,
    merge: MergeStrategy,
    epsilon: f64,
) -> Result<GkCore> {
    let pending =
        cluster.map_partitions(data, |part, _| sketch_partition(variant, epsilon, part))?;
    let cores = cluster.collect(pending);
    let merged = cluster.driver(|| match merge {
        MergeStrategy::Fold => fold_merge(cores),
        MergeStrategy::Tree => tree_merge(cores),
    });
    merged.ok_or_else(|| anyhow::anyhow!("no partitions to sketch"))
}

/// Parameters for the approximate baseline.
#[derive(Debug, Clone)]
pub struct ApproxQuantileParams {
    pub epsilon: f64,
    pub variant: SketchVariant,
    pub merge: MergeStrategy,
}

impl Default for ApproxQuantileParams {
    fn default() -> Self {
        Self {
            epsilon: 0.01,
            variant: SketchVariant::Spark,
            merge: MergeStrategy::Fold,
        }
    }
}

/// The one-round approximate path: per-partition sketches, driver-side
/// merge, sketch query. The `Sketched` plan arm and the `GkSketch`
/// strategy both run through here.
pub(crate) fn sketch_quantile_with(
    cluster: &mut Cluster,
    data: &Dataset<Key>,
    params: &ApproxQuantileParams,
    q: f64,
) -> Result<Outcome, EngineError> {
    if data.is_empty() {
        return Err(EngineError::EmptyInput);
    }
    if !(params.epsilon > 0.0 && params.epsilon < 1.0) {
        return Err(EngineError::BadEpsilon(params.epsilon));
    }
    cluster.reset_run();
    let sketch = build_global_sketch(cluster, data, params.variant, params.merge, params.epsilon)?;
    let value = cluster
        .driver(|| sketch.query_quantile(q))
        .ok_or(EngineError::EmptyInput)?;
    Ok(Outcome {
        value,
        report: run_report("GK Sketch", false, cluster, data.len()),
    })
}

/// Spark's `approxQuantile` equivalent — the stateless strategy behind
/// `AlgoChoice::GkSketch`.
#[derive(Debug, Clone)]
pub struct ApproxQuantile {
    pub params: ApproxQuantileParams,
}

impl ApproxQuantile {
    pub fn new(params: ApproxQuantileParams) -> Self {
        Self { params }
    }

    /// One approximate quantile — the pre-redesign entry point.
    #[deprecated(
        since = "0.2.0",
        note = "use `QuantileEngine::execute` (strategy `AlgoChoice::GkSketch`, or a \
                `QuantileQuery::Sketched` plan on any engine)"
    )]
    pub fn quantile(&mut self, cluster: &mut Cluster, data: &Dataset<Key>, q: f64) -> Result<Outcome> {
        Ok(sketch_quantile_with(cluster, data, &self.params, q)?)
    }
}

impl QuantileAlgorithm for ApproxQuantile {
    fn name(&self) -> &'static str {
        "GK Sketch"
    }

    fn exact(&self) -> bool {
        false
    }

    fn execute_plan(
        &self,
        ctx: &mut EngineCtx<'_>,
        query: &QuantileQuery,
    ) -> Result<QueryOutcome, EngineError> {
        let data = ctx.data;
        drive_plan(ctx.cluster, data, query, |cluster, q| {
            sketch_quantile_with(cluster, data, &self.params, q)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::oracle_quantile;
    use crate::cluster::ClusterConfig;
    use crate::data::{DataGenerator, Distribution};

    fn run(variant: SketchVariant, merge: MergeStrategy, n: u64, q: f64) -> (Outcome, Key, u64) {
        let mut c = Cluster::new(ClusterConfig::local(2, 8));
        let data = Distribution::Uniform.generator(21).generate(&mut c, n);
        let truth = oracle_quantile(&data, q).unwrap();
        let params = ApproxQuantileParams {
            epsilon: 0.01,
            variant,
            merge,
        };
        let out = sketch_quantile_with(&mut c, &data, &params, q).unwrap();
        (out, truth, n)
    }

    fn assert_rank_close(data_q: f64, n: u64, got: Key, seed: u64, tol: f64) {
        let mut c = Cluster::new(ClusterConfig::local(2, 8));
        let data = Distribution::Uniform.generator(seed).generate(&mut c, n);
        let mut all = data.to_vec();
        all.sort_unstable();
        let rank = all.partition_point(|&x| x < got) as f64;
        let target = data_q * n as f64;
        assert!(
            (rank - target).abs() <= tol * n as f64 + 2.0,
            "rank {rank} vs target {target} beyond {tol}·n"
        );
    }

    #[test]
    fn one_round_one_stage_boundary() {
        let (out, _, _) = run(SketchVariant::Spark, MergeStrategy::Fold, 50_000, 0.5);
        assert_eq!(out.report.rounds, 1);
        assert_eq!(out.report.stage_boundaries, 1);
        assert_eq!(out.report.shuffles, 0);
        assert!(!out.report.exact);
    }

    #[test]
    fn spark_fold_error_within_bound() {
        let (out, _, n) = run(SketchVariant::Spark, MergeStrategy::Fold, 80_000, 0.5);
        // pairwise merges widen the practical band; 8 partitions ⇒ stay
        // within a few epsilon
        assert_rank_close(0.5, n, out.value, 21, 0.04);
    }

    #[test]
    fn all_variants_agree_roughly() {
        for variant in [
            SketchVariant::Classical,
            SketchVariant::Spark,
            SketchVariant::Modified,
        ] {
            let (out, _, n) = run(variant, MergeStrategy::Tree, 60_000, 0.9);
            assert_rank_close(0.9, n, out.value, 21, 0.05);
        }
    }

    #[test]
    fn network_volume_is_sketch_sized_not_data_sized() {
        let (out, _, n) = run(SketchVariant::Modified, MergeStrategy::Fold, 100_000, 0.5);
        let data_bytes = n * 4;
        assert!(
            out.report.network_volume_bytes < data_bytes / 10,
            "sketch path moved {} of {} data bytes",
            out.report.network_volume_bytes,
            data_bytes
        );
    }

    #[test]
    fn rejects_empty() {
        let mut c = Cluster::new(ClusterConfig::local(1, 1));
        let data = Dataset::from_partitions(vec![vec![]]).unwrap();
        assert_eq!(
            sketch_quantile_with(&mut c, &data, &ApproxQuantileParams::default(), 0.5)
                .unwrap_err(),
            EngineError::EmptyInput
        );
    }
}
