//! **GK Select** (§V, appendix Fig. 5) — the paper's contribution, run
//! as a fused **two-round** protocol.
//!
//! The paper's appendix describes three rounds (sketch → count →
//! extract). The GK guarantee is stronger than the count round exploits:
//! from the merged sketch alone the driver can derive a *value band*
//! `[lo, hi]` ([`crate::sketch::GkCore::query_rank_bounds`]) that
//! provably contains the exact answer, so counting and candidate
//! extraction fuse into **one** executor scan and one fewer
//! synchronization:
//!
//! 1. **Approximate pivot + band** — per-partition GK sketches, merged
//!    on the driver; the queried quantile becomes the pivot `π` and the
//!    summary's rank intervals at `k ± εn` become the band `[lo, hi]`
//!    with `lo ≤ x₍k₎ ≤ hi`.
//! 2. **Fused count + extract** — `(π, lo, hi)` is TorrentBroadcast;
//!    each executor runs the `band_extract` kernel: one branchless
//!    chunked pass producing the `<π/=π/>π` counts, the five-way band
//!    counts (`<lo`, `=lo`, open band, `=hi`, `>hi`), and the open-band
//!    values themselves. Slices treeReduce `(counts, candidates)`
//!    together; the driver resolves rank `k` **inside the already
//!    extracted band** — the answer is `lo`, `hi`, or the
//!    `(k − |{x<lo}| − |{x=lo}|)`-smallest candidate.
//!
//! Exactness does not rest on the sketch: the driver re-checks
//! `|{x<lo}| ≤ k < |{x≤hi}|` against the *measured* counts before
//! resolving, and the resolution itself is pure counting over a complete
//! extraction. If the band misses the target (broken sketch) or the
//! open band exceeds the candidate budget (adversarially wide bands),
//! the driver falls back to the classic Round-3 `secondPass` +
//! `reduceSlices` path — 3 rounds, still exact.
//!
//! Net accounting on the default path: **2 rounds**, **1 post-sketch
//! data scan** (was 2), no shuffle, no persist, candidate traffic
//! bounded by the ε-band (`|{lo < x < hi}| = O(εn)` — endpoint runs are
//! counted, never shipped, so duplicate-heavy data cannot widen it).
//!
//! Since the engine redesign the protocol lives in crate-internal free
//! functions (`quantile_with` / `select_with_sketch_with`: cluster +
//! backend + params in, typed errors out); [`GkSelectStrategy`] is the
//! stateless plan executor the engine selects via
//! `AlgoChoice::GkSelect`, and the backend-owning [`GkSelect`] struct
//! is a deprecated shim.

use super::approx_quantile::{build_global_sketch, MergeStrategy, SketchVariant};
use super::{drive_plan, run_report, Outcome, QuantileAlgorithm};
use crate::cluster::dataset::Dataset;
use crate::cluster::Cluster;
use crate::engine::{EngineCtx, EngineError, QuantileQuery, QueryOutcome};
use crate::runtime::{BandExtract, KernelBackend, NativeBackend};
use crate::sketch::GkCore;
use crate::{target_rank, Key};

/// Tuning knobs for GK Select.
#[derive(Debug, Clone)]
pub struct GkSelectParams {
    /// Sketch relative error — controls pivot quality and candidate
    /// volume (`|Δk| ≤ εn`); the ablation bench sweeps this.
    pub epsilon: f64,
    /// Which GK variant runs on executors.
    pub variant: SketchVariant,
    /// Driver-side sketch merge (fold = Spark, tree = mSGK).
    pub merge: MergeStrategy,
    /// treeReduce depth override (None → ⌈log₂P⌉).
    pub tree_depth: Option<usize>,
    /// Cap on extracted open-band candidates per partition and per
    /// merged slice; past it the run falls back to the 3-round path.
    /// `None` derives the bound from ε and n — see
    /// [`default_candidate_budget`].
    pub candidate_budget: Option<usize>,
}

impl Default for GkSelectParams {
    fn default() -> Self {
        Self {
            epsilon: 0.01,
            // §Perf L3.4: bulk (radix-sort + direct summary) is ~1.5× the
            // streamed mSGK on the round-1 hot path and keeps the same
            // ε-guarantee; switch back to Modified/Spark to model Spark's
            // streaming executors.
            variant: SketchVariant::Bulk,
            merge: MergeStrategy::Fold,
            tree_depth: None,
            candidate_budget: None,
        }
    }
}

/// Derived candidate budget: the open band `{x : lo < x < hi}` spans at
/// most `|{x < hi}| − |{x ≤ lo}| ≤ 4t` ranks, where `t = ⌊2ε′n⌋` is the
/// merged summary's invariant threshold and `ε′ ≤ 2ε` after pairwise
/// merging (the factor the sketch tests measure). That gives `16εn`;
/// `+64` absorbs small-n rounding. Exceeding this means the sketch is
/// out of contract, and the run falls back rather than flooding the
/// fabric.
pub fn default_candidate_budget(epsilon: f64, n: u64) -> usize {
    (16.0 * epsilon * n as f64).ceil() as usize + 64
}

/// The full GK Select protocol — Round 1 (sketch) plus the fused
/// post-sketch rounds — through an explicit kernel backend. Resets the
/// cluster's run ledger on entry so the report covers exactly this
/// query.
pub(crate) fn quantile_with(
    cluster: &mut Cluster,
    backend: &dyn KernelBackend,
    params: &GkSelectParams,
    data: &Dataset<Key>,
    q: f64,
) -> Result<Outcome, EngineError> {
    if data.is_empty() {
        return Err(EngineError::EmptyInput);
    }
    cluster.reset_run();

    // ---- Round 1: sketch-derived pivot + candidate band ------------
    let sketch = build_global_sketch(cluster, data, params.variant, params.merge, params.epsilon)?;

    // ---- Round 2 (+3 fallback): the fused post-sketch protocol -----
    select_with_sketch_with(cluster, backend, params, data, &sketch, q)
}

/// The post-sketch fused protocol, given an **already-merged** global
/// sketch covering exactly `data`: fused count+extract (one round, one
/// scan), with the classic 3-round extraction as the overflow /
/// out-of-contract fallback.
///
/// Does NOT reset the cluster's run ledger and does NOT build a sketch —
/// [`quantile_with`] is `reset_run` + Round 1 + this; the streaming
/// query path ([`crate::stream::query`]) calls it with the store's
/// *cached* merged sketch, which is how a streamed query costs
/// rounds=1 / data_scans=1 instead of 2/2.
pub(crate) fn select_with_sketch_with(
    cluster: &mut Cluster,
    backend: &dyn KernelBackend,
    params: &GkSelectParams,
    data: &Dataset<Key>,
    sketch: &GkCore,
    q: f64,
) -> Result<Outcome, EngineError> {
    if data.is_empty() {
        return Err(EngineError::EmptyInput);
    }
    let n = data.len();
    if sketch.count != n {
        return Err(EngineError::Execution(format!(
            "sketch covers {} records, dataset holds {n}",
            sketch.count
        )));
    }
    let k = target_rank(n, q);

    let (pivot, lo, hi) = cluster
        .driver(|| {
            let pivot = sketch.query_quantile(q)?;
            // k is 0-based; the summary speaks 1-based ranks
            let (lo, hi) = sketch.query_rank_bounds(k + 1)?;
            Some((pivot, lo, hi))
        })
        .ok_or(EngineError::EmptyInput)?;

    // ---- fused count + band extraction -----------------------------
    cluster.broadcast(&(pivot, lo, hi));
    // the band's width is governed by the sketch that produced it —
    // which for cached (streamed) sketches may be coarser than this
    // engine's ε. Budget against the looser of the two, or a
    // mismatched query engine would overflow on every query and
    // silently pay the fallback round forever.
    let budget_eps = params.epsilon.max(sketch.epsilon);
    let budget = params
        .candidate_budget
        .unwrap_or_else(|| default_candidate_budget(budget_eps, n));
    let pending = cluster.map_partitions(data, |part, _| {
        backend.band_extract(part, pivot, lo, hi, budget)
    })?;
    let mut merged = cluster
        .tree_reduce(pending, params.tree_depth, |a, b| a.merge(b, budget))
        .expect("nonempty dataset");
    debug_assert_eq!(merged.band.total(), n);
    debug_assert_eq!(merged.pivot.total(), n);
    // band-efficiency ledger: candidates that actually reached the
    // driver vs the 16εn+64 bound they were allowed — merge() truncates
    // at the budget, so shipped ≤ budget holds structurally
    cluster.metrics.band_candidates += merged.candidates.len() as u64;
    cluster.metrics.band_budget += budget as u64;

    let (lt, eq) = (merged.pivot.lt, merged.pivot.eq);
    if lt <= k && k < lt + eq {
        // the pivot's own run covers the target — free exit
        return Ok(finish(cluster, n, pivot));
    }
    if let Some(value) = cluster.driver(|| resolve_band(&mut merged, lo, hi, k)) {
        // exact answer out of the extracted band
        return Ok(finish(cluster, n, value));
    }

    // ---- fallback: classic candidate extraction --------------------
    // Reached only on candidate overflow or an out-of-contract
    // sketch; the fused pass's counts still give the exact Δk.
    let delta = pivot_delta(lt, eq, k);
    debug_assert!(delta != 0);
    cluster.broadcast(&delta);
    let slices = cluster.map_partitions(data, |part, _| second_pass(part, pivot, delta))?;
    let final_slice = cluster
        .tree_reduce(slices, params.tree_depth, |a, b| reduce_slices(a, b, delta))
        .expect("nonempty dataset");

    let value = cluster.driver(|| {
        if delta < 0 {
            final_slice.iter().copied().min()
        } else {
            final_slice.iter().copied().max()
        }
    });
    let value = value.ok_or(EngineError::BudgetOverflow {
        fallback_used: true,
    })?;
    Ok(finish(cluster, n, value))
}

fn finish(cluster: &Cluster, n: u64, value: Key) -> Outcome {
    Outcome {
        value,
        report: run_report("GK Select", true, cluster, n),
    }
}

/// The stateless GK Select strategy: `AlgoChoice::GkSelect`'s plan
/// executor. `Multi` plans run the fused multi-band protocol
/// ([`super::multi_select`]) — m quantiles, one scan; everything else
/// goes through the shared single-quantile dispatch.
#[derive(Debug, Clone, Default)]
pub struct GkSelectStrategy {
    pub params: GkSelectParams,
}

impl GkSelectStrategy {
    pub fn new(params: GkSelectParams) -> Self {
        Self { params }
    }
}

impl QuantileAlgorithm for GkSelectStrategy {
    fn name(&self) -> &'static str {
        "GK Select"
    }

    fn exact(&self) -> bool {
        true
    }

    fn execute_plan(
        &self,
        ctx: &mut EngineCtx<'_>,
        query: &QuantileQuery,
    ) -> Result<QueryOutcome, EngineError> {
        let backend = ctx.backend;
        let data = ctx.data;
        if let QuantileQuery::Multi(qs) = query {
            if data.is_empty() {
                return Err(EngineError::EmptyInput);
            }
            query.validate(data.len())?;
            let out =
                super::multi_select::quantiles_with(ctx.cluster, backend, &self.params, data, qs)?;
            return Ok(out.into());
        }
        drive_plan(ctx.cluster, data, query, |cluster, q| {
            quantile_with(cluster, backend, &self.params, data, q)
        })
    }
}

/// The pre-redesign GK Select driver, owning its own kernel backend.
/// Kept as a thin shim for one release — new code builds a
/// [`crate::engine::QuantileEngine`] instead:
///
/// ```
/// use gkselect::prelude::*;
///
/// let mut engine = EngineBuilder::new()
///     .cluster(ClusterConfig::local(2, 4))
///     .algorithm(AlgoChoice::GkSelect)
///     .build()
///     .unwrap();
/// let data = Dataset::from_vec((0..1_000).collect(), 4).unwrap();
/// let out = engine.execute(Source::Dataset(&data), QuantileQuery::Single(0.5)).unwrap();
/// assert_eq!(out.value(), 500);      // exact order statistic, not approximate
/// assert!(out.report.rounds <= 2);   // sketch round + fused count/extract round
/// ```
pub struct GkSelect {
    pub params: GkSelectParams,
    backend: Box<dyn KernelBackend>,
}

impl GkSelect {
    /// Native-backend instance (no artifacts needed).
    #[deprecated(
        since = "0.2.0",
        note = "build a `QuantileEngine` via `EngineBuilder` and call `execute`"
    )]
    pub fn new(params: GkSelectParams) -> Self {
        Self {
            params,
            backend: Box::new(NativeBackend::new()),
        }
    }

    /// Run the fused pass through a specific backend (e.g. the
    /// PJRT-compiled Pallas kernel).
    #[deprecated(
        since = "0.2.0",
        note = "use `EngineBuilder::kernel_backend` / `backend_name` instead"
    )]
    pub fn with_backend(params: GkSelectParams, backend: Box<dyn KernelBackend>) -> Self {
        Self { params, backend }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Active SIMD lane width of the backend's fused band scan (1 =
    /// scalar).
    pub fn simd_lane_width(&self) -> usize {
        self.backend.simd_lane_width()
    }

    /// One exact quantile — the pre-redesign entry point. Stamps this
    /// shim's own backend lane width to preserve the old report
    /// contract (engine outcomes are stamped centrally instead).
    #[deprecated(
        since = "0.2.0",
        note = "use `QuantileEngine::execute(Source::Dataset(..), QuantileQuery::Single(q))`"
    )]
    pub fn quantile(
        &mut self,
        cluster: &mut Cluster,
        data: &Dataset<Key>,
        q: f64,
    ) -> anyhow::Result<Outcome> {
        let mut out = quantile_with(cluster, self.backend.as_ref(), &self.params, data, q)?;
        out.report.simd_lane_width = self.backend.simd_lane_width() as u64;
        Ok(out)
    }

    /// The post-sketch fused protocol against a pre-merged sketch — the
    /// pre-redesign streaming entry point.
    #[deprecated(
        since = "0.2.0",
        note = "use `QuantileEngine::execute(Source::Stream(..), ..)` — the engine owns the store"
    )]
    pub fn select_with_sketch(
        &mut self,
        cluster: &mut Cluster,
        data: &Dataset<Key>,
        sketch: &GkCore,
        q: f64,
    ) -> anyhow::Result<Outcome> {
        let mut out =
            select_with_sketch_with(cluster, self.backend.as_ref(), &self.params, data, sketch, q)?;
        out.report.simd_lane_width = self.backend.simd_lane_width() as u64;
        Ok(out)
    }
}

/// Resolve rank `k` (0-based) from a completed fused pass, or `None`
/// when the pass cannot answer (candidate overflow, or measured counts
/// contradict the sketch band). Takes `&mut` so the in-band select runs
/// on the candidate buffer in place — no driver-side copy of an
/// O(εn)-sized vector.
pub(crate) fn resolve_band(merged: &mut BandExtract, lo: Key, hi: Key, k: u64) -> Option<Key> {
    let b = merged.band;
    if k < b.below || k >= b.below + b.eq_lo + b.inner + b.eq_hi {
        return None; // band missed the target: sketch out of contract
    }
    let r = k - b.below;
    if r < b.eq_lo {
        return Some(lo);
    }
    if r < b.eq_lo + b.inner {
        if merged.overflow {
            return None; // answer is a candidate we didn't keep
        }
        debug_assert_eq!(merged.candidates.len() as u64, b.inner);
        let idx = (r - b.eq_lo) as usize;
        let (_, &mut v, _) = merged.candidates.select_nth_unstable(idx);
        return Some(v);
    }
    Some(hi)
}

/// `secondPass` (fallback round only): extract the `|Δk|` rank-closest
/// values on the side `Δk` points at.
///
/// The paper's appendix materializes the whole partition (`it.toArray`)
/// and Dutch-partitions it. Only one side of the pivot can ever contain
/// candidates, so we filter that side directly (one branch-predictable
/// pass, ~half the copies, no swap traffic) and select with std's
/// introselect — semantics identical, executor memory drops from
/// `O(n_i)` to `O(side)` (§Perf iteration L3.1/L3.2).
pub(crate) fn second_pass(part: &[Key], pivot: Key, delta: i64) -> Vec<Key> {
    debug_assert!(delta != 0);
    if delta < 0 {
        // target left of π: the |Δk| largest values below π
        let mut side: Vec<Key> = part.iter().copied().filter(|&v| v < pivot).collect();
        let l = side.len();
        let m = (-delta) as usize;
        let tgt = l.saturating_sub(m);
        if tgt > 0 && tgt < l {
            side.select_nth_unstable(tgt);
        }
        side[tgt..].to_vec()
    } else {
        // target right of π: the Δk smallest values above π
        let mut side: Vec<Key> = part.iter().copied().filter(|&v| v > pivot).collect();
        let take = (delta as usize).min(side.len());
        if take > 0 && take < side.len() {
            side.select_nth_unstable(take - 1);
        }
        side.truncate(take);
        side
    }
}

/// `reduceSlices` (appendix): merge two candidate slices, keeping only
/// the `|Δk|` values that can still be the answer.
pub(crate) fn reduce_slices(a: Vec<Key>, b: Vec<Key>, delta: i64) -> Vec<Key> {
    let mut c = a;
    c.extend_from_slice(&b);
    let m = delta.unsigned_abs() as usize;
    if c.len() <= m {
        return c;
    }
    if delta < 0 {
        // keep the m largest
        let tgt = c.len() - m;
        c.select_nth_unstable(tgt);
        c.drain(..tgt);
        c
    } else {
        // keep the m smallest
        c.select_nth_unstable(m - 1);
        c.truncate(m);
        c
    }
}

/// Signed rank distance from the pivot's run to the target (the classic
/// Round-2 → Round-3 handoff; shared by the fallback and MultiSelect).
pub(crate) fn pivot_delta(lt: u64, eq: u64, k: u64) -> i64 {
    // i64: a pivot below the whole dataset would make lt+eq-1 underflow
    // in u64 — the sketch always returns a data value so eq ≥ 1 in
    // practice, but stay defensive
    let approx_rank = if lt + eq <= k {
        lt as i64 + eq as i64 - 1
    } else {
        lt as i64
    };
    k as i64 - approx_rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::oracle_quantile;
    use crate::cluster::netmodel::CONTAINER_OVERHEAD;
    use crate::cluster::ClusterConfig;
    use crate::data::{DataGenerator, Distribution};

    fn run(
        cluster: &mut Cluster,
        data: &Dataset<Key>,
        q: f64,
        params: &GkSelectParams,
    ) -> Outcome {
        let backend = NativeBackend::new();
        quantile_with(cluster, &backend, params, data, q).unwrap()
    }

    fn check_with(
        dist: Distribution,
        n: u64,
        q: f64,
        eps: f64,
        budget: Option<usize>,
    ) -> Outcome {
        let mut c = Cluster::new(ClusterConfig::local(2, 8));
        let data = dist.generator(33).generate(&mut c, n);
        let truth = oracle_quantile(&data, q).unwrap();
        let params = GkSelectParams {
            epsilon: eps,
            candidate_budget: budget,
            ..Default::default()
        };
        let out = run(&mut c, &data, q, &params);
        assert_eq!(
            out.value, truth,
            "{}: exactness violated at q={q} n={n} eps={eps}",
            dist.label()
        );
        out
    }

    fn check(dist: Distribution, n: u64, q: f64, eps: f64) -> Outcome {
        check_with(dist, n, q, eps, None)
    }

    #[test]
    fn exact_median_uniform_two_rounds() {
        let out = check(Distribution::Uniform, 100_000, 0.5, 0.01);
        assert!(out.report.rounds <= 2, "rounds = {}", out.report.rounds);
        // sketch scan + fused scan, nothing else
        assert_eq!(out.report.data_scans, 2);
        assert_eq!(out.report.shuffles, 0);
        assert_eq!(out.report.persists, 0);
    }

    #[test]
    fn exact_p99_all_distributions() {
        for dist in [
            Distribution::Uniform,
            Distribution::Zipf,
            Distribution::Bimodal,
            Distribution::Sorted,
        ] {
            check(dist, 50_000, 0.99, 0.01);
            check(dist, 50_000, 0.5, 0.01);
        }
    }

    /// The acceptance contract: default-ε runs finish in ≤ 2 rounds with
    /// exactly 1 post-sketch scan on every evaluated distribution.
    #[test]
    fn two_rounds_one_scan_all_distributions() {
        for dist in [
            Distribution::Uniform,
            Distribution::Zipf,
            Distribution::Bimodal,
            Distribution::Sorted,
        ] {
            for q in [0.25, 0.5, 0.75, 0.99] {
                let out = check(dist, 60_000, q, 0.01);
                assert!(
                    out.report.rounds <= 2,
                    "{} q={q}: rounds = {}",
                    dist.label(),
                    out.report.rounds
                );
                assert_eq!(
                    out.report.data_scans,
                    2,
                    "{} q={q}: post-sketch scans must be exactly 1",
                    dist.label()
                );
                assert_eq!(out.report.shuffles, 0);
                assert_eq!(out.report.persists, 0);
                assert!(out.report.exact);
            }
        }
    }

    #[test]
    fn exact_extreme_quantiles() {
        check(Distribution::Uniform, 20_000, 0.0, 0.02);
        check(Distribution::Uniform, 20_000, 1.0, 0.02);
        check(Distribution::Uniform, 20_000, 0.001, 0.02);
        check(Distribution::Uniform, 20_000, 0.999, 0.02);
    }

    #[test]
    fn exact_with_coarse_epsilon() {
        // big eps → wide band → stresses extraction and the budget
        check(Distribution::Uniform, 50_000, 0.5, 0.2);
        check(Distribution::Zipf, 50_000, 0.5, 0.2);
    }

    #[test]
    fn duplicate_heavy_hits_eq_run() {
        // zipf s=2.5: one value dominates; median almost surely in an eq
        // run, and endpoint runs must be counted rather than extracted
        let out = check(Distribution::Zipf, 30_000, 0.5, 0.01);
        assert!(out.report.rounds <= 2);
    }

    #[test]
    fn two_rounds_no_shuffle_no_persist() {
        let out = check(Distribution::Uniform, 60_000, 0.75, 0.01);
        assert_eq!(out.report.rounds, 2);
        assert_eq!(out.report.stage_boundaries, 2);
        assert_eq!(out.report.data_scans, 2);
        assert_eq!(out.report.shuffles, 0);
        assert_eq!(out.report.persists, 0);
        assert!(out.report.exact);
    }

    #[test]
    fn zero_budget_falls_back_and_stays_exact() {
        // budget 0 forces candidate overflow whenever the open band is
        // nonempty → the classic 3-round path must still be exact
        let out = check_with(Distribution::Uniform, 60_000, 0.75, 0.01, Some(0));
        assert!(out.report.rounds <= 3);
        assert!(out.report.data_scans <= 3);
        assert!(out.report.exact);
    }

    #[test]
    fn candidate_volume_bounded_by_epsilon() {
        let mut c = Cluster::new(ClusterConfig::local(2, 8));
        let n = 100_000u64;
        let eps = 0.01;
        let data = Distribution::Uniform.generator(5).generate(&mut c, n);
        let params = GkSelectParams {
            epsilon: eps,
            ..Default::default()
        };
        let out = run(&mut c, &data, 0.25, &params);

        // Derived traffic bound, no magic numbers: per fused-pass message
        // the payload is the 8 counters + flag + ≤ budget candidate keys
        // (the budget caps every slice, partition-level and merged), plus
        // container framing; tree_reduce sends ≤ P-1 such messages and
        // one final partial to the driver, round 1 collects P sketch
        // summaries, and broadcasts fan (pivot, lo, hi) + Δk to E
        // executors. Bound every term by its worst case.
        let partitions = c.cfg.partitions as u64;
        let executors = c.cfg.executors as u64;
        let key_bytes = std::mem::size_of::<Key>() as u64;
        let budget = default_candidate_budget(eps, n) as u64;
        let per_msg = 2 * CONTAINER_OVERHEAD + 8 * 8 + 1 + budget * key_bytes;
        let fused_traffic = partitions * per_msg; // ≤ P-1 merges + driver root
        let sketch_summaries = out.report.bytes_to_driver; // measured round-1 collect
        let broadcasts = executors * 2 * (3 * key_bytes + CONTAINER_OVERHEAD);
        let bound = fused_traffic + sketch_summaries + broadcasts;
        assert!(
            out.report.network_volume_bytes <= bound,
            "fused candidate traffic {} vs derived bound {bound}",
            out.report.network_volume_bytes
        );
        // and the dominant term really is ε-scaled: the budget itself
        assert!(budget < 2 * (16.0 * eps * n as f64) as u64);
    }

    #[test]
    fn tiny_inputs() {
        for n in [1u64, 2, 3, 7, 8, 9] {
            let mut c = Cluster::new(ClusterConfig::local(2, 4));
            let data = Distribution::Uniform.generator(n).generate(&mut c, n.max(1));
            let truth = oracle_quantile(&data, 0.5).unwrap();
            let out = run(&mut c, &data, 0.5, &GkSelectParams::default());
            assert_eq!(out.value, truth, "n={n}");
        }
    }

    #[test]
    fn strategy_executes_all_plan_shapes() {
        let mut c = Cluster::new(ClusterConfig::local(2, 4));
        let data = Dataset::from_vec((0..1_000).collect(), 4).unwrap();
        let strategy = GkSelectStrategy::default();
        let backend = NativeBackend::new();

        let mut ctx = EngineCtx {
            cluster: &mut c,
            backend: &backend,
            data: &data,
        };
        let single = strategy
            .execute_plan(&mut ctx, &QuantileQuery::Single(0.5))
            .unwrap();
        assert_eq!(single.value(), 500);

        let rank = strategy
            .execute_plan(&mut ctx, &QuantileQuery::Rank(500))
            .unwrap();
        assert_eq!(rank.value(), 500);

        let multi = strategy
            .execute_plan(&mut ctx, &QuantileQuery::Multi(vec![0.1, 0.9]))
            .unwrap();
        assert_eq!(multi.values, vec![100, 900]);
        // the batched path shares one fused scan — not one per quantile
        assert_eq!(multi.report.data_scans, 2);

        let sk = strategy
            .execute_plan(&mut ctx, &QuantileQuery::Sketched { q: 0.5, eps: 0.1 })
            .unwrap();
        assert!(!sk.report.exact);
    }

    #[test]
    fn resolve_band_arithmetic() {
        let backend = NativeBackend::new();
        // data: 2×10, 3×20, 5×30, 4×40, 6×50  (n = 20)
        let mut data: Vec<Key> = Vec::new();
        for (v, c) in [(10, 2), (20, 3), (30, 5), (40, 4), (50, 6)] {
            data.extend(std::iter::repeat(v as Key).take(c));
        }
        let mut ext = backend.band_extract(&data, 30, 20, 40, 100);
        // sorted ranks: 10:0-1, 20:2-4, 30:5-9, 40:10-13, 50:14-19
        assert_eq!(resolve_band(&mut ext, 20, 40, 2), Some(20)); // eq_lo run
        assert_eq!(resolve_band(&mut ext, 20, 40, 7), Some(30)); // inner
        assert_eq!(resolve_band(&mut ext, 20, 40, 12), Some(40)); // eq_hi run
        assert_eq!(resolve_band(&mut ext, 20, 40, 1), None); // below band
        assert_eq!(resolve_band(&mut ext, 20, 40, 15), None); // above band
        // overflow with an inner target is unresolvable...
        let mut of = backend.band_extract(&data, 30, 20, 40, 0);
        assert!(of.overflow);
        assert_eq!(resolve_band(&mut of, 20, 40, 7), None);
        // ...but endpoint targets still resolve from counts alone
        assert_eq!(resolve_band(&mut of, 20, 40, 2), Some(20));
        assert_eq!(resolve_band(&mut of, 20, 40, 12), Some(40));
    }

    #[test]
    fn second_pass_left_and_right() {
        // part = 0..10, pivot 5
        let part: Vec<Key> = (0..10).collect();
        // delta = -2: two largest below 5 → {3, 4}
        let mut s = second_pass(&part, 5, -2);
        s.sort_unstable();
        assert_eq!(s, vec![3, 4]);
        // delta = 3: three smallest above 5 → {6, 7, 8}
        let mut s = second_pass(&part, 5, 3);
        s.sort_unstable();
        assert_eq!(s, vec![6, 7, 8]);
    }

    #[test]
    fn second_pass_clamps_to_available() {
        let part: Vec<Key> = vec![1, 2, 9];
        // delta = 5 but only one element above pivot 8
        let s = second_pass(&part, 8, 5);
        assert_eq!(s, vec![9]);
        // delta = -5 but only two below pivot 8
        let mut s = second_pass(&part, 8, -5);
        s.sort_unstable();
        assert_eq!(s, vec![1, 2]);
    }

    #[test]
    fn reduce_slices_keeps_rank_closest() {
        // delta > 0: keep smallest
        let r = reduce_slices(vec![10, 4], vec![7, 2, 8], 2);
        let mut r2 = r.clone();
        r2.sort_unstable();
        assert_eq!(r2, vec![2, 4]);
        // delta < 0: keep largest
        let r = reduce_slices(vec![10, 4], vec![7, 2, 8], -2);
        let mut r2 = r.clone();
        r2.sort_unstable();
        assert_eq!(r2, vec![8, 10]);
        // under-full: keep all
        assert_eq!(reduce_slices(vec![1], vec![2], 5).len(), 2);
    }
}
